"""Sharding rules: param / batch / cache PartitionSpecs per architecture.

Baseline layout (all 40 roofline cells):
  - tensor parallel on "model": attention heads, FFN hidden, MoE experts
    (when E % tp == 0, else the per-expert FFN hidden), vocab/embedding;
  - fully-sharded (FSDP-style) parameter + optimizer-state storage: the
    d_model axis additionally shards over ("pod","data") — this is what
    lets 35B/235B/398B fp32 masters + moments fit 16 GiB chips;
  - batch over ("pod","data");
  - decode caches: batch over data axes when divisible, cache length over
    "model" (sequence-parallel attention; XLA inserts the softmax psums).

Everything is *rules on leaf paths + shapes*, so the same code shards any
family.  The hillclimbing pass (EXPERIMENTS.md §Perf) edits these rules.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.common import ArchConfig
from .mesh import data_axes


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    """Knobs the perf pass iterates on."""
    fsdp: bool = True              # shard d_model of params over data axes
    shard_vocab: bool = True
    cache_seq_on_model: bool = True
    batch_axes: tuple = ("pod", "data")


def _divisible(n: int, size: int) -> bool:
    return size > 0 and n % size == 0


def _axis_size(mesh, name) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _data_spec(mesh, policy, dim: int) -> Any:
    axes = tuple(a for a in policy.batch_axes if a in mesh.axis_names)
    if not axes:
        return None
    total = 1
    for a in axes:
        total *= mesh.shape[a]
    return axes if _divisible(dim, total) else None


def param_spec(cfg: ArchConfig, mesh, path: str, shape: tuple,
               policy: ShardingPolicy = ShardingPolicy()) -> P:
    """PartitionSpec for one parameter leaf, identified by its tree path."""
    tp = _axis_size(mesh, "model")
    dsz = 1
    for a in policy.batch_axes:
        dsz *= _axis_size(mesh, a)
    dax = tuple(a for a in policy.batch_axes if a in mesh.axis_names) or None
    name = path.split("/")[-1]
    nd = len(shape)

    def fsdp_axis(candidates):
        """Pick one remaining axis to shard over the data axes (FSDP)."""
        if not policy.fsdp or dax is None:
            return None
        for ax in candidates:
            if shape[ax] and _divisible(shape[ax], dsz):
                return ax
        return None

    spec = [None] * nd

    # --- embeddings / heads -------------------------------------------------
    if name in ("embed", "tok_embed", "dec_pos"):
        # vocab on "model" only: FSDP-sharding the d axis as well makes the
        # token gather unpartitionable (XLA "involuntary full remat" — the
        # whole (B, S, d) activation replicates per device).  Measured in
        # EXPERIMENTS.md §Perf iteration 0.
        if policy.shard_vocab and _divisible(shape[0], tp):
            spec[0] = "model"
        return P(*spec)
    if name == "lm_head":
        if policy.shard_vocab and _divisible(shape[-1], tp):
            spec[-1] = "model"
        ax = fsdp_axis([0])
        if ax is not None:
            spec[ax] = dax
        return P(*spec)

    # --- MoE expert tensors (leading L, then E) ------------------------------
    if "moe" in path and name in ("w_gate", "w_up", "w_down"):
        e_ax = nd - 3
        if _divisible(shape[e_ax], tp):
            spec[e_ax] = "model"           # expert parallelism
            ax = fsdp_axis([nd - 2, nd - 1])
            if ax is not None and spec[ax] is None:
                spec[ax] = dax
        else:
            # per-expert tensor parallelism (e.g. granite's 40 experts on a
            # 16-wide axis).  NO FSDP here: data-sharding d conflicts with
            # the batch-sharded dispatch buffer and XLA all-gathers the
            # whole (B, E*C, d) buffer (60 GiB/device measured on granite
            # prefill_32k — EXPERIMENTS.md §Perf iteration 0).
            hid = nd - 1 if name != "w_down" else nd - 2
            if _divisible(shape[hid], tp):
                spec[hid] = "model"
        return P(*spec)
    if name == "router":
        if _divisible(shape[-1], tp):
            spec[-1] = "model"
        return P(*spec)

    # --- attention / dense FFN / projections (stacked: axis0 = L or P) ------
    if nd >= 2 and name in ("wq", "wk", "wv", "wg", "wr", "wk2", "wo",
                            "w_gate", "w_up", "w_down", "ck", "cv", "cr",
                            "in_proj", "out_proj", "x_proj", "dt_proj",
                            "x_wq", "x_wk", "x_wv", "x_wo", "conv_w"):
        out_first = name in ("wo", "w_down", "cv", "out_proj", "x_wo")
        big = nd - 2 if out_first else nd - 1      # the "parallel" axis
        other = nd - 1 if out_first else nd - 2
        if _divisible(shape[big], tp):
            spec[big] = "model"
        elif _divisible(shape[other], tp):
            spec[other] = "model"
            other = big
        ax = fsdp_axis([other])
        if ax is not None and spec[ax] is None:
            spec[ax] = dax
        return P(*spec)

    # --- everything else (norms, biases, decay vectors, A_log, ...) ---------
    return P(*spec)


def param_sharding_tree(cfg: ArchConfig, mesh, param_shapes,
                        policy: ShardingPolicy = ShardingPolicy()):
    """param_shapes: pytree of ShapeDtypeStructs (jax.eval_shape(init))."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(param_shapes)
    specs = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        specs.append(NamedSharding(
            mesh, param_spec(cfg, mesh, key, leaf.shape, policy)))
    return jax.tree_util.tree_unflatten(treedef, specs)


def opt_sharding_tree(mesh, optimizer_name: str, params_sharding,
                      params_shapes):
    """Optimizer-state shardings.  Moment tensors (AdamW m/v, momentum m)
    inherit the parameter's spec — essential for the fp32-moment memory to
    shard like FSDP params.  Adafactor's factored stats drop the factored
    axis from the parameter spec; scalars replicate."""
    rep = NamedSharding(mesh, P())
    if optimizer_name == "sgd":
        return {}
    if optimizer_name == "momentum":
        return {"m": params_sharding}
    if optimizer_name == "adamw":
        return {"m": params_sharding, "v": params_sharding, "t": rep}
    if optimizer_name == "adafactor":
        def leaf(sh, shape_sds):
            nd = len(shape_sds.shape)
            spec = list(sh.spec) + [None] * (nd - len(sh.spec))
            if nd >= 2:
                return {"vr": NamedSharding(mesh, P(*spec[:-1])),
                        "vc": NamedSharding(mesh, P(*(spec[:-2] + spec[-1:])))}
            return {"v": NamedSharding(mesh, P(*spec))}
        f = jax.tree.map(leaf, params_sharding, params_shapes,
                         is_leaf=lambda x: isinstance(x, NamedSharding))
        return {"f": f, "t": rep}
    raise ValueError(optimizer_name)


def batch_sharding(cfg: ArchConfig, mesh, batch_shapes,
                   policy: ShardingPolicy = ShardingPolicy()):
    """Shard every batch array's leading (batch) dim over the data axes."""
    def spec_for(s):
        nd = len(s.shape)
        bspec = _data_spec(mesh, policy, s.shape[0])
        return NamedSharding(mesh, P(bspec, *([None] * (nd - 1))))
    return jax.tree.map(spec_for, batch_shapes)


def cache_sharding(cfg: ArchConfig, mesh, cache_shapes,
                   policy: ShardingPolicy = ShardingPolicy()):
    """Decode caches: (L/P, B, T, kv, hd) KV tensors -> batch over data,
    T over "model" (sequence-parallel); SSM/conv states -> batch over data,
    feature dim over "model" when divisible."""
    tp = _axis_size(mesh, "model")

    def spec_for(s):
        sh = s.shape
        nd = len(sh)
        spec = [None] * nd
        if nd >= 2:
            spec[1] = _data_spec(mesh, policy, sh[1])    # batch dim
        if nd == 5:                                      # (L, B, T, kv, hd)
            if policy.cache_seq_on_model and _divisible(sh[2], tp):
                spec[2] = "model"
            elif _divisible(sh[3], tp):
                spec[3] = "model"
        elif nd == 4:                                    # (L, B, X, Y) states
            if _divisible(sh[3], tp):
                spec[3] = "model"
            elif _divisible(sh[2], tp):
                spec[2] = "model"
        elif nd == 3 and _divisible(sh[2], tp):
            spec[2] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(spec_for, cache_shapes)


def replicated(mesh):
    return NamedSharding(mesh, P())
