"""Batched serving driver: continuous-batching decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
        --requests 8 --prompt-len 16 --gen 24

Implements a small production-shaped server core: a request queue, batched
prefill (padded to the batch), then a decode loop that retires finished
sequences and admits new ones into freed KV-cache slots (continuous
batching).  Greedy sampling; the decode-shape dry-run cells lower exactly
this decode_step at 32k/500k cache lengths.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import get_model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (P,) int32
    max_new: int
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


class BatchedServer:
    def __init__(self, arch: str, *, reduced: bool = True, batch: int = 4,
                 cache_len: int = 128, seed: int = 0):
        self.cfg = get_config(arch, reduced=reduced)
        self.api = get_model(self.cfg)
        self.batch = batch
        self.cache_len = cache_len
        rng = jax.random.PRNGKey(seed)
        self.params = self.api.init(rng)
        self.decode = jax.jit(self.api.decode)
        self.queue: list = []
        self.slots: list = [None] * batch

    def submit(self, req: Request):
        self.queue.append(req)

    def _prefill_one(self, req: Request):
        """Prefill a single request into a fresh single-row cache."""
        batch = {"tokens": jnp.asarray(req.prompt[None, :])}
        if self.cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros(
                (1, self.cfg.patch_tokens, self.cfg.d_model),
                self.cfg.compute_dtype)
        if self.cfg.family == "audio":
            batch["frames"] = jnp.zeros(
                (1, self.cfg.encoder_frames, self.cfg.d_model),
                self.cfg.compute_dtype)
        logits, cache = self.api.prefill(self.params, batch, self.cache_len)
        tok = int(jnp.argmax(logits[0, -1]))
        return tok, cache, len(req.prompt)

    def run(self, *, max_ticks: int = 1000) -> dict:
        """Continuous batching: admit from queue, decode, retire."""
        stats = {"ticks": 0, "completed": [], "tokens": 0}
        t0 = time.time()
        for _ in range(max_ticks):
            # admit
            for i in range(self.batch):
                if self.slots[i] is None and self.queue:
                    req = self.queue.pop(0)
                    tok, cache, pos = self._prefill_one(req)
                    req.generated.append(tok)
                    self.slots[i] = {"req": req, "cache": cache, "pos": pos,
                                     "last": tok}
            live = [s for s in self.slots if s is not None]
            if not live:
                break
            # decode each live slot (row-batched per slot: caches are per
            # slot so heterogeneous positions are exact)
            for s in live:
                logits, s["cache"] = self.decode(
                    self.params, s["cache"],
                    jnp.asarray([[s["last"]]], jnp.int32),
                    jnp.int32(s["pos"]))
                s["last"] = int(jnp.argmax(logits[0, -1]))
                s["pos"] += 1
                s["req"].generated.append(s["last"])
                stats["tokens"] += 1
            # retire
            for i, s in enumerate(self.slots):
                if s is None:
                    continue
                req = s["req"]
                if (len(req.generated) >= req.max_new
                        or s["pos"] >= self.cache_len - 1):
                    req.done = True
                    stats["completed"].append(req)
                    self.slots[i] = None
            stats["ticks"] += 1
        stats["seconds"] = time.time() - t0
        stats["tok_per_s"] = stats["tokens"] / max(stats["seconds"], 1e-9)
        return stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--cache-len", type=int, default=128)
    args = ap.parse_args()
    srv = BatchedServer(args.arch, reduced=args.reduced, batch=args.batch,
                        cache_len=args.cache_len)
    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        srv.submit(Request(rid, rng.integers(
            0, srv.cfg.vocab, size=args.prompt_len).astype(np.int32),
            max_new=args.gen))
    stats = srv.run()
    print(f"served {len(stats['completed'])} requests, "
          f"{stats['tokens']} tokens in {stats['seconds']:.1f}s "
          f"({stats['tok_per_s']:.1f} tok/s)")


if __name__ == "__main__":
    main()
