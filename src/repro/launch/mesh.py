"""Production meshes.  A FUNCTION, not a module-level constant — importing
this module never touches jax device state (the dry-run sets the fake
device count before any jax initialization)."""

from __future__ import annotations

from .compat import AxisType, make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16 x 16 = 256 chips ("data", "model").
    Multi-pod: 2 x 16 x 16 = 512 chips ("pod", "data", "model") — the "pod"
    axis carries the cross-pod (DCN-class) gradient reduction."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes,
                     axis_types=(AxisType.Auto,) * len(axes))


def make_pipeline_mesh(*, multi_pod: bool = False, num_stages: int = 4):
    """Mesh variant for the paper's pipelined train_step: the model axis is
    factored into ("stage", "model").  16 = num_stages * tp."""
    assert 16 % num_stages == 0
    tp = 16 // num_stages
    if multi_pod:
        shape, axes = (2, 16, num_stages, tp), ("pod", "data", "stage",
                                                "model")
    else:
        shape, axes = (16, num_stages, tp), ("data", "stage", "model")
    return make_mesh(shape, axes,
                     axis_types=(AxisType.Auto,) * len(axes))


def data_axes(mesh) -> tuple:
    """The batch-sharding axes for this mesh ('pod' folds into data)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def mesh_tag(mesh) -> str:
    return "x".join(str(mesh.shape[a]) for a in mesh.axis_names)
