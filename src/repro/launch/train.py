"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --reduced \
        --steps 50 --batch 32 --seq 128 --ckpt /tmp/ckpt

Runs the real loop: synthetic LM data -> micro-batched train_step (Q from
the planner or --microbatches) -> optimizer -> periodic async checkpoints
-> restart-from-latest on relaunch.  On CPU use --reduced; the full configs
are exercised by the dry-run.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointStore
from repro.configs import get_config
from repro.data import token_lm_batches
from repro.launch.steps import make_train_step
from repro.models import get_model
from repro.optim import get_optimizer


def train(arch: str, *, reduced: bool = True, steps: int = 50,
          batch: int = 32, seq: int = 128, microbatches: int = 4,
          optimizer: str = "adamw", lr: float = 1e-3,
          ckpt_dir: str | None = None, ckpt_every: int = 20,
          log_every: int = 10, seed: int = 0) -> list:
    cfg = get_config(arch, reduced=reduced)
    api = get_model(cfg)
    opt = get_optimizer(optimizer, lr=lr)
    rng = jax.random.PRNGKey(seed)

    params = api.init(rng)
    opt_state = opt.init(params)
    step0 = 0
    store = CheckpointStore(ckpt_dir) if ckpt_dir else None
    if store is not None:
        restored, meta = store.restore_latest((params, opt_state))
        if restored is not None:
            params, opt_state = restored
            step0 = meta["step"] + 1
            print(f"restored checkpoint at step {meta['step']}")

    step_fn = jax.jit(make_train_step(cfg, opt, microbatches))
    data = token_lm_batches(batch=batch, seq_len=seq, vocab=cfg.vocab,
                            seed=seed)
    losses = []
    t0 = time.time()
    for step in range(step0, steps):
        b = next(data)
        extra = {}
        if cfg.family == "vlm":
            extra["patch_embeds"] = np.zeros(
                (batch, cfg.patch_tokens, cfg.d_model), np.float32)
        if cfg.family == "audio":
            extra["frames"] = np.random.default_rng(step).normal(
                0, 1, (batch, cfg.encoder_frames, cfg.d_model)
            ).astype(np.float32)
        batch_dev = {k: jnp.asarray(v) for k, v in {**b, **extra}.items()}
        params, opt_state, loss = step_fn(params, opt_state, batch_dev)
        losses.append(float(loss))
        if step % log_every == 0:
            rate = (step - step0 + 1) / (time.time() - t0)
            print(f"step {step:5d}  loss {float(loss):.4f}  "
                  f"{rate:.2f} steps/s", flush=True)
        if store is not None and step % ckpt_every == 0 and step > step0:
            store.save(step, (params, opt_state), blocking=False)
    if store is not None:
        store.save(steps - 1, (params, opt_state), blocking=True)
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()
    losses = train(args.arch, reduced=args.reduced, steps=args.steps,
                   batch=args.batch, seq=args.seq,
                   microbatches=args.microbatches, optimizer=args.optimizer,
                   lr=args.lr, ckpt_dir=args.ckpt)
    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f})")


if __name__ == "__main__":
    main()
