"""jax version shims: the jax>=0.6 API surface on the pinned 0.4.x wheel.

The repo was written against four jax>=0.6 APIs that do not exist on the
toolchain's jax 0.4.37 (the ISSUE 3 root-caused seed debt):

  * ``jax.sharding.AxisType`` — explicit-sharding mesh axis types.  0.4.x
    meshes are implicitly Auto, so the shim is a plain enum accepted (and
    dropped) by :func:`make_mesh`.
  * ``jax.make_mesh(axis_types=...)`` — :func:`make_mesh` forwards the
    kwarg when the installed jax takes it and drops it otherwise.
  * ``jax.set_mesh(mesh)`` — the 0.4.x idiom is entering the mesh itself
    (``with mesh:``); :func:`set_mesh` returns a context manager either way.
  * ``jax.shard_map(..., axis_names=..., check_vma=...)`` — 0.4.x ships
    ``jax.experimental.shard_map.shard_map`` with the complementary
    ``auto=``/``check_rep=`` spelling; :func:`shard_map` translates.
  * flat-dict ``Compiled.cost_analysis()`` — 0.4.x returns a per-partition
    LIST of dicts; :func:`cost_analysis` always returns the flat dict.

Every shim resolves to the native API when it exists, so this module is a
no-op on jax>=0.6 and the call sites (``launch.mesh``, ``launch.dryrun``,
``pipeline.spmd``, ``tests/test_hlo.py``, ``tests/test_spmd.py``) stay
version-agnostic.  This module must not import anything from the rest of
``repro.launch`` (it is imported by ``launch.mesh`` during package init).
"""

from __future__ import annotations

import enum
import inspect

import jax

__all__ = ["AxisType", "make_mesh", "set_mesh", "shard_map",
           "cost_analysis", "PARTIAL_AUTO_SHARD_MAP"]

#: True when the installed jax supports *partial-auto* shard_map regions
#: (manual over a subset of mesh axes).  The 0.4.x experimental shard_map
#: accepts ``auto=...`` but its CPU SPMD lowering cannot partition
#: ``axis_index``/``ppermute`` inside such a region (XLA: "PartitionId
#: instruction is not supported for SPMD partitioning"); callers that can
#: express their region fully manually should do so when this is False
#: (see ``repro.pipeline.spmd``).
PARTIAL_AUTO_SHARD_MAP = hasattr(jax, "shard_map")


# -- AxisType ---------------------------------------------------------------

if hasattr(jax.sharding, "AxisType"):
    AxisType = jax.sharding.AxisType
else:
    class AxisType(enum.Enum):
        """Stand-in for ``jax.sharding.AxisType`` (jax>=0.6).  0.4.x meshes
        have no axis types (every axis behaves like Auto)."""
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


_MAKE_MESH_TAKES_AXIS_TYPES = (
    "axis_types" in inspect.signature(jax.make_mesh).parameters)


def make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None):
    """``jax.make_mesh`` accepting ``axis_types`` on every jax version.

    On 0.4.x the kwarg is dropped: those meshes are implicitly Auto, which
    is exactly what every call site in this repo requests.
    """
    kw = {}
    if devices is not None:
        kw["devices"] = devices
    if _MAKE_MESH_TAKES_AXIS_TYPES and axis_types is not None:
        kw["axis_types"] = axis_types
    return jax.make_mesh(axis_shapes, axis_names, **kw)


# -- set_mesh ---------------------------------------------------------------

if hasattr(jax, "set_mesh"):
    set_mesh = jax.set_mesh
else:
    def set_mesh(mesh):
        """``with set_mesh(mesh):`` — on 0.4.x a ``Mesh`` is itself the
        context manager that installs the global physical mesh."""
        return mesh


# -- shard_map --------------------------------------------------------------

if hasattr(jax, "shard_map"):
    def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
                  check_vma=None):
        kw = {}
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        if check_vma is not None:
            kw["check_vma"] = check_vma
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
else:
    from jax.experimental.shard_map import shard_map as _shard_map_04

    def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
                  check_vma=None):
        """jax>=0.6 ``jax.shard_map`` surface on the 0.4.x experimental
        implementation: ``axis_names`` (the *manual* axes) becomes the
        complementary ``auto`` frozenset, ``check_vma`` maps to the old
        ``check_rep`` flag."""
        kw = {}
        if axis_names is not None:
            kw["auto"] = frozenset(mesh.axis_names) - set(axis_names)
        if check_vma is not None:
            kw["check_rep"] = check_vma
        return _shard_map_04(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)


# -- cost_analysis ----------------------------------------------------------

def cost_analysis(compiled) -> dict:
    """Flat-dict ``Compiled.cost_analysis()`` on every jax version.

    jax 0.4.x returns a per-partition list of dicts (one per SPMD
    partition; entries are replicated), jax>=0.6 the flat dict itself.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        return dict(ca[0]) if ca else {}
    return dict(ca) if ca else {}
