"""Roofline derivation from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) cell, from results/dryrun/*.json:

  compute term    = FLOPs_dev / peak_FLOPs            [s]
  memory term     = HBM_bytes_dev / HBM_bw            [s]
  collective term = coll_bytes_dev / link_bw          [s]

(Per-device numerator over per-device rate == the spec's aggregate form
``HLO_FLOPs / (chips * peak)`` with HLO_FLOPs summed over chips.)  FLOPs and
bytes are the *trip-count-corrected* HLO walks of utils/hlo.py — XLA's own
cost_analysis counts loop bodies once (tests/test_hlo.py proves both).

Also reported per cell: dominant term, MODEL_FLOPS = 6*N_active*D (train) /
2*N_active*D (prefill/decode), the usefulness ratio MODEL/HLO, and a
one-line note on what would move the dominant term.

NOTE (CPU-backend artifact, see DESIGN.md): XLA:CPU promotes bf16 dots and
all-reduces to f32, so byte-based terms are up to 2x a real TPU lowering of
the same module; the comparison ACROSS cells and iterations is unaffected.
"""

from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs import CONFIGS, SHAPES, get_config
from repro.core.network import TPU_HBM_BW, TPU_ICI_BW, TPU_PEAK_FLOPS


def active_params(arch: str) -> float:
    """N_active: parameters touched per token (MoE: top_k of E experts)."""
    cfg = get_config(arch)
    from repro.configs.base import arch_profile
    import dataclasses
    import numpy as np
    prof = arch_profile(cfg)
    total = float(prof.param_cum()[-1]) / 4.0
    if cfg.moe_experts:
        dense_cfg = dataclasses.replace(cfg, moe_experts=0, moe_top_k=0)
        # expert params scale by top_k / E for the active count
        prof_active = arch_profile(
            dataclasses.replace(cfg, moe_experts=cfg.moe_top_k))
        total = float(prof_active.param_cum()[-1]) / 4.0
    return total


def model_flops(arch: str, shape: str) -> float:
    sp = SHAPES[shape]
    n = active_params(arch)
    tokens = sp.global_batch * (1 if sp.kind == "decode" else sp.seq_len)
    factor = 6.0 if sp.kind == "train" else 2.0
    return factor * n * tokens


def model_traffic_bytes(rec: dict) -> float:
    """Analytic per-device HBM traffic of the step, at TPU dtypes.

    The HLO walk's operand+output sum double-counts producer/consumer pairs
    and inherits XLA:CPU's f32 promotion, overstating traffic ~5-20x; this
    structural model (weights / optimizer / activations / caches at their
    true dtypes) is what the roofline's memory term uses.  Both numbers are
    recorded; the walk stays as a diagnostic upper bound.
    """
    import dataclasses
    from repro.configs.base import arch_profile
    cfg = get_config(rec["arch"])
    sp = SHAPES[rec["shape"]]
    chips = rec.get("devices", 256)
    prof = arch_profile(cfg)
    n_params = float(prof.param_cum()[-1]) / 4.0
    L = cfg.num_layers + 2
    act_touch = 8.0                      # residual-stream touches per layer
    if sp.kind == "train":
        tokens = sp.global_batch * sp.seq_len
        opt_mult = {"adamw": 24.0, "adafactor": 10.0, "momentum": 12.0,
                    "sgd": 8.0}.get(rec.get("optimizer", "adamw"), 24.0)
        weights = 3 * 4.0 * n_params + opt_mult * n_params
        acts = L * tokens * cfg.d_model * 2.0 * act_touch * 2.0   # fwd+bwd
        vocab = tokens * cfg.vocab * 2.0 * 3.0
        glob = weights + acts + vocab
    elif sp.kind == "prefill":
        tokens = sp.global_batch * sp.seq_len
        glob = 2.0 * n_params + L * tokens * cfg.d_model * 2.0 * act_touch
    else:  # decode: weights + full cache read dominate; args ~= both
        glob = 0.0
    per_dev = glob / chips
    m = rec.get("memory", {})
    per_dev += float(m.get("argument_size_in_bytes", 0)) \
        + float(m.get("output_size_in_bytes", 0))
    return per_dev


def roofline_row(rec: dict) -> dict:
    chips = rec.get("devices", 256)
    comp = rec["flops_per_device"] / TPU_PEAK_FLOPS
    mem = model_traffic_bytes(rec) / TPU_HBM_BW
    mem_hlo = rec["bytes_per_device"] / TPU_HBM_BW
    coll = rec["collective_bytes_per_device"] / TPU_ICI_BW
    terms = {"compute": comp, "memory": mem, "collective": coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_total = rec["flops_per_device"] * chips
    useful = mf / hlo_total if hlo_total else 0.0
    bound = max(terms.values())
    frac = (mf / TPU_PEAK_FLOPS / chips) / bound if bound else 0.0
    notes = {
        "compute": "reduce redundant/remat FLOPs or raise arithmetic "
                   "intensity (fuse, larger tiles)",
        "memory": "keep activations in bf16, increase reuse per HBM read "
                  "(bigger microbatch / fused layers)",
        "collective": "cut per-layer psum volume (bf16 collectives, "
                      "2D sharding, overlap with compute)",
    }
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": comp, "memory_s": mem, "memory_hlo_s": mem_hlo,
        "collective_s": coll,
        "dominant": dominant,
        "model_flops": mf, "hlo_flops": hlo_total,
        "useful_ratio": useful,
        "roofline_fraction": frac,
        "hbm_gib": rec["hbm_per_device"] / 2**30,
        "fits": rec.get("fits_16gb", rec["hbm_per_device"] < 16 * 2**30),
        "note": notes[dominant],
    }


def load_records(result_dir: str, tag: str = "") -> list:
    rows = []
    for f in sorted(glob.glob(os.path.join(result_dir, "*.json"))):
        base = os.path.basename(f)[:-5]
        parts = base.split("__")
        if tag and not base.endswith(tag):
            continue
        if not tag and len(parts) == 3 and "_" in parts[2] and \
                parts[2].split("_", 1)[1] not in ("pipe",):
            # tagged perf-iteration files are excluded from the baseline table
            if parts[2] not in ("single", "multi"):
                continue
        with open(f) as fh:
            rows.append(json.load(fh))
    return rows


def markdown_table(rows: list) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | MODEL/HLO | roofline frac | HBM GiB | fits |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.2f} "
            f"| {r['hbm_gib']:.2f} | {'Y' if r['fits'] else 'N'} |")
    return hdr + "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "..", "results", "dryrun"))
    ap.add_argument("--csv", default=None)
    args = ap.parse_args()
    recs = load_records(args.dir)
    rows = [roofline_row(r) for r in recs]
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    print(markdown_table(rows))
    if args.csv:
        import csv
        with open(args.csv, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
            w.writeheader()
            w.writerows(rows)


if __name__ == "__main__":
    main()
