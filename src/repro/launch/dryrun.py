import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay the first statements in this module — jax
locks the device count on first init, and the production meshes need 512
placeholder host devices.  Nothing here allocates device memory: inputs are
ShapeDtypeStructs, params come from jax.eval_shape, and the only artifacts
are the compiled executable's memory_analysis / cost_analysis plus the HLO
collective-traffic stats, persisted to results/dryrun/*.json for the
roofline (EXPERIMENTS.md §Dry-run / §Roofline).

Usage:
  python -m repro.launch.dryrun                      # all cells, both meshes
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --mode pipeline ...  # paper-mode train cells
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import (ARCH_IDS, SHAPES, get_config, input_specs,
                           cache_specs, param_specs, supports_shape)
from repro.launch import sharding as shlib
from repro.launch.compat import cost_analysis, set_mesh
from repro.launch.mesh import make_production_mesh, make_pipeline_mesh, mesh_tag
from repro.launch.steps import (default_microbatches, default_optimizer_name,
                                make_decode_step, make_prefill_step,
                                make_train_step)
from repro.optim import get_optimizer
from repro.utils.hlo import (collective_bytes, cpu_f32_promotion_bytes,
                             hlo_cost, op_histogram)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def _mem_dict(mem) -> dict:
    return {k: getattr(mem, k) for k in (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "alias_size_in_bytes",
        "generated_code_size_in_bytes")}


def _lower_cell(arch: str, shape: str, mesh, *, policy=None, q_override=None,
                donate: bool = True):
    """Build + lower + compile one cell; returns the record dict."""
    cfg = get_config(arch)
    if os.environ.get("REPRO_SEQ_PARALLEL"):
        cfg = dataclasses.replace(cfg, seq_parallel_residual=True)
    if os.environ.get("REPRO_REMAT"):
        cfg = dataclasses.replace(cfg, remat=os.environ["REPRO_REMAT"])
    if os.environ.get("REPRO_FF_CHUNKS"):
        cfg = dataclasses.replace(cfg, moe_ff_chunks=int(os.environ["REPRO_FF_CHUNKS"]))
    if os.environ.get("REPRO_CF"):
        cfg = dataclasses.replace(cfg, capacity_factor=float(os.environ["REPRO_CF"]))
    sp = SHAPES[shape]
    policy = policy or shlib.ShardingPolicy()
    t0 = time.time()

    pshapes = param_specs(cfg)
    psh = shlib.param_sharding_tree(cfg, mesh, pshapes, policy)
    rec = {"arch": arch, "shape": shape, "mesh": mesh_tag(mesh),
           "kind": sp.kind, "policy": dataclasses.asdict(policy)}

    if sp.kind == "train":
        opt_name = default_optimizer_name(cfg)
        q = q_override or default_microbatches(cfg, sp.global_batch)
        opt = get_optimizer(opt_name)
        oshapes = jax.eval_shape(opt.init, pshapes)
        osh = shlib.opt_sharding_tree(mesh, opt_name, psh, pshapes)
        bshapes = input_specs(cfg, shape)
        bsh = shlib.batch_sharding(cfg, mesh, bshapes, policy)
        step = make_train_step(cfg, opt, q)
        rec.update(optimizer=opt_name, microbatches=q)
        jitted = jax.jit(step, in_shardings=(psh, osh, bsh),
                         out_shardings=(psh, osh, None),
                         donate_argnums=(0, 1) if donate else ())
        with set_mesh(mesh):
            lowered = jitted.lower(pshapes, oshapes, bshapes)
    elif sp.kind == "prefill":
        bshapes = input_specs(cfg, shape)
        bsh = shlib.batch_sharding(cfg, mesh, bshapes, policy)
        cshapes = cache_specs(cfg, shape)
        csh = shlib.cache_sharding(cfg, mesh, cshapes, policy)
        # serving runs bf16 params
        cfg_srv = dataclasses.replace(cfg, param_dtype=cfg.compute_dtype)
        pshapes = param_specs(cfg_srv)
        psh = shlib.param_sharding_tree(cfg_srv, mesh, pshapes, policy)
        # the cache covers the full prompt incl. prepended patch tokens
        step = make_prefill_step(cfg_srv, sp.seq_len + cfg.patch_tokens)
        jitted = jax.jit(step, in_shardings=(psh, bsh),
                         out_shardings=(None, csh))
        with set_mesh(mesh):
            lowered = jitted.lower(pshapes, bshapes)
    else:  # decode
        cfg_srv = dataclasses.replace(cfg, param_dtype=cfg.compute_dtype)
        pshapes = param_specs(cfg_srv)
        psh = shlib.param_sharding_tree(cfg_srv, mesh, pshapes, policy)
        cshapes = cache_specs(cfg_srv, shape)
        csh = shlib.cache_sharding(cfg_srv, mesh, cshapes, policy)
        tok = jax.ShapeDtypeStruct((sp.global_batch, 1), jnp.int32)
        toksh = shlib.batch_sharding(cfg_srv, mesh, {"t": tok}, policy)["t"]
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        step = make_decode_step(cfg_srv)
        jitted = jax.jit(step, in_shardings=(psh, csh, toksh, None),
                         out_shardings=(None, csh),
                         donate_argnums=(1,) if donate else ())
        with set_mesh(mesh):
            lowered = jitted.lower(pshapes, cshapes, tok, pos)

    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = cost_analysis(compiled)
    hlo = compiled.as_text()
    hc = hlo_cost(hlo)      # trip-count-aware (XLA counts loop bodies once)
    n_dev = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))

    rec.update(
        lower_compile_seconds=round(time.time() - t0, 2),
        devices=n_dev,
        memory=_mem_dict(mem),
        # raw XLA numbers (loop bodies once) — kept for reference
        xla_flops_per_device=float(cost.get("flops", 0.0)),
        xla_bytes_per_device=float(cost.get("bytes accessed", 0.0)),
        # trip-count-corrected per-device numbers (used by the roofline)
        flops_per_device=hc.flops,
        bytes_per_device=hc.traffic_bytes,
        collective_bytes_per_device=hc.collective_bytes,
        collective_breakdown=hc.collective_by_kind,
        while_trip_counts=hc.while_trip_counts,
        unresolved_loops=hc.unresolved_loops,
        op_histogram=op_histogram(hlo, top=12),
    )
    hbm = float(mem.argument_size_in_bytes + mem.output_size_in_bytes
                + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
    promo = cpu_f32_promotion_bytes(hlo)
    rec.update(
        hbm_per_device=hbm,
        cpu_f32_promotion_bytes=promo,
        hbm_per_device_tpu_adjusted=hbm - promo,
        fits_16gb=bool(hbm - promo < 16 * 2**30),
    )
    return rec


def _lower_pipeline_cell(arch: str, mesh, *, num_stages: int = 4,
                         q: int = 16):
    """Paper-mode train cell: the shard_map stage pipeline (spmd.py)."""
    from repro.pipeline import PipelineConfig, make_pipelined_train_step
    cfg = get_config(arch)
    if os.environ.get("REPRO_REMAT"):
        cfg = dataclasses.replace(cfg, remat=os.environ["REPRO_REMAT"])
    sp = SHAPES["train_4k"]
    t0 = time.time()
    if cfg.num_layers % num_stages:
        raise ValueError(f"{arch}: L={cfg.num_layers} % stages={num_stages}")
    policy = shlib.ShardingPolicy(batch_axes=("pod", "data"))
    pshapes = param_specs(cfg)
    psh = shlib.param_sharding_tree(cfg, mesh, pshapes, policy)
    opt_name = default_optimizer_name(cfg)
    opt = get_optimizer(opt_name)
    oshapes = jax.eval_shape(opt.init, pshapes)
    osh = shlib.opt_sharding_tree(mesh, opt_name, psh, pshapes)
    bshapes = input_specs(cfg, "train_4k")
    bsh = shlib.batch_sharding(cfg, mesh, bshapes, policy)
    pcfg = PipelineConfig(num_stages=num_stages, num_microbatches=q)
    step = make_pipelined_train_step(cfg, mesh, pcfg, opt)
    jitted = jax.jit(step, in_shardings=(psh, osh, bsh),
                     out_shardings=(psh, osh, None), donate_argnums=(0, 1))
    with set_mesh(mesh):
        lowered = jitted.lower(pshapes, oshapes, bshapes)
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    hc = hlo_cost(hlo)
    return {
        "arch": arch, "shape": "train_4k", "mesh": mesh_tag(mesh),
        "kind": "train-pipeline", "num_stages": num_stages,
        "microbatches": q, "optimizer": opt_name,
        "lower_compile_seconds": round(time.time() - t0, 2),
        "memory": _mem_dict(mem),
        "flops_per_device": hc.flops,
        "bytes_per_device": hc.traffic_bytes,
        "collective_bytes_per_device": hc.collective_bytes,
        "collective_breakdown": hc.collective_by_kind,
        "unresolved_loops": hc.unresolved_loops,
        "hbm_per_device": float(mem.argument_size_in_bytes
                                + mem.output_size_in_bytes
                                + mem.temp_size_in_bytes
                                - mem.alias_size_in_bytes),
    }


def run_cells(archs, shapes, meshes, *, mode="baseline", out_dir=RESULTS_DIR,
              force=False, policy=None, q_override=None, tag=""):
    os.makedirs(out_dir, exist_ok=True)
    failures, done = [], 0
    for mesh_name in meshes:
        mesh = (make_production_mesh(multi_pod=(mesh_name == "multi"))
                if mode == "baseline" else
                make_pipeline_mesh(multi_pod=(mesh_name == "multi")))
        for arch in archs:
            cfg = get_config(arch)
            for shape in shapes:
                if not supports_shape(cfg, shape):
                    print(f"SKIP {arch} x {shape} (N/A: full attention "
                          f"at 500k) ")
                    continue
                suffix = f"_{tag}" if tag else ""
                fname = os.path.join(
                    out_dir, f"{arch}__{shape}__{mesh_name}"
                             f"{'_pipe' if mode == 'pipeline' else ''}"
                             f"{suffix}.json")
                if os.path.exists(fname) and not force:
                    print(f"CACHED {arch} x {shape} x {mesh_name}")
                    done += 1
                    continue
                try:
                    if mode == "pipeline":
                        if shape != "train_4k":
                            continue
                        rec = _lower_pipeline_cell(arch, mesh)
                    else:
                        rec = _lower_cell(arch, shape, mesh, policy=policy,
                                          q_override=q_override)
                    with open(fname, "w") as f:
                        json.dump(rec, f, indent=1)
                    print(f"OK {arch} x {shape} x {mesh_name}: "
                          f"hbm/dev={rec['hbm_per_device']/2**30:.2f}GiB "
                          f"flops/dev={rec['flops_per_device']:.3e} "
                          f"coll/dev={rec['collective_bytes_per_device']/2**20:.1f}MiB "
                          f"({rec['lower_compile_seconds']}s)", flush=True)
                    done += 1
                except Exception as e:
                    failures.append((arch, shape, mesh_name, repr(e)))
                    print(f"FAIL {arch} x {shape} x {mesh_name}: {e!r}",
                          flush=True)
                    traceback.print_exc()
    print(f"\n{done} cells OK, {len(failures)} failures")
    for f in failures:
        print("  FAIL:", *f)
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCH_IDS) + [None])
    ap.add_argument("--shape", default=None,
                    choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--mode", default="baseline",
                    choices=["baseline", "pipeline"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="", help="suffix for result files "
                    "(perf-iteration variants)")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--out", default=RESULTS_DIR)
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = (["single", "multi"] if args.mesh == "both" else [args.mesh])
    policy = shlib.ShardingPolicy(fsdp=not args.no_fsdp)
    failures = run_cells(archs, shapes, meshes, mode=args.mode,
                         out_dir=args.out, force=args.force, policy=policy,
                         q_override=args.microbatches, tag=args.tag)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
