"""Launchers: production meshes, sharding rules, the multi-pod dry-run,
roofline derivation, and train/serve drivers.

NOTE: do not import ``dryrun`` from here — it sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 at import time by
design, and must only be imported as the entry module."""

from .compat import (AxisType, cost_analysis, make_mesh, set_mesh,
                     shard_map)
from .mesh import (make_production_mesh, make_pipeline_mesh, data_axes,
                   mesh_tag)
from .sharding import (ShardingPolicy, param_sharding_tree, batch_sharding,
                       cache_sharding, opt_sharding_tree, replicated)
from .steps import (make_train_step, make_prefill_step, make_decode_step,
                    default_optimizer_name, default_microbatches)

__all__ = ["AxisType", "cost_analysis", "make_mesh", "set_mesh",
           "shard_map",
           "make_production_mesh", "make_pipeline_mesh", "data_axes",
           "mesh_tag", "ShardingPolicy", "param_sharding_tree",
           "batch_sharding", "cache_sharding", "opt_sharding_tree",
           "replicated", "make_train_step", "make_prefill_step",
           "make_decode_step", "default_optimizer_name",
           "default_microbatches"]
