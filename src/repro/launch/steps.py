"""Step-function factories shared by the trainer, server, and dry-run.

``train_step`` does micro-batched gradient accumulation (lax.scan) — the
single-mesh counterpart of the paper's micro-batching (Theorem 1 picks Q)
— followed by the optimizer update.  ``prefill_step``/``decode_step`` are
the serving entries the decode-shape cells lower.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.models import get_model
from repro.models.common import ArchConfig
from repro.optim import Optimizer, get_optimizer
from repro.pipeline.executor import microbatch_grads


# Optimizer policy: AdamW by default; factored second moments once fp32
# moments stop fitting (>= ~100B params on a 256-chip pod) — DESIGN.md §2.
BIG_MODEL_OPTIMIZER_THRESHOLD = 100e9


def default_optimizer_name(cfg: ArchConfig) -> str:
    from repro.configs.base import count_params
    return ("adafactor" if count_params(cfg) >= BIG_MODEL_OPTIMIZER_THRESHOLD
            else "adamw")


def default_microbatches(cfg: ArchConfig, global_batch: int) -> int:
    """Gradient-accumulation depth Q for the train shape.  The planner
    (Theorem 1) refines this; the default keeps per-microbatch activations
    bounded for the largest configs.  Configs can pin Q (§Perf winners)."""
    q = cfg.train_microbatches
    if q <= 0:
        q = 8
        if cfg.d_model >= 8192 or cfg.num_layers >= 64:
            q = 16
    while global_batch % q:
        q //= 2
    return max(q, 1)


def make_train_step(cfg: ArchConfig, optimizer: Optimizer,
                    num_microbatches: int) -> Callable:
    api = get_model(cfg)

    def train_step(params, opt_state, batch):
        loss, grads = microbatch_grads(api.loss, params, batch,
                                       num_microbatches)
        params, opt_state = optimizer.update(params, grads, opt_state)
        return params, opt_state, loss

    return train_step


def make_prefill_step(cfg: ArchConfig, cache_len: int) -> Callable:
    api = get_model(cfg)

    def prefill_step(params, batch):
        return api.prefill(params, batch, cache_len)

    return prefill_step


def make_decode_step(cfg: ArchConfig) -> Callable:
    api = get_model(cfg)

    def decode_step(params, cache, token, pos):
        return api.decode(params, cache, token, pos)

    return decode_step
