"""Pallas min-plus / min-max scan kernel for the Algorithm-1 layered DP.

``sweep_minplus`` runs the full K-layer masked relaxation for a batch of
thresholds in one ``pl.pallas_call`` (grid over threshold tiles), mirroring
the numpy reference in :mod:`repro.core.shortest_path` (``_sweep``).  On
hosts without a TPU the kernel runs in interpreter mode — correct but slow,
kept for CI parity; the XLA-fused jit backend in
:mod:`repro.core.planner_jax` is the fast CPU path.
"""

from .kernel import sweep_minplus, pallas_available, default_interpret
from .ref import sweep_ref

__all__ = ["sweep_minplus", "sweep_ref", "pallas_available",
           "default_interpret"]
