"""Numpy reference for the min-plus sweep kernel (parity oracle).

A standalone re-statement of the two-stage layered relaxation of
``repro.core.shortest_path._sweep`` for a *single* graph and a batch of
thresholds — small enough to read side-by-side with the Pallas kernel.
"""

from __future__ import annotations

import numpy as np

_INF = np.inf


def sweep_ref(Ccom, Bcom, Sseg, Bseg, src_cost, src_beta, K, ts,
              mode: str = "sum") -> np.ndarray:
    """Best terminal value per threshold.

    Layouts: ``Ccom/Bcom[n, i, m]``, ``Sseg/Bseg[i, m, j]``,
    ``src_cost/src_beta[i]`` (structural masks pre-folded, as after
    ``_LayeredDP.rebind``).  ``mode="sum"`` is (+, min) shortest path among
    edges with beta <= t; ``mode="max"`` is (max, min) minimal bottleneck."""
    ts = np.asarray(ts, dtype=float)
    S = ts.shape[0]
    N, I1 = Ccom.shape[0], Ccom.shape[1]
    I = I1 - 1
    op = np.add if mode == "sum" else np.maximum
    src_val = src_cost if mode == "sum" else src_beta
    Vc = Ccom if mode == "sum" else Bcom
    Vs = Sseg if mode == "sum" else Bseg

    best = np.full(S, _INF)
    for s in range(S):
        t = ts[s]
        Vc_m = np.where(Bcom <= t, Vc, _INF)
        Vs_m = np.where(Bseg <= t, Vs, _INF)
        dist = np.full((N, I1), _INF)
        dist[0] = np.where(src_beta <= t, src_val, _INF)
        if np.isfinite(dist[0, I]):
            best[s] = dist[0, I]
        for _k in range(2, K + 1):
            A = op(dist[:, :, None], Vc_m).min(axis=0)        # (I1, N)
            nd = op(A[:, :, None], Vs_m).min(axis=0)          # (N, I1)
            dist = nd
            if N > 1:
                best[s] = min(best[s], nd[1:, I].min())
            if not np.isfinite(nd).any():
                break
    return best
