"""Pallas implementation of the masked min-plus / min-max DP sweep.

Follows the ``kernels/flash`` idiom: a 1-D grid over threshold tiles, the
graph tensors passed as whole blocks shared by every grid step (their
``index_map`` pins block 0), per-tile threshold/output blocks, and the
two-stage relaxation written with ``lax.fori_loop`` over the cut index so
no O(N^2 I^2) candidate tensor is materialized in VMEM.

On CPU hosts the kernel runs with ``interpret=True`` (set automatically by
:func:`default_interpret`) — numerically identical, slow; it exists so the
TPU path is exercised by the same parity tests everywhere.  Block shapes
here are not forced to the (8, 128) f32 tile grid, which the Mosaic
compiler tolerates for these small operand sizes; revisit if lowering to a
real TPU complains.
"""

from __future__ import annotations

import functools

import numpy as np

_INF = np.inf


def pallas_available() -> bool:
    try:
        from jax.experimental import pallas as pl  # noqa: F401
        return True
    except Exception:
        return False


def default_interpret() -> bool:
    """Interpreter mode unless running on a real TPU backend."""
    import jax
    try:
        return jax.default_backend() != "tpu"
    except Exception:
        return True


def _sweep_kernel(ts_ref, Cc_ref, Bc_ref, Ss_ref, Bs_ref, sc_ref, sb_ref,
                  out_ref, *, K: int, N: int, I1: int, mode: str):
    import jax.numpy as jnp
    from jax import lax

    dt = Cc_ref.dtype
    INF = jnp.asarray(np.asarray(_INF, dtype=dt))
    is_sum = mode == "sum"
    I = I1 - 1

    ts = ts_ref[...]                                   # (St,)
    t3 = ts[None, None, :]
    Cc = Cc_ref[...]                                   # (n, i, m)
    Bc = Bc_ref[...]
    Ss = Ss_ref[...]                                   # (i, m, j)
    Bs = Bs_ref[...]
    sc = sc_ref[...]                                   # (i,)
    sb = sb_ref[...]
    St = ts.shape[0]

    Vc = Cc if is_sum else Bc
    Vs = Ss if is_sum else Bs
    src = sc if is_sum else sb

    dist0 = jnp.where(sb[:, None] <= ts[None, :], src[:, None], INF)
    dist = jnp.full((N, I1, St), INF, dt).at[0].set(dist0)
    best = jnp.where(jnp.isfinite(dist[0, I]), dist[0, I], INF)

    def layer(dist):
        def per_i(i, nd):
            vc = jnp.where(Bc[:, i, :][:, :, None] <= t3,
                           Vc[:, i, :][:, :, None], INF)       # (n, m, St)
            dcol = dist[:, i, :][:, None, :]
            cand = dcol + vc if is_sum else jnp.maximum(dcol, vc)
            Ai = cand.min(axis=0)                              # (m, St)
            vs = jnp.where(Bs[i][:, :, None] <= t3,
                           Vs[i][:, :, None], INF)             # (m, j, St)
            cand2 = Ai[:, None, :] + vs if is_sum \
                else jnp.maximum(Ai[:, None, :], vs)
            return jnp.minimum(nd, cand2)
        return lax.fori_loop(0, I1, per_i, jnp.full((N, I1, St), INF, dt))

    def body(_k, carry):
        dist, best = carry
        nd = layer(dist)
        return nd, jnp.minimum(best, nd[1:, I].min(axis=0))

    dist, best = lax.fori_loop(2, K + 1, body, (dist, best))
    out_ref[...] = best


def sweep_minplus(Ccom, Bcom, Sseg, Bseg, src_cost, src_beta, K, ts, *,
                  mode: str = "sum", interpret: bool | None = None,
                  block_s: int = 128) -> np.ndarray:
    """Best terminal DP value per threshold, via one ``pallas_call``.

    Layouts match ``_LayeredDP`` buffers: ``Ccom/Bcom[n, i, m]``,
    ``Sseg/Bseg[i, m, j]``, structural masks pre-folded.  Returns a float
    array the shape of ``ts``.  Parity oracle: :func:`repro.kernels.minplus.
    ref.sweep_ref` (and transitively the numpy ``_sweep``)."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    if interpret is None:
        interpret = default_interpret()
    ts = np.atleast_1d(np.asarray(ts))
    S = ts.shape[0]
    N, I1 = Ccom.shape[0], Ccom.shape[1]
    # compute in the dtype jax will honor: f64 only under JAX_ENABLE_X64
    dt = np.dtype("float64" if jax.config.jax_enable_x64 else "float32")
    Sp = ((S + block_s - 1) // block_s) * block_s
    ts_p = np.full(Sp, -_INF, dtype=dt)
    ts_p[:S] = ts.astype(dt)

    grid = (Sp // block_s,)
    shared = lambda *shape: pl.BlockSpec(shape, lambda i: (0,) * len(shape))
    fn = pl.pallas_call(
        functools.partial(_sweep_kernel, K=int(K), N=N, I1=I1, mode=mode),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_s,), lambda i: (i,)),
            shared(N, I1, N), shared(N, I1, N),
            shared(I1, N, I1), shared(I1, N, I1),
            shared(I1), shared(I1),
        ],
        out_specs=pl.BlockSpec((block_s,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Sp,), dt),
        interpret=interpret,
    )
    out = fn(jnp.asarray(ts_p),
             jnp.asarray(np.asarray(Ccom, dtype=dt)),
             jnp.asarray(np.asarray(Bcom, dtype=dt)),
             jnp.asarray(np.asarray(Sseg, dtype=dt)),
             jnp.asarray(np.asarray(Bseg, dtype=dt)),
             jnp.asarray(np.asarray(src_cost, dtype=dt)),
             jnp.asarray(np.asarray(src_beta, dtype=dt)))
    return np.asarray(out)[:S].astype(np.float64)
