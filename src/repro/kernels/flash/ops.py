"""jit'd public wrapper for the flash-attention kernel (GQA-aware)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_fwd


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True):
    """q: (B, S, H, hd); k, v: (B, T, KV, hd), H % KV == 0.

    GQA: kv heads are broadcast to q heads *by index* (a reshape/broadcast
    of the (B, KV, T, hd) view — no per-q-head copy of K/V in HBM beyond
    the broadcast XLA will fuse).  Sequences are padded to block multiples;
    padded keys are masked inside the kernel via ``seq_k``.
    """
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    g = H // KV

    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3)                    # (B, KV, T, hd)
    vf = v.transpose(0, 2, 1, 3)
    if g > 1:
        kf = jnp.broadcast_to(kf[:, :, None], (B, KV, g, T, hd))
        vf = jnp.broadcast_to(vf[:, :, None], (B, KV, g, T, hd))
    kf = kf.reshape(B * H, T, hd)
    vf = vf.reshape(B * H, T, hd)

    pad_q = (-S) % block_q
    pad_k = (-T) % block_k
    if pad_q:
        qf = jnp.pad(qf, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kf = jnp.pad(kf, ((0, 0), (0, pad_k), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad_k), (0, 0)))

    out = flash_attention_fwd(qf, kf, vf, causal=causal, block_q=block_q,
                              block_k=block_k, interpret=interpret)
    out = out[:, :S]
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
