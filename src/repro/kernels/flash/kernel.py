"""Flash-attention forward — Pallas TPU kernel.

TPU-native tiling (not a CUDA port): the grid is (batch*heads, q-blocks,
k-blocks) with the *k-block axis innermost* — on TPU the innermost grid
dimension executes sequentially on a core, so the online-softmax
accumulators (m, l, acc) live in VMEM scratch and persist across k-steps.
Block shapes are (block_q, head_dim) / (block_k, head_dim) with
MXU-friendly 128-multiples; the (S, T) score matrix never exists — only a
(block_q, block_k) tile at a time, resident in VMEM.

GQA is handled at zero memory cost by the BlockSpec index_map: the kv-head
index is derived from the q-head index (h * KV) // H, so KV tensors are
never materialized per-q-head.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                      block_q: int, block_k: int, causal: bool, scale: float,
                      seq_q: int, seq_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    def body():
        q = q_ref[0].astype(jnp.float32)                    # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                    # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale                                       # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = kpos < seq_k
        if causal:
            mask &= kpos <= qpos
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                                 # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                              # (bq, bk)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
        pv = jax.lax.dot_general(p, v_ref[0].astype(jnp.float32),
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = m_new

    if causal:
        # skip blocks fully above the diagonal
        pl.when(k_start <= q_start + block_q - 1)(body)
    else:
        body()

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True, block_q: int = 128,
                        block_k: int = 128, interpret: bool = True):
    """q: (BH, Sq, hd) fp/bf16; k, v: (BKV, Sk, hd) where the kv-head of
    q-head h is resolved by the caller reshaping BH == B*H, BKV == B*KV and
    passing the per-head mapping via ``kv_map`` — see ops.flash_attention.

    This low-level entry expects BH == BKV (kv already head-aligned);
    ops.py does the GQA index mapping.
    """
    BH, Sq, hd = q.shape
    Sk = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    nq = pl.cdiv(Sq, block_q)
    nk = pl.cdiv(Sk, block_k)

    grid = (BH, nq, nk)
    kernel = functools.partial(
        _flash_fwd_kernel, block_q=block_q, block_k=block_k, causal=causal,
        scale=scale, seq_q=Sq, seq_k=Sk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        scratch_shapes=[
            # m, l, acc accumulators in VMEM, persist across the k axis
            # (innermost grid dim is sequential on a TPU core)
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
