"""Pure-jnp oracle for the flash-attention kernel (no Pallas, no tiling)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True):
    """q: (B, S, H, hd); k, v: (B, T, KV, hd) with H % KV == 0.
    Returns (B, S, H, hd).  fp32 softmax, dense S x T scores."""
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    g = H // KV
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32) * scale
    if causal:
        mask = jnp.arange(T)[None, :] <= jnp.arange(S)[:, None]
        scores = jnp.where(mask[None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhst,bthd->bshd", probs.astype(q.dtype), v)
