from .ops import flash_attention
from .ref import attention_ref

__all__ = ["flash_attention", "attention_ref"]
