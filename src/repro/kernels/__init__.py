"""Pallas TPU kernels for the compute hot-spots our architectures hit:
flash attention (prefill) and the RWKV6 chunked WKV scan.  Each ships
``kernel.py`` (pl.pallas_call + BlockSpec VMEM tiling), ``ops.py`` (jit'd
wrapper) and ``ref.py`` (pure-jnp oracle); validated in interpret mode.

The paper itself has no kernel-level contribution (it is a scheduling
paper) — these kernels are where the per-stage FLOPs of its pipeline go.
"""
