from .ops import wkv6
from .ref import wkv6_ref

__all__ = ["wkv6", "wkv6_ref"]
