"""Naive per-token WKV6 recurrence — the oracle for the chunked kernel.

    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, logw, u, S0):
    """r, k, v, logw: (B, S, H, hd); u: (H, hd); S0: (B, H, hd, hd) fp32.
    Returns (y (B, S, H, hd) fp32, S_final (B, H, hd, hd) fp32)."""
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    w = jnp.exp(logw.astype(jnp.float32))
    uf = u.astype(jnp.float32)

    def step(S, inp):
        rt, kt, vt, wt = inp                       # (B, H, hd)
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        y = jnp.einsum("bhk,bhkv->bhv", rt, S + uf[None, :, :, None] * kv)
        S_new = wt[..., None] * S + kv
        return S_new, y

    xs = tuple(t.swapaxes(0, 1) for t in (rf, kf, vf, w))  # (S, B, H, hd)
    S_fin, ys = jax.lax.scan(step, S0.astype(jnp.float32), xs)
    return ys.swapaxes(0, 1), S_fin
