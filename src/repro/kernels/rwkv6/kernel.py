"""Chunked RWKV6 WKV scan — Pallas TPU kernel.

TPU adaptation of the data-dependent-decay linear-attention recurrence:
the sequence is processed in chunks along the *innermost grid dimension*
(sequential on a TPU core), with the running state S (hd x hd, fp32) held
in VMEM scratch across chunks.  Inside a chunk everything is matmul-shaped
for the MXU:

    y  = q @ S  +  tril(q' k'^T, -1) @ v  +  diag-bonus
    S <- exp(L_C) * S  +  (k * exp(L_C - L))^T @ v

where q = r * exp(L_{t-1}), k' = k * exp(-L) and L = cumsum(log w) within
the chunk.  All exponents are differences of a non-increasing L (<= 0), so
no overflow.  Grid: (B*H, S/chunk); blocks (chunk, hd) live in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv6_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref,
                 y_ref, s_out_ref, s_scr, *, chunk: int, hd: int):
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = s0_ref[0]

    r = r_ref[0].astype(jnp.float32)           # (C, hd)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = lw_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)           # (1, hd)
    S = s_scr[...]                             # (hd, hd)

    L = jnp.cumsum(lw, axis=0)                 # (C, hd) inclusive
    Lm1 = L - lw                               # exclusive
    q = r * jnp.exp(Lm1)
    kd = k * jnp.exp(-L)

    # cross-chunk: q @ S
    y = jax.lax.dot_general(q, S, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # intra-chunk, strictly below the diagonal
    att = jax.lax.dot_general(q, kd, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (C, C)
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    att = jnp.where(cols < rows, att, 0.0)
    y += jax.lax.dot_general(att, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    # current-token bonus: (r . u . k) v
    y += jnp.sum(r * u * k, axis=-1, keepdims=True) * v
    y_ref[0] = y.astype(y_ref.dtype)

    # state to chunk end
    decay_all = jnp.exp(L[-1])[:, None]                    # (hd, 1)
    k_tail = k * jnp.exp(L[-1][None, :] - L)               # (C, hd)
    S_new = decay_all * S + jax.lax.dot_general(
        k_tail, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    s_scr[...] = S_new

    @pl.when(ci == nc - 1)
    def _fin():
        s_out_ref[0] = S_new


def wkv6_fwd(r, k, v, logw, u, s0, *, chunk: int = 128,
             interpret: bool = True):
    """r/k/v/logw: (BH, S, hd); u: (BH, 1, hd); s0: (BH, hd, hd) fp32.
    Returns (y (BH, S, hd) fp32, S_final (BH, hd, hd) fp32)."""
    BH, S, hd = r.shape
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    kernel = functools.partial(_wkv6_kernel, chunk=chunk, hd=hd)
    y, s_fin = pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1, hd), lambda b, c: (b, 0, 0)),
            pl.BlockSpec((1, hd, hd), lambda b, c: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, hd, hd), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, hd), jnp.float32),
            jax.ShapeDtypeStruct((BH, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u, s0)
    return y, s_fin
