"""jit'd public wrapper: model-layout (B, S, H, hd) -> kernel layout."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import wkv6_fwd


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, logw, u, s0, *, chunk: int = 128, interpret: bool = True):
    """r/k/v/logw: (B, S, H, hd); u: (H, hd); s0: (B, H, hd, hd).
    Returns (y (B, S, H, hd) fp32, S_final (B, H, hd, hd) fp32) —
    drop-in replacement for models.rwkv6.wkv_chunked."""
    B, S, H, hd = r.shape
    fold = lambda t: t.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    rf, kf, vf, lwf = map(fold, (r, k, v, logw))
    uf = jnp.broadcast_to(u[None], (B, H, hd)).reshape(B * H, 1, hd)
    s0f = s0.reshape(B * H, hd, hd).astype(jnp.float32)
    y, s_fin = wkv6_fwd(rf, kf, vf, lwf, uf, s0f, chunk=chunk,
                        interpret=interpret)
    y = y.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    return y, s_fin.reshape(B, H, hd, hd)
