"""Synthetic data substrate.

CIFAR-10/MNIST are not available offline; the classification stream keeps
their tensor shapes (32x32x3 / 10 classes) with a *learnable* structure
(class-conditional means + noise) so accuracy curves are meaningful, and
the LM stream generates a Zipf-ish token process with a planted bigram
structure so loss decreases measurably.  The multi-client split implements
IID and non-IID (Dirichlet over class proportions) partitions — the paper's
Fig. 4 settings — and Eq. (1)'s per-client micro-batch shares live in
core.latency.client_shares.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


def token_lm_batches(*, batch: int, seq_len: int, vocab: int, seed: int = 0,
                     bigram_rank: int = 64) -> Iterator[dict]:
    """Endless stream of {tokens, labels} with a planted low-rank bigram."""
    rng = np.random.default_rng(seed)
    # planted transition structure: token t+1 ~ f(token t mod rank)
    table = rng.integers(0, vocab, size=(bigram_rank, 8))
    while True:
        toks = np.empty((batch, seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, vocab, size=batch)
        noise = rng.random((batch, seq_len))
        choice = rng.integers(0, 8, size=(batch, seq_len))
        rand_tok = rng.integers(0, vocab, size=(batch, seq_len))
        for t in range(seq_len):
            follow = table[toks[:, t] % bigram_rank, choice[:, t]]
            toks[:, t + 1] = np.where(noise[:, t] < 0.75, follow,
                                      rand_tok[:, t])
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def classification_batches(*, batch: int, num_classes: int = 10,
                           image_hw: int = 32, channels: int = 3,
                           seed: int = 0, noise: float = 0.35
                           ) -> Iterator[dict]:
    """CIFAR-shaped learnable stream: class mean images + Gaussian noise."""
    rng = np.random.default_rng(seed)
    means = rng.normal(0.0, 1.0, (num_classes, image_hw, image_hw, channels))
    while True:
        labels = rng.integers(0, num_classes, size=batch)
        imgs = means[labels] + rng.normal(0, noise,
                                          (batch, image_hw, image_hw,
                                           channels))
        yield {"images": imgs.astype(np.float32),
               "labels": labels.astype(np.int32)}


# ---------------------------------------------------------------------------
# Multi-client partitioning (Sec. III-A: M clients hold the data)
# ---------------------------------------------------------------------------

def dirichlet_partition(labels: np.ndarray, num_clients: int,
                        alpha: float = 0.5, seed: int = 0) -> list:
    """Non-IID split: per-class Dirichlet proportions across clients.
    alpha -> inf recovers IID.  Returns list of index arrays."""
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    out = [[] for _ in range(num_clients)]
    for c in classes:
        idx = np.where(labels == c)[0]
        rng.shuffle(idx)
        props = rng.dirichlet([alpha] * num_clients)
        splits = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for cl, part in enumerate(np.split(idx, splits)):
            out[cl].append(part)
    return [np.concatenate(parts) if parts else np.array([], np.int64)
            for parts in out]


@dataclasses.dataclass
class ClientDataset:
    """One client's shard, serving b_m-sized micro-batch draws (Eq. 1)."""
    images: np.ndarray
    labels: np.ndarray
    rng: np.random.Generator

    def draw(self, n: int) -> dict:
        idx = self.rng.integers(0, len(self.labels), size=n)
        return {"images": self.images[idx], "labels": self.labels[idx]}


def client_datasets(num_clients: int, *, samples: int = 4096,
                    iid: bool = True, alpha: float = 0.5, seed: int = 0
                    ) -> list:
    """Materialize a synthetic CIFAR-shaped dataset split across clients."""
    gen = classification_batches(batch=samples, seed=seed)
    full = next(gen)
    if iid:
        rng = np.random.default_rng(seed)
        idx = rng.permutation(samples)
        shards = np.array_split(idx, num_clients)
    else:
        shards = dirichlet_partition(full["labels"], num_clients, alpha,
                                     seed)
    return [ClientDataset(full["images"][s], full["labels"][s],
                          np.random.default_rng(seed + 1 + i))
            for i, s in enumerate(shards)]
