"""Data pipeline: synthetic workloads + the paper's multi-client partition."""

from .synthetic import (token_lm_batches, classification_batches,
                        dirichlet_partition, ClientDataset, client_datasets)

__all__ = ["token_lm_batches", "classification_batches",
           "dirichlet_partition", "ClientDataset", "client_datasets"]
