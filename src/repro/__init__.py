"""repro — pipelined split learning in multi-hop edge networks.

Subpackages are imported lazily so that lightweight users (``repro.core``,
``repro.sim`` need only numpy) never pay for the jax-backed runtime
(``repro.pipeline``, ``repro.models``, ``repro.kernels``, ...).
"""

import importlib

_SUBMODULES = frozenset({
    "checkpoint", "compression", "configs", "core", "data", "ft", "kernels",
    "launch", "models", "optim", "pipeline", "sim", "utils",
})

# convenience re-exports: the simulation subsystem's public API
_SIM_EXPORTS = frozenset({
    "PipelineSimulator", "SimReport", "simulate_plan", "build_tasks",
    "simulate_with_replanning", "ReplanSimReport", "SegmentReport",
    "NetworkScenario", "PiecewiseTrace", "ReplanTrigger",
    "piecewise_cv_scenario", "gauss_markov_scenario",
    "CrossCheck", "cross_validate", "cross_validate_many",
    "write_chrome_trace",
})


def __getattr__(name):
    if name in _SUBMODULES:
        return importlib.import_module(f"{__name__}.{name}")
    if name in _SIM_EXPORTS:
        return getattr(importlib.import_module(f"{__name__}.sim"), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | _SUBMODULES | _SIM_EXPORTS)
