"""repro — pipelined split learning in multi-hop edge networks.

Subpackages are imported lazily so that lightweight users (``repro.core``,
``repro.sim`` need only numpy) never pay for the jax-backed runtime
(``repro.pipeline``, ``repro.models``, ``repro.kernels``, ...).
"""

import importlib

_SUBMODULES = frozenset({
    "checkpoint", "compression", "configs", "core", "data", "ft", "kernels",
    "launch", "models", "obs", "optim", "pipeline", "sim", "utils",
})

# convenience re-exports: the simulation subsystem's full public API.
# Must mirror ``repro.sim.__all__`` exactly — tests/test_exports.py asserts
# the two stay in sync and that every name below actually resolves.
_SIM_EXPORTS = frozenset({
    "Task", "Timeline", "TraceRecord", "VisitTable", "write_chrome_trace",
    "PiecewiseTrace", "constant", "piecewise", "gauss_markov",
    "iid_piecewise", "square_wave", "NetworkScenario", "ReplanTrigger",
    "piecewise_cv_scenario", "gauss_markov_scenario", "sampled_network",
    "periodic_resync_triggers",
    "AdmissionPolicy", "FIFO", "OneFOneB", "MemoryBudgeted",
    "resolve_policy",
    "activation_occupancy", "stage_activation_highwater",
    "PipelineSimulator", "SimReport", "build_tasks", "build_visit_table",
    "simulate_plan", "simulate_plans", "vectorizable",
    "SegmentReport", "ReplanSimReport", "simulate_with_replanning",
    "CrossCheck", "cross_validate", "cross_validate_many", "compare_engines",
    "compare_utilization",
    "random_chain_solution", "random_instance", "random_reentrant_solution",
    "ALL_FAMILIES", "FuzzCase", "FuzzConfig", "FuzzSummary", "ParityResult",
    "check_parity", "fuzz_case", "fuzz_event_stream", "fuzz_scenario",
    "fuzz_scenario_weighted", "load_case", "load_corpus", "run_fuzz",
    "save_case", "shrink_case",
    "RobustMakespan", "RobustnessReport", "cvar", "scenario_distribution",
    "importance_scenario_distribution", "memory_occupancy_overflow",
    "score_plan", "score_plans",
})

# the cost-model seam (ISSUE 4): mirrored from ``repro.core.cost_model``'s
# ``__all__`` — the same sync contract as _SIM_EXPORTS, same test.
_COST_MODEL_EXPORTS = frozenset({
    "CostModel", "ClosedForm", "SimMakespan", "StageClaim", "DegradedTail",
    "stage_memory_claims", "node_budget_windows",
    "node_budget_windows_many", "budget_feasible", "resolve_cost_model",
    "memoized_cost_model",
})

__all__ = sorted(_SUBMODULES | _SIM_EXPORTS | _COST_MODEL_EXPORTS)


def __getattr__(name):
    if name in _SUBMODULES:
        return importlib.import_module(f"{__name__}.{name}")
    if name in _SIM_EXPORTS:
        return getattr(importlib.import_module(f"{__name__}.sim"), name)
    if name in _COST_MODEL_EXPORTS:
        return getattr(importlib.import_module(f"{__name__}.core.cost_model"),
                       name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | _SUBMODULES | _SIM_EXPORTS
                  | _COST_MODEL_EXPORTS)
