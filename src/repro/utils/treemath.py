"""Small pytree helpers used across the trainer and tests."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_scale(a, s):
    return jax.tree.map(lambda x: x * s, a)


def tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))
