"""HLO text analysis: collective-traffic accounting for the roofline.

``cost_analysis()`` gives FLOPs and HBM bytes but NOT collective bytes, so
we parse the post-partitioning HLO module: build a name -> bytes map from
every instruction's output shape, then sum *operand* bytes of each
collective op (all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, sync and async -start forms).

The module is the per-partition (per-device) program, so operand sums are
per-device link traffic; the roofline multiplies by chips for the spec's
``collective_bytes / (chips * link_bw)`` convention (see launch/roofline).
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

# e.g.  bf16[128,4096]{1,0}   or  f32[]   or  (f32[2,3], s32[4])
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# instruction line:  %name = <shape> opcode(operands...), attrs
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")


def shape_bytes(shape_str: str) -> int:
    """Total bytes of all array shapes inside a shape string (handles
    tuples by summing every dtype[dims] occurrence)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict
    count_by_kind: dict
    total_bytes: int

    def summary(self) -> str:
        parts = [f"{k}:{v/1e6:.1f}MB(x{self.count_by_kind[k]})"
                 for k, v in sorted(self.bytes_by_kind.items())]
        return " ".join(parts) or "none"


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Sum operand bytes of every collective in a (post-optimization,
    per-partition) HLO module dump."""
    # first pass: output bytes of every named instruction
    name_bytes: dict = {}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, shape_s, _, _ = m.groups()
        name_bytes[name] = shape_bytes(shape_s)

    by_kind: dict = defaultdict(int)
    count: dict = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, shape_s, opcode, rest = m.groups()
        kind = next((c for c in _COLLECTIVES if opcode.startswith(c)), None)
        if kind is None or opcode.endswith("-done"):
            continue
        # operand bytes: prefer inline operand shapes; else look up names
        operand_str = rest.split(")", 1)[0]
        inline = sum(shape_bytes(s) for s in re.findall(
            r"[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?", operand_str))
        if inline == 0:
            for op_name in re.findall(r"%([\w.\-]+)", operand_str):
                inline += name_bytes.get(op_name, 0)
        if inline == 0:
            inline = shape_bytes(shape_s)  # fall back to output size
        by_kind[kind] += inline
        count[kind] += 1
    return CollectiveStats(bytes_by_kind=dict(by_kind),
                           count_by_kind=dict(count),
                           total_bytes=sum(by_kind.values()))


# ---------------------------------------------------------------------------
# Trip-count-aware FLOP/byte accounting
#
# XLA's compiled.cost_analysis() counts while-loop bodies ONCE (verified in
# tests/test_hlo.py), so any scanned model (layers, micro-batches, chunked
# attention) is undercounted by the trip count.  We therefore walk the HLO
# call graph ourselves: parse computations, resolve while-loop trip counts
# from their condition computations (scan lowers to  iter < constant), and
# multiply each computation's dot-FLOPs / op traffic by the product of
# enclosing trip counts.  Traffic counts operand+output bytes of
# *materializing* top-level ops (fusion boundaries = HBM round-trips).
# ---------------------------------------------------------------------------

_COMP_HDR_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*{\s*$")
_CALLEE_RE = re.compile(
    r"(?:condition|body|to_apply|called_computations=\{|calls=)[=%]*%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_DOT_DNUMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_MATERIALIZING = (
    "fusion", "dot", "convolution", "copy", "gather", "scatter", "dynamic-slice",
    "dynamic-update-slice", "all-gather", "all-reduce", "reduce-scatter",
    "all-to-all", "collective-permute", "sort", "reduce", "transpose",
    "broadcast", "iota", "concatenate", "slice", "pad", "reshape", "select",
    "compare", "add", "multiply", "subtract", "divide", "exponential",
    "convert", "rsqrt", "tanh", "maximum", "minimum", "log", "negate",
    "custom-call",
)


_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


@dataclasses.dataclass
class _Instr:
    name: str
    opcode: str
    out_bytes: int
    out_dims: tuple
    operand_names: list
    line: str


def _first_shape_dims(shape_str: str) -> tuple:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return ()
    dims = m.group(2)
    return tuple(int(d) for d in dims.split(",")) if dims else ()


def _parse_computations(hlo_text: str) -> dict:
    """name -> list[_Instr] for every computation in the module."""
    comps: dict = {}
    cur = None
    for line in hlo_text.splitlines():
        if line.rstrip().endswith("{") and ("->" in line or
                                            line.lstrip().startswith("ENTRY")):
            m2 = re.match(r"\s*(?:ENTRY\s+)?%?([\w.\-]+)", line)
            cur = m2.group(1) if m2 else None
            if cur is not None:
                comps[cur] = []
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, shape_s, opcode, rest = m.groups()
        operand_str = rest.split(")", 1)[0]
        operands = re.findall(r"%([\w.\-]+)", operand_str)
        comps[cur].append(_Instr(name, opcode, shape_bytes(shape_s),
                                 _first_shape_dims(shape_s), operands, line))
    return comps


def _trip_count(while_line: str, cond_instrs: list) -> int:
    """Prefer XLA's own backend_config known_trip_count; fall back to the
    cond computation's  compare(iter, constant(N), LT)  pattern."""
    m = _TRIP_RE.search(while_line)
    if m:
        return max(1, int(m.group(1)))
    consts = {}
    for ins in cond_instrs:
        mc = _CONST_RE.search(ins.line)
        if mc:
            consts[ins.name] = int(mc.group(1))
    for ins in cond_instrs:
        if "direction=LT" in ins.line or ins.opcode == "compare":
            for op in ins.operand_names:
                if op in consts:
                    return max(1, consts[op])
    if len(consts) == 1:          # single constant in the condition
        return max(1, next(iter(consts.values())))
    return 1


def _dot_flops(ins: _Instr, name_dims: dict) -> float:
    """2 * output_elements * contraction_size.  Operand shapes come from the
    name -> dims map (HLO prints operands by name only)."""
    out_elems = 1
    for d in ins.out_dims:
        out_elems *= d
    m = _DOT_DNUMS_RE.search(ins.line)
    lhs_dims = name_dims.get(ins.operand_names[0], ()) \
        if ins.operand_names else ()
    if not m or not lhs_dims:
        return 2.0 * out_elems          # conservative fallback
    contract = 1
    for i in (int(x) for x in m.group(1).split(",") if x):
        if i < len(lhs_dims):
            contract *= lhs_dims[i]
    return 2.0 * out_elems * contract


@dataclasses.dataclass
class HloCost:
    flops: float
    traffic_bytes: float
    collective_bytes: float
    collective_by_kind: dict
    while_trip_counts: list
    unresolved_loops: int


def hlo_cost(hlo_text: str) -> HloCost:
    """Trip-count-aware FLOPs + HBM-traffic + collective-traffic estimate."""
    comps = _parse_computations(hlo_text)

    def while_sites(instrs):
        out = []
        for ins in instrs:
            if ins.opcode == "while":
                mb = re.search(r"body=%?([\w.\-]+)", ins.line)
                mc = re.search(r"condition=%?([\w.\-]+)", ins.line)
                if mb and mc:
                    out.append((ins, mb.group(1), mc.group(1)))
        return out

    # per-computation local cost (dots + traffic + collectives); fusions
    # resolved inline (their internals are not HBM traffic)
    def local_cost(name, seen):
        instrs = comps.get(name, [])
        flops, traffic = 0.0, 0.0
        coll: dict = defaultdict(float)
        name_out = {i.name: i.out_bytes for i in instrs}
        name_dims = {i.name: i.out_dims for i in instrs}
        for ins in instrs:
            kind = next((c for c in _COLLECTIVES
                         if ins.opcode.startswith(c)), None)
            if kind is not None and not ins.opcode.endswith("-done"):
                opb = sum(name_out.get(o, 0) for o in ins.operand_names)
                coll[kind] += opb or ins.out_bytes
            if ins.opcode == "dot":
                flops += _dot_flops(ins, name_dims)
            elif ins.opcode == "fusion":
                m2 = re.search(r"calls=%?([\w.\-]+)", ins.line)
                callee = m2.group(1) if m2 else None
                if callee and callee in comps and callee not in seen:
                    f, _, _ = local_cost(callee, seen | {callee})
                    flops += f
                traffic += ins.out_bytes + sum(
                    name_out.get(o, 0) for o in ins.operand_names)
                continue
            elif ins.opcode in ("call", "conditional"):
                for cal in re.findall(r"(?:to_apply|calls)=%?([\w.\-]+)",
                                      ins.line):
                    if cal in comps and cal not in seen:
                        f, t, c = local_cost(cal, seen | {cal})
                        flops += f
                        traffic += t
                        for k, v in c.items():
                            coll[k] += v
            if ins.opcode in _MATERIALIZING and ins.opcode != "fusion":
                traffic += ins.out_bytes + sum(
                    name_out.get(o, 0) for o in ins.operand_names)
        return flops, traffic, coll

    total_flops = 0.0
    total_traffic = 0.0
    total_coll: dict = defaultdict(float)
    trips: list = []
    unresolved = 0

    def walk(name, mult, seen):
        nonlocal total_flops, total_traffic, unresolved
        if name not in comps or name in seen:
            return
        f, t, c = local_cost(name, {name})
        total_flops += mult * f
        total_traffic += mult * t
        for k, v in c.items():
            total_coll[k] += mult * v
        for ins, body, cond in while_sites(comps[name]):
            tc = _trip_count(ins.line, comps.get(cond, []))
            if tc == 1:
                unresolved += 1
            trips.append(tc)
            walk(body, mult * tc, seen | {name})

    entry = next((n for n in comps if n.startswith("main")), None)
    if entry is None:
        entry = max(comps, key=lambda n: len(comps[n])) if comps else None
    if entry is not None:
        walk(entry, 1.0, set())
    return HloCost(flops=total_flops, traffic_bytes=total_traffic,
                   collective_bytes=float(sum(total_coll.values())),
                   collective_by_kind=dict(total_coll),
                   while_trip_counts=trips, unresolved_loops=unresolved)


def cpu_f32_promotion_bytes(hlo_text: str) -> int:
    """Bytes of f32 buffers that exist ONLY because XLA:CPU promotes bf16
    dot operands to f32 (convert-fusions fed by all-gathers / parameters of
    bf16 weights).  A TPU lowering of the same module keeps these in bf16,
    so memory fit checks subtract half of these bytes (the f32 copy is 2x
    the bf16 original that would exist instead).

    Criterion: top-level f32-output fusions named *convert*/*copy*, with
    >= 64 MiB output, that satisfy EITHER
      - the operand is an all-gather (the FSDP weight-gather upcast), OR
      - a bf16 instruction of the *same dims* exists in the module (the
        f32 buffer shadows a bf16 original, e.g. the remat activation
        stash upcast before a dot).
    Activation math that legitimately runs in f32 (mamba scans, softmax
    statistics) has no bf16 twin and is never subtracted.
    """
    comps = _parse_computations(hlo_text)
    bf16_dims = set()
    for instrs in comps.values():
        for ins in instrs:
            if " bf16[" in ins.line.split("=", 1)[-1][:60]:
                bf16_dims.add(ins.out_dims)
    total = 0
    for name, instrs in comps.items():
        opcode_of = {i.name: i.opcode for i in instrs}
        for ins in instrs:
            if not (ins.opcode == "fusion"
                    and ("convert" in ins.name or "copy" in ins.name)
                    and " f32[" in ins.line
                    and ins.out_bytes >= 64 * 2**20):
                continue
            from_ag = any(opcode_of.get(o, "").startswith("all-gather")
                          or o.startswith("all-gather")
                          for o in ins.operand_names)
            has_twin = ins.out_dims in bf16_dims
            if from_ag or has_twin:
                total += ins.out_bytes // 2   # bf16 would be half
        for ins in instrs:
            # f32 collective buffers of bf16-twinned data: TPU all-gathers /
            # all-reduces bf16 natively, halving the buffer
            if (ins.opcode.startswith(("all-gather", "all-reduce"))
                    and " f32[" in ins.line
                    and ins.out_bytes >= 64 * 2**20
                    and ins.out_dims in bf16_dims):
                total += ins.out_bytes // 2
    return total


def op_histogram(hlo_text: str, top: int = 15) -> list:
    """(opcode, count) histogram — handy for spotting remat/layout waste."""
    counts: dict = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if m:
            counts[m.group(3)] += 1
    return sorted(counts.items(), key=lambda kv: -kv[1])[:top]
