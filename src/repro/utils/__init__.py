from .hlo import (collective_bytes, op_histogram, shape_bytes,
                  CollectiveStats, hlo_cost, HloCost)
from .treemath import tree_add, tree_scale, tree_bytes, global_norm

__all__ = ["collective_bytes", "op_histogram", "shape_bytes",
           "CollectiveStats", "tree_add", "tree_scale", "tree_bytes",
           "global_norm"]
