"""Minimal-but-real optimizer suite (no optax in this container)."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable            # params -> opt_state
    update: Callable          # (params, grads, opt_state) -> (params, state)
    state_bytes_per_param: float


def _tree_zeros(params):
    return jax.tree.map(jnp.zeros_like, params)


def sgd(lr: float = 1e-2) -> Optimizer:
    def init(params):
        return {}

    def update(params, grads, state):
        new = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype),
                           params, grads)
        return new, state

    return Optimizer("sgd", init, update, 0.0)


def momentum(lr: float = 1e-2, beta: float = 0.9) -> Optimizer:
    def init(params):
        return {"m": _tree_zeros(params)}

    def update(params, grads, state):
        m = jax.tree.map(lambda m, g: beta * m + g.astype(m.dtype),
                         state["m"], grads)
        new = jax.tree.map(lambda p, m: p - lr * m, params, m)
        return new, {"m": m}

    return Optimizer("momentum", init, update, 4.0)


def adamw(lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        return {"m": _tree_zeros(params), "v": _tree_zeros(params),
                "t": jnp.zeros((), jnp.int32)}

    def update(params, grads, state):
        t = state["t"] + 1
        m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(m.dtype),
                         state["m"], grads)
        v = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(v.dtype)),
            state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def upd(p, m, v):
            mh = m / bc1
            vh = v / bc2
            return (p - lr * (mh / (jnp.sqrt(vh) + eps) + weight_decay * p)
                    ).astype(p.dtype)

        return (jax.tree.map(upd, params, m, v),
                {"m": m, "v": v, "t": t})

    return Optimizer("adamw", init, update, 8.0)


def adafactor(lr: float = 1e-3, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0) -> Optimizer:
    """Factored second-moment optimizer (Shazeer & Stern) — O(n+m) state for
    an (n, m) matrix instead of AdamW's O(nm).  momentum-free variant."""

    def init(params):
        def leaf_state(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + (p.shape[-1],),
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"f": jax.tree.map(leaf_state, params,
                                  is_leaf=lambda x: hasattr(x, "ndim")),
                "t": jnp.zeros((), jnp.int32)}

    def update(params, grads, state):
        t = state["t"] + 1
        beta = 1.0 - (t.astype(jnp.float32) + 1.0) ** (-decay)

        def upd(p, g, s):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if p.ndim >= 2:
                vr = beta * s["vr"] + (1 - beta) * g2.mean(-1)
                vc = beta * s["vc"] + (1 - beta) * g2.mean(-2)
                denom = (vr[..., None] * vc[..., None, :]
                         / jnp.maximum(vr.mean(-1, keepdims=True)[..., None],
                                       eps))
                u = g * jax.lax.rsqrt(denom + eps)
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(v + eps)
                new_s = {"v": v}
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return (p - lr * u).astype(p.dtype), new_s

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = tdef.flatten_up_to(state["f"])
        outs = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
        new_p = tdef.unflatten([o[0] for o in outs])
        new_f = tdef.unflatten([o[1] for o in outs])
        return new_p, {"f": new_f, "t": t}

    return Optimizer("adafactor", init, update, 0.1)


_FACTORIES = {"sgd": sgd, "momentum": momentum, "adamw": adamw,
              "adafactor": adafactor}


def get_optimizer(name: str, **kw) -> Optimizer:
    return _FACTORIES[name](**kw)


def optimizer_state_bytes_per_param(name: str) -> float:
    """sigma~ contribution per parameter (Eq. 11's optimizer-state term)."""
    return {"sgd": 0.0, "momentum": 4.0, "adamw": 8.0,
            "adafactor": 0.1}[name]
