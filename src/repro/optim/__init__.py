"""Optimizers (pure JAX, pytree-based) + the optimizer-state byte model that
feeds the paper's memory term sigma~_i (Eq. 11).

SGD / Momentum / AdamW / Adafactor.  Adafactor (factored second moment,
T5X-style) is the default for >= 100B-parameter configs: AdamW's 8 bytes of
fp32 moments per parameter cannot fit jamba-398b on a 256-chip pod
(DESIGN.md hardware-adaptation notes)."""

from .optimizers import (Optimizer, sgd, momentum, adamw, adafactor,
                         optimizer_state_bytes_per_param, get_optimizer)

__all__ = ["Optimizer", "sgd", "momentum", "adamw", "adafactor",
           "optimizer_state_bytes_per_param", "get_optimizer"]
