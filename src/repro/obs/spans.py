"""Wall-clock span tracing: ``with span("planner.solve", b=4): ...``.

Spans record ``time.perf_counter()`` intervals into the process registry.
While telemetry is disabled :func:`span` returns one shared no-op context
manager, so instrumented call sites cost a global load plus a branch and
allocate nothing — the zero-overhead-when-disabled contract.

Finished spans export to a Perfetto/Chrome trace through
``repro.sim.events.write_chrome_trace(..., wall_spans=...)``, which puts
the wall-clock solver tracks on their own process id next to the
simulated-time pipeline tracks.
"""

from __future__ import annotations

import dataclasses
import time

from . import registry as _registry


@dataclasses.dataclass(frozen=True)
class SpanRecord:
    """One finished wall-clock span (``perf_counter`` seconds)."""
    name: str
    start: float
    end: float
    args: tuple          # ((key, value), ...) — kwargs at the call site

    @property
    def duration(self) -> float:
        return self.end - self.start


class _NullSpan:
    """Shared do-nothing context manager returned while disabled."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL = _NullSpan()


class _Span:
    __slots__ = ("name", "args", "start")

    def __init__(self, name, args):
        self.name = name
        self.args = args
        self.start = 0.0

    def __enter__(self):
        self.start = time.perf_counter()
        return self

    def __exit__(self, *exc):
        end = time.perf_counter()
        _registry.get_registry().spans.append(
            SpanRecord(self.name, self.start, end, self.args))
        return False


def span(name: str, **args):
    """Context manager timing one named operation (no-op when disabled).

    Spans nest naturally — ``bcd.solve`` wraps per-iterate spans wraps
    ``planner.solve`` spans — and the Chrome-trace exporter renders the
    nesting as stacked slices on the solver track.
    """
    if not _registry.enabled():
        return _NULL
    return _Span(name, tuple(args.items()))


def wall_spans() -> list:
    """Finished spans recorded so far (in completion order)."""
    return list(_registry.get_registry().spans)


def span_summary() -> dict:
    """Per-name ``{count, total_s}`` rollup of the finished spans."""
    out: dict = {}
    for s in _registry.get_registry().spans:
        agg = out.setdefault(s.name, {"count": 0, "total_s": 0.0})
        agg["count"] += 1
        agg["total_s"] += s.duration
    return out
