"""Per-resource busy/idle/blocked interval decomposition.

The paper's motivation (Sec. I / Fig. 2) is that sequential split
learning leaves node and link resources *idle* while other hops work, and
pipelining fills those holes.  This module measures that claim from an
executed schedule instead of asserting it: every resource's occupancy
intervals decompose exactly, over the horizon ``[t_start, makespan]``, as

    span    = makespan - t_start
    service = total occupancy            = busy + blocked
    idle    = span - service             = fill + bubble + drain
    fill    = first_start - t_start      (pipeline fill, the Eq. (12) ramp)
    drain   = makespan - last_end        (pipeline drain)
    bubble  = inter-occupancy gaps       (steady-state holes, Eq. (13))
    blocked = zero-capacity time inside occupancy (trace outages)

On a deterministic chain with the bottleneck resource at stage 0, every
downstream resource shows ``bubble = (Q-1) * (T_i - d_v)`` — the
per-resource shadow of Eq. (13)'s bottleneck interval (``tests/test_obs.py``
pins this identity, and the Eq. (12)-(14) reconciliation, to float
precision).

Builders exist for both engines — :func:`utilization_from_records` (eager
``TraceRecord`` lists from the heap event loop) and
:func:`utilization_from_timeline` (the vectorized engine's dense SoA
``Timeline``) — and share one decomposition kernel, so the standing
cross-engine parity check in ``sim/validate.py`` compares genuinely
independent reconstructions of the same intervals.

This module is duck-typed against ``repro.sim`` (records need
``.resource/.kind/.stage/.start/.end``; timelines need
``.table/.starts/.ends``) and imports nothing from it.
"""

from __future__ import annotations

import dataclasses

import numpy as np

#: canonical resource ordering (mirrors ``sim.events.KINDS``)
_KIND_ORDER = {"fp": 0, "fwd": 1, "bp": 2, "bwd": 3}


def resource_sort_key(resource: tuple):
    """Canonical per-resource sort key — node engines first, then links,
    in the fixed kind order both report builders use."""
    return (_KIND_ORDER[resource[0]], resource[1:])


# ---------------------------------------------------------------------------
# shared busy accumulation (the ISSUE 6 resource_busy unification)
# ---------------------------------------------------------------------------

def accumulate_service(resources, per_visit) -> dict:
    """Fold per-visit service totals into per-resource totals, in visit
    (chain) order.  This is the one summation every ``SimReport.resource_busy``
    site goes through, so the engines can no longer drift apart in how the
    occupancy of a co-located (reentrant) resource is accumulated."""
    out: dict = {}
    for v, res in enumerate(resources):
        out[res] = out.get(res, 0.0) + float(per_visit[v])
    return out


def busy_fractions(service_by_resource: dict, span: float) -> dict:
    """``service / span`` per resource (all zeros on an empty horizon)."""
    if span > 0:
        return {res: t / span for res, t in service_by_resource.items()}
    return {res: 0.0 for res in service_by_resource}


def service_from_records(records) -> dict:
    """Per-resource occupancy seconds from eager ``TraceRecord``s.

    Durations are grouped per (resource, kind, stage) visit stream and
    summed with ``np.sum`` in micro-batch order, then folded across
    streams — matching the vectorized engine's per-visit column sums so
    identical schedules produce identical ``resource_busy`` values.
    """
    streams: dict = {}
    order: list = []
    for r in records:
        key = (r.resource, r.kind, r.stage)
        got = streams.get(key)
        if got is None:
            streams[key] = got = []
            order.append(key)
        got.append(r.end - r.start)
    out: dict = {}
    for key in order:
        res = key[0]
        out[res] = out.get(res, 0.0) + float(np.sum(np.asarray(streams[key])))
    return out


# ---------------------------------------------------------------------------
# interval decomposition
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ResourceUtilization:
    """One resource's interval decomposition over ``[t_start, makespan]``."""
    resource: tuple
    busy: float          # serving with capacity > 0
    blocked: float       # occupied but at zero capacity (trace outage)
    fill: float          # t_start .. first occupancy start
    bubble: float        # inter-occupancy gaps (steady-state idleness)
    drain: float         # last occupancy end .. makespan
    num_tasks: int
    first_start: float
    last_end: float

    @property
    def service(self) -> float:
        """Total occupancy (``busy + blocked``)."""
        return self.busy + self.blocked

    @property
    def idle(self) -> float:
        """Unoccupied time (``fill + bubble + drain``)."""
        return self.fill + self.bubble + self.drain


@dataclasses.dataclass(frozen=True)
class UtilizationReport:
    """Per-resource decomposition plus whole-pipeline rollups.

    ``resources`` maps resource keys (see ``sim.events``) to
    :class:`ResourceUtilization`, in canonical order.  Fractions are of
    the run horizon ``span = makespan - t_start``; pipeline-level
    fractions average over all resources, i.e. they are shares of the
    total resource-time ``len(resources) * span``.
    """
    t_start: float
    makespan: float
    resources: dict

    @property
    def span(self) -> float:
        return self.makespan - self.t_start

    # -- per-resource fractions ---------------------------------------------
    def busy_fraction(self, resource) -> float:
        ru = self.resources[resource]
        return ru.busy / self.span if self.span > 0 else 0.0

    def idle_fraction(self, resource) -> float:
        ru = self.resources[resource]
        return ru.idle / self.span if self.span > 0 else 0.0

    def service_fractions(self) -> dict:
        """``resource -> occupancy/span`` — reconciles with
        ``SimReport.resource_busy`` (same intervals, same horizon)."""
        return busy_fractions(
            {res: ru.service for res, ru in self.resources.items()},
            self.span)

    # -- pipeline-level rollups ---------------------------------------------
    def _total(self, attr: str) -> float:
        return sum(getattr(ru, attr) for ru in self.resources.values())

    def _fraction(self, total: float) -> float:
        denom = self.span * len(self.resources)
        return total / denom if denom > 0 else 0.0

    @property
    def idle_fraction_total(self) -> float:
        """Share of total resource-time spent unoccupied."""
        return self._fraction(self._total("idle"))

    @property
    def bubble_fraction(self) -> float:
        """Share of total resource-time lost to steady-state bubbles."""
        return self._fraction(self._total("bubble"))

    @property
    def fill_drain_fraction(self) -> float:
        """Share of total resource-time spent in pipeline fill/drain —
        the ramp phases Eq. (12)/(14) charge once per fill ``xi``."""
        return self._fraction(self._total("fill") + self._total("drain"))

    @property
    def blocked_fraction_total(self) -> float:
        """Share of total resource-time spent *blocked* — tasks occupying a
        resource through a zero-capacity scenario window (an outage holding
        work hostage, as opposed to the schedule-shaped idle of
        ``bubble``/``fill``/``drain``).  Nonzero only when the report was
        built with scenario ``traces``."""
        return self._fraction(self._total("blocked"))

    def blocked_by_resource(self) -> dict:
        """Per-resource blocked seconds, worst first — the attribution a
        robustness report uses to say *where* a failure distribution bites
        (``sim.robustness.RobustnessReport.top_blocked``)."""
        items = [(res, ru.blocked) for res, ru in self.resources.items()
                 if ru.blocked > 0.0]
        return dict(sorted(items, key=lambda kv: -kv[1]))

    def node_idle_fraction(self) -> dict:
        """Idle fraction per node (its fp + bp engines pooled)."""
        return self._group_idle(
            lambda res: res[1] if res[0] in ("fp", "bp") else None)

    def link_idle_fraction(self) -> dict:
        """Idle fraction per directed link (fwd/bwd transfer resources
        pooled by their ``(from, to)`` node pair)."""
        return self._group_idle(
            lambda res: (res[1], res[2]) if res[0] in ("fwd", "bwd")
            else None)

    def _group_idle(self, keyfn) -> dict:
        groups: dict = {}
        for res, ru in self.resources.items():
            k = keyfn(res)
            if k is None:
                continue
            tot, n = groups.get(k, (0.0, 0))
            groups[k] = (tot + ru.idle, n + 1)
        if self.span <= 0:
            return {k: 0.0 for k in sorted(groups)}
        return {k: tot / (n * self.span)
                for k, (tot, n) in sorted(groups.items())}


def _blocked_time(trace, starts: np.ndarray, ends: np.ndarray) -> float:
    """Measure of zero-capacity time inside the ``[start, end)`` intervals
    under a piecewise-constant capacity ``trace`` (outage overlap)."""
    t = np.asarray(trace.times_arr, dtype=float)
    zero = (np.asarray(trace.values_arr, dtype=float) == 0.0).astype(float)
    # zcum[i] = zero-capacity measure of [t[0], t[i]); last segment -> inf
    zcum = np.zeros(len(t))
    if len(t) > 1:
        np.cumsum(np.diff(t) * zero[:-1], out=zcum[1:])

    def z(x):
        i = np.clip(np.searchsorted(t, x, side="right") - 1, 0, len(t) - 1)
        return zcum[i] + np.maximum(x - t[i], 0.0) * zero[i]

    return float(np.sum(z(ends) - z(starts)))


def _decompose(resource, starts, ends, t_start, makespan, trace=None):
    """Decompose one resource's occupancy intervals (FIFO — no overlap)."""
    order = np.argsort(starts, kind="stable")
    s = starts[order]
    e = ends[order]
    service = float(np.sum(e - s))
    first = float(s[0])
    last = float(e[-1])
    bubble = float(np.sum(np.maximum(s[1:] - e[:-1], 0.0))) if len(s) > 1 \
        else 0.0
    blocked = 0.0
    if trace is not None and not trace.is_constant():
        blocked = min(_blocked_time(trace, s, e), service)
    return ResourceUtilization(
        resource=resource, busy=service - blocked, blocked=blocked,
        fill=max(first - t_start, 0.0), bubble=bubble,
        drain=max(makespan - last, 0.0), num_tasks=len(s),
        first_start=first, last_end=last)


def utilization_from_records(records, t_start: float = 0.0,
                             makespan: float | None = None, *,
                             traces: dict | None = None) -> UtilizationReport:
    """Build a :class:`UtilizationReport` from eager ``TraceRecord``s
    (the heap event engine's native output)."""
    groups: dict = {}
    for r in records:
        groups.setdefault(r.resource, []).append((r.start, r.end))
    if makespan is None:
        makespan = max((r.end for r in records), default=t_start)
    out: dict = {}
    for res in sorted(groups, key=resource_sort_key):
        arr = np.asarray(groups[res], dtype=float).reshape(-1, 2)
        out[res] = _decompose(
            res, arr[:, 0], arr[:, 1], t_start, makespan,
            trace=None if traces is None else traces.get(res))
    return UtilizationReport(float(t_start), float(makespan), out)


def utilization_from_timeline(timeline, t_start: float = 0.0,
                              makespan: float | None = None, *,
                              traces: dict | None = None) -> UtilizationReport:
    """Build a :class:`UtilizationReport` directly from the vectorized
    engine's dense SoA ``Timeline`` — no ``TraceRecord`` materialization;
    a reentrant resource's occupancy is the union of its visit columns."""
    starts = np.asarray(timeline.starts, dtype=float)
    ends = np.asarray(timeline.ends, dtype=float)
    if makespan is None:
        makespan = float(ends.max()) if ends.size else float(t_start)
    if starts.size == 0:                      # zero-micro-batch run
        return UtilizationReport(float(t_start), float(makespan), {})
    visits = timeline.table.resource_visits()
    out: dict = {}
    for res in sorted(visits, key=resource_sort_key):
        vs = list(visits[res])
        out[res] = _decompose(
            res, starts[:, vs].reshape(-1), ends[:, vs].reshape(-1),
            t_start, makespan,
            trace=None if traces is None else traces.get(res))
    return UtilizationReport(float(t_start), float(makespan), out)


def resource_traces(net, scenario, resources) -> dict:
    """Per-resource capacity traces from a ``NetworkScenario`` — feed as
    ``traces=`` to the builders to split occupancy into busy vs blocked
    (only zero-capacity periods matter, so any positive scaling of the
    trace gives the same split)."""
    out: dict = {}
    for res in resources:
        if res[0] in ("fp", "bp"):
            out[res] = scenario.node_trace(net, res[1])
        else:
            out[res] = scenario.link_trace(net, res[1], res[2])
    return out
