"""Chrome-trace (Perfetto) event builders and schema validation.

``repro.sim.events.write_chrome_trace`` composes these into one JSON file
with two processes:

* pid :data:`SIM_PID` — simulated-time pipeline tracks (one thread per
  resource; "X" slices per task, optional "C" counter tracks for
  instantaneous utilization, optional "s"/"f" flow arrows tying a
  micro-batch's forward hop to its backward hop).
* pid :data:`SOLVER_PID` — wall-clock solver tracks built from
  ``obs.span()`` records (planner/BCD/cost-model/coordinator timing).

Timestamps are microseconds (``time_scale=1e6`` from seconds), matching
chrome://tracing / https://ui.perfetto.dev conventions.  The builders are
duck-typed (records need ``.microbatch/.resource/.start/.end``; spans
need ``.name/.start/.end/.args``) so this module imports nothing from
``repro.sim``.
"""

from __future__ import annotations


SIM_PID = 0       # simulated-time pipeline tracks
SOLVER_PID = 1    # wall-clock solver/span tracks


def utilization_counter_events(records, *, pid: int = SIM_PID,
                               time_scale: float = 1e6,
                               label_of=None) -> list:
    """Per-resource "C" counter tracks: instantaneous occupancy (0/1 for
    FIFO resources), plus a pipeline-wide active-task counter.  Perfetto
    renders these as stepped area charts — bubbles show as dips."""
    if label_of is None:
        label_of = str
    per_res: dict = {}
    for r in records:
        per_res.setdefault(r.resource, []).append((r.start, +1))
        per_res[r.resource].append((r.end, -1))
    events: list = []
    all_edges: list = []
    for res, edges in per_res.items():
        # ends (-1) before starts (+1) at equal timestamps, so
        # back-to-back tasks show 1 -> 0 -> 1 without a spurious 2
        edges.sort(key=lambda e: (e[0], e[1]))
        name = f"busy {label_of(res)}"
        level = 0
        for ts, delta in edges:
            level += delta
            events.append({"ph": "C", "name": name, "pid": pid, "tid": 0,
                           "ts": ts * time_scale, "args": {"busy": level}})
        all_edges.extend(edges)
    if all_edges:
        all_edges.sort(key=lambda e: (e[0], e[1]))
        level = 0
        for ts, delta in all_edges:
            level += delta
            events.append({"ph": "C", "name": "pipeline active tasks",
                           "pid": pid, "tid": 0, "ts": ts * time_scale,
                           "args": {"active": level}})
    return events


def microbatch_flow_events(records, tid_of: dict, *, pid: int = SIM_PID,
                           time_scale: float = 1e6) -> list:
    """Flow arrows linking each micro-batch's forward transfer on hop
    ``a -> c`` to the matching backward transfer on ``c -> a`` — the
    visual round trip of one micro-batch through the pipeline."""
    fwd: dict = {}
    bwd: dict = {}
    for r in records:
        if r.resource[0] == "fwd":
            key = (r.microbatch, r.resource[1], r.resource[2])
            fwd.setdefault(key, []).append(r)
        elif r.resource[0] == "bwd":
            key = (r.microbatch, r.resource[2], r.resource[1])
            bwd.setdefault(key, []).append(r)
    events: list = []
    fid = 0
    for key in sorted(fwd):
        outs = sorted(fwd[key], key=lambda r: r.start)
        # the backward pass retraces the route in reverse, so the i-th
        # forward crossing of a repeated link pairs with the (last-i)-th
        # backward crossing
        backs = sorted(bwd.get(key, []), key=lambda r: r.start, reverse=True)
        for f, b in zip(outs, backs):
            fid += 1
            common = {"cat": "microbatch", "name": f"mb{key[0]}",
                      "id": fid, "pid": pid}
            events.append({**common, "ph": "s", "tid": tid_of[f.resource],
                           "ts": f.start * time_scale})
            events.append({**common, "ph": "f", "bp": "e",
                           "tid": tid_of[b.resource],
                           "ts": b.start * time_scale})
    return events


def solver_span_events(spans, *, pid: int = SOLVER_PID,
                       time_scale: float = 1e6,
                       t0: float | None = None) -> list:
    """Wall-clock "X" slices from finished ``obs.span()`` records, on one
    thread so properly nested spans render as stacked slices.  Times are
    rebased so the earliest span starts at ts 0 (``perf_counter`` has an
    arbitrary epoch)."""
    spans = list(spans)
    if not spans:
        return []
    if t0 is None:
        t0 = min(s.start for s in spans)
    events = [
        {"ph": "M", "name": "process_name", "pid": pid, "tid": 0,
         "args": {"name": "solver (wall clock)"}},
        {"ph": "M", "name": "thread_name", "pid": pid, "tid": 0,
         "args": {"name": "spans"}},
    ]
    for s in spans:
        events.append({
            "name": s.name, "ph": "X", "pid": pid, "tid": 0,
            "ts": (s.start - t0) * time_scale,
            "dur": max(s.end - s.start, 0.0) * time_scale,
            "args": {k: v for k, v in s.args},
        })
    return events


def validate_chrome_trace(data) -> list:
    """Check a loaded trace dict against the Chrome trace-event schema
    subset this repo emits (phase/ts/dur/pid/tid types).  Returns a list
    of problem strings — empty means valid.  Used by the CI smoke job on
    ``examples/simulate_pipeline.py``'s output."""
    if not isinstance(data, dict) or \
            not isinstance(data.get("traceEvents"), list):
        return ["top level must be an object with a 'traceEvents' list"]
    errs: list = []
    for i, ev in enumerate(data["traceEvents"]):
        if not isinstance(ev, dict):
            errs.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        if not isinstance(ph, str) or len(ph) != 1:
            errs.append(f"event {i}: 'ph' must be a 1-char phase string")
            continue
        for field in ("pid", "tid"):
            if not isinstance(ev.get(field), int):
                errs.append(f"event {i} ({ph}): '{field}' must be an int")
        if ph != "M" and not isinstance(ev.get("ts"), (int, float)):
            errs.append(f"event {i} ({ph}): 'ts' must be a number")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errs.append(f"event {i}: X event needs a non-negative 'dur'")
        if ph in ("s", "t", "f") and "id" not in ev:
            errs.append(f"event {i}: flow event ({ph}) needs an 'id'")
        if not isinstance(ev.get("name", ""), str):
            errs.append(f"event {i} ({ph}): 'name' must be a string")
    return errs
