"""repro.obs — zero-overhead-when-disabled telemetry (ISSUE 6).

Three pillars:

* **Idle/bubble accounting** (``utilization``): per-resource
  busy/blocked/fill/bubble/drain interval decomposition from either
  engine's output, surfaced as ``SimReport.utilization()`` and checked
  against the Eq. (12)-(14) closed form — the paper's "resource
  idleness" motivation turned into a measured quantity.
* **Span tracing** (``spans``): ``with obs.span("planner.solve"): ...``
  wall-clock instrumentation through the planner, BCD loop, cost models,
  simulator dispatch, and replanning coordinator, exportable to one
  Perfetto file next to the simulated-time pipeline tracks.
* **Counters** (``registry``): DP-cache and solve-memo hit rates,
  engine-dispatch tallies, fixpoint sweep counts, memoized-cost-model
  hit rates — dumped by the benchmark drivers alongside their CSVs.

Everything is off until :func:`enable` (or ``enabled_scope``); while
disabled the instrumentation costs a global load plus a branch per call
site and allocates nothing (``benchmarks/bench_obs.py`` enforces < 5%
overhead even *enabled* on the 10k-micro-batch chain).
"""

from .registry import (Registry, counter, disable, dump, enable, enabled,
                       enabled_scope, get_registry, inc, reset)
from .spans import SpanRecord, span, span_summary, wall_spans
from .trace import (SIM_PID, SOLVER_PID, microbatch_flow_events,
                    solver_span_events, utilization_counter_events,
                    validate_chrome_trace)
from .utilization import (ResourceUtilization, UtilizationReport,
                          accumulate_service, busy_fractions,
                          resource_sort_key, resource_traces,
                          service_from_records, utilization_from_records,
                          utilization_from_timeline)

__all__ = [
    "Registry", "counter", "disable", "dump", "enable", "enabled",
    "enabled_scope", "get_registry", "inc", "reset",
    "SpanRecord", "span", "span_summary", "wall_spans",
    "SIM_PID", "SOLVER_PID", "microbatch_flow_events", "solver_span_events",
    "utilization_counter_events", "validate_chrome_trace",
    "ResourceUtilization", "UtilizationReport", "accumulate_service",
    "busy_fractions", "resource_sort_key", "resource_traces",
    "service_from_records", "utilization_from_records",
    "utilization_from_timeline",
]
