"""Process-local counter/span registry — zero overhead when disabled.

Telemetry is off by default.  Instrumented call sites go through the
module-level :func:`inc` / ``spans.span`` entry points, which cost one
global load plus a branch while disabled and allocate nothing, so the
hot paths (the vectorized engine, the threshold-batched planner) pay no
measurable tax (``benchmarks/bench_obs.py`` enforces this).

Counter names are dotted strings (``"planner.solve_memo_hit"``,
``"sim.fixpoint_sweeps"``); histogram-style tallies embed the bucket in
the name (``"sim.engine_reason[vectorized: ...]"``).  The registry is
process-local and deliberately lock-free: counters are advisory
telemetry, and the single-threaded planner/simulator never race on it.
"""

from __future__ import annotations

import contextlib
import json
import os


class Registry:
    """A process-local bag of named counters and finished spans.

    ``inc`` here is unconditional — the guarded module-level :func:`inc`
    is what instrumented code calls.
    """

    __slots__ = ("counters", "spans")

    def __init__(self):
        self.counters: dict = {}
        self.spans: list = []

    def inc(self, name: str, n=1) -> None:
        self.counters[name] = self.counters.get(name, 0) + n

    def snapshot(self) -> dict:
        """A point-in-time copy of the counters (cheap; used by tests to
        assert disabled-mode is a true no-op)."""
        return dict(self.counters)

    def reset(self) -> None:
        self.counters.clear()
        self.spans.clear()


_ENABLED = False
_REGISTRY = Registry()


def enabled() -> bool:
    return _ENABLED


def enable() -> None:
    global _ENABLED
    _ENABLED = True


def disable() -> None:
    global _ENABLED
    _ENABLED = False


@contextlib.contextmanager
def enabled_scope(on: bool = True):
    """Temporarily flip telemetry on (or off) around a block; yields the
    process registry.  The previous state is always restored."""
    global _ENABLED
    prev = _ENABLED
    _ENABLED = on
    try:
        yield _REGISTRY
    finally:
        _ENABLED = prev


def get_registry() -> Registry:
    return _REGISTRY


def inc(name: str, n=1) -> None:
    """Guarded hot-path increment: a global load + branch when disabled."""
    if _ENABLED:
        _REGISTRY.inc(name, n)


def counter(name: str):
    """Current value of one counter (0 when never incremented)."""
    return _REGISTRY.counters.get(name, 0)


def reset() -> None:
    """Clear all counters and recorded spans (the enabled flag is kept)."""
    _REGISTRY.reset()


def dump(path: str) -> str:
    """Write the registry (counters + per-span-name rollup) as JSON —
    what the benchmark drivers drop alongside their CSVs."""
    from .spans import span_summary
    counters = _REGISTRY.counters
    payload = {
        "counters": {k: counters[k] for k in sorted(counters, key=str)},
        "spans": span_summary(),
    }
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, default=str)
    return path
