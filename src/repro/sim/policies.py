"""Pluggable micro-batch admission policies for the pipeline simulator.

PR 1's engine admitted every micro-batch at ``t_start`` — GPipe-like FIFO:
the client injects as fast as its forward engine drains, so a stage can hold
up to ``Q`` activations at once.  An :class:`AdmissionPolicy` generalizes
this: it assigns each pipeline stage an *admission window* — the number of
micro-batches allowed past that stage's forward pass before the stage's own
backward pass reclaims an activation.  Windows become precedence edges

    BP_j(m - window(j))  -->  FP_j(m)

added on top of the per-micro-batch chains, so both the heap engine and the
vectorized engine execute any policy without special cases.

Three concrete policies ship:

* :class:`FIFO` — unbounded windows; byte-for-byte the PR 1 behavior (no
  extra edges are generated, the event loop is untouched).  Activation
  high-water claim: ``Q`` per stage (GPipe).
* :class:`OneFOneB` — window ``S - j`` at stage ``j`` of an ``S``-stage
  pipeline (1F1B): once warm, each stage alternates one forward with one
  backward, holding at most ``S - j`` live activations.  Claim:
  ``min(Q, S - j)``.
* :class:`MemoryBudgeted` — windows derived from each node's actual memory
  budget (``Node.mem`` vs the Eq. (11) activation profile) instead of fixed
  1F1B depths; must be *bound* to a concrete plan first
  (``simulate_plan`` binds automatically via :meth:`AdmissionPolicy.bind`).
  Claim: ``min(Q, floor((mem_n - static_n) / act_n))`` per stage on node n
  — the same claims source ``pipeline.schedule.memory_highwater`` and
  ``core.microbatch.feasibility_box`` consume
  (``repro.core.cost_model.node_budget_windows``).

The closed-form claims (:meth:`AdmissionPolicy.stage_capacity`) are the
single source of truth shared with ``repro.pipeline.schedule``'s
``memory_highwater`` and are cross-validated *event by event* against the
engine's measured occupancy (:func:`activation_occupancy`) in
``tests/test_sim.py``.

>>> OneFOneB().stage_capacity(4, 8)
{0: 4, 1: 3, 2: 2, 3: 1}
>>> FIFO().stage_capacity(3, 8)
{0: 8, 1: 8, 2: 8}
"""

from __future__ import annotations


class AdmissionPolicy:
    """Strategy deciding when a micro-batch may enter each pipeline stage.

    Subclasses implement :meth:`window`.  A window of ``w`` at stage ``j``
    means micro-batch ``m``'s forward pass at ``j`` must wait for micro-batch
    ``m - w``'s backward pass at ``j`` — which bounds stage ``j``'s live
    activations by ``w``.  ``None`` means unbounded (no edge).  Stages are
    numbered by *position* ``j`` in the chain of non-empty submodels
    (``0 .. S-1``), not by raw submodel index.
    """

    name = "abstract"

    def window(self, num_stages: int, stage: int) -> int | None:
        raise NotImplementedError

    # -- plan binding -------------------------------------------------------
    def bind(self, profile, net, sol, b) -> "AdmissionPolicy":
        """Specialize the policy to a concrete plan.

        Stateless policies (FIFO, 1F1B) return ``self``; plan-dependent ones
        (:class:`MemoryBudgeted`) return a bound copy whose windows are
        derived from the instance.  ``simulate_plan`` calls this before
        execution, so callers can pass unbound policies everywhere.
        """
        return self

    def bind_many(self, profile, net, plans) -> list:
        """:meth:`bind` for many ``(sol, b)`` plans at once.  Plan-dependent
        policies override this with a batched derivation (one claims pass
        per distinct split instead of one per candidate) —
        ``simulate_plans``' binding hot path."""
        return [self.bind(profile, net, sol, b) for sol, b in plans]

    def schedulable(self) -> bool:
        """False when some window is 0 — admitting even one micro-batch
        would exceed a budget, so execution must be refused (a 0-window
        edge set would deadlock the pipeline)."""
        return True

    # -- closed-form memory claim -------------------------------------------
    def stage_capacity(self, num_stages: int, num_microbatches: int) -> dict:
        """Claimed activation high-water mark per stage position.

        ``Q`` micro-batches can never exceed ``Q`` live activations, so every
        claim is clipped by ``num_microbatches``.
        """
        out = {}
        for j in range(num_stages):
            w = self.window(num_stages, j)
            out[j] = (num_microbatches if w is None
                      else min(num_microbatches, w))
        return out

    # -- edge generation for the heap engine --------------------------------
    def extra_dependencies(self, tasks) -> list:
        """``(src_tid, dst_tid)`` precedence edges encoding the windows.

        ``tasks`` is the chain task list from ``engine.build_tasks`` (any
        iterable of ``events.Task``); tid order within one micro-batch is
        chain order, so the j-th "fp" task of a micro-batch is stage position
        j and the "bp" tasks appear in reverse position order.
        """
        fp_by_mb: dict = {}
        bp_by_mb: dict = {}
        for t in sorted(tasks, key=lambda t: t.tid):
            if t.kind == "fp":
                fp_by_mb.setdefault(t.microbatch, []).append(t.tid)
            elif t.kind == "bp":
                bp_by_mb.setdefault(t.microbatch, []).append(t.tid)
        if not fp_by_mb:
            return []
        S = len(fp_by_mb[min(fp_by_mb)])
        windows = [self.window(S, j) for j in range(S)]
        edges = []
        for m, fps in fp_by_mb.items():
            for j, w in enumerate(windows):
                if w is None or m - w < 0:
                    continue
                # bp tasks run positions S-1 .. 0, so position j is entry
                # S-1-j of the earlier micro-batch's bp list
                src = bp_by_mb[m - w][S - 1 - j]
                edges.append((src, fps[j]))
        return edges


class FIFO(AdmissionPolicy):
    """GPipe-like admission (PR 1 behavior): every micro-batch is admitted
    immediately; stages buffer up to ``Q`` activations."""

    name = "fifo"

    def window(self, num_stages: int, stage: int) -> int | None:
        return None


class OneFOneB(AdmissionPolicy):
    """1F1B admission: stage ``j`` of ``S`` holds at most ``S - j``
    activations — the memory-aware schedule of PipeDream/1F1B, matching the
    claim reported by ``repro.pipeline.schedule``."""

    name = "1f1b"

    def window(self, num_stages: int, stage: int) -> int | None:
        return num_stages - stage


class MemoryBudgeted(AdmissionPolicy):
    """Admission windows derived from node memory budgets (ROADMAP item).

    Instead of 1F1B's fixed ``S - j`` depths, stage ``j`` on node ``n`` gets
    the largest window ``w`` whose live activations actually fit:
    ``static_n + w * act_n <= mem_n`` with the static/activation split of
    Eq. (11) (``repro.core.cost_model.node_budget_windows`` — the claims
    source shared with ``pipeline.schedule.memory_highwater`` and the
    planner's feasible-b box).  Co-located stages share their node's budget
    and therefore its window.

    The windows depend on ``(profile, net, sol, b)``, so the policy must be
    *bound* before use; ``simulate_plan`` binds automatically:

    >>> import numpy as np
    >>> from repro.core import EdgeNetwork, Node, SplitSolution, uniform_profile
    >>> prof = uniform_profile(4, fp=1.0, bp=1.0, act=1.0, param=1.0)
    >>> nodes = [Node("c", f=1.0, is_client=True, mem=100.0),
    ...          Node("s", f=1.0, mem=14.0)]
    >>> net = EdgeNetwork(nodes=nodes, rate=np.full((2, 2), 10.0),
    ...                   num_clients=1)
    >>> sol = SplitSolution(cuts=(2, 4), placement=(0, 1))
    >>> pol = MemoryBudgeted().bind(prof, net, sol, b=1)
    >>> pol.window(2, 1)        # server: (14 - 4 static) // (2*2 act) = 2
    2
    >>> pol.stage_capacity(2, 8)[1]
    2
    """

    name = "memory"

    def __init__(self, memory_model: str = "refined", tail=None):
        self.memory_model = memory_model
        self.tail = tail             # core.cost_model.DegradedTail or None:
        #                              windows sized for the degraded tail
        self._windows: tuple | None = None

    @property
    def bound(self) -> bool:
        return self._windows is not None

    def bind(self, profile, net, sol, b) -> "MemoryBudgeted":
        from repro.core.cost_model import node_budget_windows
        pol = MemoryBudgeted(self.memory_model, self.tail)
        pol._windows = tuple(node_budget_windows(profile, net, sol, b,
                                                 self.memory_model,
                                                 self.tail))
        return pol

    def bind_many(self, profile, net, plans) -> list:
        """Batched :meth:`bind`: one Eq. (11) claims pass per distinct
        split serves every micro-batch size
        (``cost_model.node_budget_windows_many``) — identical windows to
        one-at-a-time binding."""
        from repro.core.cost_model import node_budget_windows_many
        by_sol: dict = {}
        for i, (sol, b) in enumerate(plans):
            by_sol.setdefault((sol.cuts, sol.placement), []).append(i)
        out: list = [None] * len(plans)
        for idxs in by_sol.values():
            sol = plans[idxs[0]][0]
            wss = node_budget_windows_many(profile, net, sol,
                                           [plans[i][1] for i in idxs],
                                           self.memory_model, self.tail)
            for i, ws in zip(idxs, wss):
                pol = MemoryBudgeted(self.memory_model, self.tail)
                pol._windows = tuple(ws)
                out[i] = pol
        return out

    def schedulable(self) -> bool:
        if self._windows is None:
            return True
        return all(w is None or w >= 1 for w in self._windows)

    def window(self, num_stages: int, stage: int) -> int | None:
        if self._windows is None:
            raise RuntimeError(
                "MemoryBudgeted is plan-dependent: call "
                ".bind(profile, net, sol, b) first (simulate_plan binds "
                "automatically)")
        if num_stages != len(self._windows):
            raise ValueError(
                f"policy bound for {len(self._windows)} stages, asked about "
                f"a {num_stages}-stage pipeline")
        return self._windows[stage]


_POLICIES = {"fifo": FIFO, "gpipe": FIFO, "1f1b": OneFOneB,
             "memory": MemoryBudgeted, "memory_budgeted": MemoryBudgeted}


def resolve_policy(policy) -> AdmissionPolicy:
    """Accept a policy instance or one of the registered names
    (``"fifo"``/``"gpipe"``/``"1f1b"``)."""
    if isinstance(policy, AdmissionPolicy):
        return policy
    try:
        return _POLICIES[str(policy).lower()]()
    except KeyError:
        raise ValueError(
            f"unknown admission policy {policy!r}; expected one of "
            f"{sorted(_POLICIES)} or an AdmissionPolicy instance") from None


# ---------------------------------------------------------------------------
# Measured activation occupancy (the engine side of the cross-validation)
# ---------------------------------------------------------------------------

def activation_occupancy(records) -> dict:
    """Per-stage time series of live activations, from a simulated timeline.

    A micro-batch's activation at stage position ``j`` is *live* from the
    start of its forward pass at ``j`` to the end of its backward pass at
    ``j``.  Returns ``{position: [(time, occupancy_after_event), ...]}`` with
    events in time order; releases are processed before acquisitions at equal
    times (the window edges allow a forward to start the instant the paired
    backward frees its slot).
    """
    fp_start: dict = {}
    bp_end: dict = {}
    stages = set()
    for r in records:
        if r.kind == "fp":
            fp_start[(r.stage, r.microbatch)] = r.start
            stages.add(r.stage)
        elif r.kind == "bp":
            bp_end[(r.stage, r.microbatch)] = r.end
    out = {}
    for j, stage in enumerate(sorted(stages)):
        events = []
        for (s, m), t in fp_start.items():
            if s == stage:
                events.append((t, 1, +1))
                events.append((bp_end[(s, m)], 0, -1))
        events.sort()
        series, occ = [], 0
        for t, _, delta in events:
            occ += delta
            series.append((t, occ))
        out[j] = series
    return out


def stage_activation_highwater(records) -> dict:
    """Measured activation high-water mark per stage position — the quantity
    the closed-form :meth:`AdmissionPolicy.stage_capacity` claims bound.

    >>> from repro.sim.events import TraceRecord
    >>> recs = [TraceRecord(m, 0, "fp", ("fp", 0), m, m + 1) for m in (0, 1)]
    >>> recs += [TraceRecord(m, 0, "bp", ("bp", 0), 3 + m, 4 + m) for m in (0, 1)]
    >>> stage_activation_highwater(recs)
    {0: 2}
    """
    return {j: max((occ for _, occ in series), default=0)
            for j, series in activation_occupancy(records).items()}
