"""Event-driven executor for a pipelined-SL plan.

Each micro-batch is a chain of tasks — client FP, per-hop activation
transfers, per-stage server FP, then BP and act-gradient transfers back —
and each task occupies one FIFO resource (node FP engine, node BP engine, or
a directed link; see ``events``).  The engine maintains a priority queue of
(time, seq) events; a resource serves one task at a time and tasks queue in
arrival order, so co-located submodels *contend* exactly as the per-node
sums of Eq. (13)/C9-C16 assume.

Consistency guarantee (the standing ``sim.validate`` cross-check): on a
deterministic network whose plan places every submodel on a distinct node,
each resource is visited exactly once per micro-batch — a permutation flow
shop with identical jobs — and the simulated makespan equals the analytical

    L_t = T_f + ceil((B - b)/b) * T_i                            (Eq. 14)

to float precision, with the simulated fill time equal to Eq. (12)'s T_f and
the steady-state completion interval equal to Eq. (13)'s bottleneck T_i.
Following the paper's accounting, every pipeline slot is charged a *full*
micro-batch of size b (the trailing remainder micro-batch is padded).

With a ``NetworkScenario``, task durations integrate the piecewise-constant
capacity traces from their start time (transfers stall through outages,
compute stretches through straggler windows), and ``simulate_with_replanning``
drives an ``ft.Coordinator`` from *simulated* time: at each trigger the
completed micro-batches are banked, the coordinator replans on the mutated
network, and the remainder of the mini-batch resumes under the new plan.
"""

from __future__ import annotations

import dataclasses
import heapq
import math
from collections import deque

import numpy as np

from repro.core.latency import (SplitSolution, bp_work, bwd_bytes, fp_work,
                                fwd_bytes, num_fills)
from repro.core.network import EdgeNetwork
from repro.core.profiles import ModelProfile
from .events import Task, TraceRecord
from .scenario import NetworkScenario, PiecewiseTrace, constant


# ---------------------------------------------------------------------------
# Task construction: one chain per micro-batch
# ---------------------------------------------------------------------------

def build_tasks(profile: ModelProfile, net: EdgeNetwork, sol: SplitSolution,
                b: int, num_microbatches: int) -> list:
    """The task DAG (here: disjoint chains) for ``num_microbatches``
    micro-batches of size ``b`` through ``sol``'s stage/placement chain.

    Work terms mirror Eqs. (2)/(5)/(7)/(9) exactly: compute work is
    ``eff_b * kappa_n * delta`` served at f_n, transfer work is the
    activation/act-gradient byte volume served at the link rate; the t0/t1
    constants ride along as rate-independent ``fixed`` seconds.
    """
    segs = list(sol.segments())
    if not segs:
        raise ValueError("solution has no non-empty submodels")
    tasks: list = []
    tid = 0
    for m in range(num_microbatches):
        prev = None
        # forward sweep: FP_k, then the k -> k+1 activation transfer
        for j, (k, lo, hi, node) in enumerate(segs):
            n = net.nodes[node]
            tasks.append(Task(tid, m, k, "fp", ("fp", node),
                              work=fp_work(profile, net, lo, hi, node, b),
                              fixed=n.t0, dep=prev))
            prev = tid
            tid += 1
            if j + 1 < len(segs):
                nxt = segs[j + 1][3]
                tasks.append(Task(tid, m, k, "fwd", ("fwd", node, nxt),
                                  work=fwd_bytes(profile, net, hi, b,
                                                 from_client=(node == 0)),
                                  dep=prev))
                prev = tid
                tid += 1
        # backward sweep: BP_k, then the k -> k-1 act-gradient transfer
        for j in range(len(segs) - 1, -1, -1):
            k, lo, hi, node = segs[j]
            n = net.nodes[node]
            tasks.append(Task(tid, m, k, "bp", ("bp", node),
                              work=bp_work(profile, net, lo, hi, node, b),
                              fixed=n.t1, dep=prev))
            prev = tid
            tid += 1
            if j > 0:
                _, _, hi_prev, below = segs[j - 1]
                # grads crossing cut hi_prev flow node -> below (Eq. 9/10)
                tasks.append(Task(tid, m, k, "bwd", ("bwd", node, below),
                                  work=bwd_bytes(profile, net, hi_prev, b,
                                                 to_client=(below == 0)),
                                  dep=prev))
                prev = tid
                tid += 1
    return tasks


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------

class _Resource:
    __slots__ = ("busy", "queue", "busy_time")

    def __init__(self):
        self.busy = False
        self.queue = deque()
        self.busy_time = 0.0


@dataclasses.dataclass
class SimReport:
    """Outcome of one simulation run."""
    records: list                # TraceRecord, in completion order
    mb_complete: np.ndarray      # absolute completion time per micro-batch
    t_start: float
    b: int
    num_microbatches: int
    resource_busy: dict          # resource -> busy fraction of the run

    @property
    def makespan(self) -> float:
        """Absolute time the last micro-batch drains."""
        return float(self.mb_complete[-1]) if len(self.mb_complete) else self.t_start

    @property
    def T_f(self) -> float:
        """Simulated fill latency — first micro-batch end-to-end (Eq. 12)."""
        return float(self.mb_complete[0] - self.t_start)

    @property
    def T_i(self) -> float:
        """Simulated steady-state interval — trailing completion gap
        (Eq. 13's bottleneck on deterministic networks)."""
        if len(self.mb_complete) < 2:
            return 0.0
        return float(self.mb_complete[-1] - self.mb_complete[-2])

    @property
    def L_t(self) -> float:
        """Simulated total latency (Eq. 14's counterpart)."""
        return self.makespan - self.t_start

    def intervals(self) -> np.ndarray:
        return np.diff(self.mb_complete)


class PipelineSimulator:
    """FIFO discrete-event simulator over a task set.

    Events are ordered by (time, insertion seq); ties therefore resolve
    causally and deterministically.  Task durations are computed at service
    start by integrating the resource's capacity trace — exact for the
    piecewise-constant scenarios (no preemption is needed because traces are
    exogenous).
    """

    def __init__(self, net: EdgeNetwork, tasks, *, b: int = 0,
                 scenario: NetworkScenario | None = None, t_start: float = 0.0):
        self.net = net
        self.tasks = {t.tid: t for t in tasks}
        self.b = b                   # micro-batch size, echoed in the report
        self.scenario = scenario
        self.t_start = t_start
        self._traces: dict = {}

    # -- capacity ------------------------------------------------------------
    def _trace(self, resource: tuple) -> PiecewiseTrace:
        tr = self._traces.get(resource)
        if tr is None:
            kind = resource[0]
            if kind in ("fp", "bp"):
                if self.scenario is not None:
                    tr = self.scenario.node_trace(self.net, resource[1])
                else:
                    tr = constant(self.net.nodes[resource[1]].f)
            else:
                a, c = resource[1], resource[2]
                if self.scenario is not None:
                    tr = self.scenario.link_trace(self.net, a, c)
                else:
                    tr = constant(self.net.rate[a, c])
            self._traces[resource] = tr
        return tr

    def _duration(self, task: Task, t: float) -> float:
        if task.work <= 0.0:
            return task.fixed
        tr = self._trace(task.resource)
        if len(tr.times) == 1:                 # constant capacity fast path
            v = tr.values[0]
            return task.fixed + (task.work / v if v > 0 else math.inf)
        return task.fixed + tr.time_to_complete(t + task.fixed, task.work)

    # -- event loop ----------------------------------------------------------
    def run(self) -> SimReport:
        succs: dict = {}
        indeg = {tid: 0 for tid in self.tasks}
        for t in self.tasks.values():
            if t.dep is not None:
                succs.setdefault(t.dep, []).append(t.tid)
                indeg[t.tid] += 1
        resources: dict = {}
        for t in self.tasks.values():
            resources.setdefault(t.resource, _Resource())

        heap: list = []
        seq = 0

        def push(time, kind, tid):
            nonlocal seq
            heapq.heappush(heap, (time, seq, kind, tid))
            seq += 1

        # roots become ready at t_start, in tid (= micro-batch) order
        for tid in sorted(t.tid for t in self.tasks.values() if indeg[t.tid] == 0):
            push(self.t_start, "ready", tid)

        records: list = []
        mb_done: dict = {}
        started: dict = {}

        def start(task: Task, now: float):
            res = resources[task.resource]
            res.busy = True
            started[task.tid] = now
            dur = self._duration(task, now)
            push(now + dur, "end", task.tid)

        while heap:
            now, _, kind, tid = heapq.heappop(heap)
            task = self.tasks[tid]
            res = resources[task.resource]
            if kind == "ready":
                if res.busy:
                    res.queue.append(task)
                else:
                    start(task, now)
            else:  # "end"
                t0 = started.pop(tid)
                records.append(TraceRecord(task.microbatch, task.stage,
                                           task.kind, task.resource, t0, now))
                res.busy = False
                res.busy_time += now - t0
                if res.queue:
                    start(res.queue.popleft(), now)
                for s in succs.get(tid, ()):
                    indeg[s] -= 1
                    if indeg[s] == 0:
                        push(now, "ready", s)
                prev = mb_done.get(task.microbatch, -math.inf)
                mb_done[task.microbatch] = max(prev, now)

        n_mb = 1 + max(mb_done) if mb_done else 0
        mb_complete = np.array([mb_done[m] for m in range(n_mb)])
        span = (float(mb_complete[-1]) - self.t_start) if n_mb else 0.0
        busy = {r: (res.busy_time / span if span > 0 else 0.0)
                for r, res in resources.items()}
        return SimReport(records=records, mb_complete=mb_complete,
                         t_start=self.t_start, b=self.b,
                         num_microbatches=n_mb, resource_busy=busy)


def simulate_plan(profile: ModelProfile, net: EdgeNetwork,
                  sol: SplitSolution, b: int, *, B: int | None = None,
                  num_microbatches: int | None = None,
                  scenario: NetworkScenario | None = None,
                  t_start: float = 0.0) -> SimReport:
    """Simulate ``sol`` end to end and report the timeline.

    Give either ``B`` (mini-batch size: ``1 + ceil((B-b)/b)`` full-size
    micro-batches, the paper's Eq. (14) accounting) or an explicit
    ``num_microbatches``.
    """
    if num_microbatches is None:
        if B is None:
            raise ValueError("pass B or num_microbatches")
        num_microbatches = 1 + num_fills(B, b)
    tasks = build_tasks(profile, net, sol, b, num_microbatches)
    return PipelineSimulator(net, tasks, b=b, scenario=scenario,
                             t_start=t_start).run()


# ---------------------------------------------------------------------------
# Replanning driver: ft.Coordinator on simulated time
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SegmentReport:
    """One inter-trigger stretch of the replanned run."""
    plan: object                 # the core.Plan in force during the segment
    report: SimReport            # full hypothetical run of the segment
    completed: int               # micro-batches banked before the cutoff
    cutoff: float                # absolute time the segment ended
    trigger: object | None       # ReplanTrigger that ended it (None = drain)
    outcome: object | None       # ft.ReplanOutcome for that trigger


@dataclasses.dataclass
class ReplanSimReport:
    makespan: float              # absolute time the mini-batch drains
    segments: list               # SegmentReport
    coordinator: object          # the driven ft.Coordinator (holds outcomes)

    @property
    def num_replans(self) -> int:
        return sum(1 for s in self.segments if s.trigger is not None)


def simulate_with_replanning(profile: ModelProfile, net: EdgeNetwork, B: int,
                             triggers=(), *, coordinator=None,
                             scenario: NetworkScenario | None = None,
                             remap_penalty: float = 0.0,
                             **coordinator_kwargs) -> ReplanSimReport:
    """Execute a mini-batch of ``B`` samples while ``ReplanTrigger``s fire
    at simulated times.  Triggers come from the ``triggers`` argument and/or
    ``scenario.replan_triggers`` (composed via ``with_replan``); both are
    merged and fired in time order.

    At each trigger: micro-batches fully drained by then are banked,
    in-flight ones are discarded (they re-run after the remap), the event is
    applied to the coordinator — mutating its network and replanning per the
    paper's BCD — and the remaining samples resume at
    ``trigger.time + remap_penalty`` under the new plan.  The physical
    effect of each event (slower node, changed rate, lost server) takes hold
    from its trigger time via the coordinator's mutated network.

    ``scenario`` capacity traces are keyed by node/link index; a
    ``NodeFailure`` renumbers the network's indices, so combining the two
    would silently apply traces to the wrong nodes — that combination is
    rejected.
    """
    from repro.ft.coordinator import Coordinator, NodeFailure  # local: avoid hard dep

    coord = coordinator or Coordinator(profile, net, B, **coordinator_kwargs)
    all_triggers = tuple(triggers)
    if scenario is not None:
        all_triggers += tuple(scenario.replan_triggers)
        if any(isinstance(tr.event, NodeFailure) for tr in all_triggers):
            raise ValueError(
                "NodeFailure triggers cannot be combined with a capacity "
                "scenario: degraded() renumbers node indices, so the "
                "scenario's index-keyed traces would land on the wrong "
                "nodes/links")
    segments: list = []
    t = 0.0
    samples_left = B
    for trig in sorted(all_triggers, key=lambda tr: tr.time):
        if samples_left <= 0:
            break
        plan = coord.plan
        if not plan.feasible or plan.b <= 0:
            break
        m = max(1, math.ceil(samples_left / plan.b))
        rep = simulate_plan(profile, coord.net, plan.solution, plan.b,
                            num_microbatches=m, scenario=scenario, t_start=t)
        if rep.makespan <= trig.time:
            # drained before the event fired — the run is simply over
            segments.append(SegmentReport(plan, rep, m, rep.makespan,
                                          None, None))
            return ReplanSimReport(rep.makespan, segments, coord)
        done = int(np.searchsorted(rep.mb_complete, trig.time, side="right"))
        samples_left = max(0, samples_left - done * plan.b)
        outcome = coord.apply(trig.event)
        segments.append(SegmentReport(plan, rep, done, trig.time, trig,
                                      outcome))
        t = trig.time + remap_penalty
    if samples_left > 0:
        plan = coord.plan
        if plan.feasible and plan.b > 0:
            m = max(1, math.ceil(samples_left / plan.b))
            rep = simulate_plan(profile, coord.net, plan.solution, plan.b,
                                num_microbatches=m, scenario=scenario,
                                t_start=t)
            segments.append(SegmentReport(plan, rep, m, rep.makespan,
                                          None, None))
            t = rep.makespan
        else:
            t = math.inf
    return ReplanSimReport(t, segments, coord)
