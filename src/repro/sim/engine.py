"""Event-driven executor for a pipelined-SL plan.

Each micro-batch is a chain of tasks — client FP, per-hop activation
transfers, per-stage server FP, then BP and act-gradient transfers back —
and each task occupies one FIFO resource (node FP engine, node BP engine, or
a directed link; see ``events``).  An :class:`~repro.sim.policies.AdmissionPolicy`
("fifo" = GPipe-like, "1f1b") adds window edges that gate when a micro-batch
may enter each stage.  Two engines execute the task set:

* **event** (default) — a priority queue of (time, seq) events; a resource
  serves one task at a time and tasks queue in arrival order, so co-located
  submodels *contend* exactly as the per-node sums of Eq. (13)/C9-C16
  assume.  Exact for every scenario; under the FIFO policy this is
  bit-identical to the PR 1 engine (the policy adds zero edges and the loop
  is untouched).
* **vectorized** — heap-free batched event advancement over the
  structure-of-arrays ``VisitTable``: because micro-batches are identical
  jobs, service start/end times obey the max-plus recurrence

      end[m, v] = serve_v(max(end[m, v-1], end[m-1, v], end[m-w_j, bp_j]))

  which collapses into ``numpy`` prefix-max scans (per *visit* for FIFO, per
  *micro-batch* for windowed policies).  Constant capacities keep the PR 2
  closed-form time-space scans; piecewise-constant traces run the same scans
  in *cumulative-work* coordinates (segmented scans split at the trace
  breakpoints); reentrant/co-located placements iterate per-resource
  merged scans to the unique self-consistent FIFO schedule — see
  :mod:`repro.sim.advance`.  ``engine="auto"`` therefore picks the
  vectorized engine for every piecewise-constant scenario; only an instance
  that can stall forever (zero trailing capacity on a used resource) is
  event-engine-only, and an explicit ``engine="vectorized"`` request then
  raises naming the violated precondition instead of silently falling back.
  A 10k-micro-batch x 100-node constant chain advances in ~0.15 s; the same
  chain under Gauss-Markov traces stays >= 10x ahead of the heap
  (BENCH_sim.json).  ``SimReport.engine_reason`` records which kernel ran.

Consistency guarantee (the standing ``sim.validate`` cross-check): on a
deterministic network whose plan places every submodel on a distinct node,
each resource is visited exactly once per micro-batch — a permutation flow
shop with identical jobs — and the simulated makespan equals the analytical

    L_t = T_f + ceil((B - b)/b) * T_i                            (Eq. 14)

to float precision, with the simulated fill time equal to Eq. (12)'s T_f and
the steady-state completion interval equal to Eq. (13)'s bottleneck T_i.
Following the paper's accounting, every pipeline slot is charged a *full*
micro-batch of size b (the trailing remainder micro-batch is padded).

With a ``NetworkScenario``, task durations integrate the piecewise-constant
capacity traces from their start time (transfers stall through outages,
compute stretches through straggler windows), and ``simulate_with_replanning``
drives an ``ft.Coordinator`` from *simulated* time: at each trigger the
completed micro-batches are banked, the coordinator replans on the mutated
network, and the remainder of the mini-batch resumes under the new plan.

A two-stage pipeline on a hand-built deterministic network (FP = BP = 2 s
per stage, transfers 0.1 s each way => T_f = 8.2 s, bottleneck T_i = 2 s):

>>> import numpy as np
>>> from repro.core import uniform_profile, EdgeNetwork, Node, SplitSolution
>>> prof = uniform_profile(4, fp=1.0, bp=1.0, act=1.0)
>>> nodes = [Node("c", f=1.0, t0=0.0, t1=0.0, b_th=0, is_client=True),
...          Node("s", f=1.0, t0=0.0, t1=0.0, b_th=0)]
>>> net = EdgeNetwork(nodes=nodes, rate=np.array([[0., 10.], [10., 0.]]),
...                   num_clients=1)
>>> sol = SplitSolution(cuts=(2, 4), placement=(0, 1))
>>> rep = simulate_plan(prof, net, sol, b=1, num_microbatches=3)
>>> round(rep.T_f, 6), round(rep.T_i, 6), round(rep.L_t, 6)
(8.2, 2.0, 12.2)

The vectorized engine reproduces the event engine; 1F1B admission bounds
activation memory (the last stage holds one live micro-batch instead of
three) at the cost of serializing that stage's FP+BP into the interval:

>>> fast = simulate_plan(prof, net, sol, b=1, num_microbatches=3,
...                      engine="vectorized", policy="1f1b")
>>> round(fast.T_i, 6), round(fast.L_t, 6)
(4.2, 16.4)
"""

from __future__ import annotations

import dataclasses
import heapq
import math
from collections import deque

import numpy as np

from repro.core.latency import (SplitSolution, bp_work, bwd_bytes, fp_work,
                                fwd_bytes, num_fills)
from repro.core.network import EdgeNetwork
from repro.core.profiles import ModelProfile
from repro.obs import (accumulate_service, busy_fractions, resource_traces,
                       service_from_records, utilization_from_records,
                       utilization_from_timeline)
from repro.obs import inc as obs_inc
from repro.obs import span as obs_span
from .advance import (VisitServe, fifo_pass, fixpoint_advance,
                      stack_eligible, stacked_fifo, stacked_fixpoint,
                      stacked_windowed, windowed_pass)
from .events import Task, Timeline, TraceRecord, VisitTable
from .policies import AdmissionPolicy, resolve_policy
from .scenario import NetworkScenario, PiecewiseTrace, constant


# ---------------------------------------------------------------------------
# Task construction: one chain per micro-batch
# ---------------------------------------------------------------------------

def build_tasks(profile: ModelProfile, net: EdgeNetwork, sol: SplitSolution,
                b: int, num_microbatches: int) -> list:
    """The task DAG (here: disjoint chains) for ``num_microbatches``
    micro-batches of size ``b`` through ``sol``'s stage/placement chain.

    Work terms mirror Eqs. (2)/(5)/(7)/(9) exactly: compute work is
    ``eff_b * kappa_n * delta`` served at f_n, transfer work is the
    activation/act-gradient byte volume served at the link rate; the t0/t1
    constants ride along as rate-independent ``fixed`` seconds.

    Derived from :func:`build_visit_table` — micro-batches are identical
    jobs, so the explicit task list is the visit chain repeated
    ``num_microbatches`` times with chain edges; keeping one source of
    truth for chain order, resources, and work terms is what lets the heap
    and vectorized engines be held bit-compatible.
    """
    table = build_visit_table(profile, net, sol, b)
    R = len(table)
    tasks: list = []
    for m in range(num_microbatches):
        base = m * R
        for v in range(R):
            tasks.append(Task(base + v, m, table.stages[v], table.kinds[v],
                              table.resources[v], work=float(table.work[v]),
                              fixed=float(table.fixed[v]),
                              dep=(base + v - 1) if v else None))
    return tasks


def build_visit_table(profile: ModelProfile, net: EdgeNetwork,
                      sol: SplitSolution, b: int) -> VisitTable:
    """Batched task construction: the structure-of-arrays task table.

    One row per *visit* in the per-micro-batch chain — client FP, per-hop
    activation transfer, ... , then BP and act-gradient transfers back —
    with the micro-batch axis implicit because every micro-batch is an
    identical job (the trailing remainder is padded to a full ``b``, the
    paper's Eq. (14) accounting).  ``build_tasks`` materializes explicit
    per-micro-batch chains from this table for the heap engine.
    """
    segs = list(sol.segments())
    if not segs:
        raise ValueError("solution has no non-empty submodels")
    kinds, stages, resources, work, fixed = [], [], [], [], []
    fp_visit, bp_visit = [0] * len(segs), [0] * len(segs)
    for j, (k, lo, hi, node) in enumerate(segs):
        fp_visit[j] = len(kinds)
        kinds.append("fp"); stages.append(k); resources.append(("fp", node))
        work.append(fp_work(profile, net, lo, hi, node, b))
        fixed.append(net.nodes[node].t0)
        if j + 1 < len(segs):
            nxt = segs[j + 1][3]
            kinds.append("fwd"); stages.append(k)
            resources.append(("fwd", node, nxt))
            work.append(fwd_bytes(profile, net, hi, b,
                                  from_client=(node == 0)))
            fixed.append(0.0)
    for j in range(len(segs) - 1, -1, -1):
        k, lo, hi, node = segs[j]
        bp_visit[j] = len(kinds)
        kinds.append("bp"); stages.append(k); resources.append(("bp", node))
        work.append(bp_work(profile, net, lo, hi, node, b))
        fixed.append(net.nodes[node].t1)
        if j > 0:
            _, _, hi_prev, below = segs[j - 1]
            kinds.append("bwd"); stages.append(k)
            resources.append(("bwd", node, below))
            work.append(bwd_bytes(profile, net, hi_prev, b,
                                  to_client=(below == 0)))
            fixed.append(0.0)
    return VisitTable(kinds=tuple(kinds), stages=tuple(stages),
                      resources=tuple(resources),
                      work=np.asarray(work, dtype=float),
                      fixed=np.asarray(fixed, dtype=float),
                      fp_visit=np.asarray(fp_visit, dtype=np.intp),
                      bp_visit=np.asarray(bp_visit, dtype=np.intp))


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------

class _Resource:
    __slots__ = ("busy", "queue")

    def __init__(self):
        self.busy = False
        self.queue = deque()


def resource_trace(net: EdgeNetwork, scenario: NetworkScenario | None,
                   resource: tuple) -> PiecewiseTrace:
    """Capacity trace serving ``resource`` — node compute rate for fp/bp
    engines, directed link rate for transfers, scaled by the scenario's
    multiplier traces when one is given.  The single dispatch shared by the
    heap engine's duration integration and the vectorized engine's
    constant-capacity gate."""
    if resource[0] in ("fp", "bp"):
        if scenario is not None:
            return scenario.node_trace(net, resource[1])
        return constant(net.nodes[resource[1]].f)
    a, c = resource[1], resource[2]
    if scenario is not None:
        return scenario.link_trace(net, a, c)
    return constant(net.rate[a, c])


@dataclasses.dataclass
class SimReport:
    """Outcome of one simulation run.

    ``records`` (the explicit timeline) is materialized lazily: the
    vectorized engine keeps the dense ``timeline`` arrays and only builds
    ``TraceRecord`` objects when asked — a 10k-micro-batch run would
    otherwise pay for millions of dataclasses nobody reads.
    """
    mb_complete: np.ndarray      # absolute completion time per micro-batch
    t_start: float
    b: int
    num_microbatches: int
    resource_busy: dict          # resource -> busy fraction of the run
    policy: str = "fifo"         # admission policy that produced the run
    engine: str = "event"        # which engine ran ("event" | "vectorized")
    engine_reason: str = ""      # why that engine / which kernel path ran
    timeline: Timeline | None = None   # dense SoA timeline (vectorized runs)
    _records: list | None = None       # eager records (event runs)

    @property
    def records(self) -> list:
        """TraceRecords in completion order (materialized on first use)."""
        if self._records is None:
            if self.timeline is None:
                return []
            self._records = self.timeline.to_records()
        return self._records

    @property
    def makespan(self) -> float:
        """Absolute time the last micro-batch drains."""
        return float(self.mb_complete[-1]) if len(self.mb_complete) else self.t_start

    @property
    def T_f(self) -> float:
        """Simulated fill latency — first micro-batch end-to-end (Eq. 12)."""
        return float(self.mb_complete[0] - self.t_start)

    @property
    def T_i(self) -> float:
        """Simulated steady-state interval — trailing completion gap
        (Eq. 13's bottleneck on deterministic networks)."""
        if len(self.mb_complete) < 2:
            return 0.0
        return float(self.mb_complete[-1] - self.mb_complete[-2])

    @property
    def L_t(self) -> float:
        """Simulated total latency (Eq. 14's counterpart)."""
        return self.makespan - self.t_start

    def intervals(self) -> np.ndarray:
        return np.diff(self.mb_complete)

    def utilization(self, *, net: EdgeNetwork | None = None,
                    scenario: NetworkScenario | None = None,
                    traces: dict | None = None):
        """Per-resource busy/idle/blocked decomposition of this run — an
        ``obs.UtilizationReport`` (fill/bubble/drain split, per-node and
        per-link idle fractions; the paper's Sec. I "resource idleness"
        measured from the executed schedule).

        Built straight from the dense SoA ``timeline`` on vectorized runs
        and from the eager ``TraceRecord``s on event runs — the two paths
        are parity-checked in ``sim.validate``.  Pass ``traces`` (resource
        -> capacity trace), or ``net`` together with ``scenario`` to derive
        them, to split occupancy into busy vs blocked (zero-capacity
        outage) time.  Stacked plan-axis scoring reports carry completion
        times only and cannot be decomposed.
        """
        if self.timeline is not None:
            if traces is None and scenario is not None:
                if net is None:
                    raise ValueError("pass net together with scenario")
                traces = resource_traces(net, scenario,
                                         set(self.timeline.table.resources))
            return utilization_from_timeline(self.timeline, self.t_start,
                                             self.makespan, traces=traces)
        if self._records is not None:
            if traces is None and scenario is not None:
                if net is None:
                    raise ValueError("pass net together with scenario")
                traces = resource_traces(net, scenario,
                                         {r.resource for r in self._records})
            return utilization_from_records(self._records, self.t_start,
                                            self.makespan, traces=traces)
        raise ValueError(
            "this report carries completion times only (stacked plan-axis "
            "scoring path); re-simulate with simulate_plan for a timeline")


class PipelineSimulator:
    """FIFO-resource discrete-event simulator over a task set.

    Events are ordered by (time, insertion seq); ties therefore resolve
    causally and deterministically.  Task durations are computed at service
    start by integrating the resource's capacity trace — exact for the
    piecewise-constant scenarios (no preemption is needed because traces are
    exogenous).  The admission ``policy`` contributes extra precedence edges
    (none for FIFO — that path is bit-identical to the PR 1 engine).
    """

    def __init__(self, net: EdgeNetwork, tasks, *, b: int = 0,
                 scenario: NetworkScenario | None = None, t_start: float = 0.0,
                 policy: AdmissionPolicy | str = "fifo", extra_deps=()):
        self.net = net
        self.tasks = {t.tid: t for t in tasks}
        self.b = b                   # micro-batch size, echoed in the report
        self.scenario = scenario
        self.t_start = t_start
        self.policy = resolve_policy(policy)
        self.extra_deps = (tuple(extra_deps) +
                           tuple(self.policy.extra_dependencies(tasks)))
        self._traces: dict = {}

    # -- capacity ------------------------------------------------------------
    def _trace(self, resource: tuple) -> PiecewiseTrace:
        tr = self._traces.get(resource)
        if tr is None:
            tr = resource_trace(self.net, self.scenario, resource)
            self._traces[resource] = tr
        return tr

    def _duration(self, task: Task, t: float) -> float:
        if task.work <= 0.0:
            return task.fixed
        tr = self._trace(task.resource)
        if len(tr.times) == 1:                 # constant capacity fast path
            v = tr.values[0]
            return task.fixed + (task.work / v if v > 0 else math.inf)
        return task.fixed + tr.time_to_complete(t + task.fixed, task.work)

    # -- event loop ----------------------------------------------------------
    def run(self) -> SimReport:
        succs: dict = {}
        indeg = {tid: 0 for tid in self.tasks}
        for t in self.tasks.values():
            if t.dep is not None:
                succs.setdefault(t.dep, []).append(t.tid)
                indeg[t.tid] += 1
        for src, dst in self.extra_deps:       # admission-policy window edges
            succs.setdefault(src, []).append(dst)
            indeg[dst] += 1
        resources: dict = {}
        for t in self.tasks.values():
            resources.setdefault(t.resource, _Resource())

        heap: list = []
        seq = 0

        def push(time, kind, tid):
            nonlocal seq
            heapq.heappush(heap, (time, seq, kind, tid))
            seq += 1

        # roots become ready at t_start, in tid (= micro-batch) order
        for tid in sorted(t.tid for t in self.tasks.values() if indeg[t.tid] == 0):
            push(self.t_start, "ready", tid)

        records: list = []
        mb_done: dict = {}
        started: dict = {}

        def start(task: Task, now: float):
            res = resources[task.resource]
            res.busy = True
            started[task.tid] = now
            dur = self._duration(task, now)
            push(now + dur, "end", task.tid)

        while heap:
            now, _, kind, tid = heapq.heappop(heap)
            task = self.tasks[tid]
            res = resources[task.resource]
            if kind == "ready":
                if res.busy:
                    res.queue.append(task)
                else:
                    start(task, now)
            else:  # "end"
                t0 = started.pop(tid)
                records.append(TraceRecord(task.microbatch, task.stage,
                                           task.kind, task.resource, t0, now))
                res.busy = False
                if res.queue:
                    start(res.queue.popleft(), now)
                for s in succs.get(tid, ()):
                    indeg[s] -= 1
                    if indeg[s] == 0:
                        push(now, "ready", s)
                prev = mb_done.get(task.microbatch, -math.inf)
                mb_done[task.microbatch] = max(prev, now)

        n_mb = 1 + max(mb_done) if mb_done else 0
        mb_complete = np.array([mb_done[m] for m in range(n_mb)])
        span = (float(mb_complete[-1]) - self.t_start) if n_mb else 0.0
        # per-visit-stream sums folded by the shared obs helpers, so both
        # engines accumulate resource occupancy identically (ISSUE 6 fix)
        busy = busy_fractions(service_from_records(records), span)
        return SimReport(mb_complete=mb_complete,
                         t_start=self.t_start, b=self.b,
                         num_microbatches=n_mb, resource_busy=busy,
                         policy=self.policy.name, engine="event",
                         _records=records)


# ---------------------------------------------------------------------------
# Vectorized engine: heap-free batched event advancement
# ---------------------------------------------------------------------------

def _serve_models(table: VisitTable, net: EdgeNetwork,
                  scenario: NetworkScenario | None):
    """``(serves, why)``: the per-visit serving models, plus the violated
    vectorized-engine precondition as a string (``None`` when eligible).

    Since the trace and reentrant generalizations, the only remaining
    precondition is *finite service*: a visit whose resource has zero
    constant capacity, or whose trace ends at zero capacity, can stall
    forever — the unbounded-``inf`` bookkeeping is heap territory.  The
    single gate shared by :func:`vectorizable`, :func:`simulate_plan` and
    :func:`simulate_plans` so they can never drift.
    """
    serves = []
    why = None
    for v, res in enumerate(table.resources):
        s = VisitServe(resource_trace(net, scenario, res), table.work[v],
                       table.fixed[v])
        if why is None and not s.finite():
            why = (f"resource {res!r} cannot finish its work (zero trailing "
                   "capacity stalls forever)")
        serves.append(s)
    return serves, why


def vectorizable(profile: ModelProfile, net: EdgeNetwork, sol: SplitSolution,
                 b: int, scenario: NetworkScenario | None = None) -> bool:
    """True when the vectorized engine covers this instance — piecewise-
    constant (including constant) capacities with finite service.
    Reentrant/co-located placements are handled by the merged-scan fixpoint
    (see :mod:`repro.sim.advance`); only an instance where some visit can
    stall forever on zero trailing capacity is event-engine-only."""
    table = build_visit_table(profile, net, sol, b)
    return _serve_models(table, net, scenario)[1] is None


def _empty_report(table: VisitTable, policy: AdmissionPolicy,
                  t_start: float, b: int, reason: str) -> SimReport:
    """Zero-micro-batch run, matching the event engine's empty report."""
    empty = np.empty((0, len(table)))
    return SimReport(mb_complete=np.empty(0), t_start=t_start, b=b,
                     num_microbatches=0, resource_busy={},
                     policy=policy.name, engine="vectorized",
                     engine_reason=reason,
                     timeline=Timeline(table=table, starts=empty,
                                       ends=empty))


def _vectorized_run(table: VisitTable, durations: np.ndarray, Q: int,
                    policy: AdmissionPolicy, t_start: float, b: int
                    ) -> SimReport:
    """Batched event advancement over the SoA task table — the constant-
    capacity, distinct-placement scans (the PR 2 kernels, kept verbatim as
    the bit-stable fast path; :mod:`repro.sim.advance` holds the trace and
    reentrant generalizations).

    Identical jobs through a chain of dedicated FIFO resources obey

        end[m, v] = d_v + max(end[m, v-1], end[m-1, v], feedback)

    where ``feedback = end[m - w_j, bp_j]`` for FP visits gated by a policy
    window ``w_j``.  Fixing one index collapses the other into a prefix-max
    scan: with no windows (FIFO) we sweep the R visits, each an
    ``np.maximum.accumulate`` over all Q micro-batches; with windows (1F1B)
    we sweep the Q micro-batches, each an accumulate over the R visits with
    the window feedback gathered from earlier rows.  Either way the run is
    O(Q*R) numpy work with no heap and no per-task Python objects.
    """
    d = durations
    R = len(d)
    S = table.num_stages
    windows = [policy.window(S, j) for j in range(S)]
    ends = np.empty((Q, R))
    rmat = np.empty((Q, R))      # per-task ready time from non-chain deps

    if all(w is None for w in windows):
        # FIFO: visit-major sweep; e_v[m] = (m+1) d_v + cummax(a[m] - m d_v)
        idx = np.arange(Q, dtype=float)
        prev = np.full(Q, t_start)
        for v in range(R):
            dv = d[v]
            ends[:, v] = (idx + 1.0) * dv + np.maximum.accumulate(
                prev - idx * dv)
            prev = ends[:, v]
        rmat[0, :] = t_start
        rmat[1:, :] = ends[:-1, :]
    else:
        # windowed (e.g. 1F1B): micro-batch-major sweep with feedback edges
        D = np.cumsum(d)
        Dsh = np.concatenate(([0.0], D[:-1]))
        gated = np.array([j for j, w in enumerate(windows) if w is not None],
                         dtype=np.intp)
        fb_fp = table.fp_visit[gated]
        fb_bp = table.bp_visit[gated]
        fb_w = np.array([windows[j] for j in gated], dtype=np.intp)
        for m in range(Q):
            r = rmat[m]
            if m == 0:
                r[:] = t_start
            else:
                r[:] = ends[m - 1]
                src = m - fb_w
                sel = src >= 0
                if sel.any():
                    r[fb_fp[sel]] = np.maximum(r[fb_fp[sel]],
                                               ends[src[sel], fb_bp[sel]])
            ends[m] = D + np.maximum.accumulate(r - Dsh)

    chain_prev = np.concatenate(
        (np.full((Q, 1), t_start), ends[:, :-1]), axis=1)
    starts = np.maximum(chain_prev, rmat)
    mb_complete = ends[:, -1].copy()
    span = float(mb_complete[-1]) - t_start if Q else 0.0
    # constant capacities: per-visit service is exactly Q * d_v — O(R),
    # no (Q, R) reduction — folded through the shared obs accumulation
    busy = busy_fractions(accumulate_service(table.resources, Q * d), span)
    windowed = any(w is not None for w in windows)
    reason = ("vectorized: constant-capacity windowed scan" if windowed
              else "vectorized: constant-capacity column scans")
    return SimReport(mb_complete=mb_complete, t_start=t_start, b=b,
                     num_microbatches=Q, resource_busy=busy,
                     policy=policy.name, engine="vectorized",
                     engine_reason=reason,
                     timeline=Timeline(table=table, starts=starts, ends=ends))


def _report_from_matrices(table: VisitTable, starts: np.ndarray,
                          ends: np.ndarray, Q: int, policy: AdmissionPolicy,
                          t_start: float, b: int, reason: str) -> SimReport:
    """Assemble a report from dense (Q, R) start/end matrices.  Busy
    fractions are summed per resource (reentrant tables visit a resource
    several times per micro-batch)."""
    mb_complete = ends[:, -1].copy()
    span = float(mb_complete[-1]) - t_start if Q else 0.0
    service = (ends - starts).sum(axis=0)
    busy = busy_fractions(accumulate_service(table.resources, service), span)
    return SimReport(mb_complete=mb_complete, t_start=t_start, b=b,
                     num_microbatches=Q, resource_busy=busy,
                     policy=policy.name, engine="vectorized",
                     engine_reason=reason,
                     timeline=Timeline(table=table, starts=starts, ends=ends))


def _run_vectorized(table: VisitTable, serves, Q: int,
                    policy: AdmissionPolicy, t_start: float,
                    b: int) -> SimReport | None:
    """Dispatch one eligible instance to the right kernel.  Returns ``None``
    only when the reentrant fixpoint failed to converge (the caller decides
    between event-engine fallback and raising)."""
    S = table.num_stages
    windows = [policy.window(S, j) for j in range(S)]
    windowed = any(w is not None for w in windows)
    if not table.is_reentrant():
        if all(s.const_d is not None for s in serves):
            d = np.array([s.const_d for s in serves])
            return _vectorized_run(table, d, Q, policy, t_start, b)
        if not windowed:
            starts, ends = fifo_pass(serves, Q, t_start)
            reason = "vectorized: segmented trace column scans"
        else:
            starts, ends = windowed_pass(serves, table, windows, Q, t_start)
            reason = "vectorized: trace micro-batch-major scan"
        return _report_from_matrices(table, starts, ends, Q, policy, t_start,
                                     b, reason)
    got = fixpoint_advance(table, serves, windows, Q, t_start)
    if got is None:
        return None
    starts, ends, sweeps = got
    obs_inc("sim.fixpoint_runs")
    obs_inc("sim.fixpoint_sweeps", sweeps)
    return _report_from_matrices(
        table, starts, ends, Q, policy, t_start, b,
        f"vectorized: reentrant merged-scan fixpoint ({sweeps} sweeps)")


def simulate_plan(profile: ModelProfile, net: EdgeNetwork,
                  sol: SplitSolution, b: int, *, B: int | None = None,
                  num_microbatches: int | None = None,
                  scenario: NetworkScenario | None = None,
                  t_start: float = 0.0,
                  policy: AdmissionPolicy | str = "fifo",
                  engine: str = "event") -> SimReport:
    """Simulate ``sol`` end to end and report the timeline.

    Give either ``B`` (mini-batch size: ``1 + ceil((B-b)/b)`` full-size
    micro-batches, the paper's Eq. (14) accounting) or an explicit
    ``num_microbatches``.  ``policy`` selects micro-batch admission ("fifo"
    is the GPipe-like PR 1 behavior, "1f1b" the fixed-depth schedule,
    "memory" the ``Node.mem``-derived windows); plan-dependent policies are
    bound to ``(profile, net, sol, b)`` here, and a plan whose budget cannot
    hold even one live micro-batch is refused with ``ValueError``.
    ``engine`` picks the executor: "event" (default; exact everywhere,
    bit-identical FIFO timelines), "vectorized" (heap-free batched
    advancement — constant *and* piecewise-constant traces, distinct *and*
    reentrant placements; raises naming the violated precondition when it
    cannot run the instance — see :func:`vectorizable`), or "auto"
    (vectorized whenever it covers the instance, event otherwise).  The
    report's ``engine_reason`` records which kernel ran, or why the event
    engine was selected.
    """
    with obs_span("sim.simulate_plan", engine=engine):
        rep = _simulate_plan(profile, net, sol, b, B=B,
                             num_microbatches=num_microbatches,
                             scenario=scenario, t_start=t_start,
                             policy=policy, engine=engine)
    obs_inc("sim.dispatch." + rep.engine)
    obs_inc("sim.engine_reason[" + rep.engine_reason.split(" (")[0] + "]")
    return rep


def _simulate_plan(profile: ModelProfile, net: EdgeNetwork,
                   sol: SplitSolution, b: int, *, B: int | None = None,
                   num_microbatches: int | None = None,
                   scenario: NetworkScenario | None = None,
                   t_start: float = 0.0,
                   policy: AdmissionPolicy | str = "fifo",
                   engine: str = "event") -> SimReport:
    if num_microbatches is None:
        if B is None:
            raise ValueError("pass B or num_microbatches")
        num_microbatches = 1 + num_fills(B, b)
    if engine not in ("event", "vectorized", "auto"):
        raise ValueError(f"unknown engine {engine!r}: "
                         "expected 'event', 'vectorized' or 'auto'")
    pol = resolve_policy(policy).bind(profile, net, sol, b)
    if not pol.schedulable():
        raise ValueError(
            f"plan is memory-infeasible under the {pol.name!r} admission "
            f"policy at b={b}: some stage cannot hold even one live "
            "micro-batch within its node's memory budget")
    event_reason = "event: requested"
    if engine in ("vectorized", "auto"):
        table = build_visit_table(profile, net, sol, b)
        serves, why = _serve_models(table, net, scenario)
        if why is None:
            if num_microbatches == 0:
                return _empty_report(table, pol, t_start, b,
                                     "vectorized: empty run")
            rep = _run_vectorized(table, serves, num_microbatches, pol,
                                  t_start, b)
            if rep is not None:
                return rep
            why = ("reentrant merged-scan fixpoint did not converge "
                   "on this instance")
        if engine == "vectorized":
            raise ValueError(
                f"vectorized engine cannot run this instance: {why}; "
                "use engine='auto' or 'event'")
        event_reason = f"event: {why}"
    tasks = build_tasks(profile, net, sol, b, num_microbatches)
    rep = PipelineSimulator(net, tasks, b=b, scenario=scenario,
                            t_start=t_start, policy=pol).run()
    rep.engine_reason = event_reason
    return rep


def simulate_plans(profile: ModelProfile, net: EdgeNetwork, plans, *,
                   B: int | None = None,
                   num_microbatches: list | None = None,
                   scenario: NetworkScenario | None = None,
                   t_start: float = 0.0,
                   policy: AdmissionPolicy | str = "fifo",
                   engine: str = "auto") -> list:
    """Batched :func:`simulate_plan` over many candidate plans.

    ``plans`` is a sequence of ``(sol, b)`` pairs sharing one mini-batch
    ``B`` (or explicit per-plan ``num_microbatches``); the return is the
    list of :class:`SimReport`, one per plan, identical to looping
    ``simulate_plan`` — that identity is asserted in tests.  Plans whose
    instance is constant-capacity and non-reentrant are *stacked along a
    leading plan axis* through :func:`repro.sim.advance.stacked_fifo` /
    :func:`~repro.sim.advance.stacked_windowed` (one set of numpy scans for
    the whole group, mirroring the planner's threshold-batched kernel);
    everything else — traces, reentrant fixpoints, event-engine fallbacks —
    runs per plan.  Stacked reports carry ``timeline=None`` (completion
    times only): they exist to score candidates, not to be inspected.

    This is the ``CostModel.evaluate_many`` hot path: a micro-batch
    refinement sweep evaluates tens of ``(cuts, placement, b)`` candidates,
    and per-call python overhead — task construction, policy binding aside,
    kernel dispatch — was the dominant cost of sim-in-the-loop planning.
    """
    plans = list(plans)
    with obs_span("sim.simulate_plans", n=len(plans)):
        reports = _simulate_plans(profile, net, plans, B=B,
                                  num_microbatches=num_microbatches,
                                  scenario=scenario, t_start=t_start,
                                  policy=policy, engine=engine)
    for rep in reports:
        obs_inc("sim.dispatch." + rep.engine)
        obs_inc("sim.engine_reason[" + rep.engine_reason.split(" (")[0] + "]")
    return reports


def _simulate_plans(profile: ModelProfile, net: EdgeNetwork, plans, *,
                    B: int | None = None,
                    num_microbatches: list | None = None,
                    scenario: NetworkScenario | None = None,
                    t_start: float = 0.0,
                    policy: AdmissionPolicy | str = "fifo",
                    engine: str = "auto") -> list:
    plans = list(plans)
    if num_microbatches is None:
        if B is None:
            raise ValueError("pass B or num_microbatches")
        qs = [1 + num_fills(B, b) for _, b in plans]
    else:
        qs = list(num_microbatches)
        if len(qs) != len(plans):
            raise ValueError("num_microbatches must align with plans")
    base_pol = resolve_policy(policy)
    bound = base_pol.bind_many(profile, net, plans)
    preps = []
    for (sol, b), Q, pol in zip(plans, qs, bound):
        if not pol.schedulable():
            raise ValueError(
                f"plan is memory-infeasible under the {pol.name!r} "
                f"admission policy at b={b}")
        table = build_visit_table(profile, net, sol, b)
        serves, why = _serve_models(table, net, scenario)
        windows = [pol.window(table.num_stages, j)
                   for j in range(table.num_stages)]
        stackable = (engine in ("auto", "vectorized") and why is None
                     and Q > 0 and not table.is_reentrant()
                     and all(s.const_d is not None for s in serves))
        preps.append((sol, b, Q, pol, table, serves, windows, stackable,
                      why))

    reports: list = [None] * len(plans)
    # reentrant / traced plans sharing one visit structure: the stacked
    # merged-scan fixpoint advances the whole group at once
    fix_grps: dict = {}
    for i, p in enumerate(preps):
        sol, b, Q, pol, table, serves, windows, stackable, why = p
        if (engine in ("auto", "vectorized") and not stackable and Q > 0
                and why is None and stack_eligible(serves)):
            fix_grps.setdefault(table.resources, []).append(i)
    for grp in fix_grps.values():
        if len(grp) < 2:
            continue
        i0 = grp[0]
        got = stacked_fixpoint(preps[i0][4],
                               [preps[i][5] for i in grp],
                               [preps[i][6] for i in grp],
                               [preps[i][2] for i in grp], t_start)
        if got is None:
            continue                 # per-plan fallback below
        for g, i in enumerate(grp):
            sol, b, Q, pol = preps[i][:4]
            reports[i] = SimReport(
                mb_complete=got[g], t_start=t_start, b=b,
                num_microbatches=Q, resource_busy={}, policy=pol.name,
                engine="vectorized",
                engine_reason=(f"vectorized: stacked merged-scan fixpoint "
                               f"({len(grp)} plans)"))
    fifo_grp = [i for i, p in enumerate(preps)
                if p[7] and all(w is None for w in p[6])]
    win_grp = [i for i, p in enumerate(preps)
               if p[7] and not all(w is None for w in p[6])]
    for grp, kind in ((fifo_grp, "fifo"), (win_grp, "windowed")):
        if len(grp) < 2:
            continue                     # single plans keep the full report
        Qm = max(preps[i][2] for i in grp)
        Rm = max(len(preps[i][4]) for i in grp)
        ds = np.zeros((len(grp), Rm))
        for g, i in enumerate(grp):
            serves = preps[i][5]
            ds[g, :len(serves)] = [s.const_d for s in serves]
        if kind == "fifo":
            last = stacked_fifo(ds, Qm, t_start)
        else:
            p_idx, fp_v, bp_v, w_v = [], [], [], []
            for g, i in enumerate(grp):
                table, windows = preps[i][4], preps[i][6]
                for j, w in enumerate(windows):
                    if w is not None:
                        p_idx.append(g)
                        fp_v.append(int(table.fp_visit[j]))
                        bp_v.append(int(table.bp_visit[j]))
                        w_v.append(int(w))
            fb = tuple(np.asarray(a, dtype=np.intp)
                       for a in (p_idx, fp_v, bp_v, w_v))
            last = stacked_windowed(ds, fb, Qm, t_start)
        for g, i in enumerate(grp):
            sol, b, Q, pol = preps[i][:4]
            reports[i] = SimReport(
                mb_complete=last[g, :Q].copy(), t_start=t_start, b=b,
                num_microbatches=Q, resource_busy={}, policy=pol.name,
                engine="vectorized",
                engine_reason=(f"vectorized: stacked plan axis "
                               f"({len(grp)} plans, {kind})"))
    # everything left runs per plan, reusing the prepped table / serve
    # models / bound policy (mirroring simulate_plan's dispatch without
    # paying the construction again)
    for i, p in enumerate(preps):
        if reports[i] is not None:
            continue
        sol, b, Q, pol, table, serves, windows, stackable, why = p
        event_reason = "event: requested"
        if engine in ("vectorized", "auto") and why is None:
            if Q == 0:
                reports[i] = _empty_report(table, pol, t_start, b,
                                           "vectorized: empty run")
                continue
            rep = _run_vectorized(table, serves, Q, pol, t_start, b)
            if rep is not None:
                reports[i] = rep
                continue
            why = ("reentrant merged-scan fixpoint did not converge "
                   "on this instance")
        if engine == "vectorized":
            raise ValueError(
                f"vectorized engine cannot run this instance: {why}; "
                "use engine='auto' or 'event'")
        if engine != "event":
            event_reason = f"event: {why}"
        tasks = build_tasks(profile, net, sol, b, Q)
        rep = PipelineSimulator(net, tasks, b=b, scenario=scenario,
                                t_start=t_start, policy=pol).run()
        rep.engine_reason = event_reason
        reports[i] = rep
    return reports


# ---------------------------------------------------------------------------
# Replanning driver: ft.Coordinator on simulated time
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SegmentReport:
    """One inter-trigger stretch of the replanned run."""
    plan: object                 # the core.Plan in force during the segment
    report: SimReport            # full hypothetical run of the segment
    completed: int               # micro-batches banked before the cutoff
    cutoff: float                # absolute time the segment ended
    trigger: object | None       # ReplanTrigger that ended it (None = drain)
    outcome: object | None       # ft.ReplanOutcome for that trigger


@dataclasses.dataclass
class ReplanSimReport:
    makespan: float              # absolute time the mini-batch drains
    segments: list               # SegmentReport
    coordinator: object          # the driven ft.Coordinator (holds outcomes)
    suppressed: list = dataclasses.field(default_factory=list)
    #                            # (trigger, outcome) pairs the policy
    #                            # absorbed without cutting the segment
    downtime: float = 0.0        # total remap + solve + restore charged

    @property
    def outcomes(self) -> list:
        """Every ``ReplanOutcome`` delivered during the run, in order."""
        out = [s.outcome for s in self.segments if s.outcome is not None]
        out += [o for _, o in self.suppressed]
        out.sort(key=lambda o: (o.sim_time is None,
                                0.0 if o.sim_time is None else o.sim_time))
        return out

    @property
    def num_replans(self) -> int:
        """Replans actually *issued* (full or micro-batch re-solve) —
        absorbed/suppressed events don't count."""
        return sum(1 for o in self.outcomes
                   if o.action in ("replan", "microbatch"))

    @property
    def num_suppressed(self) -> int:
        """Events the policy absorbed (no solve, no pipeline restart)."""
        return sum(1 for o in self.outcomes if o.action == "absorb")


def simulate_with_replanning(profile: ModelProfile, net: EdgeNetwork, B: int,
                             triggers=(), *, coordinator=None,
                             scenario: NetworkScenario | None = None,
                             remap_penalty: float = 0.0,
                             solve_downtime: float | str = 0.0,
                             policy: AdmissionPolicy | str = "fifo",
                             engine: str = "event",
                             **coordinator_kwargs) -> ReplanSimReport:
    """Execute a mini-batch of ``B`` samples while ``ReplanTrigger``s fire
    at simulated times.  Triggers come from the ``triggers`` argument and/or
    ``scenario.replan_triggers`` (composed via ``with_replan``); both are
    merged and fired in time order.

    Each trigger's event is **delivered** to the coordinator
    (``Coordinator.deliver``): the coordinator's replan policy (pass
    ``policy=`` among ``coordinator_kwargs``, or a pre-built coordinator)
    decides between a full replan and *absorbing* the event.  For an
    adopted replan: micro-batches fully drained by then are banked,
    in-flight ones are discarded (they re-run after the remap), and the
    remaining samples resume at ``trigger.time + remap_penalty +
    solve_downtime + outcome.restore_seconds`` under the new plan — a
    ``NodeFailure`` additionally pays the checkpoint-restore charge the
    coordinator's ``restore_cost`` prices (see
    ``repro.checkpoint.estimate_restore_seconds``), since resuming after a
    lost server means reloading params from the latest checkpoint.
    ``solve_downtime`` is the per-replan solver stall: a float (seconds),
    or ``"wall"`` to charge the measured ``outcome.solve_seconds``.  An
    *absorbed* event that still mutated the network (a rate change ridden
    out) cuts the segment at the trigger time with **zero** downtime — the
    capacity change takes hold, the incumbent plan keeps running — while an
    absorbed no-op (a suppressed ``Resync``: any delivery that changed
    neither the coordinator's network nor its plan) does not cut at all:
    the event lands in ``ReplanSimReport.suppressed`` and the in-flight
    segment keeps streaming.  The physical effect of each event (slower
    node, changed rate, lost server) takes hold from its trigger time via
    the coordinator's mutated network.

    ``policy``/``engine`` are forwarded to each segment's ``simulate_plan``
    (``policy`` here is the *admission* policy — FIFO/1F1B — not the
    replan policy).

    ``scenario`` capacity traces are keyed by node/link index; a
    ``NodeFailure`` renumbers the network's indices, so combining the two
    would silently apply traces to the wrong nodes — that combination is
    rejected.
    """
    from repro.ft.coordinator import Coordinator, NodeFailure  # local: avoid hard dep

    coord = coordinator or Coordinator(profile, net, B, **coordinator_kwargs)
    all_triggers = tuple(triggers)
    if scenario is not None:
        all_triggers += tuple(scenario.replan_triggers)
        if any(isinstance(tr.event, NodeFailure) for tr in all_triggers):
            raise ValueError(
                "NodeFailure triggers cannot be combined with a capacity "
                "scenario: degraded() renumbers node indices, so the "
                "scenario's index-keyed traces would land on the wrong "
                "nodes/links")
    segments: list = []
    suppressed: list = []
    t = 0.0
    total_downtime = 0.0
    samples_left = B
    cur = None          # in-flight segment's SimReport, memoized across
    #                     suppressed triggers so no-ops don't re-simulate
    for trig in sorted(all_triggers, key=lambda tr: tr.time):
        if samples_left <= 0:
            break
        plan = coord.plan
        if not plan.feasible or plan.b <= 0:
            break
        m = max(1, math.ceil(samples_left / plan.b))
        if cur is None:
            cur = simulate_plan(profile, coord.net, plan.solution, plan.b,
                                num_microbatches=m, scenario=scenario,
                                t_start=t, policy=policy, engine=engine)
        rep = cur
        if rep.makespan <= trig.time:
            # drained before the event fired — the run is simply over
            segments.append(SegmentReport(plan, rep, m, rep.makespan,
                                          None, None))
            return ReplanSimReport(rep.makespan, segments, coord,
                                   suppressed, total_downtime)
        prev_net, prev_plan = coord.net, coord.plan
        outcome = coord.deliver(trig.event, sim_time=trig.time)
        if coord.net is prev_net and coord.plan is prev_plan:
            # pure suppression: nothing the simulation sees changed — the
            # in-flight segment keeps streaming, no cut, no downtime
            suppressed.append((trig, outcome))
            continue
        cur = None
        done = int(np.searchsorted(rep.mb_complete, trig.time, side="right"))
        samples_left = max(0, samples_left - done * plan.b)
        segments.append(SegmentReport(plan, rep, done, trig.time, trig,
                                      outcome))
        if outcome.action in ("replan", "microbatch"):
            solve_dt = (outcome.solve_seconds if solve_downtime == "wall"
                        else float(solve_downtime))
            dt = remap_penalty + solve_dt + outcome.restore_seconds
        else:
            dt = 0.0    # absorbed: no restart, no solve stall
        total_downtime += dt
        t = trig.time + dt
    if samples_left > 0:
        plan = coord.plan
        if plan.feasible and plan.b > 0:
            m = max(1, math.ceil(samples_left / plan.b))
            if cur is None:
                cur = simulate_plan(profile, coord.net, plan.solution,
                                    plan.b, num_microbatches=m,
                                    scenario=scenario, t_start=t,
                                    policy=policy, engine=engine)
            segments.append(SegmentReport(plan, cur, m, cur.makespan,
                                          None, None))
            t = cur.makespan
        else:
            t = math.inf
    return ReplanSimReport(t, segments, coord, suppressed, total_downtime)
