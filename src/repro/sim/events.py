"""Event, task-table, and trace records for the pipeline simulator.

A simulation run executes one unit of work per (micro-batch, resource) pair
connected by precedence edges.  Two representations exist:

* ``Task`` — one explicit unit for the heap-based event loop; a run is a
  list of tasks plus chain edges (``dep``) and any policy edges.
* ``VisitTable`` — the structure-of-arrays task table for the vectorized
  engine: because micro-batches are identical jobs, one row per *visit*
  (position in the per-micro-batch chain) describes all ``Q`` micro-batches
  at once and the micro-batch axis stays implicit until execution.

Executing either produces a timeline — eager ``TraceRecord`` lists from the
heap engine, a dense ``Timeline`` (start/end arrays) from the vectorized
engine — exportable as a Chrome-trace JSON (`chrome://tracing` / Perfetto)
for visual inspection of the schedule.

Resource keys mirror the aggregation of Eq. (13) / C9-C16:

  ("fp",  node)        the node's forward engine
  ("bp",  node)        the node's backward engine (separate resource, C13)
  ("fwd", n, n')       the directed n->n' transfer resource (activations)
  ("bwd", n', n)       the directed n'->n transfer resource (act-gradients)

Co-located submodels map to the *same* key, so their per-micro-batch work
serializes — exactly the per-node sums of the analytical bottleneck.
"""

from __future__ import annotations

import dataclasses
import json
import os

import numpy as np


#: task kinds, in the order they appear along one micro-batch's chain
KINDS = ("fp", "fwd", "bp", "bwd")


@dataclasses.dataclass(frozen=True)
class Task:
    """One unit of simulated work.

    ``work`` is in capacity units (kappa-scaled workload for compute, bytes
    for transfers) and is served at the resource's — possibly time-varying —
    capacity; ``fixed`` is a rate-independent latency constant (the paper's
    t0/t1 terms) paid up front.
    """
    tid: int
    microbatch: int
    stage: int                   # submodel index k (link tasks: upstream k)
    kind: str                    # "fp" | "bp" | "fwd" | "bwd"
    resource: tuple              # see module docstring
    work: float
    fixed: float = 0.0
    dep: int | None = None       # tid that must finish first (chain edge)

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown task kind {self.kind!r}")
        if self.work < 0 or self.fixed < 0:
            raise ValueError("work/fixed must be non-negative")


@dataclasses.dataclass(frozen=True)
class VisitTable:
    """Structure-of-arrays task table for one micro-batch's visit chain.

    Micro-batches are identical jobs, so the per-visit arrays describe every
    micro-batch; the engine broadcasts over the micro-batch axis instead of
    materializing ``Q * len(self)`` Task objects.  Visit order is chain
    order: FP/fwd sweep up the stages, then BP/bwd back down — the same
    order ``engine.build_tasks`` emits explicit tasks in.
    """
    kinds: tuple        # per visit: "fp" | "fwd" | "bp" | "bwd"
    stages: tuple       # per visit: submodel index k (links: upstream k)
    resources: tuple    # per visit: resource key (see module docstring)
    work: np.ndarray    # per visit: capacity-units of work
    fixed: np.ndarray   # per visit: rate-independent seconds
    fp_visit: np.ndarray  # stage position j -> visit index of its FP
    bp_visit: np.ndarray  # stage position j -> visit index of its BP

    def __len__(self) -> int:
        return len(self.kinds)

    @property
    def num_stages(self) -> int:
        return len(self.fp_visit)

    def is_reentrant(self) -> bool:
        """True when some resource appears at two visits (co-located
        submodels, e.g. client FP+BP split across revisits) — FIFO service
        then interleaves the visit streams and the vectorized engine runs
        its merged-scan fixpoint instead of the independent column scans."""
        return len(set(self.resources)) != len(self.resources)

    def resource_visits(self) -> dict:
        """Per-resource visit ordering: ``{resource: (visit, ...)}`` with
        visits in chain order.  The grouping the vectorized engine's
        reentrant path advances — each resource serves the *merge* of its
        visit streams (each stream internally in micro-batch order), so the
        tuple is exactly the set of streams to merge.  Cached on first use
        (the table is frozen)."""
        got = getattr(self, "_resource_visits", None)
        if got is None:
            groups: dict = {}
            for v, res in enumerate(self.resources):
                groups.setdefault(res, []).append(v)
            got = {res: tuple(vs) for res, vs in groups.items()}
            object.__setattr__(self, "_resource_visits", got)
        return got


@dataclasses.dataclass(frozen=True)
class Timeline:
    """Dense (Q, R) start/end times from the vectorized engine — the SoA
    counterpart of a ``TraceRecord`` list."""
    table: VisitTable
    starts: np.ndarray   # (num_microbatches, len(table))
    ends: np.ndarray

    @property
    def num_microbatches(self) -> int:
        return self.starts.shape[0]

    def to_records(self) -> list:
        """Materialize explicit ``TraceRecord``s (completion order)."""
        t = self.table
        recs = [
            TraceRecord(m, t.stages[v], t.kinds[v], t.resources[v],
                        float(self.starts[m, v]), float(self.ends[m, v]))
            for m in range(self.starts.shape[0])
            for v in range(len(t))
        ]
        recs.sort(key=lambda r: (r.end, r.start, r.microbatch))
        return recs


@dataclasses.dataclass(frozen=True)
class TraceRecord:
    """One executed task: [start, end) occupancy of ``resource``."""
    microbatch: int
    stage: int
    kind: str
    resource: tuple
    start: float
    end: float

    @property
    def duration(self) -> float:
        return self.end - self.start


def resource_label(resource: tuple) -> str:
    if resource[0] in ("fp", "bp"):
        return f"node{resource[1]}:{resource[0]}"
    return f"link{resource[1]}->{resource[2]}:{resource[0]}"


def write_chrome_trace(records, path: str, *, time_scale: float = 1e6,
                       counter_tracks: bool = False,
                       flow_events: bool = False,
                       wall_spans=None) -> str:
    """Write the timeline as a Chrome-trace JSON (ts/dur in microseconds).

    One "thread" per resource; each record becomes a complete ("X") event.
    Load the file at chrome://tracing or https://ui.perfetto.dev.

    Optional extras (all on pid ``obs.SIM_PID`` except the last):

    * ``counter_tracks`` — per-resource "C" counter tracks showing
      instantaneous occupancy (pipeline bubbles render as dips), plus a
      pipeline-wide active-task counter.
    * ``flow_events`` — "s"/"f" flow arrows linking each micro-batch's
      forward transfer on a hop to its backward transfer on the reverse
      hop, making the round trip visible.
    * ``wall_spans`` — finished ``obs.span()`` records (e.g.
      ``obs.wall_spans()``); rendered as wall-clock solver tracks on their
      own process (pid ``obs.SOLVER_PID``) next to the simulated-time
      pipeline tracks.
    """
    from repro.obs import trace as obs_trace

    resources = sorted({r.resource for r in records},
                       key=lambda res: (KINDS.index(res[0]), res[1:]))
    tid_of = {res: i for i, res in enumerate(resources)}
    pid = obs_trace.SIM_PID
    events: list = [{"ph": "M", "name": "process_name", "pid": pid, "tid": 0,
                     "args": {"name": "pipeline (simulated time)"}}]
    events += [{"ph": "M", "name": "thread_name", "pid": pid, "tid": tid,
                "args": {"name": resource_label(res)}}
               for res, tid in tid_of.items()]
    for r in records:
        events.append({
            "name": f"mb{r.microbatch} k{r.stage} {r.kind}",
            "ph": "X", "pid": pid, "tid": tid_of[r.resource],
            "ts": r.start * time_scale,
            "dur": max(r.end - r.start, 0.0) * time_scale,
            "args": {"microbatch": r.microbatch, "stage": r.stage,
                     "kind": r.kind},
        })
    if counter_tracks:
        events += obs_trace.utilization_counter_events(
            records, pid=pid, time_scale=time_scale,
            label_of=resource_label)
    if flow_events:
        events += obs_trace.microbatch_flow_events(
            records, tid_of, pid=pid, time_scale=time_scale)
    if wall_spans:
        events += obs_trace.solver_span_events(
            wall_spans, pid=obs_trace.SOLVER_PID, time_scale=time_scale)
    out = {"traceEvents": events, "displayTimeUnit": "ms"}
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(out, f, default=str)
    return path
