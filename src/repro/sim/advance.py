"""Heap-free advancement kernels for the vectorized engine.

The vectorized engine executes a :class:`~repro.sim.events.VisitTable` — Q
identical micro-batch chains over R visits — without a priority queue.  PR 2
covered the constant-capacity, distinct-placement case with closed-form
prefix-max scans; this module generalizes the batched max-plus advancement
along two axes:

**Piecewise-constant traces (segmented scans).**  On a FIFO resource a task
of ``work`` units started at ``t`` finishes at ``finish(W(t) + work)``,
where ``W`` is the trace's cumulative-work function and ``finish`` its
inverse (both precomputed as breakpoint prefix arrays on
:class:`~repro.sim.scenario.PiecewiseTrace`).  Back-to-back service
therefore *chains in work space*: with arrivals ``a[m]`` at a visit of
per-micro-batch work ``w``,

    target[m] = max(W(a[m]), target[m-1]) + w
              = (m+1) w + cummax(W(a[m]) - m w)

— the same prefix-max scan as the constant case, run on cumulative work
instead of time, with ``ends = finish(target)`` mapping back through the
breakpoints.  One ``np.searchsorted`` per visit replaces the event engine's
per-task trace walk.  (A rate-independent ``fixed`` latency breaks the
work-space chaining on a *varying* trace — those rare columns fall back to
an exact scalar sweep.)

**Reentrant plans (merged-scan fixpoint).**  When a resource hosts several
visits (co-located submodels), FIFO service interleaves the visit streams
by arrival time, so no single pass is exact.  But the interleave is
constrained: within a stream, service stays in micro-batch order, and a
later micro-batch's *deeper* visit can never overtake an earlier
micro-batch's shallower visit on the same resource.  The kernel therefore
iterates to the unique self-consistent schedule: per sweep, each resource
re-merges its visit streams by current arrival estimates
(:meth:`VisitTable.resource_visits` supplies the per-resource visit
ordering), serves the merged sequence with one vectorized scan (time-space
for constant capacity, work-space for traces), and the sweep repeats until
the end-time matrix reproduces itself exactly.  Admission-window feedback
edges ride along as extra ready-time terms.  Starting from the relaxed
(contention-free) lower bound, convergence typically takes a handful of
sweeps; a non-converging instance is reported so the caller can fall back
to the event engine.

**Stacked plan axis.**  ``stacked_fifo`` / ``stacked_windowed`` run *many*
candidate plans at once by adding a leading plan axis to the constant-
capacity scans (mirroring the threshold-batched planner kernel) — the
``CostModel.evaluate_many`` fast path for micro-batch refinement sweeps.
Visit axes are padded with zero-duration visits (pass-through under the
prefix-max recurrences), micro-batch axes to the largest plan.
"""

from __future__ import annotations

import math

import numpy as np

__all__ = ["VisitServe", "column_advance", "fifo_pass", "windowed_pass",
           "fixpoint_advance", "stacked_fifo", "stacked_windowed"]


class VisitServe:
    """Per-visit serving model: when does work started at ``t`` finish.

    ``const_d`` is the total service duration when it does not depend on
    the start time — constant-capacity trace, or zero work (the duration
    is then the rate-independent ``fixed`` seconds alone).  Otherwise the
    piecewise trace is served through its cumulative-work arrays.
    """

    __slots__ = ("trace", "work", "fixed", "const_d")

    def __init__(self, trace, work: float, fixed: float):
        self.work = float(work)
        self.fixed = float(fixed)
        if self.work <= 0.0:
            self.const_d = self.fixed
            self.trace = None
        elif trace.is_constant():
            v = trace.values[0]
            self.const_d = self.fixed + (self.work / v if v > 0.0
                                         else math.inf)
            self.trace = None
        else:
            self.const_d = None
            self.trace = trace

    def finite(self) -> bool:
        """Every service completes in finite time from any start."""
        if self.const_d is not None:
            return math.isfinite(self.const_d)
        return self.trace.drains()

    def end_at(self, t: float) -> float:
        """Scalar service end for a task starting (exactly) at ``t``."""
        if self.const_d is not None:
            return t + self.const_d
        tr = self.trace
        return tr.finish_time(tr.work_done(t + self.fixed) + self.work)

    def ends_at(self, t: np.ndarray) -> np.ndarray:
        """Vectorized :meth:`end_at` (no queueing — starts are given)."""
        if self.const_d is not None:
            return t + self.const_d
        tr = self.trace
        return tr.finish_many(tr.work_done_many(t + self.fixed) + self.work)


def _shift_starts(a: np.ndarray, ends: np.ndarray) -> np.ndarray:
    """Service starts for FIFO back-to-back service: max(arrival, previous
    completion on the resource)."""
    s = a.copy()
    if len(s) > 1:
        np.maximum(s[1:], ends[:-1], out=s[1:])
    return s


def column_advance(serve: VisitServe, a: np.ndarray):
    """FIFO service of one dedicated visit: arrivals ``a`` (one per
    micro-batch, in micro-batch order) -> ``(starts, ends)``.

    Constant durations use the PR 2 closed-form time-space scan verbatim;
    varying traces with no fixed latency use the work-space segmented scan
    (module docstring); the remaining corner (varying trace AND fixed > 0)
    is an exact scalar sweep.
    """
    Q = len(a)
    if serve.const_d is not None:
        dv = serve.const_d
        idx = _idx(Q)
        ends = (idx + 1.0) * dv + np.maximum.accumulate(a - idx * dv)
    elif serve.fixed == 0.0:
        w = serve.work
        idx = _idx(Q)
        A = serve.trace.work_done_many(a)
        target = (idx + 1.0) * w + np.maximum.accumulate(A - idx * w)
        ends = serve.trace.finish_many(target)
    else:
        ends = np.empty(Q)
        prev = -math.inf
        for m in range(Q):
            s = a[m] if a[m] > prev else prev
            prev = ends[m] = serve.end_at(s)
    return _shift_starts(a, ends), ends


def fifo_pass(serves, Q: int, t_start: float):
    """Single exact pass for non-reentrant FIFO admission (any traces):
    chain-ordered column scans — visit ``v``'s arrivals are visit
    ``v-1``'s completions."""
    R = len(serves)
    starts = np.empty((Q, R))
    ends = np.empty((Q, R))
    a = np.full(Q, float(t_start))
    for v in range(R):
        starts[:, v], ends[:, v] = column_advance(serves[v], a)
        a = ends[:, v]
    return starts, ends


def _feedback_map(table, windows, Q: int) -> dict:
    """``{fp_visit: (bp_visit, window)}`` for the admission windows that can
    actually bind (``window < Q``)."""
    out = {}
    for j, w in enumerate(windows):
        if w is not None and w < Q:
            out[int(table.fp_visit[j])] = (int(table.bp_visit[j]), int(w))
    return out


def windowed_pass(serves, table, windows, Q: int, t_start: float):
    """Single exact pass for non-reentrant *windowed* admission with
    time-varying traces: micro-batch-major, so the window feedback
    ``BP_j(m - w)  ->  FP_j(m)`` only ever reads earlier rows.  The chain
    scan along a row mixes per-visit traces, so it is a scalar sweep —
    exact, heap-free, O(Q R) trace lookups."""
    R = len(serves)
    fb_at = _feedback_map(table, windows, Q)
    starts = np.empty((Q, R))
    ends = np.empty((Q, R))
    for m in range(Q):
        chain = t_start
        for v in range(R):
            r = ends[m - 1, v] if m else t_start
            fb = fb_at.get(v)
            if fb is not None and m - fb[1] >= 0:
                e_fb = ends[m - fb[1], fb[0]]
                if e_fb > r:
                    r = e_fb
            s = chain if chain > r else r
            e = serves[v].end_at(s)
            starts[m, v] = s
            ends[m, v] = e
            chain = e
    return starts, ends


# ---------------------------------------------------------------------------
# Reentrant plans: merged-scan fixpoint
# ---------------------------------------------------------------------------

#: small cache of float index vectors for the prefix scans
_IDX: dict = {}


def _idx(Q: int) -> np.ndarray:
    got = _IDX.get(Q)
    if got is None:
        if len(_IDX) > 64:
            _IDX.clear()
        got = _IDX[Q] = np.arange(Q, dtype=float)
    return got


def _ready_col(v: int, ends: np.ndarray, Q: int, t_start: float,
               fb_at: dict) -> np.ndarray:
    """Ready times of visit ``v``'s tasks from the current end estimates:
    chain predecessor completions, max'd with any window feedback."""
    if v == 0:
        a = np.full(Q, float(t_start))
    else:
        a = ends[:, v - 1].copy()
    fb = fb_at.get(v)
    if fb is not None:
        bv, w = fb
        np.maximum(a[w:], ends[:Q - w, bv], out=a[w:])
    return a


class _MergedGroup:
    """Precomputed state for one reentrant resource's merged scan.

    ``arr[i]`` (stream ``i`` = visit ``vs[i]``) holds ready times; tasks are
    ordered by (effective arrival, micro-batch, stream position) — the
    within-stream cummax keeps each stream in micro-batch order even while
    the surrounding fixpoint is still settling — then served back-to-back
    with one vectorized scan (time-space for constant capacity, work-space
    for a shared trace, scalar for the fixed-latency-on-trace corner).
    """

    __slots__ = ("vs", "streams", "mbs", "pos", "kind", "d", "w", "trace",
                 "sv", "last")

    def __init__(self, vs, serves, Q):
        self.vs = vs
        k = len(vs)
        self.streams = np.repeat(np.arange(k), Q)
        self.mbs = np.tile(np.arange(Q), k)
        self.pos = self.mbs * k + self.streams   # unique (m, stream) rank
        sv = [serves[v] for v in vs]
        self.sv = sv
        self.trace = None
        self.d = self.w = None
        if all(s.const_d is not None for s in sv):
            self.kind = "const"
            self.d = np.array([s.const_d for s in sv])[self.streams]
        elif all(s.fixed == 0.0 and s.work > 0.0 for s in sv):
            self.kind = "work"
            self.trace = next(s.trace for s in sv if s.trace is not None)
            self.w = np.array([s.work for s in sv])[self.streams]
        else:
            self.kind = "scalar"
        self.last = None

    def advance(self, arr: np.ndarray, starts, ends, Q):
        """One merged scan from ready times ``arr``; writes the member
        columns of ``starts``/``ends``.  Skips the sort + scan when the
        ready times match the previous sweep exactly (outputs would too),
        and reuses the previous sweep's service order while it is still
        consistent with the new arrivals — orders settle sweeps before
        the times do."""
        if self.last is not None and np.array_equal(arr, self.last[0]):
            return
        cached = None if self.last is None else self.last[1]
        eff = np.maximum.accumulate(arr, axis=1)   # within-stream FIFO order
        flat = eff.ravel()                         # index = i * Q + m
        order = None
        if cached is not None:
            a_s = flat[cached]
            d = np.diff(a_s)
            tie = np.diff(self.pos[cached])
            if bool(np.all((d > 0) | ((d == 0) & (tie > 0)))):
                order = cached
        if order is None:
            order = np.lexsort((self.streams, self.mbs, flat))
            a_s = flat[order]
        self.last = (arr, order)
        if self.kind == "const":
            d = self.d[order]
            C = np.cumsum(d)
            ends_s = C + np.maximum.accumulate(a_s - (C - d))
        elif self.kind == "work":
            w = self.w[order]
            C = np.cumsum(w)
            tr = self.trace
            target = C + np.maximum.accumulate(tr.work_done_many(a_s)
                                               - (C - w))
            ends_s = tr.finish_many(target)
        else:
            n = len(a_s)
            ends_s = np.empty(n)
            prev = -math.inf
            st_order = self.streams[order]
            for t in range(n):
                s = a_s[t] if a_s[t] > prev else prev
                prev = ends_s[t] = self.sv[st_order[t]].end_at(s)
        starts_s = _shift_starts(a_s, ends_s)
        n = len(flat)
        st_flat = np.empty(n)
        en_flat = np.empty(n)
        st_flat[order] = starts_s
        en_flat[order] = ends_s
        for i, v in enumerate(self.vs):
            starts[:, v] = st_flat[i * Q:(i + 1) * Q]
            ends[:, v] = en_flat[i * Q:(i + 1) * Q]


def fixpoint_advance(table, serves, windows, Q: int, t_start: float,
                     max_sweeps: int | None = None):
    """Exact schedule for reentrant tables: iterate merged-scan sweeps to
    the self-consistent FIFO schedule.

    Sweeps are chaotic Gauss-Seidel over the per-resource groups (sorted by
    last visit, so a non-reentrant table degenerates to the exact
    chain-ordered single pass); dirty-column tracking skips any group whose
    inputs did not change last sweep, so late sweeps cost almost nothing.
    Returns ``(starts, ends, sweeps)`` on convergence (every column
    reproduced itself exactly), or ``None`` if the cap is hit — the caller
    falls back to the event engine (``engine="auto"``) or raises
    (``engine="vectorized"``).
    """
    R = len(serves)
    fb_at = _feedback_map(table, windows, Q)
    raw = sorted(table.resource_visits().values(), key=lambda vs: vs[-1])
    groups = [(vs, _MergedGroup(vs, serves, Q) if len(vs) > 1 else None)
              for vs in raw]
    starts = np.empty((Q, R))
    ends = np.full((Q, R), -math.inf)
    # init: relaxed lower bound — every visit its own resource, window
    # feedback reads -inf (absent) on this first chain-ordered pass
    for v in range(R):
        a = _ready_col(v, ends, Q, t_start, fb_at)
        starts[:, v], ends[:, v] = column_advance(serves[v], a)
    if max_sweeps is None:
        max_sweeps = 2 * Q + 2 * R + 8
    prev = np.empty_like(ends)
    for sweep in range(1, max_sweeps + 1):
        np.copyto(prev, ends)
        for vs, grp in groups:
            if grp is None:
                v = vs[0]
                a = _ready_col(v, ends, Q, t_start, fb_at)
                starts[:, v], ends[:, v] = column_advance(serves[v], a)
            else:
                arr = np.empty((len(vs), Q))
                for i, v in enumerate(vs):
                    arr[i] = _ready_col(v, ends, Q, t_start, fb_at)
                grp.advance(arr, starts, ends, Q)
        if np.array_equal(ends, prev):
            return starts, ends, sweep
    return None


# ---------------------------------------------------------------------------
# Stacked plan axis: many same-structure plans per fixpoint
# ---------------------------------------------------------------------------

def stack_eligible(serves) -> bool:
    """True when every visit's serving model fits a stacked scan: constant
    duration, or a trace with no fixed latency (the work-space scan).  The
    per-plan scalar corner (fixed > 0 on a varying trace) stays unstacked."""
    return all(s.const_d is not None
               or (s.fixed == 0.0 and s.work > 0.0) for s in serves)


def stacked_fixpoint(table, serves_list, windows_list, Qs, t_start: float,
                     max_sweeps: int | None = None):
    """Merged-scan fixpoint with a leading plan axis.

    ``serves_list[p]`` are plan ``p``'s per-visit :class:`VisitServe` models
    over ONE shared visit structure (identical ``table.resources`` — e.g. a
    micro-batch refinement sweep: same split, different ``b``), and
    ``windows_list[p]`` its admission windows.  All plans advance through
    one set of (P, Q, R) numpy sweeps.  Shorter plans are padded to the
    largest micro-batch count: padded tasks keep their real durations in
    the *column* scans (trailing rows never influence earlier ones) but are
    zeroed out in the *merged* scans, where a zero-duration task's
    prefix-scan term is always dominated by its successor's — inert — so
    each plan's rows stay bit-identical to its single-plan run.  Returns
    per-plan ``(Q_p,)`` completion-time vectors of the last visit, or
    ``None`` if some plan's fixpoint failed to converge.
    """
    P = len(serves_list)
    R = len(table.resources)
    # a reentrant resource whose visits MIX serving kinds (a traced visit
    # co-located with a zero-work/constant one) needs the single-plan
    # scalar merged scan — the stacked branches below pick one kind per
    # group, so such structures are declined (per-plan fallback)
    for vs in table.resource_visits().values():
        if len(vs) > 1:
            kinds = {serves_list[0][v].const_d is None for v in vs}
            if len(kinds) != 1:
                return None
    Qs = list(Qs)
    Q = max(Qs)
    mcol = np.arange(Q)
    d_vis = np.zeros((P, R))         # const total durations per (plan, visit)
    w_vis = np.zeros((P, R))         # work units for work-space visits
    use_work = np.zeros(R, dtype=bool)
    traces = [None] * R
    for v in range(R):
        if serves_list[0][v].const_d is None:
            use_work[v] = True
            traces[v] = serves_list[0][v].trace
            for p in range(P):
                w_vis[p, v] = serves_list[p][v].work
        else:
            for p in range(P):
                d_vis[p, v] = serves_list[p][v].const_d
    pad = mcol[None, :] >= np.asarray(Qs)[:, None]          # (P, Q)
    live = ~pad
    # window feedback: same (fp, bp) visit pairs, per-plan windows
    never = Q + 1
    fb_at = {}
    for j in range(table.num_stages):
        ws = np.array([windows_list[p][j]
                       if windows_list[p][j] is not None else never
                       for p in range(P)], dtype=np.intp)
        if (ws <= Q).any():
            fb_at[int(table.fp_visit[j])] = (int(table.bp_visit[j]), ws)
    p_col = np.arange(P)[:, None]

    def ready(v, ends):
        if v == 0:
            a = np.full((P, Q), float(t_start))
        else:
            a = ends[:, :, v - 1].copy()
        got = fb_at.get(v)
        if got is not None:
            bv, ws = got
            src = mcol[None, :] - ws[:, None]               # (P, Q)
            ok = src >= 0
            vals = ends[p_col, np.where(ok, src, 0), bv]
            np.maximum(a, np.where(ok, vals, -math.inf), out=a)
        return a

    idx = np.arange(Q, dtype=float)[None, :]

    def column(v, a, ends):
        # same per-plan arithmetic as column_advance, broadcast over plans
        if use_work[v]:
            w = w_vis[:, v:v + 1]
            tr = traces[v]
            A = tr.work_done_many(a)
            target = (idx + 1.0) * w + np.maximum.accumulate(A - idx * w,
                                                             axis=1)
            ends[:, :, v] = tr.finish_many(target)
        else:
            d = d_vis[:, v:v + 1]
            ends[:, :, v] = (idx + 1.0) * d + \
                np.maximum.accumulate(a - idx * d, axis=1)

    groups = sorted(table.resource_visits().values(), key=lambda vs: vs[-1])
    merged = {}
    for vs in groups:
        if len(vs) < 2:
            continue
        k = len(vs)
        # tie-break rank aligned with the micro-batch-major task flattening:
        # equal arrivals order by micro-batch, then stream position — the
        # same rule as the single-plan merged scan
        pos = np.tile(np.arange(k * Q), P)
        plan_key = np.repeat(np.arange(P), k * Q)
        # per-task durations/works, micro-batch-major, padded tasks zeroed
        # (inert in the scans)
        src = w_vis if use_work[vs[0]] else d_vis
        per = np.stack([src[:, v:v + 1] * live for v in vs],
                       axis=2).reshape(P, Q * k)
        merged[vs[-1]] = [vs, pos, plan_key, per, None]

    def advance_group(grp, ends):
        vs, pos, plan_key, per, last = grp
        k = len(vs)
        arr = np.empty((P, k, Q))
        for i, v in enumerate(vs):
            arr[:, i, :] = ready(v, ends)
        if last is not None and np.array_equal(arr, last[0]):
            return                   # inputs unchanged -> outputs unchanged
        cached = None if last is None else last[1]
        eff = np.maximum.accumulate(arr, axis=2)
        # stream-major (k, Q) -> task-flat with micro-batch-major tie-break
        flat = eff.transpose(0, 2, 1).reshape(P, k * Q)
        order = None
        if cached is not None:       # reuse the settled service order
            a_s = flat.ravel()[cached].reshape(P, k * Q)
            d = np.diff(a_s, axis=1)
            tie = np.diff(pos[cached].reshape(P, k * Q), axis=1)
            if bool(np.all((d > 0) | ((d == 0) & (tie > 0)))):
                order = cached
        if order is None:
            order = np.lexsort((pos, flat.ravel(), plan_key))
            a_s = flat.ravel()[order].reshape(P, k * Q)
        grp[4] = (arr, order)
        per_s = per.ravel()[order].reshape(P, k * Q)
        C = np.cumsum(per_s, axis=1)
        if use_work[vs[0]]:
            tr = traces[vs[0]]
            target = C + np.maximum.accumulate(
                tr.work_done_many(a_s) - (C - per_s), axis=1)
            e_s = np.where(per_s > 0.0, tr.finish_many(target), a_s)
        else:
            e_s = C + np.maximum.accumulate(a_s - (C - per_s), axis=1)
        e_flat = np.empty(P * k * Q)
        e_flat[order] = e_s.ravel()
        e = e_flat.reshape(P, Q, k)
        for i, v in enumerate(vs):
            ends[:, :, v] = e[:, :, i]

    ends = np.full((P, Q, R), -math.inf)
    for v in range(R):                       # relaxed chain-ordered init
        column(v, ready(v, ends), ends)
    if max_sweeps is None:
        max_sweeps = 2 * Q + 2 * R + 8
    prev = np.empty_like(ends)
    for _ in range(max_sweeps):
        np.copyto(prev, ends)
        for vs in groups:
            m = merged.get(vs[-1]) if len(vs) > 1 else None
            if m is None:
                column(vs[0], ready(vs[0], ends), ends)
            else:
                advance_group(m, ends)
        if np.array_equal(ends, prev):
            return [ends[p, :Qs[p], -1].copy() for p in range(P)]
    return None


# ---------------------------------------------------------------------------
# Stacked plan axis: many constant-capacity plans per scan
# ---------------------------------------------------------------------------

def stacked_fifo(ds: np.ndarray, Q: int, t_start: float) -> np.ndarray:
    """FIFO completion times for ``P`` constant-capacity plans at once.

    ``ds``: (P, R_max) per-visit durations, right-padded with 0.0
    (zero-duration visits pass arrivals through unchanged).  Returns the
    (P, Q) completion times of each plan's last visit — bit-identical per
    plan to the single-plan scan (the recurrence is elementwise along the
    plan axis).
    """
    P, Rm = ds.shape
    idx = np.arange(Q, dtype=float)[None, :]
    prev = np.full((P, Q), float(t_start))
    for v in range(Rm):
        dv = ds[:, v:v + 1]
        prev = (idx + 1.0) * dv + np.maximum.accumulate(prev - idx * dv,
                                                        axis=1)
    return prev


def stacked_windowed(ds: np.ndarray, fb: tuple, Q: int,
                     t_start: float) -> np.ndarray:
    """Windowed-admission completion times for ``P`` constant-capacity
    plans at once (micro-batch-major, the PR 2 windowed recurrence with a
    leading plan axis).

    ``fb`` carries the flattened feedback edges across all plans:
    ``(plan_idx, fp_visit, bp_visit, window)`` integer arrays.  Returns the
    (P, Q) last-visit completion times; visit padding as in
    :func:`stacked_fifo`.
    """
    P, Rm = ds.shape
    p_idx, fp_v, bp_v, w_v = fb
    D = np.cumsum(ds, axis=1)
    Dsh = np.concatenate((np.zeros((P, 1)), D[:, :-1]), axis=1)
    ends = np.empty((P, Q, Rm))
    for m in range(Q):
        if m == 0:
            r = np.full((P, Rm), float(t_start))
        else:
            r = ends[:, m - 1, :].copy()
            sel = w_v <= m
            if sel.any():
                ps, fs, bs, ws = p_idx[sel], fp_v[sel], bp_v[sel], w_v[sel]
                np.maximum.at(r, (ps, fs), ends[ps, m - ws, bs])
        ends[:, m, :] = D + np.maximum.accumulate(r - Dsh, axis=1)
    return ends[:, :, -1]
