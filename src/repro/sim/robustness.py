"""Plan robustness under failure distributions: tail-risk (CVaR) scoring and
the :class:`RobustMakespan` cost model.

The paper's Eqs. (12)-(14) optimize *expected* latency on a known network;
an edge deployment cares at least as much about the tail — the makespan when
the region degrades, a link flaps, or the bottleneck stage goes dark
mid-round.  This module runs a plan across a *distribution* of fuzzed
scenarios (:func:`repro.sim.fuzz.fuzz_scenario` families) through the
multi-plan stacked engine and reports

* **mean / p95 / CVaR_alpha of the makespan** — CVaR_alpha ("expected
  shortfall") is the mean of the worst ``ceil((1-alpha) * n)`` makespans:
  the expected latency *given* that one of the (1-alpha)-tail scenarios hit;
* **per-resource blocked-time attribution** — which node/link the tail
  scenarios starve, from ``obs.UtilizationReport``'s blocked decomposition
  (the Fig. 2 idle taxonomy, under failures instead of steady state).

:class:`RobustMakespan` threads the risk objective through the planner's
``CostModel`` seam, so ``bcd_solve`` / ``exhaustive_joint`` trade expected
speed against tail latency: ``risk_aversion=1`` selects plans by pure
CVaR, ``0`` by the mean over the distribution, anything between mixes.

>>> import numpy as np
>>> cvar([1.0, 2.0, 3.0, 10.0], alpha=0.75)
10.0
>>> cvar([1.0, 2.0, 3.0, 10.0], alpha=0.5)
6.5
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.cost_model import CostModel, SimMakespan
from repro.core.network import EdgeNetwork
from .engine import build_visit_table, simulate_plan, simulate_plans
from .fuzz import FuzzConfig, fuzz_scenario, fuzz_scenario_weighted
from .scenario import NetworkScenario

__all__ = ["cvar", "scenario_distribution", "importance_scenario_distribution",
           "RobustnessReport", "score_plan", "score_plans", "RobustMakespan",
           "memory_occupancy_overflow"]


def cvar(values, alpha: float = 0.95, weights=None) -> float:
    """Conditional value-at-risk: the mean of the worst
    ``ceil((1 - alpha) * n)`` values.  ``alpha=0`` is the plain mean,
    ``alpha -> 1`` the maximum.

    With ``weights`` (e.g. importance-sampling ratios from
    :func:`importance_scenario_distribution`) this is the *weighted*
    expected shortfall: the worst values forming exactly ``(1 - alpha)`` of
    the total weight, the boundary sample counted fractionally.  Note the
    unweighted path keeps the historical ceil-based tail (a whole number of
    samples), so ``cvar(v, a)`` and ``cvar(v, a, np.ones(n))`` differ
    whenever ``(1 - alpha) * n`` is fractional — comparisons across the two
    must use one convention (the IS regression test passes uniform weights
    to the reference sample too)."""
    if not 0.0 <= alpha < 1.0:
        raise ValueError("need 0 <= alpha < 1")
    arr = np.asarray(values, dtype=float)
    if arr.size == 0:
        raise ValueError("cvar of an empty sample")
    if weights is None:
        arr = np.sort(arr)
        k = int(math.ceil((1.0 - alpha) * arr.size))
        return float(arr[-k:].mean())
    w = np.asarray(weights, dtype=float)
    if w.shape != arr.shape:
        raise ValueError("weights must match values in shape")
    if np.any(w < 0) or not w.sum() > 0:
        raise ValueError("weights must be >= 0 with positive total")
    order = np.argsort(arr)[::-1]            # worst first
    v, w = arr[order], w[order]
    tail = (1.0 - alpha) * w.sum()
    before = np.cumsum(w) - w                # weight strictly worse than i
    take = np.minimum(w, np.maximum(0.0, tail - before))
    return float(np.dot(v, take) / tail)


def _weighted_quantile(values, weights, q: float) -> float:
    """Lower weighted quantile: smallest v with cumulative weight >= q."""
    v = np.asarray(values, dtype=float)
    w = np.asarray(weights, dtype=float)
    order = np.argsort(v)
    v, w = v[order], w[order]
    cum = np.cumsum(w) / w.sum()
    return float(v[int(np.searchsorted(cum, q, side="left").clip(0,
                                                                 v.size - 1))])


def scenario_distribution(net: EdgeNetwork, n: int, *, seed: int = 0,
                          config: FuzzConfig | None = None, profile=None,
                          sol=None, b: int | None = None,
                          num_microbatches: int = 4) -> tuple:
    """``n`` seeded fuzzed scenarios over ``net`` — the failure distribution
    every candidate plan is scored against (one *fixed* tuple, so scores are
    comparable across plans).  Passing a reference plan scales windows to
    its closed-form run length and arms the ``adversarial`` family against
    *its* bottleneck — the natural choice is the nominal (closed-form)
    selection, making the distribution a worst-case probe of the default
    plan."""
    config = config or FuzzConfig()
    rng = np.random.default_rng(seed)
    return tuple(fuzz_scenario(rng, net, config, profile=profile, sol=sol,
                               b=b, num_microbatches=num_microbatches)
                 for _ in range(n))


def importance_scenario_distribution(net: EdgeNetwork, n: int, *,
                                     seed: int = 0, tilt: float = 3.0,
                                     kind_tilt: dict | None = None,
                                     severity_tilt: float = 1.0,
                                     config: FuzzConfig | None = None,
                                     profile=None, sol=None,
                                     b: int | None = None,
                                     num_microbatches: int = 4) -> tuple:
    """``(scenarios, weights)``: an *importance-sampled* scenario
    distribution that over-draws rare compound failures and reweights.

    The nominal fuzzer draws the event count uniformly on
    ``[min_events, max_events]``, so at small ``n`` the compound tail — the
    scenarios stacking ``max_events`` simultaneous failures, which dominate
    CVaR — gets only ``n / K`` samples.  Here the count is drawn from the
    tilted proposal ``q(k) ∝ tilt**k`` (conditional stream given the count
    is unchanged: the fuzzer with ``min_events = max_events = k`` *is* the
    nominal conditional law), and each scenario carries the likelihood
    ratio ``p(k) / q(k)``.  Feed the weights to :func:`cvar` /
    :func:`score_plan`: the estimator stays unbiased for the uniform-count
    distribution while the tail is sampled ``~tilt**(K-1)`` x more densely.

    Beyond the count marginal, ``kind_tilt`` tilts the per-event *family*
    choice (name -> relative proposal mass, e.g. ``{"outage": 4.0}``) and
    ``severity_tilt > 1`` tilts each family's magnitude draw toward its
    damaging end — ``sim.fuzz.fuzz_scenario_weighted``.  The returned
    weights are the *joint* likelihood ratios (count x family x severity),
    so weighted estimators stay unbiased under any tilt combination.

    ``tilt=1`` with no kind/severity tilt recovers the uniform sampler
    (all weights 1, same RNG stream as :func:`scenario_distribution`)."""
    if tilt <= 0:
        raise ValueError("tilt must be > 0")
    config = config or FuzzConfig()
    ks = np.arange(config.min_events, config.max_events + 1)
    if ks.size == 0:
        raise ValueError("empty event-count range")
    p = np.full(ks.size, 1.0 / ks.size)
    q = np.power(float(tilt), ks - ks[0])
    q = q / q.sum()
    rng = np.random.default_rng(seed)
    scens, weights = [], []
    for _ in range(n):
        j = int(rng.choice(ks.size, p=q))
        cfg_k = dataclasses.replace(config, min_events=int(ks[j]),
                                    max_events=int(ks[j]))
        scen, w = fuzz_scenario_weighted(
            rng, net, cfg_k, profile=profile, sol=sol, b=b,
            num_microbatches=num_microbatches, family_tilt=kind_tilt,
            severity_tilt=severity_tilt)
        scens.append(scen)
        weights.append(float(p[j] / q[j]) * w)
    return tuple(scens), tuple(weights)


@dataclasses.dataclass(frozen=True)
class RobustnessReport:
    """Tail-risk profile of one plan across a scenario distribution."""
    makespans: tuple             # measured L_t, one per scenario
    nominal: float               # scenario-free makespan of the same plan
    alpha: float                 # CVaR confidence level
    blocked: dict | None = None  # resource -> mean blocked seconds, or None
    weights: tuple | None = None  # importance-sampling ratios, or None

    @property
    def mean(self) -> float:
        if self.weights is None:
            return float(np.mean(self.makespans))
        return float(np.average(self.makespans, weights=self.weights))

    @property
    def p95(self) -> float:
        if self.weights is None:
            return float(np.quantile(np.asarray(self.makespans), 0.95))
        return _weighted_quantile(self.makespans, self.weights, 0.95)

    @property
    def cvar(self) -> float:
        return cvar(self.makespans, self.alpha, self.weights)

    @property
    def worst(self) -> float:
        return float(np.max(self.makespans))

    @property
    def tail_inflation(self) -> float:
        """CVaR relative to the failure-free run — how much of the nominal
        speed the tail scenarios take back."""
        return self.cvar / self.nominal if self.nominal > 0 else math.inf

    def top_blocked(self, k: int = 3) -> list:
        """The ``k`` resources losing the most time to zero-capacity windows
        (``[(resource, mean_blocked_seconds)]``), worst first."""
        if not self.blocked:
            return []
        items = sorted(self.blocked.items(), key=lambda kv: -kv[1])
        return [(res, t) for res, t in items[:k] if t > 0.0]


def _blocked_attribution(profile, net, sol, b, reports, scenarios) -> dict:
    """Mean per-resource blocked seconds across the distribution's runs."""
    from repro.obs import resource_traces
    table = build_visit_table(profile, net, sol, b)
    resources = set(table.resources)
    total: dict = {}
    for rep, scen in zip(reports, scenarios):
        traces = resource_traces(net, scen, resources)
        for res, u in rep.utilization(traces=traces).resources.items():
            total[res] = total.get(res, 0.0) + u.blocked
    return {res: t / len(reports) for res, t in total.items()}


def score_plan(profile, net, sol, b: int, *, B: int | None = None,
               num_microbatches: int | None = None, scenarios,
               weights=None, policy="fifo", engine: str = "auto",
               alpha: float = 0.95,
               attribution: bool = True) -> RobustnessReport:
    """Run one plan across ``scenarios`` and report its tail risk.  With
    ``attribution=True`` each run keeps its timeline and the report carries
    mean per-resource blocked time (where the failures actually bit).
    ``weights`` (from :func:`importance_scenario_distribution`) makes every
    summary statistic importance-weighted."""
    scenarios = tuple(scenarios)
    if not scenarios:
        raise ValueError("need at least one scenario")
    weights = None if weights is None else tuple(weights)
    kw = dict(B=B, num_microbatches=num_microbatches, policy=policy,
              engine=engine)
    nominal = simulate_plan(profile, net, sol, b, **kw)
    if attribution:
        reports = [simulate_plan(profile, net, sol, b, scenario=s, **kw)
                   for s in scenarios]
        blocked = _blocked_attribution(profile, net, sol, b, reports,
                                       scenarios)
    else:
        reports = [
            simulate_plans(profile, net, [(sol, b)], B=B,
                           num_microbatches=None if num_microbatches is None
                           else [num_microbatches],
                           scenario=s, policy=policy, engine=engine)[0]
            for s in scenarios]
        blocked = None
    return RobustnessReport(makespans=tuple(r.L_t for r in reports),
                            nominal=nominal.L_t, alpha=alpha,
                            blocked=blocked, weights=weights)


def score_plans(profile, net, cands, *, B: int, scenarios, policy="fifo",
                engine: str = "auto", alpha: float = 0.95) -> list:
    """Batched :func:`score_plan` (no attribution): for each scenario, ONE
    ``simulate_plans`` call scores every candidate on the stacked plan axis;
    the per-candidate reports aggregate across scenarios."""
    cands = list(cands)
    scenarios = tuple(scenarios)
    if not scenarios:
        raise ValueError("need at least one scenario")
    nominal = simulate_plans(profile, net, cands, B=B, policy=policy,
                             engine=engine)
    cols = [simulate_plans(profile, net, cands, B=B, scenario=s,
                           policy=policy, engine=engine)
            for s in scenarios]
    return [RobustnessReport(
                makespans=tuple(col[i].L_t for col in cols),
                nominal=nominal[i].L_t, alpha=alpha)
            for i in range(len(cands))]


def memory_occupancy_overflow(profile, net, sol, b: int, report,
                              scenario: NetworkScenario | None = None, *,
                              memory_model: str = "refined") -> dict:
    """Measured peak bytes ABOVE each node's *effective* memory budget
    during one simulated run — ``{}`` when occupancy fits everywhere.

    Occupied bytes on node ``n`` at time ``t`` are the Eq. (11) claims
    (``core.cost_model.stage_memory_claims``) driven by the engine's
    measured per-stage activation occupancy
    (``sim.policies.activation_occupancy``):
    ``static_n + sum_j occ_j(t) * act_j`` over the node's stages.  The
    budget is ``scenario.mem_trace(net, n)`` — ``Node.mem`` scaled by the
    scenario's memory-pressure trace (nominal when ``scenario`` is None) —
    evaluated at every occupancy change and every budget breakpoint inside
    the run.  Returns ``{node: peak_overflow_bytes}`` for nodes that
    overflow: the ground truth the tail-sized admission bars in
    ``benchmarks/bench_adaptive.py`` measure nominal vs
    :class:`~repro.core.cost_model.DegradedTail` windows against."""
    from repro.core.cost_model import stage_memory_claims
    from .policies import activation_occupancy
    scenario = scenario or NetworkScenario()
    claims = stage_memory_claims(profile, net, sol, b, memory_model)
    occ = activation_occupancy(report.records)
    static_n: dict = {}
    stages_n: dict = {}
    for c in claims:
        static_n[c.node] = static_n.get(c.node, 0.0) + c.static_bytes
        stages_n.setdefault(c.node, []).append(c)
    horizon = report.makespan
    out: dict = {}
    for node, cls in stages_n.items():
        mem_tr = scenario.mem_trace(net, node)
        times = {0.0}
        for c in cls:
            times.update(t for t, _ in occ.get(c.position, ()))
        times.update(t for t in mem_tr.times if 0.0 <= t <= horizon)
        ts = np.asarray(sorted(times), dtype=float)
        occupied = np.full(ts.shape, static_n[node])
        for c in cls:
            series = occ.get(c.position, [])
            if not series:
                continue
            st = np.asarray([t for t, _ in series], dtype=float)
            sv = np.asarray([o for _, o in series], dtype=float)
            # post-event occupancy at the last change <= t (step function)
            idx = np.searchsorted(st, ts, side="right") - 1
            occupied += np.where(idx >= 0, sv[np.clip(idx, 0, None)],
                                 0.0) * c.act_bytes
        budget = np.asarray([mem_tr.value_at(float(t)) for t in ts])
        over = float(np.max(occupied - budget)) if ts.size else 0.0
        if over > 0.0:
            out[node] = over
    return out


class RobustMakespan(CostModel):
    """Distributionally-robust objective for the planner seam:

        objective = (1 - risk_aversion) * mean(L_t over scenarios)
                    + risk_aversion * CVaR_alpha(L_t over scenarios)

    measured by the simulator under an admission policy (memory-budgeted by
    default, like :class:`~repro.core.cost_model.SimMakespan`, whose memory
    predicate this model reuses — the Eq. (24) feasible-b box is a
    *capacity* property, not a scenario property).

    The scenario distribution is either passed explicitly (``scenarios=`` —
    what the benchmark does, so nominal- and robust-selected plans face the
    *same* failures) or lazily fuzzed on first evaluation against a network
    (seeded; windows scaled to the first-scored candidate, which under
    ``bcd_solve`` is the closed-form warm start — i.e. the distribution
    probes the default plan's weak spots).  Distributions are cached per
    network object: the elastic coordinator re-solves on *mutated* networks
    and must not reuse traces keyed to the old indices.
    """

    name = "robust_makespan"

    def __init__(self, *, scenarios=None, n_scenarios: int = 12,
                 alpha: float = 0.95, risk_aversion: float = 1.0,
                 seed: int = 0, config: FuzzConfig | None = None,
                 policy="memory", engine: str = "auto",
                 memory_model: str = "refined"):
        if not 0.0 <= risk_aversion <= 1.0:
            raise ValueError("need 0 <= risk_aversion <= 1")
        self.scenarios = None if scenarios is None else tuple(scenarios)
        self.n_scenarios = n_scenarios
        self.alpha = alpha
        self.risk_aversion = risk_aversion
        self.seed = seed
        self.config = config or FuzzConfig()
        self._sim = SimMakespan(policy=policy, engine=engine,
                                memory_model=memory_model)
        self._dist_cache: list = []      # [(net, scenarios)], small FIFO

    # -- the distribution ---------------------------------------------------
    def distribution(self, profile, net, sol=None, b=None,
                     B: int | None = None) -> tuple:
        """The scenario tuple this model scores against ``net`` — explicit
        ``scenarios`` if given, else the cached lazily-fuzzed one."""
        if self.scenarios is not None:
            return self.scenarios
        for cached_net, scens in self._dist_cache:
            if cached_net is net:
                return scens
        Q = 4
        if b and B:
            Q = max(1, 1 + math.ceil((B - b) / b))
        scens = scenario_distribution(net, self.n_scenarios, seed=self.seed,
                                      config=self.config, profile=profile,
                                      sol=sol, b=b, num_microbatches=Q)
        self._dist_cache.append((net, scens))
        del self._dist_cache[:-4]
        return scens

    def _risk(self, makespans) -> float:
        lam = self.risk_aversion
        return ((1.0 - lam) * float(np.mean(makespans))
                + lam * cvar(makespans, self.alpha))

    # -- the CostModel surface ---------------------------------------------
    def evaluate(self, profile, net, sol, b, B) -> float:
        return self.evaluate_many(profile, net, [(sol, b)], B)[0]

    def evaluate_many(self, profile, net, cands, B) -> list:
        cands = list(cands)
        out = [math.inf] * len(cands)
        live = [i for i, (sol, b) in enumerate(cands)
                if b >= 1 and self._sim.memory_feasible(profile, net, sol, b)]
        if not live:
            return out
        s0, b0 = cands[live[0]]
        scens = self.distribution(profile, net, s0, b0, B)
        cols = [simulate_plans(profile, net, [cands[i] for i in live], B=B,
                               scenario=s, policy=self._sim.policy,
                               engine=self._sim.engine)
                for s in scens]
        for j, i in enumerate(live):
            out[i] = self._risk([col[j].L_t for col in cols])
        return out

    def memory_feasible(self, profile, net, sol, b) -> bool:
        return self._sim.memory_feasible(profile, net, sol, b)

    def memory_feasible_many(self, profile, net, sol, bs) -> list:
        return self._sim.memory_feasible_many(profile, net, sol, bs)

    def report(self, profile, net, sol, b, B) -> RobustnessReport:
        """Full :class:`RobustnessReport` (with blocked-time attribution)
        for one plan under this model's distribution."""
        return score_plan(profile, net, sol, b, B=B,
                          scenarios=self.distribution(profile, net, sol, b,
                                                      B),
                          policy=self._sim.policy, engine=self._sim.engine,
                          alpha=self.alpha)

    def __repr__(self):
        src = f"n_scenarios={self.n_scenarios}, seed={self.seed}" \
            if self.scenarios is None else f"scenarios={len(self.scenarios)}"
        return (f"RobustMakespan({src}, alpha={self.alpha}, "
                f"risk_aversion={self.risk_aversion})")
