"""Seeded, shrinking scenario fuzzer — production failure families over the
``NetworkScenario`` primitives, plus the standing event-vs-vectorized
differential oracle.

The hand-written scenario vocabulary (stragglers, outages, capacity traces)
only exercises failures someone thought to write down.  This module *composes*
those primitives into the failure families edge deployments actually exhibit:

* **regional degradation** — one shared cause scales a node subset AND every
  link touching it by the same factor (``with_region_degradation``);
* **flapping links** — square-wave up/down multipliers
  (``with_flapping`` / ``scenario.square_wave``);
* **adversarially-timed outages** — placed on the *plan's bottleneck
  resource*, timed around the pipeline fill, where they hurt most;
* **stragglers / hard outages / Gauss-Markov drift** — the existing
  primitives, with windows scaled to the instance's closed-form timescale so
  fuzzed events actually land inside the run.

Every fuzzed trace returns to positive capacity (``NetworkScenario.drains``),
so fuzzed runs always have finite makespans — the one instance class the
vectorized engine cannot cover (zero trailing capacity) is *opt-in* via
``FuzzConfig(allow_dead=True)`` and exists to regression-test the documented
``engine="auto"`` event fallback.

A :class:`FuzzCase` couples a deterministic instance (regenerated from its
seed) with the fuzzed scenario; :func:`check_parity` replays it through the
event and the auto-dispatched vectorized engine and reports the makespan gap
— the differential oracle :func:`run_fuzz` sweeps.  A failing case is
minimized by :func:`shrink_case` (greedy: drop traces, truncate breakpoints,
shrink the run) and persisted to ``tests/corpus/`` via :func:`save_case`, so
every parity failure ever found stays a standing regression.

>>> import numpy as np
>>> case = fuzz_case(7)
>>> case.scenario.drains()
True
>>> check_parity(case).ok
True
"""

from __future__ import annotations

import dataclasses
import json
import math
import os

import numpy as np

from repro.core import latency as L
from repro.core.network import EdgeNetwork, make_edge_network
from repro.core.profiles import ModelProfile, random_profile
from .engine import build_visit_table, resource_trace, simulate_plan
from .scenario import NetworkScenario, PiecewiseTrace
from .validate import (TOPOLOGIES, random_chain_solution,
                       random_reentrant_solution)

__all__ = [
    "FuzzConfig", "FuzzCase", "ParityResult", "FuzzSummary",
    "FAMILIES", "ALL_FAMILIES",
    "fuzz_scenario", "fuzz_scenario_weighted", "fuzz_case",
    "fuzz_event_stream", "check_parity",
    "run_fuzz", "shrink_case", "save_case", "load_case", "load_corpus",
    "scenario_to_dict", "scenario_from_dict",
]

#: failure families the fuzzer samples from (see module docstring)
FAMILIES = ("degradation", "flapping", "outage", "straggler", "drift",
            "adversarial")

#: every family, including the opt-in "mem_pressure" (a co-tenant claiming
#: part of a node's memory — no timing effect, so it is excluded from the
#: default tuple to keep every historical seeded stream byte-identical;
#: enable via ``FuzzConfig(families=ALL_FAMILIES)``)
ALL_FAMILIES = FAMILIES + ("mem_pressure",)


@dataclasses.dataclass(frozen=True)
class FuzzConfig:
    """Knobs for one fuzzing campaign.

    ``horizon`` is the *fallback* timescale (seconds) used when no plan is
    given; with a plan, windows scale to the instance's closed-form total
    latency so perturbations overlap the simulated run.  ``allow_dead``
    permits non-draining traces (zero trailing capacity) — event-engine-only
    instances, off by default so fuzzed makespans are always finite.
    """
    families: tuple = FAMILIES
    min_events: int = 1
    max_events: int = 3
    horizon: float = 8.0
    allow_dead: bool = False
    policies: tuple = ("fifo", "1f1b")


# ---------------------------------------------------------------------------
# Failure-family samplers
# ---------------------------------------------------------------------------

def _links(net: EdgeNetwork) -> list:
    """Directed (a, c) pairs with positive effective rate."""
    n = len(net.nodes)
    return [(a, c) for a in range(n) for c in range(n)
            if a != c and net.rate[a, c] > 0]


def _window(rng: np.random.Generator, t_scale: float) -> tuple:
    """A perturbation window inside ~[0, 2 * t_scale)."""
    start = float(rng.uniform(0.0, 1.2)) * t_scale
    dur = float(rng.uniform(0.05, 0.8)) * t_scale
    return start, start + dur


def _timescale(profile, net, sol, b, num_microbatches) -> float:
    """Closed-form makespan estimate — the unit all fuzz windows scale by."""
    try:
        t = L.fill_latency(profile, net, sol, b) + \
            max(num_microbatches - 1, 0) * \
            L.pipeline_interval(profile, net, sol, b)
    except Exception:
        return 1.0
    return t if math.isfinite(t) and t > 0 else 1.0


def _bottleneck_resource(profile, net, sol, b) -> tuple:
    """The resource with the largest per-micro-batch service under nominal
    capacities — where an adversarially-timed outage hurts most."""
    table = build_visit_table(profile, net, sol, b)
    totals: dict = {}
    for v, res in enumerate(table.resources):
        tr = resource_trace(net, None, res)
        cap = tr.values[0]
        d = float(table.fixed[v]) + \
            (float(table.work[v]) / cap if cap > 0 else 0.0)
        totals[res] = totals.get(res, 0.0) + d
    return max(totals, key=totals.get)


def _sev(rng: np.random.Generator, lo: float, hi: float, tilt: float,
         worse: str) -> tuple:
    """One severity draw on ``[lo, hi)``, optionally tilted toward the
    *worse* end (``"high"`` or ``"low"``), as ``(value, log_lr)``.

    ``tilt=1`` is exactly ``rng.uniform(lo, hi)`` (same single RNG call,
    zero log-likelihood-ratio), so untilted streams stay byte-identical to
    the historical sampler.  ``tilt>1`` draws the unit coordinate from
    ``Beta(tilt, 1)`` (inverse CDF of one ``rng.random()``), concentrating
    mass near the worse end; the returned ``log_lr`` is
    ``log p(x) - log q(x)`` for the uniform nominal law ``p``."""
    if tilt == 1.0:
        return float(rng.uniform(lo, hi)), 0.0
    u = max(float(rng.random()) ** (1.0 / tilt), 1e-12)
    log_lr = -(math.log(tilt) + (tilt - 1.0) * math.log(u))
    x = u if worse == "high" else 1.0 - u
    return lo + (hi - lo) * x, log_lr


def _fuzz_scenario_impl(rng: np.random.Generator, net: EdgeNetwork,
                        config: FuzzConfig, *, profile, sol, b,
                        num_microbatches: int, family_probs=None,
                        severity_tilt: float = 1.0) -> tuple:
    """Shared sampler behind :func:`fuzz_scenario` (nominal law) and
    :func:`fuzz_scenario_weighted` (tilted proposal).  Returns
    ``(scenario, log_likelihood_ratio)``; the nominal path (no
    ``family_probs``, ``severity_tilt=1``) consumes the RNG stream
    byte-identically to the historical sampler and returns ``log_lr=0``."""
    planful = profile is not None and sol is not None and b is not None
    t_scale = _timescale(profile, net, sol, b, num_microbatches) \
        if planful else config.horizon
    families = [f for f in config.families
                if f != "adversarial" or planful]
    if not families:
        raise ValueError("no applicable failure families")
    links = _links(net)
    scen = NetworkScenario()
    log_lr = 0.0
    n_events = int(rng.integers(config.min_events, config.max_events + 1))
    for _ in range(n_events):
        if family_probs is None:
            fam = families[int(rng.integers(len(families)))]
        else:
            j = int(rng.choice(len(families), p=family_probs))
            fam = families[j]
            log_lr += math.log(1.0 / len(families)) - \
                math.log(family_probs[j])
        start, end = _window(rng, t_scale)
        if fam == "degradation":
            n_nodes = len(net.nodes)
            k = int(rng.integers(1, min(3, n_nodes) + 1))
            region = [int(i) for i in
                      rng.choice(n_nodes, size=k, replace=False)]
            touched = [lk for lk in links
                       if lk[0] in region or lk[1] in region]
            factor, lw = _sev(rng, 0.05, 0.6, severity_tilt, "low")
            scen = scen.with_region_degradation(region, touched, start, end,
                                                factor=factor)
            log_lr += lw
        elif fam == "flapping" and links:
            a, c = links[int(rng.integers(len(links)))]
            period = float(rng.uniform(0.05, 0.25)) * t_scale
            duty, lw = _sev(rng, 0.3, 0.7, severity_tilt, "low")
            scen = scen.with_flapping(
                a, c, start, end, period=period, duty=duty,
                low=float(rng.choice([0.0, 0.1])))
            log_lr += lw
        elif fam == "outage" and links:
            a, c = links[int(rng.integers(len(links)))]
            scen = scen.with_outage(a, c, start, end)
        elif fam == "straggler":
            node = int(rng.integers(len(net.nodes)))
            slowdown, lw = _sev(rng, 2.0, 16.0, severity_tilt, "high")
            scen = scen.with_straggler(node, start, end, slowdown=slowdown)
            log_lr += lw
        elif fam == "drift":
            from .scenario import gauss_markov
            cv, lw = _sev(rng, 0.1, 0.5, severity_tilt, "high")
            tr = gauss_markov(rng, cv=cv, dt=t_scale / 16,
                              horizon=2 * t_scale, corr=0.9)
            log_lr += lw
            if rng.random() < 0.5 or not links:
                node = int(rng.integers(len(net.nodes)))
                nm = dict(scen.node_mult)
                nm[node] = nm[node] * tr if node in nm else tr
                scen = dataclasses.replace(scen, node_mult=nm)
            else:
                a, c = links[int(rng.integers(len(links)))]
                lm = dict(scen.link_mult)
                lm[(a, c)] = lm[(a, c)] * tr if (a, c) in lm else tr
                scen = dataclasses.replace(scen, link_mult=lm)
        elif fam == "mem_pressure":
            node = int(rng.integers(len(net.nodes)))
            factor, lw = _sev(rng, 0.25, 0.9, severity_tilt, "low")
            scen = scen.with_mem_pressure(node, start, end, factor)
            log_lr += lw
        elif fam == "adversarial":
            res = _bottleneck_resource(profile, net, sol, b)
            t_fill = L.fill_latency(profile, net, sol, b)
            if not (math.isfinite(t_fill) and t_fill > 0):
                t_fill = t_scale
            a_start = float(rng.uniform(0.5, 1.2)) * t_fill
            a_end = a_start + float(rng.uniform(0.2, 0.8)) * t_fill
            if res[0] in ("fwd", "bwd"):
                scen = scen.with_outage(res[1], res[2], a_start, a_end)
            else:
                scen = scen.with_straggler(res[1], a_start, a_end,
                                           slowdown=50.0)
    if config.allow_dead and rng.random() < 0.5 and links:
        # opt-in: a trailing-zero trace (outage that never lifts) — the one
        # shape the vectorized engine refuses; exercises the auto fallback
        a, c = links[int(rng.integers(len(links)))]
        dead = PiecewiseTrace((0.0, float(rng.uniform(0.1, 0.9)) * t_scale),
                              (1.0, 0.0))
        lm = dict(scen.link_mult)
        lm[(a, c)] = lm[(a, c)] * dead if (a, c) in lm else dead
        scen = dataclasses.replace(scen, link_mult=lm)
    if not config.allow_dead:
        assert scen.drains(), "fuzzer invariant: scenarios must drain"
    return scen, log_lr


def fuzz_scenario(rng: np.random.Generator, net: EdgeNetwork,
                  config: FuzzConfig = FuzzConfig(), *, profile=None,
                  sol=None, b: int | None = None,
                  num_microbatches: int = 4) -> NetworkScenario:
    """Compose ``min_events..max_events`` sampled failure families into one
    scenario.  With a plan (``profile``/``sol``/``b``), windows scale to the
    closed-form run length and the ``adversarial`` family targets the plan's
    bottleneck resource; without one, that family is skipped and windows use
    ``config.horizon``.
    """
    scen, _ = _fuzz_scenario_impl(rng, net, config, profile=profile, sol=sol,
                                  b=b, num_microbatches=num_microbatches)
    return scen


def fuzz_scenario_weighted(rng: np.random.Generator, net: EdgeNetwork,
                           config: FuzzConfig = FuzzConfig(), *,
                           profile=None, sol=None, b: int | None = None,
                           num_microbatches: int = 4, family_tilt=None,
                           severity_tilt: float = 1.0) -> tuple:
    """Importance-sampled :func:`fuzz_scenario`: draw from a *tilted*
    proposal and return ``(scenario, weight)`` with the likelihood-ratio
    weight ``p(scenario) / q(scenario)`` against the nominal fuzzer law.

    ``family_tilt`` maps failure-family name -> relative proposal mass
    (unnormalized; families absent from the map keep mass 1), so e.g.
    ``{"outage": 4.0}`` over-draws outages 4x while the weights keep every
    downstream weighted statistic unbiased.  ``severity_tilt > 1`` tilts
    each family's magnitude draw toward its damaging end (low degradation
    factor, high straggler slowdown, ...) via a ``Beta(tilt, 1)`` unit
    coordinate.  Both tilts compose: the joint weight is the product of the
    per-event family and severity ratios.  ``family_tilt=None`` with
    ``severity_tilt=1`` recovers :func:`fuzz_scenario` exactly (same RNG
    stream, weight 1).

    Feed the weights to ``repro.sim.robustness.cvar`` / ``score_plan`` —
    see ``importance_scenario_distribution(kind_tilt=..., severity_tilt=...)``
    for the distribution-level wrapper that also tilts event counts."""
    if severity_tilt <= 0:
        raise ValueError("severity_tilt must be > 0")
    family_probs = None
    if family_tilt:
        planful = profile is not None and sol is not None and b is not None
        families = [f for f in config.families
                    if f != "adversarial" or planful]
        unknown = set(family_tilt) - set(config.families)
        if unknown:
            raise ValueError(f"family_tilt names unknown families "
                             f"{sorted(unknown)}; config has "
                             f"{sorted(config.families)}")
        if any(v <= 0 for v in family_tilt.values()):
            raise ValueError("family_tilt masses must be > 0")
        q = np.asarray([float(family_tilt.get(f, 1.0)) for f in families])
        family_probs = q / q.sum()
    scen, log_lr = _fuzz_scenario_impl(
        rng, net, config, profile=profile, sol=sol, b=b,
        num_microbatches=num_microbatches, family_probs=family_probs,
        severity_tilt=severity_tilt)
    return scen, float(math.exp(log_lr))


# ---------------------------------------------------------------------------
# Cases: deterministic instance + fuzzed scenario, (de)serializable
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FuzzCase:
    """One differential-oracle input.  The (profile, net, sol) instance is
    regenerated deterministically from ``seed``/``reentrant`` by
    :func:`case_instance`; the scenario rides along explicitly so a shrunk
    case stays reproducible byte-for-byte."""
    seed: int
    reentrant: bool
    b: int
    num_microbatches: int
    policy: str
    scenario: NetworkScenario
    note: str = ""

    def to_dict(self) -> dict:
        if self.scenario.replan_triggers:
            raise ValueError("replan triggers are not serializable")
        return {"format": "repro.sim.fuzz/1", "seed": self.seed,
                "reentrant": self.reentrant, "b": self.b,
                "num_microbatches": self.num_microbatches,
                "policy": self.policy, "note": self.note,
                "scenario": scenario_to_dict(self.scenario)}

    @classmethod
    def from_dict(cls, d: dict) -> "FuzzCase":
        if d.get("format") != "repro.sim.fuzz/1":
            raise ValueError(f"unknown corpus format {d.get('format')!r}")
        return cls(seed=int(d["seed"]), reentrant=bool(d["reentrant"]),
                   b=int(d["b"]),
                   num_microbatches=int(d["num_microbatches"]),
                   policy=str(d["policy"]), note=str(d.get("note", "")),
                   scenario=scenario_from_dict(d["scenario"]))


def _trace_to_dict(tr: PiecewiseTrace) -> dict:
    return {"times": list(tr.times), "values": list(tr.values)}


def _trace_from_dict(d: dict) -> PiecewiseTrace:
    return PiecewiseTrace(tuple(float(t) for t in d["times"]),
                          tuple(float(v) for v in d["values"]))


def scenario_to_dict(scen: NetworkScenario) -> dict:
    """JSON-safe scenario encoding (capacity multipliers only; replan
    triggers carry arbitrary event objects and are rejected)."""
    if scen.replan_triggers:
        raise ValueError("replan triggers are not serializable")
    out = {
        "node_mult": {str(n): _trace_to_dict(tr)
                      for n, tr in sorted(scen.node_mult.items())},
        "link_mult": {f"{a},{c}": _trace_to_dict(tr)
                      for (a, c), tr in sorted(scen.link_mult.items())},
    }
    if scen.mem_mult:            # omitted when empty: corpus back-compat
        out["mem_mult"] = {str(n): _trace_to_dict(tr)
                           for n, tr in sorted(scen.mem_mult.items())}
    return out


def scenario_from_dict(d: dict) -> NetworkScenario:
    node_mult = {int(n): _trace_from_dict(tr)
                 for n, tr in d.get("node_mult", {}).items()}
    link_mult = {}
    for key, tr in d.get("link_mult", {}).items():
        a, c = key.split(",")
        link_mult[(int(a), int(c))] = _trace_from_dict(tr)
    mem_mult = {int(n): _trace_from_dict(tr)
                for n, tr in d.get("mem_mult", {}).items()}
    return NetworkScenario(node_mult=node_mult, link_mult=link_mult,
                           mem_mult=mem_mult)


def _instance_from_rng(rng: np.random.Generator, seed: int, reentrant: bool):
    num_layers = int(rng.integers(5, 11))
    num_servers = int(rng.integers(2, 5))
    num_clients = int(rng.integers(1, 4))
    profile = random_profile(rng, num_layers)
    net = make_edge_network(num_servers=num_servers, num_clients=num_clients,
                            topology=TOPOLOGIES[seed % len(TOPOLOGIES)],
                            seed=seed)
    make = random_reentrant_solution if reentrant else random_chain_solution
    # the reentrant generator can draw consecutive same-node placements
    # (invalid under Eq. 21) — redraw from the same stream, so the instance
    # stays a pure function of (seed, reentrant)
    for _ in range(32):
        try:
            return profile, net, make(rng, profile, net)
        except ValueError:
            continue
    return profile, net, random_chain_solution(rng, profile, net)


def case_instance(case: FuzzCase):
    """Regenerate the deterministic (profile, net, sol) behind ``case``."""
    rng = np.random.default_rng(case.seed)
    return _instance_from_rng(rng, case.seed, case.reentrant)


def fuzz_case(seed: int, config: FuzzConfig = FuzzConfig()) -> FuzzCase:
    """One seeded oracle input: instance, run shape, and fuzzed scenario.
    Same seed + config -> byte-identical case."""
    rng = np.random.default_rng(seed)
    reentrant = seed % 3 == 2
    profile, net, sol = _instance_from_rng(rng, seed, reentrant)
    b = int(rng.integers(1, 5))
    Q = int(rng.integers(2, 9))
    policy = config.policies[int(rng.integers(len(config.policies)))]
    scen = fuzz_scenario(rng, net, config, profile=profile, sol=sol, b=b,
                         num_microbatches=Q)
    return FuzzCase(seed=seed, reentrant=reentrant, b=b,
                    num_microbatches=Q, policy=policy, scenario=scen)


# ---------------------------------------------------------------------------
# The differential oracle
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ParityResult:
    """Event-vs-auto replay of one case."""
    gap: float                   # max relative micro-batch completion gap
    engine: str                  # engine the auto dispatch ran
    engine_reason: str
    makespan: float
    finite: bool
    rtol: float = 1e-9

    @property
    def ok(self) -> bool:
        return self.finite and self.gap <= self.rtol


def check_parity(case: FuzzCase, *, rtol: float = 1e-9) -> ParityResult:
    """Replay ``case`` through the exact event engine and the auto-dispatched
    vectorized engine; report the completion-time gap.  When auto falls back
    to the event engine (non-draining trace, fixpoint non-convergence) the
    gap is trivially 0 and ``engine``/``engine_reason`` say why."""
    profile, net, sol = case_instance(case)
    kw = dict(num_microbatches=case.num_microbatches, scenario=case.scenario,
              policy=case.policy)
    ev = simulate_plan(profile, net, sol, case.b, engine="event", **kw)
    au = simulate_plan(profile, net, sol, case.b, engine="auto", **kw)
    same = ev.mb_complete == au.mb_complete           # inf == inf agrees
    with np.errstate(invalid="ignore"):
        rel = np.abs(ev.mb_complete - au.mb_complete) / \
            np.maximum(np.abs(ev.mb_complete), 1e-30)
    rel = np.where(same, 0.0, rel)
    gap = float(np.max(rel)) if rel.size else 0.0
    if math.isnan(gap):                               # inf vs finite
        gap = float("inf")
    finite = bool(math.isfinite(ev.makespan) and math.isfinite(au.makespan))
    return ParityResult(gap=gap, engine=au.engine,
                        engine_reason=au.engine_reason,
                        makespan=au.makespan, finite=finite, rtol=rtol)


@dataclasses.dataclass
class FuzzSummary:
    """Outcome of one :func:`run_fuzz` campaign."""
    trials: int
    vectorized: int              # cases the auto dispatch vectorized
    event_fallback: int          # cases auto fell back to the heap
    max_gap: float
    failures: list               # [(FuzzCase, ParityResult)] — parity broken

    @property
    def ok(self) -> bool:
        return not self.failures


def run_fuzz(trials: int, *, seed: int = 0,
             config: FuzzConfig = FuzzConfig(),
             rtol: float = 1e-9) -> FuzzSummary:
    """The standing differential campaign: ``trials`` seeded cases replayed
    through both engines.  Deterministic for a fixed (trials, seed, config).
    """
    vec = fb = 0
    max_gap = 0.0
    failures: list = []
    for i in range(trials):
        case = fuzz_case(seed * 100_003 + i, config)
        res = check_parity(case, rtol=rtol)
        if res.engine == "vectorized":
            vec += 1
        else:
            fb += 1
        max_gap = max(max_gap, res.gap)
        if not res.ok:
            failures.append((case, res))
    return FuzzSummary(trials=trials, vectorized=vec, event_fallback=fb,
                       max_gap=max_gap, failures=failures)


# ---------------------------------------------------------------------------
# Shrinking: minimize a failing case while the predicate still fails
# ---------------------------------------------------------------------------

def _trace_variants(tr: PiecewiseTrace):
    """Simpler candidate replacements for one trace, simplest first."""
    n = len(tr.times)
    if n <= 1:
        return
    yield PiecewiseTrace((0.0,), (tr.values[-1],))      # constant tail value
    yield PiecewiseTrace(tr.times[:1 + n // 2], tr.values[:1 + n // 2])
    if n > 2:                                           # decimate interior
        idx = [0] + list(range(1, n - 1, 2)) + [n - 1]
        yield PiecewiseTrace(tuple(tr.times[i] for i in idx),
                             tuple(tr.values[i] for i in idx))


def _scenario_edits(scen: NetworkScenario):
    """Candidate one-step simplifications of a scenario, biggest first."""
    for n in sorted(scen.node_mult):
        nm = {k: v for k, v in scen.node_mult.items() if k != n}
        yield dataclasses.replace(scen, node_mult=nm)
    for lk in sorted(scen.link_mult):
        lm = {k: v for k, v in scen.link_mult.items() if k != lk}
        yield dataclasses.replace(scen, link_mult=lm)
    for n in sorted(scen.node_mult):
        for var in _trace_variants(scen.node_mult[n]):
            nm = dict(scen.node_mult)
            nm[n] = var
            yield dataclasses.replace(scen, node_mult=nm)
    for lk in sorted(scen.link_mult):
        for var in _trace_variants(scen.link_mult[lk]):
            lm = dict(scen.link_mult)
            lm[lk] = var
            yield dataclasses.replace(scen, link_mult=lm)


def shrink_case(case: FuzzCase, failing, *, max_rounds: int = 16) -> FuzzCase:
    """Greedy minimization: while ``failing(case)`` stays True, try dropping
    whole multiplier traces, simplifying the survivors' breakpoints, and
    shrinking the run (fewer micro-batches, smaller b).  Deterministic; the
    result still satisfies ``failing``."""
    if not failing(case):
        raise ValueError("shrink_case needs a failing case to start from")
    for _ in range(max_rounds):
        progressed = False
        for scen in _scenario_edits(case.scenario):
            cand = dataclasses.replace(case, scenario=scen)
            if failing(cand):
                case = cand
                progressed = True
                break
        if progressed:
            continue
        for Q in (case.num_microbatches // 2, case.num_microbatches - 1):
            if Q >= 1 and Q < case.num_microbatches:
                cand = dataclasses.replace(case, num_microbatches=Q)
                if failing(cand):
                    case = cand
                    progressed = True
                    break
        if progressed:
            continue
        if case.b > 1:
            cand = dataclasses.replace(case, b=1)
            if failing(cand):
                case = cand
                continue
        break
    return case


# ---------------------------------------------------------------------------
# Corpus: persisted minimized repros, replayed by CI
# ---------------------------------------------------------------------------

def save_case(case: FuzzCase, directory: str, name: str | None = None,
              note: str | None = None) -> str:
    """Persist a (usually shrunk) case as JSON; returns the path."""
    os.makedirs(directory, exist_ok=True)
    if note is not None:
        case = dataclasses.replace(case, note=note)
    name = name or f"case_{case.seed}"
    path = os.path.join(directory, f"{name}.json")
    with open(path, "w") as f:
        json.dump(case.to_dict(), f, indent=1, sort_keys=True)
        f.write("\n")
    return path


def load_case(path: str) -> FuzzCase:
    with open(path) as f:
        return FuzzCase.from_dict(json.load(f))


def load_corpus(directory: str) -> list:
    """All corpus cases in ``directory``, as ``[(path, FuzzCase)]``."""
    if not os.path.isdir(directory):
        return []
    out = []
    for fn in sorted(os.listdir(directory)):
        if fn.endswith(".json"):
            path = os.path.join(directory, fn)
            out.append((path, load_case(path)))
    return out


# ---------------------------------------------------------------------------
# Event-stream fuzzing: churn for the elastic coordinator
# ---------------------------------------------------------------------------

def fuzz_event_stream(rng: np.random.Generator, net: EdgeNetwork, *,
                      horizon: float, max_events: int = 3,
                      min_servers: int = 2, allow_failure: bool = True,
                      flap_fraction: float = 0.0,
                      flap_window: float | None = None) -> tuple:
    """A time-ordered tuple of ``ReplanTrigger``s drawn from the ``repro.ft``
    event vocabulary — mid-round node churn (``NodeFailure``), rate drops,
    stragglers — with indices kept valid across the renumbering each failure
    causes (the coordinator's ``degraded()`` drops a server and shifts later
    indices).  Feed to ``simulate_with_replanning``.

    ``flap_fraction`` of the drawn events (rounded down) become *flaps*: a
    ``RateChange(a, c, f)`` followed within ``flap_window`` (default
    ``horizon / 20``) by its exact reversal ``RateChange(a, c, 1/f)`` — the
    route-dampening workload a debounced replan policy exists to absorb
    (``repro.ft.Hysteresis`` sees the pair cancel to zero cumulative
    deviation).  Flaps never stack with node failures; each flap consumes
    one drawn event slot but emits two triggers."""
    from repro.ft.coordinator import NodeFailure, RateChange, Straggler
    from .scenario import ReplanTrigger
    if not 0.0 <= flap_fraction <= 1.0:
        raise ValueError("flap_fraction must be in [0, 1]")
    if flap_window is None:
        flap_window = horizon / 20.0
    n_nodes = len(net.nodes)
    times = np.sort(rng.uniform(0.05 * horizon, 0.95 * horizon,
                                int(rng.integers(1, max_events + 1))))
    n_flaps = int(math.floor(flap_fraction * len(times)))
    flap_slots = set(rng.choice(len(times), size=n_flaps, replace=False)
                     .tolist()) if n_flaps else set()
    trigs = []
    for i, t in enumerate(times):
        if i in flap_slots:
            a = int(rng.integers(n_nodes))
            c = int(rng.integers(n_nodes))
            if a == c:
                c = (c + 1) % n_nodes
            f = float(rng.uniform(0.1, 0.8))
            dt = float(rng.uniform(0.1, 1.0)) * flap_window
            if i + 1 < len(times):
                # keep the reversal before the next drawn event so a later
                # NodeFailure's renumbering can't invalidate its indices
                dt = min(dt, 0.5 * (float(times[i + 1]) - float(t)))
            trigs.append(ReplanTrigger(float(t), RateChange(a, c, f)))
            trigs.append(ReplanTrigger(float(t) + dt,
                                       RateChange(a, c, 1.0 / f)))
            continue
        kinds = ["straggler", "rate"]
        if allow_failure and n_nodes - 1 > min_servers:
            kinds.append("failure")
        kind = kinds[int(rng.integers(len(kinds)))]
        if kind == "failure":
            server = int(rng.integers(1, n_nodes))
            trigs.append(ReplanTrigger(float(t), NodeFailure(server)))
            n_nodes -= 1
        elif kind == "straggler":
            node = int(rng.integers(1, n_nodes))
            trigs.append(ReplanTrigger(
                float(t), Straggler(node, float(rng.uniform(1.5, 8.0)))))
        else:
            a = int(rng.integers(n_nodes))
            c = int(rng.integers(n_nodes))
            if a == c:
                c = (c + 1) % n_nodes
            trigs.append(ReplanTrigger(
                float(t), RateChange(a, c, float(rng.uniform(0.1, 0.8)))))
    return tuple(sorted(trigs, key=lambda tr: tr.time))
