"""Discrete-event simulation of pipelined split learning (the execution
counterpart of the Eq. (1)-(14) analytical model).

``engine`` executes a split/placement solution as discrete events — per
micro-batch FP/BP compute on each node and activation/gradient transfers on
each hop, with FIFO resource occupancy (a node engine or link serves one unit
at a time, matching the co-location sums of C9-C16).  ``scenario`` supplies
time-varying capacity traces (piecewise-constant, Gauss-Markov), straggler
windows, link outages, and replan triggers.  ``validate`` cross-checks the
simulated ``T_f``/``T_i``/``L_t`` against ``core.latency`` on deterministic
networks — exact to numerical tolerance, a standing consistency test.
"""

from .events import Task, TraceRecord, write_chrome_trace
from .scenario import (PiecewiseTrace, constant, piecewise, gauss_markov,
                       iid_piecewise, NetworkScenario, ReplanTrigger,
                       piecewise_cv_scenario, gauss_markov_scenario)
from .engine import (PipelineSimulator, SimReport, build_tasks, simulate_plan,
                     SegmentReport, ReplanSimReport, simulate_with_replanning)
from .validate import (CrossCheck, cross_validate, cross_validate_many,
                       random_chain_solution, random_instance)

__all__ = [
    "Task", "TraceRecord", "write_chrome_trace",
    "PiecewiseTrace", "constant", "piecewise", "gauss_markov",
    "iid_piecewise", "NetworkScenario", "ReplanTrigger",
    "piecewise_cv_scenario", "gauss_markov_scenario",
    "PipelineSimulator", "SimReport", "build_tasks", "simulate_plan",
    "SegmentReport", "ReplanSimReport", "simulate_with_replanning",
    "CrossCheck", "cross_validate", "cross_validate_many",
    "random_chain_solution", "random_instance",
]
