"""Discrete-event simulation of pipelined split learning (the execution
counterpart of the Eq. (1)-(14) analytical model).

``engine`` executes a split/placement solution — per micro-batch FP/BP
compute on each node and activation/gradient transfers on each hop, with
FIFO resource occupancy (a node engine or link serves one unit at a time,
matching the co-location sums of C9-C16) — via either the exact heap-based
event loop or the vectorized batched-advancement engine (``engine="auto"``
picks whichever is exact and fastest).  ``policies`` supplies pluggable
micro-batch admission: GPipe-like ``FIFO``, fixed-depth ``OneFOneB`` (1F1B),
and ``MemoryBudgeted`` (windows derived from ``Node.mem`` and the Eq. (11)
activation profile), whose closed-form activation high-water claims the
engine validates event by event.  ``scenario`` supplies time-varying capacity traces
(piecewise-constant, Gauss-Markov), straggler windows, link outages, and
replan triggers.  ``validate`` cross-checks the simulated ``T_f``/``T_i``/
``L_t`` against ``core.latency`` on deterministic networks — exact to
numerical tolerance, a standing consistency test — and the two engines
against each other.  ``fuzz`` composes the scenario primitives into seeded
production-failure families (regional degradation, flapping links,
adversarially-timed bottleneck outages, node churn event streams) behind a
shrinking differential oracle, and ``robustness`` scores plans across those
distributions (mean/p95/CVaR of makespan, blocked-time attribution) with
``RobustMakespan`` threading tail risk through the planner's cost-model
seam.
"""

from .events import (Task, Timeline, TraceRecord, VisitTable,
                     write_chrome_trace)
from .scenario import (PiecewiseTrace, constant, piecewise, gauss_markov,
                       iid_piecewise, square_wave, NetworkScenario,
                       ReplanTrigger, piecewise_cv_scenario,
                       gauss_markov_scenario, sampled_network,
                       periodic_resync_triggers)
from .policies import (AdmissionPolicy, FIFO, OneFOneB, MemoryBudgeted,
                       resolve_policy, activation_occupancy,
                       stage_activation_highwater)
from .engine import (PipelineSimulator, SimReport, build_tasks,
                     build_visit_table, simulate_plan, simulate_plans,
                     vectorizable, SegmentReport, ReplanSimReport,
                     simulate_with_replanning)
from .validate import (CrossCheck, cross_validate, cross_validate_many,
                       compare_engines, compare_utilization,
                       random_chain_solution, random_instance,
                       random_reentrant_solution)
from .fuzz import (ALL_FAMILIES, FuzzCase, FuzzConfig, FuzzSummary,
                   ParityResult, check_parity, fuzz_case, fuzz_event_stream,
                   fuzz_scenario, fuzz_scenario_weighted, load_case,
                   load_corpus, run_fuzz, save_case, shrink_case)
from .robustness import (RobustMakespan, RobustnessReport, cvar,
                         scenario_distribution,
                         importance_scenario_distribution,
                         memory_occupancy_overflow, score_plan,
                         score_plans)

__all__ = [
    "Task", "Timeline", "TraceRecord", "VisitTable", "write_chrome_trace",
    "PiecewiseTrace", "constant", "piecewise", "gauss_markov",
    "iid_piecewise", "square_wave", "NetworkScenario", "ReplanTrigger",
    "piecewise_cv_scenario", "gauss_markov_scenario", "sampled_network",
    "periodic_resync_triggers",
    "AdmissionPolicy", "FIFO", "OneFOneB", "MemoryBudgeted", "resolve_policy",
    "activation_occupancy", "stage_activation_highwater",
    "PipelineSimulator", "SimReport", "build_tasks", "build_visit_table",
    "simulate_plan", "simulate_plans", "vectorizable",
    "SegmentReport", "ReplanSimReport", "simulate_with_replanning",
    "CrossCheck", "cross_validate", "cross_validate_many", "compare_engines",
    "compare_utilization",
    "random_chain_solution", "random_instance", "random_reentrant_solution",
    "ALL_FAMILIES", "FuzzCase", "FuzzConfig", "FuzzSummary", "ParityResult",
    "check_parity", "fuzz_case", "fuzz_event_stream", "fuzz_scenario",
    "fuzz_scenario_weighted", "load_case", "load_corpus", "run_fuzz",
    "save_case", "shrink_case",
    "RobustMakespan", "RobustnessReport", "cvar", "scenario_distribution",
    "importance_scenario_distribution", "memory_occupancy_overflow",
    "score_plan", "score_plans",
]
