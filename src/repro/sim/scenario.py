"""Time-varying network scenarios for the simulator.

Capacities (node FLOP/s, link bytes/s) evolve as *piecewise-constant* step
functions of simulated time — rich enough to express every dynamic the
surrounding papers study (sampled Gauss-Markov channels, straggler windows,
link outages) while keeping task-completion times exactly integrable: a task
of ``work`` units started at ``t0`` finishes when the integral of the
capacity trace reaches ``work``.

This supersedes the i.i.d. per-draw perturbations of
``core.fluctuation.evaluate_under_fluctuation`` (its ``mode="trace"`` path
routes through these scenarios): instead of one multiplicative draw per
evaluation, conditions drift *during* the pipeline, so early micro-batches
can see different capacity than late ones.

>>> tr = piecewise((0.0, 1.0), (2.0, 0.5))      # 2 units/s, then 0.5
>>> tr.time_to_complete(0.0, 3.0)               # 2.0 by t=1, then 1.0 at 0.5
3.0
>>> scen = NetworkScenario().with_straggler(1, start=1.0, end=3.0,
...                                         slowdown=4.0)
>>> scen.node_mult[1].value_at(2.0)             # 4x slower inside the window
0.25
"""

from __future__ import annotations

import bisect
import dataclasses
import functools
import math

import numpy as np

from repro.core.network import EdgeNetwork


# ---------------------------------------------------------------------------
# Piecewise-constant traces
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PiecewiseTrace:
    """value(t) = values[i] on [times[i], times[i+1]); last value holds
    forever.  ``times`` is strictly increasing with ``times[0] == 0.0``.

    ``__post_init__`` precomputes the breakpoint arrays and the
    cumulative-work prefix ``cumwork[i] = integral of the trace over
    [0, times[i])`` once per trace, so :meth:`value_at` and
    :meth:`time_to_complete` are a bisect instead of a linear walk and the
    vectorized engine's segmented scans (:meth:`work_done_many` /
    :meth:`finish_many`) are ``np.searchsorted`` lookups.
    """
    times: tuple
    values: tuple

    def __post_init__(self):
        if len(self.times) != len(self.values) or not self.times:
            raise ValueError("times/values must be non-empty, equal length")
        if self.times[0] != 0.0:
            raise ValueError("trace must start at t = 0")
        if any(b <= a for a, b in zip(self.times, self.times[1:])):
            raise ValueError("times must be strictly increasing")
        if not math.isfinite(self.times[-1]):
            raise ValueError("breakpoints must be finite (the last value "
                             "holds forever, so an inf breakpoint is "
                             "expressed by dropping it)")
        if any(v < 0 for v in self.values):
            raise ValueError("capacities must be non-negative")
        times_arr = np.asarray(self.times, dtype=float)
        values_arr = np.asarray(self.values, dtype=float)
        cumwork = np.zeros(len(times_arr))
        if len(times_arr) > 1:
            np.cumsum(values_arr[:-1] * np.diff(times_arr), out=cumwork[1:])
        # frozen dataclass: the derived caches are not fields
        object.__setattr__(self, "times_arr", times_arr)
        object.__setattr__(self, "values_arr", values_arr)
        object.__setattr__(self, "cumwork", cumwork)

    def value_at(self, t: float) -> float:
        i = bisect.bisect_right(self.times, t) - 1
        return self.values[max(i, 0)]

    def scale(self, factor: float) -> "PiecewiseTrace":
        return PiecewiseTrace(self.times,
                              tuple(v * factor for v in self.values))

    def __mul__(self, other: "PiecewiseTrace") -> "PiecewiseTrace":
        """Pointwise product (merged breakpoints)."""
        times = sorted(set(self.times) | set(other.times))
        values = tuple(self.value_at(t) * other.value_at(t) for t in times)
        return PiecewiseTrace(tuple(times), values)

    def is_constant(self) -> bool:
        return len(set(self.values)) == 1

    def drains(self) -> bool:
        """True when any positive amount of work eventually completes from
        any start time — i.e. the trailing capacity is positive.  The
        vectorized engine's eligibility gate (a trailing-zero trace stalls
        forever, which only the event engine reports exactly as ``inf``)."""
        return self.values[-1] > 0.0

    # -- cumulative-work coordinates (the segmented-scan primitives) --------
    def work_done(self, t: float) -> float:
        """Integral of the trace over [0, t) (extrapolating ``values[0]``
        left of 0, matching the historical integration semantics)."""
        if math.isinf(t):
            return math.inf if self.values[-1] > 0.0 \
                else float(self.cumwork[-1])
        i = max(bisect.bisect_right(self.times, t) - 1, 0)
        return float(self.cumwork[i]) + self.values[i] * (t - self.times[i])

    def finish_time(self, target: float) -> float:
        """Smallest ``t`` with ``work_done(t) >= target`` (``inf`` when the
        trace's total capacity never reaches ``target``)."""
        if target <= 0.0:
            return 0.0
        j = bisect.bisect_left(self.cumwork, target)
        if j < len(self.cumwork):
            return self.times[j - 1] + \
                (target - float(self.cumwork[j - 1])) / self.values[j - 1]
        v = self.values[-1]
        if v <= 0.0:
            return math.inf
        return self.times[-1] + (target - float(self.cumwork[-1])) / v

    def work_done_many(self, t: np.ndarray) -> np.ndarray:
        """Vectorized :meth:`work_done` over an array of times."""
        t = np.asarray(t, dtype=float)
        i = np.clip(np.searchsorted(self.times_arr, t, side="right") - 1,
                    0, None)
        return self.cumwork[i] + self.values_arr[i] * (t - self.times_arr[i])

    def finish_many(self, target: np.ndarray) -> np.ndarray:
        """Vectorized :meth:`finish_time` over an array of work targets.

        Assumes every positive target is reachable (``drains()`` — the
        vectorized engine gates on it); non-positive targets map to 0.
        """
        target = np.asarray(target, dtype=float)
        j = np.searchsorted(self.cumwork, target, side="left")
        pos = np.clip(j, 1, len(self.cumwork)) - 1
        with np.errstate(divide="ignore", invalid="ignore"):
            out = self.times_arr[pos] + \
                (target - self.cumwork[pos]) / self.values_arr[pos]
        return np.where(target <= 0.0, 0.0, out)

    def time_to_complete(self, t0: float, work: float) -> float:
        """Seconds after ``t0`` until the integral of the trace covers
        ``work``; ``inf`` if capacity stays zero before the work drains."""
        if work <= 0.0:
            return 0.0
        t = self.finish_time(self.work_done(t0) + work)
        if math.isinf(t):
            return math.inf
        return t - t0


@functools.lru_cache(maxsize=4096)
def _constant_cached(value: float) -> PiecewiseTrace:
    return PiecewiseTrace((0.0,), (value,))


def constant(value: float) -> PiecewiseTrace:
    """Constant-capacity trace.  Instances are immutable and cached — the
    engine asks for the same node/link constants once per visit per run,
    and the breakpoint-array precompute is not free."""
    return _constant_cached(float(value))


def piecewise(times, values) -> PiecewiseTrace:
    """Build a trace, coalescing zero-length segments.

    ``PiecewiseTrace`` itself is strict (strictly increasing breakpoints);
    this constructor additionally accepts *duplicate* consecutive times —
    zero-length segments, as produced e.g. by composing windows that share a
    boundary — and keeps the **last** value given for each time, matching
    the right-continuous ``value(t) = values[i] on [times[i], times[i+1])``
    semantics under which a zero-length segment covers no time at all.

    >>> piecewise((0.0, 1.0, 1.0, 2.0), (1.0, 99.0, 2.0, 3.0))
    PiecewiseTrace(times=(0.0, 1.0, 2.0), values=(1.0, 2.0, 3.0))
    """
    ts = [float(t) for t in times]
    vs = [float(v) for v in values]
    if len(ts) != len(vs):
        raise ValueError("times/values must have equal length")
    out_t: list = []
    out_v: list = []
    for t, v in zip(ts, vs):
        if out_t and t == out_t[-1]:
            out_v[-1] = v            # zero-length segment: last value wins
        else:
            out_t.append(t)
            out_v.append(v)
    return PiecewiseTrace(tuple(out_t), tuple(out_v))


def _window(start: float, end: float, inside: float) -> PiecewiseTrace:
    """Multiplier trace: ``inside`` on [start, end), 1 elsewhere.

    A zero-length window (``start == end``) covers no time and degenerates
    to the identity multiplier."""
    if not 0.0 <= start <= end:
        raise ValueError("need 0 <= start <= end")
    if start == end:
        return constant(1.0)
    if start == 0.0:
        return piecewise((0.0, end), (inside, 1.0))
    return piecewise((0.0, start, end), (1.0, inside, 1.0))


def square_wave(start: float, end: float, *, period: float,
                duty: float = 0.5, low: float = 0.0,
                high: float = 1.0) -> PiecewiseTrace:
    """Flapping-link multiplier: alternates ``high`` (for ``duty * period``)
    and ``low`` within ``[start, end)``, 1 outside — the square-wave model
    of a link that repeatedly drops and recovers.  The trace always returns
    to 1 at ``end``, so it drains (finite makespans) by construction.

    >>> square_wave(0.0, 2.0, period=1.0, duty=0.5, low=0.0)
    PiecewiseTrace(times=(0.0, 0.5, 1.0, 1.5, 2.0), values=(1.0, 0.0, 1.0, 0.0, 1.0))
    """
    if not 0.0 <= start <= end:
        raise ValueError("need 0 <= start <= end")
    if period <= 0.0 or not 0.0 < duty < 1.0:
        raise ValueError("need period > 0 and 0 < duty < 1")
    if start == end:
        return constant(1.0)
    times = [0.0] if start == 0.0 else [0.0, start]
    values = [high] if start == 0.0 else [1.0, high]
    t = start
    up = True
    while t < end:
        t = min(t + (duty if up else 1.0 - duty) * period, end)
        up = not up
        times.append(t)
        values.append((high if up else low) if t < end else 1.0)
    return piecewise(tuple(times), tuple(values))


def iid_piecewise(rng: np.random.Generator, cv: float, *, dt: float,
                  horizon: float, mean: float = 1.0,
                  floor: float = 0.05) -> PiecewiseTrace:
    """Independent ``max(N(mean, cv*mean), floor)`` draws every ``dt`` —
    the trace analogue of ``EdgeNetwork.with_fluctuation``'s marginals."""
    if cv <= 0:
        return constant(mean)
    n = max(int(math.ceil(horizon / dt)), 1) + 1
    vals = np.maximum(rng.normal(mean, cv * mean, n), floor)
    return piecewise(tuple(i * dt for i in range(n)), tuple(vals))


def gauss_markov(rng: np.random.Generator, cv: float, *, dt: float,
                 horizon: float, mean: float = 1.0, corr: float = 0.9,
                 floor: float = 0.05) -> PiecewiseTrace:
    """Sampled stationary AR(1) (Gauss-Markov) multiplier trace:

        x[j+1] = mean + corr * (x[j] - mean) + sigma * sqrt(1-corr^2) * eps

    with stationary std ``sigma = cv * mean`` — temporally *correlated*
    fluctuation, the standard mobility/channel drift model.
    """
    if cv <= 0:
        return constant(mean)
    n = max(int(math.ceil(horizon / dt)), 1) + 1
    sigma = cv * mean
    x = mean + sigma * float(rng.standard_normal())
    vals = []
    innov = sigma * math.sqrt(max(1.0 - corr * corr, 0.0))
    for _ in range(n):
        vals.append(max(x, floor))
        x = mean + corr * (x - mean) + innov * float(rng.standard_normal())
    return piecewise(tuple(i * dt for i in range(n)), tuple(vals))


# ---------------------------------------------------------------------------
# Network scenario: per-node / per-link multipliers + replan triggers
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ReplanTrigger:
    """At simulated ``time``, feed ``event`` (an ``repro.ft`` event —
    Straggler/RateChange/NodeFailure) to the coordinator and resume the
    remaining micro-batches under its new plan."""
    time: float
    event: object


@dataclasses.dataclass(frozen=True)
class NetworkScenario:
    """Multiplier traces over a base ``EdgeNetwork``.

    ``node_mult[n]`` scales node n's compute capability f_n over time;
    ``link_mult[(n, n')]`` scales the directed effective rate.  Absent keys
    mean "constant 1".  Scenarios are immutable; ``with_*`` helpers compose
    extra windows multiplicatively.

    ``mem_mult[n]`` scales node n's *available memory* (``Node.mem``) —
    co-tenant pressure, not a timing effect: the engines ignore it (task
    durations depend on compute/link capacity only), but admission sizing
    (``core.cost_model.DegradedTail``) and measurement snapshots
    (:func:`sampled_network`) consume it, so plans can be sized for the
    degraded-memory tail instead of the nominal budget.
    """
    node_mult: dict = dataclasses.field(default_factory=dict)
    link_mult: dict = dataclasses.field(default_factory=dict)
    replan_triggers: tuple = ()
    mem_mult: dict = dataclasses.field(default_factory=dict)

    # -- capacity traces ----------------------------------------------------
    def node_trace(self, net: EdgeNetwork, node: int) -> PiecewiseTrace:
        base = constant(net.nodes[node].f)
        m = self.node_mult.get(node)
        return base * m if m is not None else base

    def link_trace(self, net: EdgeNetwork, a: int, c: int) -> PiecewiseTrace:
        base = constant(net.rate[a, c])
        m = self.link_mult.get((a, c))
        return base * m if m is not None else base

    def mem_trace(self, net: EdgeNetwork, node: int) -> PiecewiseTrace:
        """Node ``node``'s *available memory* in bytes over time."""
        base = constant(net.nodes[node].mem)
        m = self.mem_mult.get(node)
        return base * m if m is not None else base

    # -- composition --------------------------------------------------------
    def _compose(self, table: dict, key, trace: PiecewiseTrace) -> dict:
        out = dict(table)
        out[key] = out[key] * trace if key in out else trace
        return out

    def with_straggler(self, node: int, start: float, end: float,
                       slowdown: float) -> "NetworkScenario":
        """Node ``node`` computes ``slowdown``x slower on [start, end)."""
        return dataclasses.replace(self, node_mult=self._compose(
            self.node_mult, node, _window(start, end, 1.0 / slowdown)))

    def with_outage(self, a: int, c: int, start: float, end: float,
                    both_directions: bool = True) -> "NetworkScenario":
        """Link (a, c) carries zero bytes on [start, end) — transfers in
        flight stall and resume when the outage lifts."""
        lm = self._compose(self.link_mult, (a, c), _window(start, end, 0.0))
        s = dataclasses.replace(self, link_mult=lm)
        if both_directions:
            lm = s._compose(s.link_mult, (c, a), _window(start, end, 0.0))
            s = dataclasses.replace(s, link_mult=lm)
        return s

    def with_flapping(self, a: int, c: int, start: float, end: float, *,
                      period: float, duty: float = 0.5, low: float = 0.0,
                      both_directions: bool = True) -> "NetworkScenario":
        """Link (a, c) flaps as a square wave on [start, end): up at full
        rate for ``duty * period``, down at ``low`` x for the rest of each
        period.  ``low=0`` models hard drops (transfers stall and resume)."""
        wave = square_wave(start, end, period=period, duty=duty, low=low)
        lm = self._compose(self.link_mult, (a, c), wave)
        s = dataclasses.replace(self, link_mult=lm)
        if both_directions:
            lm = s._compose(s.link_mult, (c, a), wave)
            s = dataclasses.replace(s, link_mult=lm)
        return s

    def with_mem_pressure(self, node: int, start: float, end: float,
                          factor: float) -> "NetworkScenario":
        """Node ``node``'s available memory shrinks to ``factor`` x on
        [start, end) — a co-tenant claiming part of the device.  No timing
        effect (the engines ignore it); consumed by tail-sized admission
        (``core.cost_model.DegradedTail``) and :func:`sampled_network`."""
        if factor < 0.0:
            raise ValueError("memory factor must be >= 0")
        return dataclasses.replace(self, mem_mult=self._compose(
            self.mem_mult, node, _window(start, end, factor)))

    def with_region_degradation(self, nodes, links, start: float, end: float,
                                factor: float) -> "NetworkScenario":
        """Correlated regional degradation: every node in ``nodes`` and every
        directed link in ``links`` is scaled by the SAME ``factor`` on
        [start, end) — the one-shared-cause failure mode (congested backhaul,
        regional power event) that independent per-resource noise never
        produces.  Callers pass the affected link pairs explicitly (e.g. all
        links touching the region's nodes) so the scenario stays
        network-agnostic."""
        if factor <= 0.0:
            raise ValueError("degradation factor must be positive "
                             "(use with_outage for hard zero-capacity)")
        win = _window(start, end, factor)
        nm = dict(self.node_mult)
        for n in nodes:
            nm[n] = nm[n] * win if n in nm else win
        lm = dict(self.link_mult)
        for key in links:
            a, c = key
            lm[(a, c)] = lm[(a, c)] * win if (a, c) in lm else win
        return dataclasses.replace(self, node_mult=nm, link_mult=lm)

    def drains(self) -> bool:
        """True when every multiplier trace ends at positive capacity — no
        resource can stall forever, so makespans stay finite (the fuzzer's
        standing guarantee; see ``repro.sim.fuzz``).  ``mem_mult`` is not
        part of the predicate: memory pressure resizes admission windows
        (a count, not a runtime resource), so it cannot wedge a run."""
        return all(tr.drains() for tr in self.node_mult.values()) and \
            all(tr.drains() for tr in self.link_mult.values())

    def with_replan(self, time: float, event) -> "NetworkScenario":
        trig = ReplanTrigger(time, event)
        return dataclasses.replace(
            self, replan_triggers=tuple(sorted(
                self.replan_triggers + (trig,), key=lambda t: t.time)))


def _scenario_from_sampler(net: EdgeNetwork, sampler) -> NetworkScenario:
    node_mult = {i: sampler() for i in range(len(net.nodes))}
    link_mult = {}
    for a in range(len(net.nodes)):
        for c in range(len(net.nodes)):
            if a != c and net.rate[a, c] > 0:
                link_mult[(a, c)] = sampler()
    return NetworkScenario(node_mult=node_mult, link_mult=link_mult)


def piecewise_cv_scenario(net: EdgeNetwork, cv: float,
                          rng: np.random.Generator, *, dt: float,
                          horizon: float, floor: float = 0.05
                          ) -> NetworkScenario:
    """Every node/link gets an independent i.i.d.-resampled piecewise trace
    with coefficient-of-variation ``cv`` (Fig. 6's noise, unfolded in time)."""
    return _scenario_from_sampler(
        net, lambda: iid_piecewise(rng, cv, dt=dt, horizon=horizon,
                                   floor=floor))


def gauss_markov_scenario(net: EdgeNetwork, cv: float,
                          rng: np.random.Generator, *, dt: float,
                          horizon: float, corr: float = 0.9,
                          floor: float = 0.05) -> NetworkScenario:
    """Every node/link gets an independent Gauss-Markov (AR(1)) trace."""
    return _scenario_from_sampler(
        net, lambda: gauss_markov(rng, cv, dt=dt, horizon=horizon, corr=corr,
                                  floor=floor))


def sampled_network(net: EdgeNetwork, scenario: NetworkScenario,
                    t: float) -> EdgeNetwork:
    """The network's *instantaneous measured capacities* at time ``t`` under
    ``scenario`` — what a monitoring tick would report: node ``f`` and link
    rates scaled by each multiplier trace's value at ``t``.  Feed to an
    ``repro.ft.Resync`` event so a cadence-driven coordinator replans
    against the measurement snapshot."""
    nodes = list(net.nodes)
    for i, mult in scenario.node_mult.items():
        nodes[i] = dataclasses.replace(nodes[i],
                                       f=nodes[i].f * mult.value_at(t))
    for i, mult in scenario.mem_mult.items():
        nodes[i] = dataclasses.replace(nodes[i],
                                       mem=nodes[i].mem * mult.value_at(t))
    rate = net.rate.copy()
    for (a, c), mult in scenario.link_mult.items():
        rate[a, c] = rate[a, c] * mult.value_at(t)
    return dataclasses.replace(net, nodes=nodes, rate=rate)


def periodic_resync_triggers(net: EdgeNetwork, scenario: NetworkScenario, *,
                             cadence: float, horizon: float,
                             start: float | None = None) -> tuple:
    """Measurement ticks every ``cadence`` seconds up to ``horizon``: each
    trigger carries a ``Resync`` with the scenario's sampled capacities at
    that instant.  This is the ROADMAP's replanning-cadence experiment in
    trigger form — pair with a ``Periodic``/``Hysteresis`` replan policy to
    sweep how often the coordinator should chase Gauss-Markov drift (see
    ``benchmarks/bench_ft_policy.py``)."""
    from repro.ft.coordinator import Resync  # local: avoid hard dep
    if cadence <= 0:
        raise ValueError("cadence must be > 0")
    t = cadence if start is None else start
    out = []
    while t < horizon:
        out.append(ReplanTrigger(t, Resync(sampled_network(net, scenario, t))))
        t += cadence
    return tuple(out)
