"""Cross-validation of the simulator against the analytical latency model.

On a deterministic network whose plan places every submodel on a distinct
node, each resource is visited exactly once per micro-batch, so the FIFO
pipeline is a permutation flow shop with identical jobs and the analytical
Eqs. (12)-(14) are *exact*: simulated T_f, T_i and L_t must agree with
``core.latency.fill_latency`` / ``pipeline_interval`` / ``total_latency`` to
numerical tolerance.  ``cross_validate_many`` runs this over randomized
(profile, network, plan) triples — the standing consistency test that keeps
the closed-form model and the event engine honest against each other —
and ``compare_engines`` holds the heap engine and the vectorized engine to
the same timelines under every admission policy.

>>> import numpy as np
>>> from repro.core import uniform_profile, EdgeNetwork, Node, SplitSolution
>>> prof = uniform_profile(4, fp=1.0, bp=1.0, act=1.0)
>>> nodes = [Node("c", f=1.0, t0=0.0, t1=0.0, b_th=0, is_client=True),
...          Node("s", f=1.0, t0=0.0, t1=0.0, b_th=0)]
>>> net = EdgeNetwork(nodes=nodes, rate=np.array([[0., 10.], [10., 0.]]),
...                   num_clients=1)
>>> sol = SplitSolution(cuts=(2, 4), placement=(0, 1))
>>> cross_validate(prof, net, sol, b=1, B=3).ok
True
>>> compare_engines(prof, net, sol, 1, 3, policy="1f1b") < 1e-12
True
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import latency as L
from repro.core.latency import SplitSolution, validate_solution
from repro.core.network import EdgeNetwork, make_edge_network
from repro.core.profiles import ModelProfile, random_profile
from .engine import simulate_plan

#: topologies cycled through by ``random_instance``
TOPOLOGIES = ("mesh", "line", "star", "tree")


@dataclasses.dataclass(frozen=True)
class CrossCheck:
    """Simulated vs analytical latencies for one (profile, net, plan, b, B)."""
    T_f_sim: float
    T_f_ana: float
    T_i_sim: float
    T_i_ana: float
    L_t_sim: float
    L_t_ana: float
    b: int
    B: int
    cuts: tuple
    placement: tuple
    rtol: float

    def _rel(self, a: float, c: float) -> float:
        return abs(a - c) / max(abs(c), 1e-30)

    @property
    def max_rel_err(self) -> float:
        errs = [self._rel(self.T_f_sim, self.T_f_ana),
                self._rel(self.L_t_sim, self.L_t_ana)]
        if self.B > self.b:          # T_i only observable with >= 2 slots
            errs.append(self._rel(self.T_i_sim, self.T_i_ana))
        return max(errs)

    @property
    def ok(self) -> bool:
        return bool(np.isfinite(self.L_t_ana) and self.max_rel_err <= self.rtol)


def random_chain_solution(rng: np.random.Generator, profile: ModelProfile,
                          net: EdgeNetwork,
                          max_stages: int | None = None) -> SplitSolution:
    """A random feasible solution with *distinct* placements (no co-located
    submodels — the regime where Eq. (14) is exact; see module docstring)."""
    I = profile.num_layers
    cap = min(max_stages or I, net.num_servers + 1, I)
    K = int(rng.integers(2, cap + 1)) if cap >= 2 else 1
    if K == 1:
        sol = SplitSolution((I,), (0,))
    else:
        inner = np.sort(rng.choice(np.arange(1, I), size=K - 1, replace=False))
        cuts = tuple(int(c) for c in inner) + (I,)
        servers = rng.choice(np.arange(1, len(net.nodes)), size=K - 1,
                             replace=False)
        sol = SplitSolution(cuts, (0,) + tuple(int(s) for s in servers))
    validate_solution(sol, profile, net)
    return sol


def random_instance(seed: int):
    """One randomized (profile, network, solution, b, B) validation triple."""
    rng = np.random.default_rng(seed)
    num_layers = int(rng.integers(4, 12))
    num_servers = int(rng.integers(2, 6))
    topology = TOPOLOGIES[seed % len(TOPOLOGIES)]
    profile = random_profile(rng, num_layers)
    net = make_edge_network(num_servers=num_servers,
                            num_clients=int(rng.integers(1, 5)),
                            topology=topology, seed=seed)
    sol = random_chain_solution(rng, profile, net)
    b = int(rng.integers(1, 17))
    B = b * int(rng.integers(2, 9)) + int(rng.integers(0, b))
    return profile, net, sol, b, B


def cross_validate(profile: ModelProfile, net: EdgeNetwork,
                   sol: SplitSolution, b: int, B: int, *,
                   rtol: float = 1e-6) -> CrossCheck:
    """Simulate and compare against Eqs. (12)-(14) for one instance."""
    rep = simulate_plan(profile, net, sol, b, B=B)
    return CrossCheck(
        T_f_sim=rep.T_f,
        T_f_ana=L.fill_latency(profile, net, sol, b),
        T_i_sim=rep.T_i,
        T_i_ana=L.pipeline_interval(profile, net, sol, b),
        L_t_sim=rep.L_t,
        L_t_ana=L.total_latency(profile, net, sol, b, B),
        b=b, B=B, cuts=sol.cuts, placement=sol.placement, rtol=rtol)


def cross_validate_many(trials: int = 20, *, seed: int = 0,
                        rtol: float = 1e-6) -> list:
    """The standing cross-check over ``trials`` randomized triples."""
    return [cross_validate(*random_instance(seed * 1000 + i), rtol=rtol)
            for i in range(trials)]


def compare_engines(profile: ModelProfile, net: EdgeNetwork,
                    sol: SplitSolution, b: int, num_microbatches: int, *,
                    policy="fifo", scenario=None) -> float:
    """Max relative gap between heap-engine and vectorized-engine micro-batch
    completion times for one instance — the standing engine-equivalence
    check (must be ulp-level wherever the vectorized engine is eligible:
    constant *and* piecewise-constant traces via ``scenario``, distinct
    *and* reentrant placements, every admission policy)."""
    ev = simulate_plan(profile, net, sol, b,
                       num_microbatches=num_microbatches, policy=policy,
                       scenario=scenario, engine="event")
    vec = simulate_plan(profile, net, sol, b,
                        num_microbatches=num_microbatches, policy=policy,
                        scenario=scenario, engine="vectorized")
    denom = np.maximum(np.abs(ev.mb_complete), 1e-30)
    return float(np.max(np.abs(ev.mb_complete - vec.mb_complete) / denom))


def compare_utilization(profile: ModelProfile, net: EdgeNetwork,
                        sol: SplitSolution, b: int, num_microbatches: int, *,
                        policy="fifo", scenario=None) -> float:
    """Max absolute gap (normalized by the run horizon) between the two
    engines' ``UtilizationReport`` decompositions for one instance — the
    standing idle-accounting parity check.

    The event engine's report is reconstructed from eager ``TraceRecord``s
    and the vectorized engine's directly from the dense SoA ``Timeline``,
    so this exercises two genuinely independent interval extractions of
    what must be the same schedule: per-resource service, fill, bubble,
    drain (and blocked, when a ``scenario`` provides traces) are compared
    field by field.
    """
    traces = None
    if scenario is not None:
        from repro.obs import resource_traces
        from .engine import build_visit_table
        table = build_visit_table(profile, net, sol, b)
        traces = resource_traces(net, scenario, set(table.resources))
    ev = simulate_plan(profile, net, sol, b,
                       num_microbatches=num_microbatches, policy=policy,
                       scenario=scenario, engine="event")
    vec = simulate_plan(profile, net, sol, b,
                        num_microbatches=num_microbatches, policy=policy,
                        scenario=scenario, engine="vectorized")
    ue = ev.utilization(traces=traces)
    uv = vec.utilization(traces=traces)
    if set(ue.resources) != set(uv.resources):
        raise AssertionError(
            f"resource sets differ: {set(ue.resources) ^ set(uv.resources)}")
    scale = max(ue.span, uv.span, 1e-30)
    worst = abs(ue.span - uv.span) / scale
    for res, a in ue.resources.items():
        c = uv.resources[res]
        for field in ("busy", "blocked", "fill", "bubble", "drain",
                      "first_start", "last_end"):
            worst = max(worst,
                        abs(getattr(a, field) - getattr(c, field)) / scale)
        if a.num_tasks != c.num_tasks:
            raise AssertionError(
                f"{res}: task counts differ {a.num_tasks} != {c.num_tasks}")
    return float(worst)


def random_reentrant_solution(rng: np.random.Generator,
                              profile: ModelProfile,
                              net: EdgeNetwork) -> SplitSolution:
    """A random feasible solution whose placements may repeat (co-located
    submodels) — the reentrant regime the merged-scan fixpoint covers."""
    I = profile.num_layers
    cap = min(I, 6)
    K = int(rng.integers(2, cap + 1))
    inner = np.sort(rng.choice(np.arange(1, I), size=K - 1, replace=False))
    cuts = tuple(int(c) for c in inner) + (I,)
    servers = rng.integers(1, len(net.nodes), size=K - 1)
    sol = SplitSolution(cuts, (0,) + tuple(int(s) for s in servers))
    validate_solution(sol, profile, net)
    return sol
