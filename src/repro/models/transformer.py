"""Decoder-only transformer LM (dense or uniform-MoE FFN) — pure JAX.

Covers qwen3-0.6b / llama3-8b / qwen1.5-4b / command-r-35b (dense),
qwen3-moe-235b / granite-moe-3b (MoE every layer), and the internvl2-1b LM
backbone (patch embeddings prepended by the vlm wrapper).

Layers are stacked on a leading axis and executed with ``lax.scan`` so the
lowered HLO is depth-independent; each layer body is optionally ``remat``'d.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from .common import (ArchConfig, apply_rope, chunked_attention, cross_entropy,
                     decode_attention, dense_init, embed_init, full_attention,
                     remat_wrap, rms_norm)
from . import moe as moe_lib


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init_layer_params(key, cfg: ArchConfig):
    d, hd = cfg.d_model, cfg.head_dim
    H, KV, ff = cfg.n_heads, cfg.n_kv, cfg.d_ff
    ks = jax.random.split(key, 12)
    p = {
        "ln1": jnp.ones((d,), cfg.param_dtype),
        "ln2": jnp.ones((d,), cfg.param_dtype),
        "wq": dense_init(ks[0], (d, H * hd), cfg.param_dtype),
        "wk": dense_init(ks[1], (d, KV * hd), cfg.param_dtype),
        "wv": dense_init(ks[2], (d, KV * hd), cfg.param_dtype),
        "wo": dense_init(ks[3], (H * hd, d), cfg.param_dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), cfg.param_dtype)
        p["bk"] = jnp.zeros((KV * hd,), cfg.param_dtype)
        p["bv"] = jnp.zeros((KV * hd,), cfg.param_dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), cfg.param_dtype)
        p["k_norm"] = jnp.ones((hd,), cfg.param_dtype)
    if cfg.moe_experts > 0:
        p["moe"] = moe_lib.init_moe_params(ks[4], cfg)
    elif cfg.ffn_mult == 3:
        p["w_gate"] = dense_init(ks[5], (d, ff), cfg.param_dtype)
        p["w_up"] = dense_init(ks[6], (d, ff), cfg.param_dtype)
        p["w_down"] = dense_init(ks[7], (ff, d), cfg.param_dtype)
    else:
        p["w_up"] = dense_init(ks[6], (d, ff), cfg.param_dtype)
        p["b_up"] = jnp.zeros((ff,), cfg.param_dtype)
        p["w_down"] = dense_init(ks[7], (ff, d), cfg.param_dtype)
        p["b_down"] = jnp.zeros((d,), cfg.param_dtype)
    return p


def init_params(rng, cfg: ArchConfig):
    k_emb, k_layers, k_head = jax.random.split(rng, 3)
    layer_keys = jax.random.split(k_layers, cfg.num_layers)
    layers = jax.vmap(lambda k: init_layer_params(k, cfg))(layer_keys)
    params = {
        "embed": embed_init(k_emb, (cfg.vocab, cfg.d_model), cfg.param_dtype),
        "layers": layers,
        "final_norm": jnp.ones((cfg.d_model,), cfg.param_dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, (cfg.d_model, cfg.vocab),
                                       cfg.param_dtype)
    return params


# ---------------------------------------------------------------------------
# One block
# ---------------------------------------------------------------------------

def _project_qkv(p, x, cfg: ArchConfig):
    B, S, _ = x.shape
    hd = cfg.head_dim
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, S, cfg.n_kv, hd)
    v = v.reshape(B, S, cfg.n_kv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _ffn(p, x, cfg: ArchConfig):
    if cfg.moe_experts > 0:
        return moe_lib.moe_ffn(p["moe"], x, cfg)
    if cfg.ffn_mult == 3:
        h = jax.nn.silu(x @ p["w_gate"].astype(x.dtype)) * \
            (x @ p["w_up"].astype(x.dtype))
        return h @ p["w_down"].astype(x.dtype)
    h = jax.nn.gelu(x @ p["w_up"].astype(x.dtype) + p["b_up"].astype(x.dtype),
                    approximate=True)
    return h @ p["w_down"].astype(x.dtype) + p["b_down"].astype(x.dtype)


def block_fwd(p, x, cfg: ArchConfig, *, positions, mode: str = "train",
              cache=None, pos=None):
    """mode: 'train'/'prefill' (full sequence) or 'decode' (1 token).

    Returns (y, new_cache_kv) — new_cache_kv is (k, v) to store when
    building or updating a cache, else None placeholders.
    """
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = _project_qkv(p, h, cfg)
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    if mode == "decode":
        k_cache, v_cache = cache
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            k_cache, k.astype(k_cache.dtype), pos, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            v_cache, v.astype(v_cache.dtype), pos, axis=1)
        attn = decode_attention(q, k_cache, v_cache, pos)
        new_cache = (k_cache, v_cache)
    else:
        g = cfg.q_per_kv
        if g > 1:
            kf = jnp.repeat(k, g, axis=2)
            vf = jnp.repeat(v, g, axis=2)
        else:
            kf, vf = k, v
        S = x.shape[1]
        if S > cfg.attn_chunk:
            attn = chunked_attention(q, kf, vf, causal=True,
                                     window=cfg.sliding_window,
                                     chunk=cfg.attn_chunk)
        else:
            attn = full_attention(q, kf, vf, causal=True,
                                  window=cfg.sliding_window)
        new_cache = (k, v)
    B, S = x.shape[:2]
    attn = attn.reshape(B, S, cfg.n_heads * cfg.head_dim)
    x = x + attn @ p["wo"].astype(x.dtype)
    if cfg.seq_parallel_residual and mode != "decode":
        from jax.sharding import PartitionSpec as P
        from .common import maybe_constrain
        x = maybe_constrain(x, P(("pod", "data"), "model", None))
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + _ffn(p, h, cfg)
    if cfg.seq_parallel_residual and mode != "decode":
        x = maybe_constrain(x, P(("pod", "data"), "model", None))
    return x, new_cache


# ---------------------------------------------------------------------------
# Full-model passes
# ---------------------------------------------------------------------------

def _embed(params, tokens, cfg, extra_embeds=None):
    from jax.sharding import PartitionSpec as P
    from .common import maybe_constrain
    x = params["embed"].astype(cfg.compute_dtype)[tokens]
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(cfg.compute_dtype), x],
                            axis=1)
    # keep the residual stream batch-sharded after the vocab-sharded gather
    return maybe_constrain(x, P(("pod", "data"), None, None))


def _unembed(params, x, cfg):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else
            params["lm_head"]).astype(x.dtype)
    return x @ head


def forward_hidden(params, tokens, cfg: ArchConfig, extra_embeds=None):
    """Token ids -> final hidden states, scanning stacked layers."""
    x = _embed(params, tokens, cfg, extra_embeds)
    S = x.shape[1]
    positions = jnp.arange(S)

    body = remat_wrap(
        lambda x, pl: block_fwd(pl, x, cfg, positions=positions,
                                mode="train")[0],
        cfg.remat)

    def scan_body(x, pl):
        return body(x, pl), None

    x, _ = jax.lax.scan(scan_body, x, params["layers"])
    return x


def loss_fn(params, batch, cfg: ArchConfig):
    x = forward_hidden(params, batch["tokens"], cfg,
                       batch.get("patch_embeds"))
    P = 0 if "patch_embeds" not in batch else batch["patch_embeds"].shape[1]
    x = x[:, P:]
    logits = _unembed(params, x, cfg)
    return cross_entropy(logits, batch["labels"])


def make_cache(cfg: ArchConfig, batch: int, cache_len: int,
               dtype=None):
    dtype = dtype or cfg.compute_dtype
    shape = (cfg.num_layers, batch, cache_len, cfg.n_kv, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def prefill(params, tokens, cfg: ArchConfig, cache_len: int,
            extra_embeds=None):
    """Run the full prompt, build the KV cache, return last-position logits."""
    x = _embed(params, tokens, cfg, extra_embeds)
    B, S = x.shape[:2]
    positions = jnp.arange(S)

    def body(x, pl):
        y, (k, v) = block_fwd(pl, x, cfg, positions=positions, mode="prefill")
        pad = cache_len - S
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(cfg.compute_dtype)
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(cfg.compute_dtype)
        return y, (k, v)

    body = remat_wrap(body, cfg.remat) if cfg.remat != "none" else body
    x, (ks, vs) = jax.lax.scan(lambda c, pl: body(c, pl), x, params["layers"])
    logits = _unembed(params, x[:, -1:], cfg)
    return logits, {"k": ks, "v": vs}


def decode_step(params, cache, token, pos, cfg: ArchConfig):
    """One token in, one token's logits out; cache updated in place.

    ``token``: (B, 1) int32; ``pos``: scalar int32 — current write position
    (the cache already holds ``pos`` valid entries).
    """
    x = _embed(params, token, cfg)
    positions = pos + jnp.zeros((1,), jnp.int32)

    def scan_body(x, layer):
        pl, kc, vc = layer
        y, (k2, v2) = block_fwd(pl, x, cfg, positions=positions,
                                mode="decode", cache=(kc, vc), pos=pos)
        return y, (k2, v2)

    x, (ks, vs) = jax.lax.scan(scan_body, x,
                               (params["layers"], cache["k"], cache["v"]))
    logits = _unembed(params, x, cfg)
    return logits, {"k": ks, "v": vs}
