"""Whisper-style encoder-decoder (audio backbone, conv frontend STUBBED).

Per the assignment, the modality frontend is a stub: ``input_specs`` feeds
precomputed frame embeddings (B, T_enc, d) where the two conv layers would
produce them.  The transformer backbone is faithful to Whisper: pre-LN
LayerNorm (with bias), GELU MLPs, learned positions in the decoder,
sinusoidal in the encoder, bidirectional encoder self-attention, and a
decoder with causal self-attention + cross-attention into the encoder.

Decode caches both the self-attention KV (updated per step) and the cross
KV (computed once from the encoder output).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .common import (ArchConfig, cross_entropy, decode_attention, dense_init,
                     embed_init, full_attention, layer_norm, remat_wrap)


MAX_TARGET_POSITIONS = 448


def _attn_params(key, cfg, prefix=""):
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    return {
        prefix + "wq": dense_init(ks[0], (d, d), cfg.param_dtype),
        prefix + "bq": jnp.zeros((d,), cfg.param_dtype),
        prefix + "wk": dense_init(ks[1], (d, d), cfg.param_dtype),
        prefix + "wv": dense_init(ks[2], (d, d), cfg.param_dtype),
        prefix + "bv": jnp.zeros((d,), cfg.param_dtype),
        prefix + "wo": dense_init(ks[3], (d, d), cfg.param_dtype),
        prefix + "bo": jnp.zeros((d,), cfg.param_dtype),
    }


def _mlp_params(key, cfg):
    d, ff = cfg.d_model, cfg.d_ff
    k1, k2 = jax.random.split(key)
    return {
        "w_up": dense_init(k1, (d, ff), cfg.param_dtype),
        "b_up": jnp.zeros((ff,), cfg.param_dtype),
        "w_down": dense_init(k2, (ff, d), cfg.param_dtype),
        "b_down": jnp.zeros((d,), cfg.param_dtype),
    }


def _ln_params(cfg, n=1):
    d = cfg.d_model
    return {"scale": jnp.ones((d,), cfg.param_dtype),
            "bias": jnp.zeros((d,), cfg.param_dtype)}


def init_enc_layer(key, cfg):
    k1, k2 = jax.random.split(key)
    return {"ln1": _ln_params(cfg), "ln2": _ln_params(cfg),
            **_attn_params(k1, cfg), **_mlp_params(k2, cfg)}


def init_dec_layer(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": _ln_params(cfg), "ln_x": _ln_params(cfg),
            "ln2": _ln_params(cfg),
            **_attn_params(k1, cfg),
            **_attn_params(k2, cfg, prefix="x_"),
            **_mlp_params(k3, cfg)}


def init_params(rng, cfg: ArchConfig):
    ke, kd, kemb, kp = jax.random.split(rng, 4)
    enc_keys = jax.random.split(ke, cfg.encoder_layers)
    dec_keys = jax.random.split(kd, cfg.num_layers)
    return {
        "tok_embed": embed_init(kemb, (cfg.vocab, cfg.d_model),
                                cfg.param_dtype),
        "dec_pos": embed_init(kp, (MAX_TARGET_POSITIONS, cfg.d_model),
                              cfg.param_dtype),
        "enc_layers": jax.vmap(lambda k: init_enc_layer(k, cfg))(enc_keys),
        "dec_layers": jax.vmap(lambda k: init_dec_layer(k, cfg))(dec_keys),
        "enc_ln": _ln_params(cfg),
        "dec_ln": _ln_params(cfg),
    }


def _heads(x, cfg):
    B, S, d = x.shape
    return x.reshape(B, S, cfg.n_heads, cfg.head_dim)


def _mha(p, xq, xkv, cfg, *, causal, prefix=""):
    from .common import chunked_attention
    q = _heads(xq @ p[prefix + "wq"].astype(xq.dtype) +
               p[prefix + "bq"].astype(xq.dtype), cfg)
    k = _heads(xkv @ p[prefix + "wk"].astype(xq.dtype), cfg)
    v = _heads(xkv @ p[prefix + "wv"].astype(xq.dtype) +
               p[prefix + "bv"].astype(xq.dtype), cfg)
    if xq.shape[1] > cfg.attn_chunk:
        o = chunked_attention(q, k, v, causal=causal, chunk=cfg.attn_chunk)
    else:
        o = full_attention(q, k, v, causal=causal)
    B, S = xq.shape[:2]
    o = o.reshape(B, S, cfg.d_model)
    return o @ p[prefix + "wo"].astype(xq.dtype) + \
        p[prefix + "bo"].astype(xq.dtype)


def _mlp(p, x, cfg):
    h = jax.nn.gelu(x @ p["w_up"].astype(x.dtype) + p["b_up"].astype(x.dtype),
                    approximate=True)
    return h @ p["w_down"].astype(x.dtype) + p["b_down"].astype(x.dtype)


def _ln(p, x, cfg):
    return layer_norm(x, p["scale"], p["bias"], 1e-5)


def sinusoids(length: int, channels: int):
    log_timescale = math.log(10000.0) / (channels // 2 - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(channels // 2))
    ang = jnp.arange(length)[:, None].astype(jnp.float32) * inv[None]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def encode(params, frames, cfg: ArchConfig):
    """frames: (B, T_enc, d) stubbed conv-frontend output."""
    x = frames.astype(cfg.compute_dtype)
    x = x + sinusoids(x.shape[1], cfg.d_model).astype(x.dtype)[None]

    def body(x, pl):
        h = _ln(pl["ln1"], x, cfg)
        x = x + _mha(pl, h, h, cfg, causal=False)
        h = _ln(pl["ln2"], x, cfg)
        return x + _mlp(pl, h, cfg)

    body = remat_wrap(body, cfg.remat)
    x, _ = jax.lax.scan(lambda c, pl: (body(c, pl), None), x,
                        params["enc_layers"])
    return _ln(params["enc_ln"], x, cfg)


def _dec_embed(params, tokens, cfg, pos0=0):
    x = params["tok_embed"].astype(cfg.compute_dtype)[tokens]
    S = tokens.shape[1]
    pos_ids = (pos0 + jnp.arange(S)) % MAX_TARGET_POSITIONS
    return x + params["dec_pos"].astype(x.dtype)[pos_ids][None]


def decode_train(params, tokens, enc_out, cfg: ArchConfig):
    x = _dec_embed(params, tokens, cfg)

    def body(x, pl):
        h = _ln(pl["ln1"], x, cfg)
        x = x + _mha(pl, h, h, cfg, causal=True)
        h = _ln(pl["ln_x"], x, cfg)
        x = x + _mha(pl, h, enc_out, cfg, causal=False, prefix="x_")
        h = _ln(pl["ln2"], x, cfg)
        return x + _mlp(pl, h, cfg)

    body = remat_wrap(body, cfg.remat)
    x, _ = jax.lax.scan(lambda c, pl: (body(c, pl), None), x,
                        params["dec_layers"])
    x = _ln(params["dec_ln"], x, cfg)
    return x @ params["tok_embed"].T.astype(x.dtype)   # tied head


def loss_fn(params, batch, cfg: ArchConfig):
    enc_out = encode(params, batch["frames"], cfg)
    logits = decode_train(params, batch["tokens"], enc_out, cfg)
    return cross_entropy(logits, batch["labels"])


def make_cache(cfg: ArchConfig, batch: int, cache_len: int):
    L = cfg.num_layers
    shape = (L, batch, cache_len, cfg.n_heads, cfg.head_dim)
    xshape = (L, batch, cfg.encoder_frames, cfg.n_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, cfg.compute_dtype),
            "v": jnp.zeros(shape, cfg.compute_dtype),
            "xk": jnp.zeros(xshape, cfg.compute_dtype),
            "xv": jnp.zeros(xshape, cfg.compute_dtype)}


def prefill(params, frames, tokens, cfg: ArchConfig, cache_len: int):
    """Encoder pass + decoder prompt pass; returns (logits, cache)."""
    enc_out = encode(params, frames, cfg)
    x = _dec_embed(params, tokens, cfg)
    B, S = tokens.shape

    def body(x, pl):
        from .common import chunked_attention
        h = _ln(pl["ln1"], x, cfg)
        q = _heads(h @ pl["wq"].astype(h.dtype) + pl["bq"].astype(h.dtype), cfg)
        k = _heads(h @ pl["wk"].astype(h.dtype), cfg)
        v = _heads(h @ pl["wv"].astype(h.dtype) + pl["bv"].astype(h.dtype), cfg)
        if S > cfg.attn_chunk:
            o = chunked_attention(q, k, v, causal=True, chunk=cfg.attn_chunk)
        else:
            o = full_attention(q, k, v, causal=True)
        o = o.reshape(B, S, cfg.d_model)
        x = x + o @ pl["wo"].astype(h.dtype) + pl["bo"].astype(h.dtype)
        h = _ln(pl["ln_x"], x, cfg)
        xk = _heads(enc_out @ pl["x_wk"].astype(h.dtype), cfg)
        xv = _heads(enc_out @ pl["x_wv"].astype(h.dtype) +
                    pl["x_bv"].astype(h.dtype), cfg)
        x = x + _mha(pl, h, enc_out, cfg, causal=False,
                     prefix="x_")
        h = _ln(pl["ln2"], x, cfg)
        x = x + _mlp(pl, h, cfg)
        pad = cache_len - S
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return x, (k.astype(cfg.compute_dtype), v.astype(cfg.compute_dtype),
                   xk.astype(cfg.compute_dtype), xv.astype(cfg.compute_dtype))

    x, (ks, vs, xks, xvs) = jax.lax.scan(body, x, params["dec_layers"])
    x = _ln(params["dec_ln"], x[:, -1:], cfg)
    logits = x @ params["tok_embed"].T.astype(x.dtype)
    return logits, {"k": ks, "v": vs, "xk": xks, "xv": xvs}


def decode_step(params, cache, token, pos, cfg: ArchConfig):
    x = _dec_embed(params, token, cfg, pos0=pos)
    B = token.shape[0]

    def body(x, layer):
        pl, kc, vc, xk, xv = layer
        h = _ln(pl["ln1"], x, cfg)
        q = _heads(h @ pl["wq"].astype(h.dtype) + pl["bq"].astype(h.dtype), cfg)
        k = _heads(h @ pl["wk"].astype(h.dtype), cfg)
        v = _heads(h @ pl["wv"].astype(h.dtype) + pl["bv"].astype(h.dtype), cfg)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), pos, 1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), pos, 1)
        o = decode_attention(q, kc, vc, pos).reshape(B, 1, cfg.d_model)
        x = x + o @ pl["wo"].astype(h.dtype) + pl["bo"].astype(h.dtype)
        h = _ln(pl["ln_x"], x, cfg)
        q = _heads(h @ pl["x_wq"].astype(h.dtype) +
                   pl["x_bq"].astype(h.dtype), cfg)
        o = decode_attention(q, xk, xv, xk.shape[1] - 1)
        o = o.reshape(B, 1, cfg.d_model)
        x = x + o @ pl["x_wo"].astype(h.dtype) + pl["x_bo"].astype(h.dtype)
        h = _ln(pl["ln2"], x, cfg)
        x = x + _mlp(pl, h, cfg)
        return x, (kc, vc)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]))
    x = _ln(params["dec_ln"], x, cfg)
    logits = x @ params["tok_embed"].T.astype(x.dtype)
    return logits, {"k": ks, "v": vs, "xk": cache["xk"], "xv": cache["xv"]}
