"""RWKV-6 "Finch" — attention-free LM with data-dependent decay.

Per layer: time-mix (the WKV linear-attention recurrence) + channel-mix.
The WKV recurrence per head (state S in R^{hd x hd}):

    S_t = diag(w_t) S_{t-1} + k_t  v_t^T
    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)

with per-channel decay w_t in (0,1) produced *from the input* via a LoRA
(the "data-dependent decay" that distinguishes RWKV6 from RWKV4/5).

Training uses the chunked-parallel formulation (never materializing all S_t):
within a chunk of length C, with L_t = cumsum(log w),

    y_t = (r_t . exp(L_{t-1})) S_0                       (cross-chunk)
        + sum_{tau<t} exp(L_{t-1}-L_tau) (r_t.k_tau) v_tau   (intra, C x C)
        + (r_t . u . k_t) v_t                            (current token)
    S_C = exp(L_C) S_0 + sum_tau exp(L_C - L_tau) k_tau v_tau^T

All exponents are differences of a non-increasing L — bounded <= 0 — so the
chunk math is overflow-safe.  ``kernels/rwkv6`` implements the same chunk
body as a Pallas TPU kernel; this jnp version is its oracle and the default
CPU path.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .common import ArchConfig, cross_entropy, dense_init, embed_init, rms_norm


LORA_RANK = 32


def num_heads(cfg: ArchConfig) -> int:
    return cfg.d_model // cfg.rwkv_head_dim


def init_layer_params(key, cfg: ArchConfig):
    d, ff = cfg.d_model, cfg.d_ff
    hd = cfg.rwkv_head_dim
    ks = jax.random.split(key, 16)
    mix = {f"mu_{n}": jnp.full((d,), 0.5, cfg.param_dtype)
           for n in ("w", "k", "v", "r", "g")}
    lora = {
        "w_lora_a": dense_init(ks[0], (d, LORA_RANK), cfg.param_dtype),
        "w_lora_b": dense_init(ks[1], (LORA_RANK, d), cfg.param_dtype),
        "w0": jnp.full((d,), -6.0, cfg.param_dtype),   # slow default decay
    }
    return {
        "ln1": jnp.ones((d,), cfg.param_dtype),
        "ln2": jnp.ones((d,), cfg.param_dtype),
        **mix, **lora,
        "wr": dense_init(ks[2], (d, d), cfg.param_dtype),
        "wk": dense_init(ks[3], (d, d), cfg.param_dtype),
        "wv": dense_init(ks[4], (d, d), cfg.param_dtype),
        "wg": dense_init(ks[5], (d, d), cfg.param_dtype),
        "wo": dense_init(ks[6], (d, d), cfg.param_dtype),
        "u": jnp.zeros((d,), cfg.param_dtype),         # per-channel bonus
        "gn_scale": jnp.ones((d,), cfg.param_dtype),
        # channel mix
        "mu_ck": jnp.full((d,), 0.5, cfg.param_dtype),
        "mu_cr": jnp.full((d,), 0.5, cfg.param_dtype),
        "ck": dense_init(ks[7], (d, ff), cfg.param_dtype),
        "cv": dense_init(ks[8], (ff, d), cfg.param_dtype),
        "cr": dense_init(ks[9], (d, d), cfg.param_dtype),
    }


def init_params(rng, cfg: ArchConfig):
    k_emb, k_layers, k_head = jax.random.split(rng, 3)
    layer_keys = jax.random.split(k_layers, cfg.num_layers)
    layers = jax.vmap(lambda k: init_layer_params(k, cfg))(layer_keys)
    return {
        "embed": embed_init(k_emb, (cfg.vocab, cfg.d_model), cfg.param_dtype),
        "layers": layers,
        "final_norm": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "lm_head": dense_init(k_head, (cfg.d_model, cfg.vocab),
                              cfg.param_dtype),
    }


def _token_shift(x, prev):
    """(B, S, d) -> previous-token tensor; ``prev``: (B, 1, d) carry."""
    return jnp.concatenate([prev.astype(x.dtype), x[:, :-1]], axis=1)


def _heads(x, hd):
    B, S, d = x.shape
    return x.reshape(B, S, d // hd, hd)


def wkv_chunked(r, k, v, logw, u, S0, chunk: int):
    """The chunked WKV recurrence.  All inputs (B, S, H, hd) except
    u (H, hd) and S0 (B, H, hd, hd).  Returns (y, S_final)."""
    B, S, H, hd = r.shape
    assert S % chunk == 0, (S, chunk)
    n = S // chunk
    resh = lambda x: x.reshape(B, n, chunk, H, hd).swapaxes(0, 1)
    r_c, k_c, v_c, lw_c = map(resh, (r, k, v, logw))

    def step(S_prev, inp):
        rc, kc, vc, lwc = (t.astype(jnp.float32) for t in inp)  # (B,C,H,hd)
        L = jnp.cumsum(lwc, axis=1)                      # L_t (inclusive)
        Lm1 = L - lwc                                    # L_{t-1}
        q = rc * jnp.exp(Lm1)                            # decayed queries
        kd = kc * jnp.exp(L[:, -1:,] - L)                # keys to chunk end
        # cross-chunk term: q @ S_prev
        y_cross = jnp.einsum("bchk,bhkv->bchv", q, S_prev)
        # intra-chunk: A[t,tau] = sum_k q[t] * k[tau] * exp(-L_tau), tau < t
        att = jnp.einsum("bchk,bThk->bhcT", q, kc * jnp.exp(-L))
        mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
        att = jnp.where(mask[None, None], att, 0.0)
        y_intra = jnp.einsum("bhcT,bThv->bchv", att, vc)
        # current token bonus
        y_diag = jnp.einsum("bchk,bchk->bch", rc, u[None, None] * kc)
        y_diag = y_diag[..., None] * vc
        y = y_cross + y_intra + y_diag
        # state update to chunk end (decay acts on the k-dim rows of S)
        S_new = jnp.exp(L[:, -1])[..., None] * S_prev
        S_new = S_new + jnp.einsum("bThk,bThv->bhkv", kd, vc)
        return S_new, y

    S_fin, ys = jax.lax.scan(step, S0.astype(jnp.float32),
                             (r_c, k_c, v_c, lw_c))
    y = ys.swapaxes(0, 1).reshape(B, S, H, hd)
    return y, S_fin


def time_mix(p, x, cfg: ArchConfig, *, shift_state=None, wkv_state=None):
    """Returns (y, (new_shift, new_wkv))."""
    B, S, d = x.shape
    hd = cfg.rwkv_head_dim
    H = d // hd
    prev = shift_state if shift_state is not None else \
        jnp.zeros((B, 1, d), x.dtype)
    xx = _token_shift(x, prev)

    def mixed(name):
        mu = p[f"mu_{name}"].astype(x.dtype)
        return x + (xx - x) * mu

    xw, xk, xv, xr, xg = (mixed(n) for n in ("w", "k", "v", "r", "g"))
    r = _heads(xr @ p["wr"].astype(x.dtype), hd)
    k = _heads(xk @ p["wk"].astype(x.dtype), hd)
    v = _heads(xv @ p["wv"].astype(x.dtype), hd)
    g = xg @ p["wg"].astype(x.dtype)

    # data-dependent decay (the RWKV6 LoRA): w in (0,1), logw <= 0
    lora = jnp.tanh(xw @ p["w_lora_a"].astype(x.dtype)) @ \
        p["w_lora_b"].astype(x.dtype)
    logw = -jnp.exp((p["w0"].astype(jnp.float32) +
                     lora.astype(jnp.float32)))
    logw = _heads(logw, hd)
    u = p["u"].astype(jnp.float32).reshape(H, hd)

    S0 = wkv_state if wkv_state is not None else \
        jnp.zeros((B, H, hd, hd), jnp.float32)
    if S == 1:
        # decode: one recurrence step
        rf, kf, vf = (t[:, 0].astype(jnp.float32) for t in (r, k, v))
        lw = logw[:, 0].astype(jnp.float32)
        y = jnp.einsum("bhk,bhkv->bhv", rf, S0) + \
            jnp.einsum("bhk,bhk->bh", rf, u[None] * kf)[..., None] * vf
        S_new = jnp.exp(lw)[..., None] * S0 + \
            jnp.einsum("bhk,bhv->bhkv", kf, vf)
        y = y[:, None]
    else:
        chunk = min(cfg.scan_chunk, S)
        while S % chunk != 0:
            chunk //= 2
        if cfg.use_pallas:
            from repro.kernels.rwkv6.ops import wkv6 as wkv_kernel
            y, S_new = wkv_kernel(r, k, v, logw, u, S0, chunk=max(chunk, 1))
        else:
            y, S_new = wkv_chunked(r, k, v, logw, u, S0, chunk=max(chunk, 1))

    y = y.reshape(B, S, d)
    # per-head group norm
    y = y.reshape(B, S, H, hd)
    y = (y - y.mean(-1, keepdims=True)) * jax.lax.rsqrt(
        y.var(-1, keepdims=True) + 64e-5)
    y = y.reshape(B, S, d) * p["gn_scale"].astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(g)
    out = y @ p["wo"].astype(x.dtype)
    return out, (x[:, -1:], S_new)


def channel_mix(p, x, cfg: ArchConfig, *, shift_state=None):
    B, S, d = x.shape
    prev = shift_state if shift_state is not None else \
        jnp.zeros((B, 1, d), x.dtype)
    xx = _token_shift(x, prev)
    xk = x + (xx - x) * p["mu_ck"].astype(x.dtype)
    xr = x + (xx - x) * p["mu_cr"].astype(x.dtype)
    kk = jnp.square(jax.nn.relu(xk @ p["ck"].astype(x.dtype)))
    out = jax.nn.sigmoid(xr @ p["cr"].astype(x.dtype)) * \
        (kk @ p["cv"].astype(x.dtype))
    return out, x[:, -1:]


def block_fwd(p, x, cfg: ArchConfig, *, state=None):
    """state: (shift_tm, wkv, shift_cm) or None (train)."""
    s_tm = s_wkv = s_cm = None
    if state is not None:
        s_tm, s_wkv, s_cm = state
    h, (new_tm, new_wkv) = time_mix(p, rms_norm(x, p["ln1"], cfg.norm_eps),
                                    cfg, shift_state=s_tm, wkv_state=s_wkv)
    x = x + h
    h, new_cm = channel_mix(p, rms_norm(x, p["ln2"], cfg.norm_eps), cfg,
                            shift_state=s_cm)
    x = x + h
    return x, (new_tm, new_wkv, new_cm)


def forward_hidden(params, tokens, cfg: ArchConfig):
    from .common import remat_wrap
    x = params["embed"].astype(cfg.compute_dtype)[tokens]
    body = remat_wrap(lambda x, pl: block_fwd(pl, x, cfg)[0], cfg.remat)
    x, _ = jax.lax.scan(lambda c, pl: (body(c, pl), None), x,
                        params["layers"])
    return x


def loss_fn(params, batch, cfg: ArchConfig):
    x = forward_hidden(params, batch["tokens"], cfg)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["lm_head"].astype(x.dtype)
    return cross_entropy(logits, batch["labels"])


def init_state(cfg: ArchConfig, batch: int):
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    H = d // hd
    L = cfg.num_layers
    return {
        "shift_tm": jnp.zeros((L, batch, 1, d), cfg.compute_dtype),
        "wkv": jnp.zeros((L, batch, H, hd, hd), jnp.float32),
        "shift_cm": jnp.zeros((L, batch, 1, d), cfg.compute_dtype),
    }


def prefill(params, tokens, cfg: ArchConfig, cache_len: int = 0):
    """Returns (last logits, state).  cache_len unused (state is O(1))."""
    x = params["embed"].astype(cfg.compute_dtype)[tokens]

    def scan_body(c, pl):
        y, st = block_fwd(pl, c, cfg, state=None)
        return y, st

    x, states = jax.lax.scan(scan_body, x, params["layers"])
    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = x @ params["lm_head"].astype(x.dtype)
    return logits, {"shift_tm": states[0], "wkv": states[1],
                    "shift_cm": states[2]}


def decode_step(params, state, token, pos, cfg: ArchConfig):
    x = params["embed"].astype(cfg.compute_dtype)[token]

    def scan_body(c, layer):
        pl, s_tm, s_wkv, s_cm = layer
        y, st = block_fwd(pl, c, cfg, state=(s_tm, s_wkv, s_cm))
        return y, st

    x, states = jax.lax.scan(
        scan_body, x,
        (params["layers"], state["shift_tm"], state["wkv"],
         state["shift_cm"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["lm_head"].astype(x.dtype)
    return logits, {"shift_tm": states[0], "wkv": states[1],
                    "shift_cm": states[2]}
