"""Mixture-of-Experts FFN with per-row sort-based capacity dispatch.

TPU/GSPMD-friendly routing: top-k assignment, sorting, and capacity
dropping all happen *per batch row* (vmap over the batch axis, which is
sharded over "data") — so routing never induces a global cross-device
sort.  The dense (B, E, C, d) dispatch buffer is then sharding-constrained
to expert-parallel layout (E on "model") when E divides the axis, which
makes XLA lower the dispatch as the canonical token all-to-all; otherwise
(e.g. granite's 40 experts on a 16-wide axis) experts stay replicated over
"model" and the per-expert FFN hidden dim is sharded instead (tensor
parallelism inside each expert).

FLOP accounting matches 6*N_active*D: expert matmuls cost ~ k*N*d*ff
(+ router N*d*E); capacity overflow tokens are dropped (residual keeps
them alive) — standard capacity-factor semantics.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import ArchConfig, dense_init


def init_moe_params(key, cfg: ArchConfig):
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.moe_experts
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, E), cfg.param_dtype),
        "w_gate": dense_init(ks[1], (E, d, ff), cfg.param_dtype, in_axis=-2),
        "w_up": dense_init(ks[2], (E, d, ff), cfg.param_dtype, in_axis=-2),
        "w_down": dense_init(ks[3], (E, ff, d), cfg.param_dtype, in_axis=-2),
    }


def expert_capacity(tokens_per_row: int, cfg: ArchConfig) -> int:
    c = math.ceil(cfg.moe_top_k * tokens_per_row / cfg.moe_experts
                  * cfg.capacity_factor)
    return max(4, -(-c // 4) * 4)


# sharding helper shared with the executor: drops axis entries that are
# absent from the mesh or don't divide the dim (e.g. granite's 40 experts on
# a 16-wide model axis -> per-expert hidden dim carries the parallelism).
from .common import maybe_constrain as _maybe_constrain


def _experts_shardable(E: int) -> bool:
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
            return True
        return E % mesh.shape["model"] == 0
    except Exception:
        return True


def _route_row(x_row, logits_row, C: int, E: int, K: int):
    """Per-row dispatch: x_row (S, d), logits_row (S, E) ->
    (buf (E, C, d), combine info).

    Combine info is *slot-major*: tok_slot/w_slot are (E, C) arrays giving
    each capacity slot its source token (S = empty sentinel) and gate
    weight — so the combine can scatter per expert SHARD and psum token-
    sized partials, instead of gathering the whole (E*C, d) buffer across
    the expert axis (8 GiB/layer measured on qwen3-moe prefill)."""
    S, d = x_row.shape
    probs = jax.nn.softmax(logits_row, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)            # (S, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    flat_e = gate_idx.reshape(-1)                            # (S*K,)
    flat_t = jnp.repeat(jnp.arange(S), K)
    flat_w = gate_vals.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    first = jnp.searchsorted(se, jnp.arange(E), side="left")
    pos = jnp.arange(S * K) - first[se]
    keep = pos < C
    slot = jnp.where(keep, se * C + pos, E * C)              # E*C = trash

    buf = jnp.zeros((E * C + 1, d), x_row.dtype).at[slot].set(x_row[st])
    tok_slot = jnp.full((E * C + 1,), S, jnp.int32).at[slot].set(
        st.astype(jnp.int32))
    w_slot = jnp.zeros((E * C + 1,), jnp.float32).at[slot].set(
        sw.astype(jnp.float32))
    return (buf[:-1].reshape(E, C, d),
            (tok_slot[:-1].reshape(E, C), w_slot[:-1].reshape(E, C),
             keep, slot, st, sw))


def _combine_row_scatter(out, info, S: int, d: int):
    """out (E, C, d) [expert-sharded] -> y (S, d).

    Scatter per expert into (S+1, d) partials, then sum over E — under
    GSPMD the e-axis sum lowers as a token-sized psum (the inverse
    all-to-all), never an all-gather of the capacity buffer (−16%
    collective bytes on qwen3-moe train_4k).  The (E_loc, S+1, d) partials
    scale with S, so this path is for short sequences; the gather path
    covers long prefill (§Perf iteration B3)."""
    tok_slot, w_slot = info[0], info[1]                      # (E, C)
    weighted = out * w_slot[..., None].astype(out.dtype)

    def per_expert(o_e, t_e):
        return jnp.zeros((S + 1, d), out.dtype).at[t_e].add(o_e)

    partials = jax.vmap(per_expert)(weighted, tok_slot)      # (E, S+1, d)
    return partials.sum(axis=0)[:S]


def _combine_row_gather(out_flat, info, S: int, d: int):
    """Pair-indexed gather combine: O(S*K) memory regardless of S."""
    keep, slot, st, sw = info[2], info[3], info[4], info[5]
    gathered = jnp.where(
        keep[:, None], out_flat[jnp.minimum(slot, out_flat.shape[0] - 1)],
        jnp.zeros((1, d), out_flat.dtype))
    contrib = gathered * sw[:, None].astype(out_flat.dtype)
    return jnp.zeros((S, d), out_flat.dtype).at[st].add(contrib)


def moe_ffn(p, x, cfg: ArchConfig):
    """x: (B, S, d) -> (B, S, d)."""
    B, S, d = x.shape
    E, K = cfg.moe_experts, cfg.moe_top_k
    C = expert_capacity(S, cfg)

    logits = (x @ p["router"].astype(x.dtype)).astype(jnp.float32)
    buf, info = jax.vmap(
        lambda xr, lr: _route_row(xr, lr, C, E, K))(x, logits)
    # Pin the dispatch-buffer layout.  Leaving the batch dim unspecified
    # lets GSPMD pick a contraction-sharded einsum that ALL-GATHERS the
    # whole (B, E*C, d) buffer (60 GiB/device on granite prefill_32k —
    # EXPERIMENTS.md §Perf iteration 0).  Expert-parallel when E divides
    # the model axis (-> token all-to-all), else batch-only with the
    # per-expert hidden dim carrying "model".
    bd = ("pod", "data")
    e_par = _experts_shardable(E)
    buf = _maybe_constrain(
        buf, P(bd, "model", None, None) if e_par else P(bd, None, None, None))

    n = max(1, cfg.moe_ff_chunks)
    if n > 1 and cfg.d_ff % n == 0:
        # scan over ff blocks: weights become scan xs, so the FSDP
        # all-gather happens per-slice inside the loop — at most one
        # (E_local, d, ff/n) block is ever live in gathered form.
        ffc = cfg.d_ff // n
        wg = p["w_gate"].reshape(E, cfg.d_model, n, ffc).transpose(2, 0, 1, 3)
        wu = p["w_up"].reshape(E, cfg.d_model, n, ffc).transpose(2, 0, 1, 3)
        wd = p["w_down"].reshape(E, n, ffc, cfg.d_model).transpose(1, 0, 2, 3)

        def ff_step(acc, ws):
            g, u, dn = (w.astype(x.dtype) for w in ws)
            h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, g))
            h = h * jnp.einsum("becd,edf->becf", buf, u)
            return acc + jnp.einsum("becf,efd->becd", h, dn), None

        # NOTE: no remat on ff_step — the scan structure alone bounds the
        # live gathered-weight bytes, and rematting it re-gathers every
        # chunk in the backward (+50% FLOPs, 3x collective bytes, measured).
        out, _ = jax.lax.scan(ff_step, jnp.zeros_like(buf), (wg, wu, wd))
    else:
        h_spec = (P(bd, "model", None, None) if e_par
                  else P(bd, None, None, "model"))
        h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf,
                                   p["w_gate"].astype(x.dtype)))
        h = _maybe_constrain(h, h_spec)
        h = h * jnp.einsum("becd,edf->becf", buf, p["w_up"].astype(x.dtype))
        out = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(x.dtype))
    out = _maybe_constrain(
        out, P(bd, "model", None, None) if e_par else P(bd, None, None, None))

    if S <= 8192:       # scatter+psum combine: token-sized collective
        y = jax.vmap(lambda o, i: _combine_row_scatter(o, i, S, d))(out, info)
    else:               # long prefill: S-sized partials would dominate HBM
        y = jax.vmap(lambda o, i: _combine_row_gather(
            o.reshape(E * C, d), i, S, d))(out, info)
    return y


def aux_load_balance_loss(logits, gate_idx, cfg: ArchConfig):
    """Switch-style auxiliary loss (optional; wired via --moe-aux)."""
    E = cfg.moe_experts
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    me = probs.mean(axis=tuple(range(probs.ndim - 1)))
    ce = jnp.zeros((E,)).at[gate_idx.reshape(-1)].add(1.0)
    ce = ce / jnp.maximum(ce.sum(), 1.0)
    return E * jnp.sum(me * ce)
