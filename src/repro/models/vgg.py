"""VGG-16 on 32x32 inputs — the paper's own workload (Figs. 1, 4-8).

Small enough to run on CPU; used by the pipelined-SL executor demo
(examples/train_pipeline_sl.py), the split-learning integration tests, and
as the reference whose analytical profile is core.profiles.vgg16_profile.
The 16 "layers" match the paper's I = 16 (13 conv + 3 fc); pools fold into
the following conv, exactly as the profile assumes.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .common import cross_entropy, dense_init

#: ReLU (He) gain: ``dense_init`` is 1/sqrt(fan_in) — correct for the
#: normed residual transformers, but a norm-free 16-layer ReLU stack decays
#: activations by ~1/sqrt(2) per layer under it (logits land at ~1e-3 and
#: single-batch overfit plateaus at the majority class — the ISSUE 3
#: "convergence-margin" seed debt).  Hidden layers take the sqrt(2) gain;
#: the logit layer stays at gain 1.
_RELU_GAIN = math.sqrt(2.0)


# (kind, out_channels, pool_before) mirroring core.profiles._VGG16_LAYERS
LAYERS = (
    ("conv", 64, False), ("conv", 64, False),
    ("conv", 128, True), ("conv", 128, False),
    ("conv", 256, True), ("conv", 256, False), ("conv", 256, False),
    ("conv", 512, True), ("conv", 512, False), ("conv", 512, False),
    ("conv", 512, True), ("conv", 512, False), ("conv", 512, False),
    ("fc", 4096, True), ("fc", 4096, False), ("fc", 10, False),
)


def init_params(rng, dtype=jnp.float32):
    params = []
    in_c, hw = 3, 32
    keys = jax.random.split(rng, len(LAYERS))
    for i, (key, (kind, out_c, pool)) in enumerate(zip(keys, LAYERS)):
        if pool:
            hw //= 2
        if kind == "conv":
            w = dense_init(key, (3, 3, in_c, out_c), dtype, in_axis=2) \
                * (_RELU_GAIN / 3.0)  # fan-in includes the 3x3 window
            params.append({"w": w, "b": jnp.zeros((out_c,), dtype)})
            in_c = out_c
        else:
            fan_in = in_c * hw * hw if hw > 1 else in_c
            gain = _RELU_GAIN if i < len(LAYERS) - 1 else 1.0
            w = dense_init(key, (fan_in, out_c), dtype) * gain
            params.append({"w": w, "b": jnp.zeros((out_c,), dtype)})
            in_c, hw = out_c, 1
    return params


def layer_fwd(i: int, p, x):
    """Apply layer i (with its preceding pool, if any)."""
    kind, out_c, pool = LAYERS[i]
    if pool and x.ndim == 4:
        x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                  (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    if kind == "conv":
        x = jax.lax.conv_general_dilated(
            x, p["w"], (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        return jax.nn.relu(x + p["b"])
    if x.ndim == 4:
        x = x.reshape(x.shape[0], -1)
    x = x @ p["w"] + p["b"]
    return jax.nn.relu(x) if i < len(LAYERS) - 1 else x


def forward(params, x, lo: int = 0, hi: int = len(LAYERS)):
    """Run layers [lo, hi) — the *submodel* abstraction of split learning."""
    for i in range(lo, hi):
        x = layer_fwd(i, params[i], x)
    return x


def loss_fn(params, batch):
    logits = forward(params, batch["images"])
    return cross_entropy(logits[:, None, :], batch["labels"][:, None])
