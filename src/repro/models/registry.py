"""Uniform model API over all families — used by the trainer, the server,
the dry-run, and the pipeline runtime.

    api = get_model(cfg)
    loss = api.loss(params, batch)                  # batch: dict of arrays
    logits, cache = api.prefill(params, batch, cache_len)
    logits, cache = api.decode(params, cache, token, pos)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from .common import ArchConfig
from . import jamba as jamba_lib
from . import rwkv6 as rwkv_lib
from . import transformer as tf_lib
from . import vlm as vlm_lib
from . import whisper as whisper_lib


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ArchConfig
    init: Callable            # (rng) -> params
    loss: Callable            # (params, batch) -> scalar
    prefill: Callable         # (params, batch, cache_len) -> (logits, cache)
    decode: Callable          # (params, cache, token, pos) -> (logits, cache)
    make_cache: Callable      # (batch_size, cache_len) -> cache pytree

    def param_count(self, params) -> int:
        return sum(int(p.size) for p in jax.tree.leaves(params))


def get_model(cfg: ArchConfig) -> ModelAPI:
    fam = cfg.family
    if fam in ("dense", "moe"):
        return ModelAPI(
            cfg=cfg,
            init=lambda rng: tf_lib.init_params(rng, cfg),
            loss=lambda p, b: tf_lib.loss_fn(p, b, cfg),
            prefill=lambda p, b, n: tf_lib.prefill(p, b["tokens"], cfg, n),
            decode=lambda p, c, t, pos: tf_lib.decode_step(p, c, t, pos, cfg),
            make_cache=lambda bs, n: tf_lib.make_cache(cfg, bs, n),
        )
    if fam == "vlm":
        return ModelAPI(
            cfg=cfg,
            init=lambda rng: vlm_lib.init_params(rng, cfg),
            loss=lambda p, b: vlm_lib.loss_fn(p, b, cfg),
            prefill=lambda p, b, n: vlm_lib.prefill(
                p, b["tokens"], b["patch_embeds"], cfg, n),
            decode=lambda p, c, t, pos: vlm_lib.decode_step(p, c, t, pos, cfg),
            make_cache=lambda bs, n: vlm_lib.make_cache(cfg, bs, n),
        )
    if fam == "hybrid":
        return ModelAPI(
            cfg=cfg,
            init=lambda rng: jamba_lib.init_params(rng, cfg),
            loss=lambda p, b: jamba_lib.loss_fn(p, b, cfg),
            prefill=lambda p, b, n: jamba_lib.prefill(p, b["tokens"], cfg, n),
            decode=lambda p, c, t, pos: jamba_lib.decode_step(
                p, c, t, pos, cfg),
            make_cache=lambda bs, n: jamba_lib.make_cache(cfg, bs, n),
        )
    if fam == "ssm":
        return ModelAPI(
            cfg=cfg,
            init=lambda rng: rwkv_lib.init_params(rng, cfg),
            loss=lambda p, b: rwkv_lib.loss_fn(p, b, cfg),
            prefill=lambda p, b, n: rwkv_lib.prefill(p, b["tokens"], cfg, n),
            decode=lambda p, c, t, pos: rwkv_lib.decode_step(
                p, c, t, pos, cfg),
            make_cache=lambda bs, n: rwkv_lib.init_state(cfg, bs),
        )
    if fam == "audio":
        return ModelAPI(
            cfg=cfg,
            init=lambda rng: whisper_lib.init_params(rng, cfg),
            loss=lambda p, b: whisper_lib.loss_fn(p, b, cfg),
            prefill=lambda p, b, n: whisper_lib.prefill(
                p, b["frames"], b["tokens"], cfg, n),
            decode=lambda p, c, t, pos: whisper_lib.decode_step(
                p, c, t, pos, cfg),
            make_cache=lambda bs, n: whisper_lib.make_cache(cfg, bs, n),
        )
    raise ValueError(f"unknown family {fam!r}")
