"""Mamba (selective SSM) block — the Jamba hybrid's attention-free layer.

Mamba-1 structure: in-proj -> depthwise causal conv -> selective SSM
(input-dependent delta/B/C, diagonal A) -> gate -> out-proj.  The selective
scan runs through ``chunked_linear_scan`` so the (B, S, d_inner, d_state)
state tensor never materializes beyond one time-chunk — the TPU-friendly
chunked formulation (DESIGN.md hardware adaptation).

Decode keeps (conv_state, ssm_state) per layer: the "KV cache" of an SSM is
O(1) in sequence length, which is why jamba/rwkv run the ``long_500k`` cell.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .common import ArchConfig, chunked_linear_scan, dense_init


def d_inner(cfg: ArchConfig) -> int:
    return cfg.mamba_expand * cfg.d_model


def dt_rank(cfg: ArchConfig) -> int:
    return max(1, math.ceil(cfg.d_model / 16))


def init_mamba_params(key, cfg: ArchConfig):
    d, di, ds, dc = cfg.d_model, d_inner(cfg), cfg.mamba_d_state, cfg.mamba_d_conv
    dtr = dt_rank(cfg)
    ks = jax.random.split(key, 8)
    A = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di), cfg.param_dtype),
        "conv_w": dense_init(ks[1], (dc, di), cfg.param_dtype, in_axis=0),
        "conv_b": jnp.zeros((di,), cfg.param_dtype),
        "x_proj": dense_init(ks[2], (di, dtr + 2 * ds), cfg.param_dtype),
        "dt_proj": dense_init(ks[3], (dtr, di), cfg.param_dtype),
        "dt_bias": jnp.full((di,), -4.6, cfg.param_dtype),  # softplus^-1(0.01)
        "A_log": jnp.log(A).astype(cfg.param_dtype),
        "D": jnp.ones((di,), cfg.param_dtype),
        "out_proj": dense_init(ks[4], (di, d), cfg.param_dtype),
        "norm": jnp.ones((d,), cfg.param_dtype),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv along time.  x: (B, S, di), w: (dc, di).

    ``state``: (B, dc-1, di) tail of the previous segment (decode);
    returns (y, new_state).
    """
    dc = w.shape[0]
    if state is None:
        x_pad = jnp.pad(x, ((0, 0), (dc - 1, 0), (0, 0)))
    else:
        x_pad = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(x_pad[:, i:i + x.shape[1], :] * w[i][None, None, :]
            for i in range(dc))
    new_state = x_pad[:, -(dc - 1):, :] if dc > 1 else None
    return y + b[None, None, :], new_state


def mamba_fwd(p, x, cfg: ArchConfig, *, state=None):
    """x: (B, S, d) -> (B, S, d).  ``state``: (conv_state, h) or None.

    With S == 1 and a state, this is the O(1) decode step.
    """
    from .common import rms_norm
    B, S, d = x.shape
    di, ds = d_inner(cfg), cfg.mamba_d_state
    h0 = None
    conv_state = None
    if state is not None:
        conv_state, h0 = state

    res = x
    x = rms_norm(x, p["norm"], cfg.norm_eps)
    xz = x @ p["in_proj"].astype(x.dtype)
    xin, z = jnp.split(xz, 2, axis=-1)
    xin, new_conv = _causal_conv(xin, p["conv_w"].astype(x.dtype),
                                 p["conv_b"].astype(x.dtype), conv_state)
    xin = jax.nn.silu(xin)

    proj = xin @ p["x_proj"].astype(x.dtype)
    dtr = dt_rank(cfg)
    dt, Bv, Cv = jnp.split(proj, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_proj"].astype(x.dtype) +
                         p["dt_bias"].astype(x.dtype))        # (B, S, di)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))              # (di, ds)

    # discretize: a = exp(dt*A), u = dt * B * x   (ZOH for A, Euler for B)
    from jax.sharding import PartitionSpec as P
    from .common import maybe_constrain
    dt = maybe_constrain(dt, P(("pod", "data"), None, "model"))
    xin = maybe_constrain(xin, P(("pod", "data"), None, "model"))

    if h0 is None:
        h0 = jnp.zeros((B, di, ds), jnp.float32)
    Cf = Cv.astype(jnp.float32)
    if S == 1:
        a1 = jnp.exp(dt.astype(jnp.float32)[:, 0, :, None] * A[None])
        u1 = (dt * xin).astype(jnp.float32)[:, 0, :, None] * \
            Bv.astype(jnp.float32)[:, 0, None, :]
        h = a1 * h0 + u1
        h_last = h
        y = jnp.einsum("bdn,bn->bd", h, Cf[:, 0])[:, None]      # (B,1,di)
    else:
        # Chunked selective scan, FULLY streaming: discretization (a, u),
        # the associative scan, and the C-readout all happen inside one
        # chunk — nothing (B, S, di, ds)-shaped ever materializes, neither
        # as scan xs nor as outputs.  (Full-sequence a/u cost ~0.5 GB/device
        # *per mamba layer* on jamba-398b; states-sequence materialization
        # cost ~270 GB/device — EXPERIMENTS.md §Perf iteration 0.)
        chunk = min(cfg.scan_chunk, S)
        while S % chunk != 0:
            chunk //= 2
        chunk = max(chunk, 1)
        n = S // chunk
        resh = lambda t: t.reshape((B, n, chunk) + t.shape[2:]).swapaxes(0, 1)
        dt_c, xin_c, B_c, C_c = (resh(t) for t in (dt, xin, Bv, Cf))

        def combine(c1, c2):
            a1, u1 = c1
            a2, u2 = c2
            return a1 * a2, a2 * u1 + u2

        def step(h, inp):
            dt_k, xin_k, B_k, C_k = inp                # (B, chunk, di) / ds
            a_k = jnp.exp(dt_k.astype(jnp.float32)[..., None] * A[None, None])
            u_k = (dt_k * xin_k).astype(jnp.float32)[..., None] * \
                B_k.astype(jnp.float32)[..., None, :]  # (B, chunk, di, ds)
            aa, uu = jax.lax.associative_scan(combine, (a_k, u_k), axis=1)
            h_all = aa * h[:, None] + uu
            y_k = jnp.einsum("bcdn,bcn->bcd", h_all, C_k)
            return h_all[:, -1], y_k

        step = jax.checkpoint(step)
        h_last, y = jax.lax.scan(step, h0, (dt_c, xin_c, B_c, C_c))
        y = y.swapaxes(0, 1).reshape(B, S, di)
    y = y.astype(x.dtype) + xin * p["D"].astype(x.dtype)[None, None]
    y = y * jax.nn.silu(z)
    out = res + y @ p["out_proj"].astype(x.dtype)
    return out, (new_conv, h_last)


def init_mamba_state(cfg: ArchConfig, batch: int):
    di, ds, dc = d_inner(cfg), cfg.mamba_d_state, cfg.mamba_d_conv
    conv = jnp.zeros((batch, dc - 1, di), cfg.compute_dtype)
    h = jnp.zeros((batch, di, ds), jnp.float32)
    return conv, h
