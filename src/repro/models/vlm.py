"""VLM backbone (internvl2-1b class): LM transformer + stubbed ViT frontend.

Per the assignment, only the transformer BACKBONE is modeled; the vision
encoder is a stub whose output — precomputed patch embeddings
(B, patch_tokens, d_model) — arrives via ``input_specs``.  Patches are
prepended to the token embeddings; loss is computed on the text positions
only.  Decode is identical to the plain transformer (patches live at the
head of the KV cache after prefill).
"""

from __future__ import annotations

import jax.numpy as jnp

from .common import ArchConfig
from . import transformer as tf


init_params = tf.init_params
make_cache = tf.make_cache
decode_step = tf.decode_step


def loss_fn(params, batch, cfg: ArchConfig):
    return tf.loss_fn(params, batch, cfg)   # handles batch["patch_embeds"]


def prefill(params, tokens, patch_embeds, cfg: ArchConfig, cache_len: int):
    return tf.prefill(params, tokens, cfg, cache_len,
                      extra_embeds=patch_embeds)
