"""Shared model building blocks (pure JAX, functional, scan-over-layers).

Conventions:
  - layer params are *stacked* on a leading ``L`` axis and consumed through
    ``jax.lax.scan`` so the HLO stays compact regardless of depth (critical
    for the 512-device dry-run compiles);
  - params live in ``param_dtype`` (fp32 for training masters, bf16 for
    serving) and are cast to ``compute_dtype`` at use;
  - attention supports GQA, optional qk-norm, optional QKV bias, RoPE
    on/off, sliding windows, and three execution paths: full (short
    sequences), *chunked* flash-style (long prefill — online softmax over
    query blocks, never materializing the S x S score matrix), and
    single-token decode against a fixed-size KV cache.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One config object covers every assigned family via feature flags."""
    name: str
    family: str                   # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    d_head: int = 0               # 0 -> d_model // n_heads
    qk_norm: bool = False
    qkv_bias: bool = False
    attn_out_bias: bool = False
    tie_embeddings: bool = False
    ffn_mult: int = 3             # 3 = SwiGLU, 2 = plain GELU MLP
    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_every: int = 1            # layer i is MoE iff experts>0 and i%every==0
    capacity_factor: float = 1.25
    # hybrid (Jamba): within a period of ``attn_every`` layers, exactly one
    # attention layer, the rest Mamba.  0 disables (pure attention).
    attn_every: int = 0
    mamba_d_state: int = 16
    mamba_expand: int = 2
    mamba_d_conv: int = 4
    # rwkv
    rwkv: bool = False
    rwkv_head_dim: int = 64
    # enc-dec (whisper)
    encoder_layers: int = 0
    encoder_frames: int = 1500
    # vlm
    moe_ff_chunks: int = 1        # scan expert matmuls over ff blocks:
    # bounds the live bytes of FSDP-gathered expert weights (jamba's
    # 8192x24576 experts otherwise hold ~GBs gathered per layer)
    patch_tokens: int = 0         # stub ViT patch embeddings, prepended
    # positional / numerics
    use_rope: bool = True
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    sliding_window: int = 0       # >0: attention window (for long contexts)
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    # runtime knobs (hillclimbing handles)
    attn_chunk: int = 1024        # query-block size of chunked attention
    scan_chunk: int = 256         # time-chunk of SSM/RWKV linear scans
    remat: str = "layer"          # none | layer | dots
    train_microbatches: int = 0   # 0 = auto (launch/steps.py policy)
    use_pallas: bool = False      # route attention/WKV through Pallas kernels
    seq_parallel_residual: bool = False
    # ^ Megatron-SP-style: keep the residual stream sequence-sharded over
    #   "model" between blocks, so XLA lowers the per-layer TP sync as
    #   all-gather + reduce-scatter (payload S*d bf16) instead of a full
    #   all-reduce (2x S*d) — §Perf iteration 3.

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv

    def layer_kind(self, i: int) -> str:
        """'attn' | 'mamba' for layer i of a hybrid stack."""
        if self.rwkv:
            return "rwkv"
        if self.attn_every <= 0:
            return "attn"
        return "attn" if (i % self.attn_every) == (self.attn_every - 1) else "mamba"

    def is_moe_layer(self, i: int) -> bool:
        return self.moe_experts > 0 and (i % self.moe_every) == (self.moe_every - 1)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, in_axis: int = -2):
    fan_in = shape[in_axis] if len(shape) > 1 else shape[0]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms / activations / rope
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def swiglu(x, w_gate, w_up, w_down):
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def gelu_mlp(x, w_up, b_up, w_down, b_down):
    h = jax.nn.gelu(x @ w_up + b_up, approximate=True)
    return h @ w_down + b_down


def rope_angles(positions, head_dim: int, theta: float):
    """positions: (...,) int32 -> (..., head_dim//2) angles."""
    freqs = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                             / head_dim))
    return positions.astype(jnp.float32)[..., None] * freqs


def apply_rope(x, positions, theta: float):
    """x: (..., S, n, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    ang = rope_angles(positions, hd, theta)          # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]                 # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention — three execution paths
# ---------------------------------------------------------------------------

def _repeat_kv(k, q_per_kv: int):
    """(B, T, KV, hd) -> (B, T, KV*G, hd)."""
    b, t, kv, hd = k.shape
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, t, kv, q_per_kv, hd))
    return k.reshape(b, t, kv * q_per_kv, hd)


def _heads_spec():
    """Preferred layout of (B, S, H, hd) attention tensors: batch over the
    data axes, heads over "model".  Without the explicit constraint GSPMD
    keeps heads replicated whenever the kv-head count doesn't divide the
    model axis (the repeat-kv path), running attention 16x redundantly —
    measured in EXPERIMENTS.md §Perf iteration 0."""
    from jax.sharding import PartitionSpec as P
    return P(("pod", "data"), None, "model", None)


def _kv_seq_spec():
    """Fallback when the head count doesn't divide the model axis (e.g.
    whisper's 12 heads on a 16-wide mesh): shard the KEY sequence instead
    (sequence-parallel attention; XLA inserts the softmax psums)."""
    from jax.sharding import PartitionSpec as P
    return P(("pod", "data"), "model", None, None)


def _heads_divide_model(h: int) -> bool:
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
            return True
        return h % mesh.shape["model"] == 0
    except Exception:
        return True


def full_attention(q, k, v, *, causal: bool = True, window: int = 0,
                   q_offset: int = 0):
    """q: (B, S, H, hd); k, v: (B, T, H, hd).  Returns (B, S, H, hd).

    Materializes (B, H, S, T) scores — use only when S*T is small/medium;
    ``chunked_attention`` covers the long-sequence path.
    """
    b, s, h, hd = q.shape
    t = k.shape[1]
    if _heads_divide_model(h):
        q = maybe_constrain(q, _heads_spec())
        k = maybe_constrain(k, _heads_spec())
        v = maybe_constrain(v, _heads_spec())
    else:
        k = maybe_constrain(k, _kv_seq_spec())
        v = maybe_constrain(v, _kv_seq_spec())
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32) * scale
    qpos = jnp.arange(s) + q_offset
    kpos = jnp.arange(t)
    mask = jnp.ones((s, t), dtype=bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        mask &= kpos[None, :] > (qpos[:, None] - window)
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhst,bthd->bshd", probs, v)


def chunked_attention(q, k, v, *, causal: bool = True, window: int = 0,
                      chunk: int = 1024):
    """Flash-style attention in pure jnp: scan over query blocks with an
    online softmax, so peak memory is (B, H, chunk, T) instead of
    (B, H, S, T).  This is also the oracle for the Pallas flash kernel."""
    b, s, h, hd = q.shape
    t = k.shape[1]
    if _heads_divide_model(h):
        q = maybe_constrain(q, _heads_spec())
        k = maybe_constrain(k, _heads_spec())
        v = maybe_constrain(v, _heads_spec())
    else:
        k = maybe_constrain(k, _kv_seq_spec())
        v = maybe_constrain(v, _kv_seq_spec())
    if s % chunk != 0:
        pad = chunk - s % chunk
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        s_pad = s + pad
    else:
        pad, s_pad = 0, s
    nq = s_pad // chunk
    qb = q.reshape(b, nq, chunk, h, hd).transpose(1, 0, 2, 3, 4)
    from jax.sharding import PartitionSpec as P
    qb = maybe_constrain(qb, P(None, ("pod", "data"), None, "model", None))
    scale = 1.0 / math.sqrt(hd)
    kpos = jnp.arange(t)

    def do_block(i, q_blk):
        qpos = i * chunk + jnp.arange(chunk)
        scores = jnp.einsum("bshd,bthd->bhst", q_blk, k).astype(jnp.float32)
        scores = scores * scale
        mask = jnp.ones((chunk, t), dtype=bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window > 0:
            mask &= kpos[None, :] > (qpos[:, None] - window)
        scores = jnp.where(mask[None, None], scores, -1e30)
        m = jnp.max(scores, axis=-1, keepdims=True)
        p = jnp.exp(scores - m)
        num = jnp.einsum("bhst,bthd->bshd", p.astype(q_blk.dtype), v)
        den = jnp.sum(p, axis=-1).transpose(0, 2, 1)[..., None]  # (b,s,h,1)
        return (num / jnp.maximum(den, 1e-30).astype(num.dtype))

    out = jax.lax.map(lambda args: do_block(*args),
                      (jnp.arange(nq), qb))
    out = maybe_constrain(out, P(None, ("pod", "data"), None, "model", None))
    out = out.transpose(1, 0, 2, 3, 4).reshape(b, s_pad, h, hd)
    return out[:, :s] if pad else out


def decode_attention(q, k_cache, v_cache, pos):
    """Single-token decode: q (B, 1, H, hd) against a fixed-size cache
    (B, T, KV, hd); only entries < pos+1 participate."""
    b, _, h, hd = q.shape
    t, kv = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    scale = 1.0 / math.sqrt(hd)
    q = maybe_constrain(q, _heads_spec())
    qg = q.reshape(b, 1, kv, g, hd)
    scores = jnp.einsum("bqkgh,btkh->bkgqt", qg, k_cache).astype(jnp.float32)
    scores = scores * scale
    valid = jnp.arange(t)[None, None, None, None, :] <= pos
    scores = jnp.where(valid, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqt,btkh->bqkgh", probs, v_cache)
    return out.reshape(b, 1, h, hd)


# ---------------------------------------------------------------------------
# Chunked linear recurrence  h_t = a_t * h_{t-1} + x_t   (SSM / RWKV carrier)
# ---------------------------------------------------------------------------

def chunked_linear_scan(a, x, h0, chunk: int = 256):
    """Solve h_t = a_t (*) h_{t-1} + x_t along axis 1 (time) in chunks.

    a, x: (B, S, ...) with matching trailing dims; h0: (B, ...).
    Sequential lax.scan over S/chunk chunks; inside a chunk, an associative
    scan — the standard memory/throughput trade used by chunked SSM kernels
    (keeps the transient state S_chunk x state instead of S x state).
    """
    b, s = x.shape[:2]
    assert s % chunk == 0, (s, chunk)
    n = s // chunk
    a_c = a.reshape((b, n, chunk) + a.shape[2:]).swapaxes(0, 1)
    x_c = x.reshape((b, n, chunk) + x.shape[2:]).swapaxes(0, 1)

    def combine(c1, c2):
        a1, u1 = c1
        a2, u2 = c2
        return a1 * a2, a2 * u1 + u2

    def step(h, ax):
        a_k, x_k = ax                                  # (B, chunk, ...)
        aa, uu = jax.lax.associative_scan(combine, (a_k, x_k), axis=1)
        h_all = aa * h[:, None] + uu                   # prefix-applied carry
        return h_all[:, -1], h_all

    h_last, ys = jax.lax.scan(step, h0, (a_c, x_c))
    ys = ys.swapaxes(0, 1).reshape((b, s) + x.shape[2:])
    return h_last, ys


# ---------------------------------------------------------------------------
# Cross entropy (computed in fp32, logits never stored beyond the microbatch)
# ---------------------------------------------------------------------------

def cross_entropy(logits, labels, ignore_id: int = -1):
    """logits (B, S, V) any float dtype; labels (B, S) int32.

    Logits stay in their compute dtype; only the *reductions* accumulate in
    fp32 (XLA fuses the convert into the reduce) — an fp32 copy of the
    vocab-sized logits never materializes in HBM.  Measured: -4.6 GiB/device
    on qwen3-0.6b train_4k (EXPERIMENTS.md §Perf iteration 0).
    """
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - m
    sumexp = jnp.sum(jnp.exp(shifted), axis=-1, dtype=jnp.float32)
    gold = jnp.take_along_axis(shifted, labels[..., None].astype(jnp.int32),
                               axis=-1)[..., 0]
    nll = jnp.log(sumexp) - gold.astype(jnp.float32)
    mask = (labels != ignore_id)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)


def maybe_constrain(x, spec):
    """Sharding-constrain ``x`` when a named mesh is active; silently drop
    axis entries absent from the mesh or not dividing the dim.  Lets model
    code state its preferred layout without breaking mesh-less tests."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or not mesh.axis_names:
            return x
        fixed = []
        for i, ax in enumerate(spec):
            axes = ax if isinstance(ax, tuple) else ((ax,) if ax else ())
            axes = tuple(a for a in axes if a in mesh.axis_names)
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            if axes and x.shape[i] % size == 0:
                fixed.append(axes if len(axes) > 1 else axes[0])
            else:
                fixed.append(None)
        from jax.sharding import PartitionSpec
        return jax.lax.with_sharding_constraint(x, PartitionSpec(*fixed))
    except Exception:
        return x


def remat_wrap(fn, mode: str):
    if mode == "none":
        return fn
    if mode == "layer":
        return jax.checkpoint(fn)
    if mode == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    raise ValueError(mode)
