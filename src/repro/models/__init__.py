"""All-JAX model zoo: scan-over-layers LMs for every assigned architecture
(+ VGG-16 for the paper's own edge-SL workload)."""

from .common import ArchConfig
from .registry import ModelAPI, get_model

__all__ = ["ArchConfig", "ModelAPI", "get_model"]
