"""Jamba-style hybrid: Mamba + attention (1:attn_every interleave) + MoE.

Layer i:  mixer = attention   if i % attn_every == attn_every-1 else Mamba
          ffn   = MoE         if i % moe_every == moe_every-1  else dense MLP

The stack is scanned over *periods* of ``attn_every`` layers (Jamba-1.5:
72 layers = 9 periods of 8), with the in-period structure unrolled — params
are stacked per slot on a leading period axis, so the HLO contains one
period body regardless of depth.  Heterogeneous per-layer profiles are
exactly what makes the paper's MSP planner interesting for this arch
(DESIGN.md §4).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import (ArchConfig, cross_entropy, dense_init, embed_init,
                     remat_wrap, rms_norm)
from . import mamba as mamba_lib
from . import moe as moe_lib
from . import transformer as tf_lib


def num_periods(cfg: ArchConfig) -> int:
    assert cfg.num_layers % cfg.attn_every == 0
    return cfg.num_layers // cfg.attn_every


def _slot_kinds(cfg: ArchConfig):
    """[(mixer, ffn)] for the attn_every slots inside one period."""
    kinds = []
    for j in range(cfg.attn_every):
        mixer = "attn" if j == cfg.attn_every - 1 else "mamba"
        ffn = "moe" if (j % cfg.moe_every) == (cfg.moe_every - 1) else "mlp"
        kinds.append((mixer, ffn))
    return kinds


def init_slot_params(key, cfg: ArchConfig, mixer: str, ffn: str):
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 8)
    p = {}
    if mixer == "attn":
        attn_cfg = cfg
        base = tf_lib.init_layer_params(ks[0], attn_cfg)
        # keep only the attention part; ffn handled below
        p.update({k: v for k, v in base.items()
                  if k in ("ln1", "wq", "wk", "wv", "wo", "q_norm", "k_norm",
                           "bq", "bk", "bv")})
    else:
        p["mamba"] = mamba_lib.init_mamba_params(ks[1], cfg)
    p["ln2"] = jnp.ones((d,), cfg.param_dtype)
    if ffn == "moe":
        p["moe"] = moe_lib.init_moe_params(ks[2], cfg)
    else:
        p["w_gate"] = dense_init(ks[3], (d, ff), cfg.param_dtype)
        p["w_up"] = dense_init(ks[4], (d, ff), cfg.param_dtype)
        p["w_down"] = dense_init(ks[5], (ff, d), cfg.param_dtype)
    return p


def init_params(rng, cfg: ArchConfig):
    P = num_periods(cfg)
    kinds = _slot_kinds(cfg)
    k_emb, k_head, *slot_keys = jax.random.split(rng, 2 + len(kinds))
    period = {}
    for j, ((mixer, ffn), sk) in enumerate(zip(kinds, slot_keys)):
        per_keys = jax.random.split(sk, P)
        period[f"slot{j}"] = jax.vmap(
            lambda k: init_slot_params(k, cfg, mixer, ffn))(per_keys)
    return {
        "embed": embed_init(k_emb, (cfg.vocab, cfg.d_model), cfg.param_dtype),
        "periods": period,
        "final_norm": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "lm_head": dense_init(k_head, (cfg.d_model, cfg.vocab),
                              cfg.param_dtype),
    }


def _ffn(p, x, cfg, ffn):
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if ffn == "moe":
        return x + moe_lib.moe_ffn(p["moe"], h, cfg)
    y = jax.nn.silu(h @ p["w_gate"].astype(h.dtype)) * \
        (h @ p["w_up"].astype(h.dtype))
    return x + y @ p["w_down"].astype(h.dtype)


def _attn_mixer(p, x, cfg, *, positions, mode, cache, pos):
    """Attention sub-block reusing transformer.block_fwd internals."""
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = tf_lib._project_qkv(p, h, cfg)
    # Jamba uses no positional encoding in attention (Mamba provides order)
    if mode == "decode":
        from .common import decode_attention
        kc, vc = cache
        kc = jax.lax.dynamic_update_slice_in_dim(
            kc, k.astype(kc.dtype), pos, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(
            vc, v.astype(vc.dtype), pos, axis=1)
        attn = decode_attention(q, kc, vc, pos)
        new_cache = (kc, vc)
    else:
        from .common import chunked_attention, full_attention
        g = cfg.q_per_kv
        kf = jnp.repeat(k, g, axis=2) if g > 1 else k
        vf = jnp.repeat(v, g, axis=2) if g > 1 else v
        S = x.shape[1]
        if S > cfg.attn_chunk:
            attn = chunked_attention(q, kf, vf, causal=True,
                                     chunk=cfg.attn_chunk)
        else:
            attn = full_attention(q, kf, vf, causal=True)
        new_cache = (k, v)
    B, S = x.shape[:2]
    attn = attn.reshape(B, S, cfg.n_heads * cfg.head_dim)
    return x + attn @ p["wo"].astype(x.dtype), new_cache


def period_fwd(period_params, x, cfg: ArchConfig, *, mode="train",
               caches=None, pos=None):
    """Run one period (attn_every layers).  caches: dict with
    'kv' (k, v) for the attention slot and 'mamba{j}' states."""
    kinds = _slot_kinds(cfg)
    new_caches = {}
    positions = jnp.arange(x.shape[1]) if pos is None else None
    # per-slot remat (in addition to the per-period wrap): bounds backward
    # residuals to ONE layer at a time — a 7-mamba-layer period's residuals
    # otherwise coexist (measured ~35 GiB/device on jamba-398b).
    inner_remat = (mode == "train" and cfg.remat != "none")
    for j, (mixer, ffn) in enumerate(kinds):
        p = period_params[f"slot{j}"]
        if mixer == "attn":
            cache = caches.get("kv") if caches else None
            x, new_kv = _attn_mixer(p, x, cfg, positions=positions,
                                    mode=mode, cache=cache, pos=pos)
            new_caches["kv"] = new_kv
        else:
            state = caches.get(f"mamba{j}") if caches else None
            mfwd = (jax.checkpoint(
                        lambda pp, xx: mamba_lib.mamba_fwd(pp, xx, cfg,
                                                           state=None))
                    if inner_remat else
                    lambda pp, xx: mamba_lib.mamba_fwd(pp, xx, cfg,
                                                       state=state))
            x, new_state = mfwd(p["mamba"], x)
            new_caches[f"mamba{j}"] = new_state
        if inner_remat:
            x = jax.checkpoint(lambda pp, xx: _ffn(pp, xx, cfg, ffn))(p, x)
        else:
            x = _ffn(p, x, cfg, ffn)
    return x, new_caches


def forward_hidden(params, tokens, cfg: ArchConfig):
    x = params["embed"].astype(cfg.compute_dtype)[tokens]
    body = remat_wrap(
        lambda x, pp: period_fwd(pp, x, cfg, mode="train")[0], cfg.remat)
    x, _ = jax.lax.scan(lambda c, pp: (body(c, pp), None), x,
                        params["periods"])
    return x


def loss_fn(params, batch, cfg: ArchConfig):
    x = forward_hidden(params, batch["tokens"], cfg)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["lm_head"].astype(x.dtype)
    return cross_entropy(logits, batch["labels"])


def make_cache(cfg: ArchConfig, batch: int, cache_len: int):
    P = num_periods(cfg)
    di, ds, dc = (mamba_lib.d_inner(cfg), cfg.mamba_d_state,
                  cfg.mamba_d_conv)
    cache = {
        "k": jnp.zeros((P, batch, cache_len, cfg.n_kv, cfg.head_dim),
                       cfg.compute_dtype),
        "v": jnp.zeros((P, batch, cache_len, cfg.n_kv, cfg.head_dim),
                       cfg.compute_dtype),
    }
    for j, (mixer, _) in enumerate(_slot_kinds(cfg)):
        if mixer == "mamba":
            cache[f"m{j}_conv"] = jnp.zeros((P, batch, dc - 1, di),
                                            cfg.compute_dtype)
            cache[f"m{j}_h"] = jnp.zeros((P, batch, di, ds), jnp.float32)
    return cache


def _caches_from_slices(cfg, sl):
    caches = {"kv": (sl["k"], sl["v"])}
    for j, (mixer, _) in enumerate(_slot_kinds(cfg)):
        if mixer == "mamba":
            caches[f"mamba{j}"] = (sl[f"m{j}_conv"], sl[f"m{j}_h"])
    return caches


def _slices_from_caches(cfg, new):
    out = {"k": new["kv"][0], "v": new["kv"][1]}
    for j, (mixer, _) in enumerate(_slot_kinds(cfg)):
        if mixer == "mamba":
            out[f"m{j}_conv"] = new[f"mamba{j}"][0]
            out[f"m{j}_h"] = new[f"mamba{j}"][1]
    return out


def prefill(params, tokens, cfg: ArchConfig, cache_len: int):
    x = params["embed"].astype(cfg.compute_dtype)[tokens]
    B, S = tokens.shape

    def scan_body(c, pp):
        y, new = period_fwd(pp, c, cfg, mode="prefill", caches=None)
        k, v = new["kv"]
        pad = cache_len - S
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(cfg.compute_dtype)
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(cfg.compute_dtype)
        new["kv"] = (k, v)
        return y, _slices_from_caches(cfg, new)

    x, cache = jax.lax.scan(scan_body, x, params["periods"])
    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = x @ params["lm_head"].astype(x.dtype)
    return logits, cache


def decode_step(params, cache, token, pos, cfg: ArchConfig):
    x = params["embed"].astype(cfg.compute_dtype)[token]

    def scan_body(c, layer):
        pp, sl = layer
        caches = _caches_from_slices(cfg, sl)
        y, new = period_fwd(pp, c, cfg, mode="decode", caches=caches,
                            pos=pos)
        return y, _slices_from_caches(cfg, new)

    x, new_cache = jax.lax.scan(scan_body, x, (params["periods"], cache))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["lm_head"].astype(x.dtype)
    return logits, new_cache
