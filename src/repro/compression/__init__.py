"""Inter-stage traffic compression (activations fwd / act-grads bwd)."""

from .codecs import (int8_quantize, int8_dequantize, topk_sparsify,
                     topk_densify, ErrorFeedback, make_link_hooks,
                     compressed_bytes)

__all__ = ["int8_quantize", "int8_dequantize", "topk_sparsify",
           "topk_densify", "ErrorFeedback", "make_link_hooks",
           "compressed_bytes"]
