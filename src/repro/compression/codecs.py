"""Gradient/activation compression for bandwidth-constrained links.

The paper's multi-hop links are the bottleneck term of Eq. (13) whenever
comm dominates; compressing the cut-layer traffic moves D_k / D'_k
(Eqs. 5/9) down by the codec ratio, which the planner then re-optimizes
around (the cut may move once links get cheaper!).  Codecs:

  int8     per-tensor affine quantization            (ratio 4x vs fp32)
  top-k    magnitude sparsification + error feedback (ratio ~ k)

Error feedback keeps the residual locally and re-injects it the next round
— the standard fix for biased compressors' convergence.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


def int8_quantize(x):
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def int8_dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def topk_sparsify(x, k: int):
    """Keep the k largest-|.| entries (flat); returns (values, indices)."""
    flat = x.reshape(-1)
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    return flat[idx], idx


def topk_densify(values, idx, shape):
    flat = jnp.zeros((int(jnp.prod(jnp.array(shape))),), values.dtype)
    return flat.at[idx].set(values).reshape(shape)


@dataclasses.dataclass
class ErrorFeedback:
    """Residual accumulator around a biased codec."""
    residual: jnp.ndarray | None = None

    def compress(self, x, codec_fwd: Callable, codec_bwd: Callable):
        if self.residual is not None:
            x = x + self.residual.astype(x.dtype)
        payload = codec_fwd(x)
        decoded = codec_bwd(payload).astype(x.dtype)
        self.residual = x - decoded
        return decoded


def compressed_bytes(nbytes_fp32: float, codec: str,
                     topk_ratio: float = 0.05) -> float:
    """D_k scaling for the latency model / planner."""
    if codec == "none":
        return nbytes_fp32
    if codec == "int8":
        return nbytes_fp32 / 4.0
    if codec == "topk":
        # values (4B) + indices (4B) per kept entry
        return nbytes_fp32 * topk_ratio * 2.0
    raise ValueError(codec)


def make_link_hooks(codec: str = "int8", topk_ratio: float = 0.05):
    """pipeline.LinkHooks factory applying the codec in both directions.
    Straight-through in autodiff: quantization is applied inside
    lax.stop_gradient deltas so training stays stable."""
    def roundtrip(x):
        if codec == "none":
            return x
        xf = x.astype(jnp.float32)
        if codec == "int8":
            q, s = int8_quantize(xf)
            dec = int8_dequantize(q, s)
        elif codec == "topk":
            k = max(1, int(xf.size * topk_ratio))
            vals, idx = topk_sparsify(xf, k)
            dec = topk_densify(vals, idx, xf.shape)
        else:
            raise ValueError(codec)
        # straight-through estimator
        return (x + jax.lax.stop_gradient(dec.astype(x.dtype) - x))

    from repro.pipeline.executor import LinkHooks
    return LinkHooks(fwd=roundtrip, bwd=roundtrip)
