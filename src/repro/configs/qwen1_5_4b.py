"""qwen1.5-4b [dense] — 40L d_model=2560 20H (GQA kv=20, i.e. MHA)
d_ff=6912 vocab=151936; QKV bias.  [hf:Qwen/Qwen1.5-0.5B; hf]"""

import dataclasses

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-4b", family="dense",
    num_layers=40, d_model=2560, n_heads=20, n_kv=20, d_ff=6912,
    vocab=151936, d_head=128, qk_norm=False, qkv_bias=True,
    tie_embeddings=False, ffn_mult=3, rope_theta=1e6,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="qwen1.5-4b-reduced", num_layers=2, d_model=64,
        n_heads=4, n_kv=4, d_head=16, d_ff=128, vocab=384)
