"""whisper-small [audio] — 12L d_model=768 12H (MHA kv=12) d_ff=3072
vocab=51865; enc-dec, conv frontend STUB.  [arXiv:2212.04356; unverified]
12 encoder + 12 decoder layers; input_specs() provides precomputed frame
embeddings (B, 1500, d_model) where the conv stem would emit them."""

import dataclasses

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small", family="audio",
    num_layers=12, d_model=768, n_heads=12, n_kv=12, d_ff=3072,
    vocab=51865, d_head=64, qk_norm=False, qkv_bias=True,
    tie_embeddings=True, ffn_mult=2, use_rope=False,
    encoder_layers=12, encoder_frames=1500,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="whisper-small-reduced", num_layers=2, d_model=64,
        n_heads=4, n_kv=4, d_head=16, d_ff=128, vocab=384,
        encoder_layers=2, encoder_frames=16)
