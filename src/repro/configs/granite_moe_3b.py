"""granite-moe-3b-a800m [moe] — 32L d_model=1536 24H (GQA kv=8) d_ff=512
vocab=49155, MoE 40 experts top-8.  [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
NOTE: the assignment text says both "MoE 40e" and "32 experts"; we follow the
config line (40 experts) — recorded in DESIGN.md §4.  40 does not divide the
16-wide model axis, so experts shard on d_ff instead (512/16 = 32)."""

import dataclasses

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m", family="moe",
    num_layers=32, d_model=1536, n_heads=24, n_kv=8, d_ff=512,
    vocab=49155, d_head=64, qk_norm=False, qkv_bias=False,
    tie_embeddings=True, ffn_mult=3, rope_theta=1e4,
    moe_experts=40, moe_top_k=8, moe_every=1, capacity_factor=1.25,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="granite-moe-3b-reduced", num_layers=2, d_model=64,
        n_heads=4, n_kv=2, d_head=16, d_ff=64, vocab=384,
        moe_experts=5, moe_top_k=2)
