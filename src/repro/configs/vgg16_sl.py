"""VGG-16 / CIFAR-10-class — the PAPER's own workload (Table II: I = 16).

Not one of the 40 dry-run cells; used by the paper-reproduction benchmarks
(Figs. 1, 4-8), the split-learning executor example, and the edge-network
integration tests.  Simulation defaults mirror Table II."""

from repro.core.profiles import vgg16_profile

# Table II defaults
B_MINIBATCH = 512
B0_MICRO = 20
THETA = 0.01
KAPPA = 1.0 / 32.0      # FLOPs/byte
B_TH = 32               # [b_th^c, b_th^s]
T0 = 1e-3               # t_0^c / t_0^s
T1 = 1e-3               # t_1^c / t_1^s
N_SERVERS_DEFAULT = 6
F_RANGE = (1e12, 10e12)             # 1-10 TFLOPS
BW_LOW_HZ = (10e6, 50e6)            # 5G sub-6GHz per-link bandwidth
BW_HIGH_HZ = (100e6, 200e6)         # 5G mmWave per-link bandwidth
MEM_RANGE = (2 * 2**30, 16 * 2**30)  # 2-16 GB
POWER_W = (0.1, 0.5)                # 100-500 mW
GAMMA = 3.5
NOISE_DBM_HZ = -174.0


def profile():
    """w_i in bytes so that kappa = 1/32 FLOPs/byte recovers FLOPs (Eq. 2)."""
    return vgg16_profile(work_units="bytes")
