"""qwen3-moe-235b-a22b [moe] — 94L d_model=4096 64H (GQA kv=4) d_ff=1536
vocab=151936, MoE 128 experts top-8; qk_norm.  [hf:Qwen/Qwen3-30B-A3B; hf]
d_ff is the per-expert intermediate dim; every layer is MoE."""

import dataclasses

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    num_layers=94, d_model=4096, n_heads=64, n_kv=4, d_ff=1536,
    vocab=151936, d_head=128, qk_norm=True, qkv_bias=False,
    tie_embeddings=False, ffn_mult=3, rope_theta=1e6,
    moe_experts=128, moe_top_k=8, moe_every=1, capacity_factor=1.25,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="qwen3-moe-235b-reduced", num_layers=2, d_model=64,
        n_heads=4, n_kv=2, d_head=16, d_ff=64, vocab=384,
        moe_experts=8, moe_top_k=2)
