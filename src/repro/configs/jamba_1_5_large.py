"""jamba-1.5-large-398b [hybrid] — 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, MoE 16e top-2; Mamba:attn 7:1 interleave.
[arXiv:2403.19887; hf]

Period structure: 8 layers = 7 Mamba + 1 attention; MoE every 2nd layer.
The heterogeneous per-layer profile is the most interesting input to the
paper's MSP planner among the assigned archs (DESIGN.md §4)."""

import dataclasses

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    num_layers=72, d_model=8192, n_heads=64, n_kv=8, d_ff=24576,
    vocab=65536, d_head=128, qk_norm=False, qkv_bias=False,
    tie_embeddings=False, ffn_mult=3, use_rope=False,
    moe_experts=16, moe_top_k=2, moe_every=2, capacity_factor=1.25,
    attn_every=8, mamba_d_state=16, mamba_expand=2, mamba_d_conv=4,
    moe_ff_chunks=4,   # bound live FSDP-gathered expert-weight bytes
    # §Perf cell-C winners (EXPERIMENTS.md): dots-remat kills the period
    # recompute chain (flops −45%, collectives −52%); Q=8 halves the FSDP
    # weight re-gathers (collectives −29% more); both fit 16 GiB adjusted.
    remat="dots", train_microbatches=8,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="jamba-1.5-large-reduced", num_layers=8, d_model=64,
        n_heads=4, n_kv=2, d_head=16, d_ff=128, vocab=384,
        moe_experts=4, moe_top_k=2, attn_every=4)
