"""rwkv6-1.6b "Finch" [ssm] — 24L d_model=2048 (attention-free) d_ff=7168
vocab=65536; data-dependent decay.  [arXiv:2404.05892; unverified]
O(1) decode state => runs the long_500k cell."""

import dataclasses

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b", family="ssm",
    num_layers=24, d_model=2048, n_heads=32, n_kv=32, d_ff=7168,
    vocab=65536, rwkv=True, rwkv_head_dim=64, use_rope=False,
    ffn_mult=2,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="rwkv6-1.6b-reduced", num_layers=2, d_model=64,
        n_heads=2, n_kv=2, d_ff=128, vocab=384, rwkv_head_dim=32)
