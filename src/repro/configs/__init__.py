"""Arch registry: ``get_config(arch_id)`` + the assigned shape grid."""

from repro.models.common import ArchConfig

from . import (command_r_35b, granite_moe_3b, internvl2_1b, jamba_1_5_large,
               llama3_8b, qwen1_5_4b, qwen3_0_6b, qwen3_moe_235b, rwkv6_1_6b,
               whisper_small)
from .base import (SHAPES, SHAPE_NAMES, ShapeSpec, arch_profile, cache_specs,
                   count_params, input_specs, param_specs, runnable_cells,
                   supports_shape)

_MODULES = {
    "qwen3-0.6b": qwen3_0_6b,
    "command-r-35b": command_r_35b,
    "llama3-8b": llama3_8b,
    "qwen1.5-4b": qwen1_5_4b,
    "qwen3-moe-235b-a22b": qwen3_moe_235b,
    "granite-moe-3b-a800m": granite_moe_3b,
    "jamba-1.5-large-398b": jamba_1_5_large,
    "rwkv6-1.6b": rwkv6_1_6b,
    "internvl2-1b": internvl2_1b,
    "whisper-small": whisper_small,
}

ARCH_IDS = tuple(_MODULES)
CONFIGS = {name: mod.CONFIG for name, mod in _MODULES.items()}


def get_config(arch_id: str, reduced: bool = False) -> ArchConfig:
    mod = _MODULES[arch_id]
    return mod.reduced() if reduced else mod.CONFIG


__all__ = ["ARCH_IDS", "CONFIGS", "get_config", "SHAPES", "SHAPE_NAMES",
           "ShapeSpec", "input_specs", "cache_specs", "param_specs",
           "arch_profile", "count_params", "supports_shape",
           "runnable_cells"]
