"""llama3-8b [dense] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256; GQA, 128k vocab.  [arXiv:2407.21783; unverified]"""

import dataclasses

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="llama3-8b", family="dense",
    num_layers=32, d_model=4096, n_heads=32, n_kv=8, d_ff=14336,
    vocab=128256, d_head=128, qk_norm=False, qkv_bias=False,
    tie_embeddings=False, ffn_mult=3, rope_theta=5e5,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="llama3-8b-reduced", num_layers=2, d_model=64,
        n_heads=4, n_kv=2, d_head=16, d_ff=176, vocab=384)
