"""qwen3-0.6b [dense] — 28L d_model=1024 16H (GQA kv=8) d_ff=3072
vocab=151936; qk_norm, GQA.  [hf:Qwen/Qwen3-8B; hf]
Qwen3-family head_dim is 128 (q/k/v projections are wider than d_model)."""

import dataclasses

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-0.6b", family="dense",
    num_layers=28, d_model=1024, n_heads=16, n_kv=8, d_ff=3072,
    vocab=151936, d_head=128, qk_norm=True, qkv_bias=False,
    tie_embeddings=True, ffn_mult=3, rope_theta=1e6,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="qwen3-0.6b-reduced", num_layers=2, d_model=64,
        n_heads=4, n_kv=2, d_head=16, d_ff=128, vocab=256)
