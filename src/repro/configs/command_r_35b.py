"""command-r-35b [dense] — 40L d_model=8192 64H (GQA kv=8) d_ff=22528
vocab=256000; GQA, no-bias.  [hf:CohereForAI/c4ai-command-r-v01; unverified]
Cohere ties input/output embeddings; the 256k vocab makes the embedding +
head the dominant memory terms (sharded on "model")."""

import dataclasses

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="command-r-35b", family="dense",
    num_layers=40, d_model=8192, n_heads=64, n_kv=8, d_ff=22528,
    vocab=256000, d_head=128, qk_norm=False, qkv_bias=False,
    tie_embeddings=True, ffn_mult=3, rope_theta=8e6,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="command-r-35b-reduced", num_layers=2, d_model=64,
        n_heads=8, n_kv=2, d_head=8, d_ff=192, vocab=512)
