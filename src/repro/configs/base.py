"""Config substrate: assigned input shapes, input_specs(), reduced configs,
and per-arch workload profiles for the planner.

Every assigned architecture gets ``src/repro/configs/<id>.py`` exporting:
  CONFIG   — the exact assigned dims (ArchConfig)
  reduced() — a tiny same-family config for CPU smoke tests

The four assigned shapes apply to each arch (cells), with the documented
skips: ``long_500k`` only for sub-quadratic archs (ssm / hybrid).
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ArchConfig
from repro.core.profiles import ModelProfile


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str             # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

SHAPE_NAMES = tuple(SHAPES)


def supports_shape(cfg: ArchConfig, shape: str) -> bool:
    """long_500k needs sub-quadratic attention: ssm + hybrid only
    (full-attention archs are recorded as N/A — DESIGN.md §4)."""
    if shape == "long_500k":
        return cfg.family in ("ssm", "hybrid")
    return True


def runnable_cells(configs: dict) -> list:
    return [(a, s) for a in configs for s in SHAPE_NAMES
            if supports_shape(configs[a], s)]


# ---------------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins for every model input
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape_name: str) -> dict:
    """Returns {name: ShapeDtypeStruct} for the step function of this cell.

    train/prefill: a batch dict.  decode: {'token', 'pos'} (the cache comes
    from ``cache_specs``).  No device memory is touched.
    """
    sp = SHAPES[shape_name]
    B, S = sp.global_batch, sp.seq_len
    i32 = jnp.int32
    f = cfg.compute_dtype
    if sp.kind == "train":
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                 "labels": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.family == "vlm":
            batch["patch_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.patch_tokens, cfg.d_model), f)
        if cfg.family == "audio":
            batch["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_frames, cfg.d_model), f)
        return batch
    if sp.kind == "prefill":
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.family == "vlm":
            batch["patch_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.patch_tokens, cfg.d_model), f)
        if cfg.family == "audio":
            batch["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_frames, cfg.d_model), f)
        return batch
    return {"token": jax.ShapeDtypeStruct((B, 1), i32),
            "pos": jax.ShapeDtypeStruct((), i32)}


def cache_specs(cfg: ArchConfig, shape_name: str) -> dict:
    """ShapeDtypeStructs of the KV cache / SSM state for decode cells."""
    from repro.models import get_model
    sp = SHAPES[shape_name]
    api = get_model(cfg)
    return jax.eval_shape(lambda: api.make_cache(sp.global_batch, sp.seq_len))


def param_specs(cfg: ArchConfig) -> dict:
    from repro.models import get_model
    api = get_model(cfg)
    return jax.eval_shape(api.init, jax.random.key(0))


# ---------------------------------------------------------------------------
# Workload profiles for the planner (per-layer FLOPs / boundary bytes)
# ---------------------------------------------------------------------------

def _attn_layer_flops(cfg: ArchConfig, seq: int) -> float:
    hd = cfg.head_dim
    qkv = 2 * cfg.d_model * (cfg.n_heads + 2 * cfg.n_kv) * hd
    out = 2 * cfg.n_heads * hd * cfg.d_model
    scores = 2 * 2 * cfg.n_heads * hd * (seq / 2)   # causal average
    return float((qkv + out + scores) * seq)


def _ffn_layer_flops(cfg: ArchConfig, seq: int) -> float:
    if cfg.moe_experts:
        per_tok = (cfg.moe_top_k * cfg.ffn_mult * 2 * cfg.d_model * cfg.d_ff
                   + 2 * cfg.d_model * cfg.moe_experts)
    else:
        per_tok = cfg.ffn_mult * 2 * cfg.d_model * cfg.d_ff
    return float(per_tok * seq)


def _mamba_layer_flops(cfg: ArchConfig, seq: int) -> float:
    from repro.models.mamba import d_inner, dt_rank
    di, ds, dtr = d_inner(cfg), cfg.mamba_d_state, dt_rank(cfg)
    per_tok = (2 * cfg.d_model * 2 * di + 2 * di * (dtr + 2 * ds)
               + 2 * dtr * di + 10 * di * ds + 2 * di * cfg.d_model)
    return float(per_tok * seq)


def _rwkv_layer_flops(cfg: ArchConfig, seq: int) -> float:
    d, ff, hd = cfg.d_model, cfg.d_ff, cfg.rwkv_head_dim
    per_tok = (5 * 2 * d * d        # r/k/v/g/o projections
               + 2 * d * 64 * 2     # decay LoRA
               + 4 * d * hd         # WKV state update + readout
               + 2 * 2 * d * ff + 2 * d * d)   # channel mix
    return float(per_tok * seq)


def arch_profile(cfg: ArchConfig, shape_name: str = "train_4k",
                 dtype_bytes: int = 2, optimizer_mult: float | None = None
                 ) -> ModelProfile:
    """Per-layer (embedding + blocks + head) profile for the MSP planner.

    ``optimizer_mult`` (sigma bytes per param byte): None picks the same
    policy as the trainer — AdamW (2.0 = 8 B/param) below 100B params,
    Adafactor (~0.025) above (launch/steps.py).
    """
    if optimizer_mult is None:
        probe = arch_profile(cfg, shape_name, dtype_bytes, 2.0)
        n = float(probe.param_cum()[-1]) / 4.0
        optimizer_mult = 0.025 if n >= 100e9 else 2.0
    seq = SHAPES[shape_name].seq_len
    act = float(cfg.d_model * seq * dtype_bytes)
    fp, bp, acts, grads, params, opt = [], [], [], [], [], []

    def add(flops, pbytes, a=act):
        fp.append(flops)
        bp.append(2.0 * flops)
        acts.append(a)
        grads.append(a)
        params.append(float(pbytes))
        opt.append(float(pbytes) * optimizer_mult)

    pd = 4  # param bytes (fp32 masters)
    add(1e6, cfg.vocab * cfg.d_model * pd)          # embedding
    for i in range(cfg.num_layers):
        kind = cfg.layer_kind(i)
        if kind == "attn":
            fl = _attn_layer_flops(cfg, seq)
            pb = (cfg.n_heads + 2 * cfg.n_kv) * cfg.head_dim * cfg.d_model * pd * 2
        elif kind == "mamba":
            fl = _mamba_layer_flops(cfg, seq)
            from repro.models.mamba import d_inner
            pb = 3 * cfg.d_model * d_inner(cfg) * pd
        else:  # rwkv
            fl = _rwkv_layer_flops(cfg, seq)
            pb = 6 * cfg.d_model * cfg.d_model * pd
        if kind != "rwkv":
            if cfg.is_moe_layer(i):
                fl += _ffn_layer_flops(cfg, seq)
                pb += cfg.moe_experts * cfg.ffn_mult * cfg.d_model * cfg.d_ff * pd
            else:
                fl += _ffn_layer_flops(
                    dataclasses.replace(cfg, moe_experts=0), seq)
                pb += cfg.ffn_mult * cfg.d_model * cfg.d_ff * pd
        else:
            pb += 2 * cfg.d_model * cfg.d_ff * pd
        add(fl, pb)
    add(2.0 * cfg.d_model * cfg.vocab * seq,
        cfg.vocab * cfg.d_model * pd,
        a=float(cfg.vocab * seq * dtype_bytes))     # head
    return ModelProfile(
        name=cfg.name, fp_work=np.array(fp), bp_work=np.array(bp),
        act_bytes=np.array(acts), grad_bytes=np.array(grads),
        param_bytes=np.array(params), opt_bytes=np.array(opt))


def count_params(cfg: ArchConfig) -> int:
    prof = arch_profile(cfg)
    return int(prof.param_cum()[-1] // 4)
