"""internvl2-1b [vlm] — 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655; InternViT + Qwen2-class LM backbone.  [arXiv:2404.16821; hf]
The ViT frontend is a STUB: input_specs() provides precomputed patch
embeddings (B, 256, d_model) prepended to the token stream."""

import dataclasses

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-1b", family="vlm",
    num_layers=24, d_model=896, n_heads=14, n_kv=2, d_ff=4864,
    vocab=151655, d_head=64, qk_norm=False, qkv_bias=True,
    tie_embeddings=True, ffn_mult=3, rope_theta=1e6,
    patch_tokens=256,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="internvl2-1b-reduced", num_layers=2, d_model=64,
        n_heads=4, n_kv=2, d_head=16, d_ff=128, vocab=384, patch_tokens=8)
