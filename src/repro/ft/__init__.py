"""Fault tolerance & elasticity: failure detection -> BCD re-plan -> resume,
straggler mitigation via Theorem-1 micro-batch re-solving, and pluggable
replanning *policies* (debounce, rate-limiting, cadence, tail-risk
pre-spill) deciding when the coordinator should act at all."""

from .coordinator import (Coordinator, NodeFailure, RateChange, Straggler,
                          Resync, ReplanOutcome)
from .policy import (PolicyDecision, ReplanPolicy, Eager, RideOut, Periodic,
                     Hysteresis, RateLimited, CVaRPreSpill,
                     resolve_replan_policy, event_deviation, net_deviation,
                     PolicyEvalReport, evaluate_policies)
from .adaptive import (DriftEstimator, AdaptiveCadence, TuneResult,
                       default_tuning_grid, tune_policies, network_signature,
                       clear_tune_cache)

__all__ = ["Coordinator", "NodeFailure", "RateChange", "Straggler",
           "Resync", "ReplanOutcome",
           "PolicyDecision", "ReplanPolicy", "Eager", "RideOut", "Periodic",
           "Hysteresis", "RateLimited", "CVaRPreSpill",
           "resolve_replan_policy", "event_deviation", "net_deviation",
           "PolicyEvalReport", "evaluate_policies",
           "DriftEstimator", "AdaptiveCadence", "TuneResult",
           "default_tuning_grid", "tune_policies", "network_signature",
           "clear_tune_cache"]
