"""Fault tolerance & elasticity: failure detection -> BCD re-plan -> resume,
and straggler mitigation via Theorem-1 micro-batch re-solving."""

from .coordinator import (Coordinator, NodeFailure, RateChange, Straggler,
                          ReplanOutcome)

__all__ = ["Coordinator", "NodeFailure", "RateChange", "Straggler",
           "ReplanOutcome"]
