"""Replanning policies — *when* should the elastic coordinator act at all?

``repro.ft.Coordinator`` turns the paper's Algorithm 2 into a runtime:
every event (rate change, straggler, node failure) triggers a BCD re-solve.
That is the right reflex for a one-shot failure, but production event
streams are *noisy*: a flapping link emits a rate-change per square-wave
edge, capacity drift emits a measurement per sampling tick, and each eager
replan costs solve time, a pipeline restart (in-flight micro-batches are
discarded), and possibly a checkpoint restore.  Replanning frequency is a
resource to budget, not a free action.

A :class:`ReplanPolicy` sits between event arrival and the solve: the
coordinator's ``deliver`` consults ``decide(event, time, coord)`` and either
**replans** (``Coordinator.apply`` — the eager path) or **absorbs** the
event (``Coordinator.absorb`` — the network still mutates, the incumbent
plan rides out, indices remapped across failures; absorption escalates to a
forced replan when riding out is impossible).  After every outcome the
policy's ``observe`` hook sees what happened, which is where rate-limit
budgets and backoff state live.

The zoo:

* :class:`Eager` — replan on every event (the historical behavior).
* :class:`RideOut` — never replan voluntarily; absorb everything.
* :class:`Periodic` — replan at most once per ``cadence`` simulated
  seconds (the ROADMAP's trace-driven replanning-cadence knob; sweep it
  with ``benchmarks/bench_ft_policy.py``).
* :class:`Hysteresis` — debounced triggers: per-resource *cumulative*
  log-deviation since the last replan; below ``threshold`` is absorbed,
  above it arms a pending replan that only fires once the deviation has
  **persisted** for ``cooldown`` seconds (trailing-edge debounce, so a
  flapping link is suppressed), and a reversal (the link recovers, the
  cumulative deviation returns inside the band) *cancels* the pending
  replan.
* :class:`RateLimited` — wraps any inner policy with a token-bucket
  replan budget whose refill period backs off exponentially while
  consecutive replans fail to beat riding out by ``margin`` — replan
  storms degrade gracefully to ride-out instead of thrashing.
* :class:`CVaRPreSpill` — tail-risk watchdog: score the incumbent's
  CVaR on the post-event network (``repro.sim.robustness``) and
  pre-migrate to the ``RobustMakespan``-preferred placement when the
  scored tail exceeds ``bound x`` the incumbent's nominal latency.

>>> p = Hysteresis(threshold=0.25, cooldown=1.0)
>>> p.name
'hysteresis'
>>> resolve_replan_policy("eager").name
'eager'
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro import obs

__all__ = ["PolicyDecision", "ReplanPolicy", "Eager", "RideOut", "Periodic",
           "Hysteresis", "RateLimited", "CVaRPreSpill",
           "resolve_replan_policy", "event_deviation", "net_deviation",
           "PolicyEvalReport", "evaluate_policies"]


@dataclasses.dataclass(frozen=True)
class PolicyDecision:
    """What the policy chose for one delivered event.

    ``replan=True`` routes to ``Coordinator.apply`` (full treatment:
    BCD/Theorem-1 solve, ride-out comparison); ``False`` routes to
    ``Coordinator.absorb`` (mutate the network, keep the incumbent plan).
    ``cost_model`` optionally overrides the coordinator's cost model for
    *this* replan only — how :class:`CVaRPreSpill` solves with the
    tail-risk objective while the steady state keeps the cheap one.
    """
    replan: bool
    reason: str
    cost_model: object = None

    @staticmethod
    def do_replan(reason: str, cost_model=None) -> "PolicyDecision":
        return PolicyDecision(True, reason, cost_model)

    @staticmethod
    def absorb(reason: str) -> "PolicyDecision":
        return PolicyDecision(False, reason)


def event_deviation(event) -> tuple:
    """``(key, signed_log_deviation)`` of one ft event — the hysteresis
    coordinate system.  Capacity *drops* are negative (a rate-change factor
    ``f`` contributes ``ln f``; a straggler slowdown ``s`` contributes
    ``-ln s``), so a flap's down/up edges cancel to ~0 cumulative
    deviation.  Node failures are topological, not a magnitude: ``inf``.

    >>> from repro.ft.coordinator import RateChange, Straggler
    >>> key, d = event_deviation(RateChange(0, 2, 0.5))
    >>> key, round(d, 4)
    (('link', 0, 2), -0.6931)
    >>> event_deviation(Straggler(1, 2.0))[1] < 0
    True
    """
    from .coordinator import NodeFailure, RateChange, Resync, Straggler
    if isinstance(event, RateChange):
        if event.factor <= 0:
            return ("link", event.n_from, event.n_to), -math.inf
        return ("link", event.n_from, event.n_to), math.log(event.factor)
    if isinstance(event, Straggler):
        if event.slowdown <= 0:
            return ("node", event.node), math.inf
        return ("node", event.node), -math.log(event.slowdown)
    if isinstance(event, NodeFailure):
        return ("failure", event.server), -math.inf
    if isinstance(event, Resync):
        return ("resync",), 0.0          # magnitude computed vs a reference
    return ("other", type(event).__name__), -math.inf


def net_deviation(ref, net) -> float:
    """Largest absolute log capacity ratio between two same-shape networks
    — the magnitude of a ``Resync`` measurement snapshot.  The deviation
    coordinate :class:`Hysteresis` measures snapshots in, and the increment
    ``repro.ft.adaptive.DriftEstimator`` accumulates drift rates from."""
    if ref is None or len(ref.nodes) != len(net.nodes):
        return math.inf
    dev = 0.0
    for a, b in zip(ref.nodes, net.nodes):
        if a.f > 0 and b.f > 0:
            dev = max(dev, abs(math.log(b.f / a.f)))
        elif a.f != b.f:
            return math.inf
    pos = (ref.rate > 0) & (net.rate > 0)
    if np.any(pos):
        dev = max(dev, float(np.max(np.abs(
            np.log(net.rate[pos] / ref.rate[pos])))))
    if np.any((ref.rate > 0) != (net.rate > 0)):
        return math.inf
    return dev


class ReplanPolicy:
    """Decision seam between event arrival and ``Coordinator.apply``.

    ``decide`` is consulted by ``Coordinator.deliver`` *before* the event
    mutates anything; ``observe`` runs after the outcome (replan, absorb,
    or an absorb escalated to a forced replan) so budget/backoff/reference
    state tracks what actually happened.  Policies are stateful and
    single-coordinator: use one instance per coordinator.
    """

    name = "abstract"

    def decide(self, event, time: float, coord) -> PolicyDecision:
        raise NotImplementedError

    def observe(self, outcome, time: float) -> None:
        """Called after every delivered event with the ``ReplanOutcome``."""

    def reset(self) -> None:
        """Drop accumulated state (new coordinator / new run)."""

    def __repr__(self):
        return f"{type(self).__name__}()"


class Eager(ReplanPolicy):
    """Replan on every event — the historical ``Coordinator.apply``
    behavior, now spelled as the trivial policy."""

    name = "eager"

    def decide(self, event, time, coord) -> PolicyDecision:
        return PolicyDecision.do_replan("eager")


class RideOut(ReplanPolicy):
    """Never replan voluntarily: absorb every event and keep the incumbent
    plan (the coordinator still escalates to a forced replan when riding
    out is impossible, e.g. the failed server hosted a stage)."""

    name = "ride_out"

    def decide(self, event, time, coord) -> PolicyDecision:
        return PolicyDecision.absorb("ride-out")


class Periodic(ReplanPolicy):
    """Replan at most once per ``cadence`` simulated seconds; absorb
    in-between.  With a stream of periodic ``Resync`` measurement
    snapshots this *is* the ROADMAP's replanning-cadence knob: small
    cadences track drift closely but pay solve/restart downtime per
    replan, large cadences ride out staleness."""

    name = "periodic"

    def __init__(self, cadence: float):
        if cadence < 0:
            raise ValueError("cadence must be >= 0")
        self.cadence = cadence
        self._last = -math.inf

    def decide(self, event, time, coord) -> PolicyDecision:
        from .coordinator import NodeFailure
        if isinstance(event, NodeFailure):
            return PolicyDecision.do_replan("periodic: node failure")
        if time - self._last >= self.cadence:
            return PolicyDecision.do_replan(
                f"periodic: cadence {self.cadence:g} elapsed")
        return PolicyDecision.absorb("periodic: inside cadence window")

    def observe(self, outcome, time) -> None:
        if outcome.action in ("replan", "microbatch"):
            self._last = time

    def reset(self) -> None:
        self._last = -math.inf

    def __repr__(self):
        return f"Periodic(cadence={self.cadence!r})"


class Hysteresis(ReplanPolicy):
    """Debounced triggers with reversal detection (see module docstring).

    State per resource key (a link or a node): the *cumulative* signed log
    deviation of its capacity since the last adopted replan.  An event
    whose key stays inside ``[-threshold, +threshold]`` is absorbed
    outright (and cancels any pending replan on that key — reversal
    detection: a recovered link un-arms the trigger).  Crossing the
    threshold arms a pending replan stamped with the crossing time; the
    replan fires at the first delivered event (any key) once the deviation
    has persisted ``cooldown`` seconds — trailing-edge debounce, so a link
    flapping faster than its own recovery never fires.  Node failures
    replan immediately (topology changed; per-index state is invalidated
    by the renumbering and dropped).

    ``Resync`` snapshots are measured against the network the incumbent
    plan was last solved for: the largest per-resource log capacity ratio
    is the deviation, under the same arm/persist/cancel mechanics.
    """

    name = "hysteresis"

    def __init__(self, threshold: float = 0.25, cooldown: float = 0.0):
        if threshold <= 0:
            raise ValueError("threshold must be > 0 (log-ratio units)")
        if cooldown < 0:
            raise ValueError("cooldown must be >= 0")
        self.threshold = threshold
        self.cooldown = cooldown
        self._dev: dict = {}         # key -> cumulative signed log deviation
        self._pending: dict = {}     # key -> time the deviation crossed
        self._ref_net = None         # Resync reference (last replanned-for)

    def decide(self, event, time, coord) -> PolicyDecision:
        from .coordinator import NodeFailure, Resync
        if isinstance(event, NodeFailure):
            return PolicyDecision.do_replan("hysteresis: node failure")
        key, delta = event_deviation(event)
        if isinstance(event, Resync):
            ref = self._ref_net if self._ref_net is not None else coord.net
            dev = net_deviation(ref, event.net)
        else:
            self._dev[key] = self._dev.get(key, 0.0) + delta
            dev = abs(self._dev[key])
        if dev < self.threshold:
            if key in self._pending:
                del self._pending[key]
                obs.inc("ft.policy.reversals")
                return self._or_matured(
                    time, "hysteresis: reversal cancelled pending replan")
            return self._or_matured(time, "hysteresis: below threshold")
        armed = self._pending.setdefault(key, time)
        if time - armed >= self.cooldown:
            return PolicyDecision.do_replan(
                f"hysteresis: deviation {dev:.3g} persisted >= "
                f"cooldown on {key}")
        return self._or_matured(
            time, f"hysteresis: deviation {dev:.3g} inside "
                  f"flap-suppression window on {key}")

    def _or_matured(self, time: float, absorb_reason: str) -> PolicyDecision:
        """Absorb — unless some *other* armed key's deviation has now
        persisted past the cooldown, in which case fire its replan (the
        only chance a deferred trigger gets is a later delivery)."""
        for key, armed in self._pending.items():
            if time - armed >= self.cooldown:
                return PolicyDecision.do_replan(
                    f"hysteresis: deferred replan matured on {key}")
        return PolicyDecision.absorb(absorb_reason)

    def observe(self, outcome, time) -> None:
        from .coordinator import NodeFailure, Resync
        if isinstance(outcome.event, NodeFailure):
            # degraded() renumbered every node/link index: per-key state
            # would silently track the wrong resources
            self.reset()
            return
        if outcome.action in ("replan", "microbatch"):
            self._dev.clear()
            self._pending.clear()
            if isinstance(outcome.event, Resync):
                self._ref_net = outcome.event.net

    def reset(self) -> None:
        self._dev.clear()
        self._pending.clear()
        self._ref_net = None

    def __repr__(self):
        return (f"Hysteresis(threshold={self.threshold!r}, "
                f"cooldown={self.cooldown!r})")


class RateLimited(ReplanPolicy):
    """Token-bucket replan budget with exponential backoff, wrapping any
    inner policy.

    The bucket holds up to ``capacity`` replans and refills one token per
    ``refill_period`` simulated seconds.  When the inner policy asks to
    replan with an empty bucket, the event is absorbed instead (ride-out),
    so replan storms cost a bounded number of solves.  *Backoff*: each
    adopted replan whose improvement over riding out is below ``margin``
    (relative) counts as unhelpful; the effective refill period is
    ``refill_period * backoff ** consecutive_unhelpful`` (capped at
    ``max_backoff`` doublings), and one helpful replan resets it — a storm
    of no-gain replans degrades the budget toward pure ride-out instead of
    thrashing, and recovers as soon as replanning pays again.

    Forced replans (an absorb the coordinator escalated because riding out
    was impossible) do not consume tokens — the budget gates *voluntary*
    solves only.
    """

    name = "rate_limited"

    def __init__(self, inner: ReplanPolicy, *, capacity: float = 2.0,
                 refill_period: float = 1.0, backoff: float = 2.0,
                 margin: float = 0.02, max_backoff: int = 8):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        if refill_period <= 0 or backoff < 1:
            raise ValueError("need refill_period > 0 and backoff >= 1")
        self.inner = inner
        self.capacity = float(capacity)
        self.refill_period = float(refill_period)
        self.backoff = float(backoff)
        self.margin = float(margin)
        self.max_backoff = int(max_backoff)
        self._tokens = float(capacity)
        self._last_refill = 0.0
        self._unhelpful = 0
        self._charged = False        # did the last decide spend a token?

    @property
    def effective_refill_period(self) -> float:
        return self.refill_period * \
            self.backoff ** min(self._unhelpful, self.max_backoff)

    def _refill(self, time: float) -> None:
        dt = max(0.0, time - self._last_refill)
        self._tokens = min(self.capacity,
                           self._tokens + dt / self.effective_refill_period)
        self._last_refill = time

    def decide(self, event, time, coord) -> PolicyDecision:
        self._refill(time)
        self._charged = False
        d = self.inner.decide(event, time, coord)
        if not d.replan:
            return d
        if self._tokens < 1.0:
            obs.inc("ft.policy.rate_limited")
            return PolicyDecision.absorb(
                f"rate-limited: bucket empty (refill every "
                f"{self.effective_refill_period:.3g}s after "
                f"{self._unhelpful} unhelpful replans) [{d.reason}]")
        self._tokens -= 1.0
        self._charged = True
        return d

    def observe(self, outcome, time) -> None:
        self.inner.observe(outcome, time)
        if outcome.action not in ("replan", "microbatch"):
            return
        if not self._charged:
            return                   # forced escalation: not budgeted
        ride = outcome.ride_out_latency
        if ride is None:
            return                   # no ride-out was scored: can't judge
        # an impossible ride-out (inf) means the replan was *necessary* —
        # that is the budget working as intended, not thrash
        helpful = (not math.isfinite(ride)
                   or outcome.new_latency <= ride * (1.0 - self.margin))
        if helpful:
            self._unhelpful = 0
        else:
            self._unhelpful += 1
            obs.inc("ft.policy.backoff_steps")

    def reset(self) -> None:
        self.inner.reset()
        self._tokens = self.capacity
        self._last_refill = 0.0
        self._unhelpful = 0
        self._charged = False

    def __repr__(self):
        return (f"RateLimited({self.inner!r}, capacity={self.capacity!r}, "
                f"refill_period={self.refill_period!r}, "
                f"backoff={self.backoff!r}, margin={self.margin!r})")


class CVaRPreSpill(ReplanPolicy):
    """Pre-migrate when the incumbent's *tail* goes bad, even if its mean
    is fine.

    On each event, score the incumbent plan's tail risk on the post-event
    network with ``repro.sim.robustness.RobustMakespan`` (a seeded, cached
    fuzzed scenario distribution).  If the scored risk exceeds ``bound x``
    the incumbent's nominal (closed-form) latency, the event is escalated
    to a replan **solved under the robust objective** — the BCD then
    prefers the tail-safe placement, i.e. the coordinator pre-spills to
    where the ``RobustMakespan`` planner would have put it.  Otherwise the
    event is absorbed.  Node failures always replan (robustly).
    """

    name = "cvar_pre_spill"

    def __init__(self, *, bound: float = 1.5, n_scenarios: int = 6,
                 alpha: float = 0.9, seed: int = 0,
                 risk_aversion: float = 1.0):
        if bound <= 0:
            raise ValueError("bound must be > 0")
        from repro.sim.robustness import RobustMakespan  # deferred: sim dep
        self.bound = bound
        self.robust = RobustMakespan(n_scenarios=n_scenarios, alpha=alpha,
                                     seed=seed, risk_aversion=risk_aversion)

    def decide(self, event, time, coord) -> PolicyDecision:
        from .coordinator import Coordinator, NodeFailure
        if isinstance(event, NodeFailure):
            return PolicyDecision.do_replan("pre-spill: node failure",
                                            cost_model=self.robust)
        # memoized preview: repeated decides on the same flap reuse one
        # Planner per previewed network identity (ISSUE 9 satellite)
        net, sol, _pl = coord.preview_cached(coord.plan.solution, event)
        if sol is None:
            return PolicyDecision.do_replan("pre-spill: incumbent displaced",
                                            cost_model=self.robust)
        try:
            nominal = coord.cost_model.evaluate(coord.profile, net, sol,
                                                coord.plan.b, coord.B)
            tail = self.robust.evaluate(coord.profile, net, sol,
                                        coord.plan.b, coord.B)
        except (ValueError, ArithmeticError):
            coord.eval_errors += 1
            obs.inc("ft.eval_errors")
            return PolicyDecision.do_replan("pre-spill: incumbent unscorable",
                                            cost_model=self.robust)
        if not math.isfinite(tail) or (math.isfinite(nominal) and nominal > 0
                                       and tail > self.bound * nominal):
            obs.inc("ft.policy.pre_spills")
            return PolicyDecision.do_replan(
                f"pre-spill: incumbent tail {tail:.4g} > "
                f"{self.bound:g} x nominal {nominal:.4g}",
                cost_model=self.robust)
        return PolicyDecision.absorb(
            f"pre-spill: incumbent tail {tail:.4g} within "
            f"{self.bound:g} x nominal {nominal:.4g}")

    def __repr__(self):
        return f"CVaRPreSpill(bound={self.bound!r}, robust={self.robust!r})"


# ---------------------------------------------------------------------------
# Policy evaluation harness: replay fuzzed event streams under each policy
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PolicyEvalReport:
    """One policy's aggregate over a corpus of replayed event streams.

    ``makespans`` are end-to-end (they already include the per-replan
    solve + restore + remap downtime ``simulate_with_replanning`` charges);
    ``final_objectives`` are each run's closing ``plan.objective`` — the
    latency the deployment is left with once the stream ends (the
    corpus-level guarantee is Hysteresis <= RideOut here, since absorbs
    escalate whenever riding out is impossible and every kept incumbent is
    re-priced)."""
    policy: str
    makespans: tuple
    final_objectives: tuple
    replans: int                 # replans actually issued across the corpus
    suppressed: int              # events absorbed without a solve
    downtime: float              # total solve + restore + remap seconds
    blocked: dict | None = None  # resource -> mean blocked seconds/stream
    alpha: float = 0.9
    eval_errors: int = 0         # expected-infeasibility evals swallowed
    #                              (Coordinator.eval_errors, summed) — a
    #                              policy silently failing its evals is
    #                              visible here, not just in the obs registry

    @property
    def mean(self) -> float:
        return float(np.mean(self.makespans))

    @property
    def cvar(self) -> float:
        from repro.sim.robustness import cvar
        return cvar(self.makespans, self.alpha)

    def row(self) -> dict:
        return {"policy": self.policy, "mean": self.mean, "cvar": self.cvar,
                "replans": self.replans, "suppressed": self.suppressed,
                "downtime": self.downtime,
                "eval_errors": self.eval_errors,
                "mean_final_objective":
                    float(np.mean(self.final_objectives))}


def evaluate_policies(profile, net, B: int, streams, policies, *,
                      remap_penalty: float = 0.0,
                      solve_downtime: float | str = 0.0,
                      alpha: float = 0.9, engine: str = "event",
                      attribution: bool = False,
                      **coordinator_kwargs) -> dict:
    """Replay each event ``stream`` (tuples of ``sim.ReplanTrigger``, e.g.
    from ``sim.fuzz_event_stream``) through
    ``sim.simulate_with_replanning`` under every policy and aggregate a
    :class:`PolicyEvalReport` per policy — the policy-search harness behind
    ``benchmarks/bench_ft_policy.py``.

    ``policies`` maps name -> *factory* (zero-arg callable returning a
    fresh :class:`ReplanPolicy` or ``None`` for eager): policies are
    stateful, so every stream must get its own instance.  A non-callable
    string value is resolved per stream via :func:`resolve_replan_policy`.
    ``attribution=True`` additionally aggregates per-resource blocked
    seconds from every segment's utilization decomposition."""
    from repro.ft.coordinator import Coordinator
    from repro.sim.engine import simulate_with_replanning
    streams = [tuple(s) for s in streams]
    out = {}
    for name, factory in policies.items():
        makespans, finals = [], []
        replans = suppressed = eval_errors = 0
        downtime = 0.0
        blocked: dict = {}
        for stream in streams:
            pol = factory() if callable(factory) else \
                resolve_replan_policy(factory)
            coord = Coordinator(profile, net, B, policy=pol,
                                **coordinator_kwargs)
            with obs.span("ft.policy.eval", policy=name):
                rep = simulate_with_replanning(
                    profile, net, B, stream, coordinator=coord,
                    remap_penalty=remap_penalty,
                    solve_downtime=solve_downtime, engine=engine)
            makespans.append(rep.makespan)
            finals.append(coord.plan.objective)
            replans += rep.num_replans
            suppressed += rep.num_suppressed
            downtime += rep.downtime
            eval_errors += coord.eval_errors
            if attribution:
                for seg in rep.segments:
                    u = seg.report.utilization()
                    for res, ru in u.resources.items():
                        blocked[res] = blocked.get(res, 0.0) + ru.blocked
        if attribution and streams:
            blocked = {r: t / len(streams) for r, t in blocked.items()}
        out[name] = PolicyEvalReport(
            policy=name, makespans=tuple(makespans),
            final_objectives=tuple(finals), replans=replans,
            suppressed=suppressed, downtime=downtime,
            blocked=(blocked if attribution else None), alpha=alpha,
            eval_errors=eval_errors)
    return out


_NAMED = {
    "eager": Eager,
    "ride_out": RideOut,
    "rideout": RideOut,
    "hysteresis": Hysteresis,
}


def resolve_replan_policy(policy) -> ReplanPolicy | None:
    """``None`` passes through (the coordinator treats it as eager);
    strings name zero-argument zoo members; instances pass through.
    (Named after ``repro.sim.resolve_policy``, which resolves *admission*
    policies — a different seam.)"""
    if policy is None or isinstance(policy, ReplanPolicy):
        return policy
    if isinstance(policy, str):
        if policy.lower() == "adaptive":     # lazy: adaptive imports us
            from repro.ft.adaptive import AdaptiveCadence
            return AdaptiveCadence()
        try:
            return _NAMED[policy.lower()]()
        except KeyError:
            raise ValueError(
                f"unknown replan policy {policy!r}; named policies: "
                f"{sorted(set(_NAMED) | {'adaptive'})}") from None
    raise TypeError(f"expected a ReplanPolicy, name, or None, got "
                    f"{policy!r}")
