"""Adaptive robustness: self-tuning replan cadence and online policy search.

``benchmarks/bench_ft_policy.py``'s cadence-vs-CV frontier showed the best
fixed :class:`~repro.ft.policy.Periodic` cadence shifts with the drift
regime: slow Gauss-Markov drift wants long cadences (solves are pure
overhead), fast drift wants short ones (staleness dominates).  Picking the
cadence therefore requires offline tuning per deployment — exactly the
manual knob this module removes.

Two layers:

* :class:`DriftEstimator` + :class:`AdaptiveCadence` — estimate the
  network's *drift rate* online from the cumulative **signed**
  log-deviation level the event stream already carries (the
  ``event_deviation`` coordinate ``Hysteresis`` debounces in) and set the
  ``Periodic`` cadence from the classic drift-vs-fixed-cost balance.  If
  capacity log-deviation grows ~linearly at rate ``r`` (log-units/s) and a
  stale plan costs ``staleness_weight * deviation`` in relative latency,
  the staleness cost accrued over a window ``tau`` is ``w r tau^2 / 2``
  while each window pays one ``solve_cost`` — minimizing their sum per
  unit time gives the square-root rule
  ``tau* = sqrt(2 solve_cost / (w r))``.  Two details make this robust to
  the regimes the frontier sweeps: increments are *signed*, so a flap's
  down/up edges and mean-reverting Gauss-Markov fluctuation cancel instead
  of masquerading as drift; and the EWMA rate only counts once it clears
  ``z x`` its own standard error (tracked by a companion variance EWMA), so
  bounded noise reads as rate 0 (ride out) while a persistent trend
  switches the square-root cadence on.  The policy re-evaluates ``tau*``
  at every delivered event, so one deployment tracks the frontier across
  regimes with no per-regime tuning.

* :func:`tune_policies` — successive-halving search over a grid of
  Hysteresis / RateLimited / AdaptiveCadence knobs, driven by
  :func:`repro.ft.policy.evaluate_policies` on fuzzed event-stream corpora
  (``repro.sim.fuzz_event_stream``).  Rounds replay geometrically growing
  stream batches, prune by CVaR-blended confidence bounds, and cache the
  winner per network signature so repeated tuning on the same deployment
  is free.

>>> est = DriftEstimator(halflife=1.0)
>>> for t in range(8):              # a consistent 0.2 log-dev/s ramp...
...     _ = est.observe(0.2 * t, float(t))
>>> round(est.rate, 2)              # ...reads as significant drift
0.2
>>> est2 = DriftEstimator(halflife=1.0)
>>> for t in range(8):              # a flapping level has no net drift
...     _ = est2.observe(0.3 * (t % 2), float(t))
>>> est2.rate
0.0
>>> p = AdaptiveCadence(solve_cost=0.05, staleness_weight=1.0)
>>> p.cadence                       # no drift observed yet -> ride out
inf
>>> p.estimator = est               # drifting at 0.2/s:
>>> 0.5 < p.cadence < 0.9           # ~sqrt(2 * 0.05 / (1.0 * 0.2)) = 0.71
True
"""

from __future__ import annotations

import dataclasses
import hashlib
import math

import numpy as np

from repro import obs
from repro.ft.policy import (Hysteresis, PolicyDecision, RateLimited,
                             ReplanPolicy, evaluate_policies,
                             event_deviation)

__all__ = ["DriftEstimator", "AdaptiveCadence", "TuneResult",
           "default_tuning_grid", "tune_policies", "network_signature",
           "clear_tune_cache"]


class DriftEstimator:
    """Significance-gated EWMA drift-rate estimator over the cumulative
    *signed* log-deviation level.

    Each observation is the current cumulative signed deviation ``level``
    (log units — the coordinate :func:`repro.ft.policy.event_deviation`
    measures in) at a simulated time; the rate sample is the signed
    increment ``(level - prev_level) / dt``.  Two EWMAs with time-aware
    decay (an old estimate loses half its weight every ``halflife``
    seconds) track the sample mean and variance; :attr:`rate` reports the
    mean only when it is *significantly* positive — above ``z x`` the
    EWMA's own standard error.  Mean-reverting fluctuation and flap pairs
    produce zero-mean increments with large variance, so they read as rate
    0 (ride out); a persistent capacity trend produces consistent samples
    that clear the gate.

    ``rebase`` forgets the level reference (call after a replan, when the
    deviation coordinate restarts from the fresh plan) while *keeping* the
    learned rate statistics, so the cadence stays stable across replans.
    Non-finite levels (node failures, topology renumbering) are ignored —
    those are topological events, not drift.
    """

    def __init__(self, halflife: float = 1.0, z: float = 2.0,
                 initial_rate: float = 0.0, min_samples: int = 3):
        if halflife <= 0:
            raise ValueError("halflife must be > 0 (seconds)")
        if z < 0:
            raise ValueError("z must be >= 0 (significance gate)")
        if initial_rate < 0:
            raise ValueError("initial_rate must be >= 0")
        if min_samples < 1:
            raise ValueError("min_samples must be >= 1")
        self.halflife = float(halflife)
        self.z = float(z)
        self.initial_rate = float(initial_rate)
        self.min_samples = int(min_samples)
        self._mean = float(initial_rate)  # EWMA of signed rate samples
        self._var = 0.0                   # EWMA of squared residuals
        self._w2 = 0.0                    # sum of squared EWMA weights
        self._n = 0                       # rate samples folded in
        self._prev: tuple | None = None   # (level, time)

    def observe(self, level: float, time: float) -> float:
        """Fold one cumulative-deviation level in; returns the gated rate."""
        if not math.isfinite(level):
            return self.rate
        prev = self._prev
        self._prev = (float(level), float(time))
        if prev is None:
            return self.rate
        dt = max(float(time) - prev[1], 1e-6 * self.halflife)
        sample = (float(level) - prev[0]) / dt
        w = 0.5 ** (dt / self.halflife)
        self._var = w * self._var + (1.0 - w) * (sample - self._mean) ** 2
        self._mean = w * self._mean + (1.0 - w) * sample
        self._w2 = w * w * self._w2 + (1.0 - w) ** 2
        self._n += 1
        return self.rate

    @property
    def rate(self) -> float:
        """Drift rate (log-dev/s): |EWMA mean| when significantly nonzero,
        else 0 (noise — ride it out).  Two-sided: capacity degrading *or*
        recovering both stale the incumbent plan.  Fewer than
        ``min_samples`` increments is never significant — a single large
        sample (e.g. one flap reversal) can clear any ``z x SE`` bound
        because the variance EWMA is still anchored at its initialization."""
        if self._n < self.min_samples:
            return 0.0
        se = math.sqrt(max(self._var, 0.0) * self._w2)
        m = abs(self._mean)
        return m if m > self.z * se else 0.0

    def rebase(self) -> None:
        """Forget the level reference (the deviation coordinate restarted,
        e.g. after a replan) but keep the learned rate statistics."""
        self._prev = None

    def reset(self) -> None:
        self._mean = self.initial_rate
        self._var = 0.0
        self._w2 = 0.0
        self._n = 0
        self._prev = None

    def __repr__(self):
        return (f"DriftEstimator(halflife={self.halflife!r}, z={self.z!r}, "
                f"rate={self.rate:.4g})")


def _signed_net_deviations(ref, net) -> dict:
    """Per-resource signed log capacity ratios of ``net`` vs ``ref`` — the
    vector form of :func:`repro.ft.policy.net_deviation`, keyed like
    ``event_deviation``.  Empty when shapes differ (renumbered topology)."""
    if ref is None or len(ref.nodes) != len(net.nodes):
        return {}
    out = {}
    for i, (a, b) in enumerate(zip(ref.nodes, net.nodes)):
        if a.f > 0 and b.f > 0:
            out[("node", i)] = math.log(b.f / a.f)
    pos = np.argwhere((ref.rate > 0) & (net.rate > 0))
    for i, j in pos:
        out[("link", int(i), int(j))] = float(
            math.log(net.rate[i, j] / ref.rate[i, j]))
    return out


class AdaptiveCadence(ReplanPolicy):
    """``Periodic`` whose cadence is set online by the square-root rule.

    The cumulative signed deviation level is harvested from the events
    themselves: ``Resync`` measurement snapshots contribute per-resource
    signed log capacity ratios against the snapshot the incumbent was last
    replanned at (:func:`_signed_net_deviations`), and discrete
    ``RateChange`` / ``Straggler`` events accumulate their signed
    ``event_deviation`` per resource — the same coordinate system
    ``Hysteresis`` debounces in.  The level fed to the
    :class:`DriftEstimator` is the worst (largest-|.|) resource's signed
    deviation; its significantly-positive increments are drift, everything
    else is noise.  Node failures replan immediately and invalidate the
    snapshot reference (indices renumber).

    A severe capacity *step* needs no special casing: the jump lands as one
    huge level increment, the estimator's rate spikes, and the cadence
    collapses — the next event replans.  For workloads that cannot afford
    even that one-event delay an optional debounced **step guard** — a
    :class:`~repro.ft.policy.Hysteresis` on the same deviation coordinate
    (``step_threshold`` / ``step_cooldown``, trailing-edge so flaps still
    cancel) — escalates past the estimator.  It is *off* by default
    (``step_threshold=math.inf``): under mean-reverting noise the guard
    trips on transient excursions the estimator correctly rides out
    (AR(1) decorrelation is typically longer than any sane cooldown), and
    the measured cadence frontier is strictly worse with it armed.

    ``solve_cost`` is the expected per-replan downtime in simulated seconds
    (match ``solve_downtime`` + restart cost of the harness);
    ``staleness_weight`` converts drift (log-deviation) into relative
    latency cost.  With no significant drift the cadence clamps to
    ``max_cadence`` (default: ride out).
    """

    name = "adaptive_cadence"

    def __init__(self, *, solve_cost: float = 0.05,
                 staleness_weight: float = 1.0, halflife: float = 1.0,
                 z: float = 2.0, min_cadence: float = 0.0,
                 max_cadence: float = math.inf, initial_rate: float = 0.0,
                 step_threshold: float = math.inf,
                 step_cooldown: float = 0.3):
        if solve_cost <= 0:
            raise ValueError("solve_cost must be > 0 (seconds per replan)")
        if staleness_weight <= 0:
            raise ValueError("staleness_weight must be > 0")
        if min_cadence < 0 or max_cadence < min_cadence:
            raise ValueError("need 0 <= min_cadence <= max_cadence")
        self.solve_cost = float(solve_cost)
        self.staleness_weight = float(staleness_weight)
        self.min_cadence = float(min_cadence)
        self.max_cadence = float(max_cadence)
        self.estimator = DriftEstimator(halflife=halflife, z=z,
                                        initial_rate=initial_rate)
        self.step_threshold = float(step_threshold)
        self.step_cooldown = float(step_cooldown)
        self._guard = None if math.isinf(step_threshold) else \
            Hysteresis(step_threshold, cooldown=step_cooldown)
        self._last_replan = -math.inf
        self._ref_snap = None        # Resync snapshot at the last replan
        self._cum: dict = {}         # key -> cumulative signed log dev
        self._sigs: dict = {}        # last Resync's per-resource signed devs

    @property
    def cadence(self) -> float:
        """Current ``tau* = sqrt(2 c / (w r))``, clamped to the bounds."""
        r = self.estimator.rate
        if r <= 0:
            return self.max_cadence
        tau = math.sqrt(2.0 * self.solve_cost / (self.staleness_weight * r))
        return min(max(tau, self.min_cadence), self.max_cadence)

    def _ingest(self, event, time: float) -> None:
        from .coordinator import Resync
        if isinstance(event, Resync):
            if self._ref_snap is None:
                self._ref_snap = event.net
            self._sigs = _signed_net_deviations(self._ref_snap, event.net)
        else:
            key, d = event_deviation(event)
            if math.isfinite(d):
                self._cum[key] = self._cum.get(key, 0.0) + d
        levels = {**self._cum, **self._sigs}
        level = max(levels.values(), key=abs) if levels else 0.0
        self.estimator.observe(level, time)

    def decide(self, event, time, coord) -> PolicyDecision:
        from .coordinator import NodeFailure
        if isinstance(event, NodeFailure):
            return PolicyDecision.do_replan("adaptive: node failure")
        if self._last_replan == -math.inf:
            # the incumbent was solved at stream start: the first cadence
            # window opens at t = 0, not at the first delivered event
            self._last_replan = 0.0
        self._ingest(event, time)
        if self._guard is not None:
            g = self._guard.decide(event, time, coord)
            if g.replan:
                return PolicyDecision.do_replan(
                    f"adaptive: step guard [{g.reason}]")
        tau = self.cadence
        if time - self._last_replan >= tau:
            return PolicyDecision.do_replan(
                f"adaptive: cadence {tau:.3g}s elapsed "
                f"(drift {self.estimator.rate:.3g}/s)")
        return PolicyDecision.absorb(
            f"adaptive: inside cadence window ({tau:.3g}s)")

    def observe(self, outcome, time) -> None:
        from .coordinator import NodeFailure, Resync
        if self._guard is not None:
            self._guard.observe(outcome, time)
        if outcome.action in ("replan", "microbatch"):
            self._last_replan = time
            obs.inc("ft.adaptive.replans")
            # the deviation coordinate restarts at the fresh plan; the
            # learned drift statistics survive (rebase, not reset)
            self._cum.clear()
            self._sigs.clear()
            self.estimator.rebase()
            if isinstance(outcome.event, Resync):
                self._ref_snap = outcome.event.net
        if isinstance(outcome.event, NodeFailure):
            self._ref_snap = None    # renumbered topology: stale reference
            self._cum.clear()
            self._sigs.clear()
            self.estimator.rebase()

    def reset(self) -> None:
        self.estimator.reset()
        if self._guard is not None:
            self._guard.reset()
        self._last_replan = -math.inf
        self._ref_snap = None
        self._cum.clear()
        self._sigs.clear()

    def __repr__(self):
        return (f"AdaptiveCadence(solve_cost={self.solve_cost!r}, "
                f"staleness_weight={self.staleness_weight!r}, "
                f"halflife={self.estimator.halflife!r}, "
                f"z={self.estimator.z!r}, "
                f"step_threshold={self.step_threshold!r}, "
                f"step_cooldown={self.step_cooldown!r})")


# ---------------------------------------------------------------------------
# Successive-halving policy search
# ---------------------------------------------------------------------------

def network_signature(net) -> str:
    """Stable short digest of a network's numeric surface — the
    :func:`tune_policies` cache key component, so re-tuning the *same*
    deployment is a lookup while any capacity/memory/topology change
    invalidates it.

    >>> from repro.core.network import make_edge_network
    >>> a = make_edge_network(num_servers=2, seed=0)
    >>> b = make_edge_network(num_servers=2, seed=0)
    >>> network_signature(a) == network_signature(b)
    True
    >>> network_signature(a) == network_signature(
    ...     make_edge_network(num_servers=2, seed=1))
    False
    """
    h = hashlib.sha1()
    rows = [(n.f, n.kappa, n.mem, n.p, n.t0, n.t1, float(n.b_th),
             float(n.is_client)) for n in net.nodes]
    h.update(np.asarray(rows, dtype=np.float64).tobytes())
    h.update(np.ascontiguousarray(net.rate, dtype=np.float64).tobytes())
    return h.hexdigest()[:16]


@dataclasses.dataclass(frozen=True)
class TuneResult:
    """Outcome of one :func:`tune_policies` search.

    ``best`` names the winning config in the grid the caller passed (look
    its factory up there to deploy it); ``knobs`` is the winner's repr —
    the knob settings, human-readable and cacheable.  ``leaderboard`` holds
    ``(name, score, n_streams)`` for every config, sorted best-first, with
    ``n_streams`` the number of corpus streams the config survived to see.
    """
    best: str
    knobs: str
    score: float
    alpha: float
    cvar_weight: float
    leaderboard: tuple
    rounds: tuple                # ((n_configs_alive, n_streams_total), ...)
    signature: str
    from_cache: bool = False

    def row(self) -> dict:
        return {"best": self.best, "knobs": self.knobs, "score": self.score,
                "alpha": self.alpha, "cvar_weight": self.cvar_weight,
                "rounds": [list(r) for r in self.rounds],
                "leaderboard": [list(e) for e in self.leaderboard],
                "signature": self.signature, "from_cache": self.from_cache}


def default_tuning_grid(*, solve_cost: float = 0.05) -> dict:
    """The stock knob grid: Hysteresis thresholds x cooldowns, the
    hand-picked ``RateLimited(Hysteresis(0.25, cooldown=0.3))`` point from
    ``BENCH_ft.json`` (so the tuner can never do worse than it on the
    tuning corpus), and AdaptiveCadence staleness weights.

    >>> g = default_tuning_grid()
    >>> "rate_limited+hyst(0.25,cd=0.3)" in g and len(g) == 10
    True
    """
    grid: dict = {}
    for thr in (0.15, 0.25, 0.4):
        for cd in (0.0, 0.3):
            grid[f"hyst(t={thr:g},cd={cd:g})"] = \
                (lambda t=thr, c=cd: Hysteresis(t, cooldown=c))
    grid["rate_limited+hyst(0.25,cd=0.3)"] = \
        (lambda: RateLimited(Hysteresis(0.25, cooldown=0.3)))
    for w in (0.5, 1.0, 2.0):
        grid[f"adaptive(w={w:g})"] = \
            (lambda k=w: AdaptiveCadence(solve_cost=solve_cost,
                                         staleness_weight=k))
    return grid


_TUNE_CACHE: dict = {}


def clear_tune_cache() -> None:
    _TUNE_CACHE.clear()


def _score_stats(makespans, alpha: float, w: float, z: float) -> tuple:
    """(score, half_width): CVaR-blended score and its normal-approx
    confidence half-width over one config's accumulated makespans."""
    from repro.sim.robustness import cvar
    ms = np.asarray(makespans, dtype=float)
    score = (1.0 - w) * float(np.mean(ms)) + w * cvar(ms, alpha)
    hw = z * float(np.std(ms)) / math.sqrt(len(ms)) if len(ms) > 1 else \
        math.inf
    return score, hw


def tune_policies(profile, net, B: int, streams, *, configs: dict | None =
                  None, alpha: float = 0.9, cvar_weight: float = 0.5,
                  eta: int = 2, min_streams: int = 4, z: float = 1.0,
                  remap_penalty: float = 0.0,
                  solve_downtime: float | str = 0.0,
                  engine: str = "event", cache: bool = True,
                  **coordinator_kwargs) -> TuneResult:
    """Successive-halving knob search over replan-policy configs.

    ``streams`` is a corpus of event streams (``sim.fuzz_event_stream`` /
    ``sim.periodic_resync_triggers`` tuples); ``configs`` maps name ->
    zero-arg policy factory (default :func:`default_tuning_grid`).  Round
    ``r`` replays each surviving config over a geometrically growing
    prefix of the corpus (``min_streams * eta**r`` streams total, new
    streams only — makespans accumulate), scores every survivor with
    ``(1 - cvar_weight) * mean + cvar_weight * CVaR_alpha``, drops configs
    whose score lower-bound clears the best config's upper-bound
    (``z``-sigma normal bounds), then keeps at most ``ceil(alive / eta)``
    of the rest.  Ranking (and the final pick) applies a one-SE parsimony
    rule: configs statistically tied with the best — score within the best
    config's confidence half-width — are ordered by fewest replans per
    stream, so a conservative config is never displaced by a thrasher it
    cannot be distinguished from.  Ends when one config survives or the
    corpus is spent.

    Results are cached per ``(network_signature, knobs, corpus size,
    search params)`` in a module-level table (``cache=False`` bypasses;
    :func:`clear_tune_cache` empties) — counters ``ft.tune.rounds``,
    ``ft.tune.pruned``, ``ft.tune.cache_hits`` trace the search.
    """
    if configs is None:
        sc = solve_downtime if isinstance(solve_downtime, (int, float)) \
            and solve_downtime > 0 else 0.05
        configs = default_tuning_grid(solve_cost=float(sc))
    if not configs:
        raise ValueError("configs must be a non-empty mapping")
    if not 0.0 <= cvar_weight <= 1.0:
        raise ValueError("cvar_weight must be in [0, 1]")
    if eta < 2:
        raise ValueError("eta must be >= 2")
    if min_streams < 1:
        raise ValueError("min_streams must be >= 1")
    streams = [tuple(s) for s in streams]
    if not streams:
        raise ValueError("streams must be a non-empty corpus")

    def _knobs(name):
        f = configs[name]
        return repr(f() if callable(f) else f)

    sig = network_signature(net)
    key = (sig, B, tuple(sorted((n, _knobs(n)) for n in configs)),
           len(streams), alpha, cvar_weight, eta, min_streams, z,
           remap_penalty, repr(solve_downtime), engine,
           repr(sorted(coordinator_kwargs.items())))
    if cache and key in _TUNE_CACHE:
        obs.inc("ft.tune.cache_hits")
        return dataclasses.replace(_TUNE_CACHE[key], from_cache=True)

    alive = dict(configs)
    acc: dict = {name: [] for name in configs}
    seen: dict = {name: 0 for name in configs}
    repl: dict = {name: 0 for name in configs}
    consumed = 0
    rounds = []
    r = 0

    def _rank_key(n, stats):
        # one-SE rule: configs statistically tied with the best (score
        # within the best's confidence half-width) rank by *parsimony* —
        # fewest replans per stream — so a conservative config is never
        # displaced by a noisy thrasher it cannot be distinguished from
        s, _hw = stats[n]
        s_best, hw_best = min(stats.values())
        tied = s <= s_best + hw_best
        rps = repl[n] / max(seen[n], 1)
        return (0, rps, s) if tied else (1, s, s)
    # always run at least one round, even for a single-config grid
    while consumed < len(streams) and (len(alive) > 1 or consumed == 0):
        target = min(len(streams), min_streams * eta ** r)
        r += 1
        batch = streams[consumed:target]
        if batch:
            reports = evaluate_policies(
                profile, net, B, batch, alive, alpha=alpha,
                remap_penalty=remap_penalty, solve_downtime=solve_downtime,
                engine=engine, **coordinator_kwargs)
            for name, rep in reports.items():
                acc[name].extend(rep.makespans)
                seen[name] += len(batch)
                repl[name] += rep.replans
        consumed = target
        obs.inc("ft.tune.rounds")
        stats = {n: _score_stats(acc[n], alpha, cvar_weight, z)
                 for n in alive}
        best_up = min(s + hw for s, hw in stats.values())
        confident = {n for n, (s, hw) in stats.items() if s - hw > best_up}
        ranked = sorted((n for n in alive if n not in confident),
                        key=lambda n: _rank_key(n, stats))
        cap = max(1, math.ceil(len(alive) / eta))
        survivors = set(ranked[:cap])
        dropped = len(alive) - len(survivors)
        if dropped:
            obs.inc("ft.tune.pruned", dropped)
        alive = {n: alive[n] for n in alive if n in survivors}
        rounds.append((len(alive), consumed))

    final = {n: _score_stats(acc[n], alpha, cvar_weight, z)[0]
             for n in acc if acc[n]}
    board = tuple(sorted(((n, s, seen[n]) for n, s in final.items()),
                         key=lambda e: e[1]))
    fstats = {n: _score_stats(acc[n], alpha, cvar_weight, z) for n in alive}
    best = min(alive, key=lambda n: _rank_key(n, fstats))
    result = TuneResult(best=best, knobs=_knobs(best), score=final[best],
                        alpha=alpha, cvar_weight=cvar_weight,
                        leaderboard=board, rounds=tuple(rounds),
                        signature=sig)
    if cache:
        _TUNE_CACHE[key] = result
    return result
