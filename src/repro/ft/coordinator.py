"""Elastic coordinator — the paper's BCD promoted to a runtime feature.

Events:
  NodeFailure(server)  a server drops out -> rebuild the network without it,
                       re-run Algorithm 2 (BCD), remap submodels, resume
                       from the latest checkpoint (params are cut-agnostic:
                       the full model is the unit of state, stages are views)
  RateChange(n,n',f)   a link's measured rate changed by factor f -> replan
  Straggler(stage, f)  a stage's observed compute time inflated by factor f
                       -> first try the cheap fix (Theorem 1: re-solve the
                       micro-batch size against the new bottleneck T_i);
                       only if the predicted gain is small, full re-plan
                       (move a cut across the slow boundary)

Every outcome reports (old_plan, new_plan, predicted latencies) so the
trainer can decide to pause-and-remap or continue — tests assert that the
replanned latency is sane (>= within noise of a from-scratch plan, and the
pipeline stays feasible).
"""

from __future__ import annotations

import dataclasses
import logging
import math
import time

import numpy as np

from repro.core import (EdgeNetwork, ModelProfile, Plan, bcd_solve,
                        optimal_microbatch, total_latency, pipeline_interval,
                        fill_latency, num_fills)
from repro.core.cost_model import resolve_cost_model
from repro.core.shortest_path import Planner
from repro import obs


@dataclasses.dataclass(frozen=True)
class NodeFailure:
    server: int                  # node index in the current network


@dataclasses.dataclass(frozen=True)
class RateChange:
    n_from: int
    n_to: int
    factor: float


@dataclasses.dataclass(frozen=True)
class Straggler:
    node: int
    slowdown: float              # f_n -> f_n / slowdown


@dataclasses.dataclass(frozen=True)
class Resync:
    """A measured capacity snapshot (e.g. ``sim.sampled_network`` at a
    periodic measurement tick).  Replanning against it re-solves on the
    *snapshot* while the coordinator's base network stays untouched — the
    snapshot already folds in whatever scenario multipliers produced it, so
    adopting it as ``coord.net`` would double-apply them when the driving
    simulation re-applies its traces.  Absorbing a Resync is a true no-op:
    nothing mutates, the incumbent rides on."""
    net: EdgeNetwork


logger = logging.getLogger("repro.ft.coordinator")


def _event_key(event):
    """Hashable identity of an event for the preview-planner memo."""
    if isinstance(event, NodeFailure):
        return ("NF", event.server)
    if isinstance(event, RateChange):
        return ("RC", event.n_from, event.n_to, event.factor)
    if isinstance(event, Straggler):
        return ("ST", event.node, event.slowdown)
    if isinstance(event, Resync):
        return ("RS", id(event.net))
    return ("??", id(event))


@dataclasses.dataclass
class ReplanOutcome:
    event: object
    old_latency: float
    new_plan: Plan
    action: str                  # "microbatch" | "replan" | "absorb"
    remapped_stages: bool
    solve_seconds: float = 0.0   # wall-clock spent replanning
    sim_time: float | None = None  # simulated time the event fired (if driven)
    restore_seconds: float = 0.0  # checkpoint-restore charge (NodeFailure)
    ride_out_latency: float | None = None  # incumbent on the mutated net
    #                              (inf: riding out impossible; None: unknown)
    net_changed: bool = True     # did coord.net mutate (Resync: no)
    decision: object = None      # PolicyDecision when routed via deliver()

    @property
    def new_latency(self) -> float:
        return self.new_plan.objective

    def log_record(self) -> dict:
        """Structured replan record — what the coordinator logs and what a
        replanning-cadence sweep aggregates."""
        return {
            "event": type(self.event).__name__,
            "action": self.action,
            "remapped_stages": self.remapped_stages,
            "old_latency": self.old_latency,
            "new_latency": self.new_latency,
            "solve_seconds": self.solve_seconds,
            "sim_time": self.sim_time,
            "restore_seconds": self.restore_seconds,
            "ride_out_latency": self.ride_out_latency,
            "reason": None if self.decision is None else self.decision.reason,
        }


class Coordinator:
    """Holds the live (profile, network, plan); applies events.

    ``cost_model`` (default: closed form) is threaded through every replan
    — the initial solve, full replans and the Theorem-1 cheap path — so an
    elastic deployment can replan against the *measured* makespan
    (``repro.core.cost_model.SimMakespan``) instead of Eq. (14).

    ``restore_cost`` is the checkpoint-restore charge of a ``NodeFailure``
    (resuming means reloading params from the latest checkpoint): a float
    (seconds), or a zero-argument callable queried at failure time — e.g.
    ``lambda: checkpoint.estimate_restore_seconds(ckpt_dir)``, which prices
    the restore from the store's recorded payload size / write timing.  The
    charge lands on ``ReplanOutcome.restore_seconds`` and is added to the
    downtime by ``sim.simulate_with_replanning``.

    Every full replan also scores the *ride-out* candidate — the old
    ``(solution, b)`` carried onto the mutated network (with placement
    indices remapped across a failure's renumbering) — and keeps it when it
    beats the fresh BCD solve, so the replanned latency is never worse than
    simply riding out the failure under the same cost model.
    """

    def __init__(self, profile: ModelProfile, net: EdgeNetwork, B: int,
                 *, theta: float = 0.01,
                 microbatch_gain_threshold: float = 0.95, cost_model=None,
                 restore_cost=0.0, policy=None,
                 preview_cache_size: int = 8):
        from .policy import resolve_replan_policy
        if preview_cache_size < 1:
            raise ValueError("preview_cache_size must be >= 1")
        self.profile = profile
        self.net = net
        self.B = B
        self.theta = theta
        self.mb_gain_threshold = microbatch_gain_threshold
        self.cost_model = resolve_cost_model(cost_model)
        self.restore_cost = restore_cost
        self.policy = resolve_replan_policy(policy)
        # ONE Planner serves every replan of this coordinator's lifetime:
        # events route through Planner.update (in-place graph patches + warm
        # hints) so an adopted replan after a single-link event costs a
        # patched re-sweep, not a cold Algorithm-1 solve (ISSUE 9)
        self.planner = Planner(profile, net)
        # LRU memo of preview Planners, capped at preview_cache_size: a
        # long flap storm previews a fresh (net, event) pair per flap and
        # would otherwise grow this without bound (ISSUE 10 satellite)
        self.preview_cache_size = int(preview_cache_size)
        self._preview_planners: dict = {}   # net-identity -> Planner memo
        self.eval_errors = 0   # expected-infeasibility evals (also counted
        #                        in obs as "ft.eval_errors", but obs may be
        #                        disabled — this attribute always counts)
        self.plan = bcd_solve(profile, net, B, theta=theta,
                              cost_model=self.cost_model,
                              planner=self.planner)
        self.events: list = []

    # -- event delivery (policy seam) -----------------------------------------
    def deliver(self, event, *, sim_time: float | None = None) -> ReplanOutcome:
        """Route one event through the replan policy: consult
        ``policy.decide`` and either ``apply`` (full treatment) or
        ``absorb`` (mutate the network, keep the incumbent plan).  With no
        policy this *is* ``apply`` — the historical eager behavior."""
        if self.policy is None:
            return self.apply(event, sim_time=sim_time)
        t = 0.0 if sim_time is None else sim_time
        with obs.span("ft.policy.decide", policy=self.policy.name,
                      event=type(event).__name__):
            decision = self.policy.decide(event, t, self)
        obs.inc("ft.policy.decisions[%s]"
                % ("replan" if decision.replan else "absorb"))
        logger.info("policy %s: %s -> %s (%s)", self.policy.name,
                    type(event).__name__,
                    "replan" if decision.replan else "absorb", decision.reason)
        if decision.replan:
            outcome = self.apply(event, sim_time=sim_time,
                                 cost_model=decision.cost_model)
        else:
            outcome = self.absorb(event, sim_time=sim_time)
        outcome.decision = decision
        self.policy.observe(outcome, t)
        return outcome

    # -- event application ----------------------------------------------------
    def apply(self, event, *, sim_time: float | None = None,
              cost_model=None) -> ReplanOutcome:
        """Mutate the network per ``event`` and replan.  ``sim_time`` is the
        simulated instant the event fired (recorded on the outcome when the
        coordinator is driven by ``sim.simulate_with_replanning``).
        ``cost_model`` overrides the coordinator's model for *this* replan
        only (a ``PolicyDecision`` escalating one solve to, say, the
        ``RobustMakespan`` objective)."""
        base_model = self.cost_model
        if cost_model is not None:
            self.cost_model = resolve_cost_model(cost_model)
        try:
            return self._apply(event, sim_time)
        finally:
            self.cost_model = base_model

    def _apply(self, event, sim_time) -> ReplanOutcome:
        with obs.span("ft.apply", event=type(event).__name__):
            t0 = time.perf_counter()
            old_L = self._current_latency()
            old_sol, old_b = self.plan.solution, self.plan.b
            net_changed = True
            if isinstance(event, NodeFailure):
                self._mutate(event)
                old_sol = self._remap_across_failure(old_sol, event.server)
                outcome = self._full_replan(event, old_L)
                outcome.restore_seconds = self._restore_seconds()
            elif isinstance(event, RateChange):
                self._mutate(event)
                outcome = self._full_replan(event, old_L)
            elif isinstance(event, Straggler):
                self._mutate(event)
                outcome = self._straggler_mitigation(event, old_L)
            elif isinstance(event, Resync):
                # solve against the measured snapshot; base net stays (the
                # snapshot's multipliers live in the driving scenario)
                net_changed = False
                outcome = self._full_replan(event, old_L, net=event.net)
            else:
                raise TypeError(event)
            self._prefer_ride_out(
                old_sol, old_b, outcome,
                net=event.net if isinstance(event, Resync) else None)
            outcome.solve_seconds = time.perf_counter() - t0
            outcome.sim_time = sim_time
            outcome.net_changed = net_changed
        obs.inc("ft.replans")
        obs.inc(f"ft.action[{outcome.action}]")
        logger.info(
            "replan: event=%s action=%s remapped=%s old_latency=%.6g "
            "new_latency=%.6g solve_s=%.4f sim_time=%s",
            type(event).__name__, outcome.action, outcome.remapped_stages,
            outcome.old_latency, outcome.new_latency, outcome.solve_seconds,
            "-" if sim_time is None else f"{sim_time:.6g}")
        self.events.append(outcome)
        return outcome

    def _mutate(self, event) -> None:
        """Commit an event's network mutation through the shared planner.

        ``Planner.update`` replicates the historical in-place mutations
        float-op-for-float-op (asserted in tests/test_planner_update.py), so
        ``self.net`` stays bit-identical to the pre-ISSUE-9 behavior while
        the planner's cached graphs are patched instead of rebuilt."""
        self.planner.update(event)
        self.net = self.planner.net
        self._preview_planners.clear()      # previews were for the old net

    def _planner_for(self, net: EdgeNetwork) -> Planner:
        """The memoized Planner for ``net``: the live planner when ``net``
        IS the coordinator's network, else one planner per network identity
        (Resync snapshots, policy previews) so replays stop re-paying graph
        builds (ISSUE 9 satellite)."""
        if net is self.planner.net or net is self.net:
            return self.planner
        hit = None
        for k, pl in self._preview_planners.items():  # bounded dict: scan ok
            if pl.net is net:
                hit = k
                break
        if hit is not None:
            obs.inc("ft.preview_planner_hit")
            return self._memo_touch(hit)
        obs.inc("ft.preview_planner_miss")
        pl = Planner(self.profile, net)
        self._memo_put(id(net), pl)
        return pl

    def _memo_touch(self, key):
        """Mark ``key`` most-recently-used and return its planner."""
        pl = self._preview_planners.pop(key)
        self._preview_planners[key] = pl
        return pl

    def _memo_put(self, key, pl) -> None:
        """Insert into the preview-planner memo, evicting least-recently
        used entries over the cap (``ft.preview_evictions`` counts them)."""
        self._preview_planners[key] = pl
        while len(self._preview_planners) > self.preview_cache_size:
            self._preview_planners.pop(next(iter(self._preview_planners)))
            obs.inc("ft.preview_evictions")

    # -- event absorption (ride-out path) --------------------------------------
    def absorb(self, event, *, sim_time: float | None = None) -> ReplanOutcome:
        """Take the event's network mutation **without replanning**: the
        incumbent ``(solution, b)`` rides out the change (placement indices
        remapped across a failure's renumbering), its objective re-priced on
        the mutated network.  No BCD solve, no pipeline restart, no restore
        charge.  When riding out is impossible — the failed server hosted a
        stage, or the incumbent is infeasible on the mutated network — the
        absorb *escalates* to a forced ``apply``."""
        with obs.span("ft.absorb", event=type(event).__name__):
            t0 = time.perf_counter()
            old_L = self._current_latency()
            sol, b = self.plan.solution, self.plan.b
            net_changed = True
            if isinstance(event, NodeFailure):
                new_net = self.net.degraded([event.server])
                sol = self._remap_across_failure(sol, event.server)
                if sol is None:
                    return self._escalate(
                        event, sim_time, "failed server hosts a stage")
            elif isinstance(event, RateChange):
                rate = self.net.rate.copy()
                rate[event.n_from, event.n_to] *= event.factor
                new_net = dataclasses.replace(self.net, rate=rate)
            elif isinstance(event, Straggler):
                new_net = dataclasses.replace(
                    self.net,
                    nodes=[dataclasses.replace(n, f=n.f / event.slowdown)
                           if i == event.node else n
                           for i, n in enumerate(self.net.nodes)])
            elif isinstance(event, Resync):
                new_net = self.net         # true no-op: nothing mutates
                net_changed = False
            else:
                raise TypeError(event)
            ride_L = self._evaluate_candidate(new_net, sol, b)
            if not math.isfinite(ride_L):
                return self._escalate(
                    event, sim_time, "incumbent infeasible on mutated network")
            if net_changed:
                # commit through the shared planner (same float ops as the
                # hand-built new_net above — values stay bit-identical)
                self._mutate(event)
                new_net = self.net
                self.plan = dataclasses.replace(
                    self.plan, solution=sol, b=b,
                    T_f=fill_latency(self.profile, new_net, sol, b),
                    T_i=pipeline_interval(self.profile, new_net, sol, b),
                    L_t=total_latency(self.profile, new_net, sol, b, self.B),
                    objective=ride_L, feasible=True,
                    cost_model=self.cost_model.name)
            outcome = ReplanOutcome(
                event=event, old_latency=old_L, new_plan=self.plan,
                action="absorb", remapped_stages=False,
                solve_seconds=time.perf_counter() - t0, sim_time=sim_time,
                ride_out_latency=ride_L, net_changed=net_changed)
        obs.inc("ft.absorbed")
        obs.inc("ft.action[absorb]")
        logger.info("absorb: event=%s new_latency=%.6g sim_time=%s",
                    type(event).__name__, outcome.new_latency,
                    "-" if sim_time is None else f"{sim_time:.6g}")
        self.events.append(outcome)
        return outcome

    def _escalate(self, event, sim_time, why: str) -> ReplanOutcome:
        """Ride-out impossible: the absorb becomes a forced full replan."""
        obs.inc("ft.absorb_escalated")
        logger.info("absorb escalated to replan: event=%s (%s)",
                    type(event).__name__, why)
        outcome = self.apply(event, sim_time=sim_time)
        if outcome.ride_out_latency is None:
            outcome.ride_out_latency = math.inf
        return outcome

    def _evaluate_candidate(self, net, sol, b: int) -> float:
        """Cost (under the active model) of ``(sol, b)`` on ``net`` —
        ``inf`` when memory-infeasible or expectedly unevaluable."""
        if sol is None or b < 1:
            return math.inf
        try:
            if not self.cost_model.memory_feasible(self.profile, net, sol, b):
                return math.inf
            return self.cost_model.evaluate(self.profile, net, sol, b, self.B)
        except (ValueError, ArithmeticError):
            # expected infeasibility (validate_solution / degenerate
            # capacity) — anything else is a programming error: re-raise
            self.eval_errors += 1
            obs.inc("ft.eval_errors")
            return math.inf

    @staticmethod
    def preview(net: EdgeNetwork, sol, event):
        """``(mutated_net, remapped_solution)`` the event *would* produce —
        no coordinator state touched.  Lets a policy score the incumbent on
        the post-event network before deciding (``remapped_solution`` is
        ``None`` when a failure displaces a hosted stage)."""
        if isinstance(event, NodeFailure):
            return (net.degraded([event.server]),
                    Coordinator._remap_across_failure(sol, event.server))
        if isinstance(event, RateChange):
            rate = net.rate.copy()
            rate[event.n_from, event.n_to] *= event.factor
            return dataclasses.replace(net, rate=rate), sol
        if isinstance(event, Straggler):
            return dataclasses.replace(
                net, nodes=[dataclasses.replace(n, f=n.f / event.slowdown)
                            if i == event.node else n
                            for i, n in enumerate(net.nodes)]), sol
        if isinstance(event, Resync):
            return event.net, sol
        raise TypeError(event)

    def preview_cached(self, sol, event):
        """``(mutated_net, remapped_solution, planner)`` for the event —
        :meth:`preview` plus a memoized :class:`Planner` per (base network,
        event) identity, so policy replays (CVaRPreSpill tail scoring,
        repeated decide calls on the same flap) stop re-paying graph builds.
        Coordinator state is untouched."""
        key = (id(self.net), _event_key(event))
        if key in self._preview_planners:
            obs.inc("ft.preview_planner_hit")
            got = self._memo_touch(key)
            psol = (self._remap_across_failure(sol, event.server)
                    if isinstance(event, NodeFailure) else sol)
            return got.net, psol, got
        net, psol = Coordinator.preview(self.net, sol, event)
        pl = self._planner_for(net)
        self._memo_put(key, pl)
        return net, psol, pl

    def _current_latency(self) -> float:
        try:
            return self.cost_model.evaluate(self.profile, self.net,
                                            self.plan.solution, self.plan.b,
                                            self.B)
        except (ValueError, ArithmeticError):
            # expected infeasibility errors only — see _evaluate_candidate
            self.eval_errors += 1
            obs.inc("ft.eval_errors")
            return math.inf

    def _restore_seconds(self) -> float:
        rc = self.restore_cost
        return float(rc()) if callable(rc) else float(rc)

    @staticmethod
    def _remap_across_failure(sol, server: int):
        """The old solution re-expressed in the degraded network's indices
        (``degraded([server])`` drops one row/column and shifts the rest
        down), or ``None`` when the failed server hosted a stage — then
        there is no ride-out: its submodels must move."""
        if server in sol.placement:
            return None
        placement = tuple(n - 1 if n > server else n for n in sol.placement)
        return dataclasses.replace(sol, placement=placement)

    def _prefer_ride_out(self, old_sol, old_b: int, outcome,
                         net: EdgeNetwork | None = None) -> None:
        """Score the ride-out candidate — the pre-event ``(solution, b)``
        on the *mutated* network (``net`` overrides for Resync snapshots) —
        and keep it when it strictly beats the fresh solve: the BCD
        alternation is a heuristic and need not visit the incumbent, but an
        elastic deployment should never migrate to a plan slower than
        standing pat.  Mutates ``outcome.new_plan`` (and ``self.plan``) in
        place; the action stays "replan"/"microbatch" with
        ``remapped_stages`` downgraded to whether stages still move.
        Always records ``outcome.ride_out_latency`` (``inf`` when riding
        out is impossible) — rate-limiting policies back off on replans
        that fail to beat it.
        """
        net = self.net if net is None else net
        ride_L = self._evaluate_candidate(net, old_sol, old_b)
        outcome.ride_out_latency = ride_L
        if not (math.isfinite(ride_L)
                and ride_L < self.plan.objective * (1.0 - 1e-12)):
            return
        obs.inc("ft.ride_out_kept")
        self.plan = dataclasses.replace(
            self.plan, solution=old_sol, b=old_b,
            T_f=fill_latency(self.profile, net, old_sol, old_b),
            T_i=pipeline_interval(self.profile, net, old_sol, old_b),
            L_t=total_latency(self.profile, net, old_sol, old_b, self.B),
            objective=ride_L, feasible=True,
            cost_model=self.cost_model.name)
        outcome.new_plan = self.plan
        outcome.remapped_stages = False

    def _full_replan(self, event, old_L,
                     net: EdgeNetwork | None = None) -> ReplanOutcome:
        net = self.net if net is None else net
        old_sol = self.plan.solution
        obs.inc("ft.full_solves")
        self.plan = bcd_solve(self.profile, net, self.B,
                              b0=max(self.plan.b, 1), theta=self.theta,
                              cost_model=self.cost_model,
                              planner=self._planner_for(net))
        return ReplanOutcome(
            event=event, old_latency=old_L, new_plan=self.plan,
            action="replan",
            remapped_stages=(self.plan.solution != old_sol))

    def _straggler_mitigation(self, event, old_L) -> ReplanOutcome:
        """Cheap path first: keep (x, y), re-solve b for the new bottleneck
        (no weight movement!); fall back to a full re-plan if that recovers
        too little.  The full solve is *gated*: a straggler only removes
        capacity, so the pre-event latency ``old_L`` lower-bounds what a
        fresh solve can reach — when the micro-batch fix already lands
        within the gain threshold of that bound, the BCD solve is skipped
        entirely and the cheap path is actually cheap
        (``ft.full_solve_saved`` counts the skips)."""
        incumbent = self.plan
        sol = incumbent.solution
        T_i = pipeline_interval(self.profile, self.net, sol, incumbent.b)
        mb = optimal_microbatch(self.profile, self.net, sol, self.B, T_i,
                                cost_model=self.cost_model)
        if mb.b > 0:
            cheap_L = self._evaluate_candidate(self.net, sol, mb.b)
        else:
            cheap_L = math.inf

        def adopt_cheap():
            self.plan = dataclasses.replace(
                incumbent, b=mb.b,
                T_f=fill_latency(self.profile, self.net, sol, mb.b),
                T_i=pipeline_interval(self.profile, self.net, sol, mb.b),
                L_t=total_latency(self.profile, self.net, sol, mb.b, self.B),
                objective=cheap_L, cost_model=self.cost_model.name)
            return ReplanOutcome(event=event, old_latency=old_L,
                                 new_plan=self.plan, action="microbatch",
                                 remapped_stages=False)

        if (math.isfinite(cheap_L) and math.isfinite(old_L)
                and cheap_L <= old_L / self.mb_gain_threshold):
            obs.inc("ft.full_solve_saved")
            return adopt_cheap()
        full_outcome = self._full_replan(event, old_L)
        full = self.plan
        if math.isfinite(cheap_L) and cheap_L <= full.objective / self.mb_gain_threshold:
            return adopt_cheap()
        return dataclasses.replace(full_outcome, remapped_stages=True)
