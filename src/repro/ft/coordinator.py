"""Elastic coordinator — the paper's BCD promoted to a runtime feature.

Events:
  NodeFailure(server)  a server drops out -> rebuild the network without it,
                       re-run Algorithm 2 (BCD), remap submodels, resume
                       from the latest checkpoint (params are cut-agnostic:
                       the full model is the unit of state, stages are views)
  RateChange(n,n',f)   a link's measured rate changed by factor f -> replan
  Straggler(stage, f)  a stage's observed compute time inflated by factor f
                       -> first try the cheap fix (Theorem 1: re-solve the
                       micro-batch size against the new bottleneck T_i);
                       only if the predicted gain is small, full re-plan
                       (move a cut across the slow boundary)

Every outcome reports (old_plan, new_plan, predicted latencies) so the
trainer can decide to pause-and-remap or continue — tests assert that the
replanned latency is sane (>= within noise of a from-scratch plan, and the
pipeline stays feasible).
"""

from __future__ import annotations

import dataclasses
import logging
import math
import time

import numpy as np

from repro.core import (EdgeNetwork, ModelProfile, Plan, bcd_solve,
                        optimal_microbatch, total_latency, pipeline_interval,
                        fill_latency, num_fills)
from repro.core.cost_model import resolve_cost_model
from repro import obs


@dataclasses.dataclass(frozen=True)
class NodeFailure:
    server: int                  # node index in the current network


@dataclasses.dataclass(frozen=True)
class RateChange:
    n_from: int
    n_to: int
    factor: float


@dataclasses.dataclass(frozen=True)
class Straggler:
    node: int
    slowdown: float              # f_n -> f_n / slowdown


logger = logging.getLogger("repro.ft.coordinator")


@dataclasses.dataclass
class ReplanOutcome:
    event: object
    old_latency: float
    new_plan: Plan
    action: str                  # "microbatch" | "replan" | "none"
    remapped_stages: bool
    solve_seconds: float = 0.0   # wall-clock spent replanning
    sim_time: float | None = None  # simulated time the event fired (if driven)
    restore_seconds: float = 0.0  # checkpoint-restore charge (NodeFailure)

    @property
    def new_latency(self) -> float:
        return self.new_plan.objective

    def log_record(self) -> dict:
        """Structured replan record — what the coordinator logs and what a
        replanning-cadence sweep aggregates."""
        return {
            "event": type(self.event).__name__,
            "action": self.action,
            "remapped_stages": self.remapped_stages,
            "old_latency": self.old_latency,
            "new_latency": self.new_latency,
            "solve_seconds": self.solve_seconds,
            "sim_time": self.sim_time,
            "restore_seconds": self.restore_seconds,
        }


class Coordinator:
    """Holds the live (profile, network, plan); applies events.

    ``cost_model`` (default: closed form) is threaded through every replan
    — the initial solve, full replans and the Theorem-1 cheap path — so an
    elastic deployment can replan against the *measured* makespan
    (``repro.core.cost_model.SimMakespan``) instead of Eq. (14).

    ``restore_cost`` is the checkpoint-restore charge of a ``NodeFailure``
    (resuming means reloading params from the latest checkpoint): a float
    (seconds), or a zero-argument callable queried at failure time — e.g.
    ``lambda: checkpoint.estimate_restore_seconds(ckpt_dir)``, which prices
    the restore from the store's recorded payload size / write timing.  The
    charge lands on ``ReplanOutcome.restore_seconds`` and is added to the
    downtime by ``sim.simulate_with_replanning``.

    Every full replan also scores the *ride-out* candidate — the old
    ``(solution, b)`` carried onto the mutated network (with placement
    indices remapped across a failure's renumbering) — and keeps it when it
    beats the fresh BCD solve, so the replanned latency is never worse than
    simply riding out the failure under the same cost model.
    """

    def __init__(self, profile: ModelProfile, net: EdgeNetwork, B: int,
                 *, theta: float = 0.01,
                 microbatch_gain_threshold: float = 0.95, cost_model=None,
                 restore_cost=0.0):
        self.profile = profile
        self.net = net
        self.B = B
        self.theta = theta
        self.mb_gain_threshold = microbatch_gain_threshold
        self.cost_model = resolve_cost_model(cost_model)
        self.restore_cost = restore_cost
        self.plan = bcd_solve(profile, net, B, theta=theta,
                              cost_model=self.cost_model)
        self.events: list = []

    # -- event application ----------------------------------------------------
    def apply(self, event, *, sim_time: float | None = None) -> ReplanOutcome:
        """Mutate the network per ``event`` and replan.  ``sim_time`` is the
        simulated instant the event fired (recorded on the outcome when the
        coordinator is driven by ``sim.simulate_with_replanning``)."""
        with obs.span("ft.apply", event=type(event).__name__):
            t0 = time.perf_counter()
            old_L = self._current_latency()
            old_sol, old_b = self.plan.solution, self.plan.b
            if isinstance(event, NodeFailure):
                self.net = self.net.degraded([event.server])
                old_sol = self._remap_across_failure(old_sol, event.server)
                outcome = self._full_replan(event, old_L)
                outcome.restore_seconds = self._restore_seconds()
            elif isinstance(event, RateChange):
                rate = self.net.rate.copy()
                rate[event.n_from, event.n_to] *= event.factor
                self.net = dataclasses.replace(self.net, rate=rate)
                outcome = self._full_replan(event, old_L)
            elif isinstance(event, Straggler):
                self.net = dataclasses.replace(
                    self.net,
                    nodes=[dataclasses.replace(n, f=n.f / event.slowdown)
                           if i == event.node else n
                           for i, n in enumerate(self.net.nodes)])
                outcome = self._straggler_mitigation(event, old_L)
            else:
                raise TypeError(event)
            self._prefer_ride_out(old_sol, old_b, outcome)
            outcome.solve_seconds = time.perf_counter() - t0
            outcome.sim_time = sim_time
        obs.inc("ft.replans")
        obs.inc(f"ft.action[{outcome.action}]")
        logger.info(
            "replan: event=%s action=%s remapped=%s old_latency=%.6g "
            "new_latency=%.6g solve_s=%.4f sim_time=%s",
            type(event).__name__, outcome.action, outcome.remapped_stages,
            outcome.old_latency, outcome.new_latency, outcome.solve_seconds,
            "-" if sim_time is None else f"{sim_time:.6g}")
        self.events.append(outcome)
        return outcome

    def _current_latency(self) -> float:
        try:
            return self.cost_model.evaluate(self.profile, self.net,
                                            self.plan.solution, self.plan.b,
                                            self.B)
        except Exception:
            return math.inf

    def _restore_seconds(self) -> float:
        rc = self.restore_cost
        return float(rc()) if callable(rc) else float(rc)

    @staticmethod
    def _remap_across_failure(sol, server: int):
        """The old solution re-expressed in the degraded network's indices
        (``degraded([server])`` drops one row/column and shifts the rest
        down), or ``None`` when the failed server hosted a stage — then
        there is no ride-out: its submodels must move."""
        if server in sol.placement:
            return None
        placement = tuple(n - 1 if n > server else n for n in sol.placement)
        return dataclasses.replace(sol, placement=placement)

    def _prefer_ride_out(self, old_sol, old_b: int, outcome) -> None:
        """Score the ride-out candidate — the pre-event ``(solution, b)``
        on the *mutated* network — and keep it when it strictly beats the
        fresh solve: the BCD alternation is a heuristic and need not visit
        the incumbent, but an elastic deployment should never migrate to a
        plan slower than standing pat.  Mutates ``outcome.new_plan`` (and
        ``self.plan``) in place; the action stays "replan"/"microbatch"
        with ``remapped_stages`` downgraded to whether stages still move.
        """
        if old_sol is None or old_b < 1:
            return
        try:
            if not self.cost_model.memory_feasible(self.profile, self.net,
                                                   old_sol, old_b):
                return
            ride_L = self.cost_model.evaluate(self.profile, self.net,
                                              old_sol, old_b, self.B)
        except Exception:
            return
        if not (math.isfinite(ride_L)
                and ride_L < self.plan.objective * (1.0 - 1e-12)):
            return
        obs.inc("ft.ride_out_kept")
        self.plan = dataclasses.replace(
            self.plan, solution=old_sol, b=old_b,
            T_f=fill_latency(self.profile, self.net, old_sol, old_b),
            T_i=pipeline_interval(self.profile, self.net, old_sol, old_b),
            L_t=total_latency(self.profile, self.net, old_sol, old_b, self.B),
            objective=ride_L, feasible=True,
            cost_model=self.cost_model.name)
        outcome.new_plan = self.plan
        outcome.remapped_stages = False

    def _full_replan(self, event, old_L) -> ReplanOutcome:
        old_sol = self.plan.solution
        self.plan = bcd_solve(self.profile, self.net, self.B,
                              b0=max(self.plan.b, 1), theta=self.theta,
                              cost_model=self.cost_model)
        return ReplanOutcome(
            event=event, old_latency=old_L, new_plan=self.plan,
            action="replan",
            remapped_stages=(self.plan.solution != old_sol))

    def _straggler_mitigation(self, event, old_L) -> ReplanOutcome:
        """Cheap path first: keep (x, y), re-solve b for the new bottleneck
        (no weight movement!); fall back to a full re-plan if that recovers
        too little."""
        sol = self.plan.solution
        T_i = pipeline_interval(self.profile, self.net, sol, self.plan.b)
        mb = optimal_microbatch(self.profile, self.net, sol, self.B, T_i,
                                cost_model=self.cost_model)
        if mb.b > 0:
            cheap_L = self.cost_model.evaluate(self.profile, self.net, sol,
                                               mb.b, self.B)
        else:
            cheap_L = math.inf
        full = bcd_solve(self.profile, self.net, self.B,
                         b0=max(self.plan.b, 1), theta=self.theta,
                         cost_model=self.cost_model)
        if math.isfinite(cheap_L) and cheap_L <= full.objective / self.mb_gain_threshold:
            self.plan = dataclasses.replace(
                self.plan, b=mb.b,
                T_f=fill_latency(self.profile, self.net, sol, mb.b),
                T_i=pipeline_interval(self.profile, self.net, sol, mb.b),
                L_t=total_latency(self.profile, self.net, sol, mb.b, self.B),
                objective=cheap_L, cost_model=self.cost_model.name)
            return ReplanOutcome(event=event, old_latency=old_L,
                                 new_plan=self.plan, action="microbatch",
                                 remapped_stages=False)
        self.plan = full
        return ReplanOutcome(event=event, old_latency=old_L,
                             new_plan=self.plan, action="replan",
                             remapped_stages=True)
