"""SPMD pipeline parallelism — the paper's pipelined SL on a TPU mesh.

``shard_map`` with a *manual* "stage" axis (data/model stay auto): stage k's
layer block lives on mesh slice stage=k; activations hop stage->stage+1 via
``lax.ppermute`` — the TPU-native counterpart of the paper's inter-server
activation transmissions (Eqs. 5/6), with the reverse (gradient) hops of
Eqs. (9)/(10) generated automatically by autodiff's ppermute transpose.

Schedule: GPipe-style fill/steady/drain over T = Q + S - 1 ticks (the exact
timeline the paper's Eq. (14) models: T_f fill + (Q-1) * T_i steady).  The
stage plan (cuts) and micro-batch count Q come from core.planner — i.e.
Algorithm 1 + Theorem 1 drive the actual runtime configuration.

Embedding and LM head run *outside* the pipelined region (data-parallel),
so all pipeline stages are structurally identical transformer-layer blocks;
loss is accumulated per micro-batch to keep the vocab-sized logits
transient.  Numerics are validated against the plain (non-pipelined) loss
in tests/test_pipeline.py.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.launch.compat import PARTIAL_AUTO_SHARD_MAP, shard_map
from repro.models.common import ArchConfig, cross_entropy, rms_norm
from repro.models import transformer as tf_lib
from .stage import stack_stage_params, transformer_stage_fn


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    num_stages: int
    num_microbatches: int
    stage_axis: str = "stage"


def _make_pipe_region(cfg: ArchConfig, pcfg: PipelineConfig, mesh):
    """The manual-stage shard_map region: stream (Q, mb, S, d) -> (Q, mb, S, d)."""
    stage_fn = transformer_stage_fn(cfg)
    S_axis = pcfg.num_stages
    Q = pcfg.num_microbatches
    T = Q + S_axis - 1
    ax = pcfg.stage_axis

    def pipe(stage_params, stream_f32):
        # The stream crosses the shard_map boundary in f32: its transpose
        # cotangent is a psum over the stage axis, and XLA:CPU's
        # AllReducePromotion pass aborts on bf16 all-reduce (TPU handles
        # bf16 natively; this costs nothing there since the cast fuses).
        sid = jax.lax.axis_index(ax)
        stream = stream_f32.astype(cfg.compute_dtype)
        mb_shape = stream.shape[1:]

        def tick(carry, t):
            idx = jnp.minimum(t, Q - 1)
            x0 = jax.lax.dynamic_index_in_dim(stream, idx, 0, keepdims=False)
            x = jnp.where(sid == 0, x0, carry)
            y = stage_fn(jax.tree.map(lambda p: p[0], stage_params), x)
            shifted = jax.lax.ppermute(
                y, ax, [(i, i + 1) for i in range(S_axis - 1)])
            out_t = jnp.where(sid == S_axis - 1, y,
                              jnp.zeros_like(y))
            return shifted, out_t

        init = jnp.zeros(mb_shape, stream.dtype)
        _, outs = jax.lax.scan(tick, init, jnp.arange(T))
        valid = outs[S_axis - 1:]                      # (Q, mb, seq, d)
        # combine: only the last stage holds nonzero outputs.  psum in f32 —
        # XLA:CPU's AllReducePromotion pass miscompiles bf16 all-reduce
        # (the TPU path all-reduces bf16 natively; see DESIGN.md).
        out = jax.lax.psum(valid.astype(jnp.float32), ax)
        return out.astype(stream.dtype)

    if PARTIAL_AUTO_SHARD_MAP:
        # jax>=0.6: manual over "stage" only; data/model stay auto so the
        # stream keeps its outer sharding through the region
        return shard_map(
            pipe, mesh=mesh,
            in_specs=(P(ax), P()),    # stage params split; stream replicated
            out_specs=P(),            # identical across stages after psum
            axis_names={ax}, check_vma=False)
    # jax 0.4.x: partial-auto regions cannot lower axis_index/ppermute
    # (XLA PartitionId limitation — see compat.PARTIAL_AUTO_SHARD_MAP), so
    # run fully manual and carry the data sharding through in_specs: the
    # micro-batch rows split over the data axes, d stays unsharded inside
    # the region (numerics identical; the model axis resharding happens at
    # the region boundary instead of via auto sharding)
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    stream_spec = P(None, data_axes) if data_axes else P()
    return shard_map(
        pipe, mesh=mesh,
        in_specs=(P(ax), stream_spec),
        out_specs=stream_spec,
        axis_names=set(mesh.axis_names), check_vma=False)


def make_pipelined_loss(cfg: ArchConfig, mesh, pcfg: PipelineConfig
                        ) -> Callable:
    """Returns loss(params, batch) running layers through the stage pipeline.

    ``params`` is the ordinary transformer param tree (stacked layers);
    stage stacking/sharding happens inside, so checkpoints are layout-
    compatible with the non-pipelined trainer.
    """
    pipe = _make_pipe_region(cfg, pcfg, mesh)
    Q = pcfg.num_microbatches

    def loss_fn(params, batch):
        tokens, labels = batch["tokens"], batch["labels"]
        B, S = tokens.shape
        assert B % Q == 0, (B, Q)
        from repro.models.common import maybe_constrain
        x = params["embed"].astype(cfg.compute_dtype)[tokens]
        stream = x.reshape(Q, B // Q, S, cfg.d_model).astype(jnp.float32)
        # shard the stream over data (micro-batch rows) AND model (d) on the
        # auto axes — it is replicated across "stage" by construction, and
        # leaving d unsharded costs 4x stream memory (§Perf iteration 2)
        stream = maybe_constrain(
            stream, P(None, ("pod", "data"), None, "model"))
        stage_params = stack_stage_params(params["layers"], pcfg.num_stages)
        ys = pipe(stage_params, stream)
        labels_mb = labels.reshape(Q, B // Q, S)

        def head_loss(acc, inp):
            y, lab = inp
            logits = tf_lib._unembed(params, y, cfg)
            return acc + cross_entropy(logits, lab), None

        tot, _ = jax.lax.scan(head_loss, jnp.float32(0.0), (ys, labels_mb))
        return tot / Q

    return loss_fn


def make_pipelined_train_step(cfg: ArchConfig, mesh, pcfg: PipelineConfig,
                              optimizer) -> Callable:
    loss_fn = make_pipelined_loss(cfg, mesh, pcfg)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state = optimizer.update(params, grads, opt_state)
        return params, opt_state, {"loss": loss}

    return train_step


def plan_to_pipeline_config(stage_plan, global_batch: int) -> PipelineConfig:
    """core.planner.StagePlan -> runtime pipeline config (Q from Thm 1's b)."""
    q = max(1, min(stage_plan.num_microbatches, global_batch))
    while global_batch % q:
        q -= 1
    return PipelineConfig(num_stages=stage_plan.num_stages,
                          num_microbatches=q)
