"""Single-process pipelined-SL executors.

1. ``microbatch_grads`` — gradient accumulation over micro-batches via
   ``lax.scan``; *numerically equivalent* to the full-batch gradient (the
   paper's synchronous-SGD guarantee: pipelining changes latency, not the
   update — Fig. 4's "same converged accuracy").  Tests assert allclose.

2. ``SplitLearningExecutor`` — the paper's multi-hop SL semantics made
   runnable on one host: submodels (from a core.Plan) execute as separate
   stages with explicit activation/grad hand-offs, per-link compression
   hooks, and a latency ledger driven by the core latency model, so
   training curves can be plotted against *simulated wall-clock* (Fig. 4).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import Plan, breakdown
from repro.core.latency import SplitSolution
from repro.models import vgg as vgg_lib
from .stage import split_vgg_params, vgg_stages_from_cuts


def split_batch(batch, num_microbatches: int):
    """(B, ...) -> (Q, B/Q, ...), keeping the per-microbatch batch dim
    sharded over the data axes (the reshape otherwise loses the input's
    batch sharding and every activation replicates — measured +8 GiB/device
    on qwen3-0.6b train_4k; EXPERIMENTS.md §Perf iteration 0)."""
    from repro.models.common import maybe_constrain
    from jax.sharding import PartitionSpec as P

    def resh(x):
        B = x.shape[0]
        assert B % num_microbatches == 0, (B, num_microbatches)
        y = x.reshape((num_microbatches, B // num_microbatches)
                      + x.shape[1:])
        return maybe_constrain(
            y, P(None, ("pod", "data"), *([None] * (y.ndim - 2))))

    return jax.tree.map(resh, batch)


def microbatch_grads(loss_fn: Callable, params, batch, num_microbatches: int):
    """Mean loss + grads accumulated over micro-batches (== full batch)."""
    mb = split_batch(batch, num_microbatches)
    gfn = jax.value_and_grad(loss_fn)

    def step(acc, mbatch):
        loss_acc, grad_acc = acc
        loss, grads = gfn(params, mbatch)
        return (loss_acc + loss,
                jax.tree.map(jnp.add, grad_acc, grads)), None

    zeros = jax.tree.map(jnp.zeros_like, params)
    (loss_sum, grad_sum), _ = jax.lax.scan(step, (0.0, zeros), mb)
    scale = 1.0 / num_microbatches
    return loss_sum * scale, jax.tree.map(lambda g: g * scale, grad_sum)


# ---------------------------------------------------------------------------
# Split-learning executor (paper semantics, VGG workload)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LinkHooks:
    """Per-link transforms for activations / gradients (compression/...)."""
    fwd: Callable = lambda x: x
    bwd: Callable = lambda g: g


class SplitLearningExecutor:
    """Runs one training round of pipelined SL per the paper's Plan.

    The compute graph is *identical* to centralized training (stages chain
    to the full model; autodiff crosses the cut via VJPs — the
    activation-gradient hand-off of Eq. (9)), while the latency ledger
    accounts T_f + ceil((B-b)/b)*T_i per round from the analytical model.
    """

    def __init__(self, plan: Plan, profile, net, *, hooks: LinkHooks = None,
                 seed: int = 0):
        self.plan = plan
        self.profile = profile
        self.net = net
        self.hooks = hooks or LinkHooks()
        self.stages = vgg_stages_from_cuts(plan.solution.cuts)
        rng = jax.random.PRNGKey(seed)
        self.full_params = vgg_lib.init_params(rng)
        self.round_latency = plan.L_t
        self.simulated_time = 0.0
        self._jitted_grads = {}      # q -> compiled microbatch_grads

    def stage_params(self):
        return split_vgg_params(self.full_params, self.plan.solution.cuts)

    def _forward_chain(self, params_list, x):
        """Client -> servers with link hooks at every cut (Eqs. 5/6).

        The per-stage spans time eager execution; under ``jax.jit`` they
        fire once per trace and measure *trace construction* per stage —
        compile-side telemetry, by design.
        """
        acts = [x]
        for k, (stage, sp) in enumerate(zip(self.stages, params_list)):
            with obs.span("executor.stage_fwd", stage=k):
                x = stage.forward(sp, x)
                x = self.hooks.fwd(x)
            acts.append(x)
        return x, acts

    def loss(self, params_list, batch):
        logits, _ = self._forward_chain(params_list, batch["images"])
        from repro.models.common import cross_entropy
        return cross_entropy(logits[:, None, :], batch["labels"][:, None])

    def train_round(self, batch, lr: float = 0.05, momentum: float = 0.0):
        """One mini-batch: micro-batched grads + SGD (optionally with heavy
        -ball ``momentum``); advances the simulated clock.  Momentum keeps
        the update rule client-computable (one extra buffer per stage) and
        tames plain SGD's oscillation on the norm-free VGG stack."""
        params_list = self.stage_params()
        q = self.plan.num_microbatches
        B = batch["images"].shape[0]
        q = max(1, min(q, B))
        while B % q:
            q -= 1
        # cache the compiled step per q: a fresh jit(lambda) every round
        # would recompile the whole fwd+bwd scan each call
        step = self._jitted_grads.get(q)
        if step is None:
            obs.inc("executor.jit_compile")
            with obs.span("executor.compile", q=q,
                          stages=len(params_list)):
                step = jax.jit(
                    lambda p, b: microbatch_grads(self.loss, p, b, q))
                self._jitted_grads[q] = step
        else:
            obs.inc("executor.jit_cache_hit")
        obs.inc("executor.train_rounds")
        with obs.span("executor.step", q=q, B=B):
            loss, grads = step(params_list, batch)
            if obs.enabled():
                # async dispatch would end the span at enqueue time;
                # only force the sync while actually measuring
                jax.block_until_ready((loss, grads))
        if momentum:
            vel = getattr(self, "_velocity", None)
            # a replan can change the cuts (different stage grouping/leaf
            # shapes) — a stale velocity tree would crash the tree.map, so
            # restart the buffer whenever the gradient tree changed shape
            if vel is None or (jax.tree.map(jnp.shape, vel)
                               != jax.tree.map(jnp.shape, grads)):
                vel = jax.tree.map(jnp.zeros_like, grads)
            vel = jax.tree.map(lambda v, g: momentum * v + g, vel, grads)
            self._velocity = vel
            grads = vel
        params_list = jax.tree.map(lambda p, g: p - lr * g, params_list,
                                   grads)
        # write back into the flat param list
        flat = [p for sp in params_list for p in sp]
        self.full_params = flat
        self.simulated_time += self.round_latency
        return float(loss)

    def evaluate(self, batch) -> float:
        logits = vgg_lib.forward(self.full_params, batch["images"])
        pred = jnp.argmax(logits, -1)
        return float((pred == batch["labels"]).mean())
