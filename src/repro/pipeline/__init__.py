"""Pipelined execution runtime: schedule analytics, micro-batched executors,
and the shard_map SPMD stage pipeline (the paper's technique as a
first-class runtime feature)."""

from .schedule import (SimResult, memory_highwater, simulate,
                       simulate_from_breakdown)
from .stage import (VGGStage, split_vgg_params, stack_stage_params,
                    transformer_stage_fn, unstack_stage_params,
                    vgg_stages_from_cuts)
from .executor import (LinkHooks, SplitLearningExecutor, microbatch_grads,
                       split_batch)
from .spmd import (PipelineConfig, make_pipelined_loss,
                   make_pipelined_train_step, plan_to_pipeline_config)

__all__ = [
    "SimResult", "memory_highwater", "simulate", "simulate_from_breakdown",
    "VGGStage",
    "split_vgg_params", "stack_stage_params", "transformer_stage_fn",
    "unstack_stage_params", "vgg_stages_from_cuts", "LinkHooks",
    "SplitLearningExecutor", "microbatch_grads", "split_batch",
    "PipelineConfig", "make_pipelined_loss", "make_pipelined_train_step",
    "plan_to_pipeline_config",
]
