"""Submodel (stage) construction from cut layers.

Two param layouts are supported:
  - *list-per-layer* (VGG and other heterogeneous nets): a stage is just
    ``forward(params, x, lo, hi)`` over the python list;
  - *stacked-scan* (all LM families): layer params are stacked on a leading
    axis, so a stage slices ``[lo:hi]`` and scans its own block — this is
    what the spmd pipeline shards across the "stage" mesh axis.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.models import vgg as vgg_lib
from repro.models.common import ArchConfig, remat_wrap
from repro.models import transformer as tf_lib


# ---------------------------------------------------------------------------
# VGG (list-per-layer) stages — the paper's edge-SL submodels
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class VGGStage:
    lo: int
    hi: int

    def init(self, rng):
        return [p for i, p in enumerate(vgg_lib.init_params(rng))
                if self.lo <= i < self.hi]

    def forward(self, stage_params, x):
        for off, i in enumerate(range(self.lo, self.hi)):
            x = vgg_lib.layer_fwd(i, stage_params[off], x)
        return x


def vgg_stages_from_cuts(cuts: Sequence[int]) -> list:
    """cuts: 1-based last layer per submodel (SplitSolution.cuts)."""
    stages, lo = [], 0
    for hi in cuts:
        if hi > lo:
            stages.append(VGGStage(lo, hi))
            lo = hi
    return stages


def split_vgg_params(params: list, cuts: Sequence[int]) -> list:
    out, lo = [], 0
    for hi in cuts:
        if hi > lo:
            out.append(params[lo:hi])
            lo = hi
    return out


# ---------------------------------------------------------------------------
# Stacked-scan transformer stages
# ---------------------------------------------------------------------------

def stack_stage_params(layer_params, num_stages: int):
    """(L, ...) stacked layers -> (S, L/S, ...) per-stage stacking."""
    def resh(x):
        L = x.shape[0]
        assert L % num_stages == 0, (L, num_stages)
        return x.reshape((num_stages, L // num_stages) + x.shape[1:])
    return jax.tree.map(resh, layer_params)


def unstack_stage_params(stage_params):
    def resh(x):
        return x.reshape((x.shape[0] * x.shape[1],) + x.shape[2:])
    return jax.tree.map(resh, stage_params)


def transformer_stage_fn(cfg: ArchConfig):
    """Returns f(stage_layer_params, x) scanning one stage's layer block."""
    def body(x, pl):
        positions = jnp.arange(x.shape[1])
        y, _ = tf_lib.block_fwd(pl, x, cfg, positions=positions, mode="train")
        return y

    body = remat_wrap(body, cfg.remat)

    def stage_fn(stage_layers, x):
        x, _ = jax.lax.scan(lambda c, pl: (body(c, pl), None), x,
                            stage_layers)
        return x

    return stage_fn
