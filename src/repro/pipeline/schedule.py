"""Pipeline schedule timeline — discrete-event validation of Eqs. (13)/(14).

The paper's latency model says a K-stage pipeline with Q identical
micro-batches finishes in

    L_t = T_f + (Q - 1) * T_i                                  (Eq. 14)

with T_i the bottleneck resource time (Eq. 13).  For a *permutation flow
shop with identical jobs* this is exact, so the event simulation below must
reproduce it to float precision when FP and BP engines are modeled as the
paper models them (separate per-node resources, C9/C13 separate) — a strong
internal-consistency check, asserted in tests.

The simulator also supports ``shared_engine=True`` (FP and BP of a node
contend for one engine — a physical single-accelerator node), quantifying
the optimism of the paper's assumption; and reports per-schedule activation
memory high-water marks (GPipe holds Q micro-batches in flight, 1F1B at
most K - k at 0-based stage k), which is why the runtime defaults to
1F1B-depth microbatching when memory-bound.

The closed-form high-water claims come from ``repro.sim.policies`` — the
same :class:`~repro.sim.policies.AdmissionPolicy` objects the discrete-event
engine executes — so ``memory_highwater`` here and the engine's *measured*
per-stage occupancy share one source of truth; ``tests/test_sim.py``
cross-validates them event by event.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.latency import LatencyBreakdown
from repro.sim.policies import resolve_policy


def memory_highwater(num_stages: int, num_microbatches: int,
                     policy="1f1b", *, bind=None) -> dict:
    """Closed-form activation high-water claim per 0-based stage position.

    ``policy`` is an admission-policy name ("fifo"/"gpipe"/"1f1b"/"memory")
    or an ``AdmissionPolicy`` instance; the claim is the most activations
    the schedule ever holds live at each stage.  Plan-dependent policies
    (``"memory"``: windows derived from ``Node.mem`` via the shared
    ``repro.core.cost_model.node_budget_windows`` claims source) need the
    plan context: pass ``bind=(profile, net, sol, b)`` or a pre-bound
    policy instance.

    >>> memory_highwater(3, 12, "1f1b")
    {0: 3, 1: 2, 2: 1}
    >>> memory_highwater(3, 12, "gpipe")
    {0: 12, 1: 12, 2: 12}
    """
    pol = resolve_policy(policy)
    if bind is not None:
        pol = pol.bind(*bind)
    return pol.stage_capacity(num_stages, num_microbatches)


@dataclasses.dataclass
class SimResult:
    makespan: float
    analytic: float            # T_f + (Q-1) * T_i
    rel_gap: float
    resource_busy: dict        # resource -> busy fraction
    memory_factor: dict        # schedule -> in-flight micro-batches per stage


def simulate(stage_fp: Sequence[float], stage_bp: Sequence[float],
             link_fwd: Sequence[float], link_bwd: Sequence[float],
             num_microbatches: int, *, shared_engine: bool = False
             ) -> SimResult:
    """FIFO event simulation of the pipelined FP+BP flow.

    stage_fp/bp: per-stage seconds per micro-batch (len K);
    link_fwd/bwd: per-link seconds (len K-1).
    """
    K = len(stage_fp)
    Q = num_microbatches
    # visit order per micro-batch: fp1, fwd1, fp2, ... fpK, bpK, bwdK-1, ...
    visits = []
    for k in range(K):
        visits.append((("node", k) if shared_engine else ("fp", k),
                       stage_fp[k]))
        if k < K - 1:
            visits.append((("fwd", k), link_fwd[k]))
    for k in reversed(range(K)):
        visits.append((("node", k) if shared_engine else ("bp", k),
                       stage_bp[k]))
        if k > 0:
            visits.append((("bwd", k - 1), link_bwd[k - 1]))

    avail: dict = {}
    busy: dict = {}
    makespan = 0.0
    for q in range(Q):
        t = 0.0
        for res, dur in visits:
            start = max(t, avail.get(res, 0.0))
            t = start + dur
            avail[res] = t
            busy[res] = busy.get(res, 0.0) + dur
        makespan = max(makespan, t)

    T_f = sum(d for _, d in visits)
    if shared_engine:
        node_time = {}
        for res, dur in visits:
            node_time[res] = node_time.get(res, 0.0) + dur
        T_i = max(node_time.values())
    else:
        T_i = max(d for _, d in visits) if visits else 0.0
        per_res = {}
        for res, dur in visits:
            per_res[res] = per_res.get(res, 0.0) + dur
        T_i = max(per_res.values())
    analytic = T_f + (Q - 1) * T_i
    mem = {
        "gpipe": memory_highwater(K, Q, "gpipe"),
        "1f1b": memory_highwater(K, Q, "1f1b"),
    }
    return SimResult(
        makespan=makespan, analytic=analytic,
        rel_gap=(makespan - analytic) / analytic if analytic else 0.0,
        resource_busy={r: b / makespan for r, b in busy.items()},
        memory_factor=mem)


def simulate_from_breakdown(bd: LatencyBreakdown, num_microbatches: int,
                            **kw) -> SimResult:
    """Adapter from core.latency.breakdown() (paper-model component times)."""
    ks = sorted(bd.stage_fp)
    fp = [bd.stage_fp[k] for k in ks]
    bp = [bd.stage_bp[k] for k in ks]
    fwd = [t for _, t in sorted(bd.link_fwd.items())]   # keyed (k, n, n')
    bwd = [t for _, t in sorted(bd.link_bwd.items())]   # keyed (k, n', n)
    return simulate(fp, bp, fwd, bwd, num_microbatches, **kw)
