"""Algorithm 2 — BCD over (MSP) and (micro-batch size).

    b^0 = init;  repeat:
        (x, y, T_1) <- Algorithm 1 with b fixed          (core.shortest_path)
        b           <- Theorem 1  with (x, y, T_1) fixed (core.microbatch)
    until |L_t^tau - L_t^(tau-1)| < theta  or  max_iters

Each block is solved optimally, so L_t is non-increasing across iterations
(asserted in tests) and the loop converges in a few iterations (Fig. 7 shows
the fixed point is near the joint optimum).

The returned ``Plan`` is what the rest of the repo consumes: the simulator
executes it (``repro.sim.simulate_plan``), the jax runtime maps it to stage
functions, and the elastic coordinator replans it on failures.

>>> import math
>>> from repro.core import make_edge_network, vgg16_profile
>>> prof = vgg16_profile(work_units="bytes")
>>> net = make_edge_network(num_servers=4, num_clients=4, seed=1,
...                         kappa=1 / 32.0)
>>> plan = bcd_solve(prof, net, B=64, b0=8)
>>> plan.feasible, 1 <= plan.b <= 64
(True, True)
>>> bool(plan.L_t ==
...      plan.T_f + math.ceil((plan.B - plan.b) / plan.b) * plan.T_i)
True
"""

from __future__ import annotations

import dataclasses
import math
import time

from . import latency as L
from .latency import SplitSolution
from .microbatch import optimal_microbatch
from .network import EdgeNetwork
from .profiles import ModelProfile
from .shortest_path import DEFAULT_SOLVER, MSPResult, Planner, solve_msp


@dataclasses.dataclass
class Plan:
    """A fully-specified pipelined-SL execution plan."""
    solution: SplitSolution
    b: int
    B: int
    T_f: float
    T_i: float
    L_t: float
    iterations: int
    history: list            # [(L_t, b, cuts, placement), ...] per iteration
    solve_seconds: float
    feasible: bool = True

    @property
    def num_microbatches(self) -> int:
        return math.ceil(self.B / self.b) if self.b else 0


def bcd_solve(profile: ModelProfile, net: EdgeNetwork, B: int,
              b0: int = 20, theta: float = 0.01, max_iters: int = 12,
              K: int | None = None, memory_model: str = "paper",
              refine_b: bool = True, solver: str | None = None,
              planner: Planner | None = None) -> Plan:
    """Algorithm 2.  ``theta`` is the convergence tolerance (Table II: 0.01).

    ``refine_b`` (beyond-paper, default on): Theorem 1 minimizes
    T_f(b) + xi(b)*T_1 with T_1 *fixed* from the previous MSP solve — but
    the true T_i(b) scales DOWN with b, so the alternation's fixed point
    systematically overshoots the micro-batch size (measured ~35% latency
    gap vs exhaustive on sub-second instances; see benchmarks/fig7).  The
    refinement replaces the final micro-batching step with an exact 1-D
    scan of the TRUE Eq. (14) objective over b (O(B) cheap evaluations),
    then re-runs Algorithm 1 once at the refined b.  Set False for the
    paper-faithful variant (reported separately in Fig. 7).

    ``solver`` selects the Algorithm-1 strategy ("batched" default, "scan"
    reference); a shared ``planner`` (graph factory + DP buffers) is created
    once per solve and reused across every BCD iteration — pass one in to
    amortize it further (e.g. across multi-start restarts).
    """
    t_start = time.perf_counter()
    if planner is None:
        planner = Planner(profile, net, memory_model)
    elif planner.memory_model != memory_model:
        raise ValueError(
            f"planner was built with memory_model={planner.memory_model!r} "
            f"but bcd_solve was called with {memory_model!r}")
    b = max(1, min(b0, B))
    history = []
    prev_L = math.inf
    best: MSPResult | None = None
    iters = 0
    for tau in range(1, max_iters + 1):
        iters = tau
        msp = planner.solve(b, B, K=K, solver=solver)
        if not msp.feasible:
            # shrink b: memory may be the blocker at this micro-batch size
            if b > 1:
                b = max(1, b // 2)
                continue
            return Plan(solution=SplitSolution((profile.num_layers,), (0,)),
                        b=0, B=B, T_f=math.inf, T_i=math.inf, L_t=math.inf,
                        iterations=tau, history=history,
                        solve_seconds=time.perf_counter() - t_start,
                        feasible=False)
        mb = optimal_microbatch(profile, net, msp.solution, B, msp.T_1,
                                memory_model=memory_model)
        if mb.b > 0:
            b = mb.b
        L_t = L.total_latency(profile, net, msp.solution, b, B)
        history.append((L_t, b, msp.solution.cuts, msp.solution.placement))
        best = msp
        # convergence: theta acts RELATIVE to the current latency scale
        # (Table II's theta=0.01 against ~100 s latencies; an absolute
        # 0.01 s would stop sub-second instances after one iteration)
        if abs(prev_L - L_t) < theta * max(L_t, 1e-12):
            break
        prev_L = L_t
    sol = best.solution

    if refine_b:
        from .microbatch import exhaustive_microbatch
        b_ref, _ = exhaustive_microbatch(profile, net, sol, B, T_1=None,
                                         memory_model=memory_model)
        if b_ref > 0 and b_ref != b:
            msp2 = planner.solve(b_ref, B, K=K, solver=solver)
            if msp2.feasible:
                cand_sol, cand_b = msp2.solution, b_ref
                b_ref2, _ = exhaustive_microbatch(
                    profile, net, cand_sol, B, T_1=None,
                    memory_model=memory_model)
                if b_ref2 > 0:
                    cand_b = b_ref2
                if (L.total_latency(profile, net, cand_sol, cand_b, B)
                        < L.total_latency(profile, net, sol, b, B)):
                    sol, b = cand_sol, cand_b
                    history.append((
                        L.total_latency(profile, net, sol, b, B), b,
                        sol.cuts, sol.placement))

    T_f = L.fill_latency(profile, net, sol, b)
    T_i = L.pipeline_interval(profile, net, sol, b)
    return Plan(solution=sol, b=b, B=B, T_f=T_f, T_i=T_i,
                L_t=T_f + L.num_fills(B, b) * T_i, iterations=iters,
                history=history, solve_seconds=time.perf_counter() - t_start)


def exhaustive_joint(profile: ModelProfile, net: EdgeNetwork, B: int,
                     K: int | None = None, memory_model: str = "paper",
                     b_step: int = 1, solver: str | None = None) -> Plan:
    """Fig. 7's 'optimal scheme': exhaustive over b, Algorithm 1 per b.

    With ``solver="batched"`` (default) the whole b-sweep is dispatched as
    stacked multi-slice kernel sweeps through one shared ``Planner``
    (``Planner.solve_many``): graphs assemble by broadcasting from one
    ``GraphFactory`` and all b ride the kernel's slice axis.  With
    ``solver="scan"`` each b pays the legacy per-b rebuild + threshold scan
    — the reference the ISSUE-3 benchmark measures speedup against."""
    t_start = time.perf_counter()
    solver = solver or DEFAULT_SOLVER
    bs = list(range(1, B + 1, b_step))
    if solver == "batched":
        planner = Planner(profile, net, memory_model)
        msps = planner.solve_many(bs, B, K=K)
    else:
        msps = [solve_msp(profile, net, b, B, K=K, memory_model=memory_model,
                          solver=solver) for b in bs]
    best_plan = None
    for b, msp in zip(bs, msps):
        if not msp.feasible:
            continue
        L_t = L.total_latency(profile, net, msp.solution, b, B)
        if best_plan is None or L_t < best_plan.L_t:
            best_plan = Plan(
                solution=msp.solution, b=b, B=B,
                T_f=L.fill_latency(profile, net, msp.solution, b),
                T_i=L.pipeline_interval(profile, net, msp.solution, b),
                L_t=L_t, iterations=1, history=[],
                solve_seconds=0.0)
    if best_plan is None:
        return Plan(solution=SplitSolution((profile.num_layers,), (0,)),
                    b=0, B=B, T_f=math.inf, T_i=math.inf, L_t=math.inf,
                    iterations=0, history=[], feasible=False,
                    solve_seconds=time.perf_counter() - t_start)
    return dataclasses.replace(best_plan,
                               solve_seconds=time.perf_counter() - t_start)
