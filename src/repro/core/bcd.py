"""Algorithm 2 — BCD over (MSP) and (micro-batch size).

    b^0 = init;  repeat:
        (x, y, T_1) <- Algorithm 1 with b fixed          (core.shortest_path)
        b           <- Theorem 1  with (x, y, T_1) fixed (core.microbatch)
    until |L_t^tau - L_t^(tau-1)| < theta  or  max_iters

Each block is solved optimally, so L_t is non-increasing across iterations
(asserted in tests) and the loop converges in a few iterations (Fig. 7 shows
the fixed point is near the joint optimum).  The objective — and the memory
predicate behind the feasible-b box — is pluggable (``cost_model=``, see
``repro.core.cost_model``): the default ``ClosedForm`` reproduces the
Eq. (12)-(14) path bit-for-bit, while ``SimMakespan`` scores iterates and
the final micro-batch refinement with the measured makespan of
``sim.simulate_plan`` under (by default) memory-budgeted admission; the
incumbent's objective stays non-increasing per model.

The returned ``Plan`` is what the rest of the repo consumes: the simulator
executes it (``repro.sim.simulate_plan``), the jax runtime maps it to stage
functions, and the elastic coordinator replans it on failures.

>>> import math
>>> from repro.core import make_edge_network, vgg16_profile
>>> prof = vgg16_profile(work_units="bytes")
>>> net = make_edge_network(num_servers=4, num_clients=4, seed=1,
...                         kappa=1 / 32.0)
>>> plan = bcd_solve(prof, net, B=64, b0=8)
>>> plan.feasible, 1 <= plan.b <= 64
(True, True)
>>> bool(plan.L_t ==
...      plan.T_f + math.ceil((plan.B - plan.b) / plan.b) * plan.T_i)
True
"""

from __future__ import annotations

import dataclasses
import math
import time

from repro import obs

from . import latency as L
from .cost_model import ClosedForm, memoized_cost_model, resolve_cost_model
from .latency import SplitSolution
from .microbatch import optimal_microbatch
from .network import EdgeNetwork
from .profiles import ModelProfile
from .shortest_path import DEFAULT_SOLVER, Planner, solve_msp


@dataclasses.dataclass
class Plan:
    """A fully-specified pipelined-SL execution plan.

    ``L_t``/``T_f``/``T_i`` are always the closed-form Eqs. (12)-(14)
    numbers so plans stay comparable across cost models; ``objective`` is
    the solving cost model's own metric at the final plan (equal to ``L_t``
    under the default ``ClosedForm``, the simulated makespan under
    ``SimMakespan``), and ``cost_model`` names it.
    """
    solution: SplitSolution
    b: int
    B: int
    T_f: float
    T_i: float
    L_t: float
    iterations: int
    history: list            # [(objective, b, cuts, placement)] per iteration
    solve_seconds: float
    feasible: bool = True
    objective: float = math.nan
    cost_model: str = "closed_form"

    @property
    def num_microbatches(self) -> int:
        return math.ceil(self.B / self.b) if self.b else 0


def bcd_solve(profile: ModelProfile, net: EdgeNetwork, B: int,
              b0: int = 20, theta: float = 0.01, max_iters: int = 12,
              K: int | None = None, memory_model: str = "paper",
              refine_b: bool = True, solver: str | None = None,
              planner: Planner | None = None, cost_model=None) -> Plan:
    """Algorithm 2.  ``theta`` is the convergence tolerance (Table II: 0.01).

    ``refine_b`` (beyond-paper, default on): Theorem 1 minimizes
    T_f(b) + xi(b)*T_1 with T_1 *fixed* from the previous MSP solve — but
    the true T_i(b) scales DOWN with b, so the alternation's fixed point
    systematically overshoots the micro-batch size (measured ~35% latency
    gap vs exhaustive on sub-second instances; see benchmarks/fig7).  The
    refinement replaces the final micro-batching step with an exact 1-D
    scan of the TRUE objective over b (O(B) evaluations), then re-runs
    Algorithm 1 once at the refined b.  Set False for the paper-faithful
    variant (reported separately in Fig. 7).

    ``cost_model`` selects what "the TRUE objective" means
    (``repro.core.cost_model``): the default ``ClosedForm`` is bit-identical
    to the historical hard-wired Eq. (14) path; ``SimMakespan`` scores every
    iterate and the final refinement with the *measured* makespan of
    ``sim.simulate_plan`` (which charges reentrant/co-location idle time and
    respects memory-budgeted admission), and its memory predicate reshapes
    the feasible-b box.  Candidate generation stays the paper's closed-form
    alternation either way; the cost model decides which iterate is kept
    (best-so-far, so ``history`` objectives are non-increasing under the
    chosen metric) and how the final micro-batch size is refined.  A
    non-ClosedForm model additionally warm-starts its incumbent from the
    closed-form plan (same arguments, shared planner caches) scored under
    the new metric — so the returned plan is never worse than the
    closed-form plan under the model's own objective, by construction.

    ``solver`` selects the Algorithm-1 strategy ("batched" default, "scan"
    reference); a shared ``planner`` (graph factory + DP buffers) is created
    once per solve and reused across every BCD iteration — pass one in to
    amortize it further (e.g. across multi-start restarts).
    """
    with obs.span("bcd.solve", B=B, b0=b0,
                  cost_model=getattr(cost_model, "name", cost_model)):
        return _bcd_solve(profile, net, B, b0=b0, theta=theta,
                          max_iters=max_iters, K=K,
                          memory_model=memory_model, refine_b=refine_b,
                          solver=solver, planner=planner,
                          cost_model=cost_model)


def _bcd_solve(profile: ModelProfile, net: EdgeNetwork, B: int,
               b0: int = 20, theta: float = 0.01, max_iters: int = 12,
               K: int | None = None, memory_model: str = "paper",
               refine_b: bool = True, solver: str | None = None,
               planner: Planner | None = None, cost_model=None) -> Plan:
    t_start = time.perf_counter()
    # per-solve memo: iterate scores repeat once the alternation stabilizes,
    # and the warm start + refinement sweeps revisit the same candidates —
    # a measured (simulated) objective is only ever computed once per
    # (cuts, placement, b).  ClosedForm passes through unwrapped.
    cm = memoized_cost_model(resolve_cost_model(cost_model, memory_model))
    if planner is None:
        planner = Planner(profile, net, memory_model)
    elif planner.memory_model != memory_model:
        raise ValueError(
            f"planner was built with memory_model={planner.memory_model!r} "
            f"but bcd_solve was called with {memory_model!r}")
    b = max(1, min(b0, B))
    history = []
    prev_obj = math.inf
    best: tuple | None = None           # (solution, b, objective) incumbent

    def infeasible_plan(tau):
        return Plan(solution=SplitSolution((profile.num_layers,), (0,)),
                    b=0, B=B, T_f=math.inf, T_i=math.inf, L_t=math.inf,
                    iterations=tau, history=history,
                    solve_seconds=time.perf_counter() - t_start,
                    feasible=False, objective=math.inf, cost_model=cm.name)

    if isinstance(cm, ClosedForm):
        # the historical interleaved alternation, untouched (objective
        # evaluations are closed-form-cheap; this path stays bit-identical)
        iters = 0
        for tau in range(1, max_iters + 1):
            iters = tau
            obs.inc("bcd.iterations")
            with obs.span("bcd.iterate", tau=tau, b=b):
                msp = planner.solve(b, B, K=K, solver=solver)
                if not msp.feasible:
                    # shrink b: memory may be the blocker at this size
                    if b > 1:
                        b = max(1, b // 2)
                        continue
                    return infeasible_plan(tau)
                mb = optimal_microbatch(profile, net, msp.solution, B,
                                        msp.T_1, memory_model=memory_model,
                                        cost_model=cm)
                if mb.b > 0:
                    b = mb.b
                obj = cm.evaluate(profile, net, msp.solution, b, B)
            # ties move forward, tracking the paper's always-move
            # alternation, whose objective is non-increasing anyway
            if best is None or obj <= best[2]:
                best = (msp.solution, b, obj)
            history.append((best[2], best[1], best[0].cuts,
                            best[0].placement))
            # convergence: theta acts RELATIVE to the current latency scale
            # (Table II's theta=0.01 against ~100 s latencies; an absolute
            # 0.01 s would stop sub-second instances after one iteration)
            # (the equality leg catches obj == prev_obj == inf, where the
            # subtraction would yield NaN and never satisfy the tolerance)
            if prev_obj == obj or \
                    abs(prev_obj - obj) < theta * max(obj, 1e-12):
                break
            prev_obj = obj
    else:
        # warm start: the closed-form plan, re-scored under this model —
        # guarantees the result is never worse than the closed form's plan
        # on the model's own metric, whatever the trajectories do
        seed = bcd_solve(profile, net, B, b0=b0, theta=theta,
                         max_iters=max_iters, K=K, memory_model=memory_model,
                         refine_b=refine_b, solver=solver, planner=planner)
        if not (seed.feasible and seed.b > 0):
            seed = None
        # Generate the alternation's iterates objective-free: the iterate
        # sequence (MSP solution + micro-batch trajectory) is pure
        # closed-form work — the measured objective only decides the
        # stopping point and the kept incumbent.  Scoring afterwards lets
        # the model batch every iterate, plus the warm-start seed, through
        # ONE evaluate_many (the engine's stacked plan axis); replaying the
        # stopping rule over the scores reproduces the interleaved loop's
        # plan, history and iteration count exactly.  A repeated
        # (solution, b) iterate is the alternation's fixed point (the map
        # is deterministic in b): later taus add no new candidates, and the
        # replay is guaranteed to stop at the repeat (equal objectives).
        iters = 0
        iterates: list = []             # (tau, solution, b) per scored tau
        infeasible_at = None            # tau of a b == 1 infeasible solve
        for tau in range(1, max_iters + 1):
            iters = tau
            obs.inc("bcd.iterations")
            with obs.span("bcd.iterate", tau=tau, b=b):
                msp = planner.solve(b, B, K=K, solver=solver)
                if not msp.feasible:
                    if b > 1:
                        b = max(1, b // 2)
                        continue
                    infeasible_at = tau
                    break
                mb = optimal_microbatch(profile, net, msp.solution, B,
                                        msp.T_1, memory_model=memory_model,
                                        cost_model=cm)
                if mb.b > 0:
                    b = mb.b
            iterates.append((tau, msp.solution, b))
            if len(iterates) >= 2 and iterates[-1][1:] == iterates[-2][1:]:
                break
        cands = ([(seed.solution, seed.b)] if seed is not None else []) \
            + [(s, bb) for _, s, bb in iterates]
        objs = cm.evaluate_many(profile, net, cands, B)
        if seed is not None:
            best = (seed.solution, seed.b, objs[0])
            history.append((best[2], best[1], best[0].cuts,
                            best[0].placement))
            objs = objs[1:]
        stopped = False
        for (tau, i_sol, i_b), obj in zip(iterates, objs):
            # under a measured metric a closed-form step may regress — the
            # incumbent simply survives it (ties move forward)
            if best is None or obj <= best[2]:
                best = (i_sol, i_b, obj)
            history.append((best[2], best[1], best[0].cuts,
                            best[0].placement))
            if prev_obj == obj or \
                    abs(prev_obj - obj) < theta * max(obj, 1e-12):
                iters = tau
                stopped = True
                break
            prev_obj = obj
        if infeasible_at is not None and not stopped:
            # the interleaved loop would have reached this tau un-stopped
            # and given up exactly here
            return infeasible_plan(infeasible_at)
    if best is None:
        return infeasible_plan(iters)
    sol, b, obj = best

    if refine_b:
        from .microbatch import exhaustive_microbatch
        # candidate 1: exact 1-D scan of the cost-model objective over the
        # model's feasible-b box, split fixed (the box feeds back here)
        b_ref, val_ref = exhaustive_microbatch(profile, net, sol, B,
                                               T_1=None,
                                               memory_model=memory_model,
                                               cost_model=cm)
        if b_ref > 0 and b_ref != b:
            if val_ref < obj:
                sol, b, obj = sol, b_ref, val_ref
                history.append((obj, b, sol.cuts, sol.placement))
            # candidate 2: re-run Algorithm 1 once at the refined b, then
            # re-refine b on the (possibly new) split
            msp2 = planner.solve(b_ref, B, K=K, solver=solver)
            if msp2.feasible and msp2.solution != sol:
                cand_sol, cand_b = msp2.solution, b_ref
                b_ref2, val2 = exhaustive_microbatch(
                    profile, net, cand_sol, B, T_1=None,
                    memory_model=memory_model, cost_model=cm)
                if b_ref2 > 0:
                    cand_b, cand_obj = b_ref2, val2
                else:
                    cand_obj = cm.evaluate(profile, net, cand_sol, cand_b, B)
                if cand_obj < obj:
                    sol, b, obj = cand_sol, cand_b, cand_obj
                    history.append((obj, b, sol.cuts, sol.placement))

    if math.isinf(obj):
        # no iterate (nor the warm start) was feasible under the cost model
        # — mirror exhaustive_joint: an inf-objective plan is not runnable
        # (simulate_plan would refuse it), so don't report it feasible
        return Plan(solution=SplitSolution((profile.num_layers,), (0,)),
                    b=0, B=B, T_f=math.inf, T_i=math.inf, L_t=math.inf,
                    iterations=iters, history=history,
                    solve_seconds=time.perf_counter() - t_start,
                    feasible=False, objective=math.inf, cost_model=cm.name)
    T_f = L.fill_latency(profile, net, sol, b)
    T_i = L.pipeline_interval(profile, net, sol, b)
    return Plan(solution=sol, b=b, B=B, T_f=T_f, T_i=T_i,
                L_t=T_f + L.num_fills(B, b) * T_i, iterations=iters,
                history=history, solve_seconds=time.perf_counter() - t_start,
                objective=obj, cost_model=cm.name)


def exhaustive_joint(profile: ModelProfile, net: EdgeNetwork, B: int,
                     K: int | None = None, memory_model: str = "paper",
                     b_step: int = 1, solver: str | None = None,
                     cost_model=None, backend: str = "numpy") -> Plan:
    """Fig. 7's 'optimal scheme': exhaustive over b, Algorithm 1 per b.

    With ``solver="batched"`` (default) the whole b-sweep is dispatched as
    stacked multi-slice kernel sweeps through one shared ``Planner``
    (``Planner.solve_many``): graphs assemble by broadcasting from one
    ``GraphFactory`` and all b ride the kernel's slice axis.  With
    ``solver="scan"`` each b pays the legacy per-b rebuild + threshold scan
    — the reference the ISSUE-3 benchmark measures speedup against.

    ``cost_model`` scores the per-b plans (default ``ClosedForm``: Eq. 14;
    ``SimMakespan``: measured makespan — the exhaustive counterpart of the
    sim-refined BCD).  ``backend="jax"`` routes the batched b-sweep through
    the compiled ``planner_jax`` pipeline (ISSUE 9)."""
    t_start = time.perf_counter()
    cm = memoized_cost_model(resolve_cost_model(cost_model, memory_model))
    solver = solver or DEFAULT_SOLVER
    bs = list(range(1, B + 1, b_step))
    if solver == "batched":
        planner = Planner(profile, net, memory_model)
        msps = planner.solve_many(bs, B, K=K, backend=backend)
    else:
        msps = [solve_msp(profile, net, b, B, K=K, memory_model=memory_model,
                          solver=solver) for b in bs]
    # iterate selection through the batched scorer (stacked plan axis for
    # SimMakespan; a plain evaluate loop — same floats — for ClosedForm)
    live = [(b, msp) for b, msp in zip(bs, msps) if msp.feasible]
    objs = cm.evaluate_many(profile, net,
                            [(msp.solution, b) for b, msp in live], B)
    best_plan = None
    for (b, msp), obj in zip(live, objs):
        if best_plan is None or obj < best_plan.objective:
            best_plan = Plan(
                solution=msp.solution, b=b, B=B,
                T_f=L.fill_latency(profile, net, msp.solution, b),
                T_i=L.pipeline_interval(profile, net, msp.solution, b),
                L_t=L.total_latency(profile, net, msp.solution, b, B),
                iterations=1, history=[],
                solve_seconds=0.0, objective=obj, cost_model=cm.name)
    if best_plan is None or math.isinf(best_plan.objective):
        return Plan(solution=SplitSolution((profile.num_layers,), (0,)),
                    b=0, B=B, T_f=math.inf, T_i=math.inf, L_t=math.inf,
                    iterations=0, history=[], feasible=False,
                    solve_seconds=time.perf_counter() - t_start,
                    objective=math.inf, cost_model=cm.name)
    return dataclasses.replace(best_plan,
                               solve_seconds=time.perf_counter() - t_start)
