"""Graph representation of the MSP problem (Sec. V-D, Eqs. 20-22).

The paper's vertex v^{k,n}_{(i-m),i} ("submodel k = layers i-m..i on node n")
admits a compact *state* encoding: because the next segment always starts at
the current segment's end, the reachable-cost state is ``(k, n, i)`` =
"the k-th (non-empty) submodel ends at layer i on node n".  An edge

    (k, n, i)  ->  (k+1, n', j)      with j > i, n' a server, n' != n

carries the Eq. (22) weight *folded onto the head vertex*:

    c = t^F_comm(cut i, n->n') + t^B_comm(cut i, n'->n)
      + t^F((i, j], n') + t^B((i, j], n')

so that a source->dest path cost equals T_f of Eq. (12) exactly.  (The paper
prints zero-weight terminal edges, which would drop stage-K compute from T_f;
we keep stage compute on the head so the sum is exact — noted in DESIGN.md §6.)

Each edge also carries the *bottleneck* value

    beta = max(t^F_comm, t^B_comm, t^F_head, t^B_head)

so a path's max-beta equals T_i of Eq. (13) whenever no node hosts two
submodels (paper mode; see DESIGN.md §6 for the exact-mode discussion).

Everything is materialized as dense numpy arrays over the *factored* edge
space — communication terms over ``(i, n, n')`` and segment terms over
``(n', i, j)`` — so Algorithm 1's shortest-path sweeps are vectorized and an
edge weight is recovered as ``comm + segment`` on demand.

``GraphFactory`` separates the b-independent precomputation (per-sample
segment workloads, per-cut byte volumes, rate matrices, node constants) from
the b-dependent assembly (a handful of broadcast multiplies), so the BCD loop
and the micro-batch sweep of ``exhaustive_joint`` rebuild graphs in
microseconds instead of re-running a Python double loop per b (ISSUE 3).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .latency import SplitSolution, client_max_share
from .network import EdgeNetwork
from .profiles import ModelProfile


@dataclasses.dataclass
class MSPGraph:
    """Dense arrays over the layered edge space.

    Shapes: ``N`` nodes (index 0 = client tier), ``I`` layers.
      seg_cost[n, i, j]   compute (FP+BP) of segment (i, j] on node n; inf if
                          j <= i or memory-infeasible on n  (i, j in 0..I)
      seg_beta[n, i, j]   max(FP, BP) of that segment
      comm_cost[i, n, m]  fwd + bwd comm across cut i between nodes n -> m
      comm_beta[i, n, m]  max(fwd, bwd) across cut i
      src_cost[i]         client segment (0, i] compute cost (FP+BP)
      src_beta[i]         max(FP, BP) of the client segment
    """
    profile: ModelProfile
    net: EdgeNetwork
    b: int
    seg_cost: np.ndarray
    seg_beta: np.ndarray
    comm_cost: np.ndarray
    comm_beta: np.ndarray
    src_cost: np.ndarray
    src_beta: np.ndarray

    @property
    def I(self) -> int:
        return self.profile.num_layers

    @property
    def N(self) -> int:
        return len(self.net.nodes)

    def edge_cost(self, n: int, i: int, m: int, j: int) -> float:
        """Full edge weight (comm across cut i) + (head segment (i,j] on m)."""
        return float(self.comm_cost[i, n, m] + self.seg_cost[m, i, j])

    def edge_beta(self, n: int, i: int, m: int, j: int) -> float:
        return float(max(self.comm_beta[i, n, m], self.seg_beta[m, i, j]))


class GraphFactory:
    """b-independent precomputation for MSP graph assembly.

    Everything that does not depend on the micro-batch size b — cumulative
    segment workloads delta^F/delta^B over every (i, j] range, per-sample
    memory footprints, per-cut activation/gradient byte volumes, link-rate
    reciprocals, and the node constant vectors — is computed once here.
    ``graph(b)`` then assembles an :class:`MSPGraph` with pure broadcasting:

        seg_cost(b) = eff(b) * kappa * delta^F / f + t0
                    + max(0, eff(b) - b_th) * kappa * delta^B / f + t1
        comm_cost(b) = eff(b) * phi_i / r_{nm} + eff(b) * phi'_i / r_{mn}

    where ``eff(b)`` is b for servers and the Eq. (1) max client share for
    the virtual client node.  Building a factory is O(N I^2); each
    ``graph(b)`` is a few fused array ops, so Algorithm 2's BCD iterations
    and the b-sweep of ``exhaustive_joint`` stop paying a per-b rebuild.
    """

    def __init__(self, profile: ModelProfile, net: EdgeNetwork,
                 memory_model: str = "paper"):
        self.profile, self.net, self.memory_model = profile, net, memory_model
        I = profile.num_layers
        N = len(net.nodes)
        self.I, self.N = I, N
        I1 = I + 1

        # node constant vectors
        self.f = np.array([n.f for n in net.nodes])
        self.kappa = np.array([n.kappa for n in net.nodes])
        self.t0 = np.array([n.t0 for n in net.nodes])
        self.t1 = np.array([n.t1 for n in net.nodes])
        self.b_th = np.array([float(n.b_th) for n in net.nodes])
        self.mem = np.array([n.mem for n in net.nodes])

        # per-sample segment workloads over every (i, j] range, (I1, I1)
        def seg_table(per_layer: np.ndarray) -> np.ndarray:
            c = np.concatenate([[0.0], np.cumsum(per_layer)])
            return c[None, :] - c[:, None]          # [i, j] = cum[j] - cum[i]

        self.W_fp = seg_table(profile.fp_work)
        self.W_bp = seg_table(profile.bp_work)
        # Eq. (11) per-sample footprints: paper model scales everything by b;
        # refined model scales only activations/grads (static part separate)
        self.Mem_ps = seg_table(profile.act_bytes + profile.grad_bytes +
                                profile.param_bytes + profile.opt_bytes)
        self.Mem_act = seg_table(profile.act_bytes + profile.grad_bytes)
        self.Mem_static = seg_table(profile.param_bytes + profile.opt_bytes)
        # valid segment ranges: [i, j] with j > i
        self.tri = np.arange(I1)[None, :] > np.arange(I1)[:, None]

        # per-sample byte volumes per cut i (1-based; row 0 unused -> inf comm)
        self.fb1 = np.concatenate([[0.0], profile.act_bytes])   # phi_i
        self.gb1 = np.concatenate([[0.0], profile.grad_bytes])  # phi'_i

        self.rate = net.rate                                    # (N, N)
        self.rate_T = net.rate.T

    # -- in-place patching (Planner.update; ISSUE 9) ------------------------
    def patch_rate(self, net: EdgeNetwork) -> None:
        """Rebind to a network whose ``rate`` matrix changed (same nodes).

        Only the rate views are swapped; every other basis tensor is
        b-independent of link rates, so cached graphs stay valid except for
        the comm entries of the changed link pair (see :meth:`comm_pair`)."""
        self.net = net
        self.rate = net.rate
        self.rate_T = net.rate.T

    def patch_node_speed(self, net: EdgeNetwork) -> None:
        """Rebind to a network whose node ``f`` vector changed (same nodes,
        same rates) — the straggler mutation.  Cached graphs stay valid
        except the seg row of the changed node (see :meth:`seg_node`)."""
        self.net = net
        self.f = np.array([n.f for n in net.nodes])

    def comm_pair(self, eff: np.ndarray, a: int, c: int):
        """``(comm_cost[:, a, c], comm_beta[:, a, c])`` columns for the
        *current* rate matrix — the same formula chain as :meth:`graph`
        restricted to one (n, m) pair, so patched entries are bitwise equal
        to a fresh assembly (every op is the identical IEEE-754 op on the
        identical operands)."""
        fb = eff[a] * self.fb1                       # (I1,) fwd bytes at cut i
        gb = eff[a] * self.gb1                       # (I1,) bwd bytes at cut i
        # both byte volumes scale with eff of the *forward sender* a — the
        # gradient flows back to a, whose effective batch sizes the tensor
        r, rT = self.rate[a, c], self.rate_T[a, c]
        with np.errstate(divide="ignore", invalid="ignore"):
            tf = np.where(fb == 0.0, 0.0,
                          np.where(r > 0, fb / r, np.inf))
            tb = np.where(gb == 0.0, 0.0,
                          np.where(rT > 0, gb / rT, np.inf))
        cost = tf + tb
        beta = np.maximum(tf, tb)
        cost[0] = np.inf
        beta[0] = np.inf
        if a == c:
            cost[:] = np.inf
            beta[:] = np.inf
        return cost, beta

    def seg_node(self, eff: np.ndarray, n: int):
        """``(seg_cost[n], seg_beta[n])`` rows (I1, I1) for the *current*
        node constants — :meth:`graph`'s segment formulas restricted to one
        node, bitwise equal to a fresh assembly (same op chain)."""
        e = eff[n]
        fp = (e * self.kappa[n]) * self.W_fp / self.f[n] + self.t0[n]
        bp_w = (np.maximum(e - self.b_th[n], 0.0) * self.kappa[n]) * self.W_bp
        bp = np.where(bp_w == 0.0, self.t1[n], bp_w / self.f[n] + self.t1[n])
        if self.memory_model == "paper":
            mem_ok = e * self.Mem_ps <= self.mem[n]
        else:
            mem_ok = e * self.Mem_act + self.Mem_static <= self.mem[n]
        ok = self.tri & mem_ok
        seg_cost = np.where(ok, fp + bp, np.inf)
        seg_beta = np.where(ok, np.maximum(fp, bp), np.inf)
        return seg_cost, seg_beta

    # -- assembly -----------------------------------------------------------
    def effective_batch(self, b: int) -> np.ndarray:
        """Per-node effective micro-batch: Eq. (1) max share on the client
        tier (node 0), b everywhere else."""
        eff = np.full(self.N, float(b))
        eff[0] = float(client_max_share(b, self.net.num_clients))
        return eff

    def graph(self, b: int) -> MSPGraph:
        """Assemble the dense MSPGraph for micro-batch size b (broadcast-only)."""
        I1 = self.I + 1
        eff = self.effective_batch(b)

        # segments: (N, I1, I1) over [n, i, j]
        e = eff[:, None, None]
        fp = (e * self.kappa[:, None, None]) * self.W_fp[None] \
            / self.f[:, None, None] + self.t0[:, None, None]
        bp_w = (np.maximum(e - self.b_th[:, None, None], 0.0)
                * self.kappa[:, None, None]) * self.W_bp[None]
        bp = np.where(bp_w == 0.0, self.t1[:, None, None],
                      bp_w / self.f[:, None, None] + self.t1[:, None, None])
        if self.memory_model == "paper":
            mem_ok = e * self.Mem_ps[None] <= self.mem[:, None, None]
        else:
            mem_ok = (e * self.Mem_act[None] + self.Mem_static[None]
                      <= self.mem[:, None, None])
        ok = self.tri[None] & mem_ok
        seg_cost = np.where(ok, fp + bp, np.inf)
        seg_beta = np.where(ok, np.maximum(fp, bp), np.inf)

        # comms: (I1, N, N) over [i, n, m]
        fb = eff[None, :] * self.fb1[:, None]       # (I1, N) bytes fwd at cut i
        gb = eff[None, :] * self.gb1[:, None]       # (I1, N) bytes bwd at cut i
        with np.errstate(divide="ignore", invalid="ignore"):
            tf = np.where(fb[:, :, None] == 0.0, 0.0,
                          np.where(self.rate[None] > 0,
                                   fb[:, :, None] / self.rate[None], np.inf))
            tb = np.where(gb[:, :, None] == 0.0, 0.0,
                          np.where(self.rate_T[None] > 0,
                                   gb[:, :, None] / self.rate_T[None], np.inf))
        comm_cost = tf + tb
        comm_beta = np.maximum(tf, tb)
        comm_cost[0] = np.inf                       # no cut before layer 1
        comm_beta[0] = np.inf
        idx = np.arange(self.N)
        comm_cost[:, idx, idx] = np.inf             # no self-transfer
        comm_beta[:, idx, idx] = np.inf

        return MSPGraph(profile=self.profile, net=self.net, b=b,
                        seg_cost=seg_cost, seg_beta=seg_beta,
                        comm_cost=comm_cost, comm_beta=comm_beta,
                        src_cost=seg_cost[0, 0, :].copy(),
                        src_beta=seg_beta[0, 0, :].copy())


def build_graph(profile: ModelProfile, net: EdgeNetwork, b: int,
                memory_model: str = "paper") -> MSPGraph:
    """One-shot graph build (delegates to :class:`GraphFactory`).

    Callers that need graphs for many micro-batch sizes (BCD iterations,
    exhaustive b-sweeps) should hold a ``GraphFactory`` — or a
    ``shortest_path.Planner`` — and amortize the precomputation."""
    return GraphFactory(profile, net, memory_model).graph(b)


def graph_stats(g: MSPGraph) -> dict:
    """Vertex/edge counts of the *paper's* explicit graph (Eqs. 20-21),
    for complexity reporting (Theorem 3)."""
    I, N = g.I, g.N
    vertices = sum(i for i in range(1, I + 1)) * N  # ranges x nodes
    finite_edges = int(np.isfinite(g.seg_cost).sum()) * (N - 1)
    return {"paper_vertices": vertices, "paper_edges_upper": finite_edges,
            "state_edges": int(np.isfinite(g.seg_cost).sum())}


def path_to_solution(path: list) -> SplitSolution:
    """Convert [(node, end_layer), ...] (client first) into a SplitSolution."""
    cuts = tuple(end for _, end in path)
    placement = tuple(node for node, _ in path)
    return SplitSolution(cuts=cuts, placement=placement)
