"""Graph representation of the MSP problem (Sec. V-D, Eqs. 20-22).

The paper's vertex v^{k,n}_{(i-m),i} ("submodel k = layers i-m..i on node n")
admits a compact *state* encoding: because the next segment always starts at
the current segment's end, the reachable-cost state is ``(k, n, i)`` =
"the k-th (non-empty) submodel ends at layer i on node n".  An edge

    (k, n, i)  ->  (k+1, n', j)      with j > i, n' a server, n' != n

carries the Eq. (22) weight *folded onto the head vertex*:

    c = t^F_comm(cut i, n->n') + t^B_comm(cut i, n'->n)
      + t^F((i, j], n') + t^B((i, j], n')

so that a source->dest path cost equals T_f of Eq. (12) exactly.  (The paper
prints zero-weight terminal edges, which would drop stage-K compute from T_f;
we keep stage compute on the head so the sum is exact — noted in DESIGN.md §6.)

Each edge also carries the *bottleneck* value

    beta = max(t^F_comm, t^B_comm, t^F_head, t^B_head)

so a path's max-beta equals T_i of Eq. (13) whenever no node hosts two
submodels (paper mode; see DESIGN.md §6 for the exact-mode discussion).

Everything is materialized as dense numpy arrays over the edge space
``(n, i, n', j)`` — independent of k — so Algorithm 1's repeated
shortest-path sweeps are vectorized.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .latency import (SplitSolution, bp_latency, bwd_bytes, client_max_share,
                      comm_latency, fp_latency, fwd_bytes, memory_bytes)
from .network import EdgeNetwork
from .profiles import ModelProfile


@dataclasses.dataclass
class MSPGraph:
    """Dense arrays over the layered edge space.

    Shapes: ``N`` nodes (index 0 = client tier), ``I`` layers.
      seg_cost[n, i, j]   compute (FP+BP) of segment (i, j] on node n; inf if
                          j <= i or memory-infeasible on n  (i, j in 0..I)
      seg_beta[n, i, j]   max(FP, BP) of that segment
      comm_cost[i, n, m]  fwd + bwd comm across cut i between nodes n -> m
      comm_beta[i, n, m]  max(fwd, bwd) across cut i
      src_cost[i]         client segment (0, i] compute cost (FP+BP)
      src_beta[i]         max(FP, BP) of the client segment
    """
    profile: ModelProfile
    net: EdgeNetwork
    b: int
    seg_cost: np.ndarray
    seg_beta: np.ndarray
    comm_cost: np.ndarray
    comm_beta: np.ndarray
    src_cost: np.ndarray
    src_beta: np.ndarray

    @property
    def I(self) -> int:
        return self.profile.num_layers

    @property
    def N(self) -> int:
        return len(self.net.nodes)

    def edge_cost(self, n: int, i: int, m: int, j: int) -> float:
        """Full edge weight (comm across cut i) + (head segment (i,j] on m)."""
        return float(self.comm_cost[i, n, m] + self.seg_cost[m, i, j])

    def edge_beta(self, n: int, i: int, m: int, j: int) -> float:
        return float(max(self.comm_beta[i, n, m], self.seg_beta[m, i, j]))


def build_graph(profile: ModelProfile, net: EdgeNetwork, b: int,
                memory_model: str = "paper") -> MSPGraph:
    I = profile.num_layers
    N = len(net.nodes)
    seg_cost = np.full((N, I + 1, I + 1), np.inf)
    seg_beta = np.full((N, I + 1, I + 1), np.inf)
    for n in range(N):
        node = net.nodes[n]
        for i in range(I):            # segment (i, j]
            for j in range(i + 1, I + 1):
                fp = fp_latency(profile, net, i, j, n, b)
                bp = bp_latency(profile, net, i, j, n, b)
                mem = memory_bytes(profile, net, i, j, n, b, memory_model)
                if mem > node.mem:
                    continue          # per-vertex memory infeasibility (C7/C8)
                seg_cost[n, i, j] = fp + bp
                seg_beta[n, i, j] = max(fp, bp)

    comm_cost = np.full((I + 1, N, N), np.inf)
    comm_beta = np.full((I + 1, N, N), np.inf)
    for i in range(1, I + 1):         # cut after layer i (1-based)
        for n in range(N):
            fb = fwd_bytes(profile, net, i, b, from_client=(n == 0))
            gb = bwd_bytes(profile, net, i, b, to_client=(n == 0))
            for m in range(N):
                if m == n:
                    continue
                tf = comm_latency(net, n, m, fb)
                tb = comm_latency(net, m, n, gb)
                comm_cost[i, n, m] = tf + tb
                comm_beta[i, n, m] = max(tf, tb)

    src_cost = seg_cost[0, 0, :].copy()   # client segment (0, i]
    src_beta = seg_beta[0, 0, :].copy()
    return MSPGraph(profile=profile, net=net, b=b,
                    seg_cost=seg_cost, seg_beta=seg_beta,
                    comm_cost=comm_cost, comm_beta=comm_beta,
                    src_cost=src_cost, src_beta=src_beta)


def graph_stats(g: MSPGraph) -> dict:
    """Vertex/edge counts of the *paper's* explicit graph (Eqs. 20-21),
    for complexity reporting (Theorem 3)."""
    I, N = g.I, g.N
    vertices = sum(i for i in range(1, I + 1)) * N  # ranges x nodes
    finite_edges = int(np.isfinite(g.seg_cost).sum()) * (N - 1)
    return {"paper_vertices": vertices, "paper_edges_upper": finite_edges,
            "state_edges": int(np.isfinite(g.seg_cost).sum())}


def path_to_solution(path: list) -> SplitSolution:
    """Convert [(node, end_layer), ...] (client first) into a SplitSolution."""
    cuts = tuple(end for _, end in path)
    placement = tuple(node for node, _ in path)
    return SplitSolution(cuts=cuts, placement=placement)
