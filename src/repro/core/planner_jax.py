"""Batched JAX backend for Algorithm 1 — the ISSUE 9 tentpole.

Ports the full planning pipeline (GraphFactory broadcast assembly ->
layered-DP sweep -> threshold window -> argmin finish) to jit'd XLA with a
leading *slice* axis over arbitrary (micro-batch b, threshold t) pairs.

Three design decisions, each forced by measurement on the acceptance
instance (24 servers x 30 layers x B=64):

1. **Threshold-contiguous layout.**  The slice axis is the LAST axis of
   every tensor (``dist[n, i, s]``), so the per-layer min-plus relaxation
   vectorizes across slices.  A slice-first vmap was *slower* than numpy.

2. **On-the-fly graph assembly.**  Graph weights are never materialized per
   slice.  The kernel recomputes ``seg_cost``/``comm_cost`` entries inside
   the layer loop from b-independent *basis* tensors (workload tables, rate
   matrix, node constants — a few hundred KB, shared by every slice) and a
   per-slice effective-batch vector ``e[n, s]``.  This keeps the memory
   traffic of a 450-slice sweep near zero and lets one dispatch mix slices
   of different b — which is what lets ``solve_many`` run phases A-D as a
   handful of compiled dispatches instead of per-instance numpy sweeps.
   (Materializing masked per-slice tensors was measured 1.5-2x slower:
   the sweep becomes bandwidth-bound re-reading ~80 MB per layer.)

3. **No parent tracking on device.**  Reconstruction needs argmin parents,
   which double the numpy kernel's cost.  Instead the jax sweeps optionally
   return the per-layer ``dist`` stack (a few MB) and the path is
   reconstructed host-side by :func:`backtrace_stack` against a host mirror
   of the assembled graph — reproducing ``np.argmin``'s first-minimum
   tie-breaking exactly (see the proof note on :func:`backtrace_stack`).

Numerics: the kernel runs in jax's enabled dtype (float32 unless
``JAX_ENABLE_X64`` / ``jax.config.update("jax_enable_x64", True)``).  Under
x64 every arithmetic op mirrors the numpy reference bit-for-bit, so results
are exactly equal.  Under float32 the documented contract is: feasibility
matches, the returned path is a valid path whose *float64 repriced*
objective is within ``rtol=1e-4`` of the numpy optimum (asserted by the
randomized cross-check in tests/test_msp.py).  See
:func:`sweep_dtype` / :func:`parity_tolerance`.
"""

from __future__ import annotations

import math

import numpy as np

from repro import obs

from . import latency as L

_INF = np.inf

#: slice-axis padding buckets: pad S up to the next bucket so the number of
#: compiled kernel variants stays O(log S); larger sweeps are chunked.
_S_BUCKETS = (8, 16, 32, 64, 128)
_S_MAX = _S_BUCKETS[-1]          # chunk size: keeps worst-case bucket
#                                  padding under ~6% of a large sweep (a
#                                  512 cap padded e.g. 391 -> 512, wasting
#                                  a third of the largest dispatches)


def available() -> bool:
    """True when jax is importable (the backend degrades to numpy if not)."""
    try:
        import jax  # noqa: F401
        return True
    except Exception:
        return False


def sweep_dtype() -> str:
    """The dtype the jax backend will actually compute in.

    jax silently truncates float64 requests to float32 unless x64 is
    enabled — the pre-ISSUE-9 ``_dist_at_jax`` documented this in a
    docstring but did not *detect* it (satellite task).  Returns
    ``"float64"`` iff jax will honor 64-bit, else ``"float32"``."""
    import jax
    return "float64" if jax.config.jax_enable_x64 else "float32"


def parity_tolerance() -> float:
    """Relative tolerance vs the numpy reference for the active dtype.

    0.0 under x64 (bit-exact contract); 1e-4 under float32 (covers ~K
    accumulated roundings through the DP plus the argmin near-tie slop)."""
    return 0.0 if sweep_dtype() == "float64" else 1e-4


# ---------------------------------------------------------------------------
# Device state: basis tensors + compiled sweep kernels per (factory, K, dtype)
# ---------------------------------------------------------------------------

class JaxDP:
    """Compiled batched DP over one GraphFactory's basis tensors.

    Holds the b-independent precomputation on device and a cache of jit'd
    sweep kernels keyed by (padded slice count, mode, want_stack).  Invalidate
    by dropping the object (Planner keys its cache on a factory epoch)."""

    def __init__(self, factory, K: int):
        import jax.numpy as jnp

        self.factory = factory
        self.K = K
        self.dtype = sweep_dtype()
        self.N, self.I = factory.N, factory.I
        dt = jnp.float64 if self.dtype == "float64" else jnp.float32
        self._dt = dt
        self.memory_model = factory.memory_model

        as_ = lambda a: jnp.asarray(np.asarray(a), dt)
        self.Wf = as_(factory.W_fp)
        self.Wb = as_(factory.W_bp)
        self.Mps = as_(factory.Mem_ps)
        self.Mact = as_(factory.Mem_act)
        self.Mstat = as_(factory.Mem_static)
        self.tri = jnp.asarray(factory.tri)
        self.rate = as_(factory.rate)
        self.rate_pos = jnp.asarray(factory.rate > 0)
        self.kappa = as_(factory.kappa)
        self.f = as_(factory.f)
        self.t0 = as_(factory.t0)
        self.t1 = as_(factory.t1)
        self.bth = as_(factory.b_th)
        self.mem = as_(factory.mem)
        self.fb1 = as_(factory.fb1)
        self.gb1 = as_(factory.gb1)
        idx = np.arange(self.N)
        self.struct = jnp.asarray((idx[None, :] != idx[:, None])
                                  & (idx[None, :] != 0))      # (n, m) allowed
        self._fns: dict = {}

    def refresh(self) -> None:
        """Re-upload the update-mutable basis tensors after a
        ``Planner.update`` patch (rate change / node slowdown).  Compiled
        kernels take these as traced arguments, so no retrace happens."""
        import jax.numpy as jnp
        fac = self.factory
        self.rate = jnp.asarray(np.asarray(fac.rate), self._dt)
        self.rate_pos = jnp.asarray(fac.rate > 0)
        self.f = jnp.asarray(np.asarray(fac.f), self._dt)

    # -- kernel construction ------------------------------------------------
    def _build(self, S: int, mode: str, want_stack: bool):
        import jax
        import jax.numpy as jnp
        from jax import lax

        N, I, K = self.N, self.I, self.K
        I1 = I + 1
        dt = self._dt
        INF = jnp.asarray(np.asarray(_INF, dtype=self.dtype))
        ZERO = jnp.asarray(np.asarray(0.0, dtype=self.dtype))
        Wf, Wb, tri = self.Wf, self.Wb, self.tri
        Mps, Mact, Mstat = self.Mps, self.Mact, self.Mstat
        struct = self.struct
        kappa, t0, t1 = self.kappa, self.t0, self.t1
        bth, mem = self.bth, self.mem
        fb1, gb1 = self.fb1, self.gb1
        paper_mem = self.memory_model == "paper"
        is_sum = mode == "sum"

        # rate / rate_pos / f ride as ARGUMENTS, not closure constants:
        # Planner.update patches them in place (refresh()) and a traced
        # argument re-binds per call with no retrace, where a captured
        # constant would bake the stale value into the compiled kernel.
        def kern(e, ts, rate, rate_pos, f):
            # e (N, S) per-slice effective batch; ts (S,) thresholds
            t4 = ts[None, None, None, :]
            a1 = e * kappa[:, None]                             # eff * kappa
            a2 = jnp.maximum(e - bth[:, None], ZERO) * kappa[:, None]

            # -- hoisted assembly: every edge value is k-independent, so the
            # masked relaxation operands are built ONCE per sweep instead of
            # once per scan step (the per-k rebuild dominated the kernel
            # wall-clock).  Elementwise op chains are identical to the
            # factory's, so x64 bit-parity with numpy is preserved.
            # segments (i, m, j, s): factory formulas over all cuts at once
            fp = (a1[None, :, None, :] * Wf[:, None, :, None]) \
                / f[None, :, None, None] + t0[None, :, None, None]
            bpw = a2[None, :, None, :] * Wb[:, None, :, None]
            bp = jnp.where(bpw == ZERO, t1[None, :, None, None],
                           bpw / f[None, :, None, None]
                           + t1[None, :, None, None])
            if paper_mem:
                mok = (e[None, :, None, :] * Mps[:, None, :, None]
                       <= mem[None, :, None, None])
            else:
                mok = (e[None, :, None, :] * Mact[:, None, :, None]
                       + Mstat[:, None, :, None] <= mem[None, :, None, None])
            ok = tri[:, None, :, None] & mok
            sc = jnp.where(ok, fp + bp, INF)
            sb = jnp.where(ok, jnp.maximum(fp, bp), INF)
            Vs = jnp.where(sb <= t4, sc if is_sum else sb, INF)  # (I1,N,I1,S)
            # comms (i, n, m, s): threshold-masked edge values
            fbn = fb1[:, None, None] * e[None]                   # (I1, N, S)
            gbn = gb1[:, None, None] * e[None]
            tf = jnp.where(
                fbn[:, :, None, :] == ZERO, ZERO,
                jnp.where(rate_pos[None, :, :, None],
                          fbn[:, :, None, :] / rate[None, :, :, None], INF))
            tb = jnp.where(
                gbn[:, :, None, :] == ZERO, ZERO,
                jnp.where(rate_pos.T[None, :, :, None],
                          gbn[:, :, None, :] / rate.T[None, :, :, None], INF))
            cb = jnp.maximum(tf, tb)
            cv = tf + tb if is_sum else cb
            okc = struct[None, :, :, None] & (cb <= t4)
            Vc = jnp.where(okc, cv, INF)                         # (I1,N,N,S)

            src_v = sc[0, 0] if is_sum else sb[0, 0]
            dist0 = jnp.where(sb[0, 0] <= ts[None, :], src_v, INF)  # (I1, S)
            dist = jnp.full((N, I1, S), INF, dt).at[0].set(dist0)
            fin0 = jnp.isfinite(dist[0, I])
            best = jnp.where(fin0, dist[0, I], INF)
            best_k = jnp.where(fin0, 1, 0).astype(jnp.int32)
            best_m = jnp.zeros(S, jnp.int32)

            def layer(dist):
                # two-stage relaxation; the i loop stays sequential — the
                # (N, I1, S) working set fits cache where a fully-vectorized
                # (I1, N, I1, S) pass does not (measured slower)
                def per_i(i, nd):
                    dcol = dist[:, i, :][:, None, :]
                    if is_sum:
                        cand = dcol + Vc[i]
                    else:
                        cand = jnp.maximum(dcol, Vc[i])
                    Ai = cand.min(axis=0)                       # (m, S)
                    if is_sum:
                        cand2 = Ai[:, None, :] + Vs[i]
                    else:
                        cand2 = jnp.maximum(Ai[:, None, :], Vs[i])
                    return jnp.minimum(nd, cand2)
                return lax.fori_loop(1, I1, per_i,
                                     jnp.full((N, I1, S), INF, dt))

            def body(carry, k):
                dist, best, best_k, best_m = carry
                nd = layer(dist)
                term = nd[1:, I]                                # (N-1, S)
                v = term.min(axis=0)
                upd = v < best
                best = jnp.where(upd, v, best)
                best_k = jnp.where(upd, k, best_k)
                best_m = jnp.where(upd, term.argmin(axis=0).astype(jnp.int32)
                                   + 1, best_m)
                return (nd, best, best_k, best_m), (nd if want_stack else None)

            ks = jnp.arange(2, K + 1, dtype=jnp.int32)
            (dist, best, best_k, best_m), stack = lax.scan(
                body, (dist, best, best_k, best_m), ks)
            return best, best_k, best_m, stack

        return jax.jit(kern)

    # -- dispatch -----------------------------------------------------------
    def _fn(self, S: int, mode: str, want_stack: bool):
        key = (S, mode, want_stack)
        fn = self._fns.get(key)
        if fn is None:
            fn = self._build(S, mode, want_stack)
            self._fns[key] = fn
        return fn

    def sweep(self, e: np.ndarray, ts: np.ndarray, *, mode: str = "sum",
              want_stack: bool = False):
        """Run the batched DP for slices (e[:, s], ts[s]).

        Returns ``(best_val, best_k, best_m, stack)`` as numpy arrays;
        ``stack`` is the per-layer dist tensor ``(K-1, N, I1, S)`` (or None).
        The slice axis is padded to a size bucket and chunked at 512."""
        import jax.numpy as jnp

        obs.inc("planner.jax_dispatches")
        e = np.asarray(e, dtype=self.dtype)
        ts = np.asarray(ts, dtype=self.dtype)
        S = ts.shape[0]
        if S > _S_MAX:
            parts = [self.sweep(e[:, c:c + _S_MAX], ts[c:c + _S_MAX],
                                mode=mode, want_stack=want_stack)
                     for c in range(0, S, _S_MAX)]
            stack = (np.concatenate([p[3] for p in parts], axis=3)
                     if want_stack else None)
            return (np.concatenate([p[0] for p in parts]),
                    np.concatenate([p[1] for p in parts]),
                    np.concatenate([p[2] for p in parts]), stack)
        Sp = next(b for b in _S_BUCKETS if b >= max(S, 1))
        if Sp != S:
            e = np.concatenate(
                [e, np.ones((self.N, Sp - S), dtype=self.dtype)], axis=1)
            ts = np.concatenate(
                [ts, np.full(Sp - S, -_INF, dtype=self.dtype)])
        out = self._fn(Sp, mode, want_stack)(jnp.asarray(e), jnp.asarray(ts),
                                             self.rate, self.rate_pos, self.f)
        best = np.asarray(out[0])[:S]
        best_k = np.asarray(out[1])[:S]
        best_m = np.asarray(out[2])[:S]
        stack = np.asarray(out[3])[:, :, :, :S] if want_stack else None
        return best, best_k, best_m, stack


# ---------------------------------------------------------------------------
# Host mirror of the assembled graph (for windows + backtrace), in kernel dtype
# ---------------------------------------------------------------------------

def host_mirror(factory, b: int, dtype: str):
    """Assemble the DP-layout graph tensors for micro-batch b on the host,
    replicating the kernel's arithmetic op-for-op in the kernel's dtype.

    Returns ``(Ccom, Bcom, Sseg, Bseg, src_cost, src_beta)`` with rebind's
    structural folds applied — layouts match ``_LayeredDP`` (``Ccom[n,i,m]``,
    ``Sseg[i,m,j]``).  numpy and XLA both implement IEEE-754 elementwise
    mul/div/add/max, so these values equal the kernel's assembled values
    bit-for-bit in either dtype — which is what makes the host backtrace and
    the host beta windows consistent with device sweeps."""
    dt = np.dtype(dtype)
    eff = factory.effective_batch(b).astype(dt)
    N, I1 = factory.N, factory.I + 1
    kappa = factory.kappa.astype(dt)
    f = factory.f.astype(dt)
    t0 = factory.t0.astype(dt)
    t1 = factory.t1.astype(dt)
    bth = factory.b_th.astype(dt)
    mem = factory.mem.astype(dt)
    Wf = factory.W_fp.astype(dt)
    Wb = factory.W_bp.astype(dt)

    e = eff[:, None, None]
    a1 = (eff * kappa)[:, None, None]
    a2 = (np.maximum(eff - bth, dt.type(0.0)) * kappa)[:, None, None]
    fp = (a1 * Wf[None]) / f[:, None, None] + t0[:, None, None]
    bpw = a2 * Wb[None]
    bp = np.where(bpw == 0.0, t1[:, None, None],
                  bpw / f[:, None, None] + t1[:, None, None])
    if factory.memory_model == "paper":
        mok = e * factory.Mem_ps.astype(dt)[None] <= mem[:, None, None]
    else:
        mok = (e * factory.Mem_act.astype(dt)[None]
               + factory.Mem_static.astype(dt)[None] <= mem[:, None, None])
    ok = factory.tri[None] & mok
    seg_cost = np.where(ok, fp + bp, _INF).astype(dt)     # (n, i, j)
    seg_beta = np.where(ok, np.maximum(fp, bp), _INF).astype(dt)

    fb = eff[None, :] * factory.fb1.astype(dt)[:, None]   # (I1, N)
    gb = eff[None, :] * factory.gb1.astype(dt)[:, None]
    rate = factory.rate.astype(dt)
    rpos = factory.rate > 0
    with np.errstate(divide="ignore", invalid="ignore"):
        tf = np.where(fb[:, :, None] == 0.0, dt.type(0.0),
                      np.where(rpos[None], fb[:, :, None] / rate[None], _INF))
        tb = np.where(gb[:, :, None] == 0.0, dt.type(0.0),
                      np.where(rpos.T[None],
                               gb[:, :, None] / rate.T[None], _INF))
    comm_cost = (tf + tb).astype(dt)                      # (i, n, m)
    comm_beta = np.maximum(tf, tb).astype(dt)
    comm_cost[0] = _INF
    comm_beta[0] = _INF
    idx = np.arange(N)
    comm_cost[:, idx, idx] = _INF
    comm_beta[:, idx, idx] = _INF

    Ccom = np.ascontiguousarray(comm_cost.transpose(1, 0, 2))   # (n, i, m)
    Bcom = np.ascontiguousarray(comm_beta.transpose(1, 0, 2))
    Ccom[:, :, 0] = _INF
    Bcom[:, :, 0] = _INF
    Ccom[idx, :, idx] = _INF
    Bcom[idx, :, idx] = _INF
    Sseg = np.ascontiguousarray(seg_cost.transpose(1, 0, 2))    # (i, m, j)
    Bseg = np.ascontiguousarray(seg_beta.transpose(1, 0, 2))
    src_cost = seg_cost[0, 0, :].copy()
    src_beta = seg_beta[0, 0, :].copy()
    return Ccom, Bcom, Sseg, Bseg, src_cost, src_beta


def backtrace_stack(stack, mirror, t: float, k: int, m: int, j: int) -> list:
    """Reconstruct the path for one slice from its per-layer dist stack.

    ``stack[k-2]`` is dist *after* layer k (``stack`` covers k = 2..K);
    layer 1 is the source row.  At each step the parent ``(n, i)`` of state
    ``(k, m, j)`` is found by re-running the two-stage relaxation for the
    single needed column and taking ``np.argmin`` — the *same array* the
    numpy kernel argmin'd over when ``want_parents`` was set, so the
    first-minimum tie-breaking is reproduced exactly (values are bit-equal
    because host mirror assembly matches the kernel op-for-op)."""
    Ccom, Bcom, Sseg, Bseg, src_cost, src_beta = mirror
    if k == 1:
        return [(0, j)]
    path = [(int(m), int(j))]
    N, I1 = Ccom.shape[0], Ccom.shape[1]
    dt = Ccom.dtype
    src = np.where(src_beta <= t, src_cost, dt.type(_INF))
    for kk in range(k, 1, -1):
        prev = (stack[kk - 3] if kk >= 3 else
                _src_dist(N, I1, src))                     # dist after kk-1
        Vc = np.where(Bcom[:, :, m] <= t, Ccom[:, :, m], dt.type(_INF))
        A = (prev + Vc).min(axis=0)                        # (I1,)
        Vs = np.where(Bseg[:, m, j] <= t, Sseg[:, m, j], dt.type(_INF))
        i = int(np.argmin(A + Vs))
        n = int(np.argmin(prev[:, i] + Vc[:, i]))
        path.append((n, i))
        m, j = n, i
    path.reverse()
    return path


def _src_dist(N: int, I1: int, src: np.ndarray) -> np.ndarray:
    d = np.full((N, I1), _INF, dtype=src.dtype)
    d[0] = src
    return d


# ---------------------------------------------------------------------------
# Repricing helpers (float64 — final objectives are exact for the chosen path)
# ---------------------------------------------------------------------------

def reprice_dp_order(g, path) -> tuple:
    """(cost, beta) of ``path`` on graph ``g`` with the DP's accumulation
    order ``(dist + comm) + seg`` — bit-equal to the numpy kernel's dist."""
    n0, i0 = path[0]
    cost = float(g.src_cost[i0])
    beta = float(g.src_beta[i0])
    prev_n, prev_i = n0, i0
    for (n, i) in path[1:]:
        cost = (cost + float(g.comm_cost[prev_i, prev_n, n])) \
            + float(g.seg_cost[n, prev_i, i])
        beta = max(beta, float(g.comm_beta[prev_i, prev_n, n]),
                   float(g.seg_beta[n, prev_i, i]))
        prev_n, prev_i = n, i
    return cost, beta


# ---------------------------------------------------------------------------
# The batched solve_many driver (phases A-D on device)
# ---------------------------------------------------------------------------

def solve_many_jax(planner, bs: list, B: int, K: int | None = None) -> list:
    """Full-jax ``Planner.solve_many``: phases A-D as batched device sweeps.

    Mirrors ``Planner._solve_many`` phase-for-phase; additionally shares
    upper bounds *across* b (every phase-A/B path is repriced on every live
    graph, float64) which shrinks the phase-C windows — valid because any
    real path's objective upper-bounds OPT, and a window that contains every
    global minimizer yields the same argmin winner."""
    from repro.core.shortest_path import _betas_from_arrays

    K = planner.default_K(K)
    jdp = planner._jax_dp(K)
    dtype = jdp.dtype
    fac = planner.factory
    S = len(bs)
    N, I = fac.N, fac.I

    e = np.empty((N, S), dtype=dtype)
    for s, b in enumerate(bs):
        e[:, s] = fac.effective_batch(b).astype(dtype)
    xi = np.array([L.num_fills(B, b) for b in bs])
    mirrors = [planner._jax_mirror(b, dtype) for b in bs]
    graphs = [planner.graph(b) for b in bs]

    # phase A: full-graph run for every b (dist stack -> host backtrace)
    bestA, kA, mA, stackA = jdp.sweep(e, np.full(S, _INF), want_stack=True)
    paths_full = [
        backtrace_stack(stackA[:, :, :, s], mirrors[s], _INF,
                        int(kA[s]), int(mA[s]), I) if kA[s] else None
        for s in range(S)]

    results: list = [None] * S
    live = []
    for s in range(S):
        if xi[s] == 0 or paths_full[s] is None:
            results[s] = _finish_repriced(planner, graphs[s], paths_full[s],
                                          bs[s], B, int(xi[s]), 1)
        else:
            live.append(s)
    if not live:
        return results

    # phase B: (max, min) sweep -> beta*, then a probe run at beta*
    el = e[:, live]
    beta_star, _, _, _ = jdp.sweep(el, np.full(len(live), _INF), mode="max")
    bestP, kP, mP, stackP = jdp.sweep(el, beta_star, want_stack=True)
    paths_star = [
        backtrace_stack(stackP[:, :, :, q], mirrors[live[q]],
                        float(beta_star[q]), int(kP[q]), int(mP[q]), I)
        if kP[q] else None
        for q in range(len(live))]

    # cross-b upper bounds: every candidate path repriced on every live b
    pool = [p for p in paths_full if p is not None] \
        + [p for p in paths_star if p is not None]
    windows = []
    for q, s in enumerate(live):
        g = graphs[s]
        ub = _INF
        for p in pool:
            c, beta = reprice_dp_order(g, p)
            if math.isfinite(c):
                ub = min(ub, c + xi[s] * beta)
        cap = (ub - float(bestA[s])) / xi[s]
        Ccom_m, Bcom_m, _, Bseg_m, _, src_beta_m = mirrors[s]
        w = _betas_from_arrays(Bcom_m, Bseg_m, src_beta_m,
                               float(beta_star[q]),
                               cap * (1 + 1e-12) + 1e-12)
        w = np.unique(np.concatenate(
            [np.atleast_1d(np.asarray(v, dtype=np.float64)) for v in w]))
        if w.size == 0:
            w = np.array([float(beta_star[q])])
        windows.append(w)

    # phase C: one flat sweep over every (b, threshold) pair
    slice_q = np.concatenate(
        [np.full(len(w), q, dtype=int) for q, w in zip(range(len(live)),
                                                       windows)])
    slice_t = np.concatenate(windows)
    eC = el[:, slice_q]
    dvals, _, _, _ = jdp.sweep(eC, slice_t)
    t_hat = np.empty(len(live))
    pos = 0
    for q, w in enumerate(windows):
        H = dvals[pos:pos + len(w)].astype(np.float64) + xi[live[q]] * w
        t_hat[q] = w[int(np.argmin(H))]
        pos += len(w)

    # phase D: reconstruction at the winners (reuse the probe when t̂ == β*)
    need = [q for q in range(len(live)) if t_hat[q] != beta_star[q]]
    if need:
        eD = el[:, need]
        bestR, kR, mR, stackR = jdp.sweep(eD, t_hat[need], want_stack=True)
        for r, q in enumerate(need):
            s = live[q]
            path = (backtrace_stack(stackR[:, :, :, r], mirrors[s],
                                    float(t_hat[q]), int(kR[r]), int(mR[r]),
                                    I) if kR[r] else None)
            results[s] = _finish_repriced(planner, graphs[s], path,
                                          bs[s], B, int(xi[s]), 5)
    for q, s in enumerate(live):
        if results[s] is None:
            results[s] = _finish_repriced(planner, graphs[s], paths_star[q],
                                          bs[s], B, int(xi[s]), 4)
    return results


def _finish_repriced(planner, g, path, b, B, xi, sweeps):
    """Assemble an MSPResult, repricing the chosen path in float64 so the
    reported objective/T_f are exact for the (possibly float32-chosen)
    solution — under x64 this equals the numpy result bit-for-bit."""
    if path is None:
        return planner._finish(g, _INF, None, b, B, xi, sweeps, "batched")
    cost, _beta = reprice_dp_order(g, path)
    return planner._finish(g, cost, path, b, B, xi, sweeps, "batched")


def dist_at_jax(dp, ts: np.ndarray, planner=None) -> np.ndarray:
    """dist(t) per threshold for one bound ``_LayeredDP`` via the batched
    kernel (used by ``Planner.solve(..., backend='jax')``'s window sweep).

    Requires the owning planner's factory (on-the-fly assembly); falls back
    to the numpy sweep for restricted DPs or when jax is unavailable."""
    if dp.restricted or planner is None or not available():
        return dp.sweep(ts).best_val
    jdp = planner._jax_dp(dp.K)
    b = dp.g.b
    e = np.tile(planner.factory.effective_batch(b)[:, None], (1, len(ts)))
    best, _, _, _ = jdp.sweep(e.astype(jdp.dtype), ts)
    return best.astype(np.float64)
