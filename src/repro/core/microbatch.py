"""Theorem 1 — optimal micro-batch size in closed form (Appendix A).

With the MSP result (x, y) and the auxiliary bottleneck T_1 fixed, P3 is

    min_b  T_f(b) + xi(b) * T_1,     xi(b) = ceil((B - b)/b)
    s.t.   b in [1, B],  memory (C7'/C8'),  T_i-components(b) <= T_1 (C9'-C16')

T_f(b) is piecewise linear in b:  T_f(b) = C_lin * b + C_const, with C_lin
depending on which side of the BP thresholds (b_th^c for clients, b_th^s for
servers) b falls — the four cases of Eq. (18).  Relaxing the ceil,
d/db [C_lin b + T_1 B / b] = 0 gives the paper's

    b~ = sqrt(B * T_1 / C_lin)                      (Eqs. 27/32/36/40)

and the optimum is the better of floor/ceil(b~) clamped into
[1, min(b_v, B)] where b_v is the feasibility box of Eq. (24) — here computed
exactly by binary search on the monotone predicate
``memory_feasible(b) and T_i(b) <= T_1``.

``optimal_microbatch`` evaluates the exact objective at every case's
candidate (plus the box corners), which is precisely the case analysis of
Eq. (18).  ``exhaustive_microbatch`` scans every b in [1, B] — the "optimal
scheme" of Fig. 7 and the oracle our tests compare the closed form against.

>>> from repro.core import (make_edge_network, pipeline_interval,
...                         uniform_profile, SplitSolution)
>>> prof = uniform_profile(6, fp=1.0, bp=2.0, act=1.0)
>>> net = make_edge_network(num_servers=2, num_clients=2, seed=0)
>>> sol = SplitSolution(cuts=(3, 6), placement=(0, 1))
>>> T_1 = pipeline_interval(prof, net, sol, 8)
>>> res = optimal_microbatch(prof, net, sol, B=64, T_1=T_1)
>>> res.b == exhaustive_microbatch(prof, net, sol, B=64, T_1=T_1)[0]
True
"""

from __future__ import annotations

import dataclasses
import math

from . import latency as L
from .cost_model import resolve_cost_model
from .latency import SplitSolution, client_max_share
from .network import EdgeNetwork
from .profiles import ModelProfile


@dataclasses.dataclass
class MicrobatchResult:
    b: int
    objective: float         # T_f(b) + xi(b) * T_1   (the P3 objective)
    L_t: float               # true Eq. (14) latency at this b
    case: str                # which Theorem-1 case produced the winner
    b_v: int                 # feasibility box upper corner
    candidates: dict         # case -> b~ (pre-clamp), for inspection


# ---------------------------------------------------------------------------
# Linear coefficient of T_f(b) per Theorem-1 case
# ---------------------------------------------------------------------------

def _linear_coeff(profile: ModelProfile, net: EdgeNetwork, sol: SplitSolution,
                  *, client_bp: bool, server_bp: bool) -> float:
    """dT_f/db with the chosen BP terms active.

    Comm terms and FP terms are always linear in b; BP terms contribute only
    above their threshold (slope kappa*delta^B/f).  Client-side slopes carry
    the 1/M share factor of Eq. (1) (we use the exact largest-share slope,
    which for b >> M approaches 1/M; the closed form uses 1/M as the paper
    does — the floor/ceil candidate evaluation absorbs the difference).
    """
    M = net.num_clients
    coeff = 0.0
    segs = list(sol.segments())
    for k, lo, hi, n in segs:
        node = net.nodes[n]
        share = (1.0 / M) if n == 0 else 1.0
        coeff += share * node.kappa * profile.seg_fp(lo, hi) / node.f
        include_bp = client_bp if n == 0 else server_bp
        if include_bp:
            coeff += share * node.kappa * profile.seg_bp(lo, hi) / node.f
    for (k1, _, hi1, n1), (_, _, _, n2) in zip(segs, segs[1:]):
        share = (1.0 / M) if n1 == 0 else 1.0
        r_f = net.rate[n1, n2]
        r_b = net.rate[n2, n1]
        coeff += share * profile.cut_act_bytes(hi1) / r_f
        coeff += share * profile.cut_grad_bytes(hi1) / r_b
    return coeff


# ---------------------------------------------------------------------------
# Feasibility box b_v (Eq. 24, computed exactly)
# ---------------------------------------------------------------------------

def feasibility_box(profile: ModelProfile, net: EdgeNetwork,
                    sol: SplitSolution, B: int, T_1: float,
                    memory_model: str = "paper", cost_model=None) -> int:
    """Largest b in [1, B] with memory feasible AND T_i(b) <= T_1.

    Both predicates are monotone non-increasing in b, so binary search is
    exact — this is Eq. (24)'s min-of-floors evaluated without re-deriving
    each constraint analytically.

    ``cost_model`` supplies the memory predicate (default
    ``ClosedForm(memory_model)``, i.e. Eq. (11)'s one-in-flight eta_k; a
    ``SimMakespan`` model substitutes the memory-budgeted window >= 1
    predicate derived from ``Node.mem`` — the claims source shared with
    ``sim.policies.MemoryBudgeted`` and ``pipeline.schedule``).  The
    ``T_i(b) <= T_1`` leg stays closed-form: T_1 is Algorithm 1's
    analytical bottleneck, so mixing a measured interval in would compare
    unlike quantities.
    """
    cm = resolve_cost_model(cost_model, memory_model)
    tol = 1.0 + 1e-9

    def ok(b: int) -> bool:
        if not cm.memory_feasible(profile, net, sol, b):
            return False
        return L.pipeline_interval(profile, net, sol, b) <= T_1 * tol

    if not ok(1):
        return 0
    lo, hi = 1, B
    while lo < hi:
        mid = (lo + hi + 1) // 2
        if ok(mid):
            lo = mid
        else:
            hi = mid - 1
    return lo


# ---------------------------------------------------------------------------
# Theorem 1
# ---------------------------------------------------------------------------

def _objective(profile, net, sol, b, B, T_1) -> float:
    return L.fill_latency(profile, net, sol, b) + L.num_fills(B, b) * T_1


def optimal_microbatch(profile: ModelProfile, net: EdgeNetwork,
                       sol: SplitSolution, B: int, T_1: float,
                       memory_model: str = "paper",
                       cost_model=None) -> MicrobatchResult:
    """Eq. (18): evaluate the four closed-form cases and pick the best
    feasible candidate under the exact P3 objective.

    ``cost_model`` only reshapes the feasible box (its memory predicate);
    the case analysis *is* Theorem 1's closed form — measured objectives
    enter through ``exhaustive_microbatch`` / ``bcd_solve``'s refinement.
    """
    b_v = feasibility_box(profile, net, sol, B, T_1, memory_model,
                          cost_model=cost_model)
    if b_v == 0:
        return MicrobatchResult(b=0, objective=math.inf, L_t=math.inf,
                                case="infeasible", b_v=0, candidates={})
    hi = min(b_v, B)
    M = net.num_clients

    # threshold geometry: client threshold applies to the client share
    c_th = net.client.b_th
    server_ths = [net.nodes[n].b_th for _, _, _, n in sol.segments() if n != 0]
    s_th = min(server_ths) if server_ths else 0

    cases = {
        # (client_bp_linear, server_bp_linear, valid-range predicate)
        "b1_below_both": (False, False,
                          lambda b: client_max_share(b, M) <= c_th and b <= s_th),
        "b2_above_both": (True, True,
                          lambda b: client_max_share(b, M) >= c_th and b >= s_th),
        "b3_client_only": (True, False,
                           lambda b: client_max_share(b, M) >= c_th and b <= s_th),
        "b4_server_only": (False, True,
                           lambda b: client_max_share(b, M) <= c_th and b >= s_th),
    }

    best = None
    tilde = {}
    for name, (cb, sb, in_range) in cases.items():
        C_lin = _linear_coeff(profile, net, sol, client_bp=cb, server_bp=sb)
        if C_lin <= 0:
            b_t = float(hi)
        else:
            b_t = math.sqrt(B * T_1 / C_lin)
        tilde[name] = b_t
        for cand in {int(math.floor(b_t)), int(math.ceil(b_t)), 1, hi}:
            b = min(max(cand, 1), hi)
            obj = _objective(profile, net, sol, b, B, T_1)
            # prefer candidates whose range matches the case (paper Eq. 18);
            # out-of-range candidates are still *feasible* so keep them as
            # tie-breakers — the exact objective decides.
            rank = (0 if in_range(b) else 1, obj, b)
            if best is None or rank < best[0]:
                best = (rank, b, obj, name)
    _, b_star, obj, case = best
    return MicrobatchResult(
        b=b_star, objective=obj,
        L_t=L.total_latency(profile, net, sol, b_star, B),
        case=case, b_v=hi, candidates=tilde)


def exhaustive_microbatch(profile: ModelProfile, net: EdgeNetwork,
                          sol: SplitSolution, B: int, T_1: float | None = None,
                          memory_model: str = "paper", cost_model=None):
    """Oracle: argmin over all b in [1, B].

    With ``T_1`` given, minimizes the P3 objective under the same feasibility
    box (for closed-form comparison).  With ``T_1=None``, minimizes the cost
    model's objective — Eq. (14)'s L_t for the default ``ClosedForm`` (the
    Fig. 7 optimal scheme), the *measured* makespan for ``SimMakespan``
    (the sim-in-the-loop refinement of ``bcd_solve``).  The feasible-b set
    comes from the cost model's memory predicate either way, which is how
    the memory-budgeted box feeds back into the BCD.
    """
    cm = resolve_cost_model(cost_model, memory_model)
    best_b, best_val = 0, math.inf
    if T_1 is not None:
        for b in range(1, B + 1):
            if not cm.memory_feasible(profile, net, sol, b):
                continue
            if L.pipeline_interval(profile, net, sol, b) > T_1 * (1 + 1e-9):
                continue
            val = _objective(profile, net, sol, b, B, T_1)
            if val < best_val:
                best_val, best_b = val, b
        return best_b, best_val
    # cost-model objective: batch the whole sweep — feasibility in one
    # claims pass, the survivors through evaluate_many (SimMakespan rides
    # the engine's stacked plan axis); identical results to the per-b loop
    bs = list(range(1, B + 1))
    feas = [b for b, ok in zip(bs, cm.memory_feasible_many(profile, net,
                                                           sol, bs)) if ok]
    vals = cm.evaluate_many(profile, net, [(sol, b) for b in feas], B)
    for b, val in zip(feas, vals):
        if val < best_val:
            best_val, best_b = val, b
    return best_b, best_val
