"""TPU stage planner — the paper's MSP + micro-batching, aimed at a pod.

Hardware adaptation (DESIGN.md §2): nodes become homogeneous *stage groups*
(chips x 197 TFLOP/s bf16, 16 GiB HBM each), links become ICI (~50 GB/s), and
placement is *ordered* (stage k -> group k), so Algorithm 1 runs with
``restrict_placement = (0, 1, .., S-1)`` — the min-max + min-sum structure
is unchanged: cuts balance per-stage compute against inter-stage activation
traffic, and Theorem 1 picks the pipeline micro-batch size.

The planner tries several stage counts (a pod axis can be factored many
ways) and returns the best plan; ``replan`` re-runs it after an elastic
event (lost stage group / changed link bandwidth) — this is the paper's BCD
promoted to a runtime fault-tolerance feature (ft/coordinator.py).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from .bcd import Plan, bcd_solve
from .network import (TPU_HBM_BYTES, TPU_ICI_BW, TPU_PEAK_FLOPS, EdgeNetwork,
                      tpu_stage_network)
from .profiles import ModelProfile
from .shortest_path import Planner
from .microbatch import optimal_microbatch
from . import latency as L


@dataclasses.dataclass
class StagePlan:
    """Layer ranges per pipeline stage + micro-batching, ready for spmd.py."""
    layer_ranges: tuple        # ((lo, hi], ...) per stage, 0-based cut points
    num_stages: int
    microbatch: int
    num_microbatches: int
    T_f: float
    T_i: float
    L_t: float
    bubble_fraction: float     # (T_f - T_i) overhead share, GPipe-style
    plan: Plan

    def stage_of_layer(self, layer: int) -> int:
        for s, (lo, hi) in enumerate(self.layer_ranges):
            if lo <= layer < hi:
                return s
        raise ValueError(layer)


def _solve_fixed_stages(profile: ModelProfile, net: EdgeNetwork, B: int,
                        num_stages: int, b0: int) -> Plan | None:
    # TPU memory semantics: params/optimizer state do NOT scale with the
    # micro-batch (the paper's Eq. 11 multiplies everything by b, which is
    # right for its edge servers swapping whole submodels but wrong for
    # resident pod weights) -> "refined" model.
    mm = "refined"
    placement = tuple(range(num_stages))
    b = max(1, min(b0, B))
    prev_L = math.inf
    plan = None
    planner = Planner(profile, net, mm)      # shared across BCD iterations
    for _ in range(8):                       # BCD with ordered placement
        msp = planner.solve(b, B, K=num_stages,
                            restrict_placement=placement)
        if not msp.feasible:
            if b > 1:
                b = max(1, b // 2)
                continue
            return None
        mb = optimal_microbatch(profile, net, msp.solution, B, msp.T_1,
                                memory_model=mm)
        if mb.b > 0:
            b = mb.b
        L_t = L.total_latency(profile, net, msp.solution, b, B)
        plan = Plan(solution=msp.solution, b=b, B=B,
                    T_f=L.fill_latency(profile, net, msp.solution, b),
                    T_i=L.pipeline_interval(profile, net, msp.solution, b),
                    L_t=L_t, iterations=1, history=[], solve_seconds=0.0)
        if abs(prev_L - L_t) < 1e-6 * max(L_t, 1.0):
            break
        prev_L = L_t
    return plan


def plan_stages(profile: ModelProfile, *, total_chips: int,
                stage_candidates: Sequence[int] = (2, 4, 8, 16),
                global_batch: int = 256, b0: int = 8,
                peak_flops: float = TPU_PEAK_FLOPS,
                hbm_bytes: float = TPU_HBM_BYTES,
                ici_bw: float = TPU_ICI_BW) -> StagePlan:
    """Pick (num_stages, cuts, micro-batch) minimizing Eq. (14) on a pod."""
    best: StagePlan | None = None
    for S in stage_candidates:
        if S > profile.num_layers or total_chips % S != 0:
            continue
        net = tpu_stage_network(S, total_chips // S, peak_flops=peak_flops,
                                hbm_bytes=hbm_bytes, ici_bw=ici_bw)
        plan = _solve_fixed_stages(profile, net, global_batch, S, b0)
        if plan is None:
            continue
        sp = _to_stage_plan(plan, S)
        if best is None or sp.L_t < best.L_t:
            best = sp
    if best is None:
        raise ValueError("no feasible stage plan (model too large per stage?)")
    return best


def _to_stage_plan(plan: Plan, S: int) -> StagePlan:
    segs = list(plan.solution.segments())
    ranges = tuple((lo, hi) for _, lo, hi, _ in segs)
    q = plan.num_microbatches
    bubble = (plan.L_t - q * plan.T_i) / plan.L_t if plan.L_t > 0 else 0.0
    return StagePlan(layer_ranges=ranges, num_stages=len(ranges),
                     microbatch=plan.b, num_microbatches=q,
                     T_f=plan.T_f, T_i=plan.T_i, L_t=plan.L_t,
                     bubble_fraction=max(bubble, 0.0), plan=plan)


def replan(profile: ModelProfile, *, total_chips: int, global_batch: int,
           prev: StagePlan | None = None, **kw) -> StagePlan:
    """Elastic re-plan after a resource change (ft/coordinator.py hook).
    Seeds BCD with the previous micro-batch size for fast convergence."""
    b0 = prev.microbatch if prev is not None else 8
    return plan_stages(profile, total_chips=total_chips,
                       global_batch=global_batch, b0=b0, **kw)
