"""Latency model — faithful implementation of Eqs. (1)-(14).

A solution is ``SplitSolution(cuts, placement)``:

  cuts[k]      last layer (1-based) of submodel k (k = 0..K-1, python index),
               non-decreasing, ``cuts[-1] == I``; ``cuts[k] == cuts[k-1]``
               encodes an *empty* submodel (paper C4/C5 allow this).
  placement[k] node index hosting submodel k; ``placement[0] == 0`` always
               (the virtual client node — paper constraint y_{1,client} = 1).

Equations implemented:
  (1)  client micro-batch shares b_m (floor split, remainder to client M)
  (2)+(3) FP latency  t^F_{k,n} = b * kappa_n * delta^F_k / f_n + t0
  (5)+(6) activation bytes D_k and fwd comm latency
  (7)+(8) BP latency (piecewise in b with threshold b_th)
  (9)+(10) act-grad bytes D'_k and bwd comm latency
  (11) memory footprint eta_k (paper model: everything scales with b; a
       ``refined`` mode scales only activations with b)
  (12) T_f — fill latency of the first micro-batch
  (13) T_i — steady-state pipeline interval (bottleneck over nodes & links;
       C9-C16 make the per-node terms *sums over co-located submodels*)
  (14) L_t = T_f + ceil((B-b)/b) * T_i
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from .network import EdgeNetwork
from .profiles import ModelProfile


@dataclasses.dataclass(frozen=True)
class SplitSolution:
    cuts: tuple          # length K, 1-based last layer per submodel
    placement: tuple     # length K, node index per submodel

    def __post_init__(self):
        object.__setattr__(self, "cuts", tuple(int(c) for c in self.cuts))
        object.__setattr__(self, "placement",
                           tuple(int(p) for p in self.placement))

    @property
    def K(self) -> int:
        return len(self.cuts)

    def segments(self):
        """Yield (k, lo, hi, node) for non-empty submodels; layers (lo, hi]."""
        lo = 0
        for k, (hi, node) in enumerate(zip(self.cuts, self.placement)):
            if hi > lo:
                yield k, lo, hi, node
            lo = hi

    def stage_of_layer(self, layer: int) -> int:
        """1-based layer -> submodel index k."""
        for k, lo, hi, _ in self.segments():
            if lo < layer <= hi:
                return k
        raise ValueError(f"layer {layer} not covered")


def validate_solution(sol: SplitSolution, profile: ModelProfile,
                      net: EdgeNetwork) -> None:
    K, I = sol.K, profile.num_layers
    if sol.cuts[-1] != I:
        raise ValueError(f"last cut must equal I={I}, got {sol.cuts[-1]}")
    if any(sol.cuts[k] > sol.cuts[k + 1] for k in range(K - 1)):
        raise ValueError("cuts must be non-decreasing (C5)")
    if any(c < 1 or c > I for c in sol.cuts):
        raise ValueError("cuts out of range (C4)")
    if sol.placement[0] != 0:
        raise ValueError("submodel 1 must sit on the client tier (y_1,client=1)")
    if any(p < 0 or p >= len(net.nodes) for p in sol.placement):
        raise ValueError("placement out of range (C6)")
    segs = list(sol.segments())
    for (k1, _, _, n1), (k2, _, _, n2) in zip(segs, segs[1:]):
        if n1 == n2:
            raise ValueError(
                f"consecutive submodels {k1},{k2} share node {n1} (Eq. 21 n != n')")
    if len(segs) >= 2 and any(n == 0 for _, _, _, n in segs[1:]):
        raise ValueError("server submodels cannot sit on the client tier")


# ---------------------------------------------------------------------------
# Eq. (1): client shares
# ---------------------------------------------------------------------------

def client_shares(b: int, M: int) -> np.ndarray:
    base = b // M
    shares = np.full(M, base, dtype=np.int64)
    shares[-1] = b - (M - 1) * base
    return shares


def client_max_share(b: int, M: int) -> int:
    """The slowest client's share — the arg of the max terms in Eq. (12)."""
    return int(b - (M - 1) * (b // M))


# ---------------------------------------------------------------------------
# Eqs. (2)-(11): per-stage / per-link components
# ---------------------------------------------------------------------------

def fp_work(profile: ModelProfile, net: EdgeNetwork, lo: int, hi: int,
            node: int, b: int) -> float:
    """Eq. (2)'s rate-scaled work term: eff_b * kappa_n * delta^F_k.

    Served at f_n it yields the FP latency (minus the t0 constant); the
    event simulator serves the same term against time-varying capacity.
    """
    n = net.nodes[node]
    eff_b = client_max_share(b, net.num_clients) if node == 0 else b
    return eff_b * n.kappa * profile.seg_fp(lo, hi)


def bp_work(profile: ModelProfile, net: EdgeNetwork, lo: int, hi: int,
            node: int, b: int) -> float:
    """Eq. (7)'s rate-scaled work term (0 below the b_th threshold)."""
    n = net.nodes[node]
    eff_b = client_max_share(b, net.num_clients) if node == 0 else b
    if eff_b <= n.b_th:
        return 0.0
    return (eff_b - n.b_th) * n.kappa * profile.seg_bp(lo, hi)


def fp_latency(profile: ModelProfile, net: EdgeNetwork, lo: int, hi: int,
               node: int, b: int) -> float:
    """Eq. (2): FP latency of submodel (lo, hi] on ``node`` for b samples.

    For the client tier (node 0) the per-client share of Eq. (1) applies and
    the *slowest* (largest-share) client defines the latency.
    """
    n = net.nodes[node]
    return fp_work(profile, net, lo, hi, node, b) / n.f + (n.t0)


def bp_latency(profile: ModelProfile, net: EdgeNetwork, lo: int, hi: int,
               node: int, b: int) -> float:
    """Eq. (7): piecewise BP latency with threshold b_th."""
    n = net.nodes[node]
    w = bp_work(profile, net, lo, hi, node, b)
    if w == 0.0:
        return float(n.t1)
    return w / n.f + n.t1


def fwd_bytes(profile: ModelProfile, net: EdgeNetwork, cut: int, b: int,
              from_client: bool) -> float:
    """Eq. (5): D_k — activation bytes crossing the cut after layer ``cut``."""
    eff_b = client_max_share(b, net.num_clients) if from_client else b
    return eff_b * profile.cut_act_bytes(cut)


def bwd_bytes(profile: ModelProfile, net: EdgeNetwork, cut: int, b: int,
              to_client: bool) -> float:
    """Eq. (9): D'_k — act-gradient bytes crossing the cut backwards."""
    eff_b = client_max_share(b, net.num_clients) if to_client else b
    return eff_b * profile.cut_grad_bytes(cut)


def comm_latency(net: EdgeNetwork, n_from: int, n_to: int, nbytes: float) -> float:
    """Eqs. (6)/(10): transfer latency over the (possibly multi-hop) link."""
    if nbytes == 0.0:
        return 0.0
    r = net.rate[n_from, n_to]
    if r <= 0:
        return math.inf
    return nbytes / r


def memory_split(profile: ModelProfile, net: EdgeNetwork, lo: int, hi: int,
                 node: int, b: int, model: str = "paper") -> tuple:
    """Eq. (11) split into ``(static_bytes, act_bytes)`` for one submodel.

    ``static_bytes`` is resident regardless of how many micro-batches are in
    flight (parameters + optimizer state); ``act_bytes`` is the footprint of
    ONE live micro-batch of size ``b`` (activations + act-gradients).  Under
    ``model='paper'`` Eq. (11) scales the *whole* footprint with b (as
    printed), so everything lands in the act term; ``'refined'`` scales only
    activations/grads.  This split is the single claims source shared by
    ``memory_bytes`` (C7/C8 with one live micro-batch), the memory-budgeted
    admission windows (``repro.core.cost_model.stage_memory_claims``), and
    ``pipeline.schedule.memory_highwater``.
    """
    static, per_sample = memory_split_per_sample(profile, lo, hi, model)
    eff_b = client_max_share(b, net.num_clients) if node == 0 else b
    return static, eff_b * per_sample


def memory_split_per_sample(profile: ModelProfile, lo: int, hi: int,
                            model: str = "paper") -> tuple:
    """The b-independent core of :func:`memory_split`:
    ``(static_bytes, act_bytes_per_sample)`` — the act term scales by the
    effective micro-batch size.  Factored out so batched sweeps (the
    memory-budgeted windows for a whole range of ``b``) pay the cumulative
    lookups once."""
    if model == "paper":
        return 0.0, profile.seg_mem_per_sample(lo, hi)
    act = (profile.act_cum() + profile.grad_cum())
    static = (profile.param_cum() + profile.opt_cum())
    seg = lambda c: float(c[hi - 1] - (c[lo - 1] if lo > 0 else 0.0))
    return seg(static), seg(act)


def memory_bytes(profile: ModelProfile, net: EdgeNetwork, lo: int, hi: int,
                 node: int, b: int, model: str = "paper") -> float:
    """Eq. (11): eta_k — the footprint with one micro-batch in flight.
    ``model='paper'`` scales the whole footprint by b (as printed);
    ``'refined'`` scales only activations/grads by b."""
    static, act = memory_split(profile, net, lo, hi, node, b, model)
    return act + static


# ---------------------------------------------------------------------------
# Breakdown: every (stage compute / link comm) component of a solution
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LatencyBreakdown:
    """Per-component times for one micro-batch of size b."""
    stage_fp: dict       # k -> seconds
    stage_bp: dict       # k -> seconds
    link_fwd: dict       # (k, n_from, n_to) -> seconds
    link_bwd: dict       # (k, n_from, n_to) -> seconds
    node_of_stage: dict  # k -> node index

    def node_fp_sums(self):
        out = {}
        for k, t in self.stage_fp.items():
            n = self.node_of_stage[k]
            out[n] = out.get(n, 0.0) + t
        return out

    def node_bp_sums(self):
        out = {}
        for k, t in self.stage_bp.items():
            n = self.node_of_stage[k]
            out[n] = out.get(n, 0.0) + t
        return out

    def pair_fwd_sums(self):
        out = {}
        for (_, a, c), t in self.link_fwd.items():
            out[(a, c)] = out.get((a, c), 0.0) + t
        return out

    def pair_bwd_sums(self):
        out = {}
        for (_, a, c), t in self.link_bwd.items():
            out[(a, c)] = out.get((a, c), 0.0) + t
        return out


def breakdown(profile: ModelProfile, net: EdgeNetwork, sol: SplitSolution,
              b: int) -> LatencyBreakdown:
    segs = list(sol.segments())
    stage_fp, stage_bp, link_fwd, link_bwd, node_of = {}, {}, {}, {}, {}
    for k, lo, hi, node in segs:
        stage_fp[k] = fp_latency(profile, net, lo, hi, node, b)
        stage_bp[k] = bp_latency(profile, net, lo, hi, node, b)
        node_of[k] = node
    for (k1, _, hi1, n1), (_, _, _, n2) in zip(segs, segs[1:]):
        fb = fwd_bytes(profile, net, hi1, b, from_client=(n1 == 0))
        gb = bwd_bytes(profile, net, hi1, b, to_client=(n1 == 0))
        link_fwd[(k1, n1, n2)] = comm_latency(net, n1, n2, fb)
        link_bwd[(k1, n2, n1)] = comm_latency(net, n2, n1, gb)
    return LatencyBreakdown(stage_fp, stage_bp, link_fwd, link_bwd, node_of)


# ---------------------------------------------------------------------------
# Eqs. (12)-(14)
# ---------------------------------------------------------------------------

def fill_latency(profile: ModelProfile, net: EdgeNetwork, sol: SplitSolution,
                 b: int) -> float:
    """Eq. (12): T_f — one micro-batch traverses FP then BP over the chain.

    = client FP + fwd comms + server FP/BP sums + bwd comms + client BP.
    (The client terms are maxima over clients; with Eq. (1) shares the
    largest-share client dominates, which ``client_max_share`` captures.)
    """
    bd = breakdown(profile, net, sol, b)
    return (sum(bd.stage_fp.values()) + sum(bd.stage_bp.values()) +
            sum(bd.link_fwd.values()) + sum(bd.link_bwd.values()))


def pipeline_interval(profile: ModelProfile, net: EdgeNetwork,
                      sol: SplitSolution, b: int) -> float:
    """Eq. (13): T_i — the bottleneck component.

    Per C9-C16 the per-node terms sum over co-located submodels, and FP/BP
    (and fwd/bwd links) are separate pipeline resources.
    """
    bd = breakdown(profile, net, sol, b)
    candidates = (list(bd.node_fp_sums().values()) +
                  list(bd.node_bp_sums().values()) +
                  list(bd.pair_fwd_sums().values()) +
                  list(bd.pair_bwd_sums().values()))
    return max(candidates) if candidates else 0.0


def num_fills(B: int, b: int) -> int:
    """xi(b) = ceil((B - b)/b): extra pipeline slots after the first."""
    return math.ceil((B - b) / b)


def total_latency(profile: ModelProfile, net: EdgeNetwork, sol: SplitSolution,
                  b: int, B: int) -> float:
    """Eq. (14): L_t = T_f + ceil((B-b)/b) * T_i."""
    return (fill_latency(profile, net, sol, b) +
            num_fills(B, b) * pipeline_interval(profile, net, sol, b))


def no_pipeline_latency(profile: ModelProfile, net: EdgeNetwork,
                        sol: SplitSolution, B: int) -> float:
    """The 'No Pipeline' benchmark: the whole mini-batch goes through as one
    micro-batch (b = B) — Eq. (14) degenerates to T_f(B)."""
    return fill_latency(profile, net, sol, B)


# ---------------------------------------------------------------------------
# Feasibility (C7, C8)
# ---------------------------------------------------------------------------

def node_memory_usage(profile: ModelProfile, net: EdgeNetwork,
                      sol: SplitSolution, b: int,
                      model: str = "paper") -> dict:
    usage = {}
    for k, lo, hi, node in sol.segments():
        usage[node] = usage.get(node, 0.0) + memory_bytes(
            profile, net, lo, hi, node, b, model)
    return usage


def memory_feasible(profile: ModelProfile, net: EdgeNetwork,
                    sol: SplitSolution, b: int, model: str = "paper") -> bool:
    for node, used in node_memory_usage(profile, net, sol, b, model).items():
        if used > net.nodes[node].mem:
            return False
    return True


def max_feasible_microbatch(profile: ModelProfile, net: EdgeNetwork,
                            sol: SplitSolution, B: int,
                            model: str = "paper") -> int:
    """Largest b in [1, B] satisfying C7/C8 (memory is monotone in b)."""
    lo_b, hi_b = 1, B
    if not memory_feasible(profile, net, sol, 1, model):
        return 0
    while lo_b < hi_b:
        mid = (lo_b + hi_b + 1) // 2
        if memory_feasible(profile, net, sol, mid, model):
            lo_b = mid
        else:
            hi_b = mid - 1
    return lo_b
