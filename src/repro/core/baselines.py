"""The paper's benchmark schemes (Sec. VI-A):

  RC+OP       random cut, optimal placement (our placement + micro-batching)
  RP+OC       random placement, optimal cut (our splitting + micro-batching)
  No-Pipeline optimal MSP but a single micro-batch b = B (Eq. 14 collapses
              to T_f(B)); the upper bound for non-pipelined multi-hop SL/SI
  Optimal     exhaustive-over-b joint optimum (Fig. 7's reference)
  Ours        BCD (Algorithm 2)
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from . import latency as L
from .bcd import Plan, bcd_solve, exhaustive_joint
from .cost_model import SimMakespan, resolve_cost_model
from .latency import SplitSolution
from .microbatch import optimal_microbatch
from .network import EdgeNetwork
from .profiles import ModelProfile
from .shortest_path import Planner


def _finish_plan(profile, net, sol, b, B, cm=None) -> Plan:
    T_f = L.fill_latency(profile, net, sol, b)
    T_i = L.pipeline_interval(profile, net, sol, b)
    cm = resolve_cost_model(cm)
    return Plan(solution=sol, b=b, B=B, T_f=T_f, T_i=T_i,
                L_t=T_f + L.num_fills(B, b) * T_i, iterations=1, history=[],
                solve_seconds=0.0,
                feasible=math.isfinite(T_f) and
                L.memory_feasible(profile, net, sol, b),
                objective=cm.evaluate(profile, net, sol, b, B),
                cost_model=cm.name)


def random_cuts(rng: np.random.Generator, I: int, K: int) -> tuple:
    """K-segment random non-decreasing cut vector ending at I (C4/C5)."""
    s = int(rng.integers(2, K + 1)) if K >= 2 else 1
    if s == 1:
        return (I,)
    inner = np.sort(rng.choice(np.arange(1, I), size=s - 1, replace=False))
    return tuple(int(c) for c in inner) + (I,)


def rc_op(profile: ModelProfile, net: EdgeNetwork, B: int, *, seed: int = 0,
          b0: int = 20, K: int | None = None, tries: int = 4,
          memory_model: str = "paper", solver: str | None = None,
          cost_model=None) -> Plan:
    """Random Cut + Optimal Placement (+ optimal micro-batch for the pipeline
    comparison to be apples-to-apples, as in Fig. 4/5).  ``cost_model``
    scores the re-draws (default: closed-form Eq. 14)."""
    rng = np.random.default_rng(seed)
    cm = resolve_cost_model(cost_model, memory_model)
    K = K or min(1 + net.num_servers, profile.num_layers)
    planner = Planner(profile, net, memory_model)  # shared across re-draws
    best = None
    for _ in range(tries):  # a random cut can be infeasible; re-draw
        cuts = random_cuts(rng, profile.num_layers, K)
        msp = planner.solve(b0, B, K=len(cuts), restrict_cuts=cuts,
                            solver=solver)
        if not msp.feasible:
            continue
        mb = optimal_microbatch(profile, net, msp.solution, B, msp.T_1,
                                memory_model=memory_model, cost_model=cm)
        b = mb.b if mb.b > 0 else b0
        plan = _finish_plan(profile, net, msp.solution, b, B, cm)
        if best is None or plan.objective < best.objective:
            best = plan
    return best if best is not None else _infeasible(profile, B)


def rp_oc(profile: ModelProfile, net: EdgeNetwork, B: int, *, seed: int = 0,
          b0: int = 20, K: int | None = None, tries: int = 4,
          memory_model: str = "paper", solver: str | None = None,
          cost_model=None) -> Plan:
    """Random Placement + Optimal Cut (+ optimal micro-batch)."""
    rng = np.random.default_rng(seed)
    cm = resolve_cost_model(cost_model, memory_model)
    K = K or min(1 + net.num_servers, profile.num_layers)
    servers = list(net.server_indices())
    planner = Planner(profile, net, memory_model)  # shared across re-draws
    best = None
    for _ in range(tries):
        s = min(int(rng.integers(2, K + 1)), 1 + len(servers))
        order = list(rng.permutation(servers)[:s - 1])
        placement = (0,) + tuple(int(n) for n in order)
        msp = planner.solve(b0, B, K=len(placement),
                            restrict_placement=placement, solver=solver)
        if not msp.feasible:
            continue
        mb = optimal_microbatch(profile, net, msp.solution, B, msp.T_1,
                                memory_model=memory_model, cost_model=cm)
        b = mb.b if mb.b > 0 else b0
        plan = _finish_plan(profile, net, msp.solution, b, B, cm)
        if best is None or plan.objective < best.objective:
            best = plan
    return best if best is not None else _infeasible(profile, B)


def no_pipeline(profile: ModelProfile, net: EdgeNetwork, B: int,
                K: int | None = None, memory_model: str = "paper",
                solver: str | None = None, cost_model=None) -> Plan:
    """Optimal MSP with b = B (xi = 0 -> pure min-sum Dijkstra).  'Due to the
    optimality, also the upper bound of existing split inference/learning
    schemes without pipeline parallelism' (Sec. VI-A).  ``cost_model`` is
    accepted for SCHEMES-interface uniformity; there is no pipeline to
    re-score, so it only names the plan's ``cost_model`` — the scheme's
    ``objective`` is its own sequential latency (== ``L_t``), keeping
    min-by-objective comparisons across SCHEMES well-defined."""
    cm = resolve_cost_model(cost_model, memory_model)
    planner = Planner(profile, net, memory_model)  # shared across fallbacks
    msp = planner.solve(B, B, K=K, solver=solver)
    if not msp.feasible:
        # memory may force b < B even without pipelining benefits: fall back
        # to the largest feasible single micro-batch
        for b in (B // 2, B // 4, B // 8, B // 16, 1):
            msp = planner.solve(max(b, 1), B, K=K, solver=solver)
            if msp.feasible:
                sol = msp.solution
                ticks = math.ceil(B / max(b, 1))
                T_f = L.fill_latency(profile, net, sol, max(b, 1))
                return Plan(solution=sol, b=max(b, 1), B=B, T_f=T_f,
                            T_i=T_f, L_t=ticks * T_f, iterations=1,
                            history=[], solve_seconds=0.0,
                            objective=ticks * T_f, cost_model=cm.name)
        return _infeasible(profile, B)
    sol = msp.solution
    T_f = L.fill_latency(profile, net, sol, B)
    return Plan(solution=sol, b=B, B=B, T_f=T_f, T_i=T_f, L_t=T_f,
                iterations=1, history=[], solve_seconds=0.0,
                objective=T_f, cost_model=cm.name)


def ours(profile: ModelProfile, net: EdgeNetwork, B: int, *, b0: int = 20,
         theta: float = 0.01, K: int | None = None,
         memory_model: str = "paper", restarts: bool = True,
         solver: str | None = None, cost_model=None) -> Plan:
    """Algorithm 2, with multi-start over b0 (beyond-paper robustness: BCD
    is a coordinate descent and can sit in a poor basin for one seed; three
    extra solves cost milliseconds and close most of the Fig. 7 gap).  One
    ``Planner`` (graph factory + DP buffers) is shared by every restart.
    ``cost_model`` is forwarded to every ``bcd_solve`` and also decides the
    winner across restarts."""
    cm = resolve_cost_model(cost_model, memory_model)
    planner = Planner(profile, net, memory_model)
    plan = bcd_solve(profile, net, B, b0=b0, theta=theta, K=K,
                     memory_model=memory_model, solver=solver,
                     planner=planner, cost_model=cm)
    if not restarts:
        return plan
    for alt in {max(1, B // 16), max(1, B // 4), max(1, B // 2)} - {b0}:
        cand = bcd_solve(profile, net, B, b0=alt, theta=theta, K=K,
                         memory_model=memory_model, solver=solver,
                         planner=planner, cost_model=cm)
        if cand.feasible and (not plan.feasible
                              or cand.objective < plan.objective):
            plan = cand
    return plan


def sim_refined(profile: ModelProfile, net: EdgeNetwork, B: int, *,
                b0: int = 20, theta: float = 0.01, K: int | None = None,
                memory_model: str = "paper", restarts: bool = False,
                solver: str | None = None, cost_model=None,
                policy="memory", engine: str = "auto") -> Plan:
    """Sim-in-the-loop BCD: Algorithm 2 whose iterate selection and final
    micro-batch refinement optimize the *measured* makespan of
    ``sim.simulate_plan`` under memory-budgeted admission (the default
    ``SimMakespan(policy="memory")``) instead of the closed form.  Restarts
    default off — each one pays an O(B)-simulation refinement scan."""
    cm = cost_model or SimMakespan(policy=policy, engine=engine)
    return ours(profile, net, B, b0=b0, theta=theta, K=K,
                memory_model=memory_model, restarts=restarts, solver=solver,
                cost_model=cm)


def optimal(profile: ModelProfile, net: EdgeNetwork, B: int,
            K: int | None = None, b_step: int = 1,
            memory_model: str = "paper", solver: str | None = None,
            cost_model=None) -> Plan:
    return exhaustive_joint(profile, net, B, K=K, b_step=b_step,
                            memory_model=memory_model, solver=solver,
                            cost_model=cost_model)


SCHEMES = {
    "ours": ours,
    "sim_refined": sim_refined,
    "rc_op": rc_op,
    "rp_oc": rp_oc,
    "no_pipeline": no_pipeline,
}


def _infeasible(profile: ModelProfile, B: int) -> Plan:
    return Plan(solution=SplitSolution((profile.num_layers,), (0,)), b=0, B=B,
                T_f=math.inf, T_i=math.inf, L_t=math.inf, iterations=0,
                history=[], solve_seconds=0.0, feasible=False,
                objective=math.inf)
