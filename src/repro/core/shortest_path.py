"""Algorithm 1 — bottleneck-aware shortest path for the MSP problem.

The MSP objective (P4) is  min over paths of  T_f(path) + xi(b) * T_1(path)
with T_1 = the path's bottleneck (max edge beta) — a combined min-sum +
min-max problem (Minoux 1989).  Exact strategy: dist(t) — the min-sum value
restricted to edges with beta <= t — is a non-increasing step function that
only changes at the distinct bottleneck values B = {beta(e)}, and

    OPT  =  min over t in B of  dist(t) + xi * t

(attained at t = the bottleneck of an optimal path).  Two solvers share one
layered-DP kernel and return bit-identical results:

``solver="scan"`` (the reference implementation, legacy control flow):
  1. binary-search the smallest feasible t (feasibility monotone in t)
  2. scan B ascending, one kernel sweep per threshold, objective
     dist(t) + xi * beta(path_t); break once dist(inf) + xi * t >= best
     (the paper's admissible lower-bound pruning, DESIGN.md §6)

``solver="batched"`` (the default; ISSUE 3 tentpole):
  1. one sweep at t = inf  ->  dist(inf) and the unrestricted path
  2. one *min-max* sweep   ->  beta* = the smallest feasible threshold
     (replaces the binary search: the same kernel with (max, min) algebra)
  3. the admissible window [beta*, (UB - dist(inf)) / xi] of thresholds is
     stacked as a leading axis and ONE masked broadcast min-plus sweep
     returns dist(t) for every candidate simultaneously
  4. argmin over dist(t) + xi * t, one reconstruction sweep at the winner

The kernel itself is a *two-stage* relaxation per DAG layer — first the
communication hop over (n, i, m), then the segment extension over (i, m, j)
— which is O(N^2 I + N I^2) per layer instead of the O(N^2 I^2) dense edge
tensor, and accepts a leading "slice" axis of independent (threshold,
micro-batch) instances.  Because both solvers call the same kernel with the
same float arithmetic and the same argmin tie-breaking, ``batched`` and
``scan`` agree bit-for-bit on (objective, cuts, placement, T_1) — asserted
by the standing randomized cross-check in tests/test_msp.py.

Restrictions (fixed cuts / fixed placement / ordered TPU stages) are
expressed as per-segment masks so the same solver powers the RC+OP / RP+OC
baselines and the TPU stage planner.  ``Planner`` caches the b-independent
``GraphFactory`` precomputation and the DP buffers so BCD iterations and
the b-sweep of ``exhaustive_joint`` (``Planner.solve_many``) reuse them.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Sequence

import numpy as np

from repro import obs

from . import latency as L
from .latency import SplitSolution
from .msp_graph import GraphFactory, MSPGraph, build_graph
from .network import EdgeNetwork
from .profiles import ModelProfile

#: default Algorithm-1 solver; "scan" is the legacy reference implementation
DEFAULT_SOLVER = "batched"

_INF = np.inf


@dataclasses.dataclass
class MSPResult:
    solution: SplitSolution
    objective: float        # T_f + xi * T1  as searched (paper objective)
    T_f: float              # min-sum part of the searched objective
    T_1: float              # bottleneck of the chosen path (searched beta)
    L_t: float              # true Eq. (14) latency of the solution
    T_i_true: float         # true Eq. (13) interval (with co-location sums)
    b: int
    B: int
    thresholds_scanned: int = 0   # total DP kernel sweeps (see note below)
    feasible: bool = True
    solver: str = ""

    # ``thresholds_scanned`` counts *every* DP sweep the solve performed —
    # the full-graph run, binary-search probes, per-threshold scan sweeps,
    # min-max sweeps and reconstructions alike; a batched multi-threshold
    # kernel invocation counts as 1 (ISSUE 3: the old accounting omitted
    # the binary search and the full-graph run, understating planner work).


# ---------------------------------------------------------------------------
# The shared layered-DP kernel
# ---------------------------------------------------------------------------

class _SweepResult:
    __slots__ = ("best_val", "best_k", "best_m", "parents", "stack")

    def __init__(self, best_val, best_k, best_m, parents, stack=None):
        self.best_val, self.best_k, self.best_m = best_val, best_k, best_m
        self.parents = parents
        self.stack = stack          # per-layer dist copies (want_stack=True)


def _ws_get(ws: dict, name: str, shape: tuple, dtype) -> np.ndarray:
    """Workspace buffer, reused across layers and across sweep calls."""
    a = ws.get(name)
    if a is None or a.shape != shape or a.dtype != dtype:
        a = np.empty(shape, dtype=dtype)
        ws[name] = a
    return a


def _sweep(Ccom, Bcom, Sseg, Bseg, src_cost, src_beta, K, ts, *,
           mode="sum", masks=None, want_parents=False, want_stack=False,
           ws=None):
    """Threshold-batched layered-DP sweep over the (k, n, i) DAG.

    Tensor layouts (a leading slice axis of size 1 broadcasts, size S runs
    S independent instances — thresholds and/or per-b graphs):

      Ccom/Bcom[s, n, i, m]  comm cost / bottleneck crossing cut i, n -> m
                             (structurally inf for m == n and m == 0)
      Sseg/Bseg[s, i, m, j]  segment (i, j] on node m
      src_cost/src_beta[s, i]  client segment (0, i] (inf where disallowed)

    ``mode="sum"`` relaxes with (+, min) — shortest path among edges with
    beta <= ts[s].  ``mode="max"`` relaxes with (max, min) — the minimal
    achievable path bottleneck (min-max), used to find beta* in one sweep.

    Per layer the relaxation is two-stage:  A[s, i, m] = min over n of
    dist[s, n, i] (+|max) Ccom[s, n, i, m],  then  dist'[s, m, j] = min over
    i of A[s, i, m] (+|max) Sseg[s, i, m, j].  Ties break to the smallest n
    and then the smallest i (np.argmin takes the first minimum), identically
    for every slice count — which is what makes scan == batched exact.

    ``want_stack=True`` additionally collects the per-layer ``dist`` tensors
    (``stack[k - 2]`` = dist after layer k) so a path can be reconstructed
    host-side *after* the sweep (``planner_jax.backtrace_stack``) without
    paying the argmin parent tracking — the warm-replan reconstruction path.
    """
    ts = np.asarray(ts, dtype=float)
    S = ts.shape[0]
    N, I1 = Ccom.shape[1], Ccom.shape[2]
    I = I1 - 1
    ws = {} if ws is None else ws
    src_val = src_cost if mode == "sum" else src_beta

    dist = np.full((S, N, I1), _INF)
    dist[:, 0, :] = np.where(src_beta <= ts[:, None], src_val, _INF)

    fin0 = np.isfinite(dist[:, 0, I])
    best_val = np.where(fin0, dist[:, 0, I], _INF)
    best_k = np.where(fin0, 1, 0)
    best_m = np.zeros(S, dtype=np.int64)
    parents = []
    stack = [] if want_stack else None

    # the threshold mask is layer-independent: fold beta > t edges to inf
    # ONCE per sweep instead of re-masking per layer (the per-layer work then
    # reduces to one broadcast op and one reduction per stage)
    Vc = Ccom if mode == "sum" else Bcom
    Vs = Sseg if mode == "sum" else Bseg
    if np.isfinite(ts).any():
        t4 = ts[:, None, None, None]
        Vc = np.where(Bcom <= t4, Vc, _INF)
        Vs = np.where(Bseg <= t4, Vs, _INF)
    op = np.add if mode == "sum" else np.maximum

    for k in range(2, K + 1):
        mc, msk = masks(k) if masks is not None else (None, None)
        # stage 1: communication hop (n, i) -> node m across cut i
        cand_c = _ws_get(ws, "cand_c", (S, N, I1, N), np.float64)
        op(dist[:, :, :, None], Vc, out=cand_c)
        if mc is not None:
            cand_c[:, ~mc] = _INF
        if want_parents:
            Ap = cand_c.argmin(axis=1).astype(np.int32)
            A = np.take_along_axis(cand_c, Ap[:, None], axis=1)[:, 0]
        else:
            A = cand_c.min(axis=1)                   # (S, I1, N)
        # stage 2: extend with segment (i, j] on node m
        cand_s = _ws_get(ws, "cand_s", (S, I1, N, I1), np.float64)
        op(A[:, :, :, None], Vs, out=cand_s)
        if msk is not None:
            cand_s[:, ~msk] = _INF
        if want_parents:
            Sp = cand_s.argmin(axis=1).astype(np.int32)
            nd = np.take_along_axis(cand_s, Sp[:, None], axis=1)[:, 0]
            parents.append((Ap, Sp))
        else:
            nd = cand_s.min(axis=1)                  # (S, N, I1)
        dist = nd
        if want_stack:
            stack.append(nd)                         # fresh array (no alias)
        if N > 1:
            term = nd[:, 1:, I]
            v = term.min(axis=1)
            upd = v < best_val
            if upd.any():
                best_val = np.where(upd, v, best_val)
                best_k = np.where(upd, k, best_k)
                best_m = np.where(upd, term.argmin(axis=1) + 1, best_m)
        if not np.isfinite(nd).any():
            break
    return _SweepResult(best_val, best_k, best_m, parents, stack)


def _slices_per_chunk(N: int, I1: int) -> int:
    """Cap the kernel's slice axis so one chunk's workspace stays ~64 MB."""
    return max(1, int(2 ** 23 // max(1, N * I1 * max(N, I1))))


def _walk_parents(parents, s: int, k: int, m: int, j: int) -> list:
    """Reconstruct the [(node, end_layer), ...] path for slice ``s``."""
    if k == 1:
        return [(0, j)]
    path = [(int(m), int(j))]
    for kk in range(k, 1, -1):
        Ap, Sp = parents[kk - 2]
        i = int(Sp[s, m, j])
        n = int(Ap[s, i, m])
        path.append((n, i))
        m, j = n, i
    path.reverse()
    return path


def _betas_from_arrays(Bcom, Bseg, src_beta, lo=-_INF, hi=_INF,
                       mask_c=None, mask_s=None) -> list:
    """Finite candidate bottleneck values max(Bcom, Bseg) within [lo, hi].

    Unmasked case: ``max(a, b)`` is always one of its arguments, so the
    distinct edge-beta *value set* is exactly

        {Bcom[n,i,m]  : Bcom[n,i,m] >= min_j Bseg[i,m,j]}  |
        {Bseg[i,m,j]  : Bseg[i,m,j] >= min_n Bcom[n,i,m]}

    (each side dominating some compatible partner on the shared (i, m)
    pairing) — computed in O(N I N + I N I) instead of materializing the
    O(N^2 I^2) dense max (ISSUE 9: this scan dominated the warm-replan
    wall-clock).  Masked (restricted) calls keep the dense chunked path."""
    vals = [src_beta[(src_beta >= lo) & (src_beta <= hi)
                     & np.isfinite(src_beta)]]
    if mask_c is None and mask_s is None:
        min_seg = Bseg.min(axis=2)                       # (I1, N) over (i, m)
        min_com = Bcom.min(axis=0)                       # (I1, N) over (i, m)
        a_ok = ((Bcom >= lo) & (Bcom <= hi) & np.isfinite(Bcom)
                & (Bcom >= min_seg[None]))
        b_ok = ((Bseg >= lo) & (Bseg <= hi) & np.isfinite(Bseg)
                & (Bseg >= min_com[:, :, None]))
        vals.append(Bcom[a_ok])
        vals.append(Bseg[b_ok])
        return vals
    N = Bcom.shape[0]
    chunk = max(1, int(2 ** 22 // max(1, Bseg.size)))
    for n0 in range(0, N, chunk):
        dense = np.maximum(Bcom[n0:n0 + chunk, :, :, None], Bseg[None])
        if mask_c is not None:
            dense = np.where(mask_c[n0:n0 + chunk, :, :, None], dense, _INF)
        if mask_s is not None:
            dense = np.where(mask_s[None], dense, _INF)
        sel = dense[(dense >= lo) & (dense <= hi) & np.isfinite(dense)]
        vals.append(sel)
    return vals


class _LayeredDP:
    """Rebindable two-stage DP over one MSPGraph (see ``_sweep``).

    Structural masks (servers only for k >= 2, n' != n per Eq. 21, the
    restrict_cuts / restrict_placement selections) and workspace buffers are
    built once; ``rebind`` swaps in a new micro-batch's cost tensors without
    reallocating them (ISSUE 3: reuse across BCD iterations and b-sweeps).
    """

    def __init__(self, g: MSPGraph, K: int,
                 restrict_cuts: Sequence[int] | None = None,
                 restrict_placement: Sequence[int] | None = None):
        self.K = K
        self.restrict_cuts = tuple(restrict_cuts) if restrict_cuts else None
        self.restrict_placement = (tuple(restrict_placement)
                                   if restrict_placement else None)
        self._mask_cache: dict = {}
        self._ws: dict = {}
        self.rebind(g)

    @property
    def restricted(self) -> bool:
        return (self.restrict_cuts is not None or
                self.restrict_placement is not None)

    def rebind(self, g: MSPGraph) -> "_LayeredDP":
        self.g = g
        self.N, self.I = g.N, g.I
        idx = np.arange(self.N)
        # comm-stage tensors over (n, i, m); destinations must be servers
        Ccom = np.ascontiguousarray(g.comm_cost.transpose(1, 0, 2))
        Bcom = np.ascontiguousarray(g.comm_beta.transpose(1, 0, 2))
        Ccom[:, :, 0] = _INF
        Bcom[:, :, 0] = _INF
        Ccom[idx, :, idx] = _INF                     # n' != n (Eq. 21)
        Bcom[idx, :, idx] = _INF
        # seg-stage tensors over (i, m, j)
        Sseg = np.ascontiguousarray(g.seg_cost.transpose(1, 0, 2))
        Bseg = np.ascontiguousarray(g.seg_beta.transpose(1, 0, 2))
        src_ok = np.isfinite(g.src_cost)
        if self.restrict_cuts is not None:
            sel = np.zeros_like(src_ok)
            sel[self.restrict_cuts[0]] = True
            src_ok = src_ok & sel
        self._Ccom, self._Bcom = Ccom[None], Bcom[None]
        self._Sseg, self._Bseg = Sseg[None], Bseg[None]
        self._src_cost = np.where(src_ok, g.src_cost, _INF)[None]
        self._src_beta = np.where(src_ok, g.src_beta, _INF)[None]
        self._dense_beta = None          # legacy dense edge betas, on demand
        return self

    # -- restriction masks ---------------------------------------------------
    def _masks(self, k: int):
        """(comm mask over (n,i,m), seg mask over (i,m,j)) for layer k."""
        got = self._mask_cache.get(k)
        if got is not None:
            return got
        I1, N = self.I + 1, self.N
        mc = ms = None
        if self.restrict_cuts is not None:
            prev, cur = self.restrict_cuts[k - 2], self.restrict_cuts[k - 1]
            mc = np.zeros((N, I1, N), dtype=bool)
            mc[:, prev, :] = True
            ms = np.zeros((I1, N, I1), dtype=bool)
            ms[prev, :, cur] = True
        if self.restrict_placement is not None:
            pn = self.restrict_placement[k - 2]
            cn = self.restrict_placement[k - 1]
            mc2 = np.zeros((N, I1, N), dtype=bool)
            mc2[pn, :, cn] = True
            mc = mc2 if mc is None else (mc & mc2)
            ms2 = np.zeros((I1, N, I1), dtype=bool)
            ms2[:, cn, :] = True
            ms = ms2 if ms is None else (ms & ms2)
        self._mask_cache[k] = (mc, ms)
        return mc, ms

    # -- sweeps --------------------------------------------------------------
    def sweep(self, ts, *, mode="sum", want_parents=False,
              want_stack=False) -> _SweepResult:
        return _sweep(self._Ccom, self._Bcom, self._Sseg, self._Bseg,
                      self._src_cost, self._src_beta, self.K,
                      np.atleast_1d(np.asarray(ts, dtype=float)),
                      mode=mode, masks=self._masks if self.restricted else None,
                      want_parents=want_parents, want_stack=want_stack,
                      ws=self._ws)

    def mirror(self):
        """The bound graph tensors in backtrace layout (see
        ``planner_jax.backtrace_stack``) — the DP's own float64 buffers."""
        return (self._Ccom[0], self._Bcom[0], self._Sseg[0], self._Bseg[0],
                self._src_cost[0], self._src_beta[0])

    def run(self, t: float):
        """Shortest path with all edge betas <= t. Returns (dist, path)."""
        out = self.sweep([t], want_parents=True)
        if out.best_k[0] == 0:
            return math.inf, None
        path = _walk_parents(out.parents, 0, int(out.best_k[0]),
                             int(out.best_m[0]), self.I)
        return float(out.best_val[0]), path

    def run_dense(self, t: float):
        """Legacy reference sweep: materializes the dense (i, n, m, j) edge
        tensor per layer per threshold — the pre-ISSUE-3 Algorithm-1 inner
        loop that ``solver="scan"`` keeps as the cross-validation baseline.

        Bit-identical to :meth:`run`: the edge weight is grouped as
        ``(dist + comm) + seg`` and the argmin flattens (i, n)-major, which
        reproduces the two-stage kernel's float rounding and tie-breaking
        exactly (addition of a shared addend preserves float ordering)."""
        N, I = self.N, self.I
        I1 = I + 1
        Ccom_inm = self._Ccom[0].transpose(1, 0, 2)      # (I1, N, N)
        Sseg = self._Sseg[0]                             # (I1, N, I1)
        if self._dense_beta is None:
            self._dense_beta = np.maximum(
                self._Bcom[0].transpose(1, 0, 2)[:, :, :, None],
                self._Bseg[0][:, None, :, :])
        dist = np.full((N, I1), _INF)
        dist[0, :] = np.where(self._src_beta[0] <= t, self._src_cost[0], _INF)
        best_val, best_state = _INF, None
        if np.isfinite(dist[0, I]):
            best_val, best_state = float(dist[0, I]), (1, 0, I)
        parents = []
        for k in range(2, self.K + 1):
            tmp = dist.T[:, :, None] + Ccom_inm          # (I1, N, N) [i,n,m]
            cand = tmp[:, :, :, None] + Sseg[:, None, :, :]   # (I1,N,N,I1)
            ok = self._dense_beta <= t
            if self.restricted:
                mc, msk = self._masks(k)
                if mc is not None:
                    ok = ok & mc.transpose(1, 0, 2)[:, :, :, None]
                if msk is not None:
                    ok = ok & msk[:, None, :, :]
            cand = np.where(ok, cand, _INF)
            flat = cand.reshape(I1 * N, N, I1)
            nd = flat.min(axis=0)
            parents.append(flat.argmin(axis=0))          # encodes i * N + n
            dist = nd
            if N > 1:
                v = nd[1:, I].min()
                if v < best_val:
                    best_val = float(v)
                    best_state = (k, 1 + int(nd[1:, I].argmin()), I)
            if not np.isfinite(nd).any():
                break
        if best_state is None:
            return math.inf, None
        k, m, j = best_state
        path = [(m, j)]
        while k >= 2:
            p = int(parents[k - 2][m, j])
            i, n = divmod(p, N)
            path.append((n, i))
            m, j, k = n, i, k - 1
        path.reverse()
        return best_val, path

    def dist_at(self, ts, backend: str = "numpy") -> np.ndarray:
        """dist(t) for every threshold in ``ts`` — one batched sweep
        (slice-chunked so the workspace stays memory-bounded on instances
        with weak pruning)."""
        ts = np.atleast_1d(np.asarray(ts, dtype=float))
        if backend == "jax":
            return _dist_at_jax(self, ts)
        per = _slices_per_chunk(self.N, self.I + 1)
        if len(ts) <= per:
            return self.sweep(ts).best_val
        out = np.empty(len(ts))
        for c0 in range(0, len(ts), per):
            out[c0:c0 + per] = self.sweep(ts[c0:c0 + per]).best_val
        return out

    def min_bottleneck(self) -> float:
        """beta* = min over feasible paths of the path bottleneck, via one
        (max, min) sweep — replaces the legacy feasibility binary search."""
        out = self.sweep([_INF], mode="max")
        return float(out.best_val[0])

    # -- candidate thresholds ------------------------------------------------
    def betas_window(self, lo: float, hi: float) -> np.ndarray:
        """Sorted distinct candidate bottleneck values within [lo, hi]."""
        Bcom, Bseg = self._Bcom[0], self._Bseg[0]
        src_beta = self._src_beta[0]
        if not self.restricted:
            vals = _betas_from_arrays(Bcom, Bseg, src_beta, lo, hi)
        else:
            vals = [src_beta[(src_beta >= lo) & (src_beta <= hi)
                             & np.isfinite(src_beta)]]
            for k in range(2, self.K + 1):
                mc, msk = self._masks(k)
                vals += _betas_from_arrays(Bcom, Bseg, src_beta, lo, hi,
                                           mask_c=mc, mask_s=msk)[1:]
        if not vals:
            return np.empty(0)
        return np.unique(np.concatenate([np.atleast_1d(v) for v in vals]))

    def all_betas(self) -> np.ndarray:
        return self.betas_window(-_INF, _INF)


# ---------------------------------------------------------------------------
# Optional jax backend (jit + vmap over thresholds) for the batched sweep
# ---------------------------------------------------------------------------

def _dist_at_jax(dp: _LayeredDP, ts: np.ndarray) -> np.ndarray:
    """dist(t) per threshold via jax (jit + vmap over thresholds).

    Dtype contract (ISSUE 9 satellite): jax *silently truncates* float64
    inputs to float32 unless x64 is enabled, so the compute dtype is
    **detected** (``planner_jax.sweep_dtype``), the inputs are cast to it
    explicitly, and the tolerance vs the numpy kernel is the documented
    ``planner_jax.parity_tolerance()``:

      - x64 enabled  -> float64, bit-exact with the numpy kernel;
      - x64 disabled -> float32, dist values within rtol 1e-4 (asserted by
        the both-modes parity test in tests/test_planner_jax.py).  Use the
        numpy backend where the scan == batched equality contract matters.
    """
    import jax
    import jax.numpy as jnp

    from . import planner_jax

    if dp.restricted:                 # masks are numpy-side; keep it simple
        return dp.sweep(ts).best_val
    dt = np.dtype(planner_jax.sweep_dtype())
    Ccom = jnp.asarray(dp._Ccom[0].astype(dt))
    Bcom = jnp.asarray(dp._Bcom[0].astype(dt))
    Sseg = jnp.asarray(dp._Sseg[0].astype(dt))
    Bseg = jnp.asarray(dp._Bseg[0].astype(dt))
    src_cost = jnp.asarray(dp._src_cost[0].astype(dt))
    src_beta = jnp.asarray(dp._src_beta[0].astype(dt))
    K, I, N = dp.K, dp.I, dp.N
    inf = jnp.inf
    obs.inc("planner.jax_dispatches")

    def one(t):
        dist = jnp.full((N, I + 1), inf, dtype=Ccom.dtype)
        dist = dist.at[0, :].set(jnp.where(src_beta <= t, src_cost, inf))
        best = jnp.where(jnp.isfinite(dist[0, I]), dist[0, I], inf)
        for _ in range(2, K + 1):
            cand_c = jnp.where(Bcom <= t, dist[:, :, None] + Ccom, inf)
            A = cand_c.min(axis=0)
            cand_s = jnp.where(Bseg <= t, A[:, :, None] + Sseg, inf)
            dist = cand_s.min(axis=0)
            if N > 1:
                best = jnp.minimum(best, dist[1:, I].min())
        return best

    out = jax.jit(jax.vmap(one))(jnp.asarray(ts.astype(dt)))
    return np.asarray(out).astype(np.float64)


# ---------------------------------------------------------------------------
# The reusable planner: factory + DP caches + both solver strategies
# ---------------------------------------------------------------------------

class Planner:
    """Reusable Algorithm-1 engine for one (profile, network, memory model).

    Holds the :class:`~repro.core.msp_graph.GraphFactory` (b-independent
    precomputation) plus per-restriction DP buffers, so repeated solves —
    BCD iterations, baseline restarts, the exhaustive b-sweep — share all
    structural work.  ``solve`` is one Algorithm-1 call; ``solve_many``
    batches a whole micro-batch sweep through the same kernel.
    """

    def __init__(self, profile: ModelProfile, net: EdgeNetwork,
                 memory_model: str = "paper"):
        self.profile, self.net = profile, net
        self.memory_model = memory_model
        self.factory = GraphFactory(profile, net, memory_model)
        self._graphs: dict = {}
        self._dps: dict = {}
        self._solved: dict = {}
        self._epoch = 0                 # bumped by update(); keys jax caches
        self._jax_dps: dict = {}        # (K, dtype) -> planner_jax.JaxDP
        self._mirrors: dict = {}        # (b, dtype) -> host-mirror tensors
        self._hints: dict = {}          # (b, B, K) -> warm-start hint

    # -- caches -------------------------------------------------------------
    def graph(self, b: int) -> MSPGraph:
        g = self._graphs.get(b)
        if g is None:
            obs.inc("planner.graph_cache_miss")
            g = self.factory.graph(b)
            self._graphs[b] = g
        else:
            obs.inc("planner.graph_cache_hit")
        return g

    def _dp(self, b: int, K: int, rc, rp) -> _LayeredDP:
        key = (K, rc, rp)
        g = self.graph(b)
        dp = self._dps.get(key)
        if dp is None:
            obs.inc("planner.dp_cache_miss")
            dp = _LayeredDP(g, K, rc, rp)
            self._dps[key] = dp
        else:
            obs.inc("planner.dp_cache_hit")
            if dp.g is not g:
                dp.rebind(g)
        return dp

    def default_K(self, K: int | None) -> int:
        if K is not None:
            return K
        return min(1 + self.net.num_servers, self.profile.num_layers)

    def _jax_dp(self, K: int):
        """Compiled jax backend for this factory (cached; see planner_jax)."""
        from . import planner_jax
        key = (K, planner_jax.sweep_dtype())
        jdp = self._jax_dps.get(key)
        if jdp is None:
            jdp = planner_jax.JaxDP(self.factory, K)
            self._jax_dps[key] = jdp
        return jdp

    def _jax_mirror(self, b: int, dtype: str):
        """Host mirror of the assembled graph for ``b`` in the kernel dtype
        (window candidates + backtraces for the jax backend; cached)."""
        m = self._mirrors.get((b, dtype))
        if m is None:
            from . import planner_jax
            m = planner_jax.host_mirror(self.factory, b, dtype)
            self._mirrors[(b, dtype)] = m
        return m

    # -- incremental updates (ISSUE 9 tentpole) -----------------------------
    def update(self, delta) -> "Planner":
        """Apply a single-resource delta *in place* and invalidate exactly
        what it touched — the warm-replan entry point.

        ``delta`` is duck-typed against the ``ft.coordinator`` events:

          - ``RateChange``-like (``n_from``/``n_to``/``factor``): the rate
            mutation is replicated float-op-for-float-op, the factory's rate
            views are swapped, and each cached graph's comm columns for the
            (n_from, n_to) **pair** (both directions use the link) are
            re-assembled via ``GraphFactory.comm_pair`` — bitwise equal to a
            cold rebuild on the mutated network.
          - ``Straggler``-like (``node``/``slowdown``): the node-speed
            mutation, patching that node's seg row (``seg_node``) and, for
            the client tier, the source vectors.
          - ``NodeFailure``-like (``server``): renumbering — everything is
            rebuilt on ``net.degraded([server])`` (shapes change).
          - ``Resync``-like (``net``): full rebuild on the snapshot.

        Warm-start hints survive a patch with their lower bounds scaled by
        ``r_min`` — the largest factor by which any edge weight may have
        *shrunk* (1/factor for a rate increase, the slowdown for a node
        speed-up, 1 otherwise), so the scaled values still lower-bound the
        new ``dist(inf)`` and ``beta*`` and the next ``solve`` runs one
        windowed sweep instead of a cold Algorithm 1 (proof sketch on
        ``_solve_warm``).  ``r_min`` compounds across successive updates:
        bounds only loosen, never break.  Returns ``self``.
        """
        if hasattr(delta, "server"):                      # NodeFailure
            obs.inc("planner.updates[rebuild]")
            self._rebuild(self.net.degraded([delta.server]))
            return self
        if hasattr(delta, "factor"):                      # RateChange
            obs.inc("planner.updates[rate]")
            rate = self.net.rate.copy()
            rate[delta.n_from, delta.n_to] *= delta.factor
            self.net = dataclasses.replace(self.net, rate=rate)
            self.factory.patch_rate(self.net)
            u, v = int(delta.n_from), int(delta.n_to)
            for b, g in list(self._graphs.items()):
                eff = self.factory.effective_batch(b)
                for (a, c) in {(u, v), (v, u)}:
                    cost, beta = self.factory.comm_pair(eff, a, c)
                    g.comm_cost[:, a, c] = cost
                    g.comm_beta[:, a, c] = beta
                # a NEW graph object (sharing the patched arrays) so cached
                # DPs see ``dp.g is not g`` and rebind their buffers
                self._graphs[b] = dataclasses.replace(g, net=self.net)
            r_min = min(1.0, 1.0 / delta.factor) if delta.factor > 0 else 0.0
            self._after_patch(r_min)
            return self
        if hasattr(delta, "slowdown"):                    # Straggler
            obs.inc("planner.updates[speed]")
            w = int(delta.node)
            self.net = dataclasses.replace(
                self.net,
                nodes=[dataclasses.replace(n, f=n.f / delta.slowdown)
                       if i == w else n
                       for i, n in enumerate(self.net.nodes)])
            self.factory.patch_node_speed(self.net)
            for b, g in list(self._graphs.items()):
                eff = self.factory.effective_batch(b)
                sc, sb = self.factory.seg_node(eff, w)
                g.seg_cost[w] = sc
                g.seg_beta[w] = sb
                kw = {"net": self.net}
                if w == 0:
                    kw["src_cost"] = sc[0].copy()
                    kw["src_beta"] = sb[0].copy()
                self._graphs[b] = dataclasses.replace(g, **kw)
            r_min = min(1.0, float(delta.slowdown))
            self._after_patch(r_min)
            return self
        if getattr(delta, "net", None) is not None:       # Resync snapshot
            obs.inc("planner.updates[rebuild]")
            self._rebuild(delta.net)
            return self
        raise TypeError(f"unsupported planner delta: {delta!r}")

    def _after_patch(self, r_min: float) -> None:
        """Invalidate what an in-place patch touched: solve memos, host
        mirrors, and the jax backends' device copies of rate/f (kernels are
        kept — the mutable tensors ride as arguments).  Hints survive with
        their lower bounds scaled by ``r_min``."""
        self._epoch += 1
        self._solved.clear()
        self._mirrors.clear()
        for jdp in self._jax_dps.values():
            jdp.refresh()
        for h in self._hints.values():
            h["lb_dist"] *= r_min
            h["lb_beta"] *= r_min

    def _rebuild(self, net: EdgeNetwork) -> None:
        """Full invalidation (renumbering / snapshot): new factory, drop
        every cache; hints die with the old node indices."""
        self._epoch += 1
        self.net = net
        self.factory = GraphFactory(self.profile, net, self.memory_model)
        self._graphs.clear()
        self._dps.clear()
        self._solved.clear()
        self._mirrors.clear()
        self._jax_dps.clear()
        self._hints.clear()

    # -- result assembly ----------------------------------------------------
    def _finish(self, g: MSPGraph, dist, path, b, B, xi, sweeps, solver):
        profile, net = self.profile, self.net
        if path is None:
            return MSPResult(solution=SplitSolution((profile.num_layers,), (0,)),
                             objective=math.inf, T_f=math.inf, T_1=math.inf,
                             L_t=math.inf, T_i_true=math.inf, b=b, B=B,
                             thresholds_scanned=sweeps, feasible=False,
                             solver=solver)
        sol = SplitSolution(cuts=tuple(i for _, i in path),
                            placement=tuple(n for n, _ in path))
        T_f = L.fill_latency(profile, net, sol, b)
        T_i = L.pipeline_interval(profile, net, sol, b)
        beta_path = _path_bottleneck(g, path)
        return MSPResult(solution=sol, objective=dist + xi * beta_path,
                         T_f=T_f, T_1=beta_path, L_t=T_f + xi * T_i,
                         T_i_true=T_i, b=b, B=B, thresholds_scanned=sweeps,
                         solver=solver)

    # -- solvers ------------------------------------------------------------
    def solve(self, b: int, B: int, K: int | None = None,
              restrict_cuts: Sequence[int] | None = None,
              restrict_placement: Sequence[int] | None = None,
              solver: str | None = None, backend: str = "numpy") -> MSPResult:
        solver = solver or DEFAULT_SOLVER
        K = self.default_K(K)
        rc = tuple(restrict_cuts) if restrict_cuts else None
        rp = tuple(restrict_placement) if restrict_placement else None
        # result memo: Algorithm-1 solves are deterministic in these
        # arguments, and the BCD alternation (plus a sim-scored solve's
        # closed-form warm start) re-requests the same (b, B) repeatedly —
        # the convergence iteration alone re-solves the stabilized b
        key = (b, B, K, rc, rp, solver, backend)
        hit = self._solved.get(key)
        if hit is not None:
            obs.inc("planner.solve_memo_hit")
            return hit
        obs.inc("planner.solve_memo_miss")
        with obs.span("planner.solve", b=b, B=B, solver=solver):
            dp = self._dp(b, K, rc, rp)
            g = self.graph(b)
            xi = L.num_fills(B, b)
            if solver == "scan":
                res = self._solve_scan(dp, g, b, B, xi)
            elif solver == "batched":
                res = None
                hint = (self._hints.get((b, B, K))
                        if rc is None and rp is None and backend == "numpy"
                        else None)
                if hint is not None and xi > 0:
                    res = self._solve_warm(dp, g, b, B, xi, hint)
                if res is not None:
                    obs.inc("planner.incremental_hits")
                else:
                    if rc is None and rp is None:
                        obs.inc("planner.cold_solves")
                    res = self._solve_batched(dp, g, b, B, xi, backend)
            else:
                raise ValueError(
                    f"unknown solver {solver!r} (want 'scan'|'batched')")
        obs.inc("planner.dp_sweeps", res.thresholds_scanned)
        self._solved[key] = res
        return res

    def _solve_scan(self, dp: _LayeredDP, g: MSPGraph, b, B, xi) -> MSPResult:
        """Legacy Algorithm 1: binary search + ascending pruned scan, one
        dense-tensor DP sweep per probed threshold (``_LayeredDP.run_dense``).
        Kept as the reference implementation and benchmark baseline."""
        sweeps = 0

        def run(t):
            nonlocal sweeps
            sweeps += 1
            return dp.run_dense(t)

        if xi == 0:                            # no pipelining: pure min-sum
            dist, path = run(math.inf)
            return self._finish(g, dist, path, b, B, xi, sweeps, "scan")

        betas = dp.all_betas()
        if betas.size == 0:
            return self._finish(g, math.inf, None, b, B, xi, sweeps, "scan")
        dist_full, path_full = run(math.inf)
        if path_full is None:
            return self._finish(g, math.inf, None, b, B, xi, sweeps, "scan")

        # binary search the smallest feasible threshold (monotone in t)
        lo, hi = 0, len(betas) - 1
        while lo < hi:
            mid = (lo + hi) // 2
            d, _ = run(betas[mid])
            if math.isfinite(d):
                hi = mid
            else:
                lo = mid + 1

        best, best_pair = math.inf, None
        for idx in range(lo, len(betas)):
            t = float(betas[idx])
            if dist_full + xi * t >= best:      # admissible prune -> break
                break
            d, p = run(t)
            if p is None:
                continue
            beta_p = _path_bottleneck(g, p)     # actual path bottleneck <= t
            obj = d + xi * beta_p
            if obj < best:
                best, best_pair = obj, (d, p)
        if best_pair is None:
            return self._finish(g, math.inf, None, b, B, xi, sweeps, "scan")
        return self._finish(g, best_pair[0], best_pair[1], b, B, xi, sweeps,
                            "scan")

    def _solve_batched(self, dp: _LayeredDP, g: MSPGraph, b, B, xi,
                       backend="numpy") -> MSPResult:
        """Threshold-batched Algorithm 1 (see module docstring)."""
        dist_full, path_full = dp.run(math.inf)
        sweeps = 1
        if xi == 0:
            return self._finish(g, dist_full, path_full, b, B, xi, sweeps,
                                "batched")
        if path_full is None:
            return self._finish(g, math.inf, None, b, B, xi, sweeps, "batched")

        beta_star = dp.min_bottleneck()        # smallest feasible threshold
        sweeps += 1
        d_star, p_star = dp.run(beta_star)
        sweeps += 1
        ub = min(dist_full + xi * _path_bottleneck(g, path_full),
                 d_star + xi * _path_bottleneck(g, p_star))
        cap = (ub - dist_full) / xi            # prune: dist_full + xi*t >= ub
        window = dp.betas_window(beta_star, cap * (1 + 1e-12) + 1e-12)
        if window.size == 0:                   # numerical corner: fall back
            window = np.array([beta_star])
        dvals = self._dist_window(dp, window, backend)
        sweeps += 1
        j = int(np.argmin(dvals + xi * window))   # first minimum: smallest t
        t_hat = float(window[j])
        if t_hat == beta_star:
            d_hat, p_hat = d_star, p_star
        else:
            d_hat, p_hat = dp.run(t_hat)
            sweeps += 1
        if not dp.restricted and p_hat is not None:
            self._hints[(b, B, dp.K)] = {"lb_dist": dist_full,
                                         "lb_beta": beta_star,
                                         "path": list(p_hat)}
        return self._finish(g, d_hat, p_hat, b, B, xi, sweeps, "batched")

    def _dist_window(self, dp: _LayeredDP, window, backend: str) -> np.ndarray:
        """The phase-3 window sweep, dispatched per backend:

          - ``"numpy"``  the reference chunked ``_sweep`` (bit-exact contract)
          - ``"jax"``    the batched on-the-fly-assembly kernel
                         (``planner_jax.dist_at_jax``; float32 unless x64)
          - ``"pallas"`` the ``kernels.minplus`` Pallas kernel (interpreter
                         mode off-TPU)

        Both accelerated paths degrade to numpy when unavailable or when the
        DP carries restriction masks (numpy-side only)."""
        if backend == "pallas":
            from repro.kernels.minplus import pallas_available, sweep_minplus
            if not dp.restricted and pallas_available():
                obs.inc("planner.pallas_dispatches")
                return sweep_minplus(dp._Ccom[0], dp._Bcom[0], dp._Sseg[0],
                                     dp._Bseg[0], dp._src_cost[0],
                                     dp._src_beta[0], dp.K, window)
            return dp.dist_at(window)
        if backend == "jax":
            from . import planner_jax
            if not dp.restricted and planner_jax.available():
                return planner_jax.dist_at_jax(dp, window, planner=self)
            return dp.dist_at(window)
        return dp.dist_at(window, backend=backend)

    def _solve_warm(self, dp: _LayeredDP, g: MSPGraph, b, B, xi,
                    hint: dict):
        """Warm-started Algorithm 1 from a surviving hint — bit-identical to
        the cold batched solve, in a fraction of its sweeps.

        The hint carries a known-valid path (the previous optimum, repriced
        here on the patched graph -> upper bound UB) and scaled lower bounds
        ``lb_dist <= dist(inf)`` and ``lb_beta <= beta*``.  Every global
        minimizer t of dist(t) + xi*t then lies in
        ``[lb_beta, (UB - lb_dist) / xi]``: t >= beta* >= lb_beta, and
        xi*t = OPT - dist(t) <= UB - dist(inf) <= UB - lb_dist.  The cold
        solver's window is pruned by the *same* argument with its own valid
        bounds, so both windows contain every global minimizer; the
        first-minimum argmin therefore lands on the same smallest minimizing
        threshold, and the reconstruction at that threshold runs the same
        kernel — same path, same floats (``tests/test_planner_update.py``
        asserts the end-to-end equality).  One windowed sweep + one
        single-threshold stack sweep replace the cold solve's 4-5 sweeps.

        Returns None (caller falls back to a cold solve) when the hinted
        path went infeasible or a numerical corner empties the window."""
        from . import planner_jax

        cost, beta_p = planner_jax.reprice_dp_order(g, hint["path"])
        if not (math.isfinite(cost) and math.isfinite(beta_p)):
            return None
        ub = cost + xi * beta_p
        cap = (ub - hint["lb_dist"]) / xi
        window = dp.betas_window(hint["lb_beta"], cap * (1 + 1e-12) + 1e-12)
        if window.size == 0:
            return None
        # small windows (the common case: a local delta barely moves the
        # optimum) fuse the window sweep and the reconstruction sweep into
        # one want_stack dispatch; big windows keep the stack memory bounded
        # by sweeping values first and re-running only the argmin threshold
        fused = window.size <= 32
        if fused:
            out = dp.sweep(window, want_stack=True)
            dvals = out.best_val
        else:
            dvals = dp.dist_at(window)
        j = int(np.argmin(dvals + xi * window))   # first minimum: smallest t
        t_hat = float(window[j])
        if not math.isfinite(dvals[j]):
            return None
        if not fused:
            out = dp.sweep([t_hat], want_stack=True)
            j = 0
        if out.best_k[j] == 0:
            return None
        path = planner_jax.backtrace_stack(
            [layer[j] for layer in out.stack], dp.mirror(), t_hat,
            int(out.best_k[j]), int(out.best_m[j]), dp.I)
        self._hints[(b, B, dp.K)]["path"] = list(path)
        return self._finish(g, float(out.best_val[j]), path, b, B, xi,
                            1 if fused else 2, "batched")

    # -- batched micro-batch sweep (exhaustive_joint's inner loop) ----------
    def solve_many(self, bs: Sequence[int], B: int, K: int | None = None,
                   backend: str = "numpy") -> list:
        """Algorithm 1 for every micro-batch size in ``bs`` at once.

        The b-axis rides the same kernel slice axis as the thresholds: the
        full-graph runs, the min-max beta* sweeps, the beta* probes, the
        stacked threshold windows and the reconstructions each execute as
        ONE multi-slice sweep across all b.  Results are bit-identical to
        ``[self.solve(b, B, K, solver="batched") for b in bs]`` (asserted in
        tests/test_msp.py).

        ``backend="jax"`` dispatches the whole pipeline — graph assembly
        included — to the compiled batched kernel of
        :mod:`repro.core.planner_jax` (phases A-D as a handful of XLA
        dispatches; bit-exact under x64, documented float32 tolerance
        otherwise); it degrades to numpy when jax is unavailable."""
        bs = list(bs)
        with obs.span("planner.solve_many", n=len(bs), B=B, backend=backend):
            if backend == "jax":
                from . import planner_jax
                if planner_jax.available():
                    results = planner_jax.solve_many_jax(self, bs, B, K)
                else:
                    results = self._solve_many(bs, B, K)
            else:
                results = self._solve_many(bs, B, K)
        obs.inc("planner.dp_sweeps",
                sum(r.thresholds_scanned for r in results))
        return results

    def _solve_many(self, bs: list, B: int, K: int | None = None) -> list:
        K = self.default_K(K)
        S = len(bs)
        N, I = len(self.net.nodes), self.profile.num_layers
        I1 = I + 1
        idx = np.arange(N)

        Ccom = np.empty((S, N, I1, N))
        Bcom = np.empty((S, N, I1, N))
        Sseg = np.empty((S, I1, N, I1))
        Bseg = np.empty((S, I1, N, I1))
        src_cost = np.empty((S, I1))
        src_beta = np.empty((S, I1))
        graphs = []
        for s, b in enumerate(bs):
            g = self.graph(b)
            graphs.append(g)
            Ccom[s] = g.comm_cost.transpose(1, 0, 2)
            Bcom[s] = g.comm_beta.transpose(1, 0, 2)
            Sseg[s] = g.seg_cost.transpose(1, 0, 2)
            Bseg[s] = g.seg_beta.transpose(1, 0, 2)
            src_cost[s] = g.src_cost
            src_beta[s] = g.src_beta
        Ccom[:, :, :, 0] = _INF
        Bcom[:, :, :, 0] = _INF
        Ccom[:, idx, :, idx] = _INF
        Bcom[:, idx, :, idx] = _INF

        xi = np.array([L.num_fills(B, b) for b in bs])
        inf_ts = np.full(S, _INF)

        def stacked(sel, ts, **kw):
            """Sweep the selected slices (gathered tensors) at thresholds ts."""
            sel = np.asarray(sel)
            return _sweep(Ccom[sel], Bcom[sel], Sseg[sel], Bseg[sel],
                          src_cost[sel], src_beta[sel], K,
                          np.asarray(ts, dtype=float), **kw)

        # phase A: full-graph runs for every b (one stacked sweep)
        outA = _sweep(Ccom, Bcom, Sseg, Bseg, src_cost, src_beta, K, inf_ts,
                      want_parents=True)
        paths_full = [
            _walk_parents(outA.parents, s, int(outA.best_k[s]),
                          int(outA.best_m[s]), I) if outA.best_k[s] else None
            for s in range(S)]

        results: list = [None] * S
        live = []                               # slices still being solved
        for s in range(S):
            if xi[s] == 0 or paths_full[s] is None:
                results[s] = self._finish(
                    graphs[s], float(outA.best_val[s]), paths_full[s],
                    bs[s], B, int(xi[s]), 1, "batched")
            else:
                live.append(s)
        if not live:
            return results

        # phase B: one (max, min) sweep -> beta* per live b, then one stacked
        # probe at beta* (parents -> the upper-bound path per b)
        outB = stacked(live, [_INF] * len(live), mode="max")
        beta_star = outB.best_val
        outP = stacked(live, beta_star, want_parents=True)
        paths_star, windows = [], []
        for q, s in enumerate(live):
            p_star = _walk_parents(outP.parents, q, int(outP.best_k[q]),
                                   int(outP.best_m[q]), I)
            paths_star.append(p_star)
            ub = min(float(outA.best_val[s])
                     + xi[s] * _path_bottleneck(graphs[s], paths_full[s]),
                     float(outP.best_val[q])
                     + xi[s] * _path_bottleneck(graphs[s], p_star))
            cap = (ub - float(outA.best_val[s])) / xi[s]
            w = _betas_from_arrays(Bcom[s], Bseg[s], src_beta[s],
                                   beta_star[q], cap * (1 + 1e-12) + 1e-12)
            w = np.unique(np.concatenate([np.atleast_1d(v) for v in w]))
            if w.size == 0:
                w = np.array([beta_star[q]])
            windows.append(w)

        # phase C: ONE stacked sweep over every (b, threshold) pair (chunked
        # so the slice axis stays memory-bounded), then argmin per b
        slice_b = np.concatenate(
            [np.full(len(w), s) for s, w in zip(live, windows)])
        slice_t = np.concatenate(windows)
        t_hat = np.empty(len(live))
        per_slice = _slices_per_chunk(N, I1)
        dvals = np.empty(len(slice_t))
        for c0 in range(0, len(slice_t), per_slice):
            c1 = min(c0 + per_slice, len(slice_t))
            dvals[c0:c1] = stacked(slice_b[c0:c1], slice_t[c0:c1]).best_val
        pos = 0
        for q, w in enumerate(windows):
            H = dvals[pos:pos + len(w)] + xi[live[q]] * w
            t_hat[q] = w[int(np.argmin(H))]
            pos += len(w)

        # phase D: one stacked reconstruction sweep at the winners; slices
        # whose winner IS beta* reuse the phase-B probe path instead (same
        # kernel, same threshold), exactly like the per-b solve — which also
        # keeps the 4-vs-5 sweep accounting identical to solve()
        need = [q for q in range(len(live)) if t_hat[q] != beta_star[q]]
        if need:
            outR = stacked([live[q] for q in need], t_hat[need],
                           want_parents=True)
        for r, q in enumerate(need):
            s = live[q]
            if outR.best_k[r] == 0:
                path = None
            else:
                path = _walk_parents(outR.parents, r, int(outR.best_k[r]),
                                     int(outR.best_m[r]), I)
            results[s] = self._finish(graphs[s], float(outR.best_val[r]),
                                      path, bs[s], B, int(xi[s]), 5, "batched")
        for q, s in enumerate(live):
            if results[s] is None:                  # t_hat == beta*
                results[s] = self._finish(graphs[s], float(outP.best_val[q]),
                                          paths_star[q], bs[s], B,
                                          int(xi[s]), 4, "batched")
        return results


def solve_msp(profile: ModelProfile, net: EdgeNetwork, b: int, B: int,
              K: int | None = None, memory_model: str = "paper",
              restrict_cuts: Sequence[int] | None = None,
              restrict_placement: Sequence[int] | None = None,
              solver: str | None = None,
              planner: Planner | None = None) -> MSPResult:
    """Algorithm 1.  Returns the optimal (x, y) for fixed micro-batch b.

    ``solver``: "batched" (default) or "scan" (the legacy reference — same
    results, more sweeps).  Pass a :class:`Planner` to amortize the graph
    factory and DP buffers across calls (it must have been built for the
    same memory model)."""
    if planner is not None and planner.memory_model != memory_model:
        raise ValueError(
            f"planner was built with memory_model={planner.memory_model!r} "
            f"but solve_msp was called with {memory_model!r}")
    pl = planner if planner is not None else Planner(profile, net, memory_model)
    return pl.solve(b, B, K=K, restrict_cuts=restrict_cuts,
                    restrict_placement=restrict_placement, solver=solver)


def _path_bottleneck(g: MSPGraph, path: list) -> float:
    """Max component (paper-mode T_1) along a reconstructed path."""
    (n0, i0) = path[0]
    beta = float(g.src_beta[i0])
    prev_n, prev_i = n0, i0
    for (n, i) in path[1:]:
        beta = max(beta, g.edge_beta(prev_n, prev_i, n, i))
        prev_n, prev_i = n, i
    return beta


def path_cost(g: MSPGraph, path: list) -> float:
    (n0, i0) = path[0]
    c = float(g.src_cost[i0])
    prev_n, prev_i = n0, i0
    for (n, i) in path[1:]:
        c += g.edge_cost(prev_n, prev_i, n, i)
        prev_n, prev_i = n, i
    return c


# ---------------------------------------------------------------------------
# Brute-force verifiers (tests / Fig. 7 "optimal" baseline on small instances)
# ---------------------------------------------------------------------------

def enumerate_solutions(profile: ModelProfile, net: EdgeNetwork, K: int):
    """Yield every feasible-shaped SplitSolution (cuts + placement)."""
    I = profile.num_layers
    servers = list(net.server_indices())
    for s in range(1, K + 1):                 # number of non-empty segments
        for cuts in itertools.combinations(range(1, I), s - 1):
            cuts = cuts + (I,)
            if s == 1:
                yield SplitSolution(cuts=cuts, placement=(0,))
                continue
            for placing in itertools.product(servers, repeat=s - 1):
                placement = (0,) + placing
                if any(placement[a] == placement[a + 1] for a in range(s - 1)):
                    continue
                yield SplitSolution(cuts=cuts, placement=placement)


def brute_force_msp(profile: ModelProfile, net: EdgeNetwork, b: int, B: int,
                    K: int, objective: str = "paper",
                    memory_model: str = "paper"):
    """Exhaustive MSP search.  ``objective='paper'`` replicates Algorithm 1's
    per-segment semantics (for optimality tests); ``'true'`` evaluates the
    full Eq. (13)/(14) with co-location sums and joint memory (C8)."""
    xi = L.num_fills(B, b)
    g = build_graph(profile, net, b, memory_model) if objective == "paper" else None
    best, best_sol = math.inf, None
    for sol in enumerate_solutions(profile, net, K):
        if objective == "paper":
            path = list(zip(sol.placement, sol.cuts))
            ok = np.isfinite(g.src_cost[path[0][1]])
            prev = path[0]
            cost = float(g.src_cost[path[0][1]])
            beta = float(g.src_beta[path[0][1]])
            for (n, i) in path[1:]:
                c = g.edge_cost(prev[0], prev[1], n, i)
                if not math.isfinite(c):
                    ok = False
                    break
                cost += c
                beta = max(beta, g.edge_beta(prev[0], prev[1], n, i))
                prev = (n, i)
            if not ok:
                continue
            val = cost + xi * beta
        else:
            if not L.memory_feasible(profile, net, sol, b, memory_model):
                continue
            val = L.total_latency(profile, net, sol, b, B)
        if val < best:
            best, best_sol = val, sol
    return best, best_sol
