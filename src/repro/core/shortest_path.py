"""Algorithm 1 — bottleneck-aware shortest path for the MSP problem.

The MSP objective (P4) is  min over paths of  T_f(path) + xi(b) * T_1(path)
with T_1 = the path's bottleneck (max edge beta) — a combined min-sum +
min-max problem (Minoux 1989).  Exact strategy:

  1. collect the sorted distinct bottleneck values  B = {beta(e)}
  2. for each candidate threshold t in B (ascending), restrict the graph to
     edges with beta <= t and run a shortest-path sweep on the layered DAG;
     objective(t) = dist(t) + xi * t
  3. answer = min over t.   dist(t) only changes at values of B, so scanning
     B is exhaustive; two admissible prunings keep the scan short:
       - binary-search the smallest feasible t (feasibility monotone in t)
       - break once  dist(full graph) + xi * t >= best   (the paper's
         lower-bound pruning l_b + xi*w(e) > L_t^*, with l_b the min-sum
         lower bound; ours is the combinatorial bound from the unrestricted
         graph — admissible without an LP solver, see DESIGN.md §6)

The sweep itself is a vectorized DP over the layered DAG (the graph of
msp_graph.py is acyclic in (k, i)), i.e. the role Dijkstra plays in the
paper.  Restrictions (fixed cuts / fixed placement / ordered TPU stages) are
expressed as per-segment masks so the same solver powers the RC+OP / RP+OC
baselines and the TPU stage planner.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Sequence

import numpy as np

from . import latency as L
from .latency import SplitSolution
from .msp_graph import MSPGraph, build_graph
from .network import EdgeNetwork
from .profiles import ModelProfile


@dataclasses.dataclass
class MSPResult:
    solution: SplitSolution
    objective: float        # T_f + xi * T1  as searched (paper objective)
    T_f: float              # min-sum part of the searched objective
    T_1: float              # bottleneck of the chosen path (searched beta)
    L_t: float              # true Eq. (14) latency of the solution
    T_i_true: float         # true Eq. (13) interval (with co-location sums)
    b: int
    B: int
    thresholds_scanned: int = 0
    feasible: bool = True


class _LayeredDP:
    """Vectorized shortest-path sweep over the (k, n, i) layered DAG."""

    def __init__(self, g: MSPGraph, K: int,
                 restrict_cuts: Sequence[int] | None = None,
                 restrict_placement: Sequence[int] | None = None):
        self.g = g
        self.K = K
        self.N, self.I = g.N, g.I
        # Dense edge arrays over (n, i, m, j):
        #   cost[n, i, m, j] = comm_cost[i, n, m] + seg_cost[m, i, j]
        #   beta[n, i, m, j] = max(comm_beta[i, n, m], seg_beta[m, i, j])
        I1 = self.I + 1
        cost = np.empty((self.N, I1, self.N, I1))
        beta = np.empty((self.N, I1, self.N, I1))
        cc, cb = g.comm_cost, g.comm_beta   # (I1, N, N) indexed [i, n, m]
        sc, sb = g.seg_cost, g.seg_beta     # (N, I1, I1) indexed [m, i, j]
        for n in range(self.N):
            for m in range(self.N):
                # cost[n, i, m, j] = cc[i, n, m] + sc[m, i, j]
                cost[n, :, m, :] = cc[:, n, m][:, None] + sc[m, :, :]
                beta[n, :, m, :] = np.maximum(cb[:, n, m][:, None], sb[m, :, :])
        self.cost_e, self.beta_e = cost, beta
        self.restrict_cuts = tuple(restrict_cuts) if restrict_cuts else None
        self.restrict_placement = (tuple(restrict_placement)
                                   if restrict_placement else None)

    # -- masks ---------------------------------------------------------------
    def _src_allowed(self) -> np.ndarray:
        ok = np.isfinite(self.g.src_cost)
        if self.restrict_cuts is not None:
            sel = np.zeros_like(ok)
            sel[self.restrict_cuts[0]] = True
            ok &= sel
        return ok

    def _edge_allowed(self, k: int) -> np.ndarray:
        """Mask over (n, i, m, j) for the transition into segment k (2-based)."""
        ok = np.isfinite(self.cost_e)
        ok[:, :, 0, :] = False                       # servers only for k >= 2
        for n in range(self.N):
            ok[n, :, n, :] = False                   # n' != n (Eq. 21)
        if self.restrict_cuts is not None:
            sel = np.zeros_like(ok)
            prev, cur = self.restrict_cuts[k - 2], self.restrict_cuts[k - 1]
            sel[:, prev, :, cur] = True
            ok &= sel
        if self.restrict_placement is not None:
            sel = np.zeros_like(ok)
            prev_n = self.restrict_placement[k - 2]
            cur_n = self.restrict_placement[k - 1]
            sel[prev_n, :, cur_n, :] = True
            ok &= sel
        return ok

    # -- the sweep -----------------------------------------------------------
    def run(self, t: float):
        """Shortest path with all edge betas <= t. Returns (dist, path)."""
        g = self.g
        INF = np.inf
        src_ok = self._src_allowed() & (g.src_beta <= t)
        dist = np.full((self.N, self.I + 1), INF)
        dist[0, :] = np.where(src_ok, g.src_cost, INF)
        parents = []
        best_val, best_state = INF, None
        if np.isfinite(dist[0, self.I]):             # client-only path
            best_val, best_state = float(dist[0, self.I]), (1, 0, self.I)
        dists = [dist]
        for k in range(2, self.K + 1):
            ok = self._edge_allowed(k) & (self.beta_e <= t)
            cand = np.where(ok, dists[-1][:, :, None, None] + self.cost_e, INF)
            flat = cand.reshape(-1, self.N, self.I + 1)
            nd = flat.min(axis=0)
            parent = flat.argmin(axis=0)             # encodes (n, i)
            parents.append(parent)
            dists.append(nd)
            v = nd[1:, self.I].min() if self.N > 1 else INF
            if v < best_val:
                m = 1 + int(nd[1:, self.I].argmin())
                best_val, best_state = float(v), (k, m, self.I)
            if not np.isfinite(nd).any():
                break
        if best_state is None:
            return math.inf, None
        # reconstruct
        k, n, i = best_state
        path = [(n, i)]
        while k >= 2:
            p = parents[k - 2][n, i]
            pn, pi = divmod(int(p), self.I + 1)
            path.append((pn, pi))
            n, i, k = pn, pi, k - 1
        path.reverse()
        return best_val, path

    def all_betas(self) -> np.ndarray:
        vals = [self.g.src_beta[np.isfinite(self.g.src_beta)]]
        ok = self._edge_allowed(2)  # structural mask (k-independent when free)
        if self.restrict_cuts is None and self.restrict_placement is None:
            vals.append(self.beta_e[ok & np.isfinite(self.beta_e)])
        else:
            for k in range(2, self.K + 1):
                okk = self._edge_allowed(k)
                vals.append(self.beta_e[okk & np.isfinite(self.beta_e)])
        v = np.concatenate([np.atleast_1d(x) for x in vals])
        return np.unique(np.round(v, 12))


def solve_msp(profile: ModelProfile, net: EdgeNetwork, b: int, B: int,
              K: int | None = None, memory_model: str = "paper",
              restrict_cuts: Sequence[int] | None = None,
              restrict_placement: Sequence[int] | None = None) -> MSPResult:
    """Algorithm 1.  Returns the optimal (x, y) for fixed micro-batch b."""
    if K is None:
        K = min(1 + net.num_servers, profile.num_layers)
    g = build_graph(profile, net, b, memory_model)
    dp = _LayeredDP(g, K, restrict_cuts, restrict_placement)
    xi = L.num_fills(B, b)

    def finish(dist, path, t_scanned):
        if path is None:
            return MSPResult(solution=SplitSolution((profile.num_layers,), (0,)),
                             objective=math.inf, T_f=math.inf, T_1=math.inf,
                             L_t=math.inf, T_i_true=math.inf, b=b, B=B,
                             thresholds_scanned=t_scanned, feasible=False)
        sol = SplitSolution(cuts=tuple(i for _, i in path),
                            placement=tuple(n for n, _ in path))
        T_f = L.fill_latency(profile, net, sol, b)
        T_i = L.pipeline_interval(profile, net, sol, b)
        beta_path = _path_bottleneck(g, path)
        return MSPResult(solution=sol, objective=dist + xi * beta_path,
                         T_f=T_f, T_1=beta_path, L_t=T_f + xi * T_i,
                         T_i_true=T_i, b=b, B=B, thresholds_scanned=t_scanned)

    if xi == 0:                                # no pipelining: pure min-sum
        dist, path = dp.run(math.inf)
        return finish(dist, path, 1)

    betas = dp.all_betas()
    if betas.size == 0:
        return finish(math.inf, None, 0)
    dist_full, path_full = dp.run(math.inf)
    if path_full is None:
        return finish(math.inf, None, 1)

    # binary search the smallest feasible threshold (feasibility monotone in t)
    lo, hi = 0, len(betas) - 1
    while lo < hi:
        mid = (lo + hi) // 2
        d, _ = dp.run(betas[mid])
        if math.isfinite(d):
            hi = mid
        else:
            lo = mid + 1

    best, best_pair = math.inf, None
    scanned = 0
    for idx in range(lo, len(betas)):
        t = float(betas[idx])
        if dist_full + xi * t >= best:        # admissible prune -> break
            break
        d, p = dp.run(t)
        scanned += 1
        if p is None:
            continue
        beta_p = _path_bottleneck(g, p)       # actual path bottleneck <= t
        obj = d + xi * beta_p
        if obj < best:
            best, best_pair = obj, (d, p)
    if best_pair is None:
        return finish(math.inf, None, scanned)
    return finish(best_pair[0], best_pair[1], scanned)


def _path_bottleneck(g: MSPGraph, path: list) -> float:
    """Max component (paper-mode T_1) along a reconstructed path."""
    (n0, i0) = path[0]
    beta = float(g.src_beta[i0])
    prev_n, prev_i = n0, i0
    for (n, i) in path[1:]:
        beta = max(beta, g.edge_beta(prev_n, prev_i, n, i))
        prev_n, prev_i = n, i
    return beta


def path_cost(g: MSPGraph, path: list) -> float:
    (n0, i0) = path[0]
    c = float(g.src_cost[i0])
    prev_n, prev_i = n0, i0
    for (n, i) in path[1:]:
        c += g.edge_cost(prev_n, prev_i, n, i)
        prev_n, prev_i = n, i
    return c


# ---------------------------------------------------------------------------
# Brute-force verifiers (tests / Fig. 7 "optimal" baseline on small instances)
# ---------------------------------------------------------------------------

def enumerate_solutions(profile: ModelProfile, net: EdgeNetwork, K: int):
    """Yield every feasible-shaped SplitSolution (cuts + placement)."""
    I = profile.num_layers
    servers = list(net.server_indices())
    for s in range(1, K + 1):                 # number of non-empty segments
        for cuts in itertools.combinations(range(1, I), s - 1):
            cuts = cuts + (I,)
            if s == 1:
                yield SplitSolution(cuts=cuts, placement=(0,))
                continue
            for placing in itertools.product(servers, repeat=s - 1):
                placement = (0,) + placing
                if any(placement[a] == placement[a + 1] for a in range(s - 1)):
                    continue
                yield SplitSolution(cuts=cuts, placement=placement)


def brute_force_msp(profile: ModelProfile, net: EdgeNetwork, b: int, B: int,
                    K: int, objective: str = "paper",
                    memory_model: str = "paper"):
    """Exhaustive MSP search.  ``objective='paper'`` replicates Algorithm 1's
    per-segment semantics (for optimality tests); ``'true'`` evaluates the
    full Eq. (13)/(14) with co-location sums and joint memory (C8)."""
    xi = L.num_fills(B, b)
    g = build_graph(profile, net, b, memory_model) if objective == "paper" else None
    best, best_sol = math.inf, None
    for sol in enumerate_solutions(profile, net, K):
        if objective == "paper":
            path = list(zip(sol.placement, sol.cuts))
            ok = np.isfinite(g.src_cost[path[0][1]])
            prev = path[0]
            cost = float(g.src_cost[path[0][1]])
            beta = float(g.src_beta[path[0][1]])
            for (n, i) in path[1:]:
                c = g.edge_cost(prev[0], prev[1], n, i)
                if not math.isfinite(c):
                    ok = False
                    break
                cost += c
                beta = max(beta, g.edge_beta(prev[0], prev[1], n, i))
                prev = (n, i)
            if not ok:
                continue
            val = cost + xi * beta
        else:
            if not L.memory_feasible(profile, net, sol, b, memory_model):
                continue
            val = L.total_latency(profile, net, sol, b, B)
        if val < best:
            best, best_sol = val, sol
    return best, best_sol
