"""Edge-network model: heterogeneous nodes + wireless/wired links (Sec. III).

Nodes carry ``(f_n, kappa_n, M_n, p_n, t0, t1, b_th)``; links carry
``(W_nn', d_nn')`` and yield the Shannon rate of Eq. (4):

    r_nn' = W_nn' * log2(1 + p_n * d_nn'^{-gamma} / N0)

with ``N0 = n0_density * W_nn'`` (noise power over the link bandwidth).

Topologies: ``mesh`` (full), ``line``, ``star``, ``tree`` (binary), and
``random_geometric``.  When two nodes are not directly connected, traffic is
*forwarded* along the topology's shortest path; the effective per-byte time is
the sum of per-hop times, i.e. effective rate = 1 / sum_hops(1/r_hop).  This
matches the paper's observation that star/tree topologies pay a forwarding
overhead at the hub (Fig. 8).

The same abstraction doubles as the TPU "network": ``tpu_stage_network``
builds a line of homogeneous stage groups whose link rate is the ICI
bandwidth — a link is just a bytes/s provider, so the planner is agnostic.
"""

from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Sequence

import numpy as np

# TPU v5e-class hardware constants used across the repo (see system prompt).
TPU_PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
TPU_HBM_BW = 819e9               # bytes/s per chip
TPU_ICI_BW = 50e9                # bytes/s per link
TPU_HBM_BYTES = 16 * 2**30       # 16 GiB HBM per chip


@dataclasses.dataclass(frozen=True)
class Node:
    """One compute node (client or edge server). Units per Table I/II."""
    name: str
    f: float                 # computing capability (FLOP/s)
    kappa: float = 1.0       # computing intensity (FLOPs per workload unit)
    mem: float = 8 * 2**30   # M_n: max accelerator memory (bytes)
    p: float = 0.3           # transmit power (W)
    t0: float = 1e-3         # FP init/model-load coefficient (t0^c / t0^s)
    t1: float = 1e-3         # BP constant-latency coefficient (t1^c / t1^s)
    b_th: int = 32           # BP latency threshold (b_th^c / b_th^s)
    is_client: bool = False


@dataclasses.dataclass
class EdgeNetwork:
    """N servers + one virtual client tier, with an effective rate matrix.

    ``nodes[0]`` is always the *virtual client node* (the M clients grouped
    as in Eq. (20) — "all clients grouped into one virtual node for k=1").
    ``rate[n, n']`` is the effective bytes/s between nodes, after multi-hop
    forwarding over the physical topology.
    """
    nodes: list
    rate: np.ndarray          # (|N|, |N|) effective bytes/s
    num_clients: int = 1      # M
    topology: str = "mesh"

    def __post_init__(self):
        n = len(self.nodes)
        if self.rate.shape != (n, n):
            raise ValueError("rate matrix shape mismatch")

    # -- index helpers ------------------------------------------------------
    @property
    def client(self) -> Node:
        return self.nodes[0]

    @property
    def servers(self) -> list:
        return self.nodes[1:]

    @property
    def num_servers(self) -> int:
        return len(self.nodes) - 1

    def server_indices(self) -> range:
        return range(1, len(self.nodes))

    def degraded(self, failed: Sequence[int]) -> "EdgeNetwork":
        """Return a copy with the given *server* indices removed (node loss)."""
        failed = set(failed)
        if 0 in failed:
            raise ValueError("cannot fail the client tier")
        keep = [i for i in range(len(self.nodes)) if i not in failed]
        return EdgeNetwork(
            nodes=[self.nodes[i] for i in keep],
            rate=self.rate[np.ix_(keep, keep)].copy(),
            num_clients=self.num_clients,
            topology=self.topology,
        )

    def with_fluctuation(self, rng: np.random.Generator, cv: float) -> "EdgeNetwork":
        """Gaussian multiplicative noise with coefficient-of-variation ``cv``
        on rates and compute capabilities (Fig. 6's fluctuation model)."""
        if cv <= 0:
            return self
        noise = np.maximum(rng.normal(1.0, cv, self.rate.shape), 0.05)
        rate = self.rate * noise
        nodes = [dataclasses.replace(
            n, f=n.f * max(float(rng.normal(1.0, cv)), 0.05)) for n in self.nodes]
        return EdgeNetwork(nodes=nodes, rate=rate,
                           num_clients=self.num_clients, topology=self.topology)


# ---------------------------------------------------------------------------
# Link-rate model (Eq. 4) + topology adjacency + multi-hop effective rates
# ---------------------------------------------------------------------------

def shannon_rate(bandwidth_hz: float, power_w: float, distance_m: float,
                 gamma: float = 3.5, n0_dbm_hz: float = -174.0) -> float:
    """Eq. (4): achievable rate in *bytes/s* over a wireless link."""
    n0 = 10 ** (n0_dbm_hz / 10.0) * 1e-3 * bandwidth_hz  # noise power (W)
    snr = power_w * distance_m ** (-gamma) / n0
    bits = bandwidth_hz * math.log2(1.0 + snr)
    return bits / 8.0


def _adjacency(topology: str, n: int, rng: np.random.Generator) -> np.ndarray:
    """Boolean adjacency among n physical nodes (node 0 = client tier)."""
    adj = np.zeros((n, n), dtype=bool)
    if topology == "mesh":
        adj[:] = True
    elif topology == "line":
        for i in range(n - 1):
            adj[i, i + 1] = adj[i + 1, i] = True
    elif topology == "star":
        hub = 1 if n > 1 else 0        # first server is the hub
        adj[hub, :] = adj[:, hub] = True
    elif topology == "tree":           # binary tree rooted at the client
        for i in range(1, n):
            parent = (i - 1) // 2
            adj[i, parent] = adj[parent, i] = True
    elif topology == "random_geometric":
        pos = rng.uniform(0, 500.0, (n, 2))
        d = np.linalg.norm(pos[:, None] - pos[None, :], axis=-1)
        adj = d < 300.0
        for i in range(n - 1):         # ensure connectivity
            adj[i, i + 1] = adj[i + 1, i] = True
    else:
        raise ValueError(f"unknown topology {topology!r}")
    np.fill_diagonal(adj, False)
    return adj


def _effective_rates(link_rate: np.ndarray, adj: np.ndarray) -> np.ndarray:
    """Per-pair effective bytes/s with store-and-forward over shortest
    per-byte-time paths (Dijkstra on cost = 1/r per hop)."""
    n = link_rate.shape[0]
    inv = np.where(adj & (link_rate > 0), 1.0 / np.maximum(link_rate, 1e-30), np.inf)
    eff = np.zeros((n, n))
    for s in range(n):
        dist = np.full(n, np.inf)
        dist[s] = 0.0
        pq = [(0.0, s)]
        while pq:
            d, u = heapq.heappop(pq)
            if d > dist[u]:
                continue
            for v in range(n):
                nd = d + inv[u, v]
                if nd < dist[v]:
                    dist[v] = nd
                    heapq.heappush(pq, (nd, v))
        with np.errstate(divide="ignore"):
            eff[s] = np.where(dist > 0, 1.0 / dist, 0.0)
    np.fill_diagonal(eff, 0.0)
    return eff


def make_edge_network(
    num_servers: int = 6,
    num_clients: int = 4,
    topology: str = "mesh",
    *,
    seed: int = 0,
    f_range: tuple = (1e12, 10e12),          # 1-10 TFLOPS (Table II)
    bw_range_hz: tuple = (10e6, 50e6),       # sub-6GHz low-speed case
    mem_range: tuple = (2 * 2**30, 16 * 2**30),
    power_range_w: tuple = (0.1, 0.5),
    area_m: float = 500.0,
    gamma: float = 3.5,
    kappa: float = 1.0,
    client_f: float = 13.5e9,                # Raspberry-Pi-class client tier
    client_mem: float = 4 * 2**30,
    t0: float = 1e-3, t1: float = 1e-3, b_th: int = 32,
) -> EdgeNetwork:
    """Sample a paper-style edge network (Sec. VI simulation setup)."""
    rng = np.random.default_rng(seed)
    n = num_servers + 1  # + virtual client node
    nodes = [Node(name="clients", f=client_f, kappa=kappa, mem=client_mem,
                  p=float(rng.uniform(*power_range_w)), t0=t0, t1=t1,
                  b_th=b_th, is_client=True)]
    for s in range(num_servers):
        nodes.append(Node(
            name=f"server{s}", f=float(rng.uniform(*f_range)), kappa=kappa,
            mem=float(rng.uniform(*mem_range)),
            p=float(rng.uniform(*power_range_w)), t0=t0, t1=t1, b_th=b_th))
    pos = rng.uniform(0, area_m, (n, 2))
    dist = np.maximum(np.linalg.norm(pos[:, None] - pos[None, :], axis=-1), 1.0)
    link = np.zeros((n, n))
    for i in range(n):
        for j in range(n):
            if i == j:
                continue
            w = float(rng.uniform(*bw_range_hz))
            link[i, j] = shannon_rate(w, nodes[i].p, dist[i, j], gamma)
    adj = _adjacency(topology, n, rng)
    rate = _effective_rates(link, adj)
    return EdgeNetwork(nodes=nodes, rate=rate, num_clients=num_clients,
                       topology=topology)


def tpu_stage_network(num_stages: int, chips_per_stage: int,
                      *, peak_flops: float = TPU_PEAK_FLOPS,
                      hbm_bytes: float = TPU_HBM_BYTES,
                      ici_bw: float = TPU_ICI_BW,
                      links_per_hop: int = 1) -> EdgeNetwork:
    """The TPU mapping of the paper's network (DESIGN.md hardware adaptation).

    A line of ``num_stages`` homogeneous stage groups; stage group aggregates
    ``chips_per_stage`` chips (data-parallel within the group, so per-sample
    throughput scales with the group).  Node 0 doubles as the "client tier" =
    stage 0 (embedding holder); there is no wireless channel — link rate is
    the ICI bandwidth times the number of parallel links between groups.
    """
    nodes = [Node(name="stage0", f=peak_flops * chips_per_stage, kappa=1.0,
                  mem=hbm_bytes * chips_per_stage, t0=0.0, t1=0.0,
                  b_th=0, is_client=True)]
    for s in range(1, num_stages):
        nodes.append(Node(name=f"stage{s}", f=peak_flops * chips_per_stage,
                          kappa=1.0, mem=hbm_bytes * chips_per_stage,
                          t0=0.0, t1=0.0, b_th=0))
    link = np.zeros((num_stages, num_stages))
    for i in range(num_stages - 1):
        link[i, i + 1] = link[i + 1, i] = ici_bw * links_per_hop
    adj = _adjacency("line", num_stages, np.random.default_rng(0))
    rate = _effective_rates(link, adj)
    return EdgeNetwork(nodes=nodes, rate=rate, num_clients=1, topology="line")
