"""Per-layer workload profiles — the substrate of every latency equation.

The paper (Table I) parameterizes a model as, per layer index ``i`` in ``[1, I]``:

  w_i     cumulative FP workload per data sample of the *first i layers*
  rho_i   cumulative BP workload per data sample of the first i layers
  phi_i   activation bytes produced by layer i (per sample)            [Eq. 5]
  phiG_i  activation-gradient bytes flowing back across layer i        [Eq. 9]
  beta_i  parameter bytes of the first i layers (cumulative)           [Eq. 11]
  sigma_i optimizer-state bytes of the first i layers (cumulative)     [Eq. 11]
  phiT_i  cumulative activation bytes of the first i layers            [Eq. 11]
  phiGT_i cumulative activation-gradient bytes of the first i layers   [Eq. 11]

We store *per-layer* (non-cumulative) quantities and expose cumulative views so
that the "cumulative-difference" trick of Eqs. (3)/(8)/(11) is exact:

  delta^F_k = w[cut_k] - w[cut_{k-1}]   (workload of submodel k, per sample)

Units are deliberately abstract "workload units": in the paper's edge
simulator, ``w_i`` is in bytes and the node computes
``t = b * kappa_n * delta / f_n`` with ``kappa_n`` in FLOPs/byte (Table II
uses kappa = 1/32).  In the TPU planner, ``w_i`` is directly in FLOPs and
``kappa = 1``.  Both flow through the same equations (Eqs. 2, 7).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class ModelProfile:
    """Per-layer workload profile of an ``I``-layer neural network.

    All arrays have length ``I`` and hold *per-layer* (not cumulative)
    quantities, per single data sample (micro-batch multiplies in later).
    """

    name: str
    fp_work: np.ndarray      # FP workload of layer i (workload units / sample)
    bp_work: np.ndarray      # BP workload of layer i
    act_bytes: np.ndarray    # phi_i: bytes of activations emitted by layer i
    grad_bytes: np.ndarray   # phi'_{i+1}: bytes of act-grads crossing cut at i
    param_bytes: np.ndarray  # beta contribution of layer i
    opt_bytes: np.ndarray    # sigma contribution of layer i (optimizer state)

    def __post_init__(self):
        arrays = (self.fp_work, self.bp_work, self.act_bytes, self.grad_bytes,
                  self.param_bytes, self.opt_bytes)
        n = len(self.fp_work)
        for a in arrays:
            if len(a) != n:
                raise ValueError(f"profile arrays must share length, got {n} vs {len(a)}")
            if np.any(np.asarray(a) < 0):
                raise ValueError("profile quantities must be non-negative")

    # ---- sizes -------------------------------------------------------------
    @property
    def num_layers(self) -> int:
        return len(self.fp_work)

    # ---- cumulative views (paper's w_i, rho_i, beta_i, sigma~_i, phi~_i) ----
    # Lazily cached on the (frozen) instance: the planner's inner loops ask
    # for the same cumulative arrays thousands of times per solve, and the
    # cumsum was the hot path.  ``dataclasses.replace`` builds a fresh
    # instance, so derived profiles never see a stale cache.
    def _cum(self, key: str, source) -> np.ndarray:
        got = self.__dict__.get(key)
        if got is None:
            got = np.cumsum(source)
            object.__setattr__(self, key, got)
        return got

    def w_cum(self) -> np.ndarray:
        return self._cum("_w_cum", self.fp_work)

    def rho_cum(self) -> np.ndarray:
        return self._cum("_rho_cum", self.bp_work)

    def act_cum(self) -> np.ndarray:        # phi~_i
        return self._cum("_act_cum", self.act_bytes)

    def grad_cum(self) -> np.ndarray:       # phi'~_i
        return self._cum("_grad_cum", self.grad_bytes)

    def param_cum(self) -> np.ndarray:      # beta_i
        return self._cum("_param_cum", self.param_bytes)

    def opt_cum(self) -> np.ndarray:        # sigma~_i
        return self._cum("_opt_cum", self.opt_bytes)

    # ---- submodel (segment) quantities --------------------------------------
    def seg_fp(self, lo: int, hi: int) -> float:
        """FP workload per sample of layers (lo, hi] — delta^F of Eq. (3).

        ``lo``/``hi`` are 0-based cut positions: segment covers layers
        lo+1 .. hi in 1-based paper indexing (lo == 0 means 'from layer 1').
        """
        w = self.w_cum()
        return float(w[hi - 1] - (w[lo - 1] if lo > 0 else 0.0))

    def seg_bp(self, lo: int, hi: int) -> float:
        r = self.rho_cum()
        return float(r[hi - 1] - (r[lo - 1] if lo > 0 else 0.0))

    def seg_mem_per_sample(self, lo: int, hi: int) -> float:
        """Eq. (11) inner sum over the segment: phi~ + phi'~ + sigma~ + beta."""
        tot = self.__dict__.get("_mem_cum")
        if tot is None:
            tot = (self.act_cum() + self.grad_cum() + self.opt_cum()
                   + self.param_cum())
            object.__setattr__(self, "_mem_cum", tot)
        return float(tot[hi - 1] - (tot[lo - 1] if lo > 0 else 0.0))

    def cut_act_bytes(self, cut: int) -> float:
        """phi at cut layer ``cut`` (1-based): bytes per sample sent forward."""
        return float(self.act_bytes[cut - 1])

    def cut_grad_bytes(self, cut: int) -> float:
        """phi'_(cut+1): bytes per sample of act-grads sent backward at cut."""
        return float(self.grad_bytes[cut - 1])

    def scaled(self, factor: float) -> "ModelProfile":
        """Uniformly scale compute workload (e.g. unit conversion)."""
        return dataclasses.replace(
            self,
            fp_work=self.fp_work * factor,
            bp_work=self.bp_work * factor,
        )


# ---------------------------------------------------------------------------
# VGG-16 profile (the paper's own workload: Figs. 1, 4-8, Table II I = 16)
# ---------------------------------------------------------------------------

# (kind, out_channels, spatial_out) for CIFAR-10 32x32 inputs.
_VGG16_LAYERS: Sequence[tuple] = (
    ("conv", 64, 32), ("conv", 64, 32),     # block 1 (pool folded into next)
    ("conv", 128, 16), ("conv", 128, 16),   # block 2
    ("conv", 256, 8), ("conv", 256, 8), ("conv", 256, 8),    # block 3
    ("conv", 512, 4), ("conv", 512, 4), ("conv", 512, 4),    # block 4
    ("conv", 512, 2), ("conv", 512, 2), ("conv", 512, 2),    # block 5
    ("fc", 4096, 1), ("fc", 4096, 1), ("fc", 10, 1),         # classifier
)


def vgg16_profile(dtype_bytes: int = 4, optimizer_mult: float = 1.0,
                  work_units: str = "flops") -> ModelProfile:
    """Analytical VGG-16 profile on 32x32 inputs (I = 16 layers, as Table II).

    ``work_units``: "flops" keeps w_i in FLOPs (use kappa = 1);
    "bytes" divides by 32 so the paper's kappa = 1/32 FLOPs/byte recovers
    FLOPs in Eq. (2).
    """
    fp, bp, act, grad, par, opt = [], [], [], [], [], []
    in_c, in_hw = 3, 32
    for kind, out_c, out_hw in _VGG16_LAYERS:
        if kind == "conv":
            # 3x3 conv: 2 * k^2 * Cin * Cout * H * W FLOPs (MACs*2)
            flops = 2.0 * 9 * in_c * out_c * out_hw * out_hw
            params = (9 * in_c * out_c + out_c) * dtype_bytes
            a_bytes = out_c * out_hw * out_hw * dtype_bytes
        else:
            fan_in = in_c * in_hw * in_hw
            flops = 2.0 * fan_in * out_c
            params = (fan_in * out_c + out_c) * dtype_bytes
            a_bytes = out_c * dtype_bytes
        fp.append(flops)
        bp.append(2.0 * flops)          # standard 2x FP cost for BP
        act.append(a_bytes)
        grad.append(a_bytes)            # grads mirror activations
        par.append(params)
        opt.append(params * optimizer_mult)
        in_c, in_hw = out_c, out_hw
    prof = ModelProfile(
        name="vgg16",
        fp_work=np.array(fp), bp_work=np.array(bp),
        act_bytes=np.array(act), grad_bytes=np.array(grad),
        param_bytes=np.array(par), opt_bytes=np.array(opt),
    )
    if work_units == "bytes":
        prof = prof.scaled(32.0)  # w in "bytes" such that kappa=1/32 -> FLOPs
    return prof


# ---------------------------------------------------------------------------
# Transformer-family profiles (for the TPU planner over the assigned archs)
# ---------------------------------------------------------------------------

def transformer_layer_flops(d_model: int, n_heads: int, n_kv: int, d_ff: int,
                            seq_len: int, d_head: int | None = None,
                            moe_experts: int = 0, moe_top_k: int = 0,
                            ffn_mult: int = 3) -> float:
    """Per-token FP FLOPs of one transformer layer (matmul-dominant terms).

    ``ffn_mult``: 3 for SwiGLU (gate/up/down), 2 for plain 2-matmul MLP.
    MoE: only ``top_k`` experts are active per token (6*N_active convention).
    """
    d_head = d_head or d_model // n_heads
    qkv = 2 * d_model * (n_heads + 2 * n_kv) * d_head
    attn_out = 2 * n_heads * d_head * d_model
    scores = 2 * 2 * n_heads * d_head * seq_len  # QK^T + AV, per token avg len
    if moe_experts > 0:
        ffn = moe_top_k * ffn_mult * 2 * d_model * d_ff
        router = 2 * d_model * moe_experts
        ffn += router
    else:
        ffn = ffn_mult * 2 * d_model * d_ff
    return float(qkv + attn_out + scores + ffn)


def transformer_profile(name: str, num_layers: int, d_model: int, n_heads: int,
                        n_kv: int, d_ff: int, vocab: int, seq_len: int,
                        dtype_bytes: int = 2, d_head: int | None = None,
                        moe_experts: int = 0, moe_top_k: int = 0,
                        optimizer_mult: float = 2.0, ffn_mult: int = 3,
                        param_dtype_bytes: int = 4) -> ModelProfile:
    """Profile of a decoder-only transformer as a chain of I = L + 2 'layers':

      layer 1      = embedding (lookup; negligible FLOPs, big params)
      layers 2..L+1 = transformer blocks
      layer L+2    = final norm + LM head (2 * d * V FLOPs/token)

    Per-sample quantities are per *sequence* (seq_len tokens), matching the
    paper's per-data-sample accounting.
    """
    d_head = d_head or d_model // n_heads
    blk_flops = transformer_layer_flops(
        d_model, n_heads, n_kv, d_ff, seq_len, d_head, moe_experts, moe_top_k,
        ffn_mult) * seq_len
    if moe_experts > 0:
        blk_params = ((n_heads + 2 * n_kv) * d_head * d_model +
                      n_heads * d_head * d_model +
                      moe_experts * ffn_mult * d_model * d_ff +
                      d_model * moe_experts) * param_dtype_bytes
    else:
        blk_params = ((n_heads + 2 * n_kv) * d_head * d_model +
                      n_heads * d_head * d_model +
                      ffn_mult * d_model * d_ff) * param_dtype_bytes
    act = d_model * seq_len * dtype_bytes  # boundary activation: (seq, d)

    fp = [1e6] + [blk_flops] * num_layers + [2.0 * d_model * vocab * seq_len]
    bp = [2e6] + [2.0 * blk_flops] * num_layers + [4.0 * d_model * vocab * seq_len]
    acts = [act] * (num_layers + 1) + [vocab * seq_len * dtype_bytes]
    grads = list(acts)
    params = ([vocab * d_model * param_dtype_bytes] +
              [blk_params] * num_layers +
              [vocab * d_model * param_dtype_bytes])
    opt = [p * optimizer_mult for p in params]
    return ModelProfile(
        name=name,
        fp_work=np.array(fp), bp_work=np.array(bp),
        act_bytes=np.array(acts), grad_bytes=np.array(grads),
        param_bytes=np.array(params, dtype=float), opt_bytes=np.array(opt, dtype=float),
    )


def uniform_profile(num_layers: int, fp: float = 1.0, bp: float = 2.0,
                    act: float = 1.0, param: float = 1.0,
                    name: str = "uniform") -> ModelProfile:
    """Degenerate equal-layer profile — handy for tests and analysis."""
    ones = np.ones(num_layers)
    return ModelProfile(
        name=name, fp_work=ones * fp, bp_work=ones * bp,
        act_bytes=ones * act, grad_bytes=ones * act,
        param_bytes=ones * param, opt_bytes=ones * param,
    )


def random_profile(rng: np.random.Generator, num_layers: int,
                   name: str = "random") -> ModelProfile:
    """Random positive profile for property-based tests."""
    def draw(scale):
        return rng.uniform(0.1, 1.0, num_layers) * scale
    return ModelProfile(
        name=name,
        fp_work=draw(1e9), bp_work=draw(2e9),
        act_bytes=draw(1e6), grad_bytes=draw(1e6),
        param_bytes=draw(1e7), opt_bytes=draw(1e7),
    )


def flops_summary(profile: ModelProfile) -> dict:
    return {
        "layers": profile.num_layers,
        "fp_total": float(profile.w_cum()[-1]),
        "bp_total": float(profile.rho_cum()[-1]),
        "param_bytes": float(profile.param_cum()[-1]),
    }
