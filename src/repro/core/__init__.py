"""The paper's contribution: pipelined split learning via joint model
splitting & placement (Algorithm 1), closed-form micro-batching (Theorem 1),
and their BCD combination (Algorithm 2) — plus the TPU stage-planner facade.
"""

from .profiles import (ModelProfile, vgg16_profile, transformer_profile,
                       uniform_profile, random_profile)
from .network import (Node, EdgeNetwork, make_edge_network, shannon_rate,
                      tpu_stage_network, TPU_PEAK_FLOPS, TPU_HBM_BW,
                      TPU_ICI_BW, TPU_HBM_BYTES)
from .latency import (SplitSolution, validate_solution, fill_latency,
                      pipeline_interval, total_latency, no_pipeline_latency,
                      memory_feasible, node_memory_usage, num_fills,
                      breakdown, client_shares)
from .msp_graph import GraphFactory, MSPGraph, build_graph, graph_stats
from .shortest_path import (DEFAULT_SOLVER, MSPResult, Planner, solve_msp,
                            brute_force_msp, enumerate_solutions)
from .cost_model import (CostModel, ClosedForm, SimMakespan, StageClaim,
                         DegradedTail, stage_memory_claims,
                         node_budget_windows, node_budget_windows_many,
                         budget_feasible, resolve_cost_model,
                         memoized_cost_model)
from .microbatch import (MicrobatchResult, optimal_microbatch,
                         exhaustive_microbatch, feasibility_box)
from .bcd import Plan, bcd_solve, exhaustive_joint
from .baselines import (rc_op, rp_oc, no_pipeline, ours, sim_refined,
                        optimal, SCHEMES)
from .fluctuation import FluctuationReport, evaluate_under_fluctuation
from .planner import StagePlan, plan_stages, replan

__all__ = [
    "ModelProfile", "vgg16_profile", "transformer_profile", "uniform_profile",
    "random_profile", "Node", "EdgeNetwork", "make_edge_network",
    "shannon_rate", "tpu_stage_network", "TPU_PEAK_FLOPS", "TPU_HBM_BW",
    "TPU_ICI_BW", "TPU_HBM_BYTES", "SplitSolution", "validate_solution",
    "fill_latency", "pipeline_interval", "total_latency",
    "no_pipeline_latency", "memory_feasible", "node_memory_usage",
    "num_fills", "breakdown", "client_shares", "MSPGraph", "GraphFactory",
    "build_graph", "graph_stats", "MSPResult", "Planner", "DEFAULT_SOLVER",
    "solve_msp", "brute_force_msp",
    "enumerate_solutions", "CostModel", "ClosedForm", "SimMakespan",
    "StageClaim", "DegradedTail", "stage_memory_claims",
    "node_budget_windows",
    "node_budget_windows_many", "budget_feasible", "resolve_cost_model",
    "memoized_cost_model", "MicrobatchResult",
    "optimal_microbatch",
    "exhaustive_microbatch", "feasibility_box", "Plan", "bcd_solve",
    "exhaustive_joint", "rc_op", "rp_oc", "no_pipeline", "ours",
    "sim_refined", "optimal",
    "SCHEMES", "FluctuationReport", "evaluate_under_fluctuation",
    "StagePlan", "plan_stages", "replan",
]
