"""Resource-fluctuation robustness (Fig. 6).

Edge resources fluctuate during training; the plan is computed on *measured*
conditions but executes under *actual* conditions.  We model actuals as the
measured network perturbed by Gaussian multiplicative noise with a given
coefficient of variation (CV) on both data rates and compute capabilities,
then evaluate the fixed plan's true latency under each draw.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from . import latency as L
from .bcd import Plan
from .network import EdgeNetwork
from .profiles import ModelProfile


@dataclasses.dataclass
class FluctuationReport:
    cv: float
    mean_latency: float
    std_latency: float
    p95_latency: float
    planned_latency: float
    degradation: float       # mean / planned

    def row(self):
        return (self.cv, self.mean_latency, self.std_latency,
                self.p95_latency, self.planned_latency, self.degradation)


def evaluate_under_fluctuation(profile: ModelProfile, net: EdgeNetwork,
                               plan: Plan, cv: float, *, draws: int = 32,
                               seed: int = 0) -> FluctuationReport:
    rng = np.random.default_rng(seed)
    lats = []
    for _ in range(draws):
        noisy = net.with_fluctuation(rng, cv)
        lats.append(L.total_latency(profile, noisy, plan.solution, plan.b,
                                    plan.B))
    lats = np.asarray(lats)
    return FluctuationReport(
        cv=cv, mean_latency=float(lats.mean()), std_latency=float(lats.std()),
        p95_latency=float(np.percentile(lats, 95)),
        planned_latency=plan.L_t,
        degradation=float(lats.mean() / plan.L_t) if plan.L_t > 0 else 1.0)
