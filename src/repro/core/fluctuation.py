"""Resource-fluctuation robustness (Fig. 6).

Edge resources fluctuate during training; the plan is computed on *measured*
conditions but executes under *actual* conditions.  Two evaluation modes:

``mode="iid"`` (default, the original Fig. 6 model): each draw perturbs the
whole network once by Gaussian multiplicative noise with a given coefficient
of variation (CV) and evaluates the fixed plan's *analytical* latency.

``mode="trace"``: each draw builds a time-varying capacity scenario
(piecewise-constant i.i.d. resampling or Gauss-Markov drift, per
``trace_model``) and *executes* the plan in the discrete-event simulator
(``repro.sim``), so conditions drift during the pipeline and early
micro-batches can see different capacity than late ones.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from . import latency as L
from .bcd import Plan
from .network import EdgeNetwork
from .profiles import ModelProfile


@dataclasses.dataclass
class FluctuationReport:
    cv: float
    mean_latency: float
    std_latency: float
    p95_latency: float
    planned_latency: float
    degradation: float       # mean / planned

    def row(self):
        return (self.cv, self.mean_latency, self.std_latency,
                self.p95_latency, self.planned_latency, self.degradation)


def evaluate_under_fluctuation(profile: ModelProfile, net: EdgeNetwork,
                               plan: Plan, cv: float, *, draws: int = 32,
                               seed: int = 0, mode: str = "iid",
                               trace_model: str = "piecewise",
                               dt: float | None = None,
                               horizon: float | None = None,
                               corr: float = 0.9) -> FluctuationReport:
    rng = np.random.default_rng(seed)
    lats = []
    baseline = plan.L_t
    if mode == "iid":
        for _ in range(draws):
            noisy = net.with_fluctuation(rng, cv)
            lats.append(L.total_latency(profile, noisy, plan.solution,
                                        plan.b, plan.B))
    elif mode == "trace":
        # local import: sim depends on core, so core must not import sim
        # at module scope
        from repro.sim import (simulate_plan, piecewise_cv_scenario,
                               gauss_markov_scenario)
        planned = plan.L_t if np.isfinite(plan.L_t) and plan.L_t > 0 else 1.0
        if dt is None:
            dt = max(planned / 32.0, 1e-9)         # ~32 epochs per run
        if horizon is None:
            horizon = 4.0 * planned                # slack for degraded runs
        if dt <= 0 or horizon <= 0:
            raise ValueError("dt and horizon must be positive")
        # degradation baseline: the *simulated* deterministic run, so plans
        # with co-located submodels (where FIFO execution deviates from the
        # idealized Eq. 14) don't report spurious degradation at cv = 0.
        # engine="auto": since the trace-aware vectorized engine (ISSUE 5),
        # every draw leaves the heap — the segmented scans make the whole
        # Fig. 6b sweep batched.
        baseline = simulate_plan(profile, net, plan.solution, plan.b,
                                 B=plan.B, engine="auto").L_t
        for d in range(draws):
            r = np.random.default_rng((seed, d))
            if trace_model == "piecewise":
                scen = piecewise_cv_scenario(net, cv, r, dt=dt,
                                             horizon=horizon)
            elif trace_model == "gauss_markov":
                scen = gauss_markov_scenario(net, cv, r, dt=dt,
                                             horizon=horizon, corr=corr)
            else:
                raise ValueError(f"unknown trace_model {trace_model!r}")
            rep = simulate_plan(profile, net, plan.solution, plan.b,
                                B=plan.B, scenario=scen, engine="auto")
            lats.append(rep.L_t)
    else:
        raise ValueError(f"unknown mode {mode!r}")
    lats = np.asarray(lats)
    return FluctuationReport(
        cv=cv, mean_latency=float(lats.mean()), std_latency=float(lats.std()),
        p95_latency=float(np.percentile(lats, 95)),
        planned_latency=float(baseline),
        degradation=float(lats.mean() / baseline) if baseline > 0 else 1.0)
