"""Cost models — the pluggable objective/feasibility seam of the planner.

The paper's Algorithm 2 alternates Algorithm 1 (MSP) and Theorem 1
(micro-batch size) against the idealized closed form of Eqs. (12)-(14),
which ignores the reentrant/co-location idle time and activation-memory
pressure the ``repro.sim`` engine actually measures.  A :class:`CostModel`
makes that objective (and the memory-feasibility predicate behind the
Eq. (24) box) a first-class, swappable component:

* :class:`ClosedForm` — the default; bit-identical to the historical
  hard-wired path (``latency.total_latency`` / ``latency.memory_feasible``).
* :class:`SimMakespan` — wraps ``sim.simulate_plan`` with a configurable
  admission policy (``repro.sim.policies``; the memory-budgeted policy by
  default), so ``bcd_solve``'s final micro-batch refinement optimizes the
  *measured* makespan instead of the closed form.

The Eq. (11) memory arithmetic is factored into one claims source:
``latency.memory_split`` -> :func:`stage_memory_claims` ->
:func:`node_budget_windows`.  ``MemoryBudgeted.stage_capacity`` (admission
windows), ``pipeline.schedule.memory_highwater`` (schedule claims) and
``microbatch.feasibility_box`` (the feasible-b box, via
:meth:`SimMakespan.memory_feasible`) all consume it — no duplicated
arithmetic, which is what lets the tests cross-validate the three
event-by-event against the engine's measured occupancy.

>>> from repro.core import make_edge_network, uniform_profile, SplitSolution
>>> prof = uniform_profile(6, fp=1.0, bp=2.0, act=1.0)
>>> net = make_edge_network(num_servers=2, num_clients=2, seed=0)
>>> sol = SplitSolution(cuts=(3, 6), placement=(0, 1))
>>> cm = ClosedForm()
>>> import repro.core.latency as L
>>> bool(cm.evaluate(prof, net, sol, 4, 32)
...      == L.total_latency(prof, net, sol, 4, 32))
True
>>> [c.position for c in stage_memory_claims(prof, net, sol, 4)]
[0, 1]
"""

from __future__ import annotations

import dataclasses
import math

from repro import obs

from . import latency as L
from .latency import SplitSolution, memory_split, memory_split_per_sample
from .network import EdgeNetwork
from .profiles import ModelProfile

__all__ = ["CostModel", "ClosedForm", "SimMakespan", "StageClaim",
           "DegradedTail", "stage_memory_claims", "node_budget_windows",
           "node_budget_windows_many", "budget_feasible",
           "resolve_cost_model", "memoized_cost_model"]


# ---------------------------------------------------------------------------
# The shared Eq. (11) claims source
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StageClaim:
    """Memory claim of one pipeline stage (chain position ``position``).

    ``static_bytes`` is resident once (parameters + optimizer state);
    ``act_bytes`` is the cost of ONE live micro-batch (activations +
    act-gradients).  Holding ``w`` micro-batches live at this stage costs
    ``static_bytes + w * act_bytes``.
    """
    position: int            # stage position j in the non-empty chain
    submodel: int            # paper submodel index k
    node: int                # hosting node index
    static_bytes: float
    act_bytes: float


def stage_memory_claims(profile: ModelProfile, net: EdgeNetwork,
                        sol: SplitSolution, b: int,
                        memory_model: str = "refined") -> list:
    """Per-stage :class:`StageClaim` list — Eq. (11) via
    ``latency.memory_split``, the single claims source (see module doc)."""
    claims = []
    for j, (k, lo, hi, node) in enumerate(sol.segments()):
        static, act = memory_split(profile, net, lo, hi, node, b,
                                   memory_model)
        claims.append(StageClaim(position=j, submodel=k, node=node,
                                 static_bytes=static, act_bytes=act))
    return claims


@dataclasses.dataclass(frozen=True)
class DegradedTail:
    """Tail-sized node memory budgets for admission windows.

    Nominal windows size claims against ``Node.mem`` — the budget when
    nothing else is running.  Under memory pressure (a co-tenant claiming
    part of the device, ``NetworkScenario.mem_mult``) the *degraded tail*
    is what OOMs, so this mode sizes windows to a lower-tail CVaR of the
    effective capacity across a fuzzed scenario distribution instead:
    ``mem[n]`` is the mean of the worst ``ceil((1 - alpha) * n_scen)``
    per-scenario minima of node ``n``'s memory trace.  At ``alpha`` high
    enough that the tail is a single scenario, this is the distribution's
    worst case — windows sized by it never overflow any sampled scenario.

    Thread through ``node_budget_windows(..., tail=)`` /
    ``budget_feasible(..., tail=)`` / ``MemoryBudgeted(tail=)`` /
    ``SimMakespan(tail=)``.  Nodes beyond ``len(mem)`` (or with a ``None``
    entry) keep their nominal budget.

    >>> import numpy as np
    >>> from repro.core import make_edge_network
    >>> net = make_edge_network(num_servers=2, num_clients=1, seed=0)
    >>> DegradedTail(mem=(None,) * 3).node_mem(net, 1) == net.nodes[1].mem
    True
    """

    mem: tuple                   # per-node effective budget (None: nominal)
    alpha: float = 0.95

    @classmethod
    def from_scenarios(cls, net: EdgeNetwork, scenarios,
                       alpha: float = 0.95) -> "DegradedTail":
        """Size budgets from a scenario distribution's ``mem_mult`` traces
        (worst instant per scenario, lower-tail CVaR across scenarios)."""
        if not 0.0 <= alpha < 1.0:
            raise ValueError("need 0 <= alpha < 1")
        scenarios = tuple(scenarios)
        if not scenarios:
            raise ValueError("need at least one scenario")
        k = int(math.ceil((1.0 - alpha) * len(scenarios)))
        mems = []
        for i, node in enumerate(net.nodes):
            worst_mult = sorted(
                min(s.mem_mult[i].values) if i in s.mem_mult else 1.0
                for s in scenarios)
            mems.append(node.mem * float(sum(worst_mult[:k]) / k))
        return cls(mem=tuple(mems), alpha=alpha)

    def node_mem(self, net: EdgeNetwork, n: int) -> float:
        if n < len(self.mem) and self.mem[n] is not None:
            return self.mem[n]
        return net.nodes[n].mem

    def __repr__(self):
        sized = [m for m in self.mem if m is not None]
        return (f"DegradedTail(alpha={self.alpha}, nodes={len(self.mem)}, "
                f"min_mem={min(sized):.4g})" if sized else
                f"DegradedTail(alpha={self.alpha}, nominal)")


def node_budget_windows(profile: ModelProfile, net: EdgeNetwork,
                        sol: SplitSolution, b: int,
                        memory_model: str = "refined",
                        tail: DegradedTail | None = None) -> list:
    """Per-stage admission windows derived from ``Node.mem``.

    Co-located stages share their node's budget: for node ``n`` hosting
    claims with totals ``static_n`` / ``act_n`` per live micro-batch, the
    window is the largest ``w`` with ``static_n + w * act_n <= mem_n`` —
    i.e. ``floor((mem_n - static_n) / act_n)``.  ``None`` means unbounded
    (zero activation bytes); ``0`` means even a single live micro-batch
    does not fit (the plan is memory-infeasible at this ``b``).

    ``tail`` substitutes :class:`DegradedTail` effective budgets for the
    nominal ``Node.mem`` — windows sized for the degraded-memory tail of a
    scenario distribution instead of the unloaded device.
    """
    claims = stage_memory_claims(profile, net, sol, b, memory_model)
    static_n: dict = {}
    act_n: dict = {}
    for c in claims:
        static_n[c.node] = static_n.get(c.node, 0.0) + c.static_bytes
        act_n[c.node] = act_n.get(c.node, 0.0) + c.act_bytes
    windows = []
    for c in claims:
        mem = net.nodes[c.node].mem if tail is None \
            else tail.node_mem(net, c.node)
        free = mem - static_n[c.node]
        act = act_n[c.node]
        if act <= 0.0:
            windows.append(None if free >= 0.0 else 0)
        else:
            windows.append(max(0, int(math.floor(free / act))))
    return windows


def node_budget_windows_many(profile: ModelProfile, net: EdgeNetwork,
                             sol: SplitSolution, bs,
                             memory_model: str = "refined",
                             tail: DegradedTail | None = None) -> list:
    """:func:`node_budget_windows` for a whole range of micro-batch sizes.

    The Eq. (11) cumulative lookups are b-independent
    (``latency.memory_split_per_sample``); only the effective-batch
    multiplier varies, so one claims pass serves every ``b`` — the batched
    counterpart a micro-batch refinement sweep calls once instead of
    re-deriving the claims per candidate.  Per-``b`` results are
    float-identical to the one-at-a-time function (same multiplies, same
    accumulation order; asserted in tests).
    """
    import numpy as np
    segs = list(sol.segments())
    per = [(node, *memory_split_per_sample(profile, lo, hi, memory_model))
           for _, lo, hi, node in segs]
    M = net.num_clients
    bs = list(bs)
    b_arr = np.asarray(bs, dtype=np.intp)
    share = b_arr - (M - 1) * (b_arr // M)        # client_max_share, batched
    static_n: dict = {}
    act_n: dict = {}
    for node, static, per_sample in per:
        eff = share if node == 0 else b_arr
        static_n[node] = static_n.get(node, 0.0) + static
        act_n[node] = act_n.get(node, 0.0) + eff * per_sample
    cols = []
    for node, _, _ in per:
        mem = net.nodes[node].mem if tail is None \
            else tail.node_mem(net, node)
        free = mem - static_n[node]
        act = act_n[node]
        ws: list = [None] * len(bs)
        for i in range(len(bs)):
            a = float(act[i])
            if a <= 0.0:
                ws[i] = None if free >= 0.0 else 0
            else:
                ws[i] = max(0, int(math.floor(free / a)))
        cols.append(ws)
    return [[col[i] for col in cols] for i in range(len(bs))]


def budget_feasible(profile: ModelProfile, net: EdgeNetwork,
                    sol: SplitSolution, b: int,
                    memory_model: str = "refined",
                    tail: DegradedTail | None = None) -> bool:
    """Window >= 1 everywhere: one live micro-batch per stage fits every
    node's memory — the memory predicate behind the memory-budgeted
    feasible-b box (monotone non-increasing in ``b``).  ``tail`` sizes the
    budgets for a degraded-memory scenario tail (:class:`DegradedTail`)."""
    return all(w is None or w >= 1
               for w in node_budget_windows(profile, net, sol, b,
                                            memory_model, tail))


# ---------------------------------------------------------------------------
# The cost-model protocol
# ---------------------------------------------------------------------------

class CostModel:
    """Objective + memory-feasibility pair consumed by the planner stack.

    ``evaluate`` is the quantity ``bcd_solve`` / ``exhaustive_joint`` /
    ``exhaustive_microbatch`` minimize (lower is better; ``math.inf`` for
    infeasible points); ``memory_feasible`` is the predicate behind the
    Eq. (24) feasible-b box (must be monotone non-increasing in ``b``).
    """

    name = "abstract"

    def evaluate(self, profile: ModelProfile, net: EdgeNetwork,
                 sol: SplitSolution, b: int, B: int) -> float:
        raise NotImplementedError

    def memory_feasible(self, profile: ModelProfile, net: EdgeNetwork,
                        sol: SplitSolution, b: int) -> bool:
        raise NotImplementedError

    # -- batched candidate scoring ------------------------------------------
    def evaluate_many(self, profile: ModelProfile, net: EdgeNetwork,
                      cands, B: int) -> list:
        """Objectives for many candidate ``(sol, b)`` plans at once —
        identical to looping :meth:`evaluate` (asserted in tests), which is
        exactly what this base implementation does.  Models with a batched
        fast path (``SimMakespan`` via ``sim.simulate_plans``'s stacked
        plan axis) override it; consumers — ``exhaustive_microbatch``'s
        refinement sweep, ``exhaustive_joint``'s iterate selection — call
        it instead of per-candidate ``evaluate``."""
        return [self.evaluate(profile, net, sol, b, B) for sol, b in cands]

    def memory_feasible_many(self, profile: ModelProfile, net: EdgeNetwork,
                             sol: SplitSolution, bs) -> list:
        """:meth:`memory_feasible` over a range of ``b`` (batched where the
        model supports it)."""
        return [self.memory_feasible(profile, net, sol, b) for b in bs]


class ClosedForm(CostModel):
    """The paper's Eqs. (12)-(14) objective with the Eq. (11)/C7-C8 memory
    predicate — bit-identical to the historical hard-wired path (the same
    float operations in the same order), and the default everywhere."""

    name = "closed_form"

    def __init__(self, memory_model: str = "paper"):
        self.memory_model = memory_model

    def evaluate(self, profile, net, sol, b, B) -> float:
        return L.total_latency(profile, net, sol, b, B)

    def memory_feasible(self, profile, net, sol, b) -> bool:
        return L.memory_feasible(profile, net, sol, b, self.memory_model)

    def __repr__(self):
        return f"ClosedForm(memory_model={self.memory_model!r})"


class SimMakespan(CostModel):
    """Measured makespan: ``sim.simulate_plan`` under an admission policy.

    The simulated timeline charges the reentrant/co-location idle time the
    closed form idealizes away (a resource serves one task at a time), and
    the admission ``policy`` bounds live activations — ``"memory"``
    (:class:`repro.sim.policies.MemoryBudgeted`, the default) derives the
    windows from ``Node.mem`` via :func:`node_budget_windows`, so the
    objective and the feasibility predicate consume the same claims.

    ``engine="auto"`` uses the vectorized engine wherever it is exact and
    falls back to the heap event loop (reentrant plans, time-varying
    capacity).  The import of ``repro.sim`` is deferred to call time so
    ``repro.core`` keeps importing without the sim subsystem.
    """

    name = "sim_makespan"

    def __init__(self, policy="memory", engine: str = "auto",
                 memory_model: str = "refined",
                 tail: DegradedTail | None = None):
        # keep the feasibility predicate and the executed admission windows
        # on ONE memory model: a "memory" policy name is materialized with
        # this model's memory_model (and tail budgets), and a pre-built
        # MemoryBudgeted instance donates its own (otherwise the box would
        # prune b values the simulated windows would happily schedule, or
        # vice versa)
        if isinstance(policy, str) and \
                policy.lower() in ("memory", "memory_budgeted"):
            from repro.sim.policies import MemoryBudgeted  # deferred
            policy = MemoryBudgeted(memory_model, tail=tail)
        elif getattr(policy, "name", None) == "memory":
            memory_model = policy.memory_model
            tail = policy.tail
        self.policy = policy
        self.engine = engine
        self.memory_model = memory_model
        self.tail = tail

    def evaluate(self, profile, net, sol, b, B) -> float:
        if b < 1 or not self.memory_feasible(profile, net, sol, b):
            return math.inf
        from repro.sim.engine import simulate_plan  # deferred: no hard dep
        with obs.span("cost_model.sim_evaluate", b=b, B=B):
            rep = simulate_plan(profile, net, sol, b, B=B, policy=self.policy,
                                engine=self.engine)
        return rep.L_t

    def evaluate_many(self, profile, net, cands, B) -> list:
        """Batched scoring: one ``sim.simulate_plans`` call for every
        memory-feasible candidate — refinement sweeps over ``b`` ride the
        engine's stacked plan axis instead of paying per-call dispatch.
        Results are identical to looping :meth:`evaluate`."""
        from repro.sim.engine import simulate_plans  # deferred: no hard dep
        out = [math.inf] * len(cands)
        by_sol: dict = {}
        for i, (sol, b) in enumerate(cands):
            if b >= 1:
                by_sol.setdefault((sol.cuts, sol.placement), []).append(i)
        live = []
        for idxs in by_sol.values():
            sol = cands[idxs[0]][0]
            oks = self.memory_feasible_many(profile, net, sol,
                                            [cands[i][1] for i in idxs])
            live.extend(i for i, ok in zip(idxs, oks) if ok)
        live.sort()
        if not live:
            return out
        with obs.span("cost_model.sim_evaluate_many", n=len(live), B=B):
            reps = simulate_plans(profile, net, [cands[i] for i in live],
                                  B=B, policy=self.policy,
                                  engine=self.engine)
        for i, rep in zip(live, reps):
            out[i] = rep.L_t
        return out

    def memory_feasible(self, profile, net, sol, b) -> bool:
        return budget_feasible(profile, net, sol, b, self.memory_model,
                               self.tail)

    def memory_feasible_many(self, profile, net, sol, bs) -> list:
        wss = node_budget_windows_many(profile, net, sol, bs,
                                       self.memory_model, self.tail)
        return [all(w is None or w >= 1 for w in ws) for ws in wss]

    def __repr__(self):
        extra = "" if self.tail is None else f", tail={self.tail!r}"
        return (f"SimMakespan(policy={getattr(self.policy, 'name', self.policy)!r}, "
                f"engine={self.engine!r}, "
                f"memory_model={self.memory_model!r}{extra})")


class _MemoCostModel(CostModel):
    """Per-solve memoization around another cost model.

    ``bcd_solve`` / ``exhaustive_joint`` wrap their (non-``ClosedForm``)
    model for the duration of one solve: the warm-start seed score, the
    per-iteration iterate scores (which repeat once the alternation
    stabilizes), and the two micro-batch refinement sweeps all land on the
    same ``(cuts, placement, b)`` keys, so expensive simulated objectives
    are computed once.  The cache is scoped to one ``(profile, net)`` —
    that is why this is a per-solve wrapper and not state on the model
    itself (the elastic coordinator re-solves on *mutated* networks, where
    stale makespans would be silently wrong).
    """

    def __init__(self, inner: CostModel):
        self.inner = inner
        self._eval: dict = {}
        self._mem: dict = {}

    @property
    def name(self):                      # type: ignore[override]
        return self.inner.name

    def evaluate(self, profile, net, sol, b, B) -> float:
        key = (sol.cuts, sol.placement, b, B)
        got = self._eval.get(key)
        if got is None:
            obs.inc("cost_model.memo_eval_miss")
            got = self._eval[key] = self.inner.evaluate(profile, net, sol,
                                                        b, B)
        else:
            obs.inc("cost_model.memo_eval_hit")
        return got

    def evaluate_many(self, profile, net, cands, B) -> list:
        out: list = [None] * len(cands)
        miss = []
        for i, (sol, b) in enumerate(cands):
            got = self._eval.get((sol.cuts, sol.placement, b, B))
            if got is None:
                miss.append(i)
            else:
                out[i] = got
        obs.inc("cost_model.memo_eval_hit", len(cands) - len(miss))
        obs.inc("cost_model.memo_eval_miss", len(miss))
        if miss:
            vals = self.inner.evaluate_many(profile, net,
                                            [cands[i] for i in miss], B)
            for i, val in zip(miss, vals):
                sol, b = cands[i]
                self._eval[(sol.cuts, sol.placement, b, B)] = val
                out[i] = val
        return out

    def memory_feasible(self, profile, net, sol, b) -> bool:
        key = (sol.cuts, sol.placement, b)
        got = self._mem.get(key)
        if got is None:
            obs.inc("cost_model.memo_mem_miss")
            got = self._mem[key] = self.inner.memory_feasible(profile, net,
                                                              sol, b)
        else:
            obs.inc("cost_model.memo_mem_hit")
        return got

    def memory_feasible_many(self, profile, net, sol, bs) -> list:
        out: list = [None] * len(bs)
        miss = []
        for i, b in enumerate(bs):
            got = self._mem.get((sol.cuts, sol.placement, b))
            if got is None:
                miss.append(i)
            else:
                out[i] = got
        obs.inc("cost_model.memo_mem_hit", len(bs) - len(miss))
        obs.inc("cost_model.memo_mem_miss", len(miss))
        if miss:
            vals = self.inner.memory_feasible_many(
                profile, net, sol, [bs[i] for i in miss])
            for i, val in zip(miss, vals):
                self._mem[(sol.cuts, sol.placement, bs[i])] = val
                out[i] = val
        return out

    def __repr__(self):
        return f"_MemoCostModel({self.inner!r})"


def memoized_cost_model(cm: CostModel) -> CostModel:
    """Wrap ``cm`` in a fresh per-solve memo (idempotent; ``ClosedForm`` is
    returned as-is — its evaluations are cheaper than the cache lookups,
    and the default path stays bit-identical and untouched)."""
    if isinstance(cm, (ClosedForm, _MemoCostModel)):
        return cm
    return _MemoCostModel(cm)


def resolve_cost_model(cost_model, memory_model: str = "paper") -> CostModel:
    """``None`` -> the default :class:`ClosedForm` (with the caller's
    ``memory_model``); a :class:`CostModel` instance passes through."""
    if cost_model is None:
        return ClosedForm(memory_model)
    if isinstance(cost_model, CostModel):
        return cost_model
    raise TypeError(f"expected a CostModel or None, got {cost_model!r}")
