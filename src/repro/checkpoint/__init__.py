"""Checkpoint/restart substrate with reshard-on-restore."""

from .store import (CheckpointStore, save_checkpoint, restore_checkpoint,
                    estimate_restore_seconds, latest_step)

__all__ = ["CheckpointStore", "save_checkpoint", "restore_checkpoint",
           "estimate_restore_seconds", "latest_step"]
