"""Sharding-agnostic npz checkpoints + JSON metadata, async save, and
reshard-on-restore (elastic scaling across pod counts).

Layout:  <dir>/step_<N>/arrays.npz  +  <dir>/step_<N>/meta.json
Arrays are stored *unsharded* (host-gathered); restore re-shards onto the
current mesh via ``jax.device_put`` with the caller's shardings — so a run
checkpointed on a 512-chip multi-pod mesh restores onto 256 chips (or 1 CPU
device in tests) unchanged.  A ``scratch -> rename`` commit protocol keeps
partially-written checkpoints invisible to ``latest_step``.
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import time

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        items.append((key, leaf))
    return items, treedef


def save_checkpoint(directory: str, step: int, tree, *, meta: dict = None,
                    blocking: bool = True):
    """Host-gather + write.  With blocking=False the disk write happens on a
    background thread (training continues; join via CheckpointStore.wait)."""
    items, _ = _flatten_with_paths(tree)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in items}
    payload_meta = {"step": step, "time": time.time(),
                    "bytes": int(sum(a.nbytes for a in arrays.values())),
                    **(meta or {})}

    def write():
        final = os.path.join(directory, f"step_{step:08d}")
        scratch = final + ".tmp"
        os.makedirs(scratch, exist_ok=True)
        t0 = time.perf_counter()
        np.savez(os.path.join(scratch, "arrays.npz"), **arrays)
        payload_meta["write_seconds"] = time.perf_counter() - t0
        with open(os.path.join(scratch, "meta.json"), "w") as f:
            json.dump(payload_meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(scratch, final)

    if blocking:
        write()
        return None
    t = threading.Thread(target=write, daemon=True)
    t.start()
    return t


def estimate_restore_seconds(directory: str, step: int | None = None, *,
                             read_bandwidth: float | None = None) -> float:
    """Predicted wall-clock of ``restore_checkpoint`` for an existing
    checkpoint, from its recorded metadata — the restore-cost term the
    elastic coordinator charges when a ``NodeFailure`` forces a resume.

    Every checkpoint written by :func:`save_checkpoint` records its gathered
    payload size (``bytes``) and the measured serialization time
    (``write_seconds``).  With ``read_bandwidth`` (bytes/s — e.g. the
    recovering node's measured disk or link rate) the estimate is
    ``bytes / read_bandwidth``; without it, the measured write time stands
    in for the read-back (same payload through the same storage path).
    Returns 0.0 when no checkpoint exists — nothing to restore.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            return 0.0
    path = os.path.join(directory, f"step_{step:08d}", "meta.json")
    try:
        with open(path) as f:
            meta = json.load(f)
    except OSError:
        return 0.0
    if read_bandwidth is not None and read_bandwidth > 0:
        return float(meta.get("bytes", 0)) / read_bandwidth
    return float(meta.get("write_seconds", 0.0))


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, like_tree, *,
                       shardings=None):
    """Restore into the structure of ``like_tree``; re-shard with
    ``shardings`` (same pytree structure of NamedSharding) if given."""
    path = os.path.join(directory, f"step_{step:08d}")
    with np.load(os.path.join(path, "arrays.npz")) as npz:
        arrays = {k: npz[k] for k in npz.files}
    items, treedef = _flatten_with_paths(like_tree)
    leaves = []
    for key, like in items:
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        a = arrays[key]
        if tuple(a.shape) != tuple(like.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {a.shape} vs {like.shape}")
        leaves.append(a.astype(like.dtype))
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree,
                            shardings)
    else:
        tree = jax.tree.map(jax.numpy.asarray, tree)
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    return tree, meta


@dataclasses.dataclass
class CheckpointStore:
    """Keeps the last ``keep`` checkpoints; tracks async writes."""
    directory: str
    keep: int = 3
    _threads: list = dataclasses.field(default_factory=list)

    def save(self, step: int, tree, *, meta: dict = None,
             blocking: bool = False):
        t = save_checkpoint(self.directory, step, tree, meta=meta,
                            blocking=blocking)
        if t is not None:
            self._threads.append(t)
        self._gc()

    def wait(self):
        for t in self._threads:
            t.join()
        self._threads.clear()

    def restore_latest(self, like_tree, *, shardings=None):
        step = latest_step(self.directory)
        if step is None:
            return None, None
        self.wait()
        tree, meta = restore_checkpoint(self.directory, step, like_tree,
                                        shardings=shardings)
        return tree, meta

    def _gc(self):
        if not os.path.isdir(self.directory):
            return
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.directory)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
