"""Telemetry overhead benchmark (ISSUE 6): the ``repro.obs`` layer must be
free when disabled and near-free when enabled.

Measures best-of-N wall clock of the vectorized engine on the
10k-micro-batch Gauss-Markov chain from ``bench_sim.trace_instance`` — the
same acceptance cell as the engine-scaling grid — three ways:

* **disabled** — telemetry off (the default for every library caller);
* **enabled**  — counters + spans recording;
* **enabled+util** — additionally reconstructing the full
  ``UtilizationReport`` idle/bubble decomposition from the timeline.

Asserts the enabled overhead stays under 5% (the ISSUE 6 acceptance bar)
and double-checks the zero-overhead contract structurally: a disabled run
must leave the counter registry untouched.

Outputs results/bench/bench_obs.csv (+ the registry dump of the enabled
runs).  ``--smoke`` shrinks the chain for CI and loosens the bound (tiny
runs are noise-dominated).
"""

from __future__ import annotations

import argparse

from repro import obs
from repro.sim import simulate_plan

from .bench_sim import trace_instance
from .common import Timer, dump_registry, emit

#: acceptance bar: enabled-mode slowdown on the 10k acceptance cell
MAX_ENABLED_OVERHEAD = 1.05
#: CI smoke bound — short runs are dominated by constant costs and noise
MAX_SMOKE_OVERHEAD = 1.5


def _best_of(fn, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        with Timer() as t:
            fn()
        best = min(best, t.seconds)
    return best


def run(smoke: bool = False) -> dict:
    Q = 2_000 if smoke else 10_000
    repeats = 3 if smoke else 5
    bound = MAX_SMOKE_OVERHEAD if smoke else MAX_ENABLED_OVERHEAD
    prof, net, sol, b, _, scen = trace_instance(8, Q)

    def cell():
        return simulate_plan(prof, net, sol, b, num_microbatches=Q,
                             scenario=scen, engine="vectorized")

    cell()                               # warm caches once, uncharged

    obs.disable()
    snap_before = obs.get_registry().snapshot()
    disabled_s = _best_of(cell, repeats)
    assert obs.get_registry().snapshot() == snap_before, \
        "disabled-mode run mutated the counter registry"

    obs.enable()
    enabled_s = _best_of(cell, repeats)
    util_s = _best_of(lambda: cell().utilization(), repeats)
    rep = cell()
    nres = len(rep.utilization().resources)
    dump_registry("bench_obs")
    obs.disable()

    overhead = enabled_s / max(disabled_s, 1e-9)
    util_overhead = util_s / max(disabled_s, 1e-9)
    rows = [["disabled", Q, round(disabled_s, 4), 1.0],
            ["enabled", Q, round(enabled_s, 4), round(overhead, 3)],
            ["enabled+util", Q, round(util_s, 4), round(util_overhead, 3)]]
    emit("bench_obs", rows, ["mode", "num_microbatches", "best_s",
                             "overhead_x"])
    print(f"# {nres} resources decomposed; enabled overhead "
          f"{(overhead - 1) * 100:+.1f}% (bound {(bound - 1) * 100:.0f}%)")
    assert overhead < bound, \
        f"enabled telemetry overhead {overhead:.3f}x exceeds {bound}x"
    return {"Q": Q, "disabled_s": disabled_s, "enabled_s": enabled_s,
            "enabled_util_s": util_s, "overhead_x": overhead}


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small chain + loose bound for CI")
    args = ap.parse_args()
    run(smoke=args.smoke)
