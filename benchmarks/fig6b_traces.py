"""Fig. 6b (beyond-paper): robustness under *time-varying* capacity traces.

The original Fig. 6 perturbs the whole network once per draw (i.i.d. CV
noise) and re-evaluates the analytical Eq. (14).  Here each draw is a full
discrete-event execution under capacity traces that drift *during* the
pipeline — fast i.i.d. piecewise resampling vs temporally-correlated
Gauss-Markov — producing a degradation-vs-CV table per trace model.  A
correlated bad channel epoch stalls many consecutive
micro-batches, while fast resampling averages out across pipeline slots —
visible as a much wider spread and heavier p95 tail at equal CV.
"""

from __future__ import annotations

from repro.core import evaluate_under_fluctuation, ours
from .common import emit, paper_network, paper_profile


def run(cvs=(0.0, 0.1, 0.2, 0.3), models=("piecewise", "gauss_markov"),
        seeds=(0,), draws=8, B=256):
    prof = paper_profile()
    rows = []
    for s in seeds:
        net = paper_network(num_servers=6, seed=s)
        plan = ours(prof, net, B=B, b0=20)
        for model in models:
            for cv in cvs:
                rep = evaluate_under_fluctuation(
                    prof, net, plan, cv, draws=draws, seed=s, mode="trace",
                    trace_model=model)
                rows.append([s, model, cv,
                             round(rep.planned_latency, 4),
                             round(rep.mean_latency, 4),
                             round(rep.std_latency, 4),
                             round(rep.p95_latency, 4),
                             round(rep.degradation, 4)])
    emit("fig6b_traces", rows,
         ["seed", "trace_model", "cv", "planned_s", "mean_s", "std_s",
          "p95_s", "degradation"])
    return rows


if __name__ == "__main__":
    run()
