"""Planner scaling benchmark (ISSUE 3 grid + ISSUE 9 fleet).

Grid: nodes x layers x B for ``solve_msp`` / ``bcd_solve`` /
``exhaustive_joint``, threshold-batched vs the legacy scan, with
wall-clocks and DP sweep counts.

Fleet (ISSUE 9): plans-per-second numbers for the planner-as-a-service
paths on the acceptance instance (24 servers x 30 layers x B = 64) —
  - ``solve_many`` numpy vs the compiled jax pipeline (>= 3x bar),
  - cold solve vs incremental ``Planner.update`` warm replans on
    single-edge deltas (>= 5x bar),
  - an N-topology sweep: cold / incremental / pallas plans per second.

Outputs:
  results/bench/bench_planner.csv   the full grid
  BENCH_planner.json (repo root)    summary incl. acceptance + fleet —
                                    the perf trajectory tracked across PRs

``--smoke`` shrinks the grid for the CI invocation (a few seconds) and
asserts the fleet speedup bars instead of recording them.
"""

from __future__ import annotations

import argparse
import json
import os
import time

from repro.core import (Planner, bcd_solve, exhaustive_joint,
                        make_edge_network, planner_jax, solve_msp,
                        transformer_profile)
from repro.ft import RateChange, Straggler
from .common import Timer, emit

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
JSON_PATH = os.path.join(REPO_ROOT, "BENCH_planner.json")


def bench_instance(servers: int, blocks: int, *, seed: int = 1):
    """A transformer-profile edge instance; total layers I = blocks + 2."""
    prof = transformer_profile(
        f"bench{blocks + 2}", num_layers=blocks, d_model=512, n_heads=8,
        n_kv=8, d_ff=2048, vocab=32000, seq_len=128)
    net = make_edge_network(num_servers=servers, num_clients=4, seed=seed,
                            kappa=1 / 32.0, f_range=(1e12, 10e12),
                            mem_range=(4 * 2**30, 32 * 2**30))
    return prof, net


def acceptance_instance():
    """The ISSUE-3 acceptance point: 24 servers x 30 layers."""
    return bench_instance(24, 28)


def _grid_cell(servers, blocks, B, rows):
    prof, net = bench_instance(servers, blocks)
    b = max(1, B // 8)
    with Timer() as t_bat:
        r_bat = solve_msp(prof, net, b, B, solver="batched")
    with Timer() as t_scan:
        r_scan = solve_msp(prof, net, b, B, solver="scan")
    with Timer() as t_bcd:
        bcd_solve(prof, net, B)
    with Timer() as t_ex:
        exhaustive_joint(prof, net, B, solver="batched")
    rows.append([servers, blocks + 2, B,
                 round(t_bat.seconds, 4), r_bat.thresholds_scanned,
                 round(t_scan.seconds, 4), r_scan.thresholds_scanned,
                 round(t_bcd.seconds, 4), round(t_ex.seconds, 4)])
    return rows


def acceptance_run(b_step: int = 1):
    """exhaustive_joint, batched vs legacy scan, on the acceptance instance."""
    prof, net = acceptance_instance()
    B = 64
    with Timer() as t_bat:
        p_bat = exhaustive_joint(prof, net, B, b_step=b_step, solver="batched")
    with Timer() as t_scan:
        p_scan = exhaustive_joint(prof, net, B, b_step=b_step, solver="scan")
    identical = (p_bat.solution == p_scan.solution and p_bat.b == p_scan.b
                 and p_bat.L_t == p_scan.L_t)
    return {
        "servers": 24, "layers": 30, "B": B, "b_step": b_step,
        "scan_seconds": round(t_scan.seconds, 3),
        "batched_seconds": round(t_bat.seconds, 3),
        "speedup": round(t_scan.seconds / t_bat.seconds, 2),
        "identical_plans": bool(identical),
        "L_t": round(p_bat.L_t, 6), "b": p_bat.b,
    }


def fleet_run(smoke: bool = False) -> dict:
    """ISSUE 9 planner-as-a-service numbers on the acceptance instance."""
    prof, net = acceptance_instance()
    B = 64
    bs = list(range(1, B + 1, 8 if smoke else 1))

    # -- batched solve_many: numpy vs the compiled jax pipeline ------------
    pl_np = Planner(prof, net)
    pl_np.solve_many(bs, B)                      # warm graph/DP caches
    with Timer() as t_np:
        pl_np.solve_many(bs, B)
    jax_seconds = speedup = None
    if planner_jax.available():
        pl_jx = Planner(prof, net)
        pl_jx.solve_many(bs, B, backend="jax")   # compile + warm caches
        with Timer() as t_jx:
            pl_jx.solve_many(bs, B, backend="jax")
        jax_seconds = round(t_jx.seconds, 4)
        speedup = round(t_np.seconds / t_jx.seconds, 2)
    solve_many = {
        "servers": 24, "layers": 30, "B": B, "num_bs": len(bs),
        "numpy_seconds": round(t_np.seconds, 4),
        "jax_seconds": jax_seconds, "jax_speedup": speedup,
        "jax_dtype": planner_jax.sweep_dtype()
        if planner_jax.available() else None,
    }

    # -- incremental: warm Planner.update vs cold re-solve -----------------
    b = 8
    deltas = []
    n = len(net.nodes)
    for k in range(8 if smoke else 16):
        if k % 2 == 0:
            deltas.append(RateChange(n_from=1 + k % (n - 1),
                                     n_to=1 + (k + 1) % (n - 1),
                                     factor=0.8 if k % 4 else 1.25))
        else:
            deltas.append(Straggler(node=1 + k % (n - 1),
                                    slowdown=1.5 if k % 4 == 1 else 1 / 1.5))
    warm_pl = Planner(prof, net)
    warm_pl.solve(b, B, solver="batched")        # seed the warm hint
    identical = True
    with Timer() as t_warm:
        warm_results = []
        for d in deltas:
            warm_pl.update(d)
            warm_results.append(warm_pl.solve(b, B, solver="batched"))
    # cold baseline: what _full_replan paid before ISSUE 9 — a fresh
    # Planner (factory + graph build) per delta on the mutated net
    from repro.ft.coordinator import Coordinator
    cold_net = net
    with Timer() as t_cold:
        for d, wr in zip(deltas, warm_results):
            cold_net, _ = Coordinator.preview(cold_net, None, d)
            cr = Planner(prof, cold_net).solve(b, B, solver="batched")
            identical = identical and (cr.objective == wr.objective
                                       and cr.solution == wr.solution)
    incremental = {
        "deltas": len(deltas), "b": b, "B": B,
        "cold_seconds": round(t_cold.seconds, 4),
        "warm_seconds": round(t_warm.seconds, 4),
        "speedup": round(t_cold.seconds / t_warm.seconds, 2),
        "identical_plans": bool(identical),
    }

    # -- N-topology fleet: plans per second per backend --------------------
    topo_bs = [4, 8, 16, 32]
    seeds = range(2 if smoke else 8)
    nets = [bench_instance(24, 28, seed=3 + s)[1] for s in seeds]
    rates = {}
    for name in (["cold", "incremental"]
                 + (["pallas"] if planner_jax.available() else [])):
        plans = 0
        with Timer() as t:
            for topo in nets:
                if name == "cold":
                    for bb in topo_bs:
                        Planner(prof, topo).solve(bb, B, solver="batched")
                        plans += 1
                elif name == "incremental":
                    p = Planner(prof, topo)
                    for bb in topo_bs:
                        p.solve(bb, B, solver="batched")
                        plans += 1
                    for d in deltas[:4]:
                        p.update(d)
                        for bb in topo_bs:
                            p.solve(bb, B, solver="batched")
                            plans += 1
                else:                            # pallas window sweeps
                    p = Planner(prof, topo)
                    for bb in topo_bs:
                        p.solve(bb, B, solver="batched", backend="pallas")
                        plans += 1
        rates[name] = {"plans": plans, "seconds": round(t.seconds, 4),
                       "plans_per_sec": round(plans / t.seconds, 2)}

    fleet = {"solve_many": solve_many, "incremental": incremental,
             "topologies": {"n": len(nets), "b_grid": topo_bs, **rates}}
    # CI bars (ISSUE 9): incremental >= 5x always; the jax >= 3x bar only
    # on the full b-sweep — the smoke subset (8 of 64 sizes) under-fills
    # the batched dispatches, so its ratio is not the acceptance number
    assert incremental["speedup"] >= 5.0, incremental
    assert incremental["identical_plans"], incremental
    if not smoke and speedup is not None:
        assert speedup >= 3.0, solve_many
    return fleet


def run(smoke: bool = False, b_step: int | None = None) -> dict:
    rows = []
    grid = ([(4, 8, 32)] if smoke else
            [(6, 14, 64), (12, 28, 64), (24, 28, 64), (48, 28, 128)])
    for servers, blocks, B in grid:
        _grid_cell(servers, blocks, B, rows)
    emit("bench_planner", rows,
         ["servers", "layers", "B", "msp_batched_s", "batched_sweeps",
          "msp_scan_s", "scan_sweeps", "bcd_s", "exhaustive_batched_s"])
    acc = acceptance_run(b_step=b_step if b_step is not None
                         else (32 if smoke else 1))
    fleet = fleet_run(smoke=smoke)
    summary = {
        "issue": 9,
        "generated_unix": int(time.time()),
        "smoke": smoke,
        "acceptance": acc,
        "fleet": fleet,
        "grid": [dict(zip(["servers", "layers", "B", "msp_batched_s",
                           "batched_sweeps", "msp_scan_s", "scan_sweeps",
                           "bcd_s", "exhaustive_batched_s"], r))
                 for r in rows],
    }
    if not smoke:                      # the tracked trajectory file
        with open(JSON_PATH, "w") as f:
            json.dump(summary, f, indent=2)
            f.write("\n")
        print(f"# wrote {JSON_PATH}")
    print(json.dumps(acc, indent=2))
    print(json.dumps(fleet, indent=2))
    return summary


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid for CI (no BENCH_planner.json rewrite)")
    ap.add_argument("--b-step", type=int, default=None)
    args = ap.parse_args()
    from repro import obs

    from .common import dump_registry
    obs.enable()
    run(smoke=args.smoke, b_step=args.b_step)
    dump_registry("bench_planner")
