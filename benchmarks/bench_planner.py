"""Planner scaling benchmark (ISSUE 3): nodes x layers x B grid for
``solve_msp`` / ``bcd_solve`` / ``exhaustive_joint``, threshold-batched vs
the legacy scan, with wall-clocks and DP sweep counts.

Outputs:
  results/bench/bench_planner.csv   the full grid
  BENCH_planner.json (repo root)    summary incl. the acceptance instance
                                    (24 servers x 30 layers x B = 64) —
                                    the perf trajectory tracked across PRs

``--smoke`` shrinks the grid for the CI invocation (a few seconds).
"""

from __future__ import annotations

import argparse
import json
import os
import time

from repro.core import (bcd_solve, exhaustive_joint, make_edge_network,
                        solve_msp, transformer_profile)
from .common import Timer, emit

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
JSON_PATH = os.path.join(REPO_ROOT, "BENCH_planner.json")


def bench_instance(servers: int, blocks: int, *, seed: int = 1):
    """A transformer-profile edge instance; total layers I = blocks + 2."""
    prof = transformer_profile(
        f"bench{blocks + 2}", num_layers=blocks, d_model=512, n_heads=8,
        n_kv=8, d_ff=2048, vocab=32000, seq_len=128)
    net = make_edge_network(num_servers=servers, num_clients=4, seed=seed,
                            kappa=1 / 32.0, f_range=(1e12, 10e12),
                            mem_range=(4 * 2**30, 32 * 2**30))
    return prof, net


def acceptance_instance():
    """The ISSUE-3 acceptance point: 24 servers x 30 layers."""
    return bench_instance(24, 28)


def _grid_cell(servers, blocks, B, rows):
    prof, net = bench_instance(servers, blocks)
    b = max(1, B // 8)
    with Timer() as t_bat:
        r_bat = solve_msp(prof, net, b, B, solver="batched")
    with Timer() as t_scan:
        r_scan = solve_msp(prof, net, b, B, solver="scan")
    with Timer() as t_bcd:
        bcd_solve(prof, net, B)
    with Timer() as t_ex:
        exhaustive_joint(prof, net, B, solver="batched")
    rows.append([servers, blocks + 2, B,
                 round(t_bat.seconds, 4), r_bat.thresholds_scanned,
                 round(t_scan.seconds, 4), r_scan.thresholds_scanned,
                 round(t_bcd.seconds, 4), round(t_ex.seconds, 4)])
    return rows


def acceptance_run(b_step: int = 1):
    """exhaustive_joint, batched vs legacy scan, on the acceptance instance."""
    prof, net = acceptance_instance()
    B = 64
    with Timer() as t_bat:
        p_bat = exhaustive_joint(prof, net, B, b_step=b_step, solver="batched")
    with Timer() as t_scan:
        p_scan = exhaustive_joint(prof, net, B, b_step=b_step, solver="scan")
    identical = (p_bat.solution == p_scan.solution and p_bat.b == p_scan.b
                 and p_bat.L_t == p_scan.L_t)
    return {
        "servers": 24, "layers": 30, "B": B, "b_step": b_step,
        "scan_seconds": round(t_scan.seconds, 3),
        "batched_seconds": round(t_bat.seconds, 3),
        "speedup": round(t_scan.seconds / t_bat.seconds, 2),
        "identical_plans": bool(identical),
        "L_t": round(p_bat.L_t, 6), "b": p_bat.b,
    }


def run(smoke: bool = False, b_step: int | None = None) -> dict:
    rows = []
    grid = ([(4, 8, 32)] if smoke else
            [(6, 14, 64), (12, 28, 64), (24, 28, 64), (48, 28, 128)])
    for servers, blocks, B in grid:
        _grid_cell(servers, blocks, B, rows)
    emit("bench_planner", rows,
         ["servers", "layers", "B", "msp_batched_s", "batched_sweeps",
          "msp_scan_s", "scan_sweeps", "bcd_s", "exhaustive_batched_s"])
    acc = acceptance_run(b_step=b_step if b_step is not None
                         else (32 if smoke else 1))
    summary = {
        "issue": 3,
        "generated_unix": int(time.time()),
        "smoke": smoke,
        "acceptance": acc,
        "grid": [dict(zip(["servers", "layers", "B", "msp_batched_s",
                           "batched_sweeps", "msp_scan_s", "scan_sweeps",
                           "bcd_s", "exhaustive_batched_s"], r))
                 for r in rows],
    }
    if not smoke:                      # the tracked trajectory file
        with open(JSON_PATH, "w") as f:
            json.dump(summary, f, indent=2)
            f.write("\n")
        print(f"# wrote {JSON_PATH}")
    print(json.dumps(acc, indent=2))
    return summary


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid for CI (no BENCH_planner.json rewrite)")
    ap.add_argument("--b-step", type=int, default=None)
    args = ap.parse_args()
    from repro import obs

    from .common import dump_registry
    obs.enable()
    run(smoke=args.smoke, b_step=args.b_step)
    dump_registry("bench_planner")
