"""Cost-model benchmark (ISSUE 4): closed-form vs sim-refined BCD.

For a grid of reentrant/memory-starved instances (where Eq. (14) idealizes
away real co-location contention) plus Table-II-style paper instances, run

  * the closed-form BCD (``bcd_solve`` default — Algorithm 2 + Eq. 14
    refinement), and
  * the sim-refined BCD (``cost_model=SimMakespan(policy=MemoryBudgeted)``
    — iterate selection and micro-batch refinement scored by the measured
    makespan under memory-budgeted admission),

then *execute* both plans in the simulator under the same admission policy
and record the L_t delta and the solve-time overhead.

Outputs:
  results/bench/bench_costmodel.csv   the full grid
  BENCH_costmodel.json (repo root)    summary — the perf/quality trajectory
                                      tracked across PRs

``--smoke`` shrinks the grid for the CI invocation (a few seconds).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.core import SimMakespan, bcd_solve, make_edge_network, \
    random_profile
from .common import Timer, emit, paper_network, paper_profile, sim_exec

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
JSON_PATH = os.path.join(REPO_ROOT, "BENCH_costmodel.json")


def reentrant_instance(seed: int, num_layers: int = 14,
                       num_servers: int = 2):
    """Memory-starved 2-server instances whose optimal closed-form plans
    ping-pong submodels across the servers (reentrant/co-located) — same
    generator as tests/test_cost_model.py."""
    rng = np.random.default_rng(seed)
    prof = random_profile(rng, num_layers)
    net = make_edge_network(num_servers=num_servers, num_clients=2,
                            seed=seed, bw_range_hz=(200e6, 400e6),
                            mem_range=(2**26, 2**27), f_range=(1e12, 20e12))
    return prof, net


def _cell(name, prof, net, B, K, rows):
    with Timer() as t_cf:
        cf = bcd_solve(prof, net, B=B, b0=max(1, B // 8), K=K)
    with Timer() as t_sim:
        sim = bcd_solve(prof, net, B=B, b0=max(1, B // 8), K=K,
                        cost_model=SimMakespan())
    s_cf = sim_exec(prof, net, cf, B)
    s_sim = sim_exec(prof, net, sim, B)
    placements = [n for _, _, _, n in cf.solution.segments()]
    reentrant = len(placements) != len(set(placements))
    gain = (1.0 - s_sim / s_cf) if np.isfinite(s_cf) and s_cf > 0 else 0.0
    overhead = t_sim.seconds / max(t_cf.seconds, 1e-9)
    rows.append([name, B, int(reentrant),
                 round(cf.L_t, 6), round(s_cf, 6), round(s_sim, 6),
                 round(gain, 4), cf.b, sim.b,
                 round(t_cf.seconds, 4), round(t_sim.seconds, 4),
                 round(overhead, 2)])
    return rows[-1]


def run(smoke: bool = False) -> dict:
    # warm numpy/kernel caches so the first cell is not charged the import
    # tax (the sim side pays it otherwise and the overhead column skews)
    p0, n0 = reentrant_instance(99)
    bcd_solve(p0, n0, B=16, b0=2, K=5, cost_model=SimMakespan())
    rows: list = []
    reentrant_seeds = (22, 24) if smoke else (22, 23, 24, 27, 37, 38)
    B = 32 if smoke else 64
    for seed in reentrant_seeds:
        prof, net = reentrant_instance(seed)
        _cell(f"reentrant_{seed}", prof, net, B, 7, rows)
    if not smoke:
        prof = paper_profile()
        for n in (4, 6):
            net = paper_network(num_servers=n, seed=1)
            _cell(f"paper_{n}srv", prof, net, 128, None, rows)
    header = ["scenario", "B", "reentrant", "closed_form_L_t",
              "closed_form_sim_L_t", "sim_refined_sim_L_t",
              "sim_refined_gain", "closed_form_b", "sim_refined_b",
              "closed_form_solve_s", "sim_refined_solve_s",
              "solve_overhead_x"]
    emit("bench_costmodel", rows, header)
    gains = [r[6] for r in rows]
    overheads = [r[11] for r in rows]
    summary = {
        "issue": 4,
        "generated_unix": int(time.time()),
        "smoke": smoke,
        "mean_sim_refined_gain": round(float(np.mean(gains)), 4),
        "max_sim_refined_gain": round(float(np.max(gains)), 4),
        "mean_solve_overhead_x": round(float(np.mean(overheads)), 2),
        "grid": [dict(zip(header, r)) for r in rows],
    }
    # the sim-refined plan must never execute slower than the closed form's
    # on the measured metric (its candidate scan subsumes the incumbent)
    assert all(g >= -1e-9 for g in gains), gains
    if not smoke:                       # the tracked trajectory file
        with open(JSON_PATH, "w") as f:
            json.dump(summary, f, indent=2)
            f.write("\n")
        print(f"# wrote {JSON_PATH}")
    print(json.dumps({k: v for k, v in summary.items() if k != "grid"},
                     indent=2))
    return summary


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid for CI (no BENCH_costmodel.json rewrite)")
    args = ap.parse_args()
    from repro import obs

    from .common import dump_registry
    obs.enable()
    run(smoke=args.smoke)
    dump_registry("bench_costmodel")
