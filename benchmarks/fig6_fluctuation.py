"""Fig. 6: robustness to resource fluctuation (CV noise on rates/compute)."""

from __future__ import annotations

from repro.core import evaluate_under_fluctuation, ours
from .common import emit, paper_network, paper_profile


def run(cvs=(0.0, 0.05, 0.1, 0.2, 0.3), seeds=(0, 1)):
    prof = paper_profile()
    rows = []
    for s in seeds:
        net = paper_network(num_servers=6, seed=s)
        plan = ours(prof, net, B=512, b0=20)
        for cv in cvs:
            rep = evaluate_under_fluctuation(prof, net, plan, cv,
                                             draws=32, seed=s)
            rows.append([s, cv, round(rep.planned_latency, 4),
                         round(rep.mean_latency, 4),
                         round(rep.std_latency, 4),
                         round(rep.p95_latency, 4),
                         round(rep.degradation, 4)])
    emit("fig6_fluctuation", rows,
         ["seed", "cv", "planned_s", "mean_s", "std_s", "p95_s",
          "degradation"])
    return rows


if __name__ == "__main__":
    run()
