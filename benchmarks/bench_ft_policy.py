"""Replan-policy benchmark (ISSUE 8): the policy zoo on a fixed-seed flap
corpus + the replanning cadence-vs-drift frontier.

Two sections:

* **Policy zoo** — fuzzed flappy event streams (fixed seeds, 75% of
  rate-changes paired with their reversal) replayed through
  ``simulate_with_replanning`` under every policy via
  ``repro.ft.evaluate_policies``, with real replan costs charged
  (``solve_downtime`` + ``remap_penalty``).  Per policy: makespan
  mean/CVaR, replans issued, events suppressed, downtime, final-plan
  objective, and the dominant blocked resource.  Acceptance (same contract
  as ``tests/test_policy.py::test_corpus_hysteresis_vs_eager_vs_rideout``):
  the debounced+rate-limited Hysteresis issues <= 25% of Eager's replans
  with a mean end-to-end makespan no worse than Eager's and a final
  objective no worse than RideOut's.

* **Cadence-vs-CV frontier** — Gauss-Markov capacity drift at a grid of
  coefficients of variation; a fine stream of ``Resync`` measurement ticks
  (``periodic_resync_triggers``) is filtered by ``Periodic(cadence)``
  swept over a cadence grid.  Small cadences chase drift and pay solve
  downtime per replan; large ones ride out staleness — the frontier the
  ROADMAP's replanning-cadence item asks for.  Acceptance: replans are
  monotone non-increasing from the tightest cadence to the loosest, at
  every cv.

Outputs:
  results/bench/bench_ft_policy_zoo.csv       per-policy corpus summary
  results/bench/bench_ft_policy_frontier.csv  cadence x cv grid
  BENCH_ft.json (repo root)                   summary tracked across PRs

``--smoke`` shrinks both sections for CI but keeps every assertion.
"""

from __future__ import annotations

import argparse
import json
import math
import os
import time

import numpy as np

from repro.ft import (Coordinator, CVaRPreSpill, Hysteresis, Periodic,
                      RateLimited, RideOut, evaluate_policies)
from repro.sim import (fuzz_event_stream, gauss_markov_scenario,
                       periodic_resync_triggers, simulate_plan,
                       simulate_with_replanning)
from repro.sim.validate import random_instance

from .common import Timer, emit

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
JSON_PATH = os.path.join(REPO_ROOT, "BENCH_ft.json")

ALPHA = 0.9
SOLVE_DOWNTIME = 0.05
REMAP_PENALTY = 0.01


def _flap_corpus(net, n_streams: int, *, horizon=4.0, max_events=5):
    """Fixed-seed flappy streams: no failures (the zoo compares voluntary
    replanning), 75% of rate-changes emit their reversal inside the flap
    window — the stream shape debounce exists for."""
    return [fuzz_event_stream(np.random.default_rng(1000 + s), net,
                              horizon=horizon, max_events=max_events,
                              allow_failure=False, flap_fraction=0.75)
            for s in range(n_streams)]


def run_zoo(smoke: bool = False) -> list:
    """Every policy over the same corpus; the Hysteresis-vs-Eager-vs-RideOut
    acceptance contract is asserted on the full corpus too."""
    n_streams = 4 if smoke else 10
    prof, net, _sol, _b, B = random_instance(3)
    streams = _flap_corpus(net, n_streams)
    policies = {
        "eager": lambda: None,
        "ride_out": RideOut,
        "periodic_0.5": lambda: Periodic(0.5),
        "hysteresis": lambda: RateLimited(Hysteresis(0.25, cooldown=0.3)),
        "cvar_pre_spill": lambda: CVaRPreSpill(bound=1.5, n_scenarios=4),
    }
    with Timer() as t:
        reports = evaluate_policies(
            prof, net, B, streams, policies, alpha=ALPHA,
            remap_penalty=REMAP_PENALTY, solve_downtime=SOLVE_DOWNTIME,
            attribution=True)
    rows = []
    for name, r in reports.items():
        top = max(r.blocked.items(), key=lambda kv: kv[1]) \
            if r.blocked else ("", 0.0)
        rows.append([name, round(r.mean, 6), round(r.cvar, 6), r.replans,
                     r.suppressed, round(r.downtime, 4),
                     round(float(np.mean(r.final_objectives)), 6),
                     repr(top[0]), round(top[1], 4)])
    emit("bench_ft_policy_zoo", rows,
         ["policy", "mean_makespan", f"cvar{ALPHA:g}", "replans",
          "suppressed", "downtime_s", "mean_final_objective",
          "top_blocked_resource", "top_blocked_s"])
    print(f"# zoo: {n_streams} streams in {t.seconds:.1f}s")
    eager, ride, hyst = (reports["eager"], reports["ride_out"],
                         reports["hysteresis"])
    assert eager.replans > 0
    assert hyst.replans <= 0.25 * eager.replans, \
        (hyst.replans, eager.replans)
    assert hyst.mean <= eager.mean * (1 + 1e-9), (hyst.mean, eager.mean)
    assert np.mean(hyst.final_objectives) <= \
        np.mean(ride.final_objectives) * (1 + 1e-9)
    # every delivered event is either a replan or a suppression
    assert hyst.replans + hyst.suppressed == eager.replans + eager.suppressed
    return rows


def run_frontier(smoke: bool = False) -> list:
    """Periodic(cadence) x Gauss-Markov cv grid.  Cadences are relative to
    the drift-free makespan so every cell sees multiple measurement ticks
    before the batch drains (a 2s cadence on a 1.6s batch never fires)."""
    prof, net, _sol, _b, B = random_instance(3)
    base = simulate_plan(prof, net,
                         Coordinator(prof, net, B).plan.solution,
                         Coordinator(prof, net, B).plan.b, B=B,
                         engine="auto").L_t
    tick = base / 24.0                     # measurement stream granularity
    cadences = [base / f for f in ((12, 3) if smoke else (12, 6, 3, 1.5))]
    cvs = (0.2, 0.5) if smoke else (0.1, 0.3, 0.5)
    n_draws = 2 if smoke else 4
    rows = []
    for cv in cvs:
        replans_by_cadence = []
        for cadence in cadences:
            makespans, replans, downtime = [], 0, 0.0
            for draw in range(n_draws):
                rng = np.random.default_rng(7_000 + draw)
                scen = gauss_markov_scenario(net, cv, rng, dt=tick,
                                             horizon=4.0 * base)
                trigs = periodic_resync_triggers(net, scen, cadence=tick,
                                                 horizon=2.0 * base)
                coord = Coordinator(prof, net, B, policy=Periodic(cadence))
                rep = simulate_with_replanning(
                    prof, net, B, trigs, coordinator=coord, scenario=scen,
                    remap_penalty=REMAP_PENALTY,
                    solve_downtime=SOLVE_DOWNTIME, engine="auto")
                makespans.append(rep.makespan)
                replans += rep.num_replans
                downtime += rep.downtime
            replans_by_cadence.append(replans)
            rows.append([cv, round(cadence, 4), round(cadence / base, 4),
                         round(float(np.mean(makespans)), 6),
                         round(float(np.max(makespans)), 6),
                         replans, round(downtime, 4)])
        # tighter cadence can never replan *less*: Periodic gates by time
        assert all(a >= b for a, b in
                   zip(replans_by_cadence, replans_by_cadence[1:])), \
            (cv, cadences, replans_by_cadence)
    emit("bench_ft_policy_frontier", rows,
         ["cv", "cadence_s", "cadence_rel", "mean_makespan", "max_makespan",
          "replans", "downtime_s"])
    return rows


def run(smoke: bool = False) -> dict:
    zoo_header = ["policy", "mean_makespan", f"cvar{ALPHA:g}", "replans",
                  "suppressed", "downtime_s", "mean_final_objective",
                  "top_blocked_resource", "top_blocked_s"]
    frontier_header = ["cv", "cadence_s", "cadence_rel", "mean_makespan",
                       "max_makespan", "replans", "downtime_s"]
    zoo = run_zoo(smoke)
    frontier = run_frontier(smoke)
    by_policy = {r[0]: r for r in zoo}
    summary = {
        "issue": 8,
        "generated_unix": int(time.time()),
        "smoke": smoke,
        "alpha": ALPHA,
        "solve_downtime": SOLVE_DOWNTIME,
        "remap_penalty": REMAP_PENALTY,
        "replan_ratio_hysteresis_vs_eager":
            round(by_policy["hysteresis"][3]
                  / max(1, by_policy["eager"][3]), 4),
        "policy_zoo": [dict(zip(zoo_header, r)) for r in zoo],
        "frontier": [dict(zip(frontier_header, r)) for r in frontier],
    }
    if not smoke:                       # the tracked trajectory file
        with open(JSON_PATH, "w") as f:
            json.dump(summary, f, indent=2)
            f.write("\n")
        print(f"# wrote {JSON_PATH}")
    print(json.dumps({k: v for k, v in summary.items()
                      if k not in ("policy_zoo", "frontier")}, indent=2))
    return summary


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small corpus/grid for CI (no BENCH_ft.json "
                         "rewrite)")
    args = ap.parse_args()
    from repro import obs

    from .common import dump_registry
    obs.enable()
    run(smoke=args.smoke)
    dump_registry("bench_ft_policy")
