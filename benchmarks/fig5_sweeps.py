"""Fig. 5: total latency vs (a) #servers, (b) bandwidth, (c) compute,
(d) memory — for ours / RC+OP / RP+OC / no-pipeline.

Every scheme accepts ``cost_model=`` (ISSUE 4): pass
``repro.core.SimMakespan()`` to ``run(cost_model=...)`` to score each
scheme's internal selection by the measured makespan instead of Eq. (14)
— the sim-refined "ours" then rides the same sweep as a comparable curve
(it is also reported standalone in fig7/bench_costmodel)."""

from __future__ import annotations

from repro.core import no_pipeline, ours, rc_op, rp_oc
from .common import emit, paper_network, paper_profile

B = 512
SCHEMES = {"ours": ours, "rc_op": rc_op, "rp_oc": rp_oc,
           "no_pipeline": no_pipeline}


def _latencies(net, prof, solver=None, cost_model=None):
    out = {}
    for name, fn in SCHEMES.items():
        kw = {"seed": 7} if name in ("rc_op", "rp_oc") else {}
        out[name] = fn(prof, net, B=B, solver=solver, cost_model=cost_model,
                       **kw).L_t
    return out


def run(seeds=(0, 1), cost_model=None):
    prof = paper_profile()
    rows = []
    # (a) servers 2..10
    for n in (2, 4, 6, 8, 10):
        for s in seeds:
            la = _latencies(paper_network(num_servers=n, seed=s), prof,
                            cost_model=cost_model)
            rows += [["servers", n, s, k, round(v, 4)]
                     for k, v in la.items()]
    # (b) bandwidth 10..200 MHz
    for bw in (10e6, 50e6, 100e6, 200e6):
        for s in seeds:
            net = paper_network(num_servers=6, seed=s,
                                bw_range_hz=(bw, bw * 1.2))
            la = _latencies(net, prof, cost_model=cost_model)
            rows += [["bandwidth_mhz", bw / 1e6, s, k, round(v, 4)]
                     for k, v in la.items()]
    # (c) compute 2e10..12e10 cycles/s (paper's Fig. 5(c) axis)
    for f in (2e10, 5e10, 8e10, 12e10):
        for s in seeds:
            net = paper_network(num_servers=6, seed=s,
                                f_range=(f, f * 1.2))
            la = _latencies(net, prof, cost_model=cost_model)
            rows += [["compute_flops", f, s, k, round(v, 4)]
                     for k, v in la.items()]
    # (d) memory 2..16 GB
    for gb in (2, 4, 8, 16):
        for s in seeds:
            net = paper_network(num_servers=6, seed=s,
                                mem_range=(gb * 2**30, gb * 2**30))
            la = _latencies(net, prof, cost_model=cost_model)
            rows += [["memory_gb", gb, s, k, round(v, 4)]
                     for k, v in la.items()]
    emit("fig5_sweeps", rows, ["sweep", "value", "seed", "scheme",
                               "latency_s"])
    return rows


if __name__ == "__main__":
    run()
