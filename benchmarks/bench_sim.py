"""Simulator benchmark (ISSUE 5): trace-aware vectorized engine + batched
sim-in-the-loop planning.

Two grids:

* **Engine scaling** — heap vs vectorized wall clock on micro-batch chains,
  constant-capacity *and* Gauss-Markov trace scenarios, both admission
  families.  The acceptance cell is the 10k-micro-batch trace scenario
  (every node/link carries a piecewise-constant trace): the segmented-scan
  vectorized engine must beat the heap engine >= 10x with identical
  completion times.

* **Solve overhead** — the BENCH_costmodel grid (reentrant/memory-starved
  seeds + Table-II paper instances): closed-form vs sim-refined BCD wall
  clock and executed-makespan gain.  Tracks how expensive optimizing the
  *measured* makespan is, both against today's closed form and against the
  frozen PR 4 baselines in BENCH_costmodel.json (whose 6.77x mean overhead
  this ISSUE targets).

Outputs:
  results/bench/bench_sim_engines.csv    engine-scaling grid
  results/bench/bench_sim_overhead.csv   solve-overhead grid
  BENCH_sim.json (repo root)             summary — the perf trajectory
                                         tracked across PRs

``--smoke`` shrinks both grids for the CI invocation (tens of seconds) but
keeps the 10k-micro-batch trace acceptance cell and its >= 10x assertion.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.core import SimMakespan, bcd_solve, make_edge_network, \
    random_profile
from repro.sim import gauss_markov_scenario, simulate_plan

from .common import Timer, emit, paper_network, paper_profile, sim_exec
from .sweep_grid import scale_instance

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
JSON_PATH = os.path.join(REPO_ROOT, "BENCH_sim.json")
COSTMODEL_JSON = os.path.join(REPO_ROOT, "BENCH_costmodel.json")

#: PR 4's recorded mean solve overhead on this grid (BENCH_costmodel.json)
PR4_MEAN_OVERHEAD_X = 6.77


def trace_instance(num_nodes: int = 8, num_microbatches: int = 10_000,
                   *, cv: float = 0.3, seed: int = 0):
    """The engine-scaling chain of ``sweep_grid.scale_instance`` with a
    Gauss-Markov multiplier trace on every node and link — the acceptance
    scenario for the segmented-scan vectorized path."""
    prof, net, sol, b, Q = scale_instance(num_nodes, num_microbatches)
    rng = np.random.default_rng(seed)
    horizon = 4.0 * (num_microbatches / 50.0 + num_nodes)
    scen = gauss_markov_scenario(net, cv, rng, dt=horizon / 256,
                                 horizon=horizon)
    return prof, net, sol, b, Q, scen


def run_engines(smoke: bool = False) -> list:
    """Heap vs vectorized wall clock; identical timelines asserted."""
    rows = []
    cells = [(8, 500), (8, 2_000), (8, 10_000)]
    if smoke:
        cells = [(8, 500), (8, 10_000)]
    for num_nodes, Q in cells:
        prof, net, sol, b, _, scen = trace_instance(num_nodes, Q)
        for pol in ("fifo", "1f1b"):
            with Timer() as t:
                ev = simulate_plan(prof, net, sol, b, num_microbatches=Q,
                                   scenario=scen, policy=pol,
                                   engine="event")
            heap_s = t.seconds
            best = float("inf")
            for _ in range(2):
                with Timer() as t:
                    vec = simulate_plan(prof, net, sol, b,
                                        num_microbatches=Q, scenario=scen,
                                        policy=pol, engine="vectorized")
                best = min(best, t.seconds)
            gap = float(np.max(np.abs(ev.mb_complete - vec.mb_complete)
                               / np.maximum(np.abs(ev.mb_complete), 1e-30)))
            assert gap < 1e-9, (num_nodes, Q, pol, gap)
            rows.append([num_nodes, Q, pol, "gauss_markov",
                         round(heap_s, 4), round(best, 4),
                         round(heap_s / best, 1), f"{gap:.2e}",
                         vec.engine_reason])
    emit("bench_sim_engines", rows,
         ["num_nodes", "num_microbatches", "policy", "scenario", "heap_s",
          "vectorized_s", "speedup_x", "max_rel_gap", "engine_reason"])
    return rows


def reentrant_instance(seed: int, num_layers: int = 14,
                       num_servers: int = 2):
    """Same generator as benchmarks/bench_costmodel.py (the PR 4 grid)."""
    rng = np.random.default_rng(seed)
    prof = random_profile(rng, num_layers)
    net = make_edge_network(num_servers=num_servers, num_clients=2,
                            seed=seed, bw_range_hz=(200e6, 400e6),
                            mem_range=(2**26, 2**27), f_range=(1e12, 20e12))
    return prof, net


def _pr4_baselines() -> dict:
    """Frozen PR 4 per-cell closed-form solve seconds, if recorded."""
    if not os.path.isfile(COSTMODEL_JSON):
        return {}
    with open(COSTMODEL_JSON) as f:
        data = json.load(f)
    return {row["scenario"]: row["closed_form_solve_s"]
            for row in data.get("grid", ())}


def run_overhead(smoke: bool = False) -> list:
    """Closed-form vs sim-refined BCD on the BENCH_costmodel grid."""
    pr4 = _pr4_baselines()
    # warm numpy/caches so the first cell is not charged the import tax
    p0, n0 = reentrant_instance(99)
    bcd_solve(p0, n0, B=32, b0=4, K=5, cost_model=SimMakespan())
    rows = []
    seeds = (22, 24) if smoke else (22, 23, 24, 27, 37, 38)
    B = 32 if smoke else 64
    cells = [(f"reentrant_{s}", *reentrant_instance(s), B, 7)
             for s in seeds]
    if not smoke:
        prof = paper_profile()
        cells += [(f"paper_{n}srv", prof, paper_network(num_servers=n,
                                                        seed=1), 128, None)
                  for n in (4, 6)]
    for name, prof, net, BB, K in cells:
        with Timer() as t_cf:
            cf = bcd_solve(prof, net, B=BB, b0=max(1, BB // 8), K=K)
        with Timer() as t_sim:
            sim = bcd_solve(prof, net, B=BB, b0=max(1, BB // 8), K=K,
                            cost_model=SimMakespan())
        s_cf = sim_exec(prof, net, cf, BB)
        s_sim = sim_exec(prof, net, sim, BB)
        gain = (1.0 - s_sim / s_cf) if np.isfinite(s_cf) and s_cf > 0 \
            else 0.0
        overhead = t_sim.seconds / max(t_cf.seconds, 1e-9)
        vs_pr4 = (t_sim.seconds / pr4[name]) if name in pr4 else float("nan")
        rows.append([name, BB, round(t_cf.seconds, 4),
                     round(t_sim.seconds, 4), round(overhead, 2),
                     round(vs_pr4, 2), round(gain, 4)])
    emit("bench_sim_overhead", rows,
         ["scenario", "B", "closed_form_solve_s", "sim_refined_solve_s",
          "solve_overhead_x", "overhead_vs_pr4_closed_form_x",
          "sim_refined_gain"])
    # the sim-refined plan must never execute slower than the closed form's
    # on the measured metric (its candidate scan subsumes the incumbent)
    assert all(r[6] >= -1e-9 for r in rows), rows
    return rows


def run(smoke: bool = False) -> dict:
    engines = run_engines(smoke)
    overhead = run_overhead(smoke)
    trace_rows = [r for r in engines if r[1] >= 10_000]
    # the segmented-scan acceptance cell (FIFO admission: fully batched
    # column scans); the windowed corner keeps an exact micro-batch-major
    # sweep that is heap-free but scalar along the chain — asserted at a
    # modest bar and reported alongside
    min_speedup = min(r[6] for r in trace_rows if r[2] == "fifo")
    min_windowed = min(r[6] for r in trace_rows if r[2] != "fifo")
    overheads = [r[4] for r in overhead]
    vs_pr4 = [r[5] for r in overhead if np.isfinite(r[5])]
    gains = [r[6] for r in overhead]
    summary = {
        "issue": 5,
        "generated_unix": int(time.time()),
        "smoke": smoke,
        "trace_10k_min_speedup_x": round(min_speedup, 1),
        "trace_10k_windowed_speedup_x": round(min_windowed, 1),
        "mean_solve_overhead_x": round(float(np.mean(overheads)), 2),
        "mean_overhead_vs_pr4_closed_form_x":
            round(float(np.mean(vs_pr4)), 2) if vs_pr4 else None,
        "pr4_mean_solve_overhead_x": PR4_MEAN_OVERHEAD_X,
        "mean_sim_refined_gain": round(float(np.mean(gains)), 4),
        "engines": [dict(zip(["num_nodes", "num_microbatches", "policy",
                              "scenario", "heap_s", "vectorized_s",
                              "speedup_x", "max_rel_gap", "engine_reason"],
                             r)) for r in engines],
        "overhead_grid": [dict(zip(["scenario", "B", "closed_form_solve_s",
                                    "sim_refined_solve_s",
                                    "solve_overhead_x",
                                    "overhead_vs_pr4_closed_form_x",
                                    "sim_refined_gain"], r))
                          for r in overhead],
    }
    # CI smoke assertions: the 10k-micro-batch trace scenario leaves the
    # heap >= 10x behind, and the SimMakespan solve overhead is reduced vs
    # the PR 4 baseline (6.77x mean on this grid)
    assert min_speedup >= 10.0, min_speedup
    assert min_windowed >= 2.0, min_windowed
    assert summary["mean_solve_overhead_x"] < PR4_MEAN_OVERHEAD_X * 0.75, \
        summary["mean_solve_overhead_x"]
    if not smoke:                       # the tracked trajectory file
        with open(JSON_PATH, "w") as f:
            json.dump(summary, f, indent=2)
            f.write("\n")
        print(f"# wrote {JSON_PATH}")
    print(json.dumps({k: v for k, v in summary.items()
                      if k not in ("engines", "overhead_grid")}, indent=2))
    return summary


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small grids for CI (no BENCH_sim.json rewrite)")
    args = ap.parse_args()
    from repro import obs

    from .common import dump_registry
    obs.enable()
    run(smoke=args.smoke)
    dump_registry("bench_sim")
