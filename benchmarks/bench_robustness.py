"""Robustness benchmark (ISSUE 7): the standing fuzz parity campaign + the
CVaR-aware plan-selection comparison.

Two sections:

* **Differential fuzz campaign** — >= 500 seeded scenarios (fixed seed)
  composed from the production failure families (regional degradation,
  flapping links, adversarially-timed bottleneck outages, capacity drift)
  replayed through the heap *and* vectorized engines via the ``engine="auto"``
  dispatch.  Acceptance: makespan parity <= 1e-9 on every vectorized case;
  any breaker is shrunk and written to ``tests/corpus/`` before the assert
  fires, so CI failures arrive pre-minimized.

* **CVaR plan selection** — on each grid instance, a placement-diverse
  candidate pool is selected two ways over the *same* fuzzed scenario
  distribution (targeted at the closed-form pick's bottleneck): argmin of
  the ``ClosedForm`` latency vs argmin of ``RobustMakespan`` (risk_aversion
  = 1, i.e. pure CVaR_0.95).  Acceptance: the robust pick's CVaR_0.95 is
  *strictly* lower than the closed-form pick's on at least one instance —
  tail risk is a real degree of freedom the nominal objective cannot see.

Outputs:
  results/bench/bench_robustness_fuzz.csv   parity-campaign summary
  results/bench/bench_robustness_cvar.csv   per-instance selection grid
  BENCH_robustness.json (repo root)         summary tracked across PRs

``--smoke`` shrinks both sections for the CI invocation (tens of seconds)
but keeps both acceptance assertions.
"""

from __future__ import annotations

import argparse
import json
import math
import os
import time

from repro.core import ClosedForm, bcd_solve, enumerate_solutions
from repro.sim import (FuzzConfig, NetworkScenario, RobustMakespan,
                       run_fuzz, save_case, scenario_distribution,
                       score_plan, shrink_case, simulate_plan)
from repro.sim.fuzz import check_parity
from repro.sim.validate import random_instance

from .common import Timer, emit, paper_network, paper_profile

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
JSON_PATH = os.path.join(REPO_ROOT, "BENCH_robustness.json")
CORPUS_DIR = os.path.join(REPO_ROOT, "tests", "corpus")

ALPHA = 0.95


def run_parity(smoke: bool = False) -> dict:
    """The standing differential campaign; breakers are shrunk + archived."""
    trials = 60 if smoke else 500
    with Timer() as t:
        summary = run_fuzz(trials, seed=0)
    row = [trials, summary.vectorized, summary.event_fallback,
           f"{summary.max_gap:.2e}", len(summary.failures),
           round(t.seconds, 2)]
    emit("bench_robustness_fuzz", [row],
         ["trials", "vectorized", "event_fallback", "max_rel_gap",
          "parity_failures", "wall_s"])
    for case, res in summary.failures:       # pre-minimize before failing
        small = shrink_case(case, lambda c: not check_parity(c).ok)
        path = save_case(small, CORPUS_DIR,
                         name=f"parity_break_{case.seed}",
                         note=f"bench_robustness campaign breaker: {res}")
        print(f"# shrunk parity breaker archived at {path}")
    assert summary.ok and summary.max_gap <= 1e-9, \
        (summary.max_gap, len(summary.failures))
    assert summary.vectorized > 0
    return {"trials": trials, "vectorized": summary.vectorized,
            "event_fallback": summary.event_fallback,
            "max_rel_gap": summary.max_gap, "wall_s": round(t.seconds, 2)}


def _candidate_pool(prof, net, B, b_ref, *, K=3, cap=8):
    """Placement-diverse (sol, b) pool: best closed-form b per distinct
    placement, then the ``cap`` best placements by nominal latency."""
    cm = ClosedForm()
    b_choices = sorted({1, max(1, b_ref // 2), b_ref})
    raw = [(sol, b) for sol in enumerate_solutions(prof, net, K)
           for b in b_choices]
    vals = cm.evaluate_many(prof, net, raw, B)
    best_by_placement: dict = {}
    for (sol, b), v in zip(raw, vals):
        if not math.isfinite(v):
            continue
        cur = best_by_placement.get(sol.placement)
        if cur is None or v < cur[0]:
            best_by_placement[sol.placement] = (v, sol, b)
    ranked = sorted(best_by_placement.values(), key=lambda t: t[0])[:cap]
    return [(sol, b) for _v, sol, b in ranked], [v for v, _s, _b in ranked]


def _grid(smoke: bool):
    seeds = (5, 9) if smoke else (3, 5, 9, 12)
    for seed in seeds:
        prof, net, _sol, b, B = random_instance(seed)
        yield f"random_{seed}", seed, prof, net, b, B
    if not smoke:
        prof = paper_profile()
        net = paper_network(num_servers=4, seed=1)
        plan = bcd_solve(prof, net, B=64)
        yield "paper_4srv", 1, prof, net, max(1, plan.b), 64


def run_cvar(smoke: bool = False) -> list:
    """ClosedForm-selected vs RobustMakespan-selected over a shared
    fuzzed scenario distribution."""
    n_scen = 8 if smoke else 16
    rows = []
    for name, seed, prof, net, b_ref, B in _grid(smoke):
        cands, closed_vals = _candidate_pool(prof, net, B, b_ref)
        if not cands:
            continue
        ci = min(range(len(cands)), key=lambda i: closed_vals[i])
        c_sol, c_b = cands[ci]
        # the shared distribution is targeted at the *closed-form* pick:
        # failure-family fuzz aimed at its bottleneck, plus one crafted
        # outage covering its first hop for a full nominal makespan — the
        # robust selector must route around it, the nominal one cannot see it
        cfg = FuzzConfig(families=("adversarial", "outage", "degradation",
                                   "flapping"))
        scens = list(scenario_distribution(
            net, n_scen, seed=seed, profile=prof, sol=c_sol, b=c_b,
            num_microbatches=max(1, B // c_b), config=cfg))
        width = simulate_plan(prof, net, c_sol, c_b, B=B,
                              engine="auto").L_t
        if len(c_sol.placement) > 1 and math.isfinite(width):
            a, c = c_sol.placement[0], c_sol.placement[1]
            scens.append(NetworkScenario().with_outage(
                a, c, 0.1 * width, 1.1 * width, both_directions=True))
        scens = tuple(scens)
        robust = RobustMakespan(scenarios=scens, alpha=ALPHA,
                                risk_aversion=1.0)
        r_vals = robust.evaluate_many(prof, net, cands, B)
        ri = min(range(len(cands)), key=lambda i: r_vals[i])
        r_sol, r_b = cands[ri]
        c_rep = score_plan(prof, net, c_sol, c_b, B=B, scenarios=scens,
                           alpha=ALPHA)
        r_rep = score_plan(prof, net, r_sol, r_b, B=B, scenarios=scens,
                           alpha=ALPHA, attribution=False)
        gain = 1.0 - r_rep.cvar / c_rep.cvar if c_rep.cvar > 0 else 0.0
        top = c_rep.top_blocked(1)
        rows.append([name, len(cands), c_b, r_b,
                     int(ri != ci), round(c_rep.nominal, 6),
                     round(c_rep.cvar, 6), round(r_rep.cvar, 6),
                     round(gain, 4),
                     repr(top[0][0]) if top else ""])
    emit("bench_robustness_cvar", rows,
         ["scenario", "candidates", "closed_b", "robust_b", "picks_differ",
          "closed_nominal", "closed_cvar95", "robust_cvar95",
          "robust_cvar_gain", "closed_pick_top_blocked"])
    # the robust pick can never be worse on its own objective (argmin over a
    # pool containing the closed pick) and must strictly win somewhere
    assert all(r[7] <= r[6] * (1 + 1e-9) for r in rows), rows
    assert any(r[7] < r[6] * (1 - 1e-9) for r in rows), rows
    return rows


def run(smoke: bool = False) -> dict:
    parity = run_parity(smoke)
    grid = run_cvar(smoke)
    header = ["scenario", "candidates", "closed_b", "robust_b",
              "picks_differ", "closed_nominal", "closed_cvar95",
              "robust_cvar95", "robust_cvar_gain", "closed_pick_top_blocked"]
    wins = sum(1 for r in grid if r[7] < r[6] * (1 - 1e-9))
    summary = {
        "issue": 7,
        "generated_unix": int(time.time()),
        "smoke": smoke,
        "alpha": ALPHA,
        "fuzz": parity,
        "strict_cvar_wins": wins,
        "max_robust_cvar_gain": max(r[8] for r in grid),
        "cvar_grid": [dict(zip(header, r)) for r in grid],
    }
    if not smoke:                       # the tracked trajectory file
        with open(JSON_PATH, "w") as f:
            json.dump(summary, f, indent=2)
            f.write("\n")
        print(f"# wrote {JSON_PATH}")
    print(json.dumps({k: v for k, v in summary.items()
                      if k != "cvar_grid"}, indent=2))
    return summary


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small campaign for CI (no BENCH_robustness.json "
                         "rewrite)")
    args = ap.parse_args()
    from repro import obs

    from .common import dump_registry
    obs.enable()
    run(smoke=args.smoke)
    dump_registry("bench_robustness")
