"""Fig. 1: total latency of pipelined SL vs #servers, and vs no-pipeline.

(a) pipelined SL latency falls as servers are added (1..10);
(b) pipelined vs non-pipelined across bandwidths."""

from __future__ import annotations

import numpy as np

from repro.core import no_pipeline, ours
from .common import emit, paper_network, paper_profile

B = 512


def run(seeds=(0, 1, 2)):
    prof = paper_profile()
    rows = []
    for n in range(2, 11):
        for seed in seeds:
            net = paper_network(num_servers=n, seed=seed)
            p = ours(prof, net, B=B, b0=20)
            np_ = no_pipeline(prof, net, B=B)
            rows.append([n, seed, round(p.L_t, 4), round(np_.L_t, 4),
                         round(np_.L_t / p.L_t, 3), p.b])
    emit("fig1_latency_vs_servers", rows,
         ["num_servers", "seed", "pipelined_s", "no_pipeline_s",
          "speedup", "micro_batch"])
    sp = np.array([r[4] for r in rows], dtype=float)
    print(f"# speedup range {sp.min():.2f}x..{sp.max():.2f}x "
          f"(paper: ~3-7x to reach equal accuracy)")
    return rows


if __name__ == "__main__":
    run()
