"""Pipeline-runtime micro-benchmarks (ours):

  - event-sim vs Eq. (14) across random instances (validation of the
    paper's latency model, incl. the shared-engine pessimism gap);
  - TPU stage-planner outputs for three assigned archs (stage counts,
    micro-batch, bubble fraction) — what core/planner feeds spmd.py.
"""

from __future__ import annotations

import numpy as np

from repro.configs import arch_profile, get_config
from repro.core import SplitSolution, breakdown, num_fills, plan_stages, \
    total_latency
from repro.core import make_edge_network, random_profile
from repro.pipeline import simulate_from_breakdown
from .common import emit


def run():
    rows = []
    rng = np.random.default_rng(0)
    gaps, shared_gaps = [], []
    for seed in range(10):
        prof = random_profile(np.random.default_rng(seed), 6)
        net = make_edge_network(num_servers=3, num_clients=2, seed=seed)
        sol = SplitSolution(cuts=(2, 4, 6), placement=(0, 1, 2))
        b, B = 8, 64
        q = num_fills(B, b) + 1
        bd = breakdown(prof, net, sol, b)
        sim = simulate_from_breakdown(bd, q)
        shared = simulate_from_breakdown(bd, q, shared_engine=True)
        analytic = total_latency(prof, net, sol, b, B)
        gaps.append(abs(sim.makespan - analytic) / analytic)
        shared_gaps.append(shared.makespan / analytic - 1)
    rows.append(["eventsim_vs_eq14_max_relgap", round(max(gaps), 9)])
    rows.append(["shared_engine_extra_latency_mean",
                 round(float(np.mean(shared_gaps)), 4)])

    for arch in ("llama3-8b", "qwen3-0.6b", "jamba-1.5-large-398b"):
        prof = arch_profile(get_config(arch))
        sp = plan_stages(prof, total_chips=256, global_batch=256,
                         stage_candidates=(2, 4, 8, 16))
        rows.append([f"planner_{arch}_stages", sp.num_stages])
        rows.append([f"planner_{arch}_microbatch", sp.microbatch])
        rows.append([f"planner_{arch}_bubble_frac",
                     round(sp.bubble_fraction, 4)])
    emit("pipeline_exec", rows, ["metric", "value"])
    return rows


if __name__ == "__main__":
    run()
