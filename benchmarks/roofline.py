"""Roofline harness: turn results/dryrun/*.json into the §Roofline table."""

from __future__ import annotations

import glob
import os

from repro.launch.roofline import load_records, markdown_table, roofline_row
from .common import RESULTS_DIR, emit

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                          "dryrun")


def run():
    recs = load_records(DRYRUN_DIR)
    if not recs:
        print("# roofline: no dry-run records found — run "
              "`python -m repro.launch.dryrun` first")
        return []
    rows = [roofline_row(r) for r in recs]
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    table = [[r["arch"], r["shape"], r["mesh"], f"{r['compute_s']:.3e}",
              f"{r['memory_s']:.3e}", f"{r['collective_s']:.3e}",
              r["dominant"], f"{r['useful_ratio']:.3f}",
              f"{r['roofline_fraction']:.3f}", f"{r['hbm_gib']:.2f}",
              int(r["fits"])] for r in rows]
    emit("roofline", table,
         ["arch", "shape", "mesh", "compute_s", "memory_s", "collective_s",
          "dominant", "useful_ratio", "roofline_fraction", "hbm_gib",
          "fits"])
    md = markdown_table(rows)
    path = os.path.join(RESULTS_DIR, "roofline.md")
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(path, "w") as f:
        f.write(md + "\n")
    print(f"# markdown table -> {path}")
    return rows


if __name__ == "__main__":
    run()
