"""Fig. 8: total latency across physical topologies (mesh/line/star/tree)
and vs #servers."""

from __future__ import annotations

from repro.core import ours
from .common import emit, paper_network, paper_profile

B = 512
TOPOLOGIES = ("mesh", "line", "star", "tree")


def run(seeds=(0, 1, 2), solver=None):
    prof = paper_profile()
    rows = []
    for topo in TOPOLOGIES:
        for n in (2, 4, 6, 8, 10):
            for s in seeds:
                net = paper_network(num_servers=n, seed=s, topology=topo)
                p = ours(prof, net, B=B, b0=20, solver=solver)
                rows.append([topo, n, s, round(p.L_t, 4), p.b])
    emit("fig8_topologies", rows,
         ["topology", "servers", "seed", "latency_s", "micro_batch"])
    return rows


if __name__ == "__main__":
    run()
