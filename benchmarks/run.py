"""Run every benchmark (one per paper table/figure + ours).

    PYTHONPATH=src python -m benchmarks.run [--quick]
"""

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer seeds / smaller sweeps")
    ap.add_argument("--skip", default="",
                    help="comma-separated module names to skip")
    args = ap.parse_args()
    skip = set(args.skip.split(",")) if args.skip else set()

    from . import (fig1_latency_vs_servers, fig4_accuracy, fig5_sweeps,
                   fig6_fluctuation, fig7_optimality, fig8_topologies,
                   pipeline_exec, roofline)

    jobs = [
        ("fig1_latency_vs_servers",
         lambda: fig1_latency_vs_servers.run(seeds=(0,) if args.quick
                                             else (0, 1, 2))),
        ("fig4_accuracy",
         lambda: fig4_accuracy.run(rounds=3 if args.quick else 10,
                                   batch=16 if args.quick else 32)),
        ("fig5_sweeps",
         lambda: fig5_sweeps.run(seeds=(0,) if args.quick else (0, 1))),
        ("fig6_fluctuation",
         lambda: fig6_fluctuation.run(seeds=(0,) if args.quick
                                      else (0, 1))),
        ("fig7_optimality",
         lambda: fig7_optimality.run(server_counts=(2, 6) if args.quick
                                     else (2, 4, 6, 8, 10))),
        ("fig8_topologies",
         lambda: fig8_topologies.run(seeds=(0,) if args.quick
                                     else (0, 1, 2))),
        ("pipeline_exec", pipeline_exec.run),
        ("roofline", roofline.run),
    ]
    failed = []
    for name, fn in jobs:
        if name in skip:
            print(f"# SKIP {name}")
            continue
        t0 = time.perf_counter()
        try:
            fn()
            print(f"# {name} done in {time.perf_counter() - t0:.1f}s\n")
        except Exception as e:  # keep going; report at the end
            failed.append((name, repr(e)))
            print(f"# {name} FAILED: {e!r}\n")
    if failed:
        print("FAILED:", failed)
        sys.exit(1)
    print("# all benchmarks done")


if __name__ == '__main__':
    main()
