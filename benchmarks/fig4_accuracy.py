"""Fig. 4: test accuracy vs wall-clock for the four schemes.

The update sequence of every scheme is identical (synchronous SGD; the
paper notes "the same converged accuracy") — only the per-round latency
differs, so accuracy-vs-time curves are the SAME accuracy sequence mapped
through each scheme's L_t.  We train the VGG executor once on the synthetic
CIFAR-shaped stream (no CIFAR offline — documented in DESIGN.md) and emit
time-stamped accuracy for each scheme; IID and non-IID client splits both
run (the partition affects the data stream, not the latency model).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import no_pipeline, ours, rc_op, rp_oc
from repro.data import classification_batches
from repro.pipeline import SplitLearningExecutor
from .common import emit, paper_network, paper_profile


def run(rounds: int = 10, batch: int = 32, iid: bool = True):
    prof = paper_profile()
    net = paper_network(num_servers=6, seed=1)
    plans = {
        "ours": ours(prof, net, B=batch, b0=8),
        "rc_op": rc_op(prof, net, B=batch, seed=3),
        "rp_oc": rp_oc(prof, net, B=batch, seed=3),
        "no_pipeline": no_pipeline(prof, net, B=batch),
    }
    # one shared training trajectory (updates are scheme-independent)
    ex = SplitLearningExecutor(plans["ours"], prof, net, seed=0)
    data = classification_batches(batch=batch, seed=0 if iid else 99)
    eval_batch = {k: jnp.asarray(v) for k, v in next(data).items()}
    accs = [ex.evaluate(eval_batch)]
    for _ in range(rounds):
        # lr/momentum retuned for the He-gain VGG init (models/vgg.py)
        ex.train_round({k: jnp.asarray(v) for k, v in next(data).items()},
                       lr=0.02, momentum=0.9)
        accs.append(ex.evaluate(eval_batch))
    rows = []
    for name, plan in plans.items():
        for r, acc in enumerate(accs):
            rows.append([name, r, round(r * plan.L_t, 3), round(acc, 4)])
    emit("fig4_accuracy", rows, ["scheme", "round", "sim_time_s", "accuracy"])
    return rows


if __name__ == "__main__":
    run()
