"""Fig. 7: BCD vs the exhaustive optimum — latency gap + solver runtime.

Reports FOUR solvers: the paper-faithful BCD (Algorithm 2 as printed),
our refined BCD (beyond-paper: exact 1-D re-solve of b under the true
Eq. 14 — see core/bcd.py), the sim-refined BCD (ISSUE 4: iterate selection
and micro-batch refinement scored by the *measured* makespan of
``sim.simulate_plan`` under memory-budgeted admission), and the
exhaustive-over-b oracle.  The measured ~35% paper-BCD gap on sub-second
instances (vs the paper's ~1.5% at its own scales) is a reproduction
finding discussed in EXPERIMENTS.md.

Every scheme's plan is additionally *executed* by the simulator under the
same memory-budgeted policy (the ``*_sim`` columns), so the closed-form
and sim-refined curves are compared on the metric that actually matters.
The per-scenario closed-form-vs-sim-refined deltas and solve-time overhead
are tracked in the repo-root ``BENCH_costmodel.json``
(``benchmarks/bench_costmodel.py`` / ``make bench-costmodel``).
"""

from __future__ import annotations

import math
import time

from repro.core import exhaustive_joint, ours, sim_refined
from repro.core.bcd import bcd_solve
from .common import Timer, emit, paper_network, paper_profile, sim_exec

B = 512


def run(server_counts=(2, 4, 6, 8, 10), seed=1, scan_baseline=True):
    """``scan_baseline`` additionally times the legacy ``solver="scan"``
    exhaustive sweep so Fig. 7(b)'s runtime story covers both planners
    (the ISSUE-3 threshold-batched kernel vs the per-threshold scan)."""
    prof = paper_profile()
    rows = []
    for n in server_counts:
        net = paper_network(num_servers=n, seed=seed)
        with Timer() as t_paper:
            p_paper = bcd_solve(prof, net, B, b0=20, refine_b=False)
        with Timer() as t_ours:
            p_ours = ours(prof, net, B=B, b0=20)
        with Timer() as t_sim:
            p_sim = sim_refined(prof, net, B, b0=20)
        with Timer() as t_opt:
            p_opt = exhaustive_joint(prof, net, B, b_step=4)
        t_scan = float("nan")
        if scan_baseline:
            with Timer() as t:
                p_scan = exhaustive_joint(prof, net, B, b_step=4,
                                          solver="scan")
            assert p_scan.L_t == p_opt.L_t, "scan/batched divergence"
            t_scan = t.seconds
        ours_sim = sim_exec(prof, net, p_ours, B)
        sim_sim = sim_exec(prof, net, p_sim, B)
        rows.append([
            n,
            round(p_paper.L_t, 4), round(t_paper.seconds, 3),
            round(p_ours.L_t, 4), round(t_ours.seconds, 3),
            round(p_sim.L_t, 4), round(t_sim.seconds, 3),
            round(p_opt.L_t, 4), round(t_opt.seconds, 3),
            round(t_scan, 3),
            round(p_paper.L_t / p_opt.L_t - 1, 4),
            round(p_ours.L_t / p_opt.L_t - 1, 4),
            round(ours_sim, 4), round(sim_sim, 4),
            round(1 - sim_sim / ours_sim, 4)
            if math.isfinite(ours_sim) and ours_sim > 0 else 0.0,
        ])
    emit("fig7_optimality", rows,
         ["servers", "bcd_paper_s", "bcd_paper_runtime",
          "bcd_refined_s", "bcd_refined_runtime",
          "bcd_sim_refined_s", "bcd_sim_refined_runtime",
          "optimal_s", "optimal_runtime", "optimal_scan_runtime",
          "paper_gap", "refined_gap",
          "refined_sim_exec_s", "sim_refined_sim_exec_s",
          "sim_refined_gain"])
    return rows


if __name__ == "__main__":
    run()
