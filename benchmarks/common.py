"""Shared benchmark harness: CSV emission + the Table-II simulation setup."""

from __future__ import annotations

import csv
import os
import time

from repro.core import make_edge_network, vgg16_profile

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "bench")


def paper_profile():
    return vgg16_profile(work_units="bytes")


def paper_network(num_servers=6, seed=0, *, bandwidth="low", **kw):
    bw = (10e6, 50e6) if bandwidth == "low" else (100e6, 200e6)
    kw.setdefault("bw_range_hz", bw)
    return make_edge_network(num_servers=num_servers, num_clients=4,
                             seed=seed, kappa=1 / 32.0, **kw)


def emit(name: str, rows: list, header: list):
    """Print `name,us_per_call,derived`-style CSV lines + write the file."""
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.csv")
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)
    print(f"# {name} -> {path}")
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))
    print()


def dump_registry(name: str):
    """Dump the telemetry registry (counters + span summaries) next to the
    CSVs.  No-op (returns None) when ``repro.obs`` is disabled, so drivers
    can call it unconditionally."""
    from repro import obs
    if not obs.enabled():
        return None
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = obs.dump(os.path.join(RESULTS_DIR, f"{name}_counters.json"))
    print(f"# {name} telemetry -> {path}")
    return path


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0


def sim_exec(prof, net, plan, B) -> float:
    """Measured makespan of a plan under memory-budgeted admission — the
    execution metric shared by fig7 and bench_costmodel.  Delegates to
    ``SimMakespan.evaluate``, which guards budget feasibility (returns inf
    instead of letting ``simulate_plan`` raise on unschedulable plans)."""
    from repro.core import SimMakespan
    if not plan.feasible or plan.b <= 0:
        return float("inf")
    return SimMakespan().evaluate(prof, net, plan.solution, plan.b, B)
