"""Adaptive-robustness benchmark (ISSUE 10): the self-tuning cadence vs the
fixed-cadence frontier, tail-sized admission under memory pressure, and the
successive-halving policy tuner vs the hand-picked BENCH_ft point.

Three sections, three headline bars:

* **Adaptive cadence** — ``AdaptiveCadence`` replayed against every fixed
  ``Periodic`` cadence from the BENCH_ft frontier grid, across drift
  regimes it was never tuned for: mean-reverting Gauss-Markov capacity
  noise (cv 0.1/0.3/0.5) *and* secular exponential degradation trends.
  Acceptance: one knob set lands within 10% of the best fixed cadence in
  every regime (it typically beats it — the significance-gated drift
  estimator rides out reverting noise entirely and replans under trends).

* **Tail-sized admission** — memory-starved instances fuzzed with the
  ``mem_pressure`` family; ``DegradedTail`` sizes both the plan (via
  ``SimMakespan(tail=...)``) and the admission windows
  (``MemoryBudgeted(tail=...)``) to the worst sampled capacity.
  Acceptance: on >= 1 instance the nominal-windows plan overflows measured
  occupancy on some scenario while the tail-sized plan binds and stays
  within the degraded budget on *every* scenario.

* **Policy tuner** — ``tune_policies`` successive halving on a tuning
  corpus of flappy streams, winner re-evaluated on a *held-out* corpus
  against the hand-picked ``RateLimited(Hysteresis(0.25, cooldown=0.3))``
  point from BENCH_ft.json.  Acceptance: the tuned policy matches or beats
  it on replans, mean makespan, and CVaR on the held-out corpus.

Outputs:
  results/bench/bench_adaptive_cadence.csv   regime x policy grid
  results/bench/bench_adaptive_tail.csv      per-instance overflow counts
  results/bench/bench_adaptive_tuner.csv     held-out policy comparison
  results/bench/adaptive_counters.json       telemetry registry dump
  BENCH_adaptive.json (repo root)            summary tracked across PRs

``--smoke`` shrinks every section for CI but keeps every assertion.
"""

from __future__ import annotations

import argparse
import json
import math
import os
import time

import numpy as np

from repro.core import SimMakespan, bcd_solve, make_edge_network, \
    random_profile
from repro.core.cost_model import DegradedTail
from repro.ft import Coordinator, Hysteresis, Periodic, RateLimited, \
    evaluate_policies
from repro.ft.adaptive import AdaptiveCadence, default_tuning_grid, \
    tune_policies
from repro.sim import (fuzz_event_stream, gauss_markov_scenario,
                       periodic_resync_triggers, simulate_plan,
                       simulate_with_replanning)
from repro.sim.fuzz import FuzzConfig, fuzz_scenario
from repro.sim.policies import MemoryBudgeted
from repro.sim.robustness import memory_occupancy_overflow
from repro.sim.scenario import NetworkScenario, PiecewiseTrace
from repro.sim.validate import random_instance

from .common import Timer, emit

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
JSON_PATH = os.path.join(REPO_ROOT, "BENCH_adaptive.json")

ALPHA = 0.9
SOLVE_DOWNTIME = 0.05
TUNE_DOWNTIME = 0.15             # tuner corpus: replans must cost enough that
#                                  thrash-vs-wait is a real tradeoff, not noise
REMAP_PENALTY = 0.01
CADENCE_TOL = 1.10               # adaptive within 10% of best fixed cadence


# ---------------------------------------------------------------------------
# Section 1: adaptive cadence vs the fixed-cadence frontier
# ---------------------------------------------------------------------------

def _trend_scenario(net, g, rng, dt, horizon) -> NetworkScenario:
    """Secular degradation: every node's capability declines ``exp(-g_i t)``
    with ``g_i ~ U(g/2, g)`` — the drift regime where replanning pays."""
    times = tuple(np.arange(0.0, horizon, dt))
    node_mult = {}
    for i in range(len(net.nodes)):
        gi = float(rng.uniform(0.5 * g, g))
        node_mult[i] = PiecewiseTrace(
            times, tuple(math.exp(-gi * t) for t in times))
    return NetworkScenario(node_mult=node_mult)


def run_cadence(smoke: bool = False) -> list:
    prof, net, _sol, _b, B = random_instance(3)
    plan = Coordinator(prof, net, B).plan
    base = simulate_plan(prof, net, plan.solution, plan.b, B=B,
                         engine="auto").L_t
    tick = base / 24.0
    cadences = [base / f for f in ((12, 3) if smoke else (12, 6, 3, 1.5))]
    n_draws = 2 if smoke else 4
    regimes = {}
    for cv in ((0.3,) if smoke else (0.1, 0.3, 0.5)):
        regimes[f"gauss_markov_cv{cv:g}"] = (
            lambda rng, cv=cv: gauss_markov_scenario(
                net, cv, rng, dt=tick, horizon=4.0 * base))
    for g in ((0.4,) if smoke else (0.15, 0.4)):
        regimes[f"trend_g{g:g}"] = (
            lambda rng, g=g: _trend_scenario(net, g, rng, tick, 6.0 * base))

    def _run(policy_factory, scen_fn):
        ms, replans = [], 0
        for draw in range(n_draws):
            rng = np.random.default_rng(7_000 + draw)
            scen = scen_fn(rng)
            trigs = periodic_resync_triggers(net, scen, cadence=tick,
                                             horizon=2.0 * base)
            coord = Coordinator(prof, net, B, policy=policy_factory())
            rep = simulate_with_replanning(
                prof, net, B, trigs, coordinator=coord, scenario=scen,
                remap_penalty=REMAP_PENALTY, solve_downtime=SOLVE_DOWNTIME,
                engine="auto")
            ms.append(rep.makespan)
            replans += rep.num_replans
        return float(np.mean(ms)), replans

    rows, ratios = [], {}
    for regime, scen_fn in regimes.items():
        fixed = []
        for cadence in cadences:
            m, r = _run(lambda c=cadence: Periodic(c), scen_fn)
            fixed.append((cadence, m, r))
            rows.append([regime, f"periodic_{cadence:.3f}",
                         round(m, 6), r, ""])
        best_cadence, best_ms, best_r = min(fixed, key=lambda x: x[1])
        m, r = _run(lambda: AdaptiveCadence(solve_cost=SOLVE_DOWNTIME),
                    scen_fn)
        ratio = m / best_ms
        ratios[regime] = round(ratio, 4)
        rows.append([regime, "adaptive", round(m, 6), r, round(ratio, 4)])
        # one knob set must track the per-regime best fixed cadence
        assert ratio <= CADENCE_TOL, \
            (regime, m, best_ms, ratio, best_cadence)
    emit("bench_adaptive_cadence", rows,
         ["regime", "policy", "mean_makespan", "replans",
          "adaptive_vs_best_fixed"])
    return rows, ratios


# ---------------------------------------------------------------------------
# Section 2: tail-sized admission under fuzzed memory pressure
# ---------------------------------------------------------------------------

def _starved_instance(seed: int):
    """Memory-starved 2-server instances (bench_costmodel's generator with
    the budget loosened just enough that a worst-case ``mem_pressure`` draw
    leaves room for a tail-sized plan)."""
    rng = np.random.default_rng(seed)
    prof = random_profile(rng, 14)
    net = make_edge_network(num_servers=2, num_clients=2, seed=seed,
                            bw_range_hz=(200e6, 400e6),
                            mem_range=(192 * 2**20, 2**28),
                            f_range=(1e12, 20e12))
    return prof, net


def _overflow_counts(prof, net, plan, B, policy, scens) -> tuple:
    """(scenarios overflowed, scenarios the windows refused to bind)."""
    n_over = n_fail = 0
    for sc in scens:
        try:
            rep = simulate_plan(prof, net, plan.solution, plan.b, B=B,
                                scenario=sc, policy=policy, engine="event")
            over = memory_occupancy_overflow(prof, net, plan.solution,
                                             plan.b, rep, sc)
        except ValueError:
            n_fail += 1
            continue
        if over:
            n_over += 1
    return n_over, n_fail


def run_tail(smoke: bool = False) -> list:
    B = 32
    n_scens = 8 if smoke else 12
    seeds = (38, 23) if smoke else (38, 23, 22, 24, 27, 37)
    rows = []
    demonstrated = 0
    for seed in seeds:
        prof, net = _starved_instance(seed)
        nom = bcd_solve(prof, net, B=B, b0=4, K=7,
                        cost_model=SimMakespan(policy="memory"))
        if not nom.feasible:
            continue
        cfg = FuzzConfig(families=("mem_pressure",), min_events=1,
                         max_events=2)
        rng = np.random.default_rng(500)
        scens = [fuzz_scenario(rng, net, cfg, profile=prof,
                               sol=nom.solution, b=nom.b)
                 for _ in range(n_scens)]
        # alpha so the tail is the single worst sampled scenario: the
        # windows must survive *everything* the fuzzer drew
        alpha = 1.0 - 1.0 / len(scens) + 1e-9
        tail = DegradedTail.from_scenarios(net, scens, alpha=alpha)
        tp = bcd_solve(prof, net, B=B, b0=4, K=7,
                       cost_model=SimMakespan(policy="memory", tail=tail))
        if not tp.feasible or tp.b < 1:
            rows.append([seed, nom.b, "", n_scens, "", "", "", "",
                         "tail_plan_infeasible"])
            continue
        nom_over, nom_fail = _overflow_counts(prof, net, nom, B,
                                              MemoryBudgeted(), scens)
        tail_over, tail_fail = _overflow_counts(
            prof, net, tp, B, MemoryBudgeted(tail=tail), scens)
        ok = nom_over > 0 and tail_over == 0 and tail_fail == 0
        demonstrated += int(ok)
        rows.append([seed, nom.b, tp.b, n_scens, nom_over, nom_fail,
                     tail_over, tail_fail, "ok" if ok else ""])
    emit("bench_adaptive_tail", rows,
         ["seed", "nominal_b", "tail_b", "n_scenarios",
          "nominal_overflows", "nominal_bind_failures", "tail_overflows",
          "tail_bind_failures", "status"])
    # >= 1 memory-starved instance where nominal windows overflow under
    # pressure and tail-sized windows bind and never overflow
    assert demonstrated >= 1, rows
    return rows, demonstrated


# ---------------------------------------------------------------------------
# Section 3: successive-halving tuner vs the hand-picked BENCH_ft point
# ---------------------------------------------------------------------------

def _flap_corpus(net, seeds):
    return [fuzz_event_stream(np.random.default_rng(s), net, horizon=4.0,
                              max_events=5, allow_failure=False,
                              flap_fraction=0.75)
            for s in seeds]


def run_tuner(smoke: bool = False) -> tuple:
    prof, net, _sol, _b, B = random_instance(3)
    n_tune, n_held = (6, 4) if smoke else (10, 6)
    tune_streams = _flap_corpus(net, range(1_000, 1_000 + n_tune))
    held_streams = _flap_corpus(net, range(2_000, 2_000 + n_held))
    grid = default_tuning_grid(solve_cost=TUNE_DOWNTIME)
    with Timer() as t:
        res = tune_policies(prof, net, B, tune_streams, configs=grid,
                            alpha=ALPHA, min_streams=2,
                            remap_penalty=REMAP_PENALTY,
                            solve_downtime=TUNE_DOWNTIME)
    print(f"# tuner: {len(grid)} configs, {n_tune} streams in "
          f"{t.seconds:.1f}s -> {res.best} {res.knobs}")
    reports = evaluate_policies(
        prof, net, B, held_streams,
        {"tuned": grid[res.best],
         "hand_picked": lambda: RateLimited(Hysteresis(0.25, cooldown=0.3))},
        alpha=ALPHA, remap_penalty=REMAP_PENALTY,
        solve_downtime=TUNE_DOWNTIME)
    tuned, hand = reports["tuned"], reports["hand_picked"]
    rows = [[name, round(r.mean, 6), round(r.cvar, 6), r.replans,
             r.suppressed, round(r.downtime, 4), r.eval_errors]
            for name, r in reports.items()]
    emit("bench_adaptive_tuner", rows,
         ["policy", "mean_makespan", f"cvar{ALPHA:g}", "replans",
          "suppressed", "downtime_s", "eval_errors"])
    # held-out corpus: the tuned knobs match or beat the hand-picked point
    assert tuned.mean <= hand.mean * (1 + 1e-9), (tuned.mean, hand.mean)
    assert tuned.cvar <= hand.cvar * (1 + 1e-9), (tuned.cvar, hand.cvar)
    assert tuned.replans <= hand.replans, (tuned.replans, hand.replans)
    return rows, res


def run(smoke: bool = False) -> dict:
    cadence_rows, ratios = run_cadence(smoke)
    tail_rows, demonstrated = run_tail(smoke)
    tuner_rows, tune_res = run_tuner(smoke)
    by_policy = {r[0]: r for r in tuner_rows}
    summary = {
        "issue": 10,
        "generated_unix": int(time.time()),
        "smoke": smoke,
        "alpha": ALPHA,
        "solve_downtime": SOLVE_DOWNTIME,
        "tune_downtime": TUNE_DOWNTIME,
        "remap_penalty": REMAP_PENALTY,
        "adaptive_vs_best_fixed_by_regime": ratios,
        "adaptive_worst_ratio": max(ratios.values()),
        "tail_instances_demonstrated": demonstrated,
        "tuned_policy": tune_res.best,
        "tuned_knobs": tune_res.knobs,
        "tuned_vs_hand_mean": round(
            by_policy["tuned"][1] / by_policy["hand_picked"][1], 4),
        "tuned_vs_hand_cvar": round(
            by_policy["tuned"][2] / by_policy["hand_picked"][2], 4),
        "tuned_vs_hand_replans": [by_policy["tuned"][3],
                                  by_policy["hand_picked"][3]],
        "tuner_rounds": [list(r) for r in tune_res.rounds],
        "tail": [dict(zip(["seed", "nominal_b", "tail_b", "n_scenarios",
                           "nominal_overflows", "nominal_bind_failures",
                           "tail_overflows", "tail_bind_failures",
                           "status"], r)) for r in tail_rows],
    }
    if not smoke:                       # the tracked trajectory file
        with open(JSON_PATH, "w") as f:
            json.dump(summary, f, indent=2)
            f.write("\n")
        print(f"# wrote {JSON_PATH}")
    print(json.dumps({k: v for k, v in summary.items() if k != "tail"},
                     indent=2))
    return summary


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small grids for CI (no BENCH_adaptive.json "
                         "rewrite)")
    args = ap.parse_args()
    from repro import obs

    from .common import dump_registry
    obs.enable()
    run(smoke=args.smoke)
    dump_registry("adaptive")
