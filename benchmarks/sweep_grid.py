"""Topology x fluctuation x admission-policy sweep grids (vectorized engine).

Two grids, both emitted as CSV under ``results/bench/`` with wall-clock
timings per cell:

* ``run_grid`` — the *scenario* grid: for every (topology, fluctuation CV,
  admission policy) cell, plan the paper's Table-II setup with Algorithm 2,
  then execute the plan in the simulator (``engine="auto"``: the vectorized
  engine on deterministic cells, the heap engine once capacity traces
  actually vary) and record simulated T_f / T_i / L_t plus the wall seconds
  the simulation itself took.  This is the sweep regime of *Communication-
  Computation Pipeline Parallel Split Learning over Wireless Edge Networks*
  (topology x noise) crossed with the memory-aware schedules of
  *Resource-efficient Parallel Split Learning* (FIFO vs 1F1B).

* ``run_scale`` — the *engine-scaling* grid: deterministic chains of
  ``num_nodes`` stages x ``num_microbatches`` identical micro-batches,
  timed under both admission policies.  The 10k-micro-batch x 100-node cell
  is the repo's standing engine-speed budget (< 1 s, asserted loosely in
  ``tests/test_sweep_grid.py``) — roughly 4M task executions, far past
  where the PR 1 heap engine was practical.

Run everything:     python -m benchmarks.sweep_grid
Quick smoke:        python -m benchmarks.sweep_grid --smoke
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.core import (EdgeNetwork, Node, SplitSolution, fill_latency,
                        make_edge_network, ours, pipeline_interval,
                        uniform_profile)
from repro.sim import gauss_markov_scenario, simulate_plan

from .common import Timer, emit, paper_profile

TOPOLOGIES = ("mesh", "line", "star", "tree")
POLICIES = ("fifo", "1f1b")


# ---------------------------------------------------------------------------
# Scenario grid: topology x fluctuation x admission policy
# ---------------------------------------------------------------------------

def run_grid(topologies=TOPOLOGIES, cvs=(0.0, 0.1, 0.3), policies=POLICIES,
             *, B=256, b0=20, num_servers=6, seed=0, corr=0.9):
    prof = paper_profile()
    rows = []
    for topo in topologies:
        net = make_edge_network(num_servers=num_servers, num_clients=4,
                                topology=topo, seed=seed, kappa=1 / 32.0)
        plan = ours(prof, net, B=B, b0=b0)
        if not plan.feasible:
            continue
        for cv in cvs:
            scen = None
            if cv > 0:
                rng = np.random.default_rng(seed)
                scen = gauss_markov_scenario(net, cv, rng, corr=corr,
                                             dt=plan.L_t / 16,
                                             horizon=8 * plan.L_t)
            for pol in policies:
                with Timer() as t:
                    rep = simulate_plan(prof, net, plan.solution, plan.b,
                                        B=plan.B, scenario=scen, policy=pol,
                                        engine="auto")
                rows.append([topo, cv, pol, rep.engine, rep.engine_reason,
                             plan.b, rep.num_microbatches,
                             round(rep.T_f, 5), round(rep.T_i, 5),
                             round(rep.L_t, 5),
                             round(rep.L_t / plan.L_t, 4),
                             round(t.seconds, 5)])
    emit("sweep_grid", rows,
         ["topology", "cv", "policy", "engine", "engine_reason", "b",
          "num_microbatches", "T_f_s", "T_i_s", "L_t_s", "vs_planned",
          "wall_s"])
    # ISSUE 5: the fluctuation (cv > 0) cells must run vectorized now that
    # the batched advancement splits at trace breakpoints — a cell quietly
    # landing back on the heap is a coverage regression
    fluct = [r for r in rows if r[1] > 0]
    assert all(r[3] == "vectorized" for r in fluct), \
        [(r[0], r[1], r[2], r[4]) for r in fluct if r[3] != "vectorized"]
    return rows


# ---------------------------------------------------------------------------
# Engine-scaling grid: deterministic chains, both engines' speed envelope
# ---------------------------------------------------------------------------

def scale_instance(num_nodes: int = 100, num_microbatches: int = 10_000,
                   b: int = 4):
    """A deterministic ``num_nodes``-stage chain, one stage per node —
    the engine-scaling acceptance scenario (identical homogeneous stages,
    fast links, no time variation)."""
    S = num_nodes
    prof = uniform_profile(S, fp=1.0, bp=1.0, act=1.0)
    nodes = [Node("clients", f=100.0, t0=0.0, t1=0.0, b_th=0,
                  is_client=True)]
    nodes += [Node(f"s{i}", f=100.0, t0=0.0, t1=0.0, b_th=0)
              for i in range(1, S)]
    rate = np.full((S, S), 1e4)
    np.fill_diagonal(rate, 0.0)
    net = EdgeNetwork(nodes=nodes, rate=rate, num_clients=1)
    sol = SplitSolution(cuts=tuple(range(1, S + 1)),
                        placement=tuple(range(S)))
    return prof, net, sol, b, num_microbatches


def run_scale(cells=((20, 1_000), (100, 10_000)), policies=POLICIES,
              *, repeats: int = 2):
    rows = []
    for num_nodes, Q in cells:
        prof, net, sol, b, _ = scale_instance(num_nodes, Q)
        n_tasks = Q * (4 * num_nodes - 2)
        for pol in policies:
            best, rep = np.inf, None
            for _ in range(max(repeats, 1)):
                with Timer() as t:
                    rep = simulate_plan(prof, net, sol, b,
                                        num_microbatches=Q, policy=pol,
                                        engine="vectorized")
                best = min(best, t.seconds)
            ana = (fill_latency(prof, net, sol, b)
                   + (Q - 1) * pipeline_interval(prof, net, sol, b))
            rows.append([num_nodes, Q, pol, n_tasks, round(rep.L_t, 4),
                         round(float(ana), 4), round(best, 4),
                         int(n_tasks / best)])
    emit("sweep_grid_scale", rows,
         ["num_nodes", "num_microbatches", "policy", "tasks", "L_t_s",
          "eq14_fifo_s", "wall_s", "tasks_per_s"])
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid for CI smoke testing")
    args = ap.parse_args()
    from repro import obs

    from .common import dump_registry
    obs.enable()
    if args.smoke:
        run_grid(topologies=("mesh",), cvs=(0.0, 0.2), B=64, b0=8)
        run_scale(cells=((10, 200),), repeats=1)
    else:
        run_grid()
        run_scale()
    dump_registry("sweep_grid")
