"""docs/ hygiene: every ``path.py::symbol`` anchor and every relative
markdown link in docs/*.md must resolve against the working tree, so the
paper-to-code map cannot rot silently (ISSUE 2 satellite; also run by the
CI docs job)."""

import os
import re

import pytest

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
DOCS = os.path.join(REPO, "docs")

#: `path/to/file.py::symbol` or bare `path/to/file.py` / `file.md` anchors
ANCHOR = re.compile(
    r"`(?P<path>[\w./-]+\.(?:py|md))(?:::(?P<symbol>[A-Za-z_]\w*))?`")
#: [text](relative-target) markdown links; external schemes are skipped
MDLINK = re.compile(r"\[[^\]]+\]\(([^)#\s]+)\)")


def _doc_files():
    return sorted(f for f in os.listdir(DOCS) if f.endswith(".md"))


def test_docs_tree_exists():
    assert {"paper_map.md", "sim_guide.md"} <= set(_doc_files())


def _symbol_defined(path: str, symbol: str) -> bool:
    with open(path) as f:
        src = f.read()
    pattern = re.compile(
        rf"^\s*(?:def|class)\s+{re.escape(symbol)}\b"
        rf"|^{re.escape(symbol)}\s*(?::[^=\n]+)?=",
        re.MULTILINE)
    return bool(pattern.search(src))


@pytest.mark.parametrize("doc", _doc_files())
def test_code_anchors_resolve(doc):
    text = open(os.path.join(DOCS, doc)).read()
    anchors = list(ANCHOR.finditer(text))
    assert anchors, f"{doc} has no verifiable code anchors"
    missing = []
    for m in anchors:
        path = os.path.join(REPO, m.group("path"))
        if not os.path.isfile(path):
            missing.append(f"{doc}: no such file {m.group('path')}")
            continue
        sym = m.group("symbol")
        if sym and not _symbol_defined(path, sym):
            missing.append(
                f"{doc}: {m.group('path')} does not define {sym!r}")
    assert not missing, "\n".join(missing)


@pytest.mark.parametrize("doc", _doc_files())
def test_markdown_links_resolve(doc):
    text = open(os.path.join(DOCS, doc)).read()
    bad = []
    for target in MDLINK.findall(text):
        if "://" in target or target.startswith("mailto:"):
            continue
        if not os.path.exists(os.path.join(DOCS, target)):
            bad.append(f"{doc}: broken link {target}")
    assert not bad, "\n".join(bad)


def test_readme_links_docs_tree():
    """README's architecture map must point at the docs tree."""
    readme = open(os.path.join(REPO, "README.md")).read()
    assert "docs/paper_map.md" in readme
    assert "docs/sim_guide.md" in readme
