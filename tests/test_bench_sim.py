"""ISSUE 5 acceptance: the trace-aware vectorized engine must beat the heap
engine >= 10x on a trace-scenario chain with identical completion times, and
the sim-in-the-loop (SimMakespan) solve overhead must be well below the
PR 4 baseline recorded in BENCH_costmodel.json (6.77x mean), with the
sim-refined gain intact.  The full grids live in the repo-root
BENCH_sim.json (``make bench-sim``)."""

import json
import os
import time

import numpy as np

from benchmarks.bench_sim import (JSON_PATH, PR4_MEAN_OVERHEAD_X,
                                  reentrant_instance, trace_instance)
from repro.core import SimMakespan, bcd_solve
from repro.sim import simulate_plan


def test_trace_scenario_vectorized_10x_over_heap():
    """A 2k-micro-batch Gauss-Markov chain (the acceptance scenario at
    CI-test size; bench_sim runs the 10k cell): segmented-scan FIFO must
    be >= 10x the heap engine, timelines equal to float noise.  Measured
    ~100x, so timing noise has generous headroom."""
    prof, net, sol, b, Q, scen = trace_instance(8, 2_000)
    t0 = time.perf_counter()
    ev = simulate_plan(prof, net, sol, b, num_microbatches=Q, scenario=scen,
                       engine="event")
    t_heap = time.perf_counter() - t0
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        vec = simulate_plan(prof, net, sol, b, num_microbatches=Q,
                            scenario=scen, engine="vectorized")
        best = min(best, time.perf_counter() - t0)
    assert vec.engine == "vectorized"
    assert "trace" in vec.engine_reason
    gap = np.max(np.abs(ev.mb_complete - vec.mb_complete)
                 / np.maximum(np.abs(ev.mb_complete), 1e-30))
    assert gap < 1e-9
    assert t_heap / best >= 10.0, (t_heap, best)


def test_sim_makespan_overhead_reduced_vs_pr4():
    """One reentrant cell of the BENCH grid: the sim-refined solve must
    cost well under the PR 4 mean overhead (6.77x) relative to today's
    closed form.  Measured ~3-4x, asserted loosely at < 5.5x for CI."""
    prof, net = reentrant_instance(22)
    bcd_solve(prof, net, B=32, b0=4, K=5,
              cost_model=SimMakespan())          # warm caches / numpy
    t0 = time.perf_counter()
    cf = bcd_solve(prof, net, B=32, b0=4, K=5)
    t_cf = time.perf_counter() - t0
    t0 = time.perf_counter()
    sim = bcd_solve(prof, net, B=32, b0=4, K=5, cost_model=SimMakespan())
    t_sim = time.perf_counter() - t0
    assert cf.feasible and sim.feasible
    assert t_sim / t_cf < PR4_MEAN_OVERHEAD_X * 0.8, (t_sim, t_cf)


def test_bench_sim_json_tracks_acceptance():
    """The perf trajectory file exists and records the acceptance bars:
    >= 10x on the 10k-micro-batch trace scenario and a solve overhead
    below 75% of PR 4's 6.77x, with the sim-refined gain preserved."""
    assert os.path.isfile(JSON_PATH), "run `make bench-sim` to record"
    with open(JSON_PATH) as f:
        data = json.load(f)
    assert data["trace_10k_min_speedup_x"] >= 10.0
    assert data["mean_solve_overhead_x"] < PR4_MEAN_OVERHEAD_X * 0.75
    assert data["mean_sim_refined_gain"] >= 0.5   # PR 4 recorded 0.5846
