"""ISSUE 3 acceptance: the threshold-batched planner must beat the legacy
scan by >= 10x on the 24-server x 30-layer x B=64 ``exhaustive_joint``
instance, result-for-result identical, and the wall-clocks must be tracked
in the repo-root BENCH_planner.json."""

import json
import os
import time

import pytest

from benchmarks.bench_planner import JSON_PATH, acceptance_instance
from repro.core import exhaustive_joint, solve_msp

B = 64
B_STEP = 16          # 4 micro-batch sizes: keeps the scan side test-sized
                     # (measured ~47x vs the >= 10x bar, so CI timing noise
                     # has generous headroom)


def test_batched_exhaustive_joint_10x_faster_than_scan():
    prof, net = acceptance_instance()
    assert prof.num_layers == 30 and net.num_servers == 24
    t0 = time.perf_counter()
    p_bat = exhaustive_joint(prof, net, B, b_step=B_STEP, solver="batched")
    t_bat = time.perf_counter() - t0
    t0 = time.perf_counter()
    p_scan = exhaustive_joint(prof, net, B, b_step=B_STEP, solver="scan")
    t_scan = time.perf_counter() - t0
    # result-for-result identical plans ...
    assert p_bat.solution == p_scan.solution
    assert p_bat.b == p_scan.b and p_bat.L_t == p_scan.L_t
    # ... at >= 10x the speed
    assert t_scan / t_bat >= 10.0, (t_scan, t_bat)


def test_batched_solver_does_fewer_sweeps():
    prof, net = acceptance_instance()
    r_scan = solve_msp(prof, net, 8, B, solver="scan")
    r_bat = solve_msp(prof, net, 8, B, solver="batched")
    assert r_bat.thresholds_scanned <= 5
    assert r_scan.thresholds_scanned > r_bat.thresholds_scanned


def test_bench_planner_json_tracks_acceptance():
    """The perf trajectory file exists, and the recorded acceptance run
    meets the >= 10x bar with identical plans."""
    assert os.path.isfile(JSON_PATH), "run `make bench-planner` to record"
    with open(JSON_PATH) as f:
        data = json.load(f)
    acc = data["acceptance"]
    assert (acc["servers"], acc["layers"], acc["B"]) == (24, 30, 64)
    assert acc["identical_plans"] is True
    assert acc["speedup"] >= 10.0


def test_bench_planner_json_tracks_fleet_bars():
    """ISSUE 9 acceptance: the recorded fleet section shows batched-jax
    ``solve_many`` >= 3x numpy on the full 64-size sweep and incremental
    ``Planner.update`` replans >= 5x a cold re-solve, plan-identical."""
    assert os.path.isfile(JSON_PATH), "run `make bench-planner` to record"
    with open(JSON_PATH) as f:
        data = json.load(f)
    fleet = data["fleet"]
    sm = fleet["solve_many"]
    assert (sm["servers"], sm["layers"], sm["B"]) == (24, 30, 64)
    if sm["jax_speedup"] is not None:   # jax was available at record time
        assert sm["num_bs"] == 64
        assert sm["jax_speedup"] >= 3.0
    inc = fleet["incremental"]
    assert inc["identical_plans"] is True
    assert inc["speedup"] >= 5.0
