"""Codec properties: int8 bounds, top-k support, error feedback, byte model."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep: skip module, not error
from hypothesis import given, settings, strategies as st

from repro.compression import (ErrorFeedback, compressed_bytes,
                               int8_dequantize, int8_quantize, topk_densify,
                               topk_sparsify)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 1000), scale=st.floats(0.01, 100.0))
def test_int8_roundtrip_error_bound(seed, scale):
    x = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * scale
    q, s = int8_quantize(x)
    y = int8_dequantize(q, s)
    amax = float(jnp.max(jnp.abs(x)))
    assert float(jnp.max(jnp.abs(y - x))) <= amax / 127.0 + 1e-6


def test_topk_keeps_largest():
    x = jnp.asarray([0.1, -5.0, 0.3, 2.0, -0.2, 4.0])
    vals, idx = topk_sparsify(x, 3)
    dense = topk_densify(vals, idx, x.shape)
    np.testing.assert_allclose(
        np.asarray(dense), [0, -5.0, 0, 2.0, 0, 4.0], atol=1e-7)


def test_error_feedback_accumulates_residual():
    """With EF, the long-run average of decoded outputs tracks the input:
    sum of decoded over rounds -> sum of inputs (residual stays bounded)."""
    ef = ErrorFeedback()
    x = jnp.asarray([0.3, -0.7, 0.05, 0.9])
    fwd = lambda v: topk_sparsify(v, 1)
    bwd = lambda payload: topk_densify(*payload, x.shape)
    total_dec = jnp.zeros_like(x)
    for _ in range(40):
        total_dec = total_dec + ef.compress(x, fwd, bwd)
    avg = total_dec / 40
    np.testing.assert_allclose(np.asarray(avg), np.asarray(x), atol=0.05)


def test_compressed_bytes_model():
    assert compressed_bytes(1000.0, "none") == 1000.0
    assert compressed_bytes(1000.0, "int8") == 250.0
    assert compressed_bytes(1000.0, "topk", topk_ratio=0.05) == 100.0
    with pytest.raises(ValueError):
        compressed_bytes(1.0, "nope")


def test_compression_shifts_planner_bottleneck():
    """Planner integration: compressing links reduces D_k in the latency
    model — total latency with compressed traffic <= uncompressed."""
    import dataclasses
    from repro.core import make_edge_network, vgg16_profile, ours
    prof = vgg16_profile(work_units="bytes")
    comp_prof = dataclasses.replace(
        prof,
        act_bytes=prof.act_bytes / 4.0,     # int8 links
        grad_bytes=prof.grad_bytes / 4.0)
    net = make_edge_network(num_servers=4, seed=2, kappa=1 / 32.0,
                            bw_range_hz=(10e6, 20e6))
    p0 = ours(prof, net, B=256)
    p1 = ours(comp_prof, net, B=256)
    assert p1.L_t <= p0.L_t * (1 + 1e-9)
