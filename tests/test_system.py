"""End-to-end behaviour tests for the paper's system.

Full-loop integration: plan (BCD) -> pipelined SL training on synthetic
CIFAR-shaped data -> loss decreases, and the headline paper claims hold on
the analytical side (pipelined < no-pipeline; BCD near-optimal)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (evaluate_under_fluctuation, make_edge_network,
                        no_pipeline, optimal, ours, vgg16_profile)
from repro.data import classification_batches
from repro.launch.serve import BatchedServer, Request
from repro.launch.train import train
from repro.pipeline import SplitLearningExecutor


@pytest.fixture(scope="module")
def paper_setup():
    prof = vgg16_profile(work_units="bytes")
    net = make_edge_network(num_servers=6, num_clients=4, seed=1,
                            kappa=1 / 32.0)
    return prof, net


def test_paper_headline_pipelining_speedup(paper_setup):
    """Fig. 1(b)/Fig. 4: pipelined SL reaches any accuracy level several
    times faster than no-pipeline (identical per-round updates; only the
    per-round latency differs)."""
    prof, net = paper_setup
    p = ours(prof, net, B=512, b0=20)
    np_plan = no_pipeline(prof, net, B=512)
    speedup = np_plan.L_t / p.L_t
    assert speedup > 1.5
    print(f"pipelining speedup: {speedup:.2f}x")


def test_bcd_vs_optimal_gap_small(paper_setup):
    """Fig. 7(a): suboptimal BCD within a few percent of exhaustive."""
    prof, net = paper_setup
    p = ours(prof, net, B=128, b0=20)
    o = optimal(prof, net, B=128, b_step=1)
    assert p.L_t <= o.L_t * 1.05 + 1e-9, (p.L_t, o.L_t)


def test_fluctuation_robustness(paper_setup):
    """Fig. 6: moderate CV noise degrades latency gracefully (< 2x at
    CV = 0.2)."""
    prof, net = paper_setup
    p = ours(prof, net, B=512, b0=20)
    rep = evaluate_under_fluctuation(prof, net, p, cv=0.2, draws=16)
    assert rep.degradation < 2.0
    rep0 = evaluate_under_fluctuation(prof, net, p, cv=0.01, draws=8)
    assert rep0.degradation == pytest.approx(1.0, abs=0.15)


def test_end_to_end_sl_training_converges(paper_setup):
    """Accuracy rises on the synthetic CIFAR-shaped task within a few
    rounds of pipelined SL execution.

    The former seed-debt flake: the VGG's 1/sqrt(fan_in) init decayed
    activations ~1/sqrt(2) per ReLU layer, so logits sat at ~1e-3 and the
    overfit plateaued at the majority class.  Fixed by the He gain in
    ``models/vgg.py``; the test now uses heavy-ball momentum (tames plain
    SGD's bounce on the norm-free stack), a low-initial-accuracy seed, and
    a best-of-trailing-rounds margin — and asserts the loss drop, the
    actual convergence signal, alongside accuracy.
    """
    prof, net = paper_setup
    plan = ours(prof, net, B=16, b0=4)
    ex = SplitLearningExecutor(plan, prof, net, seed=2)
    batch = {k: jnp.asarray(v)
             for k, v in next(classification_batches(batch=16, seed=0)).items()}
    first_acc = ex.evaluate(batch)
    accs, losses = [], []
    for _ in range(6):                     # single-batch overfit
        losses.append(ex.train_round(batch, lr=0.02, momentum=0.9))
        accs.append(ex.evaluate(batch))
    assert losses[-1] < losses[0] - 0.2, losses
    assert max(accs[-3:]) > max(first_acc, 0.2), (first_acc, accs)


def test_lm_trainer_loss_decreases():
    losses = train("qwen3-0.6b", reduced=True, steps=16, batch=16, seq=32,
                   microbatches=4, lr=2e-3, log_every=100)
    assert np.mean(losses[-4:]) < np.mean(losses[:4])


def test_batched_server_serves():
    srv = BatchedServer("qwen3-0.6b", reduced=True, batch=2, cache_len=48)
    rng = np.random.default_rng(0)
    for rid in range(4):
        srv.submit(Request(rid, rng.integers(0, srv.cfg.vocab, 8,
                                             ).astype(np.int32), max_new=6))
    stats = srv.run()
    assert len(stats["completed"]) == 4
    assert all(len(r.generated) >= 6 for r in stats["completed"])
