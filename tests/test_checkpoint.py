"""Checkpoint/restart: roundtrip, latest-step discovery, async commit,
restore-into-different-sharding (single-device here; multi-device reshard
covered in test_spmd.py's subprocess)."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointStore, estimate_restore_seconds,
                              latest_step, restore_checkpoint,
                              save_checkpoint)


def _tree(seed=0):
    rng = jax.random.PRNGKey(seed)
    return {"layers": {"w": jax.random.normal(rng, (4, 8)),
                       "b": jnp.arange(8, dtype=jnp.float32)},
            "step_scale": jnp.float32(0.5)}


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 7, t, meta={"note": "x"})
    assert latest_step(str(tmp_path)) == 7
    restored, meta = restore_checkpoint(str(tmp_path), 7, jax.eval_shape(
        lambda: t))
    assert meta["step"] == 7 and meta["note"] == "x"
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_gc(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        store.save(s, _tree(s), blocking=False)
    store.wait()
    store._gc()
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [3, 4]
    restored, meta = store.restore_latest(jax.eval_shape(lambda: _tree()))
    assert meta["step"] == 4


def test_shape_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 0, _tree())
    bad = jax.eval_shape(lambda: {"layers": {"w": jnp.zeros((3, 3)),
                                             "b": jnp.zeros((8,))},
                                  "step_scale": jnp.float32(0)})
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), 0, bad)


def test_missing_leaf_raises(tmp_path):
    save_checkpoint(str(tmp_path), 0, {"a": jnp.zeros((2,))})
    with pytest.raises(KeyError):
        restore_checkpoint(str(tmp_path), 0, jax.eval_shape(
            lambda: {"a": jnp.zeros((2,)), "extra": jnp.zeros((1,))}))


def test_meta_records_restore_cost_inputs(tmp_path):
    """Every checkpoint carries the payload size and measured write time
    the restore-cost estimate is priced from."""
    t = _tree()
    save_checkpoint(str(tmp_path), 2, t)
    _restored, meta = restore_checkpoint(str(tmp_path), 2,
                                         jax.eval_shape(lambda: t))
    want_bytes = sum(np.asarray(a).nbytes for a in jax.tree.leaves(t))
    assert meta["bytes"] == want_bytes
    assert meta["write_seconds"] > 0.0


def test_estimate_restore_seconds(tmp_path):
    tree = {"w": jnp.ones((64, 64), jnp.float32)}
    save_checkpoint(str(tmp_path), 5, tree)
    # bandwidth model: bytes / read_bandwidth, exactly
    assert estimate_restore_seconds(str(tmp_path), read_bandwidth=1e6) == \
        pytest.approx(64 * 64 * 4 / 1e6)
    # write-time proxy: positive, and equal to the recorded meta field
    _restored, meta = restore_checkpoint(str(tmp_path), 5,
                                         jax.eval_shape(lambda: tree))
    assert estimate_restore_seconds(str(tmp_path)) == meta["write_seconds"]
    # nothing to restore -> nothing to charge
    assert estimate_restore_seconds(str(tmp_path / "empty")) == 0.0


@pytest.mark.slow
def test_restore_estimate_tracks_measured_wallclock(tmp_path):
    """Cross-check the priced restore cost against a measured
    ``restore_checkpoint`` wall-clock on a multi-MB payload.  Wall-clock
    ratios on shared CI hardware are noisy, so the bound is deliberately
    loose — this guards against the estimate being orders of magnitude off
    (e.g. priced in the wrong unit), not against scheduler jitter."""
    tree = {f"layer{i}": jnp.ones((256, 1024), jnp.float32)
            for i in range(8)}                      # 8 MiB payload
    save_checkpoint(str(tmp_path), 1, tree)
    like = jax.eval_shape(lambda: tree)
    restore_checkpoint(str(tmp_path), 1, like)      # warm the page cache
    t0 = time.perf_counter()
    restore_checkpoint(str(tmp_path), 1, like)
    measured = time.perf_counter() - t0
    est = estimate_restore_seconds(str(tmp_path))
    assert est > 0.0 and measured > 0.0
    assert measured / 100.0 <= est <= measured * 100.0, (est, measured)


def test_trainer_restart_resumes(tmp_path):
    """End-to-end: train 6 steps with checkpoints, kill, resume -> the
    second run continues from the saved step."""
    from repro.launch.train import train
    losses1 = train("qwen3-0.6b", reduced=True, steps=6, batch=8, seq=16,
                    microbatches=2, ckpt_dir=str(tmp_path), ckpt_every=2,
                    log_every=100)
    assert latest_step(str(tmp_path)) == 5
    losses2 = train("qwen3-0.6b", reduced=True, steps=8, batch=8, seq=16,
                    microbatches=2, ckpt_dir=str(tmp_path), ckpt_every=2,
                    log_every=100)
    assert len(losses2) == 2          # only steps 6..7 ran
