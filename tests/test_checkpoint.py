"""Checkpoint/restart: roundtrip, latest-step discovery, async commit,
restore-into-different-sharding (single-device here; multi-device reshard
covered in test_spmd.py's subprocess)."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointStore, latest_step,
                              restore_checkpoint, save_checkpoint)


def _tree(seed=0):
    rng = jax.random.PRNGKey(seed)
    return {"layers": {"w": jax.random.normal(rng, (4, 8)),
                       "b": jnp.arange(8, dtype=jnp.float32)},
            "step_scale": jnp.float32(0.5)}


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 7, t, meta={"note": "x"})
    assert latest_step(str(tmp_path)) == 7
    restored, meta = restore_checkpoint(str(tmp_path), 7, jax.eval_shape(
        lambda: t))
    assert meta["step"] == 7 and meta["note"] == "x"
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_gc(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        store.save(s, _tree(s), blocking=False)
    store.wait()
    store._gc()
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [3, 4]
    restored, meta = store.restore_latest(jax.eval_shape(lambda: _tree()))
    assert meta["step"] == 4


def test_shape_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 0, _tree())
    bad = jax.eval_shape(lambda: {"layers": {"w": jnp.zeros((3, 3)),
                                             "b": jnp.zeros((8,))},
                                  "step_scale": jnp.float32(0)})
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), 0, bad)


def test_missing_leaf_raises(tmp_path):
    save_checkpoint(str(tmp_path), 0, {"a": jnp.zeros((2,))})
    with pytest.raises(KeyError):
        restore_checkpoint(str(tmp_path), 0, jax.eval_shape(
            lambda: {"a": jnp.zeros((2,)), "extra": jnp.zeros((1,))}))


def test_trainer_restart_resumes(tmp_path):
    """End-to-end: train 6 steps with checkpoints, kill, resume -> the
    second run continues from the saved step."""
    from repro.launch.train import train
    losses1 = train("qwen3-0.6b", reduced=True, steps=6, batch=8, seq=16,
                    microbatches=2, ckpt_dir=str(tmp_path), ckpt_every=2,
                    log_every=100)
    assert latest_step(str(tmp_path)) == 5
    losses2 = train("qwen3-0.6b", reduced=True, steps=8, batch=8, seq=16,
                    microbatches=2, ckpt_dir=str(tmp_path), ckpt_every=2,
                    log_every=100)
    assert len(losses2) == 2          # only steps 6..7 ran
