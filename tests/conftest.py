"""Shared fixtures.  NOTE: no XLA_FLAGS here by design — tests see 1 CPU
device; multi-device tests spawn subprocesses (see tests/test_spmd.py)."""

import numpy as np
import pytest

from repro.core import make_edge_network, vgg16_profile, random_profile


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running checks (wall-clock measurements); deselect "
        "with -m 'not slow'")
    config.addinivalue_line(
        "markers",
        "pallas: kernel parity tests; skip (not fail) where the Pallas "
        "lowering toolchain is unavailable")


@pytest.fixture
def vgg_profile():
    return vgg16_profile(work_units="bytes")


@pytest.fixture
def paper_network():
    """Table-II-style 6-server network (kappa = 1/32 to match byte units)."""
    return make_edge_network(num_servers=6, num_clients=4, seed=1,
                             kappa=1 / 32.0)


def small_instance(seed: int, num_layers: int = 6, num_servers: int = 3,
                   num_clients: int = 2):
    rng = np.random.default_rng(seed)
    prof = random_profile(rng, num_layers)
    net = make_edge_network(num_servers=num_servers,
                            num_clients=num_clients, seed=seed)
    return prof, net


def same_msp_result(r1, r2):
    """The scan == batched contract: bit-identical searched result."""
    if r1.feasible != r2.feasible:
        return False
    if not r1.feasible:
        return True
    return (r1.objective == r2.objective and r1.solution == r2.solution
            and r1.T_1 == r2.T_1 and r1.T_f == r2.T_f and r1.b == r2.b)
