"""Incremental warm-started replanning (ISSUE 9 tentpole).

``Planner.update(delta)`` patches the cached graph tensors in place and
scales the warm-start hints; these tests pin the two contracts that make
that safe:

  1. **Bitwise patch equality** — after an update, every cached graph
     array is ``np.array_equal`` to a from-scratch ``GraphFactory``
     assembly on the mutated network (the patch replays the exact float
     op chains of the full build).
  2. **Warm == cold** — the warm-started solve after an update is
     ``same_msp_result``-identical to a cold solve on a fresh Planner
     (the hint window provably contains every global minimizer; see the
     ``_solve_warm`` docstring for the proof sketch).
"""

import dataclasses

import numpy as np
import pytest

from repro import obs
from repro.core import GraphFactory, Planner
from repro.ft import Coordinator, NodeFailure, RateChange, Straggler
from conftest import same_msp_result as _same_result, small_instance

B = 64
SEEDS = [0, 1, 2, 3, 7, 11]


def _warm_planner(prof, net, bs=(4, 12)):
    """A planner with populated graph/DP caches and warm hints."""
    pl = Planner(prof, net)
    for b in bs:
        pl.solve(b, B, solver="batched")
    return pl


def _deltas(net):
    n = len(net.nodes)
    return [RateChange(n_from=1, n_to=2, factor=0.25),
            RateChange(n_from=0, n_to=1, factor=4.0),
            Straggler(node=n - 1, slowdown=3.0),
            Straggler(node=0, slowdown=2.0)]      # client node: src row


# -- contract 1: bitwise patched graphs == fresh assembly ------------------


@pytest.mark.parametrize("seed", SEEDS)
def test_patched_graphs_bitwise_equal_fresh_assembly(seed):
    prof, net = small_instance(seed, num_layers=6, num_servers=3)
    for delta in _deltas(net):
        pl = _warm_planner(prof, net)
        pl.update(delta)
        fresh = GraphFactory(prof, pl.net)
        for b, g in pl._graphs.items():
            want = fresh.graph(b)
            for f in ("comm_cost", "comm_beta", "seg_cost", "seg_beta",
                      "src_cost", "src_beta"):
                assert np.array_equal(getattr(g, f), getattr(want, f)), \
                    (delta, b, f)


# -- contract 2: warm update == cold solve ---------------------------------


@pytest.mark.parametrize("seed", SEEDS)
def test_update_matches_cold_solve(seed):
    prof, net = small_instance(seed, num_layers=6, num_servers=3)
    for delta in _deltas(net) + [NodeFailure(server=1)]:
        pl = _warm_planner(prof, net)
        pl.update(delta)
        for b in (4, 12):
            warm = pl.solve(b, B, solver="batched")
            cold = Planner(prof, pl.net).solve(b, B, solver="batched")
            assert _same_result(warm, cold), (delta, b, warm, cold)


@pytest.mark.parametrize("seed", [0, 4, 9])
def test_update_sequence_matches_cold_solve(seed):
    """Compounded deltas: each update scales the surviving hints' lower
    bounds by that delta's r_min, so the warm window stays valid across
    an arbitrary update sequence."""
    prof, net = small_instance(seed, num_layers=6, num_servers=3)
    pl = _warm_planner(prof, net)
    for delta in _deltas(net):
        pl.update(delta)
        warm = pl.solve(4, B, solver="batched")
        cold = Planner(prof, pl.net).solve(4, B, solver="batched")
        assert _same_result(warm, cold), (delta, warm, cold)


def test_node_failure_renumbers_and_matches_cold():
    """NodeFailure is a rebuild: server removal renumbers every node
    after it, so patching is unsound — update() must swap in a degraded
    network and still agree with a cold solve on it."""
    prof, net = small_instance(3, num_layers=6, num_servers=4)
    pl = _warm_planner(prof, net)
    n_before = len(pl.net.nodes)
    pl.update(NodeFailure(server=2))
    assert len(pl.net.nodes) == n_before - 1
    r = pl.solve(4, B, solver="batched")
    cold = Planner(prof, pl.net).solve(4, B, solver="batched")
    assert _same_result(r, cold)
    if r.feasible:
        assert all(p < len(pl.net.nodes) for p in r.solution.placement)


def test_update_rejects_nothing_quietly():
    """An unknown delta type raises instead of silently no-oping."""
    prof, net = small_instance(0)
    pl = Planner(prof, net)
    with pytest.raises(TypeError):
        pl.update(object())


# -- counters ---------------------------------------------------------------


def test_incremental_hit_and_cold_counters():
    prof, net = small_instance(1, num_layers=6, num_servers=3)
    pl = _warm_planner(prof, net, bs=(4,))
    obs.reset()
    with obs.enabled_scope():
        pl.update(RateChange(n_from=1, n_to=2, factor=0.5))
        pl.solve(4, B, solver="batched")         # warm: hint survives
        pl.solve(12, B, solver="batched")        # cold: no hint for b=12
    assert obs.counter("planner.incremental_hits") == 1
    assert obs.counter("planner.cold_solves") == 1
    assert obs.counter("planner.updates[rate]") == 1
    obs.reset()


def test_warm_solve_scans_fewer_thresholds():
    prof, net = small_instance(2, num_layers=6, num_servers=3)
    pl = _warm_planner(prof, net, bs=(8,))
    cold = pl.solve(8, B, solver="batched")      # memoized pre-update
    pl.update(Straggler(node=1, slowdown=1.5))
    warm = pl.solve(8, B, solver="batched")
    if warm.feasible and cold.feasible:
        assert warm.thresholds_scanned <= cold.thresholds_scanned


# -- coordinator integration ----------------------------------------------


def _coord(seed=5):
    prof, net = small_instance(seed, num_layers=6, num_servers=4)
    return Coordinator(prof, net, B=128), prof


@pytest.mark.parametrize("event", [
    RateChange(n_from=1, n_to=2, factor=0.2),
    Straggler(node=1, slowdown=2.0),
    NodeFailure(server=1),
])
def test_coordinator_apply_routes_through_planner_update(event):
    """apply() now mutates the network through the shared planner;
    the resulting plan must match a coordinator built from scratch on
    the mutated network (same BCD search, warm caches)."""
    c, prof = _coord()
    c.apply(event)
    assert c.net is c.planner.net
    fresh = Coordinator(prof, c.net, B=128)
    assert c.plan.feasible == fresh.plan.feasible
    if c.plan.feasible:
        assert c.plan.L_t == pytest.approx(fresh.plan.L_t, rel=1e-9)


def test_coordinator_absorb_keeps_planner_in_sync():
    c, prof = _coord(6)
    node = c.plan.solution.placement[-1]
    c.absorb(Straggler(node=node, slowdown=1.2))
    assert c.net is c.planner.net
    # a later replan reuses the patched planner and stays consistent
    c.apply(RateChange(n_from=1, n_to=2, factor=0.5))
    assert c.net is c.planner.net
    assert c.plan.feasible


def test_preview_cached_memoizes_per_event():
    c, _ = _coord(7)
    ev = RateChange(n_from=1, n_to=2, factor=0.5)
    obs.reset()
    with obs.enabled_scope():
        net1, sol1, pl1 = c.preview_cached(c.plan.solution, ev)
        net2, sol2, pl2 = c.preview_cached(c.plan.solution, ev)
    assert net1 is net2 and pl1 is pl2
    assert obs.counter("ft.preview_planner_hit") >= 1
    assert sol1 == sol2 == c.plan.solution
    # coordinator state untouched by previews
    assert c.net is c.planner.net and c.planner is not pl1
    obs.reset()


def test_preview_cache_invalidated_by_mutation():
    c, _ = _coord(8)
    ev = Straggler(node=1, slowdown=2.0)
    _, _, pl1 = c.preview_cached(c.plan.solution, ev)
    c.apply(RateChange(n_from=1, n_to=2, factor=0.5))   # mutates c.net
    _, _, pl2 = c.preview_cached(c.plan.solution, ev)
    assert pl1 is not pl2            # old preview was for the old net
