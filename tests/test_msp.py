"""Algorithm 1 (bottleneck-aware shortest path) — optimality + properties."""

import math

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep: skip module, not error
from hypothesis import given, settings, strategies as st

from repro.core import (Planner, brute_force_msp, build_graph, graph_stats,
                        make_edge_network, random_profile, solve_msp,
                        total_latency, validate_solution)
from repro.core.shortest_path import path_cost, _path_bottleneck
from conftest import same_msp_result as _same_result, small_instance


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 200), b=st.sampled_from([4, 8, 16]),
       B=st.sampled_from([32, 64]))
def test_alg1_matches_brute_force_paper_objective(seed, b, B):
    """Theorem 2: Algorithm 1 is optimal for the MSP objective."""
    prof, net = small_instance(seed, num_layers=5, num_servers=3)
    res = solve_msp(prof, net, b, B, K=3)
    bf, bf_sol = brute_force_msp(prof, net, b, B, K=3, objective="paper")
    if not res.feasible:
        assert bf == math.inf
    else:
        assert res.objective == pytest.approx(bf, rel=1e-9)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 100))
def test_alg1_solution_is_valid(seed):
    prof, net = small_instance(seed, num_layers=6, num_servers=4)
    res = solve_msp(prof, net, 8, 64, K=4)
    if res.feasible:
        validate_solution(res.solution, prof, net)
        # reported L_t is the true Eq.14 value of the returned solution
        assert res.L_t == pytest.approx(
            total_latency(prof, net, res.solution, 8, 64), rel=1e-9)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 100))
def test_paper_gap_to_true_objective_is_bounded(seed):
    """Paper-mode search vs the TRUE objective (co-location sums, joint
    memory): the found solution evaluates within 25% of the true optimum on
    small instances (usually exact; DESIGN.md §6 discusses why not always)."""
    prof, net = small_instance(seed, num_layers=5, num_servers=3)
    res = solve_msp(prof, net, 8, 64, K=3)
    bf, bf_sol = brute_force_msp(prof, net, 8, 64, K=3, objective="true")
    if res.feasible and bf_sol is not None:
        assert res.L_t <= bf * 1.25 + 1e-9


def test_path_cost_equals_fill_latency(vgg_profile, paper_network):
    from repro.core import fill_latency
    res = solve_msp(vgg_profile, paper_network, 16, 512)
    g = build_graph(vgg_profile, paper_network, 16)
    path = list(zip(res.solution.placement, res.solution.cuts))
    assert path_cost(g, path) == pytest.approx(
        fill_latency(vgg_profile, paper_network, res.solution, 16), rel=1e-9)


def test_restricted_cuts_respected(vgg_profile, paper_network):
    cuts = (4, 10, 16)
    res = solve_msp(vgg_profile, paper_network, 16, 512,
                    restrict_cuts=cuts, K=len(cuts))
    assert res.feasible
    assert res.solution.cuts == cuts


def test_restricted_placement_respected(vgg_profile, paper_network):
    placement = (0, 2, 1)
    res = solve_msp(vgg_profile, paper_network, 16, 512,
                    restrict_placement=placement, K=3)
    if res.feasible:
        assert tuple(res.solution.placement) == \
            placement[:len(res.solution.placement)]


def test_no_pipeline_solves_pure_min_sum(vgg_profile):
    """b = B => xi = 0: Algorithm 1 degenerates to plain shortest path.
    (Needs roomy nodes: the paper's Eq. 11 scales the WHOLE footprint by b,
    so b = 512 on 2-16 GB nodes is memory-infeasible — that infeasibility
    is itself one of the paper's arguments for micro-batching.)"""
    net = make_edge_network(num_servers=6, num_clients=4, seed=1,
                            kappa=1 / 32.0, mem_range=(1e15, 1e15),
                            client_mem=1e15)
    res = solve_msp(vgg_profile, net, 512, 512)
    assert res.feasible
    assert res.thresholds_scanned == 1
    # objective must equal T_f exactly (no bottleneck contribution)
    assert res.objective == pytest.approx(res.T_f, rel=1e-9)


def test_bottleneck_consistency(vgg_profile, paper_network):
    res = solve_msp(vgg_profile, paper_network, 16, 512)
    g = build_graph(vgg_profile, paper_network, 16)
    path = list(zip(res.solution.placement, res.solution.cuts))
    assert _path_bottleneck(g, path) == pytest.approx(res.T_1, rel=1e-9)


def test_graph_stats_reports_paper_scale(vgg_profile, paper_network):
    g = build_graph(vgg_profile, paper_network, 16)
    s = graph_stats(g)
    assert s["paper_vertices"] > 0
    assert s["paper_edges_upper"] > 0


# ---------------------------------------------------------------------------
# ISSUE 3: threshold-batched solver — standing randomized cross-check
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 400), b=st.sampled_from([1, 4, 8, 16, 64]),
       B=st.sampled_from([32, 64]),
       mem_scale=st.sampled_from([1.0, 1.0, 1e-3, 1e-9]),
       restrict=st.sampled_from(["free", "cuts", "placement"]))
def test_batched_equals_scan_equals_brute_force(seed, b, B, mem_scale, restrict):
    """solver='batched' returns bit-identical (objective, cuts, placement,
    T_1) results to the legacy solver='scan', and both match brute force —
    across free/restricted solves, memory-tight (infeasible / client-only)
    instances and micro-batch sizes (incl. b >= B, i.e. xi = 0)."""
    rng = np.random.default_rng(seed)
    prof = random_profile(rng, 5)
    net = make_edge_network(
        num_servers=3, num_clients=2, seed=seed,
        mem_range=(mem_scale * 2 * 2**30, mem_scale * 16 * 2**30),
        client_mem=4 * 2**30)   # roomy client: tight servers -> client-only
    kw = {"K": 3}
    if restrict == "cuts":
        cuts = tuple(sorted(rng.choice(np.arange(1, 5), 2, replace=False)))
        kw["restrict_cuts"] = cuts + (5,)
    elif restrict == "placement":
        kw["restrict_placement"] = (0,) + tuple(
            int(x) for x in rng.permutation(list(net.server_indices()))[:2])
    b = min(b, B)
    r_scan = solve_msp(prof, net, b, B, solver="scan", **kw)
    r_bat = solve_msp(prof, net, b, B, solver="batched", **kw)
    assert _same_result(r_scan, r_bat), (r_scan, r_bat)
    if restrict == "free":
        bf, _ = brute_force_msp(prof, net, b, B, K=3, objective="paper")
        if r_scan.feasible:
            assert r_scan.objective == pytest.approx(bf, rel=1e-9)
        else:
            assert bf == math.inf


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 60))
def test_solve_many_matches_per_b_solve(seed):
    """Planner.solve_many (the stacked b-sweep under exhaustive_joint) is
    bit-identical to independent per-b batched solves."""
    prof, net = small_instance(seed, num_layers=5, num_servers=3)
    pl = Planner(prof, net)
    B = 32
    bs = list(range(1, B + 1, 3))
    for b, many in zip(bs, pl.solve_many(bs, B)):
        solo = pl.solve(b, B, solver="batched")
        assert _same_result(many, solo), (b, many, solo)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 60), b=st.sampled_from([4, 8, 16]))
def test_numpy_vs_jax_randomized_cross_check(seed, b):
    """Standing randomized parity gate: the jitted JAX planner pipeline
    (on-the-fly graph assembly + scanned min-plus sweeps) against the
    numpy batched solver.  Bit-exact under x64; objective within
    ``parity_tolerance()`` and identical feasibility/solution under the
    default float32 config (see planner_jax module docstring)."""
    pytest.importorskip("jax")
    from repro.core import planner_jax
    if not planner_jax.available():
        pytest.skip("jax backend unavailable")
    prof, net = small_instance(seed, num_layers=5, num_servers=3)
    pl = Planner(prof, net)
    B = 32
    r_np = pl.solve(b, B, solver="batched")
    r_jx = Planner(prof, net).solve(b, B, solver="batched", backend="jax")
    rtol = planner_jax.parity_tolerance()
    if rtol == 0.0:
        assert _same_result(r_np, r_jx), (r_np, r_jx)
    else:
        assert r_np.feasible == r_jx.feasible
        if r_np.feasible:
            assert r_jx.objective == pytest.approx(r_np.objective, rel=rtol)
            assert r_jx.b == r_np.b
    # full batched dispatch (solve_many) through the same gate
    bs = [max(1, b - 2), b]
    many_np = pl.solve_many(bs, B)
    many_jx = Planner(prof, net).solve_many(bs, B, backend="jax")
    for m_np, m_jx in zip(many_np, many_jx):
        assert m_np.feasible == m_jx.feasible
        if m_np.feasible:
            if rtol == 0.0:
                assert _same_result(m_np, m_jx), (m_np, m_jx)
            else:
                assert m_jx.objective == pytest.approx(m_np.objective,
                                                       rel=rtol)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 50))
def test_more_servers_never_hurt(seed):
    """Fig. 5(a): latency is non-increasing in N (the planner can ignore
    extra servers)."""
    rng = np.random.default_rng(seed)
    prof = random_profile(rng, 6)
    net_small = make_edge_network(num_servers=3, seed=seed)
    net_big = make_edge_network(num_servers=3, seed=seed)  # same base
    r1 = solve_msp(prof, net_small, 8, 64)
    r2 = solve_msp(prof, net_big, 8, 64)
    assert r2.objective <= r1.objective * (1 + 1e-9)
