"""Theorem 1 (closed-form micro-batch) vs the exhaustive oracle."""

import math

import pytest

pytest.importorskip("hypothesis")  # optional dev dep: skip module, not error
from hypothesis import given, settings, strategies as st

from repro.core import (exhaustive_microbatch, feasibility_box,
                        optimal_microbatch, pipeline_interval, solve_msp,
                        memory_feasible)
from conftest import small_instance


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 200), B=st.sampled_from([64, 128, 256]))
def test_closed_form_matches_oracle(seed, B):
    """The Theorem-1 candidate set must attain the oracle objective within
    2% (the closed form relaxes the ceil; floor/ceil + box-corner candidates
    recover it in practice — exact-match rate asserted separately)."""
    prof, net = small_instance(seed, num_layers=6, num_servers=3)
    msp = solve_msp(prof, net, 16, B, K=3)
    if not msp.feasible:
        return
    mb = optimal_microbatch(prof, net, msp.solution, B, msp.T_1)
    ob, ov = exhaustive_microbatch(prof, net, msp.solution, B, msp.T_1)
    if mb.b == 0:
        assert ob == 0
        return
    assert mb.objective <= ov * 1.02 + 1e-12


def test_exact_match_rate():
    """On 30 random instances the closed form matches the oracle exactly in
    >= 80% of cases (ties in objective count as matches)."""
    hits = total = 0
    for seed in range(30):
        prof, net = small_instance(seed, num_layers=6, num_servers=3)
        msp = solve_msp(prof, net, 16, 128, K=3)
        if not msp.feasible:
            continue
        mb = optimal_microbatch(prof, net, msp.solution, 128, msp.T_1)
        ob, ov = exhaustive_microbatch(prof, net, msp.solution, 128,
                                       msp.T_1)
        if mb.b == 0:
            continue
        total += 1
        if mb.objective == pytest.approx(ov, rel=1e-9):
            hits += 1
    assert total > 10
    assert hits / total >= 0.8, (hits, total)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 100))
def test_feasibility_box_is_tight(seed):
    """b_v is the LARGEST feasible b: b_v feasible, b_v + 1 not."""
    prof, net = small_instance(seed, num_layers=6, num_servers=3)
    msp = solve_msp(prof, net, 16, 128, K=3)
    if not msp.feasible:
        return
    bv = feasibility_box(prof, net, msp.solution, 128, msp.T_1)
    if bv == 0:
        return
    tol = 1 + 1e-9
    assert memory_feasible(prof, net, msp.solution, bv)
    assert pipeline_interval(prof, net, msp.solution, bv) <= msp.T_1 * tol
    if bv < 128:
        over = (not memory_feasible(prof, net, msp.solution, bv + 1)) or \
            pipeline_interval(prof, net, msp.solution, bv + 1) > \
            msp.T_1 * tol
        assert over


def test_infeasible_returns_zero(vgg_profile, paper_network):
    import dataclasses
    # shrink all memories so nothing fits
    tiny = dataclasses.replace(
        paper_network,
        nodes=[dataclasses.replace(n, mem=1.0) for n in paper_network.nodes])
    from repro.core import SplitSolution
    sol = SplitSolution(cuts=(8, 16), placement=(0, 1))
    mb = optimal_microbatch(vgg_profile, tiny, sol, 512, T_1=1.0)
    assert mb.b == 0 and mb.objective == math.inf
