"""Replan-policy layer: debounce/hysteresis semantics, token-bucket
rate-limiting with backoff, Resync snapshots, policy-mediated simulation
accounting, and the corpus-level Eager/RideOut/Hysteresis guarantees."""

import math

import numpy as np
import pytest

from repro import obs
from repro.ft import (Coordinator, Eager, RideOut, Periodic, Hysteresis,
                      RateLimited, CVaRPreSpill, NodeFailure, RateChange,
                      Resync, Straggler, PolicyDecision, ReplanPolicy,
                      resolve_replan_policy, event_deviation,
                      evaluate_policies)
from repro.sim import (fuzz_event_stream, simulate_with_replanning,
                       sampled_network, periodic_resync_triggers,
                       gauss_markov_scenario, ReplanTrigger)
from repro.sim.validate import random_instance
from conftest import small_instance


@pytest.fixture
def inst():
    prof, net = small_instance(5, num_layers=6, num_servers=4)
    return prof, net


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------

def test_event_deviation_signs():
    key, d = event_deviation(RateChange(0, 2, 0.5))
    assert key == ("link", 0, 2) and d == pytest.approx(math.log(0.5))
    key, d = event_deviation(Straggler(1, 2.0))
    assert key == ("node", 1) and d == pytest.approx(-math.log(2.0))
    # a flap's two edges cancel exactly
    assert event_deviation(RateChange(0, 2, 0.5))[1] + \
        event_deviation(RateChange(0, 2, 2.0))[1] == pytest.approx(0.0)
    assert event_deviation(NodeFailure(1))[1] == -math.inf


def test_resolve_replan_policy():
    assert resolve_replan_policy(None) is None
    assert isinstance(resolve_replan_policy("eager"), Eager)
    assert isinstance(resolve_replan_policy("ride_out"), RideOut)
    assert isinstance(resolve_replan_policy("hysteresis"), Hysteresis)
    p = Periodic(2.0)
    assert resolve_replan_policy(p) is p
    with pytest.raises(ValueError):
        resolve_replan_policy("nope")
    with pytest.raises(TypeError):
        resolve_replan_policy(42)


def test_policy_constructor_validation():
    with pytest.raises(ValueError):
        Hysteresis(threshold=0.0)
    with pytest.raises(ValueError):
        Hysteresis(cooldown=-1.0)
    with pytest.raises(ValueError):
        Periodic(-1.0)
    with pytest.raises(ValueError):
        RateLimited(Eager(), capacity=0.5)


# ---------------------------------------------------------------------------
# Hysteresis: debounce, persistence, reversal, failure reset
# ---------------------------------------------------------------------------

def test_hysteresis_absorbs_below_threshold(inst):
    prof, net = inst
    c = Coordinator(prof, net, B=128, policy=Hysteresis(threshold=0.25))
    out = c.deliver(RateChange(1, 2, 0.9), sim_time=0.0)   # |ln 0.9| ~ 0.105
    assert out.action == "absorb"
    assert not out.decision.replan


def test_hysteresis_replans_past_threshold(inst):
    prof, net = inst
    c = Coordinator(prof, net, B=128,
                    policy=Hysteresis(threshold=0.25, cooldown=0.0))
    out = c.deliver(RateChange(1, 2, 0.4), sim_time=0.0)   # |ln 0.4| ~ 0.92
    assert out.action in ("replan", "microbatch")
    assert out.decision.replan


def test_hysteresis_accumulates_small_deviations(inst):
    """Three sub-threshold drops on the SAME link accumulate past the
    threshold — debounce is cumulative, not per-event."""
    prof, net = inst
    c = Coordinator(prof, net, B=128,
                    policy=Hysteresis(threshold=0.25, cooldown=0.0))
    acts = [c.deliver(RateChange(1, 2, 0.9), sim_time=float(t)).action
            for t in range(3)]                            # 3 x 0.105 > 0.25
    assert acts[0] == "absorb" and acts[1] == "absorb"
    assert acts[2] in ("replan", "microbatch")


def test_hysteresis_reversal_cancels_pending(inst):
    """A flap: the down edge arms a pending replan, the up edge restores
    the cumulative deviation to ~0 and CANCELS it — no replan ever fires."""
    prof, net = inst
    pol = Hysteresis(threshold=0.25, cooldown=1.0)
    c = Coordinator(prof, net, B=128, policy=pol)
    with obs.enabled_scope():
        obs.reset()
        out1 = c.deliver(RateChange(1, 2, 0.4), sim_time=0.0)
        assert out1.action == "absorb"          # inside suppression window
        assert ("link", 1, 2) in pol._pending
        out2 = c.deliver(RateChange(1, 2, 2.5), sim_time=0.2)  # recovery
        assert out2.action == "absorb"
        assert ("link", 1, 2) not in pol._pending
        assert obs.counter("ft.policy.reversals") == 1
        # and much later nothing is armed anymore
        out3 = c.deliver(RateChange(2, 3, 0.95), sim_time=5.0)
        assert out3.action == "absorb"


def test_hysteresis_deferred_replan_matures(inst):
    """Trailing-edge debounce: a super-threshold deviation that PERSISTS
    for the cooldown fires at the next delivery, whatever its key."""
    prof, net = inst
    c = Coordinator(prof, net, B=128,
                    policy=Hysteresis(threshold=0.25, cooldown=1.0))
    assert c.deliver(RateChange(1, 2, 0.4), sim_time=0.0).action == "absorb"
    out = c.deliver(RateChange(2, 3, 0.99), sim_time=1.5)
    assert out.action in ("replan", "microbatch")
    assert "matured" in out.decision.reason or "persisted" in \
        out.decision.reason


def test_hysteresis_node_failure_always_replans_and_resets(inst):
    prof, net = inst
    pol = Hysteresis(threshold=0.25, cooldown=5.0)
    c = Coordinator(prof, net, B=128, policy=pol)
    c.deliver(RateChange(1, 2, 0.4), sim_time=0.0)
    assert pol._pending
    out = c.deliver(NodeFailure(server=3), sim_time=0.5)
    assert out.action in ("replan", "microbatch")
    # renumbering invalidated every per-index key: state dropped
    assert not pol._pending and not pol._dev


# ---------------------------------------------------------------------------
# Periodic + Resync
# ---------------------------------------------------------------------------

def test_periodic_cadence(inst):
    prof, net = inst
    c = Coordinator(prof, net, B=128, policy=Periodic(cadence=2.0))
    a0 = c.deliver(RateChange(1, 2, 0.5), sim_time=0.0).action
    a1 = c.deliver(RateChange(1, 2, 0.5), sim_time=1.0).action
    a2 = c.deliver(RateChange(1, 2, 0.5), sim_time=2.5).action
    assert a0 in ("replan", "microbatch")
    assert a1 == "absorb"
    assert a2 in ("replan", "microbatch")


def test_resync_absorb_is_a_true_noop(inst):
    prof, net = inst
    c = Coordinator(prof, net, B=128, policy=RideOut())
    plan_before, net_before = c.plan, c.net
    out = c.deliver(Resync(net), sim_time=1.0)
    assert out.action == "absorb"
    assert not out.net_changed
    assert c.plan is plan_before and c.net is net_before


def test_resync_replan_keeps_base_network(inst):
    """Replanning against a snapshot solves on the snapshot but must NOT
    adopt it as the coordinator's base network (the driving simulation
    re-applies its scenario multipliers on top of coord.net)."""
    import dataclasses as dc
    prof, net = inst
    c = Coordinator(prof, net, B=128)         # no policy: eager
    slow = dc.replace(net, nodes=[dc.replace(n, f=n.f * 0.5)
                                  for n in net.nodes])
    out = c.apply(Resync(slow), sim_time=1.0)
    assert out.action in ("replan", "microbatch")
    assert not out.net_changed
    assert c.net is net                        # base net untouched
    # the adopted plan was priced on the snapshot (halved compute)
    assert out.new_latency > 0


def test_sampled_network_and_resync_triggers(inst):
    prof, net = inst
    rng = np.random.default_rng(0)
    scen = gauss_markov_scenario(net, 0.3, rng, dt=0.5, horizon=8.0)
    snap = sampled_network(net, scen, 1.0)
    assert len(snap.nodes) == len(net.nodes)
    assert any(abs(a.f - b.f) > 0 for a, b in zip(snap.nodes, net.nodes))
    trigs = periodic_resync_triggers(net, scen, cadence=2.0, horizon=8.0)
    assert [t.time for t in trigs] == [2.0, 4.0, 6.0]
    assert all(isinstance(t.event, Resync) for t in trigs)
    with pytest.raises(ValueError):
        periodic_resync_triggers(net, scen, cadence=0.0, horizon=8.0)


# ---------------------------------------------------------------------------
# RateLimited: token bucket + exponential backoff
# ---------------------------------------------------------------------------

def test_rate_limited_bucket_absorbs_when_empty(inst):
    prof, net = inst
    pol = RateLimited(Eager(), capacity=1.0, refill_period=100.0)
    c = Coordinator(prof, net, B=128, policy=pol)
    with obs.enabled_scope():
        obs.reset()
        out1 = c.deliver(RateChange(1, 2, 0.5), sim_time=0.0)
        assert out1.action in ("replan", "microbatch")
        out2 = c.deliver(RateChange(1, 2, 0.5), sim_time=1.0)
        assert out2.action == "absorb"
        assert "rate-limited" in out2.decision.reason
        assert obs.counter("ft.policy.rate_limited") == 1


def test_rate_limited_backoff_grows_on_unhelpful_replans(inst):
    """Replans that fail to beat riding out by the margin stretch the
    refill period exponentially; the wrapped reason is preserved."""
    prof, net = inst
    pol = RateLimited(Eager(), capacity=3.0, refill_period=1.0,
                      backoff=2.0, margin=0.02)
    c = Coordinator(prof, net, B=128, policy=pol)
    assert pol.effective_refill_period == 1.0
    with obs.enabled_scope():
        obs.reset()
        # mild rate changes: the fresh solve cannot beat riding out, so
        # every adopted replan is "unhelpful"
        c.deliver(RateChange(1, 2, 0.95), sim_time=0.0)
        c.deliver(RateChange(1, 2, 0.95), sim_time=0.01)
        assert obs.counter("ft.policy.backoff_steps") == 2
    assert pol.effective_refill_period == 4.0
    pol.reset()
    assert pol.effective_refill_period == 1.0
    assert pol._tokens == 3.0


def test_rate_limited_refills_with_time(inst):
    prof, net = inst
    pol = RateLimited(Eager(), capacity=1.0, refill_period=1.0, margin=0.9)
    # margin=0.9: essentially every replan counts as helpful is impossible,
    # but helpful-ness doesn't matter here — only the refill clock does
    c = Coordinator(prof, net, B=128, policy=pol)
    c.deliver(RateChange(1, 2, 0.9), sim_time=0.0)      # spends the token
    out = c.deliver(RateChange(1, 2, 0.9), sim_time=0.1)
    assert out.action == "absorb"                       # bucket empty


# ---------------------------------------------------------------------------
# CVaRPreSpill
# ---------------------------------------------------------------------------

def test_cvar_pre_spill_decides_by_tail():
    prof, net, sol, b, B = random_instance(3)
    tight = CVaRPreSpill(bound=1.05, n_scenarios=4, seed=0)
    loose = CVaRPreSpill(bound=1e6, n_scenarios=4, seed=0)
    c = Coordinator(prof, net, B=B, policy=tight)
    ev = Straggler(1, 3.0)
    d_tight = tight.decide(ev, 1.0, c)
    d_loose = loose.decide(ev, 1.0, c)
    # a loose bound absorbs; a tight bound escalates (robust cost model)
    assert not d_loose.replan
    if d_tight.replan:
        assert d_tight.cost_model is tight.robust


# ---------------------------------------------------------------------------
# simulate_with_replanning: suppression + downtime accounting
# ---------------------------------------------------------------------------

def test_suppressed_events_do_not_cut_segments(inst):
    prof, net = inst
    c = Coordinator(prof, net, B=128, policy=RideOut())
    trigs = [ReplanTrigger(0.1, Resync(net)), ReplanTrigger(0.2, Resync(net))]
    rep = simulate_with_replanning(prof, net, 128, trigs, coordinator=c)
    assert rep.num_suppressed == 2
    assert rep.num_replans == 0
    assert len(rep.suppressed) == 2
    assert len(rep.segments) == 1              # one unbroken run
    assert rep.downtime == 0.0
    assert len(rep.outcomes) == 2


def test_downtime_charged_only_for_adopted_replans(inst):
    prof, net = inst
    trig = ReplanTrigger(0.1, RateChange(1, 2, 0.5))
    eager = simulate_with_replanning(prof, net, 128, [trig],
                                     remap_penalty=0.25, solve_downtime=0.5)
    assert eager.num_replans == 1
    assert eager.downtime == pytest.approx(0.75)
    c = Coordinator(prof, net, B=128, policy=RideOut())
    ride = simulate_with_replanning(prof, net, 128, [trig], coordinator=c,
                                    remap_penalty=0.25, solve_downtime=0.5)
    assert ride.num_replans == 0
    assert ride.downtime == 0.0
    # the absorbed rate change still takes physical effect: segment cut
    assert len(ride.segments) == 2


def test_wall_clock_solve_downtime(inst):
    prof, net = inst
    trig = ReplanTrigger(0.1, RateChange(1, 2, 0.5))
    rep = simulate_with_replanning(prof, net, 128, [trig],
                                   solve_downtime="wall")
    out = rep.segments[0].outcome
    assert rep.downtime == pytest.approx(out.solve_seconds)
    assert rep.downtime > 0.0


# ---------------------------------------------------------------------------
# corpus-level guarantees (the CI smoke contract; bench asserts the same)
# ---------------------------------------------------------------------------

def _flap_corpus(net, n_streams=4, horizon=4.0):
    return [fuzz_event_stream(np.random.default_rng(1000 + s), net,
                              horizon=horizon, max_events=5,
                              allow_failure=False, flap_fraction=0.75)
            for s in range(n_streams)]


def test_corpus_hysteresis_vs_eager_vs_rideout():
    prof, net, sol, b, B = random_instance(3)
    streams = _flap_corpus(net)
    reports = evaluate_policies(
        prof, net, B, streams,
        {"eager": lambda: None,
         "ride_out": RideOut,
         "hysteresis": lambda: RateLimited(Hysteresis(0.25, cooldown=0.3))},
        remap_penalty=0.01, solve_downtime=0.05)
    eager, ride, hyst = (reports["eager"], reports["ride_out"],
                         reports["hysteresis"])
    assert eager.replans > 0
    # debounce + backoff: a small fraction of eager's replans...
    assert hyst.replans <= 0.25 * eager.replans
    # ...never more than eager issues, with less downtime...
    assert hyst.downtime <= eager.downtime
    # ...an end-to-end makespan (incl. solve downtime) no worse than eager's
    assert np.mean(hyst.makespans) <= np.mean(eager.makespans) * (1 + 1e-9)
    # ...and a final objective never worse than never replanning at all
    assert np.mean(hyst.final_objectives) <= \
        np.mean(ride.final_objectives) * (1 + 1e-9)
    # replans + suppressions account for every delivered event
    assert hyst.replans + hyst.suppressed == eager.replans + eager.suppressed


def test_evaluate_policies_report_surface():
    prof, net, sol, b, B = random_instance(3)
    streams = _flap_corpus(net, n_streams=2)
    reports = evaluate_policies(prof, net, B, streams,
                                {"eager": lambda: None}, attribution=True,
                                solve_downtime=0.05)
    r = reports["eager"]
    row = r.row()
    assert set(row) == {"policy", "mean", "cvar", "replans", "suppressed",
                        "downtime", "eval_errors", "mean_final_objective"}
    assert r.cvar >= r.mean > 0
    assert r.eval_errors >= 0
    assert r.blocked is not None
    assert len(r.makespans) == 2
