"""Property-based lockdown of ``sim/scenario.py``'s trace algebra.

The vectorized engine's segmented scans ride entirely on ``PiecewiseTrace``'s
cumulative-work coordinates — ``work_done_many`` / ``finish_many`` must be
exact inverses wherever capacity is positive, cumulative work must be
monotone, and breakpoint-merged products must compose associatively — so
these invariants get hypothesis coverage instead of a handful of
hand-picked breakpoints.  (Module skips without hypothesis, like the
engine-parity twin in test_sim.py.)
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import make_edge_network
from repro.sim.scenario import (NetworkScenario, PiecewiseTrace, constant,
                                piecewise, square_wave)


@st.composite
def traces(draw, min_value=0.0, max_value=8.0, max_segments=6):
    """Random well-formed trace: strictly increasing breakpoints from 0,
    bounded non-negative values."""
    n = draw(st.integers(1, max_segments))
    dts = draw(st.lists(st.floats(0.01, 5.0), min_size=n - 1,
                        max_size=n - 1))
    times = tuple(np.concatenate([[0.0], np.cumsum(dts)]))
    values = tuple(draw(st.lists(st.floats(min_value, max_value),
                                 min_size=n, max_size=n)))
    return PiecewiseTrace(times, values)


# ---------------------------------------------------------------------------
# work/finish are inverse coordinate transforms (capacity > 0)
# ---------------------------------------------------------------------------

@settings(max_examples=80, deadline=None)
@given(tr=traces(min_value=0.05), t=st.floats(0.0, 60.0))
def test_finish_inverts_work_done(tr, t):
    w = tr.work_done(t)
    t_back = tr.finish_time(w)
    scale = max(1.0, abs(t))
    assert t_back == pytest.approx(t, rel=1e-9, abs=1e-9 * scale)
    assert tr.work_done(t_back) == pytest.approx(w, rel=1e-9, abs=1e-12)


@settings(max_examples=80, deadline=None)
@given(tr=traces(min_value=0.05),
       ws=st.lists(st.floats(-1.0, 100.0), min_size=1, max_size=8))
def test_work_inverts_finish_many(tr, ws):
    target = np.asarray(ws)
    t = tr.finish_many(target)
    back = tr.work_done_many(t)
    # non-positive targets clamp to t = 0 (work 0); positive ones roundtrip
    want = np.maximum(target, 0.0)
    np.testing.assert_allclose(back, want, rtol=1e-9, atol=1e-9)
    # vectorized == scalar, element by element
    for wi, ti in zip(target, t):
        assert ti == pytest.approx(tr.finish_time(float(wi))
                                   if wi > 0 else 0.0, rel=1e-12, abs=1e-12)


@settings(max_examples=80, deadline=None)
@given(tr=traces(), ts=st.lists(st.floats(0.0, 60.0), min_size=2,
                                max_size=10))
def test_cumulative_work_monotone_and_vectorized_matches_scalar(tr, ts):
    t = np.sort(np.asarray(ts))
    w = tr.work_done_many(t)
    assert np.all(np.diff(w) >= -1e-12), "cumulative work must be monotone"
    for ti, wi in zip(t, w):
        assert wi == pytest.approx(tr.work_done(float(ti)), rel=1e-12,
                                   abs=1e-12)


# ---------------------------------------------------------------------------
# breakpoint-merge product: commutative, associative, unit
# ---------------------------------------------------------------------------

@settings(max_examples=80, deadline=None)
@given(a=traces(), b=traces())
def test_product_commutes_exactly(a, b):
    assert a * b == b * a            # IEEE multiplication commutes


@settings(max_examples=60, deadline=None)
@given(a=traces(), b=traces(), c=traces(),
       ts=st.lists(st.floats(0.0, 60.0), min_size=1, max_size=6))
def test_product_associative(a, b, c, ts):
    left = (a * b) * c
    right = a * (b * c)
    assert left.times == right.times        # same merged breakpoint set
    np.testing.assert_allclose(left.values, right.values, rtol=1e-9,
                               atol=1e-12)
    for t in ts:                            # and pointwise off-breakpoint
        assert left.value_at(t) == pytest.approx(right.value_at(t),
                                                 rel=1e-9, abs=1e-12)


@settings(max_examples=80, deadline=None)
@given(a=traces())
def test_product_unit(a):
    assert a * constant(1.0) == a


# ---------------------------------------------------------------------------
# constructors: coalescing, square waves, scenario composition
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(tr=traces(), dup_at=st.integers(0, 5))
def test_piecewise_coalesces_duplicates_last_wins(tr, dup_at):
    i = min(dup_at, len(tr.times) - 1)
    times = tr.times[:i + 1] + (tr.times[i],) + tr.times[i + 1:]
    values = tr.values[:i + 1] + (99.0,) + tr.values[i + 1:]
    out = piecewise(times, values)
    assert out.times == tr.times
    assert out.value_at(tr.times[i]) == 99.0


@settings(max_examples=60, deadline=None)
@given(start=st.floats(0.0, 4.0), periods=st.integers(1, 5),
       period=st.sampled_from([0.25, 0.5, 1.0]),
       duty=st.sampled_from([0.25, 0.5, 0.75]),
       low=st.sampled_from([0.0, 0.2]))
def test_square_wave_properties(start, periods, period, duty, low):
    end = start + periods * period
    tr = square_wave(start, end, period=period, duty=duty, low=low)
    assert tr.drains()
    assert tr.value_at(end + 0.1) == 1.0
    if start > 0:
        assert tr.value_at(start / 2) == 1.0
    # integral over the flapping window = duty-weighted mean capacity
    work = tr.work_done(end) - tr.work_done(start)
    want = periods * period * (duty * 1.0 + (1 - duty) * low)
    assert work == pytest.approx(want, rel=1e-9, abs=1e-12)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 1000), factor=st.floats(0.05, 0.9),
       start=st.floats(0.0, 2.0), dur=st.floats(0.1, 3.0))
def test_region_degradation_composes_multiplicatively(seed, factor, start,
                                                      dur):
    net = make_edge_network(num_servers=3, num_clients=2, seed=seed)
    nodes = [1, 2]
    links = [(0, 1), (1, 2)]
    scen = NetworkScenario().with_region_degradation(
        nodes, links, start, start + dur, factor)
    mid, after = start + dur / 2, start + dur + 1.0
    for n in nodes:
        assert scen.node_mult[n].value_at(mid) == pytest.approx(factor)
        assert scen.node_mult[n].value_at(after) == 1.0
    for lk in links:
        assert scen.link_mult[lk].value_at(mid) == pytest.approx(factor)
    assert scen.drains()
    # stacking a second event multiplies into the same keys
    again = scen.with_region_degradation(nodes, [], start, start + dur,
                                         factor)
    assert again.node_mult[1].value_at(mid) == pytest.approx(factor ** 2)
