"""The CostModel seam (ISSUE 4): ClosedForm bit-identity with the default
path, sim-in-the-loop BCD on reentrant/co-located scenarios, MemoryBudgeted
admission windows vs engine-measured occupancy, and the shared Eq. (11)
claims source across policies / schedule / feasibility box."""

import dataclasses
import math

import numpy as np
import pytest

from repro.core import (ClosedForm, SimMakespan, bcd_solve, budget_feasible,
                        exhaustive_joint, feasibility_box, make_edge_network,
                        node_budget_windows, node_budget_windows_many,
                        random_profile, stage_memory_claims, total_latency,
                        ours, sim_refined, EdgeNetwork, Node, SplitSolution,
                        uniform_profile)
from repro.core.cost_model import resolve_cost_model
from repro.pipeline.schedule import memory_highwater
from repro.sim import (MemoryBudgeted, activation_occupancy, resolve_policy,
                       simulate_plan, stage_activation_highwater)

from conftest import small_instance


# ---------------------------------------------------------------------------
# Scenario generators
# ---------------------------------------------------------------------------

def reentrant_instance(seed, num_layers=14, num_servers=2):
    """Memory-starved 2-server instances whose optimal closed-form plan
    ping-pongs submodels across the servers (reentrant/co-located) — the
    regime where Eq. (14) idealizes away real contention."""
    rng = np.random.default_rng(seed)
    prof = random_profile(rng, num_layers)
    net = make_edge_network(num_servers=num_servers, num_clients=2, seed=seed,
                            bw_range_hz=(200e6, 400e6),
                            mem_range=(2**26, 2**27),
                            f_range=(1e12, 20e12))
    return prof, net


#: seeds whose closed-form plan is verified reentrant (asserted below)
REENTRANT_SEEDS = (22, 24, 27)


def _sim_makespan(prof, net, plan, B):
    return simulate_plan(prof, net, plan.solution, plan.b, B=B,
                         policy=MemoryBudgeted(), engine="auto").L_t


# ---------------------------------------------------------------------------
# ClosedForm is bit-identical to the default path
# ---------------------------------------------------------------------------

def _plans_bit_identical(p0, p1):
    return (p0.objective == p1.objective
            and p0.solution.cuts == p1.solution.cuts
            and p0.solution.placement == p1.solution.placement
            and p0.b == p1.b and p0.L_t == p1.L_t
            and p0.T_f == p1.T_f and p0.T_i == p1.T_i
            and p0.history == p1.history)


@pytest.mark.parametrize("seed", range(8))
def test_bcd_closed_form_bit_identical_to_default(seed):
    prof, net = small_instance(seed, num_layers=6, num_servers=3)
    p0 = bcd_solve(prof, net, B=96, b0=12)
    p1 = bcd_solve(prof, net, B=96, b0=12, cost_model=ClosedForm())
    assert p0.feasible == p1.feasible
    if p0.feasible:
        assert _plans_bit_identical(p0, p1)
        assert p0.objective == p0.L_t         # ClosedForm objective IS Eq. 14


@pytest.mark.parametrize("seed", range(4))
def test_exhaustive_joint_closed_form_bit_identical(seed):
    prof, net = small_instance(seed, num_layers=6, num_servers=3)
    e0 = exhaustive_joint(prof, net, B=48)
    e1 = exhaustive_joint(prof, net, B=48, cost_model=ClosedForm())
    assert e0.feasible == e1.feasible
    if e0.feasible:
        assert _plans_bit_identical(e0, e1)


def test_sim_makespan_accepts_policy_instance():
    """The acceptance spelling: SimMakespan(policy=MemoryBudgeted())."""
    prof, net = reentrant_instance(REENTRANT_SEEDS[0])
    a = bcd_solve(prof, net, B=32, b0=4, K=5,
                  cost_model=SimMakespan(policy=MemoryBudgeted()))
    b = bcd_solve(prof, net, B=32, b0=4, K=5,
                  cost_model=SimMakespan(policy="memory"))
    assert a.feasible and a.cost_model == "sim_makespan"
    assert (a.solution, a.b, a.objective) == (b.solution, b.b, b.objective)


def test_resolve_cost_model():
    cm = resolve_cost_model(None, "refined")
    assert isinstance(cm, ClosedForm) and cm.memory_model == "refined"
    sim = SimMakespan()
    assert resolve_cost_model(sim) is sim
    with pytest.raises(TypeError):
        resolve_cost_model("closed_form")


# ---------------------------------------------------------------------------
# Sim-in-the-loop BCD on reentrant/co-located scenarios
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", REENTRANT_SEEDS)
def test_sim_refined_beats_closed_form_on_reentrant_scenarios(seed):
    """The acceptance scenarios: the closed-form plan is reentrant
    (co-located submodels), and optimizing the measured makespan produces a
    plan whose *simulated* makespan is <= the closed-form plan's."""
    prof, net = reentrant_instance(seed)
    B = 64
    cf = bcd_solve(prof, net, B=B, b0=8, K=7)
    assert cf.feasible
    placements = [n for _, _, _, n in cf.solution.segments()]
    assert len(placements) != len(set(placements)), placements  # reentrant
    sim = bcd_solve(prof, net, B=B, b0=8, K=7, cost_model=SimMakespan())
    assert sim.feasible
    s_cf = _sim_makespan(prof, net, cf, B)
    s_sim = _sim_makespan(prof, net, sim, B)
    assert s_sim <= s_cf * (1 + 1e-9), (s_sim, s_cf)
    # the sim plan's recorded objective is the measured makespan itself
    assert sim.objective == pytest.approx(s_sim, rel=1e-9)
    assert sim.cost_model == "sim_makespan"
    # ... and on these instances the measured metric strictly improves
    assert s_sim < s_cf * 0.999


@pytest.mark.parametrize("seed", REENTRANT_SEEDS)
def test_sim_metric_history_non_increasing(seed):
    """Per-iteration objective non-increasing *under the sim metric*."""
    prof, net = reentrant_instance(seed)
    plan = bcd_solve(prof, net, B=64, b0=8, K=7, cost_model=SimMakespan())
    objs = [h[0] for h in plan.history]
    assert objs, "history must record the incumbent objective"
    for a, b in zip(objs, objs[1:]):
        assert b <= a * (1 + 1e-12)
    assert plan.objective == objs[-1]


def test_sim_refined_scheme_wraps_sim_cost_model():
    prof, net = reentrant_instance(REENTRANT_SEEDS[0])
    p = sim_refined(prof, net, 64, b0=8, K=7)
    q = bcd_solve(prof, net, 64, b0=8, K=7, cost_model=SimMakespan())
    assert p.cost_model == "sim_makespan"
    assert p.solution == q.solution and p.b == q.b
    assert p.objective == pytest.approx(q.objective, rel=1e-12)


def test_ours_restarts_select_by_cost_model():
    prof, net = reentrant_instance(REENTRANT_SEEDS[0])
    p = ours(prof, net, B=64, K=7, cost_model=SimMakespan(), restarts=True)
    single = sim_refined(prof, net, 64, b0=20, K=7)
    assert p.objective <= single.objective * (1 + 1e-9)


# ---------------------------------------------------------------------------
# MemoryBudgeted: windows, claims vs measured occupancy, engine refusal
# ---------------------------------------------------------------------------

def _budget_instance(mem_server=14.0, S=4):
    """Hand-built chain: param+opt = 2/layer static, act+grad = 2/layer per
    live micro-batch (b=1), one layer per stage, distinct nodes."""
    prof = uniform_profile(S, fp=1.0, bp=1.0, act=1.0, param=1.0)
    nodes = [Node("c", f=1.0, t0=0.0, t1=0.0, b_th=0, is_client=True,
                  mem=1000.0)]
    nodes += [Node(f"s{i}", f=1.0, t0=0.0, t1=0.0, b_th=0, mem=mem_server)
              for i in range(1, S)]
    rate = np.full((S, S), 1e6)
    np.fill_diagonal(rate, 0.0)
    net = EdgeNetwork(nodes=nodes, rate=rate, num_clients=1)
    sol = SplitSolution(cuts=tuple(range(1, S + 1)),
                        placement=tuple(range(S)))
    return prof, net, sol


def test_window_arithmetic_from_claims():
    prof, net, sol = _budget_instance(mem_server=14.0)
    claims = stage_memory_claims(prof, net, sol, b=1)
    assert [c.static_bytes for c in claims] == [2.0] * 4
    assert [c.act_bytes for c in claims] == [2.0] * 4
    # server: floor((14 - 2) / 2) = 6 live micro-batches; client mem ample
    ws = node_budget_windows(prof, net, sol, b=1)
    assert ws == [499, 6, 6, 6]
    pol = MemoryBudgeted().bind(prof, net, sol, 1)
    assert [pol.window(4, j) for j in range(4)] == ws
    assert pol.stage_capacity(4, 20) == {0: 20, 1: 6, 2: 6, 3: 6}
    assert pol.stage_capacity(4, 3) == {0: 3, 1: 3, 2: 3, 3: 3}  # clip at Q


def test_budget_claims_validated_event_by_event():
    """Engine-measured activation occupancy never exceeds the closed-form
    stage_capacity claims, at every event of the timeline; on a saturating
    pipeline the bound is achieved exactly."""
    prof, net, sol = _budget_instance(mem_server=8.0)  # window (8-2)/2 = 3
    # make the LAST stage the bottleneck so upstream stages saturate their
    # admission windows (same trick as tests/test_sim.py)
    slow = dataclasses.replace(prof, bp_work=np.array([0.001] * 3 + [10.0]))
    Q = 12
    pol = MemoryBudgeted().bind(slow, net, sol, 1)
    claims = pol.stage_capacity(4, Q)
    for engine in ("event", "vectorized"):
        rep = simulate_plan(slow, net, sol, 1, num_microbatches=Q,
                            policy=MemoryBudgeted(), engine=engine)
        occ = activation_occupancy(rep.records)
        assert set(occ) == set(claims)
        for j, series in occ.items():
            for _, level in series:
                assert level <= claims[j]
        # stages feeding the bottleneck achieve their windows exactly
        hw = stage_activation_highwater(rep.records)
        assert hw[2] == claims[2] == 3
    # engines agree under the memory policy
    ev = simulate_plan(slow, net, sol, 1, num_microbatches=Q,
                       policy="memory", engine="event")
    vec = simulate_plan(slow, net, sol, 1, num_microbatches=Q,
                        policy="memory", engine="vectorized")
    np.testing.assert_allclose(ev.mb_complete, vec.mb_complete, rtol=1e-12)


def test_memory_policy_tightens_with_budget():
    """Shrinking Node.mem can only shrink windows, raise the makespan, and
    lower the high-water marks."""
    prevL, prev_hw = -math.inf, None
    for mem in (20.0, 8.0, 6.0):
        prof, net, sol = _budget_instance(mem_server=mem)
        slow = dataclasses.replace(prof,
                                   bp_work=np.array([0.001] * 3 + [10.0]))
        ws = node_budget_windows(slow, net, sol, 1)
        rep = simulate_plan(slow, net, sol, 1, num_microbatches=10,
                            policy="memory")
        hw = stage_activation_highwater(rep.records)
        assert rep.L_t >= prevL - 1e-9
        if prev_hw is not None:
            assert all(hw[j] <= prev_hw[j] for j in hw)
        prevL, prev_hw = rep.L_t, hw
        assert all(w >= 1 for w in ws)


def test_engine_refuses_unschedulable_budget():
    prof, net, sol = _budget_instance(mem_server=3.0)   # static 2 + act 2 > 3
    assert not budget_feasible(prof, net, sol, 1)
    with pytest.raises(ValueError, match="memory-infeasible"):
        simulate_plan(prof, net, sol, 1, num_microbatches=4, policy="memory")


def test_unbound_memory_policy_raises():
    pol = MemoryBudgeted()
    assert not pol.bound
    with pytest.raises(RuntimeError, match="bind"):
        pol.window(3, 0)
    assert resolve_policy("memory").name == "memory"
    assert resolve_policy("memory_budgeted").name == "memory"


# ---------------------------------------------------------------------------
# One shared claims source: policy == schedule == feasibility box
# ---------------------------------------------------------------------------

def test_highwater_schedule_and_policy_agree():
    prof, net, sol = _budget_instance(mem_server=8.0)
    pol = MemoryBudgeted().bind(prof, net, sol, 2)
    S, Q = 4, 9
    assert memory_highwater(S, Q, "memory", bind=(prof, net, sol, 2)) \
        == pol.stage_capacity(S, Q)
    # and the claims trace back to the same node_budget_windows numbers
    ws = node_budget_windows(prof, net, sol, 2)
    assert memory_highwater(S, Q, pol) == {
        j: (Q if w is None else min(Q, w)) for j, w in enumerate(ws)}


def test_feasibility_box_uses_budget_predicate():
    """feasibility_box under SimMakespan must agree with budget_feasible —
    the very same windows >= 1 predicate the policy binds."""
    prof, net, sol = _budget_instance(mem_server=8.0)
    T_1 = 1e9               # deactivate the T_i leg: isolate the memory leg
    box = feasibility_box(prof, net, sol, B=64, T_1=T_1,
                          cost_model=SimMakespan())
    assert box >= 1
    assert budget_feasible(prof, net, sol, box)
    if box < 64:
        assert not budget_feasible(prof, net, sol, box + 1)
    pol = MemoryBudgeted().bind(prof, net, sol, box)
    assert pol.schedulable()


@pytest.mark.parametrize("seed", [0, 3, 7])
def test_tightening_memory_never_widens_feasible_box(seed):
    """Property: scaling every Node.mem down monotonically shrinks the
    feasible-b box under the memory-budgeted predicate."""
    prof, net = reentrant_instance(seed)
    sol = None
    plan = bcd_solve(prof, net, B=32, b0=4, K=5)
    if not plan.feasible:
        pytest.skip("no feasible base plan")
    sol = plan.solution
    prev_box = math.inf
    for scale in (1.0, 0.5, 0.25, 0.1, 0.02):
        tight = dataclasses.replace(
            net, nodes=[dataclasses.replace(n, mem=n.mem * scale)
                        for n in net.nodes])
        box = feasibility_box(prof, tight, sol, B=32, T_1=1e9,
                              cost_model=SimMakespan())
        assert box <= prev_box
        prev_box = box
    # ... and the closed-form box obeys the same monotonicity
    prev_box = math.inf
    for scale in (1.0, 0.5, 0.1):
        tight = dataclasses.replace(
            net, nodes=[dataclasses.replace(n, mem=n.mem * scale)
                        for n in net.nodes])
        box = feasibility_box(prof, tight, sol, B=32, T_1=1e9)
        assert box <= prev_box
        prev_box = box


# ---------------------------------------------------------------------------
# Batched scoring: evaluate_many == looped evaluate, batched windows, memo
# ---------------------------------------------------------------------------

def _candidate_grid(seed, B=32):
    """A mixed candidate set: the closed-form plan's split over a range of
    b (the refinement-sweep shape), plus an infeasible b=0 probe."""
    prof, net = reentrant_instance(seed)
    plan = bcd_solve(prof, net, B=B, b0=4, K=5)
    cands = [(plan.solution, b) for b in range(1, 11)] + [(plan.solution, 0)]
    return prof, net, cands, B


@pytest.mark.parametrize("seed", [22, 24, 3])
def test_evaluate_many_identity_with_looped_evaluate(seed):
    """CostModel.evaluate_many must return exactly what looping evaluate
    returns — for the sim model that holds the stacked plan axis and the
    per-plan kernels to the same floats."""
    prof, net, cands, B = _candidate_grid(seed)
    for cm in (ClosedForm(), SimMakespan()):
        cs = cands if isinstance(cm, SimMakespan) else cands[:-1]
        looped = [cm.evaluate(prof, net, sol, b, B) for sol, b in cs]
        batched = cm.evaluate_many(prof, net, cs, B)
        assert looped == batched, cm.name


@pytest.mark.parametrize("seed", [22, 27, 5])
def test_node_budget_windows_many_identity(seed):
    prof, net = reentrant_instance(seed)
    plan = bcd_solve(prof, net, B=32, b0=4, K=5)
    sol = plan.solution
    bs = list(range(1, 33))
    many = node_budget_windows_many(prof, net, sol, bs)
    for b, ws in zip(bs, many):
        assert ws == node_budget_windows(prof, net, sol, b)
    sm = SimMakespan()
    assert sm.memory_feasible_many(prof, net, sol, bs) \
        == [sm.memory_feasible(prof, net, sol, b) for b in bs]


def test_memoized_cost_model_caches_and_forwards():
    from repro.core import memoized_cost_model
    prof, net, cands, B = _candidate_grid(22)
    inner = SimMakespan()
    calls = {"n": 0}
    orig = inner.evaluate_many

    def counting(profile, network, cs, BB):
        calls["n"] += len(cs)
        return orig(profile, network, cs, BB)

    inner.evaluate_many = counting
    cm = memoized_cost_model(inner)
    assert cm.name == "sim_makespan"
    first = cm.evaluate_many(prof, net, cands, B)
    n_first = calls["n"]
    again = cm.evaluate_many(prof, net, cands, B)
    assert again == first
    assert calls["n"] == n_first          # all hits the second time
    assert cm.evaluate(prof, net, *cands[0], B) == first[0]
    # ClosedForm passes through unwrapped; wrapping is idempotent
    cf = ClosedForm()
    assert memoized_cost_model(cf) is cf
    assert memoized_cost_model(cm) is cm


def test_memory_policy_bind_many_matches_bind():
    prof, net = reentrant_instance(24)
    plan = bcd_solve(prof, net, B=32, b0=4, K=5)
    sol = plan.solution
    plans = [(sol, b) for b in (1, 2, 3, 5)]
    pols = MemoryBudgeted().bind_many(prof, net, plans)
    for (s, b), pol in zip(plans, pols):
        one = MemoryBudgeted().bind(prof, net, s, b)
        assert pol._windows == one._windows


# ---------------------------------------------------------------------------
# Coordinator threading
# ---------------------------------------------------------------------------

def test_coordinator_accepts_cost_model():
    from repro.ft import Coordinator, Straggler
    prof, net = reentrant_instance(REENTRANT_SEEDS[0])
    coord = Coordinator(prof, net, B=32, cost_model=SimMakespan())
    assert coord.plan.cost_model == "sim_makespan"
    out = coord.apply(Straggler(1, 4.0))
    assert out.new_plan.feasible
    assert out.new_plan.cost_model == "sim_makespan"
    assert math.isfinite(out.new_plan.objective)
