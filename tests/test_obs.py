"""ISSUE 6 telemetry layer: idle/bubble accounting vs the Eq. (12)-(14)
closed form, event-vs-vectorized ``UtilizationReport`` parity, the unified
``resource_busy`` regression, disabled-mode no-op guarantees, and the
generalized Chrome-trace export."""

import json

import numpy as np
import pytest

from repro import obs
from repro.core import EdgeNetwork, Node, SplitSolution, uniform_profile
from repro.core.latency import (fill_latency, pipeline_interval,
                                total_latency)
from repro.sim import (compare_utilization, simulate_plan, simulate_plans,
                       write_chrome_trace)
from repro.sim.scenario import NetworkScenario, gauss_markov_scenario
from repro.sim.validate import random_instance, random_reentrant_solution


@pytest.fixture(autouse=True)
def _clean_registry():
    """Telemetry state is process-global: leave it as we found it."""
    obs.reset()
    yield
    obs.reset()
    obs.disable()


def _chain():
    """Deterministic 2-stage chain whose bottleneck is the FIRST chain
    resource (client FP): every downstream resource then shows the
    steady-state bubble ``(Q-1) * (T_i - d_v)`` of Eq. (13)."""
    prof = uniform_profile(4, fp=1.0, bp=0.5, act=1.0)
    nodes = [Node("c", f=0.5, t0=0.0, t1=0.0, b_th=0, is_client=True),
             Node("s", f=2.0, t0=0.0, t1=0.0, b_th=0)]
    net = EdgeNetwork(nodes=nodes,
                      rate=np.array([[0.0, 10.0], [10.0, 0.0]]),
                      num_clients=1)
    sol = SplitSolution(cuts=(2, 4), placement=(0, 1))
    return prof, net, sol


# ---------------------------------------------------------------------------
# idle accounting vs the closed form
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["event", "vectorized"])
def test_bubble_identity_closed_form(engine):
    """On the deterministic chain, per-resource bubbles equal
    ``(Q-1) * (T_i - d_v)`` and idle totals reconcile with Eqs. (12)-(14)
    to float precision."""
    prof, net, sol, b, Q = *_chain(), 2, 8
    rep = simulate_plan(prof, net, sol, b, num_microbatches=Q,
                        engine=engine)
    u = rep.utilization()
    # the simulated run is the closed form (standing sim.validate check)
    B = b * Q                                   # => num_fills == Q - 1
    assert rep.T_f == pytest.approx(fill_latency(prof, net, sol, b),
                                    rel=1e-12)
    assert rep.T_i == pytest.approx(pipeline_interval(prof, net, sol, b),
                                    rel=1e-12)
    assert rep.L_t == pytest.approx(total_latency(prof, net, sol, b, B),
                                    rel=1e-12)
    # constant capacities: per-task service is constant per resource
    d = {res: ru.service / Q for res, ru in u.resources.items()}
    T_i = pipeline_interval(prof, net, sol, b)
    assert max(d.values()) == pytest.approx(T_i, rel=1e-12)
    assert d[("fp", 0)] == pytest.approx(T_i, rel=1e-12), \
        "fixture must keep the bottleneck at the first chain resource"
    for res, ru in u.resources.items():
        # Eq. (13)'s bottleneck interval, shadowed per resource
        assert ru.bubble == pytest.approx((Q - 1) * (T_i - d[res]),
                                          rel=1e-9, abs=1e-12), res
        # per-resource idle reconciles with Eq. (14): span is L_t and
        # occupancy is Q * d_v, so idle = L_t - Q * d_v exactly
        assert ru.idle == pytest.approx(rep.L_t - Q * d[res], rel=1e-12)
        # the decomposition is exhaustive: span = service + idle
        assert u.span - ru.service == pytest.approx(ru.idle, rel=1e-12)
        assert ru.blocked == 0.0
    # the bottleneck never bubbles in steady state
    assert u.resources[("fp", 0)].bubble == 0.0
    assert 0.0 < u.bubble_fraction < 1.0
    assert 0.0 < u.fill_drain_fraction < 1.0
    assert u.idle_fraction_total == pytest.approx(
        u.bubble_fraction + u.fill_drain_fraction, rel=1e-12)


def test_rollups_group_by_node_and_link():
    prof, net, sol = _chain()
    u = simulate_plan(prof, net, sol, 2, num_microbatches=5,
                      engine="auto").utilization()
    nodes = u.node_idle_fraction()
    links = u.link_idle_fraction()
    assert set(nodes) == {0, 1}
    assert set(links) == {(0, 1), (1, 0)}
    assert all(0.0 <= v <= 1.0 for v in nodes.values())
    assert all(0.0 <= v <= 1.0 for v in links.values())


def test_blocked_time_under_outage():
    """A zero-capacity window on the forward link shows up as blocked (not
    busy) time, and busy + blocked still equals total occupancy."""
    prof, net, sol = _chain()
    # first forward transfer starts at t = 8 (client FP of mb0 takes 8s):
    # cut the link mid-flight so the transfer stalls inside the window
    scen = NetworkScenario().with_outage(0, 1, 8.05, 9.0)
    rep = simulate_plan(prof, net, sol, 2, num_microbatches=4,
                        scenario=scen, engine="event")
    u = rep.utilization(net=net, scenario=scen)
    ru = u.resources[("fwd", 0, 1)]
    assert ru.blocked > 0.0
    assert ru.busy > 0.0
    assert ru.service == pytest.approx(ru.busy + ru.blocked, rel=1e-12)
    # resources with constant capacity never report blocked time
    assert u.resources[("fp", 0)].blocked == 0.0
    # and the decomposition still closes
    assert u.span - ru.service == pytest.approx(ru.idle, rel=1e-9)


# ---------------------------------------------------------------------------
# engine parity (deterministic grid + hypothesis twin)
# ---------------------------------------------------------------------------

def _parity_case(seed: int, reentrant: bool, traced: bool, policy: str):
    prof, net, sol, b, _B = random_instance(seed)
    if reentrant:
        sol = random_reentrant_solution(np.random.default_rng(seed), prof,
                                        net)
    scen = None
    if traced:
        scen = gauss_markov_scenario(net, 0.4, np.random.default_rng(seed),
                                     dt=0.37, horizon=60.0)
    return compare_utilization(prof, net, sol, b, 6, policy=policy,
                               scenario=scen)


def test_utilization_parity_grid():
    """Event-reconstructed and timeline-reconstructed reports agree field
    by field on the randomized grid (the ISSUE 6 acceptance check)."""
    hits = 0
    for seed in range(10):
        for reentrant in (False, True):
            for traced in (False, True):
                for pol in ("fifo", "1f1b"):
                    try:
                        gap = _parity_case(seed, reentrant, traced, pol)
                    except ValueError:
                        continue       # infeasible draw (e.g. co-location)
                    assert gap < 1e-9, (seed, reentrant, traced, pol, gap)
                    hits += 1
    assert hits >= 30


def test_utilization_parity_hypothesis():
    """Property-based twin of the parity grid (skips without hypothesis)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 10_000), reentrant=st.booleans(),
           traced=st.booleans(), pol=st.sampled_from(["fifo", "1f1b"]))
    def run(seed, reentrant, traced, pol):
        try:
            gap = _parity_case(seed, reentrant, traced, pol)
        except ValueError:
            return
        assert gap < 1e-9

    run()


# ---------------------------------------------------------------------------
# resource_busy unification (the ISSUE 6 bugfix regression)
# ---------------------------------------------------------------------------

def test_resource_busy_unified_across_engines_trace_scaled():
    """Both engines must report the same busy fractions through the shared
    interval accounting, including on trace-scaled resources — and the
    coarse ``resource_busy`` must equal the decomposition's occupancy
    fractions exactly."""
    for seed in (0, 3, 5):
        prof, net, sol, b, _B = random_instance(seed)
        scen = gauss_markov_scenario(net, 0.5, np.random.default_rng(seed),
                                     dt=0.31, horizon=80.0)
        ev = simulate_plan(prof, net, sol, b, num_microbatches=6,
                           scenario=scen, engine="event")
        vec = simulate_plan(prof, net, sol, b, num_microbatches=6,
                            scenario=scen, engine="vectorized")
        assert set(ev.resource_busy) == set(vec.resource_busy)
        for res in ev.resource_busy:
            assert ev.resource_busy[res] == pytest.approx(
                vec.resource_busy[res], rel=1e-12, abs=1e-12), (seed, res)
        for rep in (ev, vec):
            frac = rep.utilization().service_fractions()
            for res in rep.resource_busy:
                assert frac[res] == pytest.approx(rep.resource_busy[res],
                                                  rel=1e-12, abs=1e-12)


def test_stacked_scoring_report_refuses_utilization():
    prof, net, sol, b, _B = random_instance(1)
    reps = simulate_plans(prof, net, [(sol, b), (sol, max(1, b - 1))],
                          num_microbatches=[5, 5], engine="auto")
    stacked = [r for r in reps if r.timeline is None and r._records is None]
    if not stacked:
        pytest.skip("instance did not take the stacked plan axis")
    with pytest.raises(ValueError, match="stacked"):
        stacked[0].utilization()


# ---------------------------------------------------------------------------
# disabled-mode is a true no-op; counters/spans record when enabled
# ---------------------------------------------------------------------------

def test_disabled_mode_is_noop():
    prof, net, sol = _chain()
    snap = obs.get_registry().snapshot()
    assert not obs.enabled()
    simulate_plan(prof, net, sol, 2, num_microbatches=4, engine="auto")
    obs.inc("should.not.appear")
    assert obs.get_registry().snapshot() == snap == {}
    assert obs.wall_spans() == []
    # the disabled span is one shared singleton — nothing is allocated
    assert obs.span("a", x=1) is obs.span("b", y=2)


def test_counters_and_spans_record_when_enabled():
    prof, net, sol = _chain()
    with obs.enabled_scope() as reg:
        simulate_plan(prof, net, sol, 2, num_microbatches=4, engine="auto")
        snap = reg.snapshot()
        assert snap.get("sim.dispatch.vectorized", 0) == 1
        assert any(k.startswith("sim.engine_reason[") for k in snap)
        names = [s.name for s in obs.wall_spans()]
        assert "sim.simulate_plan" in names
    assert not obs.enabled()          # scope restored
    obs.reset()
    assert obs.get_registry().snapshot() == {}


def test_planner_and_bcd_counters():
    from repro.core.bcd import bcd_solve
    prof, net, sol, b, B = random_instance(2)
    with obs.enabled_scope() as reg:
        bcd_solve(prof, net, B)
        snap = reg.snapshot()
        assert snap.get("bcd.iterations", 0) >= 1
        assert snap.get("planner.solve_memo_miss", 0) >= 1
        assert snap.get("planner.dp_sweeps", 0) >= 1
        names = {s.name for s in obs.wall_spans()}
        assert {"bcd.solve", "bcd.iterate", "planner.solve"} <= names


def test_coordinator_outcome_timing_fields():
    from repro.ft.coordinator import Coordinator, Straggler
    prof, net, sol, b, B = random_instance(4)
    coord = Coordinator(prof, net, B)
    out = coord.apply(Straggler(node=1, slowdown=3.0), sim_time=12.5)
    assert out.sim_time == 12.5
    assert out.solve_seconds > 0.0
    rec = out.log_record()
    assert rec["event"] == "Straggler"
    assert rec["action"] in ("microbatch", "replan")
    assert rec["sim_time"] == 12.5


# ---------------------------------------------------------------------------
# Chrome-trace export (counter tracks, flows, wall-clock solver tracks)
# ---------------------------------------------------------------------------

def test_chrome_trace_extras_validate(tmp_path):
    prof, net, sol = _chain()
    with obs.enabled_scope():
        rep = simulate_plan(prof, net, sol, 2, num_microbatches=3,
                            engine="event")
        spans = obs.wall_spans()
    path = write_chrome_trace(rep.records, str(tmp_path / "trace.json"),
                              counter_tracks=True, flow_events=True,
                              wall_spans=spans)
    data = json.loads(open(path).read())
    errs = obs.validate_chrome_trace(data)
    assert errs == [], errs
    evs = data["traceEvents"]
    phases = {e["ph"] for e in evs}
    assert {"M", "X", "C", "s", "f"} <= phases
    # flows come in matched s/f pairs, one per (micro-batch, hop) round trip
    starts = [e for e in evs if e["ph"] == "s"]
    finishes = [e for e in evs if e["ph"] == "f"]
    assert len(starts) == len(finishes) == 3     # 3 micro-batches x 1 hop
    assert {e["id"] for e in starts} == {e["id"] for e in finishes}
    # wall-clock solver tracks live on their own process
    pids = {e["pid"] for e in evs}
    assert obs.SOLVER_PID in pids and obs.SIM_PID in pids
    sim_x = [e for e in evs if e["ph"] == "X" and e["pid"] == obs.SIM_PID]
    assert len(sim_x) == len(rep.records)


def test_validate_chrome_trace_flags_garbage():
    assert obs.validate_chrome_trace([]) != []
    assert obs.validate_chrome_trace({"traceEvents": 3}) != []
    bad = {"traceEvents": [{"ph": "X", "pid": 0, "tid": "zero", "ts": 1.0,
                            "dur": -2.0, "name": "x"}]}
    errs = obs.validate_chrome_trace(bad)
    assert any("tid" in e for e in errs)
    assert any("dur" in e for e in errs)
    good = {"traceEvents": [{"ph": "X", "pid": 0, "tid": 0, "ts": 0.0,
                             "dur": 1.0, "name": "ok", "args": {}}]}
    assert obs.validate_chrome_trace(good) == []


def test_registry_dump_roundtrip(tmp_path):
    with obs.enabled_scope():
        obs.inc("a.counter", 3)
        with obs.span("a.span"):
            pass
        path = obs.dump(str(tmp_path / "counters.json"))
    data = json.loads(open(path).read())
    assert data["counters"]["a.counter"] == 3
    assert data["spans"]["a.span"]["count"] == 1
