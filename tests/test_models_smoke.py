"""Per-architecture smoke tests: REDUCED same-family configs, one forward /
train / prefill / decode step on CPU asserting shapes + finiteness, plus
prefill-vs-decode consistency for the stateful families."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, count_params
from repro.models import get_model


def _batch(cfg, B=2, S=32, rng=None):
    rng = rng or jax.random.PRNGKey(0)
    b = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab),
         "labels": jax.random.randint(rng, (B, S), 0, cfg.vocab)}
    if cfg.family == "vlm":
        b["patch_embeds"] = jnp.full((B, cfg.patch_tokens, cfg.d_model),
                                     0.01, cfg.compute_dtype)
    if cfg.family == "audio":
        b["frames"] = jnp.full((B, cfg.encoder_frames, cfg.d_model), 0.01,
                               cfg.compute_dtype)
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_config(arch, reduced=True)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, grads = jax.jit(jax.value_and_grad(api.loss))(params, batch)
    assert np.isfinite(float(loss)), arch
    assert float(loss) > 0
    for leaf in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(leaf, np.float32)).all(), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode(arch):
    cfg = get_config(arch, reduced=True)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    B, S, cache_len = 2, 8, 32
    batch = _batch(cfg, B, S)
    batch.pop("labels")
    logits, cache = jax.jit(lambda p, b: api.prefill(p, b, cache_len))(
        params, batch)
    assert logits.shape[0] == B and logits.shape[-1] == cfg.vocab
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
    tok = batch["tokens"][:, :1]
    logits2, cache2 = jax.jit(api.decode)(params, cache, tok,
                                          jnp.int32(S))
    assert logits2.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits2, np.float32)).all(), arch


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "rwkv6-1.6b",
                                  "jamba-1.5-large-398b", "whisper-small"])
def test_prefill_decode_consistency(arch):
    """Prefill of N tokens == N single-token decode steps (f32)."""
    cfg = dataclasses.replace(get_config(arch, reduced=True),
                              compute_dtype=jnp.float32,
                              param_dtype=jnp.float32)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(1))
    B, S, cache_len = 1, 8, 16
    batch = _batch(cfg, B, S)
    batch.pop("labels")
    logits_p, _ = api.prefill(params, batch, cache_len)
    cache = api.make_cache(B, cache_len)
    if cfg.family == "audio":
        # decode needs the cross-attention KV: take it from prefill
        _, cache_full = api.prefill(params, batch, cache_len)
        cache["xk"], cache["xv"] = cache_full["xk"], cache_full["xv"]
        cache["k"] = jnp.zeros_like(cache_full["k"])
        cache["v"] = jnp.zeros_like(cache_full["v"])
    logits_d = None
    for t in range(S):
        logits_d, cache = api.decode(params, cache,
                                     batch["tokens"][:, t:t + 1],
                                     jnp.int32(t))
    np.testing.assert_allclose(np.asarray(logits_p, np.float32),
                               np.asarray(logits_d, np.float32),
                               atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("arch,lo,hi", [
    ("qwen3-0.6b", 0.5e9, 1.0e9),
    ("llama3-8b", 7e9, 9e9),
    ("command-r-35b", 30e9, 40e9),
    ("qwen3-moe-235b-a22b", 200e9, 260e9),
    ("jamba-1.5-large-398b", 360e9, 430e9),
    ("rwkv6-1.6b", 1.2e9, 2.0e9),
])
def test_full_config_param_counts(arch, lo, hi):
    """The FULL configs hit their nameplate parameter counts (analytic —
    no allocation; full configs are exercised only via the dry-run)."""
    n = count_params(get_config(arch))
    assert lo <= n <= hi, (arch, n)


def test_moe_capacity_drops_are_bounded():
    """With capacity factor 1.25, > 60% of routed tokens survive dispatch
    (structure check on the combine mask)."""
    from repro.models import moe as moe_lib
    cfg = get_config("qwen3-moe-235b-a22b", reduced=True)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model),
                          cfg.compute_dtype)
    p = jax.tree.map(lambda a: a[0], params["layers"]["moe"])
    y = moe_lib.moe_ffn(p, x, cfg)
    assert y.shape == x.shape
    frac_nonzero = float((jnp.abs(y).sum(-1) > 0).mean())
    assert frac_nonzero > 0.6
