"""Multi-device tests (subprocess: the main pytest process keeps 1 device).

Covers: the shard_map stage pipeline's numerics on a real (fake-device)
mesh, checkpoint reshard-on-restore across meshes, and a small-mesh
train_step lowering with the production sharding rules.
"""

import subprocess
import sys
import textwrap

import pytest


def _run(code: str, devices: int = 4):
    prelude = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count={devices}"
        import sys
        sys.path.insert(0, "src")
    """)
    r = subprocess.run([sys.executable, "-c", prelude + textwrap.dedent(code)],
                       capture_output=True, text=True, cwd=".",
                       timeout=900)
    assert r.returncode == 0 and "PASS" in r.stdout, \
        (r.stdout[-2000:], r.stderr[-3000:])


def test_pipeline_loss_and_grads_match_plain():
    _run("""
        import jax, jax.numpy as jnp, dataclasses
        from repro.configs import get_config
        from repro.models import get_model
        from repro.pipeline import PipelineConfig, make_pipelined_loss
        cfg = dataclasses.replace(get_config("llama3-8b", reduced=True),
                                  num_layers=4, remat="none",
                                  compute_dtype=jnp.float32)
        api = get_model(cfg)
        rng = jax.random.key(0)
        params = api.init(rng)
        batch = {"tokens": jax.random.randint(rng, (8, 16), 0, cfg.vocab),
                 "labels": jax.random.randint(rng, (8, 16), 0, cfg.vocab)}
        from repro.launch.compat import AxisType, make_mesh, set_mesh
        mesh = make_mesh((2, 2), ("data", "stage"),
                         axis_types=(AxisType.Auto,) * 2)
        pcfg = PipelineConfig(num_stages=2, num_microbatches=4)
        with set_mesh(mesh):
            ploss = make_pipelined_loss(cfg, mesh, pcfg)
            lp = float(jax.jit(ploss)(params, batch))
            gp = jax.jit(jax.grad(ploss))(params, batch)
        l0 = float(jax.jit(api.loss)(params, batch))
        g0 = jax.jit(jax.grad(api.loss))(params, batch)
        assert abs(lp - l0) < 1e-5, (lp, l0)
        err = max(jax.tree.leaves(jax.tree.map(
            lambda a, b: float(jnp.max(jnp.abs(a - b))), gp, g0)))
        assert err < 1e-4, err
        print("PASS")
    """)


def test_planner_drives_pipeline_config():
    _run("""
        from repro.configs import get_config, arch_profile
        from repro.core import plan_stages
        from repro.pipeline import plan_to_pipeline_config
        prof = arch_profile(get_config("llama3-8b"))
        sp = plan_stages(prof, total_chips=256, stage_candidates=(2, 4, 8),
                         global_batch=256)
        assert sp.num_stages in (2, 4, 8)
        assert 1 <= sp.microbatch <= 256
        pcfg = plan_to_pipeline_config(sp, 256)
        assert 256 % pcfg.num_microbatches == 0
        assert sp.T_i > 0 and sp.L_t >= sp.T_f
        print("PASS")
    """, devices=1)


def test_checkpoint_reshards_across_meshes():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import save_checkpoint, restore_checkpoint
        import tempfile, os
        d = tempfile.mkdtemp()
        from repro.launch.compat import AxisType, make_mesh
        mesh4 = make_mesh((4,), ("model",),
                          axis_types=(AxisType.Auto,))
        x = jax.device_put(jnp.arange(32.0).reshape(8, 4),
                           NamedSharding(mesh4, P("model", None)))
        save_checkpoint(d, 0, {"x": x})
        mesh2 = make_mesh((2, 2), ("data", "model"),
                          axis_types=(AxisType.Auto,) * 2)
        sh = {"x": NamedSharding(mesh2, P(None, "model"))}
        restored, _ = restore_checkpoint(
            d, 0, jax.eval_shape(lambda: {"x": jnp.zeros((8, 4))}),
            shardings=sh)
        np.testing.assert_array_equal(np.asarray(restored["x"]),
                                      np.asarray(x))
        assert restored["x"].sharding.spec == P(None, "model")
        print("PASS")
    """)


def test_small_mesh_train_step_lowers_with_production_rules():
    """8-device (2 data x 4 model) lowering of the full train_step using
    the same sharding rules as the 512-device dry-run."""
    _run("""
        import jax, jax.numpy as jnp
        from repro.configs import get_config, input_specs, param_specs
        from repro.launch import (ShardingPolicy, batch_sharding,
                                  opt_sharding_tree, param_sharding_tree,
                                  make_train_step)
        from repro.optim import get_optimizer
        import dataclasses
        cfg = get_config("qwen3-0.6b", reduced=True)
        from repro.launch.compat import AxisType, make_mesh, set_mesh
        mesh = make_mesh((2, 4), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
        policy = ShardingPolicy()
        pshapes = param_specs(cfg)
        psh = param_sharding_tree(cfg, mesh, pshapes, policy)
        opt = get_optimizer("adamw")
        oshapes = jax.eval_shape(opt.init, pshapes)
        osh = opt_sharding_tree(mesh, "adamw", psh, pshapes)
        bshapes = {
            "tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
            "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
        bsh = batch_sharding(cfg, mesh, bshapes, policy)
        step = make_train_step(cfg, opt, 2)
        jitted = jax.jit(step, in_shardings=(psh, osh, bsh),
                         out_shardings=(psh, osh, None))
        with set_mesh(mesh):
            compiled = jitted.lower(pshapes, oshapes, bshapes).compile()
        assert compiled.memory_analysis().temp_size_in_bytes > 0
        print("PASS")
    """, devices=8)


def test_elastic_restart_resharded():
    """Train on 4 devices, checkpoint, restore into a 2-device mesh and
    continue — elastic scaling across 'pod' counts."""
    _run("""
        import jax, jax.numpy as jnp, tempfile
        from repro.launch.train import train
        d = tempfile.mkdtemp()
        l1 = train("qwen3-0.6b", reduced=True, steps=4, batch=8, seq=16,
                   microbatches=2, ckpt_dir=d, ckpt_every=2, log_every=100)
        l2 = train("qwen3-0.6b", reduced=True, steps=6, batch=8, seq=16,
                   microbatches=2, ckpt_dir=d, ckpt_every=2, log_every=100)
        assert len(l2) == 2
        print("PASS")
    """, devices=2)
